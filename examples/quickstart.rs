//! Quickstart: train a micro LLaMA with GUM for 100 steps.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use gum::coordinator::{TrainConfig, Trainer};

fn main() -> anyhow::Result<()> {
    let cfg = TrainConfig {
        model: "micro".into(),
        optimizer: "gum".into(), // try "galore-muon", "muon", "adamw", …
        lr: 8e-3,
        steps: 100,
        period_k: 20, // sampling period K (Algorithm 2)
        rank: 16,     // projection rank r
        gamma: 2.0,   // expected full-rank blocks per period
        eval_every: 50,
        ..TrainConfig::default()
    };
    let result = Trainer::new(cfg).run()?;
    println!(
        "\nquickstart done: train loss {:.3}, val loss {:?}, optimizer \
         state {}",
        result.final_train_loss,
        result.final_val_loss,
        gum::optim::bytes_human(result.state_bytes),
    );
    let curve = result.metrics.series("train_loss");
    println!(
        "{}",
        gum::coordinator::metrics::ascii_curve(&curve, 60, 10)
    );
    Ok(())
}
