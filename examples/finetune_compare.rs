//! Fine-tuning comparison (paper Table 2): pretrain a shared base, then
//! fine-tune with AdamW / Muon / GaLore / Fira / GUM on instruction +
//! arithmetic tasks; exact-match evaluation via greedy decoding.
//!
//! ```bash
//! cargo run --release --example finetune_compare -- [--quick]
//! ```

use gum::experiments::{table2, ExpOpts};
use gum::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    table2::run(&ExpOpts::from_args(&args))
}
