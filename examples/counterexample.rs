//! The paper's Figure-1 counterexample as a runnable example: noisy
//! linear regression where GaLore-Muon stalls, GUM converges.
//!
//! ```bash
//! cargo run --release --example counterexample -- [--steps 3000]
//! ```

use gum::experiments::{fig1, ExpOpts};
use gum::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    fig1::run(&ExpOpts::from_args(&args))
}
