//! End-to-end pre-training driver — the full-system validation run
//! (DESIGN.md deliverable (b)/EXPERIMENTS.md §E2E): trains a transformer
//! through all three layers (L1 Pallas kernels and L2 JAX graph compiled
//! to HLO, L3 Rust coordinator with GUM's layerwise sampling), on the
//! synthetic multi-domain corpus, logging the loss curve, validation
//! loss, throughput, and the 7-domain probe suite.
//!
//! ```bash
//! cargo run --release --example pretrain_e2e -- \
//!     [--model tiny] [--optimizer gum] [--steps 400] [--out results/e2e]
//! ```

use std::path::PathBuf;

use gum::coordinator::{TrainConfig, Trainer};
use gum::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let steps = args.get_parse("steps", 400usize);
    let cfg = TrainConfig {
        model: args.get_or("model", "tiny").to_string(),
        optimizer: args.get_or("optimizer", "gum").to_string(),
        lr: args.get_parse("lr", 6e-3),
        steps,
        period_k: args.get_parse("period-k", 50usize),
        rank: args.get_parse("rank", 32usize),
        gamma: args.get_parse("gamma", 2.0f64),
        seed: args.get_parse("seed", 0u64),
        warmup: steps / 20,
        eval_every: (steps / 8).max(1),
        eval_batches: 8,
        ckpt_every: 0,
        probes: true,
        probe_items: 32,
        artifacts_dir: PathBuf::from(args.get_or("artifacts", "artifacts")),
        out_dir: Some(PathBuf::from(args.get_or("out", "results/e2e"))),
        log_every: 20,
        ..TrainConfig::default()
    };
    println!(
        "=== end-to-end pretraining: {} / {} / {} steps ===",
        cfg.model, cfg.optimizer, cfg.steps
    );
    let result = Trainer::new(cfg).run()?;

    println!("\n--- loss curve ---");
    let curve = result.metrics.series("train_loss");
    println!(
        "{}",
        gum::coordinator::metrics::ascii_curve(&curve, 70, 12)
    );
    println!("final train loss: {:.4}", result.final_train_loss);
    if let Some(v) = result.final_val_loss {
        println!("final val loss:   {v:.4}");
    }
    let tput = result.metrics.tail_mean("tokens_per_s", 50).unwrap_or(0.0);
    println!("throughput (tail mean): {tput:.0} tokens/s");
    println!("optimizer state: {}", gum::optim::bytes_human(result.state_bytes));
    println!("\n7-domain probe suite (chance 25%):");
    let mut avg = 0.0;
    for (d, acc) in &result.probe_scores {
        println!("  {d:<16} {:>6.1}%", acc * 100.0);
        avg += acc / result.probe_scores.len() as f64;
    }
    println!("  {:<16} {:>6.1}%", "AVG", avg * 100.0);
    println!("\nmetrics written to results/e2e/metrics.csv");
    Ok(())
}
