//! Memory accounting report: Table 1 (space complexity) + Table 3 (peak
//! memory for the paper's 7–9B models) + measured small-scale states.
//!
//! ```bash
//! cargo run --release --example memory_report
//! ```

use gum::experiments::{table1, table3, ExpOpts};
use gum::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let opts = ExpOpts::from_args(&args);
    table1::run(&opts)?;
    println!();
    table3::run(&opts)
}
