//! Weight-spectrum + activation-tail analysis (paper Figs. 3 & 5):
//! trains GaLore and GUM (or reuses checkpoints under
//! results/fig3/<method>/final.bin) and compares singular-value
//! distributions, stable ranks and salient-activation tails.
//!
//! ```bash
//! cargo run --release --example spectrum_analysis -- [--quick]
//! ```

use gum::experiments::{fig3, ExpOpts};
use gum::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    fig3::run(&ExpOpts::from_args(&args))
}
