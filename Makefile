# Convenience targets. `artifacts` runs the Python AOT compile path
# (L1 Pallas kernels + L2 model graphs → artifacts/ HLO text +
# manifest.json); everything else is plain cargo.

.PHONY: artifacts build test test-release test-faults bench bench-smoke fmt lint clean

artifacts:
	cd python && python3 -m compile.aot --out ../artifacts

build:
	cargo build --release

test:
	cargo test -q

# Release-optimization tests with debug-assertions kept on (the
# profile CI runs so svd_thin/gemm debug_assert guards stay exercised).
test-release:
	cargo test --profile release-test -q

# Just the fault-injection / recovery suites (elastic determinism and
# checkpoint corruption). Failing cases drop replayable plan specs in
# target/fault-plans/.
test-faults:
	cargo test -q --test elastic_recovery --test checkpoint_robustness

# Full bench sweep with machine-readable output: the linalg GEMM sweep
# refreshes BENCH_gemm.json (the checked-in baseline) and the
# train-throughput run writes BENCH_projector.json (local, not
# committed). Remaining bench binaries run without a JSON path (their
# stats print only; pass GUM_BENCH_JSON to dump them too).
bench:
	GUM_BENCH_JSON=BENCH_gemm.json cargo bench --bench linalg
	GUM_BENCH_JSON=BENCH_projector.json cargo bench --bench train_throughput
	cargo bench --bench optim_step
	cargo bench --bench runtime_exec

# CI's smoke slice of the same pipeline (tiny shapes, JSON to *_smoke).
bench-smoke:
	GUM_BENCH_FILTER=smoke GUM_BENCH_JSON=BENCH_gemm_smoke.json \
		cargo bench --bench linalg
	GUM_BENCH_FILTER=projector_refresh/smoke \
		GUM_BENCH_JSON=BENCH_projector_smoke.json \
		cargo bench --bench train_throughput

fmt:
	cargo fmt

lint:
	cargo clippy --all-targets -- -D warnings

clean:
	cargo clean
	rm -rf artifacts results
