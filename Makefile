# Convenience targets. `artifacts` runs the Python AOT compile path
# (L1 Pallas kernels + L2 model graphs → artifacts/ HLO text +
# manifest.json); everything else is plain cargo.

.PHONY: artifacts build test test-release test-faults test-rank test-period test-tune test-reduce test-dtype bench bench-smoke bench-optim bench-gate bench-gate-accept doc fmt lint clean

artifacts:
	cd python && python3 -m compile.aot --out ../artifacts

build:
	cargo build --release

test:
	cargo test -q

# Release-optimization tests with debug-assertions kept on (the
# profile CI runs so svd_thin/gemm debug_assert guards stay exercised).
test-release:
	cargo test --profile release-test -q

# Just the fault-injection / recovery suites (elastic determinism and
# checkpoint corruption). Failing cases drop replayable plan specs in
# target/fault-plans/.
test-faults:
	cargo test -q --test elastic_recovery --test checkpoint_robustness

# The adaptive rank-schedule matrix: controller properties, sync≡async
# with adaptive ranks, thread-width/replica determinism, plus the
# rank-aware resume and fault cases in the other suites.
test-rank:
	cargo test -q --test rank_schedule
	cargo test -q --test checkpoint_robustness rank
	cargo test -q --test elastic_recovery adaptive

# The GEMM autotuner matrix: off-mode bitwise identity to the fixed
# tiling across thread widths, cache round-trip + warm-reload (zero
# re-searches), corrupt-cache silent fallback, plus the kernel-variant
# unit tests inside the linalg module.
test-tune:
	cargo test -q --test tune_cache
	cargo test -q --lib -- linalg::tune linalg::gemm

# The compressed all-reduce matrix (`--reduce lowrank`): wire-order
# spec, thread-width/sync-async bitwise invariance, dense-vs-lowrank
# round-off parity across replica splits and adaptive rank/period
# boundaries, and lane-kill replays — plus the combine/plan unit tests
# inside the coordinator module.
test-reduce:
	cargo test -q --test reduce_compression
	cargo test -q --lib -- coordinator::parallel

# The adaptive refresh-period matrix: sync≡async with variable
# boundaries, thread-width/replica determinism, mid-period resume after
# a period change, lane kills at a shrunk boundary, plus the PERIODS
# checkpoint section and tmp-sweep cases in the other suites.
test-period:
	cargo test -q --test period_schedule
	cargo test -q --lib -- period orphaned_tmp

# The reduced-precision state matrix (`--state-dtype bf16|f16`):
# bf16/f16 conversion exactness (every 16-bit pattern + RTNE ties),
# fused lowp kernels vs f64 references at odd/unaligned lengths,
# thread-width and replica/sync-async bitwise invariance of bf16
# trajectories, DTYPE-tagged checkpoint round-trips + mismatch
# rejection, and f32-vs-bf16 loss parity — plus the pack/unpack and
# MomentBuf unit tests inside the linalg module.
test-dtype:
	cargo test -q --test state_dtype
	cargo test -q --lib -- linalg::lowp

# Full bench sweep with machine-readable output: the linalg GEMM sweep
# refreshes BENCH_gemm.json and the optimizer-step run BENCH_optim.json
# (both checked-in baselines); the train-throughput run writes
# BENCH_projector.json (local, not committed). Remaining bench binaries
# run without a JSON path (their stats print only; pass GUM_BENCH_JSON
# to dump them too).
bench:
	GUM_BENCH_JSON=BENCH_gemm.json cargo bench --bench linalg
	GUM_BENCH_JSON=BENCH_projector.json cargo bench --bench train_throughput
	GUM_BENCH_JSON=BENCH_optim.json cargo bench --bench optim_step
	cargo bench --bench runtime_exec

# Refresh just the optimizer-step baseline (fused-vs-scalar elementwise
# and sync-vs-async refresh stall rows included).
bench-optim:
	GUM_BENCH_JSON=BENCH_optim.json cargo bench --bench optim_step

# CI's smoke slice of the same pipeline (tiny shapes, JSON to *_smoke).
bench-smoke:
	GUM_BENCH_FILTER=smoke GUM_BENCH_JSON=BENCH_gemm_smoke.json \
		cargo bench --bench linalg
	GUM_BENCH_FILTER=projector_refresh/smoke \
		GUM_BENCH_JSON=BENCH_projector_smoke.json \
		cargo bench --bench train_throughput
	GUM_BENCH_FILTER=reduce_bytes/smoke \
		GUM_BENCH_JSON=BENCH_reduce_smoke.json \
		cargo bench --bench train_throughput
	GUM_BENCH_FILTER=step_elementwise \
		GUM_BENCH_JSON=BENCH_optim_smoke.json \
		cargo bench --bench optim_step
	GUM_BENCH_FILTER=rank_schedule \
		GUM_BENCH_JSON=BENCH_rank_schedule_smoke.json \
		cargo bench --bench optim_step
	GUM_BENCH_FILTER=period_schedule \
		GUM_BENCH_JSON=BENCH_period_schedule_smoke.json \
		cargo bench --bench optim_step
	GUM_BENCH_FILTER=state_dtype \
		GUM_BENCH_JSON=BENCH_state_dtype_smoke.json \
		cargo bench --bench optim_step

# Regression gate: regenerate fresh bench JSON into target/bench-gate/
# and compare each suite against its checked-in baseline with a relative
# tolerance (non-gating in CI — annotations only; locally it exits 1 on
# a regression so it can anchor a bisect).
bench-gate:
	mkdir -p target/bench-gate
	GUM_BENCH_JSON=target/bench-gate/BENCH_gemm.json cargo bench --bench linalg
	GUM_BENCH_JSON=target/bench-gate/BENCH_optim.json cargo bench --bench optim_step
	cargo run --release -- bench-gate --baseline BENCH_gemm.json \
		--fresh target/bench-gate/BENCH_gemm.json --tolerance 0.5
	cargo run --release -- bench-gate --baseline BENCH_optim.json \
		--fresh target/bench-gate/BENCH_optim.json --tolerance 0.5

# The *gating* acceptance checks CI runs on every push: regenerate just
# the acceptance rows and gate their self-relative speedups at the
# floors characterized in EXPERIMENTS.md §Perf — packed-vs-legacy GEMM
# (1024×4096 r128 NT/TN, ≥1.35×) and the fused-vs-scalar elementwise
# step (step_elementwise, ≥1.3×). Self-relative ratios cancel runner
# speed, so these stay hard gates even on noisy shared runners.
bench-gate-accept:
	mkdir -p target/bench-gate
	GUM_BENCH_FILTER=1024x4096_r128 \
		GUM_BENCH_JSON=target/bench-gate/BENCH_gemm_accept.json \
		cargo bench --bench linalg
	cargo run --release -- bench-gate \
		--fresh target/bench-gate/BENCH_gemm_accept.json \
		--speedup-floor 1.35 \
		--speedup-cases nt_1024x4096_r128,tn_1024x4096_r128
	GUM_BENCH_FILTER=step_elementwise \
		GUM_BENCH_JSON=target/bench-gate/BENCH_optim_accept.json \
		cargo bench --bench optim_step
	cargo run --release -- bench-gate \
		--fresh target/bench-gate/BENCH_optim_accept.json \
		--speedup-floor 1.3 \
		--speedup-cases step_elementwise

# Rustdoc as CI checks it: warnings (broken intra-doc links included)
# are errors.
doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

fmt:
	cargo fmt

lint:
	cargo clippy --all-targets -- -D warnings

clean:
	cargo clean
	rm -rf artifacts results
