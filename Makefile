# Convenience targets. `artifacts` runs the Python AOT compile path
# (L1 Pallas kernels + L2 model graphs → artifacts/ HLO text +
# manifest.json); everything else is plain cargo.

.PHONY: artifacts build test bench fmt lint clean

artifacts:
	cd python && python3 -m compile.aot --out ../artifacts

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

fmt:
	cargo fmt

lint:
	cargo clippy --all-targets -- -D warnings

clean:
	cargo clean
	rm -rf artifacts results
