"""L2 model correctness: shapes, masking, gradients vs finite differences."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.configs import ModelConfig, MICRO

# A deliberately tiny config for finite-difference gradient checking.
NANO = ModelConfig("nano", vocab=17, dim=8, n_layers=1, n_heads=2, ffn=16,
                   seq_len=6, batch=2)


def _setup(cfg, seed=0):
    key = jax.random.PRNGKey(seed)
    params = model.init_params(cfg, key)
    rng = np.random.default_rng(seed)
    tokens = rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq_len))
    targets = rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq_len))
    return params, jnp.array(tokens, jnp.int32), jnp.array(targets, jnp.int32)


class TestShapes:
    def test_param_blocks_count(self):
        blocks = NANO.param_blocks()
        assert len(blocks) == 3 + 9 * NANO.n_layers
        names = [n for n, _ in blocks]
        assert names[0] == "embed" and names[-1] == "lm_head"
        assert len(set(names)) == len(names)

    def test_forward_logits_shape(self):
        params, tokens, _ = _setup(NANO)
        logits = model.forward(NANO, params, tokens)
        assert logits.shape == (NANO.batch, NANO.seq_len, NANO.vocab)
        assert bool(jnp.isfinite(logits).all())

    def test_loss_outputs(self):
        params, tokens, targets = _setup(NANO)
        loss, per_ex = model.loss_fn(NANO, params, tokens, targets)
        assert loss.shape == ()
        assert per_ex.shape == (NANO.batch,)
        assert float(loss) > 0

    def test_n_params_micro(self):
        # embed 256*64 + head 64*256 + final norm 64 + per-layer blocks
        per_layer = 2 * 64 + 4 * 64 * 64 + 3 * 64 * 192
        expect = 2 * 256 * 64 + 64 + 2 * per_layer
        assert MICRO.n_params() == expect


class TestMasking:
    def test_negative_targets_masked(self):
        params, tokens, targets = _setup(NANO)
        # Mask all of example 1 except position 0.
        t2 = np.array(targets)
        t2[1, 1:] = -1
        loss_a, per_a = model.loss_fn(NANO, params, tokens, jnp.array(t2))
        # per-example NLL of example 1 must equal NLL at position 0 only.
        logits = model.forward(NANO, params, tokens)
        logp = jax.nn.log_softmax(logits, axis=-1)
        want = -logp[1, 0, int(t2[1, 0])]
        np.testing.assert_allclose(float(per_a[1]), float(want), rtol=1e-5)

    def test_all_masked_is_finite(self):
        params, tokens, targets = _setup(NANO)
        t2 = -np.ones_like(np.array(targets))
        loss, per_ex = model.loss_fn(NANO, params, tokens, jnp.array(t2))
        assert np.isfinite(float(loss))
        assert np.isfinite(np.array(per_ex)).all()


class TestGradients:
    def test_grad_matches_finite_difference(self):
        params, tokens, targets = _setup(NANO)
        grad_fn = model.make_grad(NANO)
        out = grad_fn(*params, tokens, targets)
        loss, grads = out[0], out[1:]
        assert len(grads) == len(params)

        def scalar(ps):
            return float(model.loss_fn(NANO, ps, tokens, targets)[0])

        rng = np.random.default_rng(1)
        eps = 1e-3
        # Spot-check a few coordinates in a few blocks.
        for bi in [0, 2, len(params) - 1]:
            p = np.array(params[bi])
            g = np.array(grads[bi])
            flat_idx = rng.integers(0, p.size, 3)
            for fi in flat_idx:
                idx = np.unravel_index(fi, p.shape)
                pp = p.copy()
                pp[idx] += eps
                plus = scalar(params[:bi] + [jnp.array(pp)] +
                              params[bi + 1:])
                pp[idx] -= 2 * eps
                minus = scalar(params[:bi] + [jnp.array(pp)] +
                               params[bi + 1:])
                fd = (plus - minus) / (2 * eps)
                np.testing.assert_allclose(g[idx], fd, rtol=0.1, atol=5e-3)

    def test_grad_loss_matches_fwd_loss(self):
        params, tokens, targets = _setup(NANO)
        fwd = model.make_fwd(NANO)
        grad_fn = model.make_grad(NANO)
        l1 = float(fwd(*params, tokens, targets)[0])
        l2 = float(grad_fn(*params, tokens, targets)[0])
        np.testing.assert_allclose(l1, l2, rtol=1e-6)


class TestCausality:
    def test_future_tokens_do_not_affect_past_logits(self):
        params, tokens, _ = _setup(NANO)
        logits_a = np.array(model.forward(NANO, params, tokens))
        t2 = np.array(tokens)
        t2[:, -1] = (t2[:, -1] + 1) % NANO.vocab  # perturb last token
        logits_b = np.array(model.forward(NANO, params, jnp.array(t2)))
        # All positions before the perturbed one are identical.
        np.testing.assert_allclose(
            logits_a[:, :-1], logits_b[:, :-1], rtol=1e-5, atol=1e-5
        )
        # The perturbed position itself must change.
        assert np.abs(logits_a[:, -1] - logits_b[:, -1]).max() > 1e-4

    def test_rope_preserves_norm(self):
        cos, sin = model.rope_tables(NANO)
        x = np.random.default_rng(0).standard_normal(
            (2, NANO.n_heads, NANO.seq_len, NANO.head_dim)
        ).astype(np.float32)
        rx = np.array(model.apply_rope(jnp.array(x), cos, sin))
        np.testing.assert_allclose(
            np.linalg.norm(rx, axis=-1),
            np.linalg.norm(x, axis=-1),
            rtol=1e-4,
        )

    def test_rope_position_zero_is_identity(self):
        cos, sin = model.rope_tables(NANO)
        x = np.random.default_rng(1).standard_normal(
            (1, NANO.n_heads, NANO.seq_len, NANO.head_dim)
        ).astype(np.float32)
        rx = np.array(model.apply_rope(jnp.array(x), cos, sin))
        np.testing.assert_allclose(rx[:, :, 0], x[:, :, 0], atol=1e-6)


class TestPallasIntegration:
    def test_pallas_lmhead_matches_plain(self):
        params, tokens, _ = _setup(NANO)
        a = model.forward(NANO, params, tokens, use_pallas_lmhead=False)
        b = model.forward(NANO, params, tokens, use_pallas_lmhead=True)
        np.testing.assert_allclose(
            np.array(a), np.array(b), rtol=1e-4, atol=1e-4
        )


class TestTrainingSignal:
    def test_loss_decreases_under_sgd(self):
        """A handful of SGD steps on a fixed batch must reduce the loss —
        catches sign errors anywhere in fwd/bwd."""
        params, tokens, targets = _setup(NANO, seed=3)
        grad_fn = jax.jit(model.make_grad(NANO))
        losses = []
        for _ in range(8):
            out = grad_fn(*params, tokens, targets)
            losses.append(float(out[0]))
            grads = out[1:]
            params = [p - 0.5 * g for p, g in zip(params, grads)]
        assert losses[-1] < losses[0] * 0.9, losses
