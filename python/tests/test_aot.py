"""AOT lowering smoke tests: HLO text emitted, manifest coherent."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from compile import aot, configs, model


class TestHloText:
    def test_fwd_lowers_to_hlo_text(self):
        cfg = configs.get("micro")
        fn = model.make_fwd(cfg)
        lowered = jax.jit(fn).lower(*model.example_args(cfg))
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text
        assert "HloModule" in text

    def test_ns_kernel_lowers(self):
        from compile.kernels.newton_schulz import newton_schulz
        spec = jax.ShapeDtypeStruct((64, 192), jnp.float32)
        lowered = jax.jit(lambda g: (newton_schulz(g),)).lower(spec)
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text


class TestManifest:
    @pytest.fixture(scope="class")
    def out_dir(self, tmp_path_factory):
        out = tmp_path_factory.mktemp("artifacts")
        entries = []
        cfg = configs.get("micro")
        aot.lower_model(cfg, str(out), entries, "fwd")
        aot.lower_ns(16, 32, str(out), entries)
        aot.lower_lowrank(16, 32, 4, str(out), entries)
        manifest = {"version": aot.MANIFEST_VERSION, "entries": entries}
        with open(out / "manifest.json", "w") as f:
            json.dump(manifest, f)
        return out

    def test_entries_reference_existing_files(self, out_dir):
        with open(out_dir / "manifest.json") as f:
            manifest = json.load(f)
        assert manifest["entries"]
        for e in manifest["entries"]:
            assert (out_dir / e["path"]).exists(), e["path"]

    def test_model_entry_io_specs(self, out_dir):
        with open(out_dir / "manifest.json") as f:
            manifest = json.load(f)
        e = [x for x in manifest["entries"] if x["kind"] == "model_fwd"][0]
        cfg = configs.get("micro")
        blocks = cfg.param_blocks()
        assert len(e["inputs"]) == len(blocks) + 2
        assert e["inputs"][-2]["name"] == "tokens"
        assert e["inputs"][-2]["dtype"] == "i32"
        for inp, (name, shape) in zip(e["inputs"], blocks):
            assert inp["name"] == name
            assert tuple(inp["shape"]) == tuple(shape)

    def test_fingerprint_stable(self):
        assert aot.input_fingerprint() == aot.input_fingerprint()
