"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (and the f32/bf16 dtypes the kernels support);
numpy.testing.assert_allclose against ref.py is the core signal.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.matmul import matmul, matmul_nt, matmul_tn, _block_edge
from compile.kernels.newton_schulz import newton_schulz
from compile.kernels.lowrank import project, project_back, debias_residual

RNG = np.random.default_rng(0)

dims = st.integers(min_value=1, max_value=96)
small_dims = st.integers(min_value=2, max_value=48)


def _rand(*shape, dtype=np.float32):
    return RNG.standard_normal(shape).astype(dtype)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

class TestMatmul:
    @settings(max_examples=40, deadline=None)
    @given(m=dims, k=dims, n=dims)
    def test_matches_ref_f32(self, m, k, n):
        x, y = _rand(m, k), _rand(k, n)
        got = np.array(matmul(jnp.array(x), jnp.array(y)))
        want = np.array(ref.matmul_ref(jnp.array(x), jnp.array(y)))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(m=small_dims, k=small_dims, n=small_dims)
    def test_matches_ref_bf16(self, m, k, n):
        x = jnp.array(_rand(m, k)).astype(jnp.bfloat16)
        y = jnp.array(_rand(k, n)).astype(jnp.bfloat16)
        got = np.array(matmul(x, y).astype(jnp.float32))
        want = np.array(
            jnp.dot(x, y, preferred_element_type=jnp.float32)
        )
        np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)

    @pytest.mark.parametrize("block", [8, 32, 64, 128, 256])
    def test_block_sweep(self, block):
        # atol covers accumulation-order differences across tilings.
        x, y = _rand(96, 160), _rand(160, 64)
        got = np.array(matmul(jnp.array(x), jnp.array(y), block=block))
        np.testing.assert_allclose(got, x @ y, rtol=1e-4, atol=1e-4)

    def test_non_divisible_shapes(self):
        x, y = _rand(97, 131), _rand(131, 53)
        got = np.array(matmul(jnp.array(x), jnp.array(y)))
        np.testing.assert_allclose(got, x @ y, rtol=1e-5, atol=1e-5)

    def test_transposed_variants(self):
        x, y = _rand(24, 40), _rand(24, 40)
        nt = np.array(matmul_nt(jnp.array(x), jnp.array(y)))
        np.testing.assert_allclose(nt, x @ y.T, rtol=1e-5, atol=1e-5)
        tn = np.array(matmul_tn(jnp.array(x), jnp.array(y)))
        np.testing.assert_allclose(tn, x.T @ y, rtol=1e-5, atol=1e-5)

    @given(d=st.integers(1, 300), b=st.integers(1, 256))
    @settings(max_examples=50, deadline=None)
    def test_block_edge_divides(self, d, b):
        e = _block_edge(d, b)
        assert 1 <= e <= min(d, b)
        assert d % e == 0


# ---------------------------------------------------------------------------
# Newton–Schulz
# ---------------------------------------------------------------------------

class TestNewtonSchulz:
    @settings(max_examples=25, deadline=None)
    @given(m=small_dims, n=small_dims)
    def test_matches_ref(self, m, n):
        g = _rand(m, n)
        got = np.array(newton_schulz(jnp.array(g)))
        want = np.array(ref.newton_schulz_ref(jnp.array(g)))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_approximates_msign(self):
        # After 5 quintic iterations singular values land in ~[0.7, 1.3]
        # (Jordan et al.); check directional agreement with exact msign.
        g = _rand(32, 64)
        got = np.array(newton_schulz(jnp.array(g)))
        exact = np.array(ref.msign_exact(jnp.array(g)))
        # Inner product per unit norm close to 1:
        cos = (got * exact).sum() / (
            np.linalg.norm(got) * np.linalg.norm(exact)
        )
        assert cos > 0.98

    def test_singular_values_near_one(self):
        g = _rand(48, 48)
        out = np.array(newton_schulz(jnp.array(g), steps=8))
        sv = np.linalg.svd(out, compute_uv=False)
        assert sv.max() < 1.5 and sv.min() > 0.5

    def test_tall_matrix_transposes(self):
        g = _rand(96, 24)
        got = np.array(newton_schulz(jnp.array(g)))
        want = np.array(ref.newton_schulz_ref(jnp.array(g)))
        assert got.shape == (96, 24)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_scale_invariance(self):
        # msign is scale-invariant; NS pre-normalizes so scaling the input
        # must not change the output materially.
        g = _rand(24, 40)
        a = np.array(newton_schulz(jnp.array(g)))
        b = np.array(newton_schulz(jnp.array(100.0 * g)))
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Low-rank projection ops
# ---------------------------------------------------------------------------

def _ortho(m, r):
    q, _ = np.linalg.qr(RNG.standard_normal((m, r)))
    return q.astype(np.float32)


class TestLowRank:
    @settings(max_examples=25, deadline=None)
    @given(m=small_dims, n=small_dims, r=st.integers(1, 16))
    def test_project(self, m, n, r):
        r = min(r, m)
        p, g = _ortho(m, r), _rand(m, n)
        got = np.array(project(jnp.array(p), jnp.array(g)))
        np.testing.assert_allclose(got, p.T @ g, rtol=1e-5, atol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(m=small_dims, n=small_dims, r=st.integers(1, 16))
    def test_project_back(self, m, n, r):
        r = min(r, m)
        p, rr = _ortho(m, r), _rand(r, n)
        got = np.array(project_back(jnp.array(p), jnp.array(rr)))
        np.testing.assert_allclose(got, p @ rr, rtol=1e-5, atol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(m=small_dims, n=small_dims, r=st.integers(1, 16),
           scale=st.floats(0.1, 10.0))
    def test_debias_residual(self, m, n, r, scale):
        r = min(r, m)
        p, g = _ortho(m, r), _rand(m, n)
        got = np.array(
            debias_residual(jnp.array(p), jnp.array(g), jnp.float32(scale))
        )
        want = scale * (g - p @ (p.T @ g))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_unbiasedness_identity(self):
        # q·(1/q)(I-PPᵀ)G + (1-q)·(1/(1-q))PPᵀG == G  (Lemma 2 algebra)
        m, n, r, q = 32, 48, 8, 0.25
        p, g = _ortho(m, r), _rand(m, n)
        full = np.array(
            debias_residual(jnp.array(p), jnp.array(g), jnp.float32(1 / q))
        )
        low = np.array(
            project_back(
                jnp.array(p),
                project(jnp.array(p), jnp.array(g)),
            )
        ) / (1 - q)
        recon = q * full + (1 - q) * low
        np.testing.assert_allclose(recon, g, rtol=1e-4, atol=1e-4)

    def test_ns_on_orthogonal_input_preserves_direction(self):
        # msign(Q) = Q for orthogonal Q. The quintic NS lands singular
        # values in the documented ~[0.7, 1.3] band (Jordan et al.), so
        # assert direction (per-column alignment), not exact identity.
        q, _ = np.linalg.qr(RNG.standard_normal((24, 24)))
        q = q.astype(np.float32)
        out = np.array(newton_schulz(jnp.array(q), steps=8))
        cos = (out * q).sum() / (
            np.linalg.norm(out) * np.linalg.norm(q)
        )
        assert cos > 0.995, cos
        sv = np.linalg.svd(out, compute_uv=False)
        assert sv.min() > 0.6 and sv.max() < 1.4

    def test_ns_commutes_with_orthonormal_projection(self):
        # Property II behind GUM's Lemma 1: NS(P X) = P NS(X).
        p = _ortho(32, 8)
        x = _rand(8, 40)
        left = np.array(newton_schulz(jnp.array(p @ x)))
        right = p @ np.array(newton_schulz(jnp.array(x)))
        np.testing.assert_allclose(left, right, rtol=1e-3, atol=1e-3)

    def test_projector_orthonormal_ref(self):
        g = _rand(32, 64)
        p = np.array(ref.galore_projector_ref(jnp.array(g), 8))
        np.testing.assert_allclose(p.T @ p, np.eye(8), atol=1e-5)
