"""AOT lowering: JAX entry points → HLO *text* artifacts + manifest.json.

HLO text (NOT ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids that xla_extension 0.5.1 (the
version behind the published ``xla`` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Artifacts produced (under --out, default ../artifacts):

- ``model_fwd_<cfg>.hlo.txt``   (params…, tokens, targets) → (loss, nll[B])
- ``model_grad_<cfg>.hlo.txt``  (params…, tokens, targets) → (loss, grads…)
- ``ns_<m>x<n>.hlo.txt``        Newton–Schulz msign over an m×n matrix
                                (L1 Pallas kernel lowered into the graph)
- ``project_<m>x<n>_r<r>.hlo.txt``        R = Pᵀ G
- ``project_back_<m>x<n>_r<r>.hlo.txt``   U = P R
- ``debias_<m>x<n>_r<r>.hlo.txt``         D = s · (G − P Pᵀ G)
- ``manifest.json``  — entry-point index: path, input/output specs, param
  block order. Parsed by rust/src/runtime/artifacts.rs.

Usage:  cd python && python -m compile.aot --out ../artifacts \
            [--configs micro,tiny] [--ns-shapes 64x192,128x384] [--force]
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, model
from .kernels import newton_schulz as ns_mod
from .kernels import lowrank

MANIFEST_VERSION = 1


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return {"shape": list(shape), "dtype": str(dtype)}


def lower_model(cfg, out_dir, entries, which):
    """Lower model_fwd / model_grad / model_logits for one config."""
    fn = {
        "fwd": model.make_fwd,
        "grad": model.make_grad,
        "logits": model.make_logits,
    }[which](cfg)
    args = model.example_args(cfg)
    if which == "logits":
        args = args[:-1]  # no targets
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    name = f"model_{which}_{cfg.name}"
    path = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(text)

    blocks = cfg.param_blocks()
    inputs = [
        {"name": n, **_spec(s, "f32")} for n, s in blocks
    ] + [
        {"name": "tokens", **_spec((cfg.batch, cfg.seq_len), "i32")},
    ]
    if which != "logits":
        inputs.append(
            {"name": "targets", **_spec((cfg.batch, cfg.seq_len), "i32")}
        )
    if which == "fwd":
        outputs = [
            {"name": "loss", **_spec((), "f32")},
            {"name": "per_example_nll", **_spec((cfg.batch,), "f32")},
        ]
    elif which == "logits":
        outputs = [
            {
                "name": "logits",
                **_spec((cfg.batch, cfg.seq_len, cfg.vocab), "f32"),
            }
        ]
    else:
        outputs = [{"name": "loss", **_spec((), "f32")}] + [
            {"name": f"grad.{n}", **_spec(s, "f32")} for n, s in blocks
        ]
    entries.append(
        {
            "name": name,
            "path": path,
            "kind": f"model_{which}",
            "config": cfg.to_dict(),
            "inputs": inputs,
            "outputs": outputs,
        }
    )
    print(f"  wrote {path} ({len(text)} chars)")


def lower_ns(m, n, out_dir, entries):
    """Lower the L1 Newton–Schulz kernel for an m×n block."""
    spec = jax.ShapeDtypeStruct((m, n), jnp.float32)
    lowered = jax.jit(
        lambda g: (ns_mod.newton_schulz(g),)
    ).lower(spec)
    name = f"ns_{m}x{n}"
    path = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(to_hlo_text(lowered))
    entries.append(
        {
            "name": name,
            "path": path,
            "kind": "newton_schulz",
            "inputs": [{"name": "g", **_spec((m, n), "f32")}],
            "outputs": [{"name": "msign", **_spec((m, n), "f32")}],
        }
    )
    print(f"  wrote {path}")


def lower_lowrank(m, n, r, out_dir, entries):
    """Lower project / project_back / debias kernels for (m, n, r)."""
    p_spec = jax.ShapeDtypeStruct((m, r), jnp.float32)
    g_spec = jax.ShapeDtypeStruct((m, n), jnp.float32)
    r_spec = jax.ShapeDtypeStruct((r, n), jnp.float32)
    s_spec = jax.ShapeDtypeStruct((), jnp.float32)

    for name, fn, ins, outs in [
        (
            f"project_{m}x{n}_r{r}",
            lambda p, g: (lowrank.project(p, g),),
            [("p", p_spec), ("g", g_spec)],
            [("r", (r, n))],
        ),
        (
            f"project_back_{m}x{n}_r{r}",
            lambda p, rr: (lowrank.project_back(p, rr),),
            [("p", p_spec), ("r", r_spec)],
            [("u", (m, n))],
        ),
        (
            f"debias_{m}x{n}_r{r}",
            lambda p, g, s: (lowrank.debias_residual(p, g, s),),
            [("p", p_spec), ("g", g_spec), ("scale", s_spec)],
            [("d", (m, n))],
        ),
    ]:
        lowered = jax.jit(fn).lower(*[s for _, s in ins])
        path = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(to_hlo_text(lowered))
        entries.append(
            {
                "name": name,
                "path": path,
                "kind": name.split("_")[0],
                "inputs": [
                    {"name": nm, **_spec(s.shape, "f32" if s.dtype ==
                                         jnp.float32 else str(s.dtype))}
                    for nm, s in ins
                ],
                "outputs": [
                    {"name": nm, **_spec(sh, "f32")} for nm, sh in outs
                ],
            }
        )
        print(f"  wrote {path}")


def input_fingerprint() -> str:
    """Hash of the compile-path sources; lets `make artifacts` skip
    regeneration when nothing changed."""
    h = hashlib.sha256()
    base = os.path.dirname(os.path.abspath(__file__))
    for root, _, files in os.walk(base):
        for fn in sorted(files):
            if fn.endswith(".py"):
                with open(os.path.join(root, fn), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()[:16]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="micro,tiny",
                    help="comma-separated model configs to lower")
    ap.add_argument("--ns-shapes", default="",
                    help="extra mxn shapes for standalone NS artifacts")
    ap.add_argument("--lowrank-shapes", default="",
                    help="extra mxn_r shapes, e.g. 128x384_32")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    fp = input_fingerprint()
    stamp = os.path.join(args.out, ".fingerprint")
    req = f"{fp}|{args.configs}|{args.ns_shapes}|{args.lowrank_shapes}"
    if not args.force and os.path.exists(stamp):
        with open(stamp) as f:
            if f.read().strip() == req:
                print("artifacts up to date (fingerprint match); "
                      "use --force to regenerate")
                return

    entries = []
    cfg_names = [c for c in args.configs.split(",") if c]
    for cname in cfg_names:
        cfg = configs.get(cname)
        print(f"lowering model '{cname}' "
              f"({cfg.n_params()/1e6:.2f}M params)…")
        lower_model(cfg, args.out, entries, "fwd")
        lower_model(cfg, args.out, entries, "grad")
        lower_model(cfg, args.out, entries, "logits")
        # Optimizer kernels sized for this config's projectable blocks:
        dims = sorted({(cfg.dim, cfg.dim), (cfg.dim, cfg.ffn),
                       (cfg.ffn, cfg.dim)})
        for (m, n) in dims:
            lower_ns(m, n, args.out, entries)
            r = max(2, min(m, n) // 4)
            lower_lowrank(m, n, r, args.out, entries)

    for s in [x for x in args.ns_shapes.split(",") if x]:
        m, n = (int(v) for v in s.split("x"))
        lower_ns(m, n, args.out, entries)
    for s in [x for x in args.lowrank_shapes.split(",") if x]:
        mn, r = s.split("_")
        m, n = (int(v) for v in mn.split("x"))
        lower_lowrank(m, n, int(r), args.out, entries)

    manifest = {
        "version": MANIFEST_VERSION,
        "fingerprint": fp,
        "jax_version": jax.__version__,
        "entries": entries,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    with open(stamp, "w") as f:
        f.write(req)
    print(f"manifest: {len(entries)} entries → "
          f"{os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
