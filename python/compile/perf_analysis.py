"""L1/L2 performance analysis (build-time): BlockSpec VMEM footprints and
MXU-utilization estimates for the Pallas kernels, plus XLA cost analysis
of the lowered L2 graphs.

interpret=True gives CPU-numpy timings only — NOT a TPU proxy — so the
L1 numbers here are *structural*: for each kernel/tile configuration we
report the VMEM working set (must stay ≪ ~16 MiB/core) and the MXU duty
estimate (fraction of issued MXU cycles doing useful work for 128×128
systolic tiles). These are the quantities DESIGN.md §8 commits to.

Usage:  cd python && python -m compile.perf_analysis
"""

import jax
import jax.numpy as jnp

from . import configs, model

VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM budget (v4-class)
MXU = 128  # systolic array edge


def matmul_tile_report(m, k, n, bm, bk, bn, dtype_bytes=4):
    """VMEM + MXU stats for one (bm, bk, bn) tiling of an m×k @ k×n."""
    # Working set per grid step: A-tile, B-tile, accumulator (+ double
    # buffering of the input tiles by the pipeline).
    tile_in = (bm * bk + bk * bn) * dtype_bytes
    acc = bm * bn * 4  # f32 accumulator scratch
    vmem = 2 * tile_in + acc  # 2× for pipelined prefetch
    # MXU utilization: each (bm×bk)@(bk×bn) issue uses ceil-padded
    # 128-multiples; utilization = useful MACs / padded MACs.
    pad = lambda x: -(-x // MXU) * MXU
    useful = bm * bk * bn
    padded = pad(bm) * pad(bk) * pad(bn)
    return {
        "tile": (bm, bk, bn),
        "vmem_bytes": vmem,
        "vmem_frac": vmem / VMEM_BYTES,
        "mxu_util": useful / padded,
        "grid": (-(-m // bm), -(-n // bn), -(-k // bk)),
    }


def ns_report(m, n, block):
    """Newton–Schulz = 3 matmuls per iteration on the (small, large)
    orientation; report the dominant Gram matmul tiling."""
    small, large = min(m, n), max(m, n)
    r = matmul_tile_report(small, large, small, min(block, small),
                           min(block, large), min(block, small))
    r["kernel"] = f"ns_{m}x{n} gram ({small}x{large}@{large}x{small})"
    return r


def l2_cost(cfg_name):
    cfg = configs.get(cfg_name)
    fn = model.make_grad(cfg)
    lowered = jax.jit(fn).lower(*model.example_args(cfg))
    compiled = lowered.compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns [dict]
        ca = ca[0]
    flops = ca.get("flops", float("nan"))
    bytes_ = ca.get("bytes accessed", float("nan"))
    tokens = cfg.batch * cfg.seq_len
    return {
        "config": cfg_name,
        "flops": flops,
        "bytes": bytes_,
        "flops_per_token": flops / tokens,
        "arithmetic_intensity": flops / bytes_ if bytes_ else float("nan"),
        # 6·N heuristic for fwd+bwd of an N-param transformer:
        "heuristic_6N_per_token": 6.0 * cfg.n_params(),
    }


def main():
    print("== L1: Pallas tile analysis (structural; see DESIGN.md §8) ==")
    print(f"{'kernel':<44} {'tile':>14} {'VMEM':>10} {'%VMEM':>7} "
          f"{'MXU util':>9}")
    for (m, n) in [(64, 192), (128, 384), (256, 768), (512, 1376),
                   (1024, 2736), (4096, 14336)]:
        for block in [64, 128, 256]:
            r = ns_report(m, n, block)
            print(f"{r['kernel']:<44} {str(r['tile']):>14} "
                  f"{r['vmem_bytes']/1024:>8.0f}Ki {r['vmem_frac']*100:>6.2f} "
                  f"{r['mxu_util']*100:>8.1f}%")
    print("\n-> 128-tiles keep VMEM < 2% of budget with 100% MXU packing "
          "for all production shapes; 64-tiles waste 75% of MXU issue "
          "slots (64³ useful / 128·64·128 padded); 256-tiles gain nothing "
          "over 128 (already aligned) while 4× the working set. "
          "DEFAULT_BLOCK=128 is the roofline choice.")

    print("\n== L2: XLA cost analysis of model_grad ==")
    for name in ["micro", "tiny"]:
        c = l2_cost(name)
        print(f"  {name}: {c['flops']:.3e} FLOP/step "
              f"({c['flops_per_token']:.3e}/token; 6N heuristic "
              f"{c['heuristic_6N_per_token']:.3e}), "
              f"AI={c['arithmetic_intensity']:.1f} FLOP/B")


if __name__ == "__main__":
    main()
