"""L1 Pallas kernels: tiled matmul, Newton–Schulz (Muon), low-rank ops."""

from . import matmul, newton_schulz, lowrank, ref  # noqa: F401
