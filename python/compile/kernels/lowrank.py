"""L1: GaLore/GUM low-rank projection kernels.

Three operations, all composed from the tiled Pallas matmul:

- ``project``:        R = Pᵀ G                      (low-rank gradient)
- ``project_back``:   U = P R                       (update back-projection)
- ``debias_residual``: D = (G − P Pᵀ G) · scale     (GUM full-rank branch)

``P`` is m×r column-orthonormal, ``G`` is m×n; memory-wise these are the
exact tensors Algorithm 2 stores (Pᵀ G is r×n; the residual is m×n only on
the γ sampled blocks).
"""

import functools

import jax
import jax.numpy as jnp

from .matmul import matmul, matmul_tn


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def project(p, g, *, block: int = 128, interpret: bool = True):
    """R = Pᵀ G — project the gradient into the rank-r subspace."""
    return matmul_tn(p, g, block=block, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def project_back(p, r, *, block: int = 128, interpret: bool = True):
    """U = P R — lift a low-rank quantity back to the full space."""
    return matmul(p, r, block=block, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def debias_residual(p, g, scale, *, block: int = 128, interpret: bool = True):
    """D = scale · (G − P Pᵀ G) — GUM's compensated full-rank gradient.

    ``scale`` is 1/q in Algorithm 2 (or (1−q)/q·(−PPᵀG) under the
    Appendix-C.1 variant, which the Rust coordinator applies by passing the
    already-combined scale factors).
    """
    ptg = matmul_tn(p, g, block=block, interpret=interpret)
    pptg = matmul(p, ptg, block=block, interpret=interpret)
    return scale * (g - pptg)
