"""L1: Newton–Schulz orthogonalization — Muon's compute hot-spot.

Computes ``msign(X) ≈ U Vᵀ`` for ``X = U Σ Vᵀ`` via the quintic
Newton–Schulz iteration used by Muon [Jordan et al., 2024]:

    X ← a·X + b·(X Xᵀ)·X + c·(X Xᵀ)²·X,   (a,b,c) = (3.4445, −4.7750, 2.0315)

after pre-normalizing X by its Frobenius norm (plus eps).

The Gram products A = X Xᵀ, A X and A² X are the FLOP sink and run through
the tiled Pallas matmul kernel, so the whole iteration inherits the
MXU/VMEM schedule expressed there. The elementwise polynomial combination
is a separate (trivially vectorizable) Pallas kernel.

For a wide matrix (m > n) we orthogonalize the transpose — same convention
as the reference Muon implementation — so the Gram matrix is always the
small ``min(m,n)²`` side.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import matmul, matmul_nt, _block_edge

# Quintic NS coefficients from Jordan et al. (2024).
NS_A = 3.4445
NS_B = -4.7750
NS_C = 2.0315

DEFAULT_STEPS = 5
EPS = 1e-7


def _poly_kernel(x_ref, ax_ref, aax_ref, o_ref):
    """o = a*x + b*(A x) + c*(A² x), fused elementwise combine."""
    o_ref[...] = (
        NS_A * x_ref[...] + NS_B * ax_ref[...] + NS_C * aax_ref[...]
    )


def _poly_combine(x, ax, aax, *, block=128, interpret=True):
    m, n = x.shape
    bm = _block_edge(m, block)
    bn = _block_edge(n, block)
    return pl.pallas_call(
        _poly_kernel,
        grid=(m // bm, n // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))] * 3,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x, ax, aax)


@functools.partial(
    jax.jit, static_argnames=("steps", "block", "interpret")
)
def newton_schulz(
    g,
    *,
    steps: int = DEFAULT_STEPS,
    block: int = 128,
    interpret: bool = True,
):
    """msign(G) via quintic Newton–Schulz (Pallas-backed matmuls)."""
    m, n = g.shape
    transposed = m > n
    x = jnp.transpose(g) if transposed else g
    x = x / (jnp.linalg.norm(x) + EPS)
    for _ in range(steps):
        a = matmul_nt(x, x, block=block, interpret=interpret)  # X Xᵀ (m×m)
        ax = matmul(a, x, block=block, interpret=interpret)  # A X
        aax = matmul(a, ax, block=block, interpret=interpret)  # A² X
        x = _poly_combine(x, ax, aax, block=block, interpret=interpret)
    return jnp.transpose(x) if transposed else x
