"""L1: tiled Pallas matmul — the workhorse kernel.

All higher-level kernels (Newton–Schulz, low-rank projection) compose this
kernel, so the HBM↔VMEM schedule is expressed in exactly one place.

TPU mapping (DESIGN.md §7): the grid is (M/bm, N/bn, K/bk); each step stages
one bm×bk and one bk×bn tile into VMEM and feeds the MXU with an f32
accumulation tile held in VMEM scratch. On this image kernels run with
``interpret=True`` (the CPU PJRT plugin cannot execute Mosaic custom-calls);
numerics are identical to the TPU lowering.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default tile edge: 128 matches the MXU systolic array and keeps the VMEM
# working set at 3 * 128*128*4B = 192 KiB per grid step (double-buffered by
# the pipeline: ~384 KiB), far under the ~16 MiB VMEM budget. See the
# BlockSpec sweep in EXPERIMENTS.md §Perf.
DEFAULT_BLOCK = 128


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, k_steps):
    """One (i, j, k) grid step: acc[i,j] += x[i,k] @ y[k,j]."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _block_edge(dim: int, requested: int) -> int:
    """Largest tile edge <= requested that divides dim."""
    b = min(requested, dim)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def matmul(x, y, *, block: int = DEFAULT_BLOCK, interpret: bool = True):
    """C = X @ Y via the tiled Pallas kernel.

    Shapes need not be multiples of ``block``; tile edges shrink to the
    largest divisor of each dim (interpret mode has no alignment
    constraint — on real TPU the wrapper would pad to (8,128) lane tiles).
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {x.shape} @ {y.shape}"
    bm = _block_edge(m, block)
    bn = _block_edge(n, block)
    bk = _block_edge(k, block)
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, y)


def matmul_nt(x, y, **kw):
    """C = X @ Yᵀ (used for Gram matrices in Newton–Schulz)."""
    return matmul(x, jnp.transpose(y), **kw)


def matmul_tn(x, y, **kw):
    """C = Xᵀ @ Y (used for PᵀG projection)."""
    return matmul(jnp.transpose(x), y, **kw)
