"""Pure-jnp oracles for every L1 kernel — the correctness ground truth.

pytest (python/tests/test_kernels.py) sweeps shapes/dtypes with hypothesis
and asserts allclose between these references and the Pallas kernels.
"""

import jax.numpy as jnp

from .newton_schulz import NS_A, NS_B, NS_C, EPS


def matmul_ref(x, y):
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def newton_schulz_ref(g, steps: int = 5):
    """Quintic Newton–Schulz with plain jnp ops (same math, no Pallas)."""
    m, n = g.shape
    transposed = m > n
    x = jnp.transpose(g) if transposed else g
    x = x / (jnp.linalg.norm(x) + EPS)
    for _ in range(steps):
        a = x @ x.T
        b = a @ x
        x = NS_A * x + NS_B * b + NS_C * (a @ b)
    return jnp.transpose(x) if transposed else x


def msign_exact(g):
    """Exact msign via SVD (Assumption 4 in the paper)."""
    u, _, vt = jnp.linalg.svd(g, full_matrices=False)
    return u @ vt


def project_ref(p, g):
    return p.T @ g


def project_back_ref(p, r):
    return p @ r


def debias_residual_ref(p, g, scale):
    return scale * (g - p @ (p.T @ g))


def galore_projector_ref(g, rank: int):
    """GaLore projector: top-r left singular vectors of G (m <= n case)."""
    u, _, _ = jnp.linalg.svd(g, full_matrices=False)
    return u[:, :rank]
