"""Model size registry (L2). Mirrored by rust/src/model/registry.rs.

Runnable sizes (micro/tiny/small) use a byte-level vocab so the embedding
does not dominate; the paper sizes (60m/130m/350m, vocab 32000, shapes from
GaLore's LLaMA table) are exported for memory accounting and compile-only
validation — CPU wall-clock makes full Chinchilla-budget runs impractical,
so end-to-end experiments run the small sizes (see DESIGN.md §2).
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    dim: int
    n_layers: int
    n_heads: int
    ffn: int
    seq_len: int
    batch: int
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    # Whether `make artifacts` lowers fwd/grad HLO for this config by default.
    export: bool = True

    @property
    def head_dim(self) -> int:
        assert self.dim % self.n_heads == 0
        return self.dim // self.n_heads

    def param_blocks(self):
        """Canonical ordered list of (name, shape) parameter blocks.

        The order here is the ABI between aot.py's HLO argument list and the
        Rust parameter store — never reorder without bumping the manifest
        version.
        """
        blocks = [("embed", (self.vocab, self.dim))]
        for i in range(self.n_layers):
            p = f"layers.{i}."
            blocks += [
                (p + "attn_norm", (self.dim,)),
                (p + "wq", (self.dim, self.dim)),
                (p + "wk", (self.dim, self.dim)),
                (p + "wv", (self.dim, self.dim)),
                (p + "wo", (self.dim, self.dim)),
                (p + "mlp_norm", (self.dim,)),
                (p + "w_gate", (self.dim, self.ffn)),
                (p + "w_up", (self.dim, self.ffn)),
                (p + "w_down", (self.ffn, self.dim)),
            ]
        blocks += [
            ("final_norm", (self.dim,)),
            ("lm_head", (self.dim, self.vocab)),
        ]
        return blocks

    def n_params(self) -> int:
        total = 0
        for _, shape in self.param_blocks():
            n = 1
            for d in shape:
                n *= d
            total += n
        return total

    def to_dict(self):
        return asdict(self)


# Runnable configs (byte vocab). seq/batch chosen so a grad step is CPU-fast.
MICRO = ModelConfig("micro", vocab=256, dim=64, n_layers=2, n_heads=4,
                    ffn=192, seq_len=64, batch=8)
TINY = ModelConfig("tiny", vocab=256, dim=128, n_layers=4, n_heads=4,
                   ffn=384, seq_len=128, batch=8)
SMALL = ModelConfig("small", vocab=512, dim=256, n_layers=6, n_heads=8,
                    ffn=768, seq_len=128, batch=8)

# Paper sizes (GaLore LLaMA table; vocab 32000). Export disabled by default:
# they lower fine but compiling/running them on the CPU plugin is slow.
LLAMA_60M = ModelConfig("llama-60m", vocab=32000, dim=512, n_layers=8,
                        n_heads=8, ffn=1376, seq_len=1024, batch=8,
                        export=False)
LLAMA_130M = ModelConfig("llama-130m", vocab=32000, dim=768, n_layers=12,
                         n_heads=12, ffn=2048, seq_len=1024, batch=8,
                         export=False)
LLAMA_350M = ModelConfig("llama-350m", vocab=32000, dim=1024, n_layers=24,
                         n_heads=16, ffn=2736, seq_len=1024, batch=8,
                         export=False)

CONFIGS = {c.name: c for c in
           [MICRO, TINY, SMALL, LLAMA_60M, LLAMA_130M, LLAMA_350M]}


def get(name: str) -> ModelConfig:
    if name not in CONFIGS:
        raise KeyError(f"unknown model config '{name}' "
                       f"(have: {sorted(CONFIGS)})")
    return CONFIGS[name]
