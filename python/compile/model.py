"""L2: LLaMA-style decoder-only transformer in JAX (build-time only).

Defines the forward pass, cross-entropy loss, and per-block gradients that
``aot.py`` lowers to HLO text. Parameters are a flat *list* of arrays in the
canonical order of ``ModelConfig.param_blocks()`` — that order is the ABI
shared with the Rust parameter store via ``artifacts/manifest.json``.

Architecture (matching the paper's LLaMA configs): RMSNorm → causal
multi-head attention with RoPE → residual → RMSNorm → SwiGLU MLP →
residual; final RMSNorm; untied LM head.

The fwd/bwd compute graph is plain jnp (XLA fuses it well on every
backend). The L1 Pallas kernels live on the *optimizer* side of the system
(Newton–Schulz / projection artifacts), which is where this paper's compute
contribution sits; ``use_pallas_lmhead=True`` optionally routes the LM-head
matmul through the Pallas tiled matmul to prove the kernels compose into the
model graph (exercised by tests, off by default for CPU speed).
"""

import functools

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.matmul import matmul as pallas_matmul


# ---------------------------------------------------------------------------
# Parameter handling
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key):
    """Initialize the flat parameter list (truncated-normal-ish scaling)."""
    params = []
    for name, shape in cfg.param_blocks():
        key, sub = jax.random.split(key)
        if len(shape) == 1:
            params.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[0]
            std = fan_in ** -0.5
            params.append(
                std * jax.random.normal(sub, shape, jnp.float32)
            )
    return params


def _unpack(cfg: ModelConfig, params):
    """View the flat list as a structured dict, by canonical order."""
    names = [n for n, _ in cfg.param_blocks()]
    return dict(zip(names, params))


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope_tables(cfg: ModelConfig):
    hd = cfg.head_dim
    pos = jnp.arange(cfg.seq_len, dtype=jnp.float32)
    inv = cfg.rope_theta ** (
        -jnp.arange(0, hd, 2, dtype=jnp.float32) / hd
    )
    ang = pos[:, None] * inv[None, :]  # (S, hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, H, S, hd). Rotate pairs (even, odd)."""
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    c = cos[None, None, :, :]
    s = sin[None, None, :, :]
    out = jnp.stack([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.reshape(x.shape)


def attention(x, p, prefix, cfg: ModelConfig, cos, sin):
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim

    def split_heads(t):
        return t.reshape(b, s, h, hd).transpose(0, 2, 1, 3)

    q = split_heads(x @ p[prefix + "wq"])
    k = split_heads(x @ p[prefix + "wk"])
    v = split_heads(x @ p[prefix + "wv"])
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(
        jnp.float32(hd)
    )
    mask = jnp.tril(jnp.ones((s, s), jnp.bool_))
    scores = jnp.where(mask[None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, d)
    return ctx @ p[prefix + "wo"]


def swiglu(x, p, prefix):
    gate = jax.nn.silu(x @ p[prefix + "w_gate"])
    up = x @ p[prefix + "w_up"]
    return (gate * up) @ p[prefix + "w_down"]


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params, tokens, *, use_pallas_lmhead=False,
            return_hidden=False):
    """tokens: i32 (B, S) → logits f32 (B, S, vocab)."""
    p = _unpack(cfg, params)
    cos, sin = rope_tables(cfg)
    x = p["embed"][tokens]  # (B, S, D)
    for i in range(cfg.n_layers):
        pre = f"layers.{i}."
        x = x + attention(
            rmsnorm(x, p[pre + "attn_norm"], cfg.norm_eps), p, pre, cfg,
            cos, sin,
        )
        x = x + swiglu(rmsnorm(x, p[pre + "mlp_norm"], cfg.norm_eps), p, pre)
    x = rmsnorm(x, p["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x
    if use_pallas_lmhead:
        b, s, d = x.shape
        logits = pallas_matmul(x.reshape(b * s, d), p["lm_head"])
        return logits.reshape(b, s, cfg.vocab)
    return x @ p["lm_head"]


def loss_fn(cfg: ModelConfig, params, tokens, targets, **kw):
    """Mean next-token cross entropy + per-example NLL.

    targets: i32 (B, S); positions with target < 0 are masked out (padding),
    which lets the Rust eval loop score variable-length continuations for
    multiple-choice probes.
    """
    logits = forward(cfg, params, tokens, **kw)
    logp = jax.nn.log_softmax(logits, axis=-1)
    mask = (targets >= 0).astype(jnp.float32)
    safe = jnp.maximum(targets, 0)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    nll = nll * mask
    per_example = jnp.sum(nll, axis=-1) / jnp.maximum(
        jnp.sum(mask, axis=-1), 1.0
    )
    total = jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return total, per_example


# ---------------------------------------------------------------------------
# AOT entry points (lowered by aot.py)
# ---------------------------------------------------------------------------

def make_fwd(cfg: ModelConfig):
    """(params..., tokens, targets) -> (loss, per_example_nll)."""
    n = len(cfg.param_blocks())

    def fwd(*args):
        params = list(args[:n])
        tokens, targets = args[n], args[n + 1]
        loss, per_ex = loss_fn(cfg, params, tokens, targets)
        return (loss, per_ex)

    return fwd


def make_grad(cfg: ModelConfig):
    """(params..., tokens, targets) -> (loss, grad_0, ..., grad_{P-1})."""
    n = len(cfg.param_blocks())

    def grad_fn(*args):
        params = list(args[:n])
        tokens, targets = args[n], args[n + 1]

        def scalar_loss(ps):
            return loss_fn(cfg, ps, tokens, targets)[0]

        loss, grads = jax.value_and_grad(scalar_loss)(params)
        return tuple([loss] + list(grads))

    return grad_fn


def make_logits(cfg: ModelConfig):
    """(params..., tokens) -> (logits,) — used by the Rust greedy decoder
    for the exact-match fine-tuning evals (Table 2)."""
    n = len(cfg.param_blocks())

    def logits_fn(*args):
        params = list(args[:n])
        tokens = args[n]
        return (forward(cfg, params, tokens),)

    return logits_fn


def example_args(cfg: ModelConfig, key=None):
    """ShapeDtypeStructs for lowering (params..., tokens, targets)."""
    specs = [
        jax.ShapeDtypeStruct(shape, jnp.float32)
        for _, shape in cfg.param_blocks()
    ]
    tok = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
    return specs + [tok, tok]
