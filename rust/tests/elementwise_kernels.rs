//! Property suite for the fused elementwise engine
//! (`linalg::elementwise`): every kernel against an f64 scalar
//! reference across odd lengths and unaligned sub-slices, bitwise
//! equality across `GUM_THREADS` widths, SIMD-vs-portable agreement,
//! and the fused optimizer paths against their pre-engine multi-pass
//! compositions.

use gum::linalg::{elementwise, Matrix};
use gum::model::{BlockKind, ParamBlock, ParamStore};
use gum::optim::{self, StepCtx};
use gum::rng::Pcg;
use gum::thread::{num_threads, set_num_threads};

/// Serializes tests that flip process-global state (thread width, the
/// portable-dispatch override) — same discipline as
/// `parallel_equivalence.rs`.
static GLOBAL_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Lengths that cross every dispatch regime: empty, sub-SIMD-width,
/// odd, just over a vector register, and (last entry) big enough to
/// split into several parallel chunks.
const LENGTHS: [usize; 8] = [0, 1, 3, 7, 17, 63, 1025, 3 * (1 << 15) + 7];

fn data(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg::new(seed);
    (0..n).map(|_| rng.normal_f32()).collect()
}

fn assert_close(got: &[f32], want_f64: &[f64], ctx: &str) {
    assert_eq!(got.len(), want_f64.len(), "{ctx}: length");
    for (i, (&g, &w)) in got.iter().zip(want_f64).enumerate() {
        let tol = 1e-5 * w.abs().max(1.0);
        assert!(
            (g as f64 - w).abs() <= tol,
            "{ctx}[{i}]: got {g}, want {w}"
        );
    }
}

#[test]
fn axpby_matches_f64_reference_all_lengths() {
    for &n in &LENGTHS {
        let mut x = data(n, 1);
        let y = data(n, 2);
        let want: Vec<f64> = x
            .iter()
            .zip(&y)
            .map(|(&a, &b)| 0.9f64 * a as f64 + 1.7f64 * b as f64)
            .collect();
        elementwise::axpby(0.9, &mut x, 1.7, &y);
        assert_close(&x, &want, &format!("axpby n={n}"));
    }
}

#[test]
fn add_scaled_matches_f64_reference_all_lengths() {
    for &n in &LENGTHS {
        let mut x = data(n, 3);
        let y = data(n, 4);
        let want: Vec<f64> = x
            .iter()
            .zip(&y)
            .map(|(&a, &b)| a as f64 - 0.05f64 * b as f64)
            .collect();
        elementwise::add_scaled(&mut x, -0.05, &y);
        assert_close(&x, &want, &format!("add_scaled n={n}"));
    }
}

#[test]
fn decay_accumulate2_matches_f64_reference_all_lengths() {
    for &n in &LENGTHS {
        let mut m = data(n, 5);
        let x = data(n, 6);
        let y = data(n, 7);
        let want: Vec<f64> = m
            .iter()
            .zip(&x)
            .zip(&y)
            .map(|((&mv, &xv), &yv)| {
                0.95f64 * mv as f64 + 2.5f64 * xv as f64 - 2.5f64 * yv as f64
            })
            .collect();
        elementwise::decay_accumulate2(&mut m, 0.95, 2.5, &x, -2.5, &y);
        assert_close(&m, &want, &format!("decay_accumulate2 n={n}"));
    }
}

#[test]
fn residual_add_matches_f64_reference_all_lengths() {
    for &n in &LENGTHS {
        let mut w = data(n, 8);
        let g = data(n, 9);
        let r = data(n, 10);
        let want: Vec<f64> = w
            .iter()
            .zip(&g)
            .zip(&r)
            .map(|((&wv, &gv), &rv)| {
                wv as f64 - 0.3f64 * (gv as f64 - rv as f64)
            })
            .collect();
        elementwise::residual_add(&mut w, -0.3, &g, &r);
        assert_close(&w, &want, &format!("residual_add n={n}"));
    }
}

#[test]
fn adam_kernels_match_f64_reference_all_lengths() {
    let (b1, b2, eps, lr, wd) = (0.9f32, 0.999, 1e-8, 0.05, 0.01);
    let (bc1, bc2) = (1.0 - b1.powi(4), 1.0 - b2.powi(4));
    for &n in &LENGTHS {
        let g = data(n, 11);
        // adam_update.
        let mut m = data(n, 12);
        let mut v: Vec<f32> = data(n, 13).iter().map(|x| x * x).collect();
        let (m0, v0) = (m.clone(), v.clone());
        let mut upd = vec![0.0f32; n];
        elementwise::adam_update(
            &mut upd, &g, &mut m, &mut v, b1, b2, bc1, bc2, eps,
        );
        let mut want_upd = Vec::with_capacity(n);
        let mut want_m = Vec::with_capacity(n);
        for i in 0..n {
            let mi = b1 as f64 * m0[i] as f64
                + (1.0 - b1 as f64) * g[i] as f64;
            let vi = b2 as f64 * v0[i] as f64
                + (1.0 - b2 as f64) * (g[i] as f64) * (g[i] as f64);
            want_m.push(mi);
            want_upd.push(
                (mi / bc1 as f64) / ((vi / bc2 as f64).sqrt() + eps as f64),
            );
        }
        assert_close(&m, &want_m, &format!("adam_update m n={n}"));
        assert_close(&upd, &want_upd, &format!("adam_update upd n={n}"));

        // adam_apply.
        let mut w = data(n, 14);
        let w0 = w.clone();
        let mut m = m0.clone();
        let mut v = v0.clone();
        elementwise::adam_apply(
            &mut w, &g, &mut m, &mut v, b1, b2, bc1, bc2, eps, lr, wd,
        );
        let mut want_w = Vec::with_capacity(n);
        for i in 0..n {
            let mi = b1 as f64 * m0[i] as f64
                + (1.0 - b1 as f64) * g[i] as f64;
            let vi = b2 as f64 * v0[i] as f64
                + (1.0 - b2 as f64) * (g[i] as f64) * (g[i] as f64);
            let mhat = mi / bc1 as f64;
            let vhat = vi / bc2 as f64;
            let x = w0[i] as f64 * (1.0 - lr as f64 * wd as f64);
            want_w.push(x - lr as f64 * mhat / (vhat.sqrt() + eps as f64));
        }
        assert_close(&w, &want_w, &format!("adam_apply n={n}"));
    }
}

/// Unaligned sub-slices: the SIMD paths must not assume 32-byte (or
/// any) alignment. Operate on `[off..]` windows of a larger buffer for
/// every small offset.
#[test]
fn unaligned_subslices_match_aligned_results() {
    let n = 4096 + 11;
    for off in 1..=7usize {
        let mut x_full = data(n + off, 20);
        let y_full = data(n + off, 21);
        let mut x_ref: Vec<f32> = x_full[off..].to_vec();
        let y_ref: Vec<f32> = y_full[off..].to_vec();
        elementwise::axpby(0.8, &mut x_full[off..], -1.2, &y_full[off..]);
        elementwise::axpby(0.8, &mut x_ref, -1.2, &y_ref);
        assert_eq!(
            &x_full[off..],
            &x_ref[..],
            "axpby offset {off} changed the bytes"
        );

        let mut w_full = data(n + off, 22);
        let g_full = data(n + off, 23);
        let r_full = data(n + off, 24);
        let mut w_ref: Vec<f32> = w_full[off..].to_vec();
        elementwise::residual_add(
            &mut w_full[off..],
            0.4,
            &g_full[off..],
            &r_full[off..],
        );
        elementwise::residual_add(
            &mut w_ref,
            0.4,
            &g_full[off..],
            &r_full[off..],
        );
        assert_eq!(&w_full[off..], &w_ref[..], "residual_add offset {off}");
    }
}

/// The determinism contract: every kernel is bit-identical under any
/// `GUM_THREADS` width, because each output element is a pure function
/// of its index (chunk boundaries cannot change the arithmetic).
#[test]
fn kernels_bit_identical_across_thread_widths() {
    let _g = GLOBAL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let n = 3 * (1 << 15) + 777; // several chunks wide at any width
    let orig = num_threads();
    let (b1, b2, eps, lr, wd) = (0.9f32, 0.999, 1e-8, 0.05, 0.01);
    let run = |width: usize| {
        set_num_threads(width);
        let mut x = data(n, 30);
        let y = data(n, 31);
        elementwise::axpby(0.95, &mut x, 0.3, &y);
        let mut m = data(n, 32);
        elementwise::decay_accumulate2(&mut m, 0.9, 1.5, &x, -1.5, &y);
        let mut w = data(n, 33);
        elementwise::residual_add(&mut w, -0.2, &x, &y);
        let g = data(n, 34);
        let mut am = data(n, 35);
        let mut av: Vec<f32> = data(n, 36).iter().map(|v| v * v).collect();
        let mut upd = vec![0.0f32; n];
        elementwise::adam_update(
            &mut upd, &g, &mut am, &mut av, b1, b2, 0.5, 0.5, eps,
        );
        elementwise::adam_apply(
            &mut w, &g, &mut am, &mut av, b1, b2, 0.5, 0.5, eps, lr, wd,
        );
        (x, m, w, upd, am, av)
    };
    let golden = run(1);
    for width in [2usize, 8, 16] {
        let got = run(width);
        set_num_threads(orig);
        assert_eq!(golden, got, "width {width} changed kernel bytes");
    }
    set_num_threads(orig);
}

/// The portable bodies agree with the (probed) dispatch path within
/// FMA-rounding tolerance — the benches' A/B switch is sound.
#[test]
fn forced_portable_agrees_with_dispatch() {
    let _g = GLOBAL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let n = 10_007;
    let mut x_fast = data(n, 40);
    let y = data(n, 41);
    let mut x_port = x_fast.clone();
    let prev = elementwise::force_portable(false);
    elementwise::axpby(0.7, &mut x_fast, -0.9, &y);
    elementwise::force_portable(true);
    elementwise::axpby(0.7, &mut x_port, -0.9, &y);
    elementwise::force_portable(prev);
    for (i, (a, b)) in x_fast.iter().zip(&x_port).enumerate() {
        assert!(
            (a - b).abs() <= 1e-5 * a.abs().max(1.0),
            "portable vs dispatch diverged at {i}: {a} vs {b}"
        );
    }
}

/// End-to-end: the fused optimizer step paths (GaLore-Adam's fused
/// moments, Fira's fused residual, GUM's fused compensated momentum,
/// DenseAdamW's fused apply) reproduce the pre-engine multi-pass
/// compositions on a real block set within float tolerance.
#[test]
fn fused_optimizer_steps_match_multipass_reference() {
    let mut rng = Pcg::new(50);
    let store = ParamStore {
        blocks: vec![
            ParamBlock {
                name: "w0".into(),
                shape: vec![24, 40],
                kind: BlockKind::Projectable,
                value: Matrix::randn(24, 40, 0.1, &mut rng),
            },
            ParamBlock {
                name: "norm".into(),
                shape: vec![16],
                kind: BlockKind::Dense,
                value: Matrix::from_vec(1, 16, vec![1.0; 16]),
            },
        ],
    };
    let grads: Vec<Matrix> = store
        .blocks
        .iter()
        .map(|b| Matrix::randn(b.value.rows, b.value.cols, 1.0, &mut rng))
        .collect();
    // Two identical optimizers stepping identical stores must stay
    // bitwise in lockstep (sanity that the fused path is deterministic),
    // and the loss-relevant outcome must be finite and nontrivial.
    for name in ["galore-adam", "fira", "gum", "adamw", "muon"] {
        let mut a = optim::build(name, &store, 4, 1.0, 9).unwrap();
        let mut b = optim::build(name, &store, 4, 1.0, 9).unwrap();
        let mut sa = store.clone();
        let mut sb = store.clone();
        let mut ra = Pcg::new(1);
        let mut rb = Pcg::new(1);
        a.begin_period(&sa, &grads, &mut ra);
        b.begin_period(&sb, &grads, &mut rb);
        for step in 0..3 {
            a.step(&mut sa, &grads, &StepCtx { lr: 0.01, step });
            b.step(&mut sb, &grads, &StepCtx { lr: 0.01, step });
        }
        for (x, y) in sa.blocks.iter().zip(&sb.blocks) {
            assert_eq!(x.value, y.value, "{name}: {} diverged", x.name);
            assert!(x.value.is_finite(), "{name}: {} not finite", x.name);
        }
        let moved = sa.blocks[0].value.max_abs_diff(&store.blocks[0].value);
        assert!(moved > 0.0, "{name}: step must move the weights");
    }
}
