//! Checkpoint corruption robustness: the hardened `GUMCKPT3` container
//! must *detect* every torn-write shape with a clear diagnostic —
//! truncated tail, flipped bytes, unknown version header — and the
//! directory-level recovery path must fall back past corrupt tails to
//! the last good snapshot.
//!
//! The adaptive rank schedule rides in the optional `RANKS` section:
//! a mid-period snapshot taken after a rank change must resume
//! bit-identically, and checkpoints written before the section existed
//! (equivalently: by any fixed-schedule run) must still load.

use std::path::{Path, PathBuf};

use gum::coordinator::{
    load_latest_train_state, load_train_state, save_checkpoint,
    save_train_state, save_train_state_v2, LrSchedule, ParallelConfig,
    ParallelSession, ShardMode, ShardedBatcher, SyntheticGradSource,
    TrainState,
};
use gum::data::corpus::CorpusSpec;
use gum::data::tokenizer::ByteTokenizer;
use gum::linalg::Matrix;
use gum::model::{
    init_param_store, registry, BlockKind, ParamBlock, ParamStore,
};
use gum::optim::{
    self, AdaptiveRankCfg, OptSnapshot, PendingRefresh, PreparedRefresh,
    Projector, RankSchedule, RankState, RefreshPipelineMode, RefreshStrategy,
    SnapValue,
};
use gum::rng::Pcg;

fn sample_state(step: u64) -> TrainState {
    let params = init_param_store(&registry::get("micro").unwrap(), step);
    let mut snap = OptSnapshot::default();
    snap.push("period", SnapValue::U64(step / 5));
    snap.push("sampler/state", SnapValue::U64(0xdead_beef ^ step));
    snap.push("sampler/spare", SnapValue::F64(-0.25));
    snap.push("b0/full", SnapValue::Bool(step % 2 == 0));
    snap.push(
        "b0/mom",
        SnapValue::Mat(Matrix::from_vec(
            2,
            3,
            vec![1.0, -2.0, 0.5, 0.0, 9.0, -0.125],
        )),
    );
    TrainState {
        step,
        params,
        opt: Some(snap),
        rng_raw: (42 + step, 99, Some(1.5)),
        lanes: vec![(7 + step, vec![1, 2, 3]), (1007, vec![])],
        val_lane: Some((1_000_003, vec![9, 8])),
        pending_refresh: Some(PendingRefresh {
            boundary: step + 3,
            prepared: PreparedRefresh {
                projectors: vec![
                    None,
                    Some(Projector {
                        p: Matrix::from_vec(4, 2, vec![0.5; 8]),
                        left: false,
                        rank: 2,
                    }),
                ],
                rank_state: Some(RankState {
                    ranks: vec![2, 0],
                    pressure: vec![-1, 0],
                }),
                period_state: None,
            },
        }),
        rank_state: Some(RankState {
            ranks: vec![3, 0],
            pressure: vec![1, 0],
        }),
        period_state: None,
    }
}

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("gum_ckpt_rob_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn state_path(dir: &Path, step: u64) -> PathBuf {
    dir.join(format!("state_{step:06}.bin"))
}

fn err_string(result: anyhow::Result<TrainState>) -> String {
    format!("{:#}", result.expect_err("corrupt checkpoint must not load"))
}

#[test]
fn v3_roundtrip_is_bit_exact() {
    let dir = fresh_dir("roundtrip");
    let state = sample_state(17);
    let path = state_path(&dir, 17);
    save_train_state(&state, &path).unwrap();
    let loaded = load_train_state(&path).unwrap();
    assert_eq!(loaded.step, state.step);
    assert_eq!(loaded.params, state.params);
    assert_eq!(loaded.opt, state.opt);
    assert_eq!(loaded.rng_raw, state.rng_raw);
    assert_eq!(loaded.lanes, state.lanes);
    assert_eq!(loaded.val_lane, state.val_lane);
    assert_eq!(loaded.pending_refresh, state.pending_refresh);
    assert_eq!(loaded.rank_state, state.rank_state);
}

#[test]
fn legacy_v2_writer_output_still_loads() {
    let dir = fresh_dir("legacy_v2");
    let state = sample_state(9);
    let path = state_path(&dir, 9);
    save_train_state_v2(&state, &path).unwrap();
    let loaded = load_train_state(&path).unwrap();
    assert_eq!(loaded.step, state.step);
    assert_eq!(loaded.params, state.params);
    assert_eq!(loaded.opt, state.opt);
    assert_eq!(loaded.lanes, state.lanes);
    // The v2 format predates the RANKS section entirely.
    assert_eq!(loaded.rank_state, None);
}

#[test]
fn truncated_tail_is_detected_with_diagnostic() {
    let dir = fresh_dir("truncate");
    let path = state_path(&dir, 5);
    save_train_state(&sample_state(5), &path).unwrap();
    let full = std::fs::read(&path).unwrap();
    // A torn write that kept only the first 100 bytes.
    std::fs::write(&path, &full[..100]).unwrap();
    let msg = err_string(load_train_state(&path));
    assert!(msg.contains("truncated"), "{msg}");
}

#[test]
fn flipped_checksum_byte_is_detected() {
    let dir = fresh_dir("flip_checksum");
    let path = state_path(&dir, 5);
    save_train_state(&sample_state(5), &path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    // The file ends with the final (REFRESH) section's stored checksum.
    let last = bytes.len() - 1;
    bytes[last] ^= 0xff;
    std::fs::write(&path, &bytes).unwrap();
    let msg = err_string(load_train_state(&path));
    assert!(msg.contains("checksum mismatch"), "{msg}");
}

#[test]
fn flipped_payload_byte_is_detected() {
    let dir = fresh_dir("flip_payload");
    let path = state_path(&dir, 5);
    save_train_state(&sample_state(5), &path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    // Mid-file lands inside the PARAMS payload (the dominant section).
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01;
    std::fs::write(&path, &bytes).unwrap();
    let msg = err_string(load_train_state(&path));
    assert!(msg.contains("checksum mismatch"), "{msg}");
    assert!(msg.contains("PARAMS"), "{msg}");
}

#[test]
fn version_mismatch_headers_fail_clearly() {
    let dir = fresh_dir("version");
    // A future format this build does not read.
    let future = dir.join("state_000001.bin");
    let mut bytes = b"GUMCKPT9".to_vec();
    bytes.extend_from_slice(&[0u8; 64]);
    std::fs::write(&future, &bytes).unwrap();
    let msg = err_string(load_train_state(&future));
    assert!(msg.contains("unsupported train-state format"), "{msg}");

    // A v1 parameter-only checkpoint is named as such.
    let v1 = dir.join("params.bin");
    let store = init_param_store(&registry::get("micro").unwrap(), 0);
    save_checkpoint(&store, &v1).unwrap();
    let msg = err_string(load_train_state(&v1));
    assert!(msg.contains("GUMCKPT1"), "{msg}");

    // Arbitrary garbage is rejected without a panic.
    let junk = dir.join("junk.bin");
    std::fs::write(&junk, b"definitely not a checkpoint").unwrap();
    let msg = err_string(load_train_state(&junk));
    assert!(msg.contains("not a GUM train-state"), "{msg}");
}

#[test]
fn load_latest_recovers_past_a_corrupt_tail() {
    let dir = fresh_dir("latest_fallback");
    save_train_state(&sample_state(5), &state_path(&dir, 5)).unwrap();
    let newest = state_path(&dir, 10);
    save_train_state(&sample_state(10), &newest).unwrap();
    // Torn write on the newest snapshot.
    let full = std::fs::read(&newest).unwrap();
    std::fs::write(&newest, &full[..64.min(full.len())]).unwrap();

    let latest = load_latest_train_state(&dir).unwrap();
    assert_eq!(latest.state.step, 5, "must fall back to the good snapshot");
    assert_eq!(latest.path, state_path(&dir, 5));
    assert_eq!(latest.skipped.len(), 1);
    assert_eq!(latest.skipped[0].0, newest);
    assert!(
        latest.skipped[0].1.contains("truncated")
            || latest.skipped[0].1.contains("checksum"),
        "{}",
        latest.skipped[0].1
    );
}

#[test]
fn load_latest_prefers_newest_and_ignores_tmp_leftovers() {
    let dir = fresh_dir("latest_order");
    save_train_state(&sample_state(5), &state_path(&dir, 5)).unwrap();
    save_train_state(&sample_state(10), &state_path(&dir, 10)).unwrap();
    // A stale interrupted write must never be considered.
    std::fs::write(dir.join("state_000099.bin.tmp"), b"torn").unwrap();
    let latest = load_latest_train_state(&dir).unwrap();
    assert_eq!(latest.state.step, 10);
    assert!(latest.skipped.is_empty());
}

#[test]
fn load_latest_reports_empty_and_all_corrupt_directories() {
    let empty = fresh_dir("latest_empty");
    let err = format!("{:#}", load_latest_train_state(&empty).unwrap_err());
    assert!(err.contains("no train-state snapshots"), "{err}");

    let broken = fresh_dir("latest_all_corrupt");
    std::fs::write(state_path(&broken, 5), b"GUMCKPT3 and then garbage")
        .unwrap();
    let err = format!("{:#}", load_latest_train_state(&broken).unwrap_err());
    assert!(err.contains("unloadable"), "{err}");
}

#[test]
fn save_commits_atomically_without_tmp_siblings() {
    let dir = fresh_dir("atomic");
    save_train_state(&sample_state(3), &state_path(&dir, 3)).unwrap();
    let names: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .collect();
    assert_eq!(names, vec!["state_000003.bin".to_string()], "{names:?}");
}

// ---------------------------------------------------------------------
// Adaptive rank schedule ↔ checkpoint interplay (the RANKS section).
// ---------------------------------------------------------------------

const BATCH: usize = 4;
const SEQ: usize = 32;
const PERIOD_K: usize = 5;
const BASE_RANK: usize = 4;
const SRC_SEED: u64 = 23;

fn small_store() -> ParamStore {
    let mut rng = Pcg::new(5);
    let blocks = vec![
        ParamBlock {
            name: "w0".into(),
            shape: vec![24, 32],
            kind: BlockKind::Projectable,
            value: Matrix::randn(24, 32, 0.1, &mut rng),
        },
        ParamBlock {
            name: "w1".into(),
            shape: vec![32, 24],
            kind: BlockKind::Projectable,
            value: Matrix::randn(32, 24, 0.1, &mut rng),
        },
        ParamBlock {
            name: "norm".into(),
            shape: vec![16],
            kind: BlockKind::Dense,
            value: Matrix::from_vec(1, 16, vec![1.0; 16]),
        },
    ];
    ParamStore { blocks }
}

fn gum_session(schedule: &RankSchedule) -> ParallelSession {
    let params = small_store();
    let opt = optim::build_with_schedule(
        "gum",
        &params,
        BASE_RANK,
        1.0,
        99,
        RefreshStrategy::default(),
        schedule,
    )
    .unwrap();
    let pcfg = ParallelConfig {
        replicas: 2,
        accum_steps: 1,
        shard_mode: ShardMode::DocPartition,
        doc_stride: 100_000,
    };
    let batcher = ShardedBatcher::new(
        &CorpusSpec::default(),
        &ByteTokenizer::new(256),
        BATCH,
        SEQ,
        &pcfg,
    );
    let mut s = ParallelSession::new(
        params,
        opt,
        batcher,
        PERIOD_K,
        LrSchedule::constant(0.02),
        17,
    );
    s.set_refresh_mode(RefreshPipelineMode::Async);
    s
}

fn adaptive() -> RankSchedule {
    RankSchedule::Adaptive(AdaptiveRankCfg {
        energy: 0.90,
        deadband: 1,
        patience: 2,
        min_rank: 1,
        max_rank: 8,
        budget: 12,
    })
}

fn srcs(s: &ParallelSession) -> Vec<SyntheticGradSource> {
    vec![SyntheticGradSource::new(&s.params, SRC_SEED); 2]
}

/// Resume from a `GUMCKPT3` snapshot written mid-period *after* the
/// controller committed a rank change — and with the next refresh (at
/// its new ranks) already in flight. The restored session must replay
/// the uninterrupted run bit-for-bit: parameters, losses, and every
/// subsequent rank decision.
#[test]
fn resume_after_rank_change_is_bit_identical() {
    let schedule = adaptive();
    let mut a = gum_session(&schedule);
    let mut sa = srcs(&a);
    // Observes at boundaries 0 and 5 (patience 2) commit the rank move;
    // the trigger at step 2K−1 then arms boundary 2K's refresh at the
    // *new* ranks. Stop right there: step 2K, boundary not yet applied.
    for _ in 0..2 * PERIOD_K {
        a.global_step(&mut sa).unwrap();
    }
    let state = a.train_state();
    let rs = state.rank_state.clone().expect("adaptive run must snapshot \
         its rank state");
    assert_ne!(
        rs.ranks,
        vec![BASE_RANK as u32, BASE_RANK as u32, 0],
        "controller must have committed a rank change before the snapshot"
    );
    let pending = state.pending_refresh.as_ref().expect("in-flight refresh");
    assert_eq!(pending.boundary, 2 * PERIOD_K as u64);
    assert!(
        pending.prepared.rank_state.is_some(),
        "planned refresh must carry the controller bookkeeping"
    );

    let path = std::env::temp_dir().join("gum_rank_change_resume.bin");
    save_train_state(&state, &path).unwrap();
    let loaded = load_train_state(&path).unwrap();
    assert_eq!(loaded.rank_state, state.rank_state);
    assert_eq!(loaded.pending_refresh, state.pending_refresh);

    let mut b = gum_session(&schedule);
    let mut sb = srcs(&b);
    b.restore_train_state(&loaded).unwrap();
    assert_eq!(b.opt.rank_state(), state.rank_state);

    let (mut la, mut lb) = (Vec::new(), Vec::new());
    for _ in 0..2 * PERIOD_K + 3 {
        la.push(a.global_step(&mut sa).unwrap().loss);
        lb.push(b.global_step(&mut sb).unwrap().loss);
    }
    assert_eq!(la, lb, "resumed adaptive trace diverged");
    for (x, y) in a.params.blocks.iter().zip(&b.params.blocks) {
        assert_eq!(x.value, y.value, "{}", x.name);
    }
    assert_eq!(
        a.opt.rank_state(),
        b.opt.rank_state(),
        "rank decisions diverged after resume"
    );
}

/// Checkpoints written by fixed-schedule runs carry no RANKS section
/// (byte-compatible with the pre-adaptive writer) and still load and
/// resume; feeding an *adaptive* checkpoint into a fixed-built session
/// is a config mismatch, rejected with a clear error.
#[test]
fn fixed_checkpoint_has_no_ranks_section_and_mismatch_is_rejected() {
    let mut a = gum_session(&RankSchedule::Fixed);
    let mut sa = srcs(&a);
    for _ in 0..PERIOD_K + 2 {
        a.global_step(&mut sa).unwrap();
    }
    let state = a.train_state();
    assert!(state.rank_state.is_none(), "fixed run must not carry RANKS");

    let path = std::env::temp_dir().join("gum_fixed_no_ranks.bin");
    save_train_state(&state, &path).unwrap();
    let loaded = load_train_state(&path).unwrap();
    assert!(loaded.rank_state.is_none());

    // Fixed → fixed resumes bitwise.
    let mut b = gum_session(&RankSchedule::Fixed);
    let mut sb = srcs(&b);
    b.restore_train_state(&loaded).unwrap();
    for _ in 0..PERIOD_K {
        let la = a.global_step(&mut sa).unwrap().loss;
        let lb = b.global_step(&mut sb).unwrap().loss;
        assert_eq!(la, lb);
    }

    // Adaptive checkpoint into a fixed session: refused, not corrupted.
    let mut adaptive_state = loaded;
    adaptive_state.rank_state = Some(RankState {
        ranks: vec![6, 6, 0],
        pressure: vec![0, 0, 0],
    });
    let mut c = gum_session(&RankSchedule::Fixed);
    let err = c
        .restore_train_state(&adaptive_state)
        .expect_err("fixed session must reject adaptive rank state");
    let msg = format!("{err:#}");
    assert!(msg.contains("rank"), "unhelpful mismatch diagnostic: {msg}");
}
