//! Coordinator integration: short end-to-end training runs per
//! optimizer, checkpoint round-trips through the trainer, probe
//! evaluation, and data pairing. Requires `make artifacts`.

use std::path::PathBuf;

use gum::coordinator::{load_checkpoint, TrainConfig, Trainer};

fn base_cfg(optimizer: &str, steps: usize) -> TrainConfig {
    assert!(
        PathBuf::from("artifacts/manifest.json").exists(),
        "artifacts missing — run `make artifacts` before `cargo test`"
    );
    gum::util::logging::set_level(1);
    TrainConfig {
        model: "micro".into(),
        optimizer: optimizer.into(),
        lr: 8e-3,
        steps,
        period_k: 10,
        rank: 16,
        gamma: 2.0,
        seed: 42,
        warmup: 2,
        log_every: 0,
        ..TrainConfig::default()
    }
}

#[test]
fn every_optimizer_trains_and_reduces_loss() {
    for opt in [
        "sgdm", "adamw", "muon", "galore-muon", "galore-adam",
        "golore-muon", "fira", "lisa", "gum",
    ] {
        let result = Trainer::new(base_cfg(opt, 40)).run().unwrap();
        let first = result.metrics.series("train_loss")[0].1;
        let last = result.final_train_loss;
        assert!(
            last < first,
            "{opt}: loss did not decrease ({first} -> {last})"
        );
        assert!(last.is_finite(), "{opt}: non-finite loss");
    }
}

#[test]
fn training_is_deterministic_per_seed() {
    let a = Trainer::new(base_cfg("gum", 12)).run().unwrap();
    let b = Trainer::new(base_cfg("gum", 12)).run().unwrap();
    assert_eq!(
        a.metrics.series("train_loss"),
        b.metrics.series("train_loss"),
        "same seed must replay identically"
    );
    let mut cfg = base_cfg("gum", 12);
    cfg.seed = 43;
    let c = Trainer::new(cfg).run().unwrap();
    assert_ne!(
        a.metrics.series("train_loss"),
        c.metrics.series("train_loss")
    );
}

#[test]
fn data_order_is_paired_across_optimizers() {
    // The first-step loss (before any update differences) must be
    // identical across optimizers: same init, same first batch.
    let a = Trainer::new(base_cfg("adamw", 2)).run().unwrap();
    let b = Trainer::new(base_cfg("gum", 2)).run().unwrap();
    assert_eq!(
        a.metrics.series("train_loss")[0].1,
        b.metrics.series("train_loss")[0].1
    );
}

#[test]
fn checkpoints_written_and_loadable() {
    let dir = std::env::temp_dir().join("gum_train_ckpt_test");
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = base_cfg("gum", 10);
    cfg.ckpt_every = 5;
    cfg.out_dir = Some(dir.clone());
    let result = Trainer::new(cfg).run().unwrap();
    let ck = load_checkpoint(&dir.join("ckpt_000005.bin")).unwrap();
    assert_eq!(ck.blocks.len(), result.params.blocks.len());
    let fin = load_checkpoint(&dir.join("final.bin")).unwrap();
    for (a, b) in fin.blocks.iter().zip(&result.params.blocks) {
        assert_eq!(a.value, b.value, "{}", a.name);
    }
    assert!(dir.join("metrics.csv").exists());
}

#[test]
fn probe_suite_runs_and_scores_in_range() {
    let mut cfg = base_cfg("muon", 8);
    cfg.probes = true;
    cfg.probe_items = 8;
    let result = Trainer::new(cfg).run().unwrap();
    assert_eq!(result.probe_scores.len(), 7, "7 domains");
    for (name, acc) in &result.probe_scores {
        assert!(
            (0.0..=1.0).contains(acc),
            "{name}: accuracy {acc} out of range"
        );
    }
}

#[test]
fn gum_state_smaller_than_adamw_state() {
    let gum = Trainer::new(base_cfg("gum", 6)).run().unwrap();
    let adamw = Trainer::new(base_cfg("adamw", 6)).run().unwrap();
    assert!(
        gum.state_bytes < adamw.state_bytes,
        "gum {} !< adamw {}",
        gum.state_bytes,
        adamw.state_bytes
    );
}

#[test]
fn unknown_optimizer_is_clean_error() {
    match Trainer::new(base_cfg("sophia", 2)).run() {
        Ok(_) => panic!("unknown optimizer must error"),
        Err(err) => {
            assert!(format!("{err:#}").contains("unknown optimizer"))
        }
    }
}
