//! Coordinator integration: short end-to-end training runs per
//! optimizer, checkpoint round-trips through the trainer, probe
//! evaluation, data pairing, and data-parallel equivalence through the
//! real PJRT gradient path. Every test skips (with a note) when the AOT
//! artifacts have not been built, so a fresh clone still passes
//! `cargo test`; run `make artifacts` to enable the full suite.

use std::path::PathBuf;

use gum::coordinator::{load_checkpoint, ShardMode, TrainConfig, Trainer};

fn artifacts_present() -> bool {
    let present = PathBuf::from("artifacts/manifest.json").exists();
    if !present {
        eprintln!("skipping: artifacts missing — run `make artifacts`");
    }
    present
}

fn base_cfg(optimizer: &str, steps: usize) -> TrainConfig {
    gum::util::logging::set_level(1);
    TrainConfig {
        model: "micro".into(),
        optimizer: optimizer.into(),
        lr: 8e-3,
        steps,
        period_k: 10,
        rank: 16,
        gamma: 2.0,
        seed: 42,
        warmup: 2,
        log_every: 0,
        ..TrainConfig::default()
    }
}

#[test]
fn every_optimizer_trains_and_reduces_loss() {
    if !artifacts_present() {
        return;
    }
    for opt in [
        "sgdm", "adamw", "muon", "galore-muon", "galore-adam",
        "golore-muon", "fira", "lisa", "gum",
    ] {
        let result = Trainer::new(base_cfg(opt, 40)).run().unwrap();
        let first = result.metrics.series("train_loss")[0].1;
        let last = result.final_train_loss;
        assert!(
            last < first,
            "{opt}: loss did not decrease ({first} -> {last})"
        );
        assert!(last.is_finite(), "{opt}: non-finite loss");
    }
}

#[test]
fn training_is_deterministic_per_seed() {
    if !artifacts_present() {
        return;
    }
    let a = Trainer::new(base_cfg("gum", 12)).run().unwrap();
    let b = Trainer::new(base_cfg("gum", 12)).run().unwrap();
    assert_eq!(
        a.metrics.series("train_loss"),
        b.metrics.series("train_loss"),
        "same seed must replay identically"
    );
    let mut cfg = base_cfg("gum", 12);
    cfg.seed = 43;
    let c = Trainer::new(cfg).run().unwrap();
    assert_ne!(
        a.metrics.series("train_loss"),
        c.metrics.series("train_loss")
    );
}

#[test]
fn data_order_is_paired_across_optimizers() {
    if !artifacts_present() {
        return;
    }
    // The first-step loss (before any update differences) must be
    // identical across optimizers: same init, same first batch.
    let a = Trainer::new(base_cfg("adamw", 2)).run().unwrap();
    let b = Trainer::new(base_cfg("gum", 2)).run().unwrap();
    assert_eq!(
        a.metrics.series("train_loss")[0].1,
        b.metrics.series("train_loss")[0].1
    );
}

/// Data-parallel equivalence through the real PJRT gradient path: a
/// 4-lane run over the same global batch matches the 1-lane golden
/// trace within 1e-5 per block.
#[test]
fn data_parallel_trainer_matches_sequential_golden_trace() {
    if !artifacts_present() {
        return;
    }
    // Interleaved sharding: both runs consume the *same* global token
    // stream, split 1×4 vs 4×1.
    let mut golden_cfg = base_cfg("gum", 10);
    golden_cfg.replicas = 1;
    golden_cfg.accum_steps = 4;
    golden_cfg.shard_mode = ShardMode::Interleaved;
    let golden = Trainer::new(golden_cfg).run().unwrap();

    let mut wide_cfg = base_cfg("gum", 10);
    wide_cfg.replicas = 4;
    wide_cfg.accum_steps = 1;
    wide_cfg.shard_mode = ShardMode::Interleaved;
    let wide = Trainer::new(wide_cfg).run().unwrap();

    let gl = golden.metrics.series("train_loss");
    let wl = wide.metrics.series("train_loss");
    assert_eq!(gl.len(), wl.len());
    for ((_, a), (_, b)) in gl.iter().zip(&wl) {
        assert!((a - b).abs() < 1e-5, "loss trace diverged: {a} vs {b}");
    }
    for (x, y) in golden.params.blocks.iter().zip(&wide.params.blocks) {
        let diff = x.value.max_abs_diff(&y.value);
        assert!(diff < 1e-5, "block {}: max diff {diff}", x.name);
    }
}

#[test]
fn checkpoints_written_and_loadable() {
    if !artifacts_present() {
        return;
    }
    let dir = std::env::temp_dir().join("gum_train_ckpt_test");
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = base_cfg("gum", 10);
    cfg.ckpt_every = 5;
    cfg.out_dir = Some(dir.clone());
    let result = Trainer::new(cfg).run().unwrap();
    let ck = load_checkpoint(&dir.join("ckpt_000005.bin")).unwrap();
    assert_eq!(ck.blocks.len(), result.params.blocks.len());
    let fin = load_checkpoint(&dir.join("final.bin")).unwrap();
    for (a, b) in fin.blocks.iter().zip(&result.params.blocks) {
        assert_eq!(a.value, b.value, "{}", a.name);
    }
    assert!(dir.join("metrics.csv").exists());
    // The resumable GUMCKPT2 sibling rides along with every v1 file.
    assert!(dir.join("state_000005.bin").exists());
}

/// Mid-period trainer resume through the CLI-visible config surface: a
/// run checkpointed at step 5 (period_k = 10) and resumed must land on
/// the same parameters as the uninterrupted run.
#[test]
fn trainer_resume_from_state_matches_uninterrupted() {
    if !artifacts_present() {
        return;
    }
    let dir = std::env::temp_dir().join("gum_train_resume_test");
    let _ = std::fs::remove_dir_all(&dir);

    let full = Trainer::new(base_cfg("gum", 12)).run().unwrap();

    let mut head_cfg = base_cfg("gum", 12);
    head_cfg.steps = 12;
    head_cfg.ckpt_every = 5;
    head_cfg.out_dir = Some(dir.clone());
    let _ = Trainer::new(head_cfg).run().unwrap();

    let mut tail_cfg = base_cfg("gum", 12);
    tail_cfg.resume_from = Some(dir.join("state_000005.bin"));
    let resumed = Trainer::new(tail_cfg).run().unwrap();

    for (a, b) in full.params.blocks.iter().zip(&resumed.params.blocks) {
        assert_eq!(a.value, b.value, "{}", a.name);
    }
}

#[test]
fn probe_suite_runs_and_scores_in_range() {
    if !artifacts_present() {
        return;
    }
    let mut cfg = base_cfg("muon", 8);
    cfg.probes = true;
    cfg.probe_items = 8;
    let result = Trainer::new(cfg).run().unwrap();
    assert_eq!(result.probe_scores.len(), 7, "7 domains");
    for (name, acc) in &result.probe_scores {
        assert!(
            (0.0..=1.0).contains(acc),
            "{name}: accuracy {acc} out of range"
        );
    }
}

#[test]
fn gum_state_smaller_than_adamw_state() {
    if !artifacts_present() {
        return;
    }
    let gum = Trainer::new(base_cfg("gum", 6)).run().unwrap();
    let adamw = Trainer::new(base_cfg("adamw", 6)).run().unwrap();
    assert!(
        gum.state_bytes < adamw.state_bytes,
        "gum {} !< adamw {}",
        gum.state_bytes,
        adamw.state_bytes
    );
}

#[test]
fn unknown_optimizer_is_clean_error() {
    if !artifacts_present() {
        return;
    }
    match Trainer::new(base_cfg("sophia", 2)).run() {
        Ok(_) => panic!("unknown optimizer must error"),
        Err(err) => {
            assert!(format!("{err:#}").contains("unknown optimizer"))
        }
    }
}
