//! The data-parallel contract, locked in end-to-end (no AOT artifacts
//! needed):
//!
//! 1. **Equivalence** — an N-replica run over the same global batch
//!    matches the 1-replica golden trace within 1e-5 per block (bitwise
//!    for the power-of-two windows exercised here).
//! 2. **Determinism** — the tree all-reduce is bit-identical under any
//!    `GUM_THREADS` width (1, 2, 8), and so is a whole training session.
//! 3. **Sampling invariance** — GUM's `full_rank_mask` sequence is
//!    unchanged by the replica count.
//! 4. **Mid-period resume** — projector, momentum, and sampler state
//!    round-trip through a `GUMCKPT2` file so a resumed run replays the
//!    uninterrupted one exactly.

use gum::coordinator::{
    pairwise_tree_sum, save_train_state, tree_all_reduce, LrSchedule,
    ParallelConfig, ParallelSession, ShardMode, ShardedBatcher,
    SyntheticGradSource,
};
use gum::data::corpus::CorpusSpec;
use gum::data::tokenizer::ByteTokenizer;
use gum::linalg::Matrix;
use gum::model::{BlockKind, ParamBlock, ParamStore};
use gum::optim::{self, Gum, RefreshStrategy};
use gum::rng::Pcg;

const BATCH: usize = 4;
const SEQ: usize = 32;
const PERIOD_K: usize = 5;

/// Serializes the tests that flip the process-global chunking width —
/// without this, two width tests interleaving could run each other's
/// widths (passing vacuously) or leave a temporary override behind.
static WIDTH_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Small multi-block store: three projectable matrices + one dense norm,
/// big enough to exercise left/right projection and the dense AdamW path
/// without paying micro-model Newton–Schulz costs per test.
fn small_store() -> ParamStore {
    let mut rng = Pcg::new(5);
    let blocks = vec![
        ParamBlock {
            name: "w0".into(),
            shape: vec![24, 32],
            kind: BlockKind::Projectable,
            value: Matrix::randn(24, 32, 0.1, &mut rng),
        },
        ParamBlock {
            name: "w1".into(),
            shape: vec![32, 24],
            kind: BlockKind::Projectable,
            value: Matrix::randn(32, 24, 0.1, &mut rng),
        },
        ParamBlock {
            name: "w2".into(),
            shape: vec![16, 16],
            kind: BlockKind::Projectable,
            value: Matrix::randn(16, 16, 0.1, &mut rng),
        },
        ParamBlock {
            name: "norm".into(),
            shape: vec![16],
            kind: BlockKind::Dense,
            value: Matrix::from_vec(1, 16, vec![1.0; 16]),
        },
    ];
    ParamStore { blocks }
}

fn session(replicas: usize, accum: usize, mode: ShardMode) -> ParallelSession {
    session_with(replicas, accum, mode, RefreshStrategy::default())
}

fn session_with(
    replicas: usize,
    accum: usize,
    mode: ShardMode,
    refresh: RefreshStrategy,
) -> ParallelSession {
    let params = small_store();
    let opt =
        optim::build_with_refresh("gum", &params, 4, 1.0, 99, refresh)
            .unwrap();
    let pcfg = ParallelConfig {
        replicas,
        accum_steps: accum,
        shard_mode: mode,
        doc_stride: 500_000,
    };
    let batcher = ShardedBatcher::new(
        &CorpusSpec::default(),
        &ByteTokenizer::new(256),
        BATCH,
        SEQ,
        &pcfg,
    );
    ParallelSession::new(
        params,
        opt,
        batcher,
        PERIOD_K,
        LrSchedule::constant(0.02),
        17,
    )
}

fn sources(session: &ParallelSession, n: usize) -> Vec<SyntheticGradSource> {
    vec![SyntheticGradSource::new(&session.params, 23); n]
}

/// Golden-trace equivalence: splits of the same 4-micro-batch global
/// step — (replicas, accum) ∈ {(1,4), (2,2), (4,1)} — must agree on the
/// loss trace and on every parameter block within 1e-5.
#[test]
fn replica_splits_match_single_replica_golden_trace() {
    let variants = [(1usize, 4usize), (2, 2), (4, 1)];
    let mut runs: Vec<(Vec<f64>, ParamStore)> = Vec::new();
    for (replicas, accum) in variants {
        let mut s = session(replicas, accum, ShardMode::Interleaved);
        let mut srcs = sources(&s, replicas);
        let mut losses = Vec::new();
        for _ in 0..12 {
            losses.push(s.global_step(&mut srcs).unwrap().loss);
        }
        runs.push((losses, s.params));
    }
    let (golden_losses, golden_params) = &runs[0];
    for (i, (losses, params)) in runs.iter().enumerate().skip(1) {
        let (replicas, accum) = variants[i];
        for (a, b) in golden_losses.iter().zip(losses) {
            assert!(
                (a - b).abs() < 1e-5,
                "{replicas}x{accum}: loss trace diverged ({a} vs {b})"
            );
        }
        for (x, y) in golden_params.blocks.iter().zip(&params.blocks) {
            let diff = x.value.max_abs_diff(&y.value);
            assert!(
                diff < 1e-5,
                "{replicas}x{accum}: block {} max diff {diff}",
                x.name
            );
        }
    }
}

/// The all-reduce is bit-identical however wide the chunking runs — the
/// in-process equivalent of relaunching with GUM_THREADS ∈ {1, 2, 8}.
#[test]
fn tree_all_reduce_bit_identical_across_thread_widths() {
    let _w = WIDTH_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut rng = Pcg::new(3);
    let per_replica: Vec<Vec<Matrix>> = (0..8)
        .map(|_| {
            vec![
                Matrix::randn(17, 9, 1.0, &mut rng),
                Matrix::randn(3, 41, 1.0, &mut rng),
                Matrix::randn(1, 7, 1.0, &mut rng),
            ]
        })
        .collect();
    let orig = gum::thread::num_threads();
    let mut outs = Vec::new();
    for width in [1usize, 2, 8] {
        gum::thread::set_num_threads(width);
        outs.push(tree_all_reduce(&per_replica));
    }
    gum::thread::set_num_threads(orig);
    for (i, out) in outs.iter().enumerate().skip(1) {
        assert_eq!(
            &outs[0], out,
            "width {} changed the all-reduce bytes",
            [1, 2, 8][i]
        );
    }
    // And the parallel reduction equals the sequential per-block tree.
    for (b, got) in outs[0].iter().enumerate() {
        let want = pairwise_tree_sum(
            per_replica.iter().map(|g| g[b].clone()).collect(),
        );
        assert_eq!(got, &want);
    }
}

/// Whole-session determinism: a 2×2 data-parallel run produces
/// bit-identical parameters and losses under any thread width.
#[test]
fn training_session_bit_identical_across_thread_widths() {
    let _w = WIDTH_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let run = |width: usize| {
        let orig = gum::thread::num_threads();
        gum::thread::set_num_threads(width);
        let mut s = session(2, 2, ShardMode::Interleaved);
        let mut srcs = sources(&s, 2);
        let mut losses = Vec::new();
        for _ in 0..10 {
            losses.push(s.global_step(&mut srcs).unwrap().loss);
        }
        gum::thread::set_num_threads(orig);
        (losses, s.params)
    };
    let (l1, p1) = run(1);
    let (l2, p2) = run(2);
    let (l8, p8) = run(8);
    assert_eq!(l1, l2);
    assert_eq!(l1, l8);
    assert_eq!(p1, p2);
    assert_eq!(p1, p8);
}

/// GUM's layerwise full-rank sampling sequence is a function of the
/// optimizer seed and the period count only — never the replica layout.
#[test]
fn gum_full_rank_mask_sequence_unchanged_by_replica_count() {
    let collect_masks = |replicas: usize, accum: usize| {
        let mut s = session(replicas, accum, ShardMode::Interleaved);
        let mut srcs = sources(&s, replicas);
        let mut masks = Vec::new();
        for step in 0..3 * PERIOD_K {
            s.global_step(&mut srcs).unwrap();
            if step % PERIOD_K == 0 {
                let g = s
                    .opt
                    .as_any()
                    .and_then(|a| a.downcast_ref::<Gum>())
                    .expect("session runs GUM");
                masks.push(g.full_rank_mask());
            }
        }
        masks
    };
    let golden = collect_masks(1, 4);
    assert_eq!(golden.len(), 3);
    assert_eq!(golden, collect_masks(2, 2));
    assert_eq!(golden, collect_masks(4, 1));
}

/// Mid-period save/resume: snapshot at a non-boundary step, round-trip
/// through the GUMCKPT2 file, and replay — the resumed run must match
/// the uninterrupted one bit-for-bit (projector, momentum, sampler,
/// lane positions, coordinator RNG all restored).
#[test]
fn mid_period_checkpoint_resume_matches_uninterrupted() {
    let mut a = session(2, 2, ShardMode::Interleaved);
    let mut sa = sources(&a, 2);
    for _ in 0..8 {
        a.global_step(&mut sa).unwrap();
    }
    assert_ne!(a.step % PERIOD_K, 0, "snapshot must land mid-period");
    let state = a.train_state();
    assert!(state.opt.is_some(), "GUM must produce an optimizer snapshot");

    let path = std::env::temp_dir().join("gum_parallel_resume_test.bin");
    save_train_state(&state, &path).unwrap();
    let loaded = gum::coordinator::load_train_state(&path).unwrap();

    let mut b = session(2, 2, ShardMode::Interleaved);
    let mut sb = sources(&b, 2);
    b.restore_train_state(&loaded).unwrap();
    assert_eq!(b.step, 8);

    let mut la = Vec::new();
    let mut lb = Vec::new();
    for _ in 0..7 {
        la.push(a.global_step(&mut sa).unwrap().loss);
        lb.push(b.global_step(&mut sb).unwrap().loss);
    }
    assert_eq!(la, lb, "resumed loss trace must match uninterrupted run");
    for (x, y) in a.params.blocks.iter().zip(&b.params.blocks) {
        assert_eq!(x.value, y.value, "{}", x.name);
    }
}

/// The equivalence and sampling-invariance contracts survive the new
/// projector-refresh strategies: replica splits of the same global batch
/// agree, and GUM's full-rank mask sequence is unchanged by the replica
/// layout, under both exact-Jacobi and warm-started refreshes. (The rsvd
/// sketch streams are derived from the optimizer seed + period counter,
/// never from lane-dependent state.)
#[test]
fn replica_equivalence_holds_under_refresh_strategies() {
    for refresh in [RefreshStrategy::ExactJacobi, RefreshStrategy::WarmStart]
    {
        let run = |replicas: usize, accum: usize| {
            let mut s = session_with(
                replicas,
                accum,
                ShardMode::Interleaved,
                refresh,
            );
            let mut srcs = sources(&s, replicas);
            let mut losses = Vec::new();
            let mut masks = Vec::new();
            for step in 0..2 * PERIOD_K {
                losses.push(s.global_step(&mut srcs).unwrap().loss);
                if step % PERIOD_K == 0 {
                    let g = s
                        .opt
                        .as_any()
                        .and_then(|a| a.downcast_ref::<Gum>())
                        .expect("session runs GUM");
                    masks.push(g.full_rank_mask());
                }
            }
            (losses, masks, s.params)
        };
        let (gl, gm, gp) = run(1, 4);
        for (replicas, accum) in [(2usize, 2usize), (4, 1)] {
            let (l, m, p) = run(replicas, accum);
            for (a, b) in gl.iter().zip(&l) {
                assert!(
                    (a - b).abs() < 1e-5,
                    "{replicas}x{accum} ({:?}): loss diverged ({a} vs {b})",
                    refresh
                );
            }
            assert_eq!(
                gm, m,
                "{replicas}x{accum} ({refresh:?}): mask sequence changed"
            );
            for (x, y) in gp.blocks.iter().zip(&p.blocks) {
                let diff = x.value.max_abs_diff(&y.value);
                assert!(
                    diff < 1e-5,
                    "{replicas}x{accum} ({refresh:?}): block {} diff {diff}",
                    x.name
                );
            }
        }
    }
}

/// Doc-partition sharding streams disjoint lanes and still trains: the
/// production layout smoke check.
#[test]
fn doc_partition_session_trains_and_reduces_loss() {
    let mut s = session(4, 1, ShardMode::DocPartition);
    let mut srcs = sources(&s, 4);
    let first = s.global_step(&mut srcs).unwrap().loss;
    let mut last = first;
    for _ in 0..24 {
        last = s.global_step(&mut srcs).unwrap().loss;
    }
    assert!(last.is_finite());
    assert!(
        last < first,
        "synthetic quadratic must descend ({first} -> {last})"
    );
}
