//! Property suite for the linalg substrate behind the projector-refresh
//! engine, seeded through `testing::check` generators:
//!
//! 1. rsvd factors are well-formed: U orthonormal, singular values
//!    descending, and (on low-rank-plus-noise inputs) matching the exact
//!    Jacobi reference.
//! 2. rsvd reconstruction error sits within the Eckart–Young optimum
//!    plus tolerance.
//! 3. QR invariants: orthonormality, span preservation, and span
//!    invariance under scaling/transposed regeneration.
//! 4. Newton–Schulz invariants under transpose and positive scaling.
//!
//! Every failure reports its generator seed for replay
//! (`GUM_PROP_SEED` / `testing::check_seed`).

use gum::linalg::{
    fro_norm, matmul, matmul_tn, newton_schulz, qr_orthonormal, rsvd,
    singular_values, svd_thin, top_singular_vectors, Matrix, RsvdOpts,
    NS_STEPS,
};
use gum::testing::{self, Gen};

/// Strong rank-k signal plus small dense noise — the separated-spectrum
/// regime GaLore exploits and rsvd is specified for.
fn low_rank_plus_noise(
    gen: &mut Gen,
    m: usize,
    n: usize,
    k: usize,
    noise: f32,
) -> Matrix {
    let u = Matrix::randn(m, k, 1.0, &mut gen.rng);
    let v = Matrix::randn(k, n, 1.0, &mut gen.rng);
    let mut a = matmul(&u, &v);
    a.add_scaled_in_place(noise, &Matrix::randn(m, n, 1.0, &mut gen.rng));
    a
}

fn assert_orthonormal(q: &Matrix, tol: f32, ctx: &str) {
    let qtq = matmul_tn(q, q);
    let err = qtq.max_abs_diff(&Matrix::eye(q.cols));
    assert!(err < tol, "{ctx}: QᵀQ − I = {err}");
}

/// Orthonormal bases span the same subspace iff the cross-Gram
/// (PᵀQ)ᵀ(PᵀQ) is the identity.
fn assert_same_subspace(p: &Matrix, q: &Matrix, tol: f32, ctx: &str) {
    assert_eq!(p.shape(), q.shape(), "{ctx}: shape");
    let cross = matmul_tn(p, q);
    let gram = matmul_tn(&cross, &cross);
    let err = gram.max_abs_diff(&Matrix::eye(p.cols));
    assert!(err < tol, "{ctx}: subspace distance {err}");
}

#[test]
fn rsvd_u_orthonormal_and_values_descending() {
    testing::check(16, |gen| {
        let m = gen.dim(4, 48);
        let n = gen.dim(4, 48);
        let k = gen.dim(1, m.min(n).min(6));
        let r = gen.dim(1, m.min(n));
        let a = low_rank_plus_noise(gen, m, n, k, 0.05);
        let svd = rsvd(&a, r, &RsvdOpts::default(), None, &mut gen.rng);
        let rr = r.min(m.min(n));
        assert_eq!(svd.u.shape(), (m, rr));
        assert_eq!(svd.vt.shape(), (rr, n));
        assert_eq!(svd.s.len(), rr);
        assert_orthonormal(&svd.u, 1e-3, "rsvd U");
        for w in svd.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-4, "σ not descending: {:?}", svd.s);
        }
        assert!(svd.s.iter().all(|v| *v >= 0.0 && v.is_finite()));
    });
}

#[test]
fn rsvd_matches_exact_jacobi_on_low_rank_plus_noise() {
    testing::check(12, |gen| {
        let m = gen.dim(8, 40);
        let n = gen.dim(8, 40);
        let k = gen.dim(1, m.min(n).min(4));
        let a = low_rank_plus_noise(gen, m, n, k, 0.01);
        // Values: top-k from rsvd vs exact Jacobi.
        let exact = singular_values(&a);
        let svd = rsvd(&a, k, &RsvdOpts::default(), None, &mut gen.rng);
        for (i, (&got, &want)) in svd.s.iter().zip(&exact).enumerate() {
            assert!(
                (got - want).abs() < 1e-2 * (1.0 + want),
                "σ{i}: randomized {got} vs exact {want}"
            );
        }
        // Vectors: dominant subspaces agree.
        let exact_u = top_singular_vectors(&a, k);
        assert_same_subspace(&exact_u, &svd.u, 2e-2, "top-k subspace");
    });
}

#[test]
fn rsvd_reconstruction_within_eckart_young_bound() {
    testing::check(12, |gen| {
        let m = gen.dim(6, 40);
        let n = gen.dim(6, 40);
        let k = gen.dim(1, m.min(n).min(5));
        let r = gen.dim(k, m.min(n)); // r ≥ signal rank
        let a = low_rank_plus_noise(gen, m, n, k, 0.05);

        // Optimal rank-r residual from the exact factorization
        // (Eckart–Young): ‖A − A_r‖_F² = Σ_{i>r} σᵢ².
        let s = singular_values(&a);
        let opt_resid: f32 = s[r.min(s.len())..]
            .iter()
            .map(|v| v * v)
            .sum::<f32>()
            .sqrt();

        let svd = rsvd(&a, r, &RsvdOpts::default(), None, &mut gen.rng);
        let mut us = svd.u.clone();
        for i in 0..us.rows {
            for j in 0..svd.s.len() {
                us.data[i * us.cols + j] *= svd.s[j];
            }
        }
        let rec = matmul(&us, &svd.vt);
        let resid = fro_norm(&a.sub(&rec));
        assert!(
            resid <= 2.0 * opt_resid + 1e-3 * (1.0 + fro_norm(&a)),
            "rsvd residual {resid} vs Eckart–Young optimum {opt_resid}"
        );
    });
}

#[test]
fn warm_start_matches_exact_after_drift() {
    testing::check(8, |gen| {
        let m = gen.dim(10, 40);
        let n = gen.dim(10, 40);
        let k = gen.dim(1, m.min(n).min(4));
        let a = low_rank_plus_noise(gen, m, n, k, 0.01);
        let cold = rsvd(&a, k, &RsvdOpts::default(), None, &mut gen.rng);
        let mut a2 = a.clone();
        a2.add_scaled_in_place(
            0.05,
            &Matrix::randn(m, n, 1.0, &mut gen.rng),
        );
        let warm_opts = RsvdOpts {
            oversample: 4,
            power_iters: 1,
        };
        let warm = rsvd(&a2, k, &warm_opts, Some(&cold.u), &mut gen.rng);
        let exact = top_singular_vectors(&a2, k);
        assert_same_subspace(&exact, &warm.u, 2e-2, "warm after drift");
        assert_orthonormal(&warm.u, 1e-3, "warm U");
    });
}

/// Warm-started re-ranking — the adaptive rank schedule's core move.
/// When the controller shrinks or grows a block's rank, the refresh
/// reuses the previous basis at the *new* width (rsvd truncates or
/// Gaussian-pads the warm columns) instead of paying a cold SVD. Both
/// directions must keep the factorization well-formed and near-optimal.
#[test]
fn warm_basis_survives_rank_shrink_and_grow() {
    testing::check(8, |gen| {
        let m = gen.dim(14, 40);
        let n = gen.dim(14, 40);
        let (lo, hi) = (4usize, 10usize);
        // Signal rank = lo, so the top-lo subspace is spectrally
        // separated and the shrink target is well-defined.
        let a = low_rank_plus_noise(gen, m, n, lo, 0.01);
        let mut a2 = a.clone();
        a2.add_scaled_in_place(
            0.05,
            &Matrix::randn(m, n, 1.0, &mut gen.rng),
        );
        let warm_opts = RsvdOpts {
            oversample: 4,
            power_iters: 1,
        };
        let resid = |q: &Matrix, a: &Matrix| {
            fro_norm(&a.sub(&matmul(q, &matmul_tn(q, a))))
        };

        // Shrink: a wide (rank-hi) basis warm-starts a rank-lo rebuild.
        let cold_hi = rsvd(&a, hi, &RsvdOpts::default(), None, &mut gen.rng);
        assert_eq!(cold_hi.u.shape(), (m, hi));
        let shrunk = rsvd(&a2, lo, &warm_opts, Some(&cold_hi.u), &mut gen.rng);
        assert_eq!(shrunk.u.shape(), (m, lo), "shrink truncates the basis");
        assert_orthonormal(&shrunk.u, 1e-3, "shrunk U");
        let exact_lo = top_singular_vectors(&a2, lo);
        assert_same_subspace(&exact_lo, &shrunk.u, 2e-2, "shrink subspace");

        // Grow: a narrow (rank-lo) basis warm-starts a rank-hi rebuild.
        let cold_lo = rsvd(&a, lo, &RsvdOpts::default(), None, &mut gen.rng);
        let grown = rsvd(&a2, hi, &warm_opts, Some(&cold_lo.u), &mut gen.rng);
        assert_eq!(grown.u.shape(), (m, hi), "grow pads the basis");
        assert_orthonormal(&grown.u, 1e-3, "grown U");
        for w in grown.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-4, "σ not descending: {:?}", grown.s);
        }
        // The grown span still contains the dominant (signal) subspace:
        // QQᵀ·U_lo ≈ U_lo.
        let proj = matmul(&grown.u, &matmul_tn(&grown.u, &exact_lo));
        assert!(
            proj.max_abs_diff(&exact_lo) < 5e-2,
            "grown basis lost the signal subspace"
        );
        // Eckart–Young monotonicity: widening the basis can only reduce
        // the residual the projector leaves behind.
        let r_lo = resid(&shrunk.u, &a2);
        let r_hi = resid(&grown.u, &a2);
        assert!(
            r_hi <= r_lo + 1e-3 * (1.0 + fro_norm(&a2)),
            "rank-{hi} residual {r_hi} worse than rank-{lo} {r_lo}"
        );
    });
}

#[test]
fn qr_orthonormal_invariants_under_scaling() {
    testing::check(16, |gen| {
        let m = gen.dim(2, 40);
        let k = gen.dim(1, m);
        let a = gen.matrix(m, k);
        let q = qr_orthonormal(&a);
        assert_orthonormal(&q, 1e-4, "Q");
        // Span preservation: QQᵀA = A.
        let proj = matmul(&q, &matmul_tn(&q, &a));
        assert!(
            proj.max_abs_diff(&a) < 1e-3 * (1.0 + fro_norm(&a)),
            "span(Q) must contain col(A)"
        );
        // Positive scaling leaves the span (hence the projector QQᵀ)
        // unchanged.
        let c = gen.f32_in(0.1, 10.0);
        let q2 = qr_orthonormal(&a.scaled(c));
        let p1 = matmul(&q, &q.transpose());
        let p2 = matmul(&q2, &q2.transpose());
        assert!(
            p1.max_abs_diff(&p2) < 1e-3,
            "QQᵀ changed under scaling by {c}"
        );
    });
}

#[test]
fn newton_schulz_invariant_under_transpose_and_scaling() {
    testing::check(12, |gen| {
        let m = gen.dim(2, 24);
        let n = gen.dim(2, 24);
        let a = gen.matrix(m, n);
        let ns = newton_schulz(&a, NS_STEPS);
        assert_eq!(ns.shape(), (m, n));
        assert!(ns.is_finite());
        // msign(Aᵀ) = msign(A)ᵀ.
        let ns_t = newton_schulz(&a.transpose(), NS_STEPS);
        assert!(
            ns_t.max_abs_diff(&ns.transpose()) < 1e-3,
            "transpose equivariance"
        );
        // msign(cA) = msign(A) for c > 0 (Frobenius pre-normalization).
        let c = gen.f32_in(0.5, 5.0);
        let ns_c = newton_schulz(&a.scaled(c), NS_STEPS);
        assert!(
            ns_c.max_abs_diff(&ns) < 1e-3,
            "scale invariance at c = {c}"
        );
    });
}

#[test]
fn exact_svd_values_descend_and_capture_frobenius_mass() {
    testing::check(12, |gen| {
        let m = gen.dim(2, 32);
        let n = gen.dim(2, 32);
        let k = gen.dim(1, m.min(n));
        let a = low_rank_plus_noise(gen, m, n, k, 0.1);
        let svd = svd_thin(&a);
        for w in svd.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-4);
        }
        let fro2: f32 = a.data.iter().map(|v| v * v).sum();
        let s2: f32 = svd.s.iter().map(|v| v * v).sum();
        assert!(
            (fro2 - s2).abs() <= 1e-3 * (1.0 + fro2),
            "Σσ² {s2} vs ‖A‖² {fro2}"
        );
        assert_orthonormal(&svd.u, 1e-3, "exact U");
    });
}
