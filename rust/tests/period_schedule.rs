//! Adaptive refresh-period scheduling, locked down end-to-end: a
//! variable boundary sequence must join every determinism contract the
//! fixed modular schedule already holds.
//!
//! 1. **Sync ≡ async with adaptive periods.** The drift-driven
//!    controller commits bit-identical losses, parameters, and period
//!    decisions whether the refresh runs inline or overlapped — the
//!    decision rides the prepared refresh, never the critical path.
//! 2. **Thread-width invariance.** The adaptive trajectory (including
//!    every committed period) is bit-identical under `GUM_THREADS`
//!    ∈ {1, 2, 8}.
//! 3. **Replica invariance.** Splits of the same global batch —
//!    (replicas, accum) ∈ {(1,4), (2,2), (4,1)} — commit the exact same
//!    boundary sequence, and the trajectory holds the repo's 1e-5
//!    data-parallel contract.
//! 4. **Mid-period resume after a period change.** A GUMCKPT3 snapshot
//!    taken inside a *stretched* period round-trips through disk and
//!    replays the uninterrupted run bit-for-bit.
//! 5. **Lane kills at a shrunk boundary ± 1.** Elastic rollback replays
//!    to the fault-free adaptive trajectory bit-for-bit, including the
//!    shrunk boundary sequence.
//! 6. **Fixed stays fixed.** A session with `PeriodSchedule::Fixed` is
//!    bitwise identical to one that never heard of period schedules,
//!    and reports no period state in its snapshots.

use std::sync::Arc;

use gum::coordinator::{
    save_train_state, ElasticConfig, ElasticSession, LrSchedule,
    ParallelConfig, ParallelSession, ShardMode, ShardedBatcher,
    SyntheticGradSource,
};
use gum::data::corpus::CorpusSpec;
use gum::data::tokenizer::ByteTokenizer;
use gum::linalg::Matrix;
use gum::model::{BlockKind, ParamBlock, ParamStore};
use gum::optim::{
    self, AdaptivePeriodCfg, PeriodSchedule, RefreshPipelineMode,
};
use gum::rng::Pcg;
use gum::testing::{FaultPlan, FaultPlanArtifact};

const BATCH: usize = 4;
const SEQ: usize = 32;
const PERIOD_K: usize = 5;
const SRC_SEED: u64 = 23;
const BASE_RANK: usize = 4;

/// Serializes the thread-width test against itself across parallel test
/// threads (the width override is process-global).
static WIDTH_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn small_store() -> ParamStore {
    let mut rng = Pcg::new(5);
    let blocks = vec![
        ParamBlock {
            name: "w0".into(),
            shape: vec![24, 32],
            kind: BlockKind::Projectable,
            value: Matrix::randn(24, 32, 0.1, &mut rng),
        },
        ParamBlock {
            name: "w1".into(),
            shape: vec![32, 24],
            kind: BlockKind::Projectable,
            value: Matrix::randn(32, 24, 0.1, &mut rng),
        },
        ParamBlock {
            name: "norm".into(),
            shape: vec![16],
            kind: BlockKind::Dense,
            value: Matrix::from_vec(1, 16, vec![1.0; 16]),
        },
    ];
    ParamStore { blocks }
}

/// Stretch regime: the synthetic gradient stream's subspace drift is
/// always below this (absurdly lax) threshold, so every observed
/// boundary counts as stable and K climbs 5 → 7 → 10 → 15 → 20.
fn stretch() -> PeriodSchedule {
    PeriodSchedule::Adaptive(AdaptivePeriodCfg {
        drift: 0.999,
        patience: 1,
        min_period: 2,
        max_period: 20,
    })
}

/// Shrink regime: any positive drift is a spike, so the first observed
/// boundary halves K to the floor (5 → 2) and it stays there.
fn shrink() -> PeriodSchedule {
    PeriodSchedule::Adaptive(AdaptivePeriodCfg {
        drift: 0.0,
        patience: 10_000,
        min_period: 2,
        max_period: 20,
    })
}

fn session(
    replicas: usize,
    accum: usize,
    shard: ShardMode,
    mode: RefreshPipelineMode,
    schedule: Option<&PeriodSchedule>,
) -> ParallelSession {
    let params = small_store();
    let opt =
        optim::build("gum", &params, BASE_RANK, 1.0, 99).unwrap();
    let pcfg = ParallelConfig {
        replicas,
        accum_steps: accum,
        shard_mode: shard,
        doc_stride: 100_000,
    };
    let batcher = ShardedBatcher::new(
        &CorpusSpec::default(),
        &ByteTokenizer::new(256),
        BATCH,
        SEQ,
        &pcfg,
    );
    let mut s = ParallelSession::new(
        params,
        opt,
        batcher,
        PERIOD_K,
        LrSchedule::constant(0.02),
        17,
    );
    s.set_refresh_mode(mode);
    if let Some(schedule) = schedule {
        s.set_period_schedule(schedule);
    }
    s
}

fn sources(s: &ParallelSession, n: usize) -> Vec<SyntheticGradSource> {
    vec![SyntheticGradSource::new(&s.params, SRC_SEED); n]
}

/// Losses, the period length in force after every step, and the final
/// parameters.
fn run_trace(
    mode: RefreshPipelineMode,
    schedule: &PeriodSchedule,
    steps: usize,
) -> (Vec<f64>, Vec<usize>, ParamStore) {
    let mut s = session(2, 1, ShardMode::DocPartition, mode, Some(schedule));
    let mut srcs = sources(&s, 2);
    let mut losses = Vec::with_capacity(steps);
    let mut periods = Vec::with_capacity(steps);
    for _ in 0..steps {
        losses.push(s.global_step(&mut srcs).unwrap().loss);
        periods.push(s.periods.current_period());
    }
    (losses, periods, s.params)
}

/// Sync ≡ async with adaptive periods: bit-identical losses,
/// parameters, and committed period sequence — and the period must have
/// actually moved off the base K (otherwise the equality is vacuous).
#[test]
fn adaptive_sync_matches_async_bitwise() {
    // Boundaries 0, 5 (adopt 7), 12 (adopt 10), 22 (adopt 15): three
    // overlapped handoffs with a different period length each time.
    let steps = 25;
    let schedule = stretch();
    let (sl, sp, spar) =
        run_trace(RefreshPipelineMode::Sync, &schedule, steps);
    let (al, ap, apar) =
        run_trace(RefreshPipelineMode::Async, &schedule, steps);
    assert_eq!(sl, al, "adaptive loss trace diverged between sync/async");
    assert_eq!(sp, ap, "committed period sequence diverged between modes");
    for (a, b) in spar.blocks.iter().zip(&apar.blocks) {
        assert_eq!(a.value, b.value, "block {} diverged", a.name);
    }
    assert!(
        sp.iter().any(|&k| k != PERIOD_K),
        "period never moved off base K: {sp:?}"
    );
    assert_eq!(
        *sp.last().unwrap(),
        15,
        "expected 5 → 7 → 10 → 15 by step {steps}: {sp:?}"
    );
}

/// The adaptive trajectory is bit-identical across worker-pool widths:
/// drift measurement, the controller, and the boundary bookkeeping are
/// functions of the observed bases only, never of thread count.
#[test]
fn adaptive_trace_bit_identical_across_thread_widths() {
    let _w = WIDTH_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let steps = 2 * PERIOD_K + 3;
    let schedule = stretch();
    let run = |width: usize| {
        let orig = gum::thread::num_threads();
        gum::thread::set_num_threads(width);
        let out = run_trace(RefreshPipelineMode::Async, &schedule, steps);
        gum::thread::set_num_threads(orig);
        out
    };
    let (l1, k1, p1) = run(1);
    assert!(k1.iter().any(|&k| k != PERIOD_K), "period never moved");
    for width in [2usize, 8] {
        let (l, k, p) = run(width);
        assert_eq!(l1, l, "width {width} changed the adaptive loss trace");
        assert_eq!(k1, k, "width {width} changed the period sequence");
        for (a, b) in p1.blocks.iter().zip(&p.blocks) {
            assert_eq!(a.value, b.value, "width {width}: {}", a.name);
        }
    }
}

/// Replica invariance: splits of the same 4-micro-batch global step
/// commit the exact same boundary/period sequence, and the trajectory
/// holds the repo's 1e-5 data-parallel equivalence contract.
#[test]
fn period_decisions_unchanged_by_replica_count() {
    let steps = 25;
    let schedule = stretch();
    let run = |replicas: usize, accum: usize| {
        let mut s = session(
            replicas,
            accum,
            ShardMode::Interleaved,
            RefreshPipelineMode::Async,
            Some(&schedule),
        );
        let mut srcs = sources(&s, replicas);
        let mut losses = Vec::new();
        let mut periods = Vec::new();
        for _ in 0..steps {
            losses.push(s.global_step(&mut srcs).unwrap().loss);
            periods.push(s.periods.current_period());
        }
        (losses, periods, s.params)
    };
    let (gl, gk, gp) = run(1, 4);
    assert!(gk.iter().any(|&k| k != PERIOD_K), "period never moved");
    for (replicas, accum) in [(2usize, 2usize), (4, 1)] {
        let (l, k, p) = run(replicas, accum);
        assert_eq!(
            gk, k,
            "{replicas}x{accum}: committed period sequence changed"
        );
        for (a, b) in gl.iter().zip(&l) {
            assert!(
                (a - b).abs() < 1e-5,
                "{replicas}x{accum}: loss diverged ({a} vs {b})"
            );
        }
        for (x, y) in gp.blocks.iter().zip(&p.blocks) {
            let diff = x.value.max_abs_diff(&y.value);
            assert!(
                diff < 1e-5,
                "{replicas}x{accum}: block {} max diff {diff}",
                x.name
            );
        }
    }
}

/// Mid-period resume after a period change: snapshot at step 8 — inside
/// the period *stretched* at boundary 5 (K = 7, next boundary 12) —
/// round-trip through a GUMCKPT3 file, restore into a fresh session,
/// and replay. The resumed run must match the uninterrupted one
/// bit-for-bit, boundary bookkeeping included.
#[test]
fn mid_period_resume_after_period_change_matches_uninterrupted() {
    let schedule = stretch();
    let mk = || {
        session(
            2,
            2,
            ShardMode::Interleaved,
            RefreshPipelineMode::Async,
            Some(&schedule),
        )
    };
    let mut a = mk();
    let mut sa = sources(&a, 2);
    for _ in 0..8 {
        a.global_step(&mut sa).unwrap();
    }
    // Boundary 5 adopted the stretched period: we are mid-period with
    // K ≠ base — the exact state `step % K` bookkeeping cannot restore.
    assert_eq!(a.periods.current_period(), 7);
    assert_ne!(a.periods.last_period_start(8), 8);
    let state = a.train_state();
    assert!(
        state.period_state.is_some(),
        "adaptive runs must snapshot a PERIODS section"
    );

    let path = std::env::temp_dir().join("gum_period_resume_test.bin");
    save_train_state(&state, &path).unwrap();
    let loaded = gum::coordinator::load_train_state(&path).unwrap();
    assert_eq!(loaded.period_state, state.period_state);

    let mut b = mk();
    let mut sb = sources(&b, 2);
    b.restore_train_state(&loaded).unwrap();
    assert_eq!(b.step, 8);
    assert_eq!(b.periods.current_period(), 7);

    let mut la = Vec::new();
    let mut lb = Vec::new();
    let mut ka = Vec::new();
    let mut kb = Vec::new();
    for _ in 0..10 {
        la.push(a.global_step(&mut sa).unwrap().loss);
        ka.push(a.periods.current_period());
        lb.push(b.global_step(&mut sb).unwrap().loss);
        kb.push(b.periods.current_period());
    }
    assert_eq!(la, lb, "resumed loss trace must match uninterrupted run");
    assert_eq!(ka, kb, "resumed period sequence must match");
    assert!(
        ka.iter().any(|&k| k == 10),
        "the replay must cross the next stretch (boundary 12): {ka:?}"
    );
    for (x, y) in a.params.blocks.iter().zip(&b.params.blocks) {
        assert_eq!(x.value, y.value, "{}", x.name);
    }
}

/// Lane kills at the *shrunk* boundary ± 1: under the shrink regime the
/// schedule commits 0 (K5), 5 (adopt 2), 7, 9, … — boundary 7 is the
/// first laid out by a shrunk period. Kills at steps 6, 7, 8 must
/// replay to the fault-free adaptive trajectory bit-for-bit, boundary
/// sequence included.
#[test]
fn lane_kill_at_shrunk_boundary_stays_bitwise() {
    let steps = 12;
    let replicas = 4;
    let schedule = shrink();
    let golden = {
        let mut s = session(
            replicas,
            1,
            ShardMode::DocPartition,
            RefreshPipelineMode::Async,
            Some(&schedule),
        );
        let mut srcs = sources(&s, replicas);
        let mut losses = Vec::with_capacity(steps);
        for _ in 0..steps {
            losses.push(s.global_step(&mut srcs).unwrap().loss);
        }
        (losses, s.params, s.periods.snapshot())
    };
    assert_eq!(
        golden.2.as_ref().map(|ps| ps.period),
        Some(2),
        "the golden run must actually shrink K"
    );
    for kill_step in [6u64, 7, 8] {
        let plan = Arc::new(
            FaultPlan::parse(&format!("kill:1@{kill_step}")).unwrap(),
        );
        let _artifact = FaultPlanArtifact::new(
            &format!("period_shrink_kill_step{kill_step}"),
            &plan,
        );
        let lane_plan = plan.clone();
        let mut sess = ElasticSession::new(
            session(
                replicas,
                1,
                ShardMode::DocPartition,
                RefreshPipelineMode::Async,
                Some(&schedule),
            ),
            ElasticConfig::default(),
            plan.clone(),
            move |params, lane| {
                SyntheticGradSource::new(params, SRC_SEED)
                    .with_faults(lane, lane_plan.clone())
            },
        );
        let losses = sess.run(steps).unwrap();
        assert_eq!(plan.fired_count(), 1, "kill@{kill_step} must fire");
        assert_eq!(
            golden.0, losses,
            "kill@{kill_step}: committed loss trace diverged"
        );
        for (want, got) in golden.1.blocks.iter().zip(&sess.inner.params.blocks)
        {
            assert_eq!(
                want.value, got.value,
                "kill@{kill_step}: block {} diverged",
                want.name
            );
        }
        assert_eq!(
            sess.inner.periods.snapshot(),
            golden.2,
            "kill@{kill_step}: boundary bookkeeping diverged"
        );
    }
}

/// Fixed stays fixed: threading `PeriodSchedule::Fixed` through the
/// session changes nothing against a session that never heard of period
/// schedules, and fixed snapshots carry no period state.
#[test]
fn fixed_schedule_is_bitwise_identical_to_legacy_session() {
    let steps = 2 * PERIOD_K + 2;
    let run = |schedule: Option<&PeriodSchedule>| {
        let mut s = session(
            2,
            1,
            ShardMode::DocPartition,
            RefreshPipelineMode::Async,
            schedule,
        );
        let mut srcs = sources(&s, 2);
        let mut losses = Vec::with_capacity(steps);
        for _ in 0..steps {
            losses.push(s.global_step(&mut srcs).unwrap().loss);
        }
        let state = s.train_state();
        (losses, s.params, state)
    };
    let (legacy_losses, legacy_params, legacy_state) = run(None);
    let (losses, params, state) = run(Some(&PeriodSchedule::Fixed));
    assert_eq!(legacy_losses, losses, "Fixed schedule changed the trace");
    for (a, b) in legacy_params.blocks.iter().zip(&params.blocks) {
        assert_eq!(a.value, b.value, "{}", a.name);
    }
    assert!(
        legacy_state.period_state.is_none()
            && state.period_state.is_none(),
        "fixed runs must not carry period state"
    );
}
