//! Adaptive per-layer rank scheduling, locked down end-to-end: the
//! spectrum-driven controller must join every determinism contract the
//! fixed schedule already holds.
//!
//! 1. **Controller properties.** Hysteresis (deadband + patience) keeps
//!    a flat or alternating spectrum from ever oscillating the rank;
//!    the total committed rank never exceeds the budget under arbitrary
//!    spectra; per-block clamps hold and dense blocks stay rank 0.
//! 2. **Sync ≡ async with adaptive ranks.** For every spectral
//!    optimizer family (GUM, GaLore-Muon, GaLore-Adam, Fira) the
//!    adaptive run commits bit-identical losses, parameters, and rank
//!    decisions whether the refresh runs inline or overlapped.
//! 3. **Thread-width and replica invariance.** The adaptive trajectory
//!    is bit-identical under `GUM_THREADS` ∈ {1, 2, 8}, and replica
//!    splits of the same global batch agree on the committed rank
//!    sequence (exactly) and the trajectory (within the repo's 1e-5
//!    data-parallel contract).
//! 4. **Fixed stays fixed.** Threading the schedule through the build
//!    path changes nothing when the schedule is `Fixed`, and adaptive
//!    scheduling on non-spectral optimizers is a config error.

use gum::coordinator::{
    LrSchedule, ParallelConfig, ParallelSession, ShardMode, ShardedBatcher,
    SyntheticGradSource,
};
use gum::data::corpus::CorpusSpec;
use gum::data::tokenizer::ByteTokenizer;
use gum::linalg::Matrix;
use gum::model::{BlockKind, ParamBlock, ParamStore};
use gum::optim::{
    self, AdaptiveRankCfg, RankController, RankSchedule, RankState,
    RefreshPipelineMode, RefreshStrategy,
};
use gum::rng::Pcg;

const BATCH: usize = 4;
const SEQ: usize = 32;
const PERIOD_K: usize = 5;
const SRC_SEED: u64 = 23;
const BASE_RANK: usize = 4;

/// Serializes the thread-width test against itself across parallel test
/// threads (the width override is process-global).
static WIDTH_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn small_store() -> ParamStore {
    let mut rng = Pcg::new(5);
    let blocks = vec![
        ParamBlock {
            name: "w0".into(),
            shape: vec![24, 32],
            kind: BlockKind::Projectable,
            value: Matrix::randn(24, 32, 0.1, &mut rng),
        },
        ParamBlock {
            name: "w1".into(),
            shape: vec![32, 24],
            kind: BlockKind::Projectable,
            value: Matrix::randn(32, 24, 0.1, &mut rng),
        },
        ParamBlock {
            name: "norm".into(),
            shape: vec![16],
            kind: BlockKind::Dense,
            value: Matrix::from_vec(1, 16, vec![1.0; 16]),
        },
    ];
    ParamStore { blocks }
}

/// The adaptive configuration the session tests run under: probe width
/// 8, clamps [1, 8], budget 12 — tight enough that the controller must
/// actually move rank off the uniform base-4 initialization and then
/// hit the budget ceiling.
fn adaptive() -> RankSchedule {
    RankSchedule::Adaptive(AdaptiveRankCfg {
        energy: 0.90,
        deadband: 1,
        patience: 2,
        min_rank: 1,
        max_rank: 8,
        budget: 12,
    })
}

fn session(
    optimizer: &str,
    replicas: usize,
    accum: usize,
    shard: ShardMode,
    mode: RefreshPipelineMode,
    schedule: &RankSchedule,
) -> ParallelSession {
    let params = small_store();
    let opt = optim::build_with_schedule(
        optimizer,
        &params,
        BASE_RANK,
        1.0,
        99,
        RefreshStrategy::default(),
        schedule,
    )
    .unwrap();
    let pcfg = ParallelConfig {
        replicas,
        accum_steps: accum,
        shard_mode: shard,
        doc_stride: 100_000,
    };
    let batcher = ShardedBatcher::new(
        &CorpusSpec::default(),
        &ByteTokenizer::new(256),
        BATCH,
        SEQ,
        &pcfg,
    );
    let mut s = ParallelSession::new(
        params,
        opt,
        batcher,
        PERIOD_K,
        LrSchedule::constant(0.02),
        17,
    );
    s.set_refresh_mode(mode);
    s
}

fn sources(s: &ParallelSession, n: usize) -> Vec<SyntheticGradSource> {
    vec![SyntheticGradSource::new(&s.params, SRC_SEED); n]
}

fn run_trace(
    optimizer: &str,
    mode: RefreshPipelineMode,
    schedule: &RankSchedule,
    steps: usize,
) -> (Vec<f64>, ParamStore, Option<RankState>) {
    let mut s =
        session(optimizer, 2, 1, ShardMode::DocPartition, mode, schedule);
    let mut srcs = sources(&s, 2);
    let mut losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        losses.push(s.global_step(&mut srcs).unwrap().loss);
    }
    let rank_state = s.opt.rank_state();
    (losses, s.params, rank_state)
}

fn ctl_cfg() -> AdaptiveRankCfg {
    AdaptiveRankCfg {
        energy: 0.90,
        deadband: 1,
        patience: 2,
        min_rank: 1,
        max_rank: 8,
        budget: 1000, // property tests isolate hysteresis from the budget
    }
}

/// Hysteresis property: a flat spectrum settles once and never moves
/// again, and spectra whose targets alternate inside the deadband never
/// commit at all — no oscillation.
#[test]
fn flat_spectrum_never_oscillates() {
    let store = small_store();
    let mut ctl = RankController::new(&ctl_cfg(), &store, BASE_RANK);
    assert_eq!(ctl.ranks(), &[BASE_RANK, BASE_RANK, 0]);

    // Perfectly flat probe spectrum: energy target = probe width (8).
    let flat = [1.0f32; 8];
    let mut trajectory = Vec::new();
    for _ in 0..20 {
        ctl.observe(&[Some(&flat), Some(&flat), None]);
        trajectory.push(ctl.ranks().to_vec());
    }
    // Patience 2 delays the commit one boundary, then the rank is
    // stationary forever.
    assert_eq!(trajectory[0], vec![BASE_RANK, BASE_RANK, 0]);
    assert_eq!(trajectory[1], vec![8, 8, 0]);
    for (i, ranks) in trajectory.iter().enumerate().skip(1) {
        assert_eq!(
            ranks,
            &vec![8, 8, 0],
            "rank oscillated at observation {i}: {trajectory:?}"
        );
    }

    // Alternating targets 8 and 7 are both within deadband 1 of the
    // committed 8: the controller must never move or build pressure.
    // ([2, 1×7]: Σσ² = 11, want 9.9, reached at t = 7.)
    let t7 = [2.0f32, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0];
    for i in 0..10 {
        let spec: &[f32] = if i % 2 == 0 { &t7 } else { &flat };
        ctl.observe(&[Some(spec), Some(spec), None]);
        assert_eq!(
            ctl.ranks(),
            &[8, 8, 0],
            "near-flat alternation moved the rank at observation {i}"
        );
    }
    assert_eq!(ctl.state().pressure, vec![0, 0, 0]);
}

/// Budget + clamp property: under arbitrary random spectra the total
/// committed rank never exceeds the budget, every projectable block
/// stays inside [min_rank, max_rank], and dense blocks stay at 0.
#[test]
fn budget_and_clamps_hold_under_random_spectra() {
    let store = small_store();
    let cfg = AdaptiveRankCfg {
        energy: 0.90,
        deadband: 0,
        patience: 1,
        min_rank: 1,
        max_rank: 8,
        budget: 10,
    };
    let mut ctl = RankController::new(&cfg, &store, BASE_RANK);
    let mut rng = Pcg::new(7);
    for round in 0..100 {
        // Random magnitudes sorted descending: a plausible spectrum with
        // round-dependent concentration.
        let raw = Matrix::randn(1, 8, 1.0 + (round % 5) as f32, &mut rng);
        let mut spec: Vec<f32> = raw.data.iter().map(|v| v.abs()).collect();
        spec.sort_by(|a, b| b.partial_cmp(a).unwrap());
        ctl.observe(&[Some(&spec), Some(&spec), None]);
        assert!(
            ctl.total_rank() <= 10,
            "round {round}: total rank {} exceeds budget 10 ({:?})",
            ctl.total_rank(),
            ctl.ranks()
        );
        for (i, &r) in ctl.ranks().iter().enumerate() {
            match store.blocks[i].kind {
                BlockKind::Projectable => assert!(
                    (1..=8).contains(&r),
                    "round {round}: block {i} rank {r} outside [1, 8]"
                ),
                BlockKind::Dense => {
                    assert_eq!(r, 0, "round {round}: dense block got rank")
                }
            }
        }
    }
}

/// Sync ≡ async with adaptive ranks, for every spectral optimizer
/// family: bit-identical losses, parameters, and committed rank state —
/// and the controller must have actually moved rank off the uniform
/// initialization (otherwise the equality is vacuous).
#[test]
fn adaptive_sync_matches_async_bitwise() {
    let steps = 3 * PERIOD_K + 2; // three overlapped handoffs
    let schedule = adaptive();
    for optimizer in ["gum", "galore-muon", "galore-adam", "fira"] {
        let (sync_losses, sync_params, sync_ranks) =
            run_trace(optimizer, RefreshPipelineMode::Sync, &schedule, steps);
        let (async_losses, async_params, async_ranks) =
            run_trace(optimizer, RefreshPipelineMode::Async, &schedule, steps);
        assert_eq!(
            sync_losses, async_losses,
            "{optimizer}: adaptive loss trace diverged between sync and async"
        );
        for (a, b) in sync_params.blocks.iter().zip(&async_params.blocks) {
            assert_eq!(
                a.value, b.value,
                "{optimizer}: block {} diverged",
                a.name
            );
        }
        let sync_ranks = sync_ranks
            .unwrap_or_else(|| panic!("{optimizer}: no rank state"));
        let async_ranks = async_ranks
            .unwrap_or_else(|| panic!("{optimizer}: no rank state"));
        assert_eq!(
            sync_ranks, async_ranks,
            "{optimizer}: committed rank state diverged between modes"
        );
        assert!(
            sync_ranks.total() <= 12,
            "{optimizer}: total rank {} exceeds budget 12",
            sync_ranks.total()
        );
        assert_ne!(
            sync_ranks.ranks,
            vec![BASE_RANK as u32, BASE_RANK as u32, 0],
            "{optimizer}: controller never moved — the adaptive run \
             degenerated to the fixed one"
        );
    }
}

/// The adaptive trajectory is bit-identical across worker-pool widths:
/// probing, the controller, and the moment resizing are all functions
/// of the observed spectra only, never of thread count.
#[test]
fn adaptive_trace_bit_identical_across_thread_widths() {
    let _w = WIDTH_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let steps = 2 * PERIOD_K + 1;
    let schedule = adaptive();
    let run = |width: usize| {
        let orig = gum::thread::num_threads();
        gum::thread::set_num_threads(width);
        let out =
            run_trace("gum", RefreshPipelineMode::Async, &schedule, steps);
        gum::thread::set_num_threads(orig);
        out
    };
    let (l1, p1, r1) = run(1);
    assert!(r1.is_some());
    for width in [2usize, 8] {
        let (l, p, r) = run(width);
        assert_eq!(l1, l, "width {width} changed the adaptive loss trace");
        assert_eq!(r1, r, "width {width} changed the committed ranks");
        for (a, b) in p1.blocks.iter().zip(&p.blocks) {
            assert_eq!(a.value, b.value, "width {width}: {}", a.name);
        }
    }
}

/// Replica invariance: splits of the same 4-micro-batch global step —
/// (replicas, accum) ∈ {(1,4), (2,2), (4,1)} — commit the exact same
/// rank sequence, and the trajectory holds the repo's 1e-5
/// data-parallel equivalence contract.
#[test]
fn adaptive_rank_decisions_unchanged_by_replica_count() {
    let steps = 3 * PERIOD_K;
    let schedule = adaptive();
    let run = |replicas: usize, accum: usize| {
        let mut s = session(
            "gum",
            replicas,
            accum,
            ShardMode::Interleaved,
            RefreshPipelineMode::Async,
            &schedule,
        );
        let mut srcs = sources(&s, replicas);
        let mut losses = Vec::new();
        let mut rank_seq = Vec::new();
        for step in 0..steps {
            losses.push(s.global_step(&mut srcs).unwrap().loss);
            if step % PERIOD_K == 0 {
                rank_seq.push(s.opt.rank_state().expect("adaptive").ranks);
            }
        }
        (losses, rank_seq, s.params)
    };
    let (gl, gr, gp) = run(1, 4);
    assert_eq!(gr.len(), 3);
    for (replicas, accum) in [(2usize, 2usize), (4, 1)] {
        let (l, r, p) = run(replicas, accum);
        assert_eq!(
            gr, r,
            "{replicas}x{accum}: committed rank sequence changed"
        );
        for (a, b) in gl.iter().zip(&l) {
            assert!(
                (a - b).abs() < 1e-5,
                "{replicas}x{accum}: loss diverged ({a} vs {b})"
            );
        }
        for (x, y) in gp.blocks.iter().zip(&p.blocks) {
            let diff = x.value.max_abs_diff(&y.value);
            assert!(
                diff < 1e-5,
                "{replicas}x{accum}: block {} max diff {diff}",
                x.name
            );
        }
    }
}

/// Threading the schedule through the build path is invisible to fixed
/// runs: `build_with_schedule(…, Fixed)` equals the historical `build`
/// bit-for-bit and reports no rank state.
#[test]
fn fixed_schedule_is_bitwise_identical_to_legacy_build() {
    let steps = 2 * PERIOD_K + 2;
    let (legacy_losses, legacy_params) = {
        let params = small_store();
        let opt = optim::build("gum", &params, BASE_RANK, 1.0, 99).unwrap();
        let pcfg = ParallelConfig {
            replicas: 2,
            accum_steps: 1,
            shard_mode: ShardMode::DocPartition,
            doc_stride: 100_000,
        };
        let batcher = ShardedBatcher::new(
            &CorpusSpec::default(),
            &ByteTokenizer::new(256),
            BATCH,
            SEQ,
            &pcfg,
        );
        let mut s = ParallelSession::new(
            params,
            opt,
            batcher,
            PERIOD_K,
            LrSchedule::constant(0.02),
            17,
        );
        s.set_refresh_mode(RefreshPipelineMode::Async);
        let mut srcs = sources(&s, 2);
        let mut losses = Vec::new();
        for _ in 0..steps {
            losses.push(s.global_step(&mut srcs).unwrap().loss);
        }
        (losses, s.params)
    };
    let (losses, params, rank_state) = run_trace(
        "gum",
        RefreshPipelineMode::Async,
        &RankSchedule::Fixed,
        steps,
    );
    assert_eq!(legacy_losses, losses, "Fixed schedule changed the trace");
    for (a, b) in legacy_params.blocks.iter().zip(&params.blocks) {
        assert_eq!(a.value, b.value, "{}", a.name);
    }
    assert!(rank_state.is_none(), "fixed runs must report no rank state");
}

/// Adaptive scheduling on optimizers without a spectral projector is a
/// config error, caught at build time.
#[test]
fn adaptive_rejects_non_spectral_optimizers() {
    let params = small_store();
    for name in ["sgd", "adamw", "muon", "golore-muon", "lisa"] {
        let err = optim::build_with_schedule(
            name,
            &params,
            BASE_RANK,
            1.0,
            99,
            RefreshStrategy::default(),
            &adaptive(),
        );
        assert!(err.is_err(), "{name} must reject the adaptive schedule");
    }
    // The spectral families accept it.
    for name in ["gum", "galore-muon", "galore-adam", "fira"] {
        assert!(optim::build_with_schedule(
            name,
            &params,
            BASE_RANK,
            1.0,
            99,
            RefreshStrategy::default(),
            &adaptive(),
        )
        .is_ok());
    }
}
