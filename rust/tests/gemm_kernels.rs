//! Property suite for the packed cache-blocked GEMM kernels
//! (`linalg/gemm.rs`): agreement with an f64 naive reference across
//! odd non-multiple-of-tile shapes, degenerate dimensions, `_into`
//! buffer-reuse semantics (shape asserts, resize behaviour), and the
//! determinism contract — bitwise-equal results under any
//! `set_num_threads` width, which is what DESIGN.md §4's reduction
//! guarantees stand on.

use gum::linalg::{
    gemm, gemm_nt, gemm_tn, matmul, matmul_into, matmul_nt, matmul_nt_into,
    matmul_tn, matmul_tn_into, Matrix,
};
use gum::rng::Pcg;
use gum::thread::set_num_threads;

/// f64-accumulating reference for C = A·B.
fn naive(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows);
    let mut c = Matrix::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for j in 0..b.cols {
            let mut s = 0.0f64;
            for k in 0..a.cols {
                s += a.at(i, k) as f64 * b.at(k, j) as f64;
            }
            *c.at_mut(i, j) = s as f32;
        }
    }
    c
}

/// Tolerance for f32 kernels vs the f64 reference, scaled by the
/// accumulation depth.
fn tol(k: usize) -> f32 {
    1e-4 * (k.max(1) as f32).sqrt().max(1.0)
}

/// Shapes chosen to straddle every blocking edge: the MR/NR microtile
/// (8), the MC row panel (128), the KC depth slab (256), and the NC
/// column panel (512) — plus primes and 1-thin extremes.
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (1, 37, 1),
    (37, 1, 19),
    (2, 3, 5),
    (7, 9, 13),
    (8, 8, 8),
    (9, 7, 17),
    (16, 16, 16),
    (31, 129, 33),
    (64, 64, 64),
    (100, 50, 70),
    (127, 255, 65),
    (129, 257, 63),
    (130, 300, 96),
    (8, 513, 8),
    (257, 16, 300),
];

#[test]
fn nn_nt_tn_match_naive_on_odd_shapes() {
    let mut rng = Pcg::new(0);
    for &(m, k, n) in SHAPES {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let want = naive(&a, &b);
        let t = tol(k);

        let nn = matmul(&a, &b);
        assert!(nn.max_abs_diff(&want) < t, "nn {m}x{k}x{n}");

        let bt = b.transpose();
        let nt = matmul_nt(&a, &bt);
        assert!(nt.max_abs_diff(&want) < t, "nt {m}x{k}x{n}");

        let at = a.transpose();
        let tn = matmul_tn(&at, &b);
        assert!(tn.max_abs_diff(&want) < t, "tn {m}x{k}x{n}");
    }
}

#[test]
fn alpha_beta_accumulate_matches_reference() {
    let mut rng = Pcg::new(1);
    for &(m, k, n) in &[(5usize, 7usize, 9usize), (130, 290, 77), (64, 256, 64)]
    {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let c0 = Matrix::randn(m, n, 1.0, &mut rng);
        let mut want = naive(&a, &b);
        want.scale_in_place(1.5);
        want.add_scaled_in_place(-0.25, &c0);

        let mut c = c0.clone();
        gemm(1.5, &a, &b, -0.25, &mut c);
        assert!(c.max_abs_diff(&want) < tol(k), "gemm {m}x{k}x{n}");

        let bt = b.transpose();
        let mut c = c0.clone();
        gemm_nt(1.5, &a, &bt, -0.25, &mut c);
        assert!(c.max_abs_diff(&want) < tol(k), "gemm_nt {m}x{k}x{n}");

        let at = a.transpose();
        let mut c = c0.clone();
        gemm_tn(1.5, &at, &b, -0.25, &mut c);
        assert!(c.max_abs_diff(&want) < tol(k), "gemm_tn {m}x{k}x{n}");
    }
}

#[test]
fn degenerate_dims() {
    // m = 0, n = 0, k = 0, and 1×1 are all well-defined.
    assert_eq!(matmul(&Matrix::zeros(0, 4), &Matrix::zeros(4, 3)).shape(), (0, 3));
    assert_eq!(matmul(&Matrix::zeros(4, 3), &Matrix::zeros(3, 0)).shape(), (4, 0));

    // k = 0: alpha-term vanishes, beta still applies.
    let mut c = Matrix::from_vec(2, 2, vec![2.0, 4.0, 6.0, 8.0]);
    gemm(3.0, &Matrix::zeros(2, 0), &Matrix::zeros(0, 2), 0.5, &mut c);
    assert_eq!(c.data, vec![1.0, 2.0, 3.0, 4.0]);

    // alpha = 0 short-circuits the product but not beta.
    let a = Matrix::from_vec(1, 1, vec![7.0]);
    let mut c = Matrix::from_vec(1, 1, vec![10.0]);
    gemm(0.0, &a, &a, 0.25, &mut c);
    assert_eq!(c.data, vec![2.5]);

    let one = Matrix::from_vec(1, 1, vec![-3.0]);
    assert_eq!(matmul(&one, &one).data, vec![9.0]);
}

#[test]
fn into_variants_resize_and_match() {
    let mut rng = Pcg::new(2);
    let a = Matrix::randn(33, 65, 1.0, &mut rng);
    let b = Matrix::randn(65, 17, 1.0, &mut rng);
    // One buffer reused across all three variants — resizes each time.
    let mut c = Matrix::zeros(500, 2);
    matmul_into(&a, &b, &mut c);
    assert_eq!(c.shape(), (33, 17));
    assert_eq!(c.data, matmul(&a, &b).data);

    matmul_tn_into(&a, &a, &mut c);
    assert_eq!(c.shape(), (65, 65));
    assert_eq!(c.data, matmul_tn(&a, &a).data);

    matmul_nt_into(&a, &a, &mut c);
    assert_eq!(c.shape(), (33, 33));
    assert_eq!(c.data, matmul_nt(&a, &a).data);
}

#[test]
#[should_panic(expected = "gemm out")]
fn gemm_into_rejects_wrong_output_shape() {
    // The accumulate forms cannot resize (beta reads C), so a
    // mis-shaped output is a hard error, not a silent resize.
    let a = Matrix::zeros(4, 3);
    let b = Matrix::zeros(3, 5);
    let mut c = Matrix::zeros(4, 6);
    gemm(1.0, &a, &b, 1.0, &mut c);
}

#[test]
#[should_panic(expected = "gemm_tn inner dim")]
fn gemm_tn_rejects_mismatched_inner_dim() {
    let a = Matrix::zeros(4, 3);
    let b = Matrix::zeros(5, 6);
    let mut c = Matrix::zeros(3, 6);
    gemm_tn(1.0, &a, &b, 0.0, &mut c);
}

#[test]
fn bitwise_identical_across_thread_widths() {
    // The determinism contract: chunking never changes the per-element
    // k-order, so any `GUM_THREADS` produces the same bits. Shapes
    // cross the KC slab boundary (k > 256) and the NC panel boundary
    // (n > 512) to exercise multi-slab, multi-panel accumulation.
    let mut rng = Pcg::new(3);
    let cases = [
        (64usize, 300usize, 528usize),
        (130, 70, 90),
        (17, 513, 33),
        (256, 256, 256),
    ];
    let orig = set_num_threads(1);
    let mut serial = Vec::new();
    for &(m, k, n) in &cases {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let bt = b.transpose();
        let at = a.transpose();
        serial.push((
            a.clone(),
            b.clone(),
            matmul(&a, &b),
            matmul_nt(&a, &bt),
            matmul_tn(&at, &b),
        ));
    }
    for threads in [2usize, 3, 8, 32] {
        set_num_threads(threads);
        for (a, b, nn, nt, tn) in &serial {
            let bt = b.transpose();
            let at = a.transpose();
            assert_eq!(matmul(a, b).data, nn.data, "nn t={threads}");
            assert_eq!(matmul_nt(a, &bt).data, nt.data, "nt t={threads}");
            assert_eq!(matmul_tn(&at, b).data, tn.data, "tn t={threads}");
        }
    }
    set_num_threads(orig);
}

#[test]
fn projection_identities_hold_through_packed_kernels() {
    // PᵀP = I for orthonormal P, and (A·B)ᵀ = Bᵀ·Aᵀ — end-to-end
    // algebra through all three op paths at a non-tile-aligned size.
    let mut rng = Pcg::new(4);
    let p = gum::linalg::random_orthonormal(200, 37, &mut rng);
    let ptp = matmul_tn(&p, &p);
    assert!(ptp.max_abs_diff(&Matrix::eye(37)) < 1e-3);

    let a = Matrix::randn(45, 70, 1.0, &mut rng);
    let b = Matrix::randn(70, 31, 1.0, &mut rng);
    let ab_t = matmul(&a, &b).transpose();
    let bt_at = matmul(&b.transpose(), &a.transpose());
    assert!(ab_t.max_abs_diff(&bt_at) < tol(70));
}
