//! The compressed all-reduce contract (`--reduce lowrank`), locked in
//! end-to-end — the parity matrix the multi-process transport will
//! inherit:
//!
//! 1. **Wire-order spec.** `pairwise_tree_sum` follows the documented
//!    stride-doubling combine order bitwise for every replica count a
//!    scalar replay can check (including the non-power-of-two counts 3,
//!    5, 6, 7). For n ≤ 3 that order *is* the sequential left fold, so
//!    those counts are additionally left-fold-bitwise; for n ≥ 4 the
//!    tree groups differently (f32 addition is not associative), so the
//!    left fold only agrees to round-off — asserting it bitwise there
//!    would pin a property f32 does not have.
//! 2. **Within-mode determinism.** A `lowrank` run is bit-identical
//!    across thread widths 1/2/8 and across sync↔async refresh — the
//!    payload plan is a pure function of committed state and the tree
//!    order is fixed.
//! 3. **Cross-mode parity.** `lowrank` commits the same trajectory as
//!    `dense` to round-off (1e-4, vs the repo's 1e-5 data-parallel
//!    contract): projecting each lane *before* the tree sum reorders
//!    the f32 contractions, so exact bit-equality across modes is not a
//!    property either mode can promise — but the committed rank and
//!    period decisions must agree exactly, because every gradient that
//!    feeds a boundary refresh or controller ships dense by plan.
//! 4. **Replica splits.** (1,4)/(2,2)/(4,1) of the same global batch
//!    agree within the same tolerance under `lowrank`.
//! 5. **Elastic replay.** Lane kills at a refresh boundary ± 1 under
//!    `FaultPlan` roll back and replay to the fault-free `lowrank`
//!    trajectory bit-for-bit — the plan is recomputed per attempt from
//!    committed state, so a replayed step ships the same payloads.

use std::sync::Arc;

use gum::coordinator::{
    pairwise_tree_sum, ElasticConfig, ElasticSession, LrSchedule,
    ParallelConfig, ParallelSession, ReduceMode, ReduceStats, ShardMode,
    ShardedBatcher, SyntheticGradSource,
};
use gum::data::corpus::CorpusSpec;
use gum::data::tokenizer::ByteTokenizer;
use gum::linalg::Matrix;
use gum::model::{BlockKind, ParamBlock, ParamStore};
use gum::optim::{
    self, AdaptivePeriodCfg, AdaptiveRankCfg, PeriodSchedule, RankSchedule,
    RefreshPipelineMode, RefreshStrategy,
};
use gum::rng::Pcg;
use gum::testing::{FaultPlan, FaultPlanArtifact};

const BATCH: usize = 4;
const SEQ: usize = 32;
const PERIOD_K: usize = 5;
const REPLICAS: usize = 4;
const SRC_SEED: u64 = 23;

/// Serializes the tests that flip the process-global chunking width.
static WIDTH_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn small_store() -> ParamStore {
    let mut rng = Pcg::new(5);
    let blocks = vec![
        ParamBlock {
            name: "w0".into(),
            shape: vec![24, 32],
            kind: BlockKind::Projectable,
            value: Matrix::randn(24, 32, 0.1, &mut rng),
        },
        ParamBlock {
            name: "w1".into(),
            shape: vec![32, 24],
            kind: BlockKind::Projectable,
            value: Matrix::randn(32, 24, 0.1, &mut rng),
        },
        ParamBlock {
            name: "w2".into(),
            shape: vec![16, 16],
            kind: BlockKind::Projectable,
            value: Matrix::randn(16, 16, 0.1, &mut rng),
        },
        ParamBlock {
            name: "norm".into(),
            shape: vec![16],
            kind: BlockKind::Dense,
            value: Matrix::from_vec(1, 16, vec![1.0; 16]),
        },
    ];
    ParamStore { blocks }
}

fn session(
    replicas: usize,
    accum: usize,
    reduce: ReduceMode,
    refresh_mode: RefreshPipelineMode,
) -> ParallelSession {
    let params = small_store();
    let opt = optim::build("gum", &params, 4, 1.0, 99).unwrap();
    let pcfg = ParallelConfig {
        replicas,
        accum_steps: accum,
        shard_mode: ShardMode::Interleaved,
        doc_stride: 500_000,
    };
    let batcher = ShardedBatcher::new(
        &CorpusSpec::default(),
        &ByteTokenizer::new(256),
        BATCH,
        SEQ,
        &pcfg,
    );
    let mut s = ParallelSession::new(
        params,
        opt,
        batcher,
        PERIOD_K,
        LrSchedule::constant(0.02),
        17,
    );
    s.set_refresh_mode(refresh_mode);
    s.set_reduce_mode(reduce);
    s
}

fn sources(s: &ParallelSession, n: usize) -> Vec<SyntheticGradSource> {
    vec![SyntheticGradSource::new(&s.params, SRC_SEED); n]
}

/// Drive `steps` global steps, returning the loss trace, the final
/// parameters, and every step's payload accounting.
fn run(
    mut s: ParallelSession,
    replicas: usize,
    steps: usize,
) -> (Vec<f64>, ParamStore, Vec<ReduceStats>) {
    let mut srcs = sources(&s, replicas);
    let mut losses = Vec::with_capacity(steps);
    let mut stats = Vec::with_capacity(steps);
    for _ in 0..steps {
        losses.push(s.global_step(&mut srcs).unwrap().loss);
        stats.push(s.last_reduce.expect("stats recorded every step"));
    }
    (losses, s.params, stats)
}

fn assert_close(
    ctx: &str,
    golden: &(Vec<f64>, ParamStore),
    losses: &[f64],
    params: &ParamStore,
    tol: f64,
) {
    for (i, (a, b)) in golden.0.iter().zip(losses).enumerate() {
        assert!(
            (a - b).abs() < tol,
            "{ctx}: loss diverged at step {i} ({a} vs {b})"
        );
    }
    for (x, y) in golden.1.blocks.iter().zip(&params.blocks) {
        let diff = x.value.max_abs_diff(&y.value) as f64;
        assert!(diff < tol, "{ctx}: block {} max diff {diff}", x.name);
    }
}

/// Scalar replay of the documented wire order: stride-doubling combines
/// `acc[i] += acc[i + s]` for `i ≡ 0 (mod 2s)`, elementwise in f32.
/// This is the order the socket transport must reproduce.
fn reference_tree(mut acc: Vec<Vec<f32>>) -> Vec<f32> {
    let n = acc.len();
    let mut stride = 1;
    while stride < n {
        let mut i = 0;
        while i + stride < n {
            for k in 0..acc[i].len() {
                let add = acc[i + stride][k];
                acc[i][k] += add;
            }
            i += 2 * stride;
        }
        stride *= 2;
    }
    acc.swap_remove(0)
}

#[test]
fn pairwise_tree_sum_matches_the_scalar_wire_spec_bitwise() {
    let mut rng = Pcg::new(11);
    for n in [1usize, 2, 3, 4, 5, 6, 7, 8] {
        let parts: Vec<Matrix> = (0..n)
            .map(|_| Matrix::randn(9, 13, 1.0, &mut rng))
            .collect();
        let want =
            reference_tree(parts.iter().map(|p| p.data.clone()).collect());
        let got = pairwise_tree_sum(parts.clone());
        assert_eq!(got.data, want, "n={n}: wire-order spec violated");

        // Sequential left fold: bitwise for n ≤ 3 (the tree *is* the
        // left fold there); for n ≥ 4 the grouping differs — e.g. n=5
        // reduces as ((0+1)+(2+3))+4, not (((0+1)+2)+3)+4 — so f32
        // non-associativity only admits a round-off bound.
        let mut fold = parts[0].clone();
        for p in &parts[1..] {
            fold.add_scaled_in_place(1.0, p);
        }
        if n <= 3 {
            assert_eq!(got, fold, "n={n}: left fold must be bitwise");
        } else {
            let diff = got.max_abs_diff(&fold);
            assert!(diff < 1e-4, "n={n}: left fold diff {diff}");
        }
    }
}

/// Contract 2, thread widths: the in-process equivalent of relaunching
/// a `--reduce lowrank` run with GUM_THREADS ∈ {1, 2, 8}. Also checks
/// the payload accounting: mid-period steps actually compress, while
/// period-boundary and refresh-trigger steps ship all-dense by plan.
#[test]
fn lowrank_run_bit_identical_across_thread_widths() {
    let _w = WIDTH_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let steps = 2 * PERIOD_K + 2;
    let orig = gum::thread::num_threads();
    let mut runs = Vec::new();
    for width in [1usize, 2, 8] {
        gum::thread::set_num_threads(width);
        runs.push(run(
            session(2, 2, ReduceMode::LowRank, RefreshPipelineMode::Async),
            2,
            steps,
        ));
    }
    gum::thread::set_num_threads(orig);
    for (i, (losses, params, stats)) in runs.iter().enumerate().skip(1) {
        let width = [1, 2, 8][i];
        assert_eq!(&runs[0].0, losses, "width {width}: losses");
        assert_eq!(&runs[0].1, params, "width {width}: params");
        assert_eq!(&runs[0].2, stats, "width {width}: payload stats");
    }
    let stats = &runs[0].2;
    for (step, st) in stats.iter().enumerate() {
        let boundary = step % PERIOD_K == 0;
        let trigger = (step + 1) % PERIOD_K == 0;
        if boundary || trigger {
            assert_eq!(
                st.payload_bytes, st.dense_bytes,
                "step {step}: boundary/trigger steps must ship dense"
            );
            assert_eq!(st.lowrank_blocks, 0, "step {step}");
        } else {
            // Mid-period: every block carries a payload tag, and any
            // block the mask did not sample full-rank ships projected.
            // (A period where *all* projectable blocks drew full-rank
            // legitimately ships dense, so the strict check is on the
            // run total below.)
            assert_eq!(st.lowrank_blocks + st.dense_blocks, 4, "{step}");
            assert!(st.payload_bytes <= st.dense_bytes, "step {step}");
        }
    }
    assert!(
        stats.iter().any(|s| s.lowrank_blocks > 0),
        "the compressed path must engage somewhere in the run"
    );
    let (payload, dense): (usize, usize) = stats
        .iter()
        .fold((0, 0), |(p, d), s| (p + s.payload_bytes, d + s.dense_bytes));
    assert!(
        payload < dense,
        "the run as a whole must move fewer bytes ({payload} vs {dense})"
    );
}

/// Contract 2, refresh pipeline: sync and async plan and reduce
/// identically (the trigger step ships dense under both, and both
/// commit the same bases at the boundary).
#[test]
fn lowrank_sync_and_async_refresh_commit_identical_trajectories() {
    let steps = 3 * PERIOD_K + 1;
    let sync = run(
        session(2, 2, ReduceMode::LowRank, RefreshPipelineMode::Sync),
        2,
        steps,
    );
    let async_ = run(
        session(2, 2, ReduceMode::LowRank, RefreshPipelineMode::Async),
        2,
        steps,
    );
    assert_eq!(sync.0, async_.0, "losses");
    assert_eq!(sync.1, async_.1, "params");
    assert_eq!(sync.2, async_.2, "payload stats");
}

/// Contract 3 + 4: `lowrank` vs `dense` to round-off, and replica
/// splits of the same global batch under `lowrank` agree with the
/// single-lane run at the same tolerance.
#[test]
fn lowrank_matches_dense_across_replica_splits() {
    let steps = 2 * PERIOD_K + 2;
    let dense = {
        let (losses, params, stats) = run(
            session(1, 4, ReduceMode::Dense, RefreshPipelineMode::Async),
            1,
            steps,
        );
        assert!(
            stats.iter().all(|s| s.payload_bytes == s.dense_bytes),
            "dense mode must never compress"
        );
        (losses, params)
    };
    for (replicas, accum) in [(1usize, 4usize), (2, 2), (4, 1)] {
        let (losses, params, stats) = run(
            session(
                replicas,
                accum,
                ReduceMode::LowRank,
                RefreshPipelineMode::Async,
            ),
            replicas,
            steps,
        );
        let ctx = format!("lowrank {replicas}x{accum}");
        assert_close(&ctx, &dense, &losses, &params, 1e-4);
        assert!(
            stats.iter().any(|s| s.lowrank_blocks > 0),
            "{ctx}: the compressed path must actually engage"
        );
    }
}

/// Contract 3 at moving boundaries: adaptive rank and adaptive period
/// schedules re-plan projectors at variable boundaries; `lowrank` must
/// track `dense` to round-off *and* commit exactly the same rank and
/// period decisions — every gradient feeding a controller ships dense.
#[test]
fn adaptive_rank_and_period_schedules_keep_parity() {
    let steps = 3 * PERIOD_K + 2;
    let rank_session = |reduce: ReduceMode| {
        let params = small_store();
        let schedule = RankSchedule::Adaptive(AdaptiveRankCfg {
            energy: 0.90,
            deadband: 1,
            patience: 2,
            min_rank: 1,
            max_rank: 8,
            budget: 12,
        });
        let opt = optim::build_with_schedule(
            "gum",
            &params,
            4,
            1.0,
            99,
            RefreshStrategy::default(),
            &schedule,
        )
        .unwrap();
        let pcfg = ParallelConfig {
            replicas: 2,
            accum_steps: 2,
            shard_mode: ShardMode::Interleaved,
            doc_stride: 500_000,
        };
        let batcher = ShardedBatcher::new(
            &CorpusSpec::default(),
            &ByteTokenizer::new(256),
            BATCH,
            SEQ,
            &pcfg,
        );
        let mut s = ParallelSession::new(
            params,
            opt,
            batcher,
            PERIOD_K,
            LrSchedule::constant(0.02),
            17,
        );
        s.set_reduce_mode(reduce);
        s
    };
    let run_ranks = |reduce: ReduceMode| {
        let mut s = rank_session(reduce);
        let mut srcs = sources(&s, 2);
        let mut losses = Vec::new();
        for _ in 0..steps {
            losses.push(s.global_step(&mut srcs).unwrap().loss);
        }
        let ranks = s.opt.rank_state().expect("adaptive run");
        (losses, s.params, ranks)
    };
    let (dl, dp, dr) = run_ranks(ReduceMode::Dense);
    let (ll, lp, lr) = run_ranks(ReduceMode::LowRank);
    assert_close("adaptive rank", &(dl, dp), &ll, &lp, 1e-4);
    assert_eq!(dr, lr, "committed rank decisions must agree exactly");

    // Adaptive period: a stretch regime whose boundary sequence must be
    // identical under both reduce modes (period decisions ride the
    // dense-shipped trigger gradients).
    let period_schedule = PeriodSchedule::Adaptive(AdaptivePeriodCfg {
        drift: 0.999,
        patience: 1,
        min_period: 2,
        max_period: 20,
    });
    let run_periods = |reduce: ReduceMode| {
        let mut s =
            session(2, 2, reduce, RefreshPipelineMode::Async);
        s.set_period_schedule(&period_schedule);
        let mut srcs = sources(&s, 2);
        let mut losses = Vec::new();
        let mut periods = Vec::new();
        for _ in 0..steps {
            losses.push(s.global_step(&mut srcs).unwrap().loss);
            periods.push(s.periods.current_period());
        }
        (losses, periods, s.params)
    };
    let (dl, dk, dp) = run_periods(ReduceMode::Dense);
    let (ll, lk, lp) = run_periods(ReduceMode::LowRank);
    assert_eq!(dk, lk, "committed period sequence must agree exactly");
    assert_close("adaptive period", &(dl, dp), &ll, &lp, 1e-4);
}

/// Contract 5: lane kills at a refresh boundary ± 1 under `FaultPlan`.
/// The elastic supervisor recomputes the payload plan per attempt from
/// committed state, so the rollback replay ships the same payloads and
/// commits the fault-free `lowrank` trajectory bit-for-bit.
#[test]
fn lane_kills_replay_the_compressed_reduce_bitwise() {
    let steps = 2 * PERIOD_K + 2;
    let golden = {
        let (losses, params, _) = run(
            session(
                REPLICAS,
                1,
                ReduceMode::LowRank,
                RefreshPipelineMode::Async,
            ),
            REPLICAS,
            steps,
        );
        (losses, params)
    };
    let boundary = PERIOD_K as u64;
    for lane in [0usize, REPLICAS - 1] {
        for kill_step in [boundary - 1, boundary, boundary + 1] {
            let plan = Arc::new(
                FaultPlan::parse(&format!("kill:{lane}@{kill_step}"))
                    .unwrap(),
            );
            let _artifact = FaultPlanArtifact::new(
                &format!("reduce_lowrank_kill{lane}_step{kill_step}"),
                &plan,
            );
            let lane_plan = plan.clone();
            let mut sess = ElasticSession::new(
                session(
                    REPLICAS,
                    1,
                    ReduceMode::LowRank,
                    RefreshPipelineMode::Async,
                ),
                ElasticConfig::default(),
                plan.clone(),
                move |params, lane| {
                    SyntheticGradSource::new(params, SRC_SEED)
                        .with_faults(lane, lane_plan.clone())
                },
            );
            let losses = sess.run(steps).unwrap();
            let ctx = format!("lowrank kill:{lane}@{kill_step}");
            assert_eq!(plan.fired_count(), 1, "{ctx}: fault must fire");
            assert_eq!(sess.restarts_used(), 1, "{ctx}");
            assert_eq!(golden.0, losses, "{ctx}: loss trace diverged");
            for (x, y) in golden.1.blocks.iter().zip(&sess.inner.params.blocks)
            {
                assert_eq!(x.value, y.value, "{ctx}: block {}", x.name);
            }
            let last = sess.inner.last_reduce.expect("stats recorded");
            assert!(
                last.dense_bytes >= last.payload_bytes,
                "{ctx}: accounting sane"
            );
        }
    }
}
