//! The reduced-precision optimizer-state contract
//! (`linalg::lowp` + `--state-dtype`):
//!
//! 1. **Conversions** — bf16/f16 pack is round-to-nearest-even:
//!    every 16-bit pattern round-trips exactly, random values land
//!    within half a ULP, and halfway cases break to the even mantissa.
//! 2. **Fused kernels** — each lowp kernel against an f64 reference at
//!    odd lengths and unaligned sub-slices; the persisted bits are
//!    exactly the RTNE image of the unrounded f32 accumulator.
//! 3. **Determinism** — kernel outputs and whole bf16 GUM trajectories
//!    are bit-identical under `GUM_THREADS` ∈ {1, 2, 8}, replica
//!    splits, and sync↔async refresh (within one ISA path).
//! 4. **Checkpoints** — bf16 state round-trips through a `GUMCKPT3`
//!    file (DTYPE-tagged sections), f32 states keep the legacy layout,
//!    and a dtype-mismatched resume is rejected with a diagnostic.
//! 5. **Parity** — a short f32 vs bf16 training run stays within 1e-2
//!    on the loss trace.

use gum::coordinator::{
    load_train_state, save_train_state, LrSchedule, ParallelConfig,
    ParallelSession, ShardMode, ShardedBatcher, SyntheticGradSource,
};
use gum::data::corpus::CorpusSpec;
use gum::data::tokenizer::ByteTokenizer;
use gum::linalg::lowp::{
    self, bf16_to_f32, f16_to_f32, f32_to_bf16, f32_to_f16, StateDtype,
};
use gum::linalg::Matrix;
use gum::model::{BlockKind, ParamBlock, ParamStore};
use gum::optim::{
    self, Optimizer, RankSchedule, RefreshPipelineMode, RefreshStrategy,
    StepCtx,
};
use gum::rng::Pcg;
use gum::thread::{num_threads, set_num_threads};

/// Serializes tests that flip the process-global thread width — same
/// discipline as `elementwise_kernels.rs` / `parallel_equivalence.rs`.
static GLOBAL_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Lengths crossing every dispatch regime: empty, sub-SIMD-width, odd,
/// just over a vector register, and several parallel chunks wide.
const LENGTHS: [usize; 8] = [0, 1, 3, 7, 17, 63, 1025, 3 * (1 << 15) + 7];

const DTYPES: [StateDtype; 2] = [StateDtype::Bf16, StateDtype::F16];

fn data(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg::new(seed);
    (0..n).map(|_| rng.normal_f32()).collect()
}

/// Pack a fresh random buffer and return (bits, exact f32 unpacking).
fn packed(dtype: StateDtype, n: usize, seed: u64) -> (Vec<u16>, Vec<f32>) {
    let src = data(n, seed);
    let mut bits = vec![0u16; n];
    lowp::pack_slice(dtype, &src, &mut bits);
    let mut exact = vec![0f32; n];
    lowp::unpack_slice(dtype, &bits, &mut exact);
    (bits, exact)
}

fn assert_close(got: &[f32], want_f64: &[f64], ctx: &str) {
    assert_eq!(got.len(), want_f64.len(), "{ctx}: length");
    for (i, (&g, &w)) in got.iter().zip(want_f64).enumerate() {
        let tol = 1e-5 * w.abs().max(1.0);
        assert!(
            (g as f64 - w).abs() <= tol,
            "{ctx}[{i}]: got {g}, want {w}"
        );
    }
}

/// The persisted 16-bit state vs an f64 reference: one RTNE rounding of
/// the format (2⁻⁸ / 2⁻¹¹ ULP) plus an absolute floor covering f32
/// accumulation error under cancellation.
fn assert_close_packed(
    bits: &[u16],
    want_f64: &[f64],
    dtype: StateDtype,
    ctx: &str,
) {
    let mut got = vec![0f32; bits.len()];
    lowp::unpack_slice(dtype, bits, &mut got);
    let rel = match dtype {
        StateDtype::Bf16 => 2f64.powi(-7),
        _ => 2f64.powi(-10),
    };
    for (i, (&g, &w)) in got.iter().zip(want_f64).enumerate() {
        let tol = rel * w.abs() + 1e-6;
        assert!(
            (g as f64 - w).abs() <= tol,
            "{ctx}[{i}]: unpacked {g}, want {w} ({dtype})"
        );
    }
}

// ---------------------------------------------------------------------------
// 1. Conversions
// ---------------------------------------------------------------------------

#[test]
fn bf16_every_pattern_roundtrips_exactly() {
    for b in 0..=u16::MAX {
        let x = bf16_to_f32(b);
        let rb = f32_to_bf16(x);
        if x.is_nan() {
            // NaN payloads may be quieted but must stay NaN.
            assert_eq!(rb & 0x7F80, 0x7F80, "pattern {b:#06x}");
            assert_ne!(rb & 0x007F, 0, "pattern {b:#06x}");
        } else {
            // Exactly representable values (±0, ±Inf, subnormals
            // included) are fixed points of pack∘unpack.
            assert_eq!(rb, b, "pattern {b:#06x}");
        }
    }
}

#[test]
fn f16_every_pattern_roundtrips_exactly() {
    for h in 0..=u16::MAX {
        let x = f16_to_f32(h);
        let rh = f32_to_f16(x);
        if x.is_nan() {
            assert_eq!(rh & 0x7C00, 0x7C00, "pattern {h:#06x}");
            assert_ne!(rh & 0x03FF, 0, "pattern {h:#06x}");
        } else {
            assert_eq!(rh, h, "pattern {h:#06x}");
        }
    }
}

#[test]
fn bf16_packing_is_round_to_nearest_even() {
    // Half-ULP bound on random normals: bf16 keeps 8 significand bits,
    // so ULP(x) ≤ 2⁻⁷·|x| and an RTNE result sits within 2⁻⁸·|x|.
    for &x in &data(4096, 7) {
        let q = bf16_to_f32(f32_to_bf16(x)) as f64;
        let tol = 2f64.powi(-8) * (x as f64).abs();
        assert!(
            (q - x as f64).abs() <= tol,
            "pack({x}) = {q} misses the half-ULP bound"
        );
    }
    // Exact halfway cases break toward the even mantissa.
    assert_eq!(f32_to_bf16(f32::from_bits(0x3F80_8000)), 0x3F80);
    assert_eq!(f32_to_bf16(f32::from_bits(0x3F81_8000)), 0x3F82);
    // Just past the tie rounds away.
    assert_eq!(f32_to_bf16(f32::from_bits(0x3F80_8001)), 0x3F81);
}

#[test]
fn f16_handles_overflow_and_subnormals() {
    assert_eq!(f16_to_f32(f32_to_f16(65504.0)), 65504.0); // f16 max
    assert!(f16_to_f32(f32_to_f16(70000.0)).is_infinite()); // overflow
    let tiny = 5.960_464_5e-8; // min f16 subnormal
    assert_eq!(f16_to_f32(f32_to_f16(tiny)), tiny);
    assert_eq!(f16_to_f32(f32_to_f16(1e-9)), 0.0); // below half min subnormal
}

// ---------------------------------------------------------------------------
// 2. Fused kernels vs f64 references
// ---------------------------------------------------------------------------

#[test]
fn lowp_axpby_matches_f64_reference_all_lengths() {
    for dtype in DTYPES {
        for &n in &LENGTHS {
            let (mut bits, m0) = packed(dtype, n, 1);
            let y = data(n, 2);
            let want: Vec<f64> = m0
                .iter()
                .zip(&y)
                .map(|(&mv, &yv)| 0.95f64 * mv as f64 + 1.5f64 * yv as f64)
                .collect();
            let mut out = vec![0f32; n];
            lowp::axpby(dtype, 0.95, &mut bits, 1.5, &y, &mut out);
            let ctx = format!("lowp axpby {dtype} n={n}");
            assert_close(&out, &want, &ctx);
            // The persisted bits are exactly pack(out): only the RTNE
            // image of the unrounded accumulator survives the step.
            let mut repack = vec![0u16; n];
            lowp::pack_slice(dtype, &out, &mut repack);
            assert_eq!(repack, bits, "{ctx}: bits are not pack(out)");
        }
    }
}

#[test]
fn lowp_decay_accumulate2_matches_f64_reference_all_lengths() {
    for dtype in DTYPES {
        for &n in &LENGTHS {
            let (mut bits, m0) = packed(dtype, n, 3);
            let x = data(n, 4);
            let y = data(n, 5);
            let want: Vec<f64> = m0
                .iter()
                .zip(&x)
                .zip(&y)
                .map(|((&mv, &xv), &yv)| {
                    0.9f64 * mv as f64 + 2.5f64 * xv as f64
                        - 2.5f64 * yv as f64
                })
                .collect();
            let mut out = vec![0f32; n];
            lowp::decay_accumulate2(
                dtype, &mut bits, 0.9, 2.5, &x, -2.5, &y, &mut out,
            );
            let ctx = format!("lowp decay_accumulate2 {dtype} n={n}");
            assert_close(&out, &want, &ctx);
            let mut repack = vec![0u16; n];
            lowp::pack_slice(dtype, &out, &mut repack);
            assert_eq!(repack, bits, "{ctx}: bits are not pack(out)");
        }
    }
}

#[test]
fn lowp_adam_kernels_match_f64_reference_all_lengths() {
    let (b1, b2, eps, lr, wd) = (0.9f32, 0.999, 1e-8, 0.05, 0.01);
    let (bc1, bc2) = (1.0 - b1.powi(4), 1.0 - b2.powi(4));
    for dtype in DTYPES {
        for &n in &LENGTHS {
            let g = data(n, 11);
            let (mut m, m0) = packed(dtype, n, 12);
            let vsrc: Vec<f32> = data(n, 13).iter().map(|x| x * x).collect();
            let mut v = vec![0u16; n];
            lowp::pack_slice(dtype, &vsrc, &mut v);
            let mut v0 = vec![0f32; n];
            lowp::unpack_slice(dtype, &v, &mut v0);

            let mut want_upd = Vec::with_capacity(n);
            let mut want_m = Vec::with_capacity(n);
            let mut want_v = Vec::with_capacity(n);
            for i in 0..n {
                let mi = b1 as f64 * m0[i] as f64
                    + (1.0 - b1 as f64) * g[i] as f64;
                let vi = b2 as f64 * v0[i] as f64
                    + (1.0 - b2 as f64) * (g[i] as f64) * (g[i] as f64);
                want_m.push(mi);
                want_v.push(vi);
                want_upd.push(
                    (mi / bc1 as f64)
                        / ((vi / bc2 as f64).sqrt() + eps as f64),
                );
            }
            let mut upd = vec![0f32; n];
            lowp::adam_update(
                dtype, &mut upd, &g, &mut m, &mut v, b1, b2, bc1, bc2, eps,
            );
            let ctx = format!("lowp adam_update {dtype} n={n}");
            // The step direction comes from the unrounded accumulators…
            assert_close(&upd, &want_upd, &ctx);
            // …while the stored moments are one RTNE rounding away.
            assert_close_packed(&m, &want_m, dtype, &format!("{ctx} m"));
            assert_close_packed(&v, &want_v, dtype, &format!("{ctx} v"));

            // adam_apply from the same starting moments.
            let mut m = vec![0u16; n];
            lowp::pack_slice(dtype, &m0, &mut m);
            let mut v = vec![0u16; n];
            lowp::pack_slice(dtype, &v0, &mut v);
            let mut w = data(n, 14);
            let w0 = w.clone();
            let mut want_w = Vec::with_capacity(n);
            for i in 0..n {
                let mhat = want_m[i] / bc1 as f64;
                let vhat = want_v[i] / bc2 as f64;
                let x = w0[i] as f64 * (1.0 - lr as f64 * wd as f64);
                want_w.push(
                    x - lr as f64 * mhat / (vhat.sqrt() + eps as f64),
                );
            }
            lowp::adam_apply(
                dtype, &mut w, &g, &mut m, &mut v, b1, b2, bc1, bc2, eps,
                lr, wd,
            );
            let ctx = format!("lowp adam_apply {dtype} n={n}");
            assert_close(&w, &want_w, &ctx);
            assert_close_packed(&m, &want_m, dtype, &format!("{ctx} m"));
            assert_close_packed(&v, &want_v, dtype, &format!("{ctx} v"));
        }
    }
}

/// The SIMD paths must not assume any alignment: `[off..]` windows of a
/// larger buffer produce the same bytes as a fresh aligned copy.
#[test]
fn lowp_unaligned_subslices_match_aligned_results() {
    let n = 4096 + 11;
    for dtype in DTYPES {
        for off in 1..=7usize {
            let (bits_full, _) = packed(dtype, n + off, 20);
            let y_full = data(n + off, 21);

            let mut bits_win = bits_full.clone();
            let mut out_win = vec![0f32; n + off];
            lowp::axpby(
                dtype,
                0.8,
                &mut bits_win[off..],
                -1.2,
                &y_full[off..],
                &mut out_win[off..],
            );

            let mut bits_ref: Vec<u16> = bits_full[off..].to_vec();
            let y_ref: Vec<f32> = y_full[off..].to_vec();
            let mut out_ref = vec![0f32; n];
            lowp::axpby(dtype, 0.8, &mut bits_ref, -1.2, &y_ref, &mut out_ref);

            assert_eq!(
                &bits_win[off..],
                &bits_ref[..],
                "{dtype} axpby offset {off} changed the packed bits"
            );
            assert_eq!(
                &out_win[off..],
                &out_ref[..],
                "{dtype} axpby offset {off} changed the accumulator"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// 3. Determinism
// ---------------------------------------------------------------------------

/// Every lowp kernel is bit-identical under any `GUM_THREADS` width:
/// each output element is a pure function of its index, so chunk
/// boundaries cannot change the arithmetic.
#[test]
fn lowp_kernels_bit_identical_across_thread_widths() {
    let _g = GLOBAL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let n = 3 * (1 << 15) + 777; // several chunks wide at any width
    let orig = num_threads();
    let (b1, b2, eps, lr, wd) = (0.9f32, 0.999, 1e-8, 0.05, 0.01);
    let run = |dtype: StateDtype, width: usize| {
        set_num_threads(width);
        let (mut bits, _) = packed(dtype, n, 30);
        let y = data(n, 31);
        let mut out = vec![0f32; n];
        lowp::axpby(dtype, 0.95, &mut bits, 0.3, &y, &mut out);
        let (mut dm, _) = packed(dtype, n, 32);
        let mut dout = vec![0f32; n];
        lowp::decay_accumulate2(
            dtype, &mut dm, 0.9, 1.5, &out, -1.5, &y, &mut dout,
        );
        let g = data(n, 34);
        let (mut am, _) = packed(dtype, n, 35);
        let (mut av, _) = packed(dtype, n, 36);
        let mut upd = vec![0f32; n];
        lowp::adam_update(
            dtype, &mut upd, &g, &mut am, &mut av, b1, b2, 0.5, 0.5, eps,
        );
        let mut w = data(n, 37);
        lowp::adam_apply(
            dtype, &mut w, &g, &mut am, &mut av, b1, b2, 0.5, 0.5, eps, lr,
            wd,
        );
        (bits, out, dm, dout, upd, am, av, w)
    };
    for dtype in DTYPES {
        let golden = run(dtype, 1);
        for width in [2usize, 8, 16] {
            let got = run(dtype, width);
            set_num_threads(orig);
            assert_eq!(
                golden, got,
                "{dtype}: width {width} changed kernel bytes"
            );
        }
    }
    set_num_threads(orig);
}

/// Small multi-block store, same shape mix as `parallel_equivalence.rs`:
/// left/right projection plus a dense AdamW block.
fn small_store() -> ParamStore {
    let mut rng = Pcg::new(5);
    ParamStore {
        blocks: vec![
            ParamBlock {
                name: "w0".into(),
                shape: vec![24, 32],
                kind: BlockKind::Projectable,
                value: Matrix::randn(24, 32, 0.1, &mut rng),
            },
            ParamBlock {
                name: "w1".into(),
                shape: vec![32, 24],
                kind: BlockKind::Projectable,
                value: Matrix::randn(32, 24, 0.1, &mut rng),
            },
            ParamBlock {
                name: "norm".into(),
                shape: vec![16],
                kind: BlockKind::Dense,
                value: Matrix::from_vec(1, 16, vec![1.0; 16]),
            },
        ],
    }
}

fn build_gum(dtype: StateDtype, params: &ParamStore) -> Box<dyn optim::Optimizer> {
    optim::build_with_state(
        "gum",
        params,
        4,
        1.0,
        99,
        RefreshStrategy::default(),
        &RankSchedule::Fixed,
        dtype,
    )
    .unwrap()
}

/// A whole bf16 GUM trajectory is bit-identical under any thread width.
#[test]
fn bf16_gum_trajectory_bit_identical_across_thread_widths() {
    let _g = GLOBAL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let orig = num_threads();
    let run = |width: usize| {
        set_num_threads(width);
        let store = small_store();
        let grads: Vec<Matrix> = store
            .blocks
            .iter()
            .map(|b| {
                let mut rng = Pcg::new(7);
                Matrix::randn(b.value.rows, b.value.cols, 1.0, &mut rng)
            })
            .collect();
        let mut opt = build_gum(StateDtype::Bf16, &store);
        let mut s = store.clone();
        let mut rng = Pcg::new(9);
        opt.begin_period(&s, &grads, &mut rng);
        for step in 0..6 {
            opt.step(&mut s, &grads, &StepCtx { lr: 0.02, step });
        }
        set_num_threads(orig);
        s
    };
    let golden = run(1);
    for width in [2usize, 8] {
        let got = run(width);
        for (a, b) in golden.blocks.iter().zip(&got.blocks) {
            assert_eq!(
                a.value, b.value,
                "width {width}: block {} diverged",
                a.name
            );
        }
    }
}

const BATCH: usize = 4;
const SEQ: usize = 32;
const PERIOD_K: usize = 5;

fn session_with_dtype(
    replicas: usize,
    accum: usize,
    dtype: StateDtype,
) -> ParallelSession {
    let params = small_store();
    let opt = build_gum(dtype, &params);
    let pcfg = ParallelConfig {
        replicas,
        accum_steps: accum,
        shard_mode: ShardMode::Interleaved,
        doc_stride: 500_000,
    };
    let batcher = ShardedBatcher::new(
        &CorpusSpec::default(),
        &ByteTokenizer::new(256),
        BATCH,
        SEQ,
        &pcfg,
    );
    ParallelSession::new(
        params,
        opt,
        batcher,
        PERIOD_K,
        LrSchedule::constant(0.02),
        17,
    )
}

fn sources(session: &ParallelSession, n: usize) -> Vec<SyntheticGradSource> {
    vec![SyntheticGradSource::new(&session.params, 23); n]
}

/// Replica splits of the same global batch leave a bf16 trajectory
/// bit-identical (power-of-two windows, fixed ISA path — the packed
/// state only ever sees the reduced gradient, which is split-invariant).
#[test]
fn bf16_trajectory_bit_identical_across_replica_splits() {
    let mut runs: Vec<(Vec<f64>, ParamStore)> = Vec::new();
    for (replicas, accum) in [(1usize, 2usize), (2, 1)] {
        let mut s = session_with_dtype(replicas, accum, StateDtype::Bf16);
        let mut srcs = sources(&s, replicas);
        let mut losses = Vec::new();
        for _ in 0..2 * PERIOD_K + 1 {
            losses.push(s.global_step(&mut srcs).unwrap().loss);
        }
        runs.push((losses, s.params));
    }
    assert_eq!(runs[0].0, runs[1].0, "loss trace diverged across splits");
    for (a, b) in runs[0].1.blocks.iter().zip(&runs[1].1.blocks) {
        assert_eq!(a.value, b.value, "block {} diverged", a.name);
    }
}

/// Sync and async refresh pipelines produce the same bf16 trajectory —
/// the overlap changes scheduling, never arithmetic.
#[test]
fn bf16_trajectory_identical_sync_vs_async_refresh() {
    let mut runs: Vec<ParamStore> = Vec::new();
    for mode in [RefreshPipelineMode::Sync, RefreshPipelineMode::Async] {
        let mut s = session_with_dtype(1, 2, StateDtype::Bf16);
        s.set_refresh_mode(mode);
        let mut srcs = sources(&s, 1);
        for _ in 0..3 * PERIOD_K + 1 {
            s.global_step(&mut srcs).unwrap();
        }
        runs.push(s.params);
    }
    for (a, b) in runs[0].blocks.iter().zip(&runs[1].blocks) {
        assert_eq!(a.value, b.value, "block {}: sync vs async", a.name);
    }
}

// ---------------------------------------------------------------------------
// 4. Checkpoints
// ---------------------------------------------------------------------------

/// Mid-period save/resume with bf16 state: momentum bits, projector,
/// and sampler round-trip through a GUMCKPT3 file (DTYPE-tagged moment
/// sections) and the resumed run replays the uninterrupted one
/// bit-for-bit.
#[test]
fn bf16_mid_period_checkpoint_resume_matches_uninterrupted() {
    let mut a = session_with_dtype(1, 2, StateDtype::Bf16);
    let mut sa = sources(&a, 1);
    for _ in 0..PERIOD_K + 2 {
        a.global_step(&mut sa).unwrap();
    }
    assert_ne!(a.step % PERIOD_K, 0, "snapshot must land mid-period");
    let state = a.train_state();
    assert!(state.opt.is_some(), "GUM must produce an optimizer snapshot");

    let path = std::env::temp_dir().join("gum_state_dtype_resume_test.bin");
    save_train_state(&state, &path).unwrap();
    let loaded = load_train_state(&path).unwrap();
    assert_eq!(loaded.opt, state.opt, "bf16 snapshot must round-trip");

    let mut b = session_with_dtype(1, 2, StateDtype::Bf16);
    let mut sb = sources(&b, 1);
    b.restore_train_state(&loaded).unwrap();

    for _ in 0..PERIOD_K + 3 {
        let la = a.global_step(&mut sa).unwrap().loss;
        let lb = b.global_step(&mut sb).unwrap().loss;
        assert_eq!(la, lb, "resumed loss trace must match");
    }
    for (x, y) in a.params.blocks.iter().zip(&b.params.blocks) {
        assert_eq!(x.value, y.value, "{}", x.name);
    }
}

/// Restoring a bf16 checkpoint into an f32-configured session must fail
/// with a diagnostic naming both dtypes — never silently reinterpret.
#[test]
fn dtype_mismatched_resume_is_rejected() {
    let mut a = session_with_dtype(1, 2, StateDtype::Bf16);
    let mut sa = sources(&a, 1);
    for _ in 0..3 {
        a.global_step(&mut sa).unwrap();
    }
    let path = std::env::temp_dir().join("gum_state_dtype_mismatch_test.bin");
    save_train_state(&a.train_state(), &path).unwrap();
    let loaded = load_train_state(&path).unwrap();

    let mut b = session_with_dtype(1, 2, StateDtype::F32);
    let err = b.restore_train_state(&loaded).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("bf16") && msg.contains("f32"),
        "diagnostic must name both dtypes: {msg}"
    );
}

/// The f32 path never emits DTYPE-tagged sections: a default-dtype
/// train state keeps the legacy `Mat` layout and restores into a
/// default session — files from before the state-dtype layer read the
/// same way.
#[test]
fn f32_checkpoints_keep_the_legacy_layout() {
    let mut a = session_with_dtype(1, 2, StateDtype::F32);
    let mut sa = sources(&a, 1);
    for _ in 0..3 {
        a.global_step(&mut sa).unwrap();
    }
    let state = a.train_state();
    let snap = state.opt.as_ref().expect("GUM snapshots");
    for (key, value) in &snap.entries {
        assert!(
            !matches!(value, optim::SnapValue::LowpMat { .. }),
            "f32 snapshot entry '{key}' must stay a legacy Mat section"
        );
    }
    let path = std::env::temp_dir().join("gum_state_dtype_legacy_test.bin");
    save_train_state(&state, &path).unwrap();
    let loaded = load_train_state(&path).unwrap();
    assert_eq!(loaded.opt, state.opt);

    let mut b = session_with_dtype(1, 2, StateDtype::F32);
    let mut sb = sources(&b, 1);
    b.restore_train_state(&loaded).unwrap();
    let la = a.global_step(&mut sa).unwrap().loss;
    let lb = b.global_step(&mut sb).unwrap().loss;
    assert_eq!(la, lb);
}

// ---------------------------------------------------------------------------
// 5. Parity
// ---------------------------------------------------------------------------

/// bf16 moments track the f32 trajectory: after a short run the loss
/// traces stay within 1e-2 — the storage dtype is a memory knob, not a
/// different optimizer.
#[test]
fn bf16_loss_trace_stays_close_to_f32() {
    let run = |dtype: StateDtype| {
        let mut s = session_with_dtype(1, 2, dtype);
        let mut srcs = sources(&s, 1);
        let mut last = 0.0;
        for _ in 0..2 * PERIOD_K {
            last = s.global_step(&mut srcs).unwrap().loss;
        }
        (last, s.opt.state_bytes())
    };
    let (loss_f32, bytes_f32) = run(StateDtype::F32);
    let (loss_bf16, bytes_bf16) = run(StateDtype::Bf16);
    assert!(
        (loss_f32 - loss_bf16).abs() < 1e-2,
        "final loss diverged: f32 {loss_f32} vs bf16 {loss_bf16}"
    );
    assert!(
        bytes_bf16 < bytes_f32,
        "bf16 must shrink the state: {bytes_bf16} vs {bytes_f32}"
    );
}
