//! GEMM autotuner + tuning-cache integration suite.
//!
//! What it locks in:
//! - tuning **off** (the default, and what CI/determinism suites pin)
//!   is bitwise-identical to the fixed-tiling path across
//!   `GUM_THREADS` 1/2/8;
//! - a search persists a cache file that round-trips: a fresh table
//!   warm-loaded from disk serves every class with **zero** new
//!   searches and reproduces the tuned results bit-for-bit;
//! - a corrupt/truncated cache is ignored silently (the run still
//!   produces correct results and re-searches), then gets rewritten
//!   valid;
//! - tuned results are correct vs the off-path to accumulation-order
//!   tolerance, and bit-identical across thread widths for a pinned
//!   (warm-cache) tile choice.
//!
//! The tuner is process-global state, so every test serializes on one
//! mutex and restores mode/path on exit.

use std::path::PathBuf;
use std::sync::Mutex;

use gum::linalg::tune::{self, TuneMode};
use gum::linalg::{gemm_forced, matmul_nt, matmul_tn, Matrix};
use gum::rng::Pcg;
use gum::thread::set_num_threads;

static TUNER_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` with the tuner in a known state (mode + cache path),
/// restoring the previous state after — panics included.
fn with_tuner<R>(
    mode: TuneMode,
    path: Option<PathBuf>,
    f: impl FnOnce() -> R,
) -> R {
    let _guard = TUNER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev_mode = tune::set_mode(Some(mode));
    let prev_path = tune::set_cache_path(path);
    tune::reset();
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
    tune::set_cache_path(prev_path);
    tune::set_mode(prev_mode);
    tune::reset();
    match result {
        Ok(r) => r,
        Err(p) => std::panic::resume_unwind(p),
    }
}

fn tmp_cache(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("gum_tune_it_{name}.json"));
    let _ = std::fs::remove_file(&p);
    p
}

/// A narrow-k projection shape big enough to clear the Small region
/// (2·256·512·32 = 2²³ FLOPs → NarrowK) but cheap enough to search in
/// milliseconds.
fn narrow_k_operands(rng: &mut Pcg) -> (Matrix, Matrix) {
    let a = Matrix::randn(256, 32, 1.0, rng); // m×k
    let b = Matrix::randn(512, 32, 1.0, rng); // n×k (NT)
    (a, b)
}

#[test]
fn tune_off_is_bitwise_identical_to_fixed_path_across_threads() {
    with_tuner(TuneMode::Off, None, || {
        let mut rng = Pcg::new(7);
        let (a, b) = narrow_k_operands(&mut rng);
        // The off-mode driver must take exactly the fixed-tiling path.
        let mut fixed = Matrix::zeros(a.rows, b.rows);
        gemm_forced(
            1.0, &a, &b, 0.0, &mut fixed, false, true, tune::fixed_config(),
        );
        let orig = set_num_threads(1);
        for t in [1usize, 2, 8] {
            set_num_threads(t);
            let got = matmul_nt(&a, &b);
            assert_eq!(got.data, fixed.data, "off-mode bits, threads {t}");
        }
        set_num_threads(orig);

        // Below the cutover the off path runs the unpacked kernel;
        // gemm_forced's Unpacked config is that same kernel.
        let a = Matrix::randn(16, 8, 1.0, &mut rng);
        let b = Matrix::randn(24, 8, 1.0, &mut rng);
        let mut unpacked = Matrix::zeros(16, 24);
        gemm_forced(
            1.0,
            &a,
            &b,
            0.0,
            &mut unpacked,
            false,
            true,
            tune::TileConfig::unpacked(),
        );
        assert_eq!(matmul_nt(&a, &b).data, unpacked.data, "tiny cutover bits");
        assert_eq!(tune::searches_performed(), 0, "off mode never searches");
    });
}

#[test]
fn search_persists_cache_and_warm_reload_skips_search() {
    let path = tmp_cache("roundtrip");
    with_tuner(TuneMode::On, Some(path.clone()), || {
        let mut rng = Pcg::new(11);
        let (a, b) = narrow_k_operands(&mut rng);

        let first = matmul_nt(&a, &b);
        assert_eq!(tune::searches_performed(), 1, "cold miss searches once");
        // Same class again: served from the in-memory table.
        let again = matmul_nt(&a, &b);
        assert_eq!(first.data, again.data, "stable within a process");
        assert_eq!(tune::searches_performed(), 1, "table hit, no re-search");

        // The cache file exists, is valid JSON with the versioned
        // header, and holds the searched class.
        let table = tune::load_cache_file(&path)
            .expect("persisted cache parses and matches this host");
        assert!(
            table.contains_key("nt/k5"),
            "searched class recorded: {table:?}"
        );

        // Fresh table + warm file: the reload must serve the class
        // with zero new searches and reproduce the bits.
        tune::reset();
        let warm = matmul_nt(&a, &b);
        assert_eq!(tune::searches_performed(), 0, "warm cache skips search");
        assert_eq!(warm.data, first.data, "warm-loaded config, same bits");

        // Correctness of whatever config won: compare against the
        // fixed path to accumulation-order tolerance (tuned kc may
        // split the k-reduction differently).
        let mut fixed = Matrix::zeros(a.rows, b.rows);
        gemm_forced(
            1.0, &a, &b, 0.0, &mut fixed, false, true, tune::fixed_config(),
        );
        assert!(
            warm.max_abs_diff(&fixed) < 1e-3,
            "tuned result agrees with fixed path"
        );
    });
    let _ = std::fs::remove_file(&path);
}

#[test]
fn corrupt_or_truncated_cache_falls_back_silently() {
    for (name, junk) in [
        ("corrupt", "this is not json {"),
        ("truncated", r#"{"magic": "gum-tune-cache", "version": 1, "ent"#),
        ("wrong_magic", r#"{"magic": "other", "version": 1, "entries": []}"#),
        ("wrong_version", r#"{"magic": "gum-tune-cache", "version": 999}"#),
    ] {
        let path = tmp_cache(name);
        std::fs::write(&path, junk).unwrap();
        with_tuner(TuneMode::On, Some(path.clone()), || {
            let mut rng = Pcg::new(13);
            let (a, b) = narrow_k_operands(&mut rng);
            // Bad cache: ignored without error; the run searches as if
            // no cache existed and still computes correct results.
            let got = matmul_nt(&a, &b);
            assert_eq!(tune::searches_performed(), 1, "{name}: re-searched");
            let mut fixed = Matrix::zeros(a.rows, b.rows);
            gemm_forced(
                1.0, &a, &b, 0.0, &mut fixed, false, true,
                tune::fixed_config(),
            );
            assert!(got.max_abs_diff(&fixed) < 1e-3, "{name}: correct");
            // And the bad file was replaced by a valid one.
            assert!(
                tune::load_cache_file(&path).is_some(),
                "{name}: cache rewritten valid"
            );
        });
        let _ = std::fs::remove_file(&path);
    }
}

#[test]
fn tuned_results_are_thread_invariant_with_warm_cache() {
    let path = tmp_cache("threads");
    with_tuner(TuneMode::On, Some(path.clone()), || {
        let mut rng = Pcg::new(17);
        let (a, b) = narrow_k_operands(&mut rng);
        // Narrow-m too (TN): Pᵀ·G with r=32 output rows.
        let p = Matrix::randn(256, 32, 1.0, &mut rng); // stored k×m
        let g = Matrix::randn(256, 512, 1.0, &mut rng);

        // Populate the cache (searches happen at whatever thread count
        // the harness runs), then pin: every later call warm-loads the
        // same tile choice, so bits must match across widths.
        let nt_ref = matmul_nt(&a, &b);
        let tn_ref = matmul_tn(&p, &g);
        let orig = set_num_threads(1);
        for t in [1usize, 2, 8] {
            set_num_threads(t);
            tune::reset(); // drop the table; reload from the warm file
            let nt = matmul_nt(&a, &b);
            let tn = matmul_tn(&p, &g);
            assert_eq!(
                tune::searches_performed(),
                0,
                "warm cache at threads {t}"
            );
            assert_eq!(nt.data, nt_ref.data, "nt bits, threads {t}");
            assert_eq!(tn.data, tn_ref.data, "tn bits, threads {t}");
        }
        set_num_threads(orig);
    });
    let _ = std::fs::remove_file(&path);
}

#[test]
fn unwritable_cache_path_still_computes() {
    // A cache path whose parent can't be created must not fail the
    // GEMM — persistence is best-effort by contract.
    let path = PathBuf::from("/proc/gum-definitely-not-writable/tune.json");
    with_tuner(TuneMode::On, Some(path), || {
        let mut rng = Pcg::new(19);
        let (a, b) = narrow_k_operands(&mut rng);
        let got = matmul_nt(&a, &b);
        let mut fixed = Matrix::zeros(a.rows, b.rows);
        gemm_forced(
            1.0, &a, &b, 0.0, &mut fixed, false, true, tune::fixed_config(),
        );
        assert!(got.max_abs_diff(&fixed) < 1e-3);
        assert_eq!(tune::searches_performed(), 1);
    });
}
