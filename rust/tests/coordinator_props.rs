//! Property-based coordinator/optimizer invariants (no artifacts needed):
//! unbiasedness of the sampled update family, routing/sampling statistics,
//! state-memory monotonicity, and failure injection on malformed inputs.

use gum::linalg::{fro_norm, Matrix};
use gum::model::{BlockKind, ParamBlock, ParamStore};
use gum::optim::{
    self, Compensation, Gum, Optimizer, ProjKind, Projector, StepCtx,
};
use gum::rng::Pcg;
use gum::testing;

fn store_with_blocks(shapes: &[(usize, usize)]) -> ParamStore {
    ParamStore {
        blocks: shapes
            .iter()
            .enumerate()
            .map(|(i, &(m, n))| ParamBlock {
                name: format!("b{i}"),
                shape: vec![m, n],
                kind: BlockKind::Projectable,
                value: Matrix::zeros(m, n),
            })
            .collect(),
    }
}

/// Lemma 2 (Monte-Carlo form): averaging the *sampled* effective
/// gradients over many periods converges to the true gradient.
#[test]
fn prop_monte_carlo_unbiasedness() {
    testing::check(5, |gen| {
        let m = gen.dim(4, 16);
        let n = gen.dim(4, 16);
        let r = gen.dim(1, m.min(n) - 1);
        let q = 0.2 + 0.6 * gen.rng.f64();
        let g = gen.matrix(m, n);
        let proj = Projector::build(&g, r, ProjKind::SvdTopR, &mut gen.rng);
        let trials = 4000;
        let mut mean = Matrix::zeros(m, n);
        for _ in 0..trials {
            let full = gen.rng.bernoulli(q);
            let eff = Gum::effective_gradient(
                &proj,
                &g,
                full,
                q,
                Compensation::Paper,
            );
            // The low-rank branch contributes its *back-projected* form.
            let eff = if full { eff } else { proj.project_back(&proj.project(&g)).scaled((1.0 / (1.0 - q)) as f32) };
            mean.add_scaled_in_place(1.0 / trials as f32, &eff);
        }
        let err = mean.max_abs_diff(&g);
        let scale = fro_norm(&g);
        assert!(
            err < 0.15 * scale.max(1.0),
            "MC mean err {err} (‖G‖ = {scale}, q = {q})"
        );
    });
}

/// Sampling statistics: across many periods, each block is full-rank at
/// rate q, independently.
#[test]
fn prop_sampling_rate_per_block() {
    testing::check(3, |gen| {
        let n_blocks = gen.dim(3, 8);
        let shapes: Vec<(usize, usize)> =
            (0..n_blocks).map(|_| (8, 8)).collect();
        let store = store_with_blocks(&shapes);
        let q = 0.2 + 0.5 * gen.rng.f64();
        let mut gum = Gum::new(&store, 2, q, 0.9, Compensation::Paper, gen.seed);
        let grads: Vec<Matrix> =
            (0..n_blocks).map(|_| gen.matrix(8, 8)).collect();
        let mut counts = vec![0usize; n_blocks];
        let periods = 600;
        let mut rng = Pcg::new(1);
        for _ in 0..periods {
            gum.begin_period(&store, &grads, &mut rng);
            for (c, &f) in counts.iter_mut().zip(&gum.full_rank_mask()) {
                *c += f as usize;
            }
        }
        for (i, c) in counts.iter().enumerate() {
            let rate = *c as f64 / periods as f64;
            assert!(
                (rate - q).abs() < 0.08,
                "block {i}: rate {rate} vs q {q}"
            );
        }
    });
}

/// Memory monotonicity: state bytes increase with rank and with q.
#[test]
fn prop_state_bytes_monotone() {
    let store = store_with_blocks(&[(32, 48), (48, 32), (16, 64)]);
    let mut rng = Pcg::new(0);
    let grads: Vec<Matrix> = store
        .blocks
        .iter()
        .map(|b| Matrix::randn(b.value.rows, b.value.cols, 1.0, &mut rng))
        .collect();
    let measure = |rank: usize, q: f64| {
        let mut gum =
            Gum::new(&store, rank, q, 0.9, Compensation::Paper, 7);
        let mut s = store.clone();
        let mut prng = Pcg::new(2);
        // Average over several periods (sampling changes the footprint).
        let mut total = 0usize;
        for _ in 0..24 {
            gum.begin_period(&s, &grads, &mut prng);
            gum.step(&mut s, &grads, &StepCtx { lr: 1e-3, step: 0 });
            total += gum.state_bytes();
        }
        total / 24
    };
    let small = measure(2, 0.1);
    let big_rank = measure(8, 0.1);
    let big_q = measure(2, 0.9);
    assert!(small < big_rank, "{small} !< {big_rank}");
    assert!(small < big_q, "{small} !< {big_q}");
}

/// All optimizers make progress on a simple separable quadratic over a
/// multi-block store — the family-wide smoke invariant.
#[test]
fn prop_all_optimizers_descend_quadratic() {
    let shapes = [(12usize, 20usize), (20, 12), (16, 16)];
    for name in [
        "sgd", "sgdm", "adam", "adamw", "muon", "galore-muon",
        "galore-adam", "golore-muon", "fira", "gum",
    ] {
        let store = store_with_blocks(&shapes);
        let mut rng = Pcg::new(3);
        let targets: Vec<Matrix> = store
            .blocks
            .iter()
            .map(|b| Matrix::randn(b.value.rows, b.value.cols, 1.0, &mut rng))
            .collect();
        let mut opt = optim::build(name, &store, 4, 1.0, 9).unwrap();
        let mut s = store.clone();
        let loss = |s: &ParamStore| -> f64 {
            s.blocks
                .iter()
                .zip(&targets)
                .map(|(b, t)| fro_norm(&b.value.sub(t)) as f64)
                .sum()
        };
        let l0 = loss(&s);
        let mut prng = Pcg::new(4);
        for step in 0..120 {
            let grads: Vec<Matrix> = s
                .blocks
                .iter()
                .zip(&targets)
                .map(|(b, t)| b.value.sub(t))
                .collect();
            if step % 20 == 0 {
                opt.begin_period(&s, &grads, &mut prng);
            }
            opt.step(&mut s, &grads, &StepCtx { lr: 0.05, step });
        }
        let l1 = loss(&s);
        assert!(l1 < 0.9 * l0, "{name}: {l0} -> {l1}");
    }
}

/// LISA freezes everything not sampled, so with γ = 0 no projectable
/// block may ever move.
#[test]
fn prop_lisa_gamma_zero_freezes_all() {
    let store = store_with_blocks(&[(8, 8), (8, 8)]);
    let mut opt = optim::build("lisa", &store, 4, 0.0, 0).unwrap();
    let mut rng = Pcg::new(0);
    let grads: Vec<Matrix> = store
        .blocks
        .iter()
        .map(|b| {
            let mut g = Matrix::zeros(b.value.rows, b.value.cols);
            g.fill(1.0);
            g
        })
        .collect();
    let mut s = store.clone();
    opt.begin_period(&s, &grads, &mut rng);
    opt.step(&mut s, &grads, &StepCtx { lr: 0.1, step: 0 });
    for (a, b) in s.blocks.iter().zip(&store.blocks) {
        assert_eq!(a.value, b.value);
    }
}

/// Failure injection: mismatched grads length must panic, not corrupt.
#[test]
fn prop_mismatched_grads_panics() {
    let store = store_with_blocks(&[(8, 8), (8, 8)]);
    let mut opt = optim::build("adamw", &store, 4, 1.0, 0).unwrap();
    let grads = vec![Matrix::zeros(8, 8)]; // one short
    let mut s = store.clone();
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        opt.step(&mut s, &grads, &StepCtx { lr: 0.1, step: 0 });
    }));
    assert!(r.is_err());
}
