//! Refresh-strategy equivalence, end-to-end: the randomized/warm-started
//! projector engines must be drop-in replacements for the exact Jacobi
//! reference —
//!
//! 1. GUM on the synthetic quadratic converges to the same final loss
//!    (gap ≤ 1e-3) under every `RefreshStrategy`, and its full-rank
//!    sampling mask sequence is *identical* across strategies (the rsvd
//!    sketch draws come from a derived stream, never the Bernoulli
//!    sampler).
//! 2. GaLore-Muon on the paper's linear-regression task (deterministic
//!    gradients) converges to the same final adjusted loss under exact
//!    vs randomized vs warm-started refreshes.
//! 3. A warm-started GUM run snapshots/restores mid-period and replays
//!    bit-identically — the warm basis and the sketch-stream seed are
//!    resumable state.

use gum::linalg::Matrix;
use gum::model::{BlockKind, ParamBlock, ParamStore};
use gum::optim::{
    BaseOpt, Compensation, GaLore, Gum, Optimizer, ProjKind,
    RefreshStrategy, StepCtx,
};
use gum::rng::Pcg;
use gum::synthetic::{NoisyLinReg, Quadratic};

const STRATEGIES: [RefreshStrategy; 3] = [
    RefreshStrategy::ExactJacobi,
    RefreshStrategy::Randomized {
        oversample: 4,
        power_iters: 2,
    },
    RefreshStrategy::WarmStart,
];

fn single_block_store(m: usize, n: usize) -> ParamStore {
    ParamStore {
        blocks: vec![ParamBlock {
            name: "w".into(),
            shape: vec![m, n],
            kind: BlockKind::Projectable,
            value: Matrix::zeros(m, n),
        }],
    }
}

/// Geometric LR decay so the sign-scale update noise shrinks below the
/// loss-gap tolerance by the end of the run.
fn lr_at(step: usize) -> f32 {
    0.3 * 0.985f32.powi(step as i32)
}

fn run_gum_quadratic(
    refresh: RefreshStrategy,
    steps: usize,
    period_k: usize,
) -> (f64, Vec<Vec<bool>>) {
    let problem = Quadratic::new(24, 32, 0.0, 3);
    let mut store = single_block_store(24, 32);
    let mut gum = Gum::new(&store, 4, 0.3, 0.95, Compensation::Paper, 11);
    gum.rms_scale = false;
    gum.refresh = refresh;
    let mut period_rng = Pcg::new(5);
    let mut grad_rng = Pcg::new(7); // unused: noise_std = 0
    let mut masks = Vec::new();
    for step in 0..steps {
        let g = problem.grad(&store.blocks[0].value, &mut grad_rng);
        if step % period_k == 0 {
            gum.begin_period(
                &store,
                std::slice::from_ref(&g),
                &mut period_rng,
            );
            masks.push(gum.full_rank_mask());
        }
        gum.step(
            &mut store,
            std::slice::from_ref(&g),
            &StepCtx {
                lr: lr_at(step),
                step,
            },
        );
    }
    (problem.loss(&store.blocks[0].value), masks)
}

#[test]
fn gum_quadratic_final_loss_agrees_across_strategies() {
    let (exact_loss, exact_masks) =
        run_gum_quadratic(RefreshStrategy::ExactJacobi, 600, 10);
    assert!(
        exact_loss < 1e-3,
        "exact-Jacobi run must converge (loss {exact_loss})"
    );
    for strat in STRATEGIES {
        let (loss, masks) = run_gum_quadratic(strat, 600, 10);
        assert!(
            (loss - exact_loss).abs() <= 1e-3,
            "{}: final loss {loss} vs exact {exact_loss}",
            strat.label()
        );
        // The full-rank sampling sequence is a function of the sampler
        // seed only — never of the refresh strategy's sketch draws.
        assert_eq!(
            masks,
            exact_masks,
            "{}: full_rank_mask diverged",
            strat.label()
        );
    }
}

fn run_galore_linreg(refresh: RefreshStrategy, steps: usize) -> f64 {
    // n = 16 with rank-6 noise support ⇒ the exact gradient lives in a
    // 10-dimensional column space; rank-10 GaLore captures it fully, so
    // the run converges and the only moving part is the refresh engine.
    let problem = NoisyLinReg::new(16, 6, 0.0, 2);
    let mut store = single_block_store(16, 16);
    let mut opt = GaLore::new(
        &store,
        10,
        BaseOpt::Muon { beta: 0.95 },
        ProjKind::SvdTopR,
    );
    opt.rms_scale = false;
    opt.refresh = refresh;
    let mut period_rng = Pcg::new(9);
    for step in 0..steps {
        let g = problem.grad_exact(&store.blocks[0].value);
        if step % 10 == 0 {
            opt.begin_period(
                &store,
                std::slice::from_ref(&g),
                &mut period_rng,
            );
        }
        opt.step(
            &mut store,
            std::slice::from_ref(&g),
            &StepCtx {
                lr: lr_at(step),
                step,
            },
        );
    }
    problem.adjusted_loss(&store.blocks[0].value)
}

#[test]
fn galore_linreg_final_loss_agrees_across_strategies() {
    let exact = run_galore_linreg(RefreshStrategy::ExactJacobi, 600);
    assert!(exact < 1e-3, "exact-Jacobi run must converge (loss {exact})");
    for strat in STRATEGIES {
        let loss = run_galore_linreg(strat, 600);
        assert!(
            (loss - exact).abs() <= 1e-3,
            "{}: adjusted loss {loss} vs exact {exact}",
            strat.label()
        );
    }
}

/// Mid-period snapshot/restore under `WarmStart`: the restored twin must
/// replay bit-identically through the *next* refresh, which exercises
/// both the restored warm basis and the restored sketch-stream seed.
#[test]
fn warm_start_snapshot_resume_is_bit_identical() {
    let problem = Quadratic::new(16, 24, 0.0, 1);
    let mut store = single_block_store(16, 24);
    let mut gum = Gum::new(&store, 3, 0.4, 0.95, Compensation::Paper, 11);
    gum.rms_scale = false;
    gum.refresh = RefreshStrategy::WarmStart;
    let mut rng = Pcg::new(2);
    let mut throwaway = Pcg::new(0);
    for step in 0..7 {
        let g = problem.grad(&store.blocks[0].value, &mut throwaway);
        if step % 5 == 0 {
            gum.begin_period(&store, std::slice::from_ref(&g), &mut rng);
        }
        gum.step(
            &mut store,
            std::slice::from_ref(&g),
            &StepCtx { lr: 0.05, step },
        );
    }

    let snap = gum.snapshot().expect("gum snapshots");
    // Different construction seed: restore must fully overwrite it,
    // including the sketch-stream seed the warm refreshes draw from.
    let mut twin = Gum::new(&store, 3, 0.4, 0.95, Compensation::Paper, 0);
    twin.rms_scale = false;
    twin.refresh = RefreshStrategy::WarmStart;
    twin.restore_snapshot(&snap).unwrap();

    let mut s1 = store.clone();
    let mut s2 = store.clone();
    let mut other_rng = Pcg::new(1234);
    for step in 7..17 {
        let g1 = problem.grad(&s1.blocks[0].value, &mut throwaway);
        let g2 = problem.grad(&s2.blocks[0].value, &mut throwaway);
        if step % 5 == 0 {
            // Period boundary at step 10/15: both must warm-start from
            // the same (restored) basis with the same derived stream.
            gum.begin_period(&s1, std::slice::from_ref(&g1), &mut rng);
            twin.begin_period(
                &s2,
                std::slice::from_ref(&g2),
                &mut other_rng,
            );
        }
        gum.step(
            &mut s1,
            std::slice::from_ref(&g1),
            &StepCtx { lr: 0.05, step },
        );
        twin.step(
            &mut s2,
            std::slice::from_ref(&g2),
            &StepCtx { lr: 0.05, step },
        );
    }
    assert_eq!(
        s1.blocks[0].value, s2.blocks[0].value,
        "resumed warm-start run diverged"
    );
    assert_eq!(gum.full_rank_mask(), twin.full_rank_mask());
}
