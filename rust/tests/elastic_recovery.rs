//! Elastic determinism under injected faults — the supervision
//! contract, locked in end-to-end (no AOT artifacts needed):
//!
//! 1. **Bit-identical trajectories.** A run with injected lane kills —
//!    at every lane, at refresh-period boundaries ± 1 step — commits
//!    exactly the loss trace and parameters of the fault-free run at
//!    the same seed: fencing, rollback, and rejoin restore the Pcg
//!    streams, sampler state, warm projector basis and loader
//!    positions, so recovery is invisible to the debiased trajectory.
//! 2. **Budgets bound retries.** Exhausting `max_lane_restarts` fails
//!    the run with the event log and the fault-plan spec for replay.
//! 3. **Real bugs are recovered too — and labeled.** A genuine panic in
//!    a gradient lane is fenced and rolled back like an injected one,
//!    but the event log marks it `injected: false`.
//! 4. **Corrupt-tail recovery.** With on-disk snapshots and a planned
//!    checkpoint-write truncation, rollback falls back past the corrupt
//!    snapshot to the last good one and still reproduces the fault-free
//!    trajectory.
//!
//! Every fault-driven test holds a `FaultPlanArtifact` guard: if the
//! test panics, the plan spec lands in `target/fault-plans/` for CI to
//! upload, so a failing seed is replayable from the workflow artifacts.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use gum::coordinator::{
    ElasticConfig, ElasticEventKind, ElasticSession, GradSource, LaneStatus,
    LrSchedule, ParallelConfig, ParallelSession, ShardMode, ShardedBatcher,
    SyntheticGradSource,
};
use gum::data::corpus::CorpusSpec;
use gum::data::loader::Batch;
use gum::data::tokenizer::ByteTokenizer;
use gum::linalg::Matrix;
use gum::model::{BlockKind, ParamBlock, ParamStore};
use gum::optim::{
    self, AdaptiveRankCfg, RankSchedule, RefreshPipelineMode, RefreshStrategy,
};
use gum::rng::Pcg;
use gum::testing::{FaultPlan, FaultPlanArtifact};

const BATCH: usize = 4;
const SEQ: usize = 32;
const PERIOD_K: usize = 5;
const REPLICAS: usize = 4;
const SRC_SEED: u64 = 23;

fn small_store() -> ParamStore {
    let mut rng = Pcg::new(5);
    let blocks = vec![
        ParamBlock {
            name: "w0".into(),
            shape: vec![24, 32],
            kind: BlockKind::Projectable,
            value: Matrix::randn(24, 32, 0.1, &mut rng),
        },
        ParamBlock {
            name: "w1".into(),
            shape: vec![32, 24],
            kind: BlockKind::Projectable,
            value: Matrix::randn(32, 24, 0.1, &mut rng),
        },
        ParamBlock {
            name: "norm".into(),
            shape: vec![16],
            kind: BlockKind::Dense,
            value: Matrix::from_vec(1, 16, vec![1.0; 16]),
        },
    ];
    ParamStore { blocks }
}

fn session(replicas: usize) -> ParallelSession {
    let params = small_store();
    let opt = optim::build("gum", &params, 4, 1.0, 99).unwrap();
    let pcfg = ParallelConfig {
        replicas,
        accum_steps: 1,
        shard_mode: ShardMode::DocPartition,
        doc_stride: 100_000,
    };
    let batcher = ShardedBatcher::new(
        &CorpusSpec::default(),
        &ByteTokenizer::new(256),
        BATCH,
        SEQ,
        &pcfg,
    );
    ParallelSession::new(
        params,
        opt,
        batcher,
        PERIOD_K,
        LrSchedule::constant(0.02),
        17,
    )
}

/// The golden trajectory: an unsupervised fault-free run.
fn baseline(replicas: usize, steps: usize) -> (Vec<f64>, ParamStore) {
    let mut s = session(replicas);
    let mut srcs: Vec<SyntheticGradSource> = (0..replicas)
        .map(|_| SyntheticGradSource::new(&s.params, SRC_SEED))
        .collect();
    let mut losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        losses.push(s.global_step(&mut srcs).unwrap().loss);
    }
    (losses, s.params)
}

fn elastic(
    replicas: usize,
    plan: Arc<FaultPlan>,
    cfg: ElasticConfig,
) -> ElasticSession<SyntheticGradSource> {
    let lane_plan = plan.clone();
    ElasticSession::new(session(replicas), cfg, plan, move |params, lane| {
        SyntheticGradSource::new(params, SRC_SEED)
            .with_faults(lane, lane_plan.clone())
    })
}

fn assert_same_trajectory(
    ctx: &str,
    golden: &(Vec<f64>, ParamStore),
    losses: &[f64],
    params: &ParamStore,
) {
    assert_eq!(
        golden.0, losses,
        "{ctx}: committed loss trace must be bit-identical"
    );
    for (want, got) in golden.1.blocks.iter().zip(&params.blocks) {
        assert_eq!(
            want.value, got.value,
            "{ctx}: block {} diverged",
            want.name
        );
    }
}

#[test]
fn fault_free_supervision_is_invisible() {
    let steps = 2 * PERIOD_K + 2;
    let golden = baseline(REPLICAS, steps);
    let mut sess = elastic(
        REPLICAS,
        Arc::new(FaultPlan::empty()),
        ElasticConfig::default(),
    );
    let losses = sess.run(steps).unwrap();
    assert_same_trajectory("fault-free", &golden, &losses, &sess.inner.params);
    assert_eq!(sess.restarts_used(), 0);
    assert!(sess
        .events()
        .iter()
        .all(|e| matches!(e.kind, ElasticEventKind::SlowLane { .. })));
}

/// The acceptance matrix: kill each lane at each refresh-period
/// boundary ± 1 step; every run must commit the fault-free trajectory
/// bit-for-bit and retire exactly one restart.
#[test]
fn lane_kill_matrix_preserves_bitwise_trajectory() {
    let steps = 2 * PERIOD_K + 2;
    let golden = baseline(REPLICAS, steps);
    let boundary = PERIOD_K as u64;
    for lane in 0..REPLICAS {
        for kill_step in [boundary - 1, boundary, boundary + 1] {
            let plan = Arc::new(
                FaultPlan::parse(&format!("kill:{lane}@{kill_step}")).unwrap(),
            );
            let _artifact = FaultPlanArtifact::new(
                &format!("kill_lane{lane}_step{kill_step}"),
                &plan,
            );
            let mut sess =
                elastic(REPLICAS, plan.clone(), ElasticConfig::default());
            let losses = sess.run(steps).unwrap();
            let ctx = format!("kill:{lane}@{kill_step}");
            assert_same_trajectory(&ctx, &golden, &losses, &sess.inner.params);
            assert_eq!(plan.fired_count(), 1, "{ctx}: fault must fire");
            assert_eq!(sess.restarts_used(), 1, "{ctx}");
            assert!(
                sess.status().iter().all(|s| *s == LaneStatus::Healthy),
                "{ctx}: every lane must have rejoined"
            );
            let events = sess.events();
            assert!(
                events.iter().any(|e| matches!(
                    (&e.kind, e.lane),
                    (
                        ElasticEventKind::LaneFault { injected: true, .. },
                        Some(l)
                    ) if l == lane
                )),
                "{ctx}: injected fault must be logged for the lane"
            );
            assert!(
                events
                    .iter()
                    .any(|e| matches!(e.kind, ElasticEventKind::Fence)),
                "{ctx}: fence event"
            );
            assert!(
                events.iter().any(|e| matches!(
                    e.kind,
                    ElasticEventKind::Rollback { .. }
                )),
                "{ctx}: rollback event"
            );
            assert!(
                events
                    .iter()
                    .any(|e| matches!(e.kind, ElasticEventKind::Rejoin)),
                "{ctx}: rejoin event"
            );
        }
    }
}

#[test]
fn seeded_random_kills_also_preserve_the_trajectory() {
    let steps = 2 * PERIOD_K + 2;
    let golden = baseline(REPLICAS, steps);
    let plan = Arc::new(FaultPlan::seeded(41, REPLICAS, steps as u64, 2));
    let _artifact = FaultPlanArtifact::new("seeded_41", &plan);
    let mut sess = elastic(
        REPLICAS,
        plan.clone(),
        ElasticConfig {
            max_lane_restarts: 8,
            ..ElasticConfig::default()
        },
    );
    let losses = sess.run(steps).unwrap();
    let ctx = format!("seeded plan '{}'", plan.spec());
    assert_same_trajectory(&ctx, &golden, &losses, &sess.inner.params);
    assert_eq!(plan.fired_count(), 2, "{ctx}");
}

#[test]
fn restart_budget_exhaustion_fails_with_event_log() {
    let plan =
        Arc::new(FaultPlan::parse("kill:0@2,kill:0@4,kill:0@6").unwrap());
    let mut sess = elastic(
        2,
        plan,
        ElasticConfig {
            max_lane_restarts: 2,
            ..ElasticConfig::default()
        },
    );
    let mut failure = None;
    for _ in 0..12 {
        if let Err(e) = sess.global_step() {
            failure = Some(e);
            break;
        }
    }
    let err = format!("{:#}", failure.expect("third kill must exhaust"));
    assert!(err.contains("budget exhausted"), "{err}");
    assert!(err.contains("kill:0@6"), "spec must be replayable: {err}");
    assert_eq!(sess.restarts_used(), 2);
    assert!(sess
        .events()
        .iter()
        .any(|e| matches!(e.kind, ElasticEventKind::BudgetExhausted)));
}

/// A gradient source with a genuine one-shot bug (a bare panic, no
/// typed payload). Supervision recovers it like an injected fault but
/// the event log marks it as real.
struct FlakySource {
    inner: SyntheticGradSource,
    lane: usize,
    step: u64,
    bombed: Arc<AtomicBool>,
}

impl GradSource for FlakySource {
    fn grad(
        &mut self,
        params: &ParamStore,
        batch: &Batch,
    ) -> anyhow::Result<(f32, Vec<Matrix>)> {
        if self.lane == 1
            && self.step == 3
            && !self.bombed.swap(true, Ordering::SeqCst)
        {
            panic!("real bug in lane 1");
        }
        self.inner.grad(params, batch)
    }

    fn begin_step(&mut self, step: u64) {
        self.step = step;
        self.inner.begin_step(step);
    }
}

#[test]
fn real_panics_recover_but_are_not_labeled_injected() {
    let steps = PERIOD_K + 3;
    let golden = baseline(REPLICAS, steps);
    let bombed = Arc::new(AtomicBool::new(false));
    let factory_bombed = bombed.clone();
    let mut sess = ElasticSession::new(
        session(REPLICAS),
        ElasticConfig::default(),
        Arc::new(FaultPlan::empty()),
        move |params, lane| FlakySource {
            inner: SyntheticGradSource::new(params, SRC_SEED),
            lane,
            step: 0,
            bombed: factory_bombed.clone(),
        },
    );
    let losses = sess.run(steps).unwrap();
    assert_same_trajectory("real panic", &golden, &losses, &sess.inner.params);
    assert!(bombed.load(Ordering::SeqCst), "the bug must have fired");
    assert_eq!(sess.restarts_used(), 1);
    let fault = sess
        .events()
        .iter()
        .find_map(|e| match &e.kind {
            ElasticEventKind::LaneFault { injected, message } => {
                Some((*injected, message.clone()))
            }
            _ => None,
        })
        .expect("fault event");
    assert!(!fault.0, "a bare panic is a real bug, not an injected fault");
    assert!(fault.1.contains("real bug"), "{}", fault.1);
}

#[test]
fn corrupt_snapshot_tail_recovers_to_previous_and_stays_bitwise() {
    let steps = 2 * PERIOD_K + 3;
    let golden = baseline(REPLICAS, steps);
    let dir = std::env::temp_dir().join("gum_elastic_disk_test");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    // Period-boundary snapshots land at steps 0, 5, 10 (saves #0/#1/#2);
    // the plan tears save #2 (step 10), then kills lane 1 at step 12 —
    // recovery must skip the corrupt step-10 snapshot, roll back to
    // step 5, and replay to a bit-identical trajectory.
    let plan = Arc::new(FaultPlan::parse("trunc:2@64,kill:1@12").unwrap());
    let _artifact = FaultPlanArtifact::new("disk_trunc_then_kill", &plan);
    let mut sess = elastic(
        REPLICAS,
        plan.clone(),
        ElasticConfig {
            snapshot_dir: Some(dir.clone()),
            ..ElasticConfig::default()
        },
    );
    let losses = sess.run(steps).unwrap();
    assert_same_trajectory(
        "disk truncation",
        &golden,
        &losses,
        &sess.inner.params,
    );
    assert_eq!(plan.fired_count(), 2);
    let events = sess.events();
    assert!(
        events
            .iter()
            .any(|e| matches!(e.kind, ElasticEventKind::SnapshotCorrupt { .. })),
        "corrupt snapshot must be logged"
    );
    assert!(
        events.iter().any(|e| matches!(
            e.kind,
            ElasticEventKind::Rollback {
                to_step: 5,
                from_disk: true
            }
        )),
        "rollback must land on the previous good snapshot"
    );
}

#[test]
fn slow_lane_stall_is_flagged_and_harmless() {
    let steps = PERIOD_K + 2;
    let golden = baseline(REPLICAS, steps);
    let plan = Arc::new(FaultPlan::parse("stall:0@3:100").unwrap());
    let _artifact = FaultPlanArtifact::new("stall_lane0", &plan);
    let mut sess = elastic(REPLICAS, plan, ElasticConfig::default());
    let losses = sess.run(steps).unwrap();
    assert_same_trajectory("stall", &golden, &losses, &sess.inner.params);
    assert_eq!(sess.restarts_used(), 0, "a straggler is not a failure");
    assert!(
        sess.events().iter().any(|e| matches!(
            (&e.kind, e.lane),
            (ElasticEventKind::SlowLane { .. }, Some(0))
        )),
        "the 100ms straggler must be flagged"
    );
}

/// The metrics-log rollback boundary, driven exactly like the trainer's
/// open-coded loop: one row set per committed step, and a rollback
/// rewinds the log with `retain_before(state.step)` before replaying.
/// A row logged *at* the rollback-target step must not survive the
/// rewind — the replay re-logs it — so after recovery the log holds no
/// duplicate (step, key) pairs and exactly one row per step, and the
/// replayed values are bit-identical to the fault-free run's.
#[test]
fn rollback_rewinds_metrics_without_duplicate_rows() {
    use gum::coordinator::MetricsLog;
    use std::collections::HashSet;

    let steps = 2 * PERIOD_K + 2;
    let golden = baseline(REPLICAS, steps);
    let snap_step = 3usize;
    let fail_step = PERIOD_K + 1; // the attempt that "fails"
    let mut s = session(REPLICAS);
    let mut srcs: Vec<SyntheticGradSource> = (0..REPLICAS)
        .map(|_| SyntheticGradSource::new(&s.params, SRC_SEED))
        .collect();
    let mut metrics = MetricsLog::new();
    let mut snapshot = None;
    let mut rolled = false;
    let mut step = 0usize;
    while step < steps {
        if step == snap_step && snapshot.is_none() {
            snapshot = Some(s.train_state());
        }
        if step == fail_step && !rolled {
            // A lane failure at `step`: nothing for this step was
            // logged yet, but rows for snap_step..fail_step exist —
            // including rows *at* snap_step, which the replay re-logs.
            rolled = true;
            let state = snapshot.as_ref().unwrap();
            s.restore_train_state(state).unwrap();
            metrics.retain_before(state.step as usize);
            step = state.step as usize;
            continue;
        }
        let global = s.global_step(&mut srcs).unwrap();
        metrics.push(step, "train_loss", global.loss);
        metrics.push(
            step,
            "reduce_bytes",
            s.last_reduce.map_or(0.0, |r| r.payload_bytes as f64),
        );
        step += 1;
    }
    assert!(rolled, "the rollback path must have been exercised");
    let mut seen = HashSet::new();
    for r in &metrics.rows {
        assert!(
            seen.insert((r.step, r.key.clone())),
            "duplicate metric row ({}, {})",
            r.step,
            r.key
        );
    }
    let series = metrics.series("train_loss");
    assert_eq!(series.len(), steps, "one loss row per committed step");
    for (i, ((got_step, got), want)) in
        series.iter().zip(&golden.0).enumerate()
    {
        assert_eq!(*got_step, i);
        assert_eq!(got, want, "step {i}: replayed loss diverged");
    }
}

// ---------------------------------------------------------------------
// Fault injection × adaptive rank schedule: kills landing while the
// controller is mid-decision must roll back the rank bookkeeping too.
// ---------------------------------------------------------------------

fn adaptive_session(
    replicas: usize,
    mode: RefreshPipelineMode,
) -> ParallelSession {
    let params = small_store();
    let schedule = RankSchedule::Adaptive(AdaptiveRankCfg {
        energy: 0.90,
        deadband: 1,
        patience: 2,
        min_rank: 1,
        max_rank: 8,
        budget: 12,
    });
    let opt = optim::build_with_schedule(
        "gum",
        &params,
        4,
        1.0,
        99,
        RefreshStrategy::default(),
        &schedule,
    )
    .unwrap();
    let pcfg = ParallelConfig {
        replicas,
        accum_steps: 1,
        shard_mode: ShardMode::DocPartition,
        doc_stride: 100_000,
    };
    let batcher = ShardedBatcher::new(
        &CorpusSpec::default(),
        &ByteTokenizer::new(256),
        BATCH,
        SEQ,
        &pcfg,
    );
    let mut s = ParallelSession::new(
        params,
        opt,
        batcher,
        PERIOD_K,
        LrSchedule::constant(0.02),
        17,
    );
    s.set_refresh_mode(mode);
    s
}

/// Kill matrix at the rank-change boundaries: with patience 2 the
/// controller commits its first rank move at boundary K, and boundary
/// 2K's refresh is the first planned at the *new* ranks. Kills at the
/// trigger (boundary − 1), the boundary, and boundary + 1 — around both
/// boundaries, under both pipeline modes — must replay to the
/// fault-free adaptive trajectory bit-for-bit, including every
/// committed rank decision.
#[test]
fn adaptive_rank_change_kill_matrix_stays_bitwise() {
    let steps = 3 * PERIOD_K + 2;
    for mode in [RefreshPipelineMode::Sync, RefreshPipelineMode::Async] {
        let (golden, golden_ranks) = {
            let mut s = adaptive_session(REPLICAS, mode);
            let mut srcs: Vec<SyntheticGradSource> = (0..REPLICAS)
                .map(|_| SyntheticGradSource::new(&s.params, SRC_SEED))
                .collect();
            let mut losses = Vec::with_capacity(steps);
            for _ in 0..steps {
                losses.push(s.global_step(&mut srcs).unwrap().loss);
            }
            let ranks = s.opt.rank_state().expect("adaptive run");
            ((losses, s.params), ranks)
        };
        assert_ne!(
            golden_ranks.ranks,
            vec![4u32, 4, 0],
            "{}: the golden run must actually cross a rank change",
            mode.label()
        );
        let commit = PERIOD_K as u64; // first committed rank move
        let replan = 2 * PERIOD_K as u64; // first refresh at the new ranks
        for boundary in [commit, replan] {
            for kill_step in [boundary - 1, boundary, boundary + 1] {
                let plan = Arc::new(
                    FaultPlan::parse(&format!("kill:1@{kill_step}")).unwrap(),
                );
                let _artifact = FaultPlanArtifact::new(
                    &format!(
                        "rank_adaptive_{}_kill_step{kill_step}",
                        mode.label()
                    ),
                    &plan,
                );
                let lane_plan = plan.clone();
                let mut sess = ElasticSession::new(
                    adaptive_session(REPLICAS, mode),
                    ElasticConfig::default(),
                    plan.clone(),
                    move |params, lane| {
                        SyntheticGradSource::new(params, SRC_SEED)
                            .with_faults(lane, lane_plan.clone())
                    },
                );
                let losses = sess.run(steps).unwrap();
                let ctx =
                    format!("{} adaptive kill:1@{kill_step}", mode.label());
                assert_eq!(plan.fired_count(), 1, "{ctx}: fault must fire");
                assert_same_trajectory(
                    &ctx,
                    &golden,
                    &losses,
                    &sess.inner.params,
                );
                assert_eq!(
                    sess.inner.opt.rank_state().as_ref(),
                    Some(&golden_ranks),
                    "{ctx}: committed rank decisions diverged"
                );
            }
        }
    }
}
