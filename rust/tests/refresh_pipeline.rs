//! Refresh-pipeline determinism, end-to-end: moving the projector
//! refresh off the critical path must be **invisible to the committed
//! trajectory**.
//!
//! 1. **Sync ≡ async.** A session with the refresh overlapped on the
//!    worker pool commits bit-identical losses and parameters to the
//!    same session with the refresh inline at the boundary — for GUM
//!    (own derived sketch streams) and GaLore/Fira (pipeline-derived
//!    stream), across several periods.
//! 2. **Mid-period resume across the trigger.** A `GUMCKPT3` snapshot
//!    taken while a refresh job is armed/in flight serializes the
//!    resolved bases; a session restored from the file replays the
//!    uninterrupted run bit-for-bit through the handoff it never
//!    computed itself.
//! 3. **Kill/rollback under `FaultPlan`.** Lane kills at the refresh
//!    trigger step, the boundary, and boundary ± 1 roll back, discard
//!    the in-flight bases, and still commit the fault-free trajectory
//!    bitwise — under both pipeline modes.

use std::sync::Arc;

use gum::coordinator::{
    load_train_state, save_train_state, ElasticConfig, ElasticSession,
    LrSchedule, ParallelConfig, ParallelSession, ShardMode, ShardedBatcher,
    SyntheticGradSource,
};
use gum::data::corpus::CorpusSpec;
use gum::data::tokenizer::ByteTokenizer;
use gum::linalg::Matrix;
use gum::model::{BlockKind, ParamBlock, ParamStore};
use gum::optim::{self, RefreshPipelineMode};
use gum::rng::Pcg;
use gum::testing::{FaultPlan, FaultPlanArtifact};

const BATCH: usize = 4;
const SEQ: usize = 32;
const PERIOD_K: usize = 5;
const REPLICAS: usize = 2;
const SRC_SEED: u64 = 23;

fn small_store() -> ParamStore {
    let mut rng = Pcg::new(5);
    let blocks = vec![
        ParamBlock {
            name: "w0".into(),
            shape: vec![24, 32],
            kind: BlockKind::Projectable,
            value: Matrix::randn(24, 32, 0.1, &mut rng),
        },
        ParamBlock {
            name: "w1".into(),
            shape: vec![32, 24],
            kind: BlockKind::Projectable,
            value: Matrix::randn(32, 24, 0.1, &mut rng),
        },
        ParamBlock {
            name: "norm".into(),
            shape: vec![16],
            kind: BlockKind::Dense,
            value: Matrix::from_vec(1, 16, vec![1.0; 16]),
        },
    ];
    ParamStore { blocks }
}

fn session(
    optimizer: &str,
    replicas: usize,
    mode: RefreshPipelineMode,
) -> ParallelSession {
    let params = small_store();
    let opt = optim::build(optimizer, &params, 4, 1.0, 99).unwrap();
    let pcfg = ParallelConfig {
        replicas,
        accum_steps: 1,
        shard_mode: ShardMode::DocPartition,
        doc_stride: 100_000,
    };
    let batcher = ShardedBatcher::new(
        &CorpusSpec::default(),
        &ByteTokenizer::new(256),
        BATCH,
        SEQ,
        &pcfg,
    );
    let mut s = ParallelSession::new(
        params,
        opt,
        batcher,
        PERIOD_K,
        LrSchedule::constant(0.02),
        17,
    );
    s.set_refresh_mode(mode);
    s
}

fn sources(s: &ParallelSession, n: usize) -> Vec<SyntheticGradSource> {
    vec![SyntheticGradSource::new(&s.params, SRC_SEED); n]
}

fn run_trace(
    optimizer: &str,
    mode: RefreshPipelineMode,
    steps: usize,
) -> (Vec<f64>, ParamStore) {
    let mut s = session(optimizer, REPLICAS, mode);
    let mut srcs = sources(&s, REPLICAS);
    let mut losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        losses.push(s.global_step(&mut srcs).unwrap().loss);
    }
    (losses, s.params)
}

/// Pillar 1: the async-refresh trajectory is bit-identical to the
/// sync-refresh spec trace, for every projected optimizer family.
#[test]
fn async_refresh_matches_sync_spec_trace_bitwise() {
    let steps = 3 * PERIOD_K + 2; // three overlapped handoffs
    for optimizer in ["gum", "galore-muon", "galore-adam", "fira"] {
        let (sync_losses, sync_params) =
            run_trace(optimizer, RefreshPipelineMode::Sync, steps);
        let (async_losses, async_params) =
            run_trace(optimizer, RefreshPipelineMode::Async, steps);
        assert_eq!(
            sync_losses, async_losses,
            "{optimizer}: loss trace diverged between sync and async"
        );
        for (a, b) in sync_params.blocks.iter().zip(&async_params.blocks) {
            assert_eq!(
                a.value, b.value,
                "{optimizer}: block {} diverged",
                a.name
            );
        }
    }
}

/// Non-projected optimizers are untouched by the pipeline: both modes
/// equal each other (the pipeline stays idle throughout).
#[test]
fn non_projected_optimizers_unaffected_by_mode() {
    let steps = PERIOD_K + 2;
    let (a, pa) = run_trace("adamw", RefreshPipelineMode::Sync, steps);
    let (b, pb) = run_trace("adamw", RefreshPipelineMode::Async, steps);
    assert_eq!(a, b);
    for (x, y) in pa.blocks.iter().zip(&pb.blocks) {
        assert_eq!(x.value, y.value);
    }
}

/// Pillar 2: snapshot exactly at the point where a refresh job is in
/// flight (after the trigger step, before the boundary), round-trip it
/// through a `GUMCKPT3` file, and replay — the restored session consumes
/// the serialized bases at the handoff and stays bitwise on the
/// uninterrupted trajectory.
#[test]
fn resume_across_inflight_refresh_is_bit_identical() {
    for mode in [RefreshPipelineMode::Sync, RefreshPipelineMode::Async] {
        let mut a = session("gum", REPLICAS, mode);
        let mut sa = sources(&a, REPLICAS);
        // Steps 0..=PERIOD_K-1: the trigger for boundary PERIOD_K fires
        // at step PERIOD_K-1, so after PERIOD_K steps the pipeline holds
        // the next period's bases and the boundary has NOT run yet.
        for _ in 0..PERIOD_K {
            a.global_step(&mut sa).unwrap();
        }
        assert_eq!(a.step, PERIOD_K);
        let state = a.train_state();
        assert!(
            state.pending_refresh.is_some(),
            "{}: snapshot between trigger and boundary must carry the \
             resolved refresh",
            mode.label()
        );
        assert_eq!(
            state.pending_refresh.as_ref().unwrap().boundary,
            PERIOD_K as u64
        );

        let path = std::env::temp_dir()
            .join(format!("gum_refresh_resume_{}.bin", mode.label()));
        save_train_state(&state, &path).unwrap();
        let loaded = load_train_state(&path).unwrap();
        assert_eq!(loaded.pending_refresh, state.pending_refresh);

        let mut b = session("gum", REPLICAS, mode);
        let mut sb = sources(&b, REPLICAS);
        b.restore_train_state(&loaded).unwrap();

        let mut la = Vec::new();
        let mut lb = Vec::new();
        for _ in 0..PERIOD_K + 3 {
            la.push(a.global_step(&mut sa).unwrap().loss);
            lb.push(b.global_step(&mut sb).unwrap().loss);
        }
        assert_eq!(la, lb, "{}: resumed trace diverged", mode.label());
        for (x, y) in a.params.blocks.iter().zip(&b.params.blocks) {
            assert_eq!(x.value, y.value, "{}: {}", mode.label(), x.name);
        }
    }
}

/// A snapshot taken when no refresh is pending (mid-period, before the
/// trigger) carries no REFRESH payload and still resumes bitwise.
#[test]
fn resume_with_idle_pipeline_carries_no_refresh_state() {
    let mut a = session("gum", REPLICAS, RefreshPipelineMode::Async);
    let mut sa = sources(&a, REPLICAS);
    for _ in 0..PERIOD_K + 2 {
        a.global_step(&mut sa).unwrap();
    }
    // Step PERIOD_K+2 is mid-period, two steps before the next trigger.
    let state = a.train_state();
    assert!(state.pending_refresh.is_none());

    let mut b = session("gum", REPLICAS, RefreshPipelineMode::Async);
    let mut sb = sources(&b, REPLICAS);
    b.restore_train_state(&state).unwrap();
    for _ in 0..PERIOD_K {
        let la = a.global_step(&mut sa).unwrap().loss;
        let lb = b.global_step(&mut sb).unwrap().loss;
        assert_eq!(la, lb);
    }
}

/// Pillar 3: lane kills around the refresh window — at the trigger
/// step, the boundary, and boundary + 1 — under supervision. Rollback
/// discards the in-flight bases; the replayed trigger re-derives them;
/// the committed trajectory equals the fault-free run bit-for-bit in
/// both pipeline modes.
#[test]
fn lane_kills_around_refresh_window_stay_bitwise() {
    let steps = 2 * PERIOD_K + 2;
    for mode in [RefreshPipelineMode::Sync, RefreshPipelineMode::Async] {
        let golden = {
            let mut s = session("gum", REPLICAS, mode);
            let mut srcs = sources(&s, REPLICAS);
            let mut losses = Vec::with_capacity(steps);
            for _ in 0..steps {
                losses.push(s.global_step(&mut srcs).unwrap().loss);
            }
            (losses, s.params)
        };
        let boundary = PERIOD_K as u64;
        // boundary − 1 is the trigger step: the kill lands exactly while
        // the next period's bases are in flight.
        for kill_step in [boundary - 1, boundary, boundary + 1] {
            let plan = Arc::new(
                FaultPlan::parse(&format!("kill:1@{kill_step}")).unwrap(),
            );
            let _artifact = FaultPlanArtifact::new(
                &format!(
                    "refresh_{}_kill_step{kill_step}",
                    mode.label()
                ),
                &plan,
            );
            let lane_plan = plan.clone();
            let mut sess = ElasticSession::new(
                session("gum", REPLICAS, mode),
                ElasticConfig::default(),
                plan.clone(),
                move |params, lane| {
                    SyntheticGradSource::new(params, SRC_SEED)
                        .with_faults(lane, lane_plan.clone())
                },
            );
            let losses = sess.run(steps).unwrap();
            let ctx = format!("{} kill:1@{kill_step}", mode.label());
            assert_eq!(plan.fired_count(), 1, "{ctx}: fault must fire");
            assert_eq!(
                golden.0, losses,
                "{ctx}: committed loss trace diverged"
            );
            for (want, got) in
                golden.1.blocks.iter().zip(&sess.inner.params.blocks)
            {
                assert_eq!(
                    want.value, got.value,
                    "{ctx}: block {} diverged",
                    want.name
                );
            }
        }
    }
}

/// Sessions under the default (async) pipeline remain bit-identical
/// across worker-pool widths: the handoff consumes the same bases no
/// matter how many threads raced to compute them.
#[test]
fn async_session_bit_identical_across_thread_widths() {
    // The same lock discipline as parallel_equivalence.rs: width flips
    // are process-global. A dedicated lock here is fine — the suites
    // run in separate test binaries.
    static WIDTH_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    let _w = WIDTH_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let steps = 2 * PERIOD_K + 1;
    let run = |width: usize| {
        let orig = gum::thread::num_threads();
        gum::thread::set_num_threads(width);
        let out = run_trace("gum", RefreshPipelineMode::Async, steps);
        gum::thread::set_num_threads(orig);
        out
    };
    let (l1, p1) = run(1);
    for width in [2usize, 8] {
        let (l, p) = run(width);
        assert_eq!(l1, l, "width {width} changed the loss trace");
        for (a, b) in p1.blocks.iter().zip(&p.blocks) {
            assert_eq!(a.value, b.value, "width {width}: {}", a.name);
        }
    }
}
