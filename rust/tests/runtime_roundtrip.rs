//! Cross-layer integration: AOT artifacts (L1/L2) executed through the
//! PJRT runtime (L3) must agree with the native Rust implementations and
//! with basic calculus (finite differences). Requires `make artifacts`.

use std::path::{Path, PathBuf};

use gum::linalg::{newton_schulz, Matrix};
use gum::model::{init_param_store, registry};
use gum::rng::Pcg;
use gum::runtime::{Executor, HloKernels, ModelRunner};

/// AOT artifacts directory, or `None` when they have not been built —
/// each test then skips (tier-1 `cargo test` must pass on a fresh clone;
/// run `make artifacts` to enable the cross-layer suite).
fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts missing — run `make artifacts`");
        None
    }
}

#[test]
fn manifest_loads_and_entries_compile() {
    let Some(dir) = artifacts() else { return };
    let mut exec = Executor::new(&dir).unwrap();
    assert!(exec.manifest.entries.len() >= 10);
    // Compile a couple of small entries eagerly.
    let names: Vec<String> = exec
        .manifest
        .entries
        .iter()
        .filter(|e| e.kind == "newton_schulz")
        .map(|e| e.name.clone())
        .take(2)
        .collect();
    for n in names {
        exec.compile(&n).unwrap();
    }
}

#[test]
fn l1_newton_schulz_matches_native() {
    let Some(dir) = artifacts() else { return };
    let mut exec = Executor::new(&dir).unwrap();
    let shapes: Vec<(usize, usize)> = exec
        .manifest
        .entries
        .iter()
        .filter(|e| e.kind == "newton_schulz")
        .map(|e| (e.inputs[0].shape[0], e.inputs[0].shape[1]))
        .collect();
    assert!(!shapes.is_empty());
    let mut rng = Pcg::new(7);
    for (m, n) in shapes {
        let g = Matrix::randn(m, n, 1.0, &mut rng);
        let hlo = HloKernels::newton_schulz(&mut exec, &g).unwrap();
        let native = newton_schulz(&g, 5);
        let err = hlo.max_abs_diff(&native);
        assert!(err < 1e-3, "NS {m}x{n}: err {err}");
    }
}

#[test]
fn l1_projection_kernels_match_native() {
    let Some(dir) = artifacts() else { return };
    let mut exec = Executor::new(&dir).unwrap();
    let entries: Vec<(String, usize, usize, usize)> = exec
        .manifest
        .entries
        .iter()
        // `project_back_*` shares the "project" kind prefix; its inputs
        // are (p, r) not (p, g), so exclude it here.
        .filter(|e| e.kind == "project" && !e.name.starts_with("project_back"))
        .map(|e| {
            let g = &e.inputs[1];
            let p = &e.inputs[0];
            (e.name.clone(), g.shape[0], g.shape[1], p.shape[1])
        })
        .collect();
    assert!(!entries.is_empty());
    let mut rng = Pcg::new(8);
    for (_, m, n, r) in entries {
        let g = Matrix::randn(m, n, 1.0, &mut rng);
        let p = gum::linalg::random_orthonormal(m, r, &mut rng);
        // project
        let hlo = HloKernels::project(&mut exec, &p, &g).unwrap();
        let native = gum::linalg::matmul_tn(&p, &g);
        assert!(hlo.max_abs_diff(&native) < 1e-4, "project {m}x{n}r{r}");
        // debias: scale·(G − PPᵀG)
        let scale = 2.5f32;
        let hlo = HloKernels::debias(&mut exec, &p, &g, scale).unwrap();
        let rec = gum::linalg::matmul(&p, &native);
        let mut want = g.clone();
        want.add_scaled_in_place(-1.0, &rec);
        want.scale_in_place(scale);
        assert!(hlo.max_abs_diff(&want) < 1e-3, "debias {m}x{n}r{r}");
    }
}

#[test]
fn l2_gradients_match_finite_differences() {
    // The HLO-side autodiff must agree with numeric differentiation of
    // the HLO-side loss — the strongest cross-layer correctness check.
    let Some(dir) = artifacts() else { return };
    let mut exec = Executor::new(&dir).unwrap();
    let cfg = registry::get("micro").unwrap();
    let runner = ModelRunner::new(&exec, &cfg).unwrap();
    let mut params = init_param_store(&cfg, 3);
    let n = cfg.batch * cfg.seq_len;
    let mut rng = Pcg::new(4);
    let tokens: Vec<i32> =
        (0..n).map(|_| rng.below(cfg.vocab) as i32).collect();
    let targets: Vec<i32> =
        (0..n).map(|_| rng.below(cfg.vocab) as i32).collect();

    let out = runner
        .grad_step(&mut exec, &params, &tokens, &targets)
        .unwrap();
    assert!(out.loss.is_finite());

    // Spot-check coordinates in three different blocks.
    let eps = 1e-2f32;
    for (bi, idx) in [(1usize, 5usize), (2, 123), (20, 999)] {
        let idx = idx % params.blocks[bi].value.data.len();
        let orig = params.blocks[bi].value.data[idx];
        params.blocks[bi].value.data[idx] = orig + eps;
        let (lp, _) = runner
            .eval(&mut exec, &params, &tokens, &targets)
            .unwrap();
        params.blocks[bi].value.data[idx] = orig - eps;
        let (lm, _) = runner
            .eval(&mut exec, &params, &tokens, &targets)
            .unwrap();
        params.blocks[bi].value.data[idx] = orig;
        let fd = (lp - lm) / (2.0 * eps);
        let an = out.grads[bi].data[idx];
        assert!(
            (fd - an).abs() < 2e-2 + 0.15 * an.abs().max(fd.abs()),
            "block {bi} idx {idx}: analytic {an} vs fd {fd}"
        );
    }
}

#[test]
fn l2_eval_per_example_nll_consistent_with_loss() {
    let Some(dir) = artifacts() else { return };
    let mut exec = Executor::new(&dir).unwrap();
    let cfg = registry::get("micro").unwrap();
    let runner = ModelRunner::new(&exec, &cfg).unwrap();
    let params = init_param_store(&cfg, 0);
    let n = cfg.batch * cfg.seq_len;
    let tokens: Vec<i32> = (0..n).map(|i| (i % 250 + 4) as i32).collect();
    let (loss, nll) = runner
        .eval(&mut exec, &params, &tokens, &tokens)
        .unwrap();
    assert_eq!(nll.len(), cfg.batch);
    // All positions unmasked + equal counts ⇒ mean of per-example NLLs
    // equals the scalar loss.
    let mean = nll.iter().sum::<f32>() / nll.len() as f32;
    assert!((mean - loss).abs() < 1e-4, "{mean} vs {loss}");
}

#[test]
fn greedy_decode_shapes_and_determinism() {
    let Some(dir) = artifacts() else { return };
    let mut exec = Executor::new(&dir).unwrap();
    let cfg = registry::get("micro").unwrap();
    let runner = ModelRunner::new(&exec, &cfg).unwrap();
    let params = init_param_store(&cfg, 0);
    let prompts = vec![vec![1, 10, 11, 3], vec![1, 12, 3]];
    let a = runner
        .greedy_decode(&mut exec, &params, &prompts, 6)
        .unwrap();
    let b = runner
        .greedy_decode(&mut exec, &params, &prompts, 6)
        .unwrap();
    assert_eq!(a.len(), 2);
    assert!(a[0].len() <= 6);
    assert_eq!(a, b, "greedy decode must be deterministic");
}

#[test]
fn abi_mismatch_detected() {
    // A config whose artifacts were never lowered must fail cleanly.
    let Some(dir) = artifacts() else { return };
    let exec = Executor::new(&dir).unwrap();
    let missing = registry::get("llama-350m").unwrap();
    match ModelRunner::new(&exec, &missing) {
        Ok(_) => panic!("missing artifacts must error"),
        Err(err) => {
            let msg = format!("{err:#}");
            assert!(msg.contains("not in manifest"), "{msg}");
        }
    }
}

#[test]
fn hlo_files_are_text_not_proto() {
    // Guardrail for the interchange-format gotcha: artifacts must be
    // parseable HLO *text* (jax-serialized protos are rejected by
    // xla_extension 0.5.1).
    let Some(dir) = artifacts() else { return };
    let sample = std::fs::read_to_string(
        Path::new(&dir).join("model_fwd_micro.hlo.txt"),
    )
    .unwrap();
    assert!(sample.starts_with("HloModule"), "not HLO text");
    assert!(sample.contains("ENTRY"));
}
