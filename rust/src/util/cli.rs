//! Minimal CLI argument parser (no `clap` in the offline registry).
//!
//! Grammar: `prog <subcommand> [positional…] [--key value | --flag]…`.
//! Values never start with `--`; `--key=value` is also accepted.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Typed option with default; exits with a readable message on a
    /// malformed value (CLI surface, not library surface).
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.get(name) {
            None => default,
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("error: --{name} expects a {}", std::any::type_name::<T>());
                std::process::exit(2);
            }),
        }
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn mixed_grammar() {
        let a = parse("train --steps 100 --fast --lr=0.02 cfg.json");
        assert_eq!(a.subcommand(), Some("train"));
        assert_eq!(a.positional, vec!["train", "cfg.json"]);
        assert_eq!(a.get("steps"), Some("100"));
        assert_eq!(a.get("lr"), Some("0.02"));
        assert!(a.has_flag("fast"));
        assert!(!a.has_flag("slow"));
    }

    #[test]
    fn typed_access() {
        let a = parse("x --steps 100");
        assert_eq!(a.get_parse("steps", 5usize), 100);
        assert_eq!(a.get_parse("missing", 5usize), 5);
        assert_eq!(a.get_parse("missing", 0.5f64), 0.5);
    }

    #[test]
    fn trailing_option_becomes_flag() {
        let a = parse("x --verbose");
        assert!(a.has_flag("verbose"));
    }
}
