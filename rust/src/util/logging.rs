//! Tiny leveled logger writing to stderr with wall-clock offsets.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

// std-only lazy init (the offline registry has no once_cell).
static START: OnceLock<Instant> = OnceLock::new();
static LEVEL: AtomicU8 = AtomicU8::new(2); // 0=off 1=warn 2=info 3=debug

/// Set the global log level (0=off, 1=warn, 2=info, 3=debug).
pub fn set_level(level: u8) {
    LEVEL.store(level, Ordering::Relaxed);
}

pub fn level() -> u8 {
    LEVEL.load(Ordering::Relaxed)
}

pub fn log(lvl: u8, tag: &str, msg: &str) {
    if lvl <= level() {
        let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
        eprintln!("[{t:9.3}s {tag}] {msg}");
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::logging::log(2, "info", &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log(1, "warn", &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log(3, "debug", &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gates() {
        set_level(1);
        assert_eq!(level(), 1);
        set_level(2);
        assert_eq!(level(), 2);
    }
}
