//! Minimal JSON substrate (parser + writer).
//!
//! The offline registry has no `serde`/`serde_json`, so the artifact
//! manifest (`artifacts/manifest.json`), experiment configs, and metric
//! dumps go through this module. Supports the full JSON grammar; numbers
//! are kept as `f64` plus an integer fast path.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object with insertion-stable ordering not required; BTreeMap keeps
    /// output deterministic.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|v| v as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|v| {
            if v >= 0.0 && v.fract() == 0.0 {
                Some(v as usize)
            } else {
                None
            }
        })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    // -- construction helpers ------------------------------------------

    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            fields
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }
    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // -- serialization --------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(1), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else if n.is_finite() {
                    let _ = write!(out, "{}", n);
                } else {
                    // JSON has no NaN/Inf; emit null (documented lossy).
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..(w * depth) {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

/// Parse a JSON document. Returns an error with byte offset on failure.
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let cp = self.unicode_escape()?;
                            s.push(cp);
                            continue;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (input is valid UTF-8).
                    let start = self.pos;
                    let rest = &self.bytes[start..];
                    let step = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..step])
                        .map_err(|_| self.err("bad utf-8"))?;
                    s.push_str(chunk);
                    self.pos += step;
                }
            }
        }
    }

    fn unicode_escape(&mut self) -> Result<char, JsonError> {
        // self.pos is at 'u'
        self.pos += 1;
        let hex = self
            .bytes
            .get(self.pos..self.pos + 4)
            .ok_or_else(|| self.err("short \\u escape"))?;
        let hex = std::str::from_utf8(hex).map_err(|_| self.err("bad hex"))?;
        let mut code =
            u32::from_str_radix(hex, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        // Surrogate pair handling.
        if (0xD800..0xDC00).contains(&code) {
            if self.bytes.get(self.pos) == Some(&b'\\')
                && self.bytes.get(self.pos + 1) == Some(&b'u')
            {
                self.pos += 2;
                let hex2 = self
                    .bytes
                    .get(self.pos..self.pos + 4)
                    .ok_or_else(|| self.err("short surrogate"))?;
                let hex2 = std::str::from_utf8(hex2)
                    .map_err(|_| self.err("bad hex"))?;
                let low = u32::from_str_radix(hex2, 16)
                    .map_err(|_| self.err("bad hex"))?;
                self.pos += 4;
                code = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
            } else {
                return Err(self.err("lone surrogate"));
            }
        }
        char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "3", "-2.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, {"b": null}, "x\ny"], "c": 2e3}"#)
            .unwrap();
        assert_eq!(v.get("c").unwrap().as_f64(), Some(2000.0));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_i64(), Some(1));
        assert_eq!(arr[2].as_str(), Some("x\ny"));
    }

    #[test]
    fn parse_unicode_escape() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn pretty_output_reparses() {
        let v = Json::obj(vec![
            ("xs", Json::arr(vec![Json::num(1.0), Json::num(2.5)])),
            ("s", Json::str("a\"b")),
        ]);
        assert_eq!(parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn non_finite_nums_serialize_as_null_and_reparse() {
        // Degenerate bench/metric rows can carry NaN/±inf; bare `NaN`
        // or `inf` tokens would make the whole document unparseable.
        // Lock the documented lossy mapping: non-finite → null, and the
        // output always round-trips through the in-tree parser.
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let v = Json::obj(vec![
                ("x", Json::num(bad)),
                ("xs", Json::arr(vec![Json::num(1.0), Json::num(bad)])),
            ]);
            for text in [v.to_string(), v.to_string_pretty()] {
                let back = parse(&text).unwrap_or_else(|e| {
                    panic!("unparseable output {text:?}: {e}")
                });
                assert_eq!(back.get("x"), Some(&Json::Null));
                let xs = back.get("xs").unwrap().as_arr().unwrap();
                assert_eq!(xs[0].as_f64(), Some(1.0));
                assert_eq!(xs[1], Json::Null);
            }
        }
    }

    #[test]
    fn integers_stay_integral() {
        let v = Json::num(42.0);
        assert_eq!(v.to_string(), "42");
        assert_eq!(parse("42").unwrap().as_usize(), Some(42));
        assert_eq!(parse("-1").unwrap().as_usize(), None);
    }
}
