//! Wall-clock timing helpers for the trainer and the bench harness.

use std::time::Instant;

/// Simple scope timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer {
            start: Instant::now(),
        }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }

    pub fn elapsed_us(&self) -> f64 {
        self.elapsed_s() * 1e6
    }
}

/// Exponential moving average for smoothed throughput displays.
#[derive(Debug, Clone)]
pub struct Ema {
    beta: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(beta: f64) -> Ema {
        assert!((0.0..1.0).contains(&beta));
        Ema { beta, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => self.beta * v + (1.0 - self.beta) * x,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Human-readable duration (e.g. "1.52s", "312ms", "45.1us").
pub fn format_duration(seconds: f64) -> String {
    if seconds >= 1.0 {
        format!("{seconds:.2}s")
    } else if seconds >= 1e-3 {
        format!("{:.1}ms", seconds * 1e3)
    } else if seconds >= 1e-6 {
        format!("{:.1}us", seconds * 1e6)
    } else {
        format!("{:.0}ns", seconds * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.elapsed_ms() >= 1.0);
        assert!(t.elapsed_s() < 1.0);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        assert_eq!(e.get(), None);
        for _ in 0..30 {
            e.update(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-6);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(format_duration(2.0), "2.00s");
        assert_eq!(format_duration(0.25), "250.0ms");
        assert_eq!(format_duration(5e-5), "50.0us");
        assert_eq!(format_duration(5e-8), "50ns");
    }
}
