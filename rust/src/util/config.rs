//! Config loading: JSON config files + `--key value` CLI overrides.
//!
//! Training runs are described by a flat JSON object (see
//! `examples/configs/`), loaded here and consumed by
//! [`crate::coordinator::trainer::TrainConfig`]. CLI overrides are applied
//! by string key so every config field is script-sweepable.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use super::json::{self, Json};

/// A flat key→Json view of a config object with typed accessors.
#[derive(Debug, Clone, Default)]
pub struct Config {
    pub values: BTreeMap<String, Json>,
}

impl Config {
    pub fn from_json(v: &Json) -> Result<Config> {
        let obj = v
            .as_obj()
            .context("config root must be a JSON object")?;
        Ok(Config {
            values: obj.clone().into_iter().collect(),
        })
    }

    pub fn load(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let v = json::parse(&text)
            .with_context(|| format!("parsing config {}", path.display()))?;
        Config::from_json(&v)
    }

    /// Apply `--key value` overrides (numbers parsed when possible,
    /// `true`/`false` as booleans, everything else as strings).
    pub fn apply_overrides<'a>(
        &mut self,
        overrides: impl IntoIterator<Item = (&'a String, &'a String)>,
    ) {
        for (k, v) in overrides {
            let parsed = if let Ok(n) = v.parse::<f64>() {
                Json::Num(n)
            } else if v == "true" || v == "false" {
                Json::Bool(v == "true")
            } else {
                Json::Str(v.clone())
            };
            self.values.insert(k.clone(), parsed);
        }
    }

    pub fn str(&self, key: &str) -> Option<&str> {
        self.values.get(key).and_then(|v| v.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str(key).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.values
            .get(key)
            .and_then(|v| v.as_f64())
            .unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.values
            .get(key)
            .and_then(|v| v.as_usize())
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.values
            .get(key)
            .and_then(|v| v.as_i64())
            .map(|v| v as u64)
            .unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.values
            .get(key)
            .and_then(|v| v.as_bool())
            .unwrap_or(default)
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(self.values.clone().into_iter().collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_and_override() {
        let v = json::parse(
            r#"{"model": "micro", "steps": 100, "lr": 0.01, "muon": true}"#,
        )
        .unwrap();
        let mut cfg = Config::from_json(&v).unwrap();
        assert_eq!(cfg.str("model"), Some("micro"));
        assert_eq!(cfg.usize_or("steps", 1), 100);
        assert_eq!(cfg.f64_or("lr", 0.0), 0.01);
        assert!(cfg.bool_or("muon", false));
        assert_eq!(cfg.usize_or("missing", 7), 7);

        let k = "steps".to_string();
        let val = "200".to_string();
        cfg.apply_overrides([(&k, &val)]);
        assert_eq!(cfg.usize_or("steps", 1), 200);
    }

    #[test]
    fn rejects_non_object_root() {
        let v = json::parse("[1,2]").unwrap();
        assert!(Config::from_json(&v).is_err());
    }
}
