//! General-purpose substrates: JSON, CLI parsing, config, logging, timing.

pub mod cli;
pub mod config;
pub mod json;
pub mod logging;
pub mod timer;
