//! GaLore bias residual χ_t = ‖Gᵘ − Gᵖ‖_F / ‖Gᵘ‖_F (paper Fig. 4 /
//! eq. 13): the relative error between the original gradient and its
//! low-rank reconstruction under the *current* projector.

use crate::linalg::{fro_norm, Matrix};
use crate::optim::Projector;

/// χ_t for one block given the full gradient and its projector.
pub fn bias_residual(proj: &Projector, g: &Matrix) -> f64 {
    let gnorm = fro_norm(g) as f64;
    if gnorm == 0.0 {
        return 0.0;
    }
    let rec = proj.reconstruct(g);
    let diff = g.sub(&rec);
    fro_norm(&diff) as f64 / gnorm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul;
    use crate::optim::ProjKind;
    use crate::rng::Pcg;

    #[test]
    fn residual_zero_for_captured_gradient() {
        // Projector built from G itself with rank ≥ rank(G): χ ≈ 0.
        let mut rng = Pcg::new(0);
        let u = Matrix::randn(16, 3, 1.0, &mut rng);
        let v = Matrix::randn(3, 24, 1.0, &mut rng);
        let g = matmul(&u, &v);
        let proj = Projector::build(&g, 3, ProjKind::SvdTopR, &mut rng);
        assert!(bias_residual(&proj, &g) < 1e-2);
    }

    #[test]
    fn residual_grows_for_fresh_gradients() {
        // Projector from G₀ applied to an unrelated G₁: χ near √(1−r/m).
        let mut rng = Pcg::new(1);
        let g0 = Matrix::randn(32, 64, 1.0, &mut rng);
        let g1 = Matrix::randn(32, 64, 1.0, &mut rng);
        let proj = Projector::build(&g0, 4, ProjKind::SvdTopR, &mut rng);
        let chi0 = bias_residual(&proj, &g0);
        let chi1 = bias_residual(&proj, &g1);
        assert!(chi1 > chi0, "{chi1} vs {chi0}");
        // A random 4-dim subspace of a 32-dim space captures ~1/8 of an
        // independent Gaussian's energy: χ ≈ √(1 − 4/32) ≈ 0.94.
        assert!(chi1 > 0.8 && chi1 <= 1.0, "{chi1}");
    }

    #[test]
    fn zero_gradient_defined() {
        let mut rng = Pcg::new(2);
        let g = Matrix::randn(8, 8, 1.0, &mut rng);
        let proj = Projector::build(&g, 2, ProjKind::SvdTopR, &mut rng);
        assert_eq!(bias_residual(&proj, &Matrix::zeros(8, 8)), 0.0);
    }
}
