//! Analyses behind the paper's Figures 2–5: stable rank, singular-value
//! spectra, salient-activation tails, and the GaLore bias residual χ_t.

pub mod activations;
pub mod bias;
pub mod spectrum;

pub use activations::salient_tail_distribution;
pub use bias::bias_residual;
pub use spectrum::{model_stable_rank, spectrum_report, SpectrumRow};
