//! Salient-activation tail analysis (paper Fig. 3-right).
//!
//! The paper feeds 1K C4 prompts through the trained model, takes the
//! global top-k (k = 10 000) activations by score across all modules,
//! and looks at how many *modules* own salient activations — GUM's are
//! spread across more modules (longer tail).
//!
//! Offline proxy (documented in DESIGN.md §2): for each projectable
//! weight W we draw shared probe vectors x (deterministic Gaussian — the
//! same x for every module, standing in for layer inputs), compute
//! |W·x| activation magnitudes, pool them globally, take the top-k, and
//! count per-module membership. The comparison between two checkpoints
//! (GaLore vs GUM) is the meaningful output, exactly as in the paper.


use crate::model::ParamStore;
use crate::rng::{derive_seed, Pcg};

/// Per-module salient-activation counts, sorted descending.
/// Returns (module name, count) with modules owning zero salient
/// activations included (count 0) — the "tail" is how far nonzero counts
/// extend.
pub fn salient_tail_distribution(
    store: &ParamStore,
    n_probes: usize,
    top_k: usize,
    seed: u64,
) -> Vec<(String, usize)> {
    // Collect (|activation|, module index) lazily via a global threshold
    // pass: first gather all magnitudes, then cut at the k-th largest.
    let mut all: Vec<(f32, usize)> = Vec::new();
    let proj_blocks: Vec<usize> = store.projectable_indices();
    for (mod_idx, &bi) in proj_blocks.iter().enumerate() {
        let w = &store.blocks[bi].value;
        let mut rng = Pcg::new(derive_seed(seed, "probe"));
        for _ in 0..n_probes {
            // Shared probe stream: same seed ⇒ same x sequence for every
            // module of the same input dim; deterministic overall.
            let x: Vec<f32> =
                (0..w.rows).map(|_| rng.normal_f32()).collect();
            // a = Wᵀ x (activations of this module's outputs).
            let mut a = vec![0.0f32; w.cols];
            for i in 0..w.rows {
                let xi = x[i];
                if xi == 0.0 {
                    continue;
                }
                let row = w.row(i);
                for (j, aj) in a.iter_mut().enumerate() {
                    *aj += xi * row[j];
                }
            }
            for v in a {
                all.push((v.abs(), mod_idx));
            }
        }
    }
    let k = top_k.min(all.len());
    // Partial selection of the top-k by magnitude.
    all.select_nth_unstable_by(k.saturating_sub(1), |a, b| {
        b.0.partial_cmp(&a.0).unwrap()
    });
    let mut counts = vec![0usize; proj_blocks.len()];
    for &(_, m) in &all[..k] {
        counts[m] += 1;
    }
    let mut out: Vec<(String, usize)> = proj_blocks
        .iter()
        .zip(counts)
        .map(|(&bi, c)| (store.blocks[bi].name.clone(), c))
        .collect();
    out.sort_by(|a, b| b.1.cmp(&a.1));
    out
}

/// Tail length: number of modules owning at least one salient activation.
pub fn tail_length(dist: &[(String, usize)]) -> usize {
    dist.iter().filter(|(_, c)| *c > 0).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{init_param_store, registry};

    #[test]
    fn counts_sum_to_top_k() {
        let store = init_param_store(&registry::get("micro").unwrap(), 0);
        let dist = salient_tail_distribution(&store, 4, 500, 0);
        let total: usize = dist.iter().map(|(_, c)| c).sum();
        assert_eq!(total, 500);
        assert_eq!(dist.len(), store.projectable_indices().len());
        // Sorted descending.
        for w in dist.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
    }

    #[test]
    fn dominant_module_owns_the_top_k() {
        let mut store = init_param_store(&registry::get("micro").unwrap(), 0);
        // Scale one block hugely: it should own ~all salient activations.
        let idx = store.projectable_indices()[3];
        store.blocks[idx].value.scale_in_place(1000.0);
        let dist = salient_tail_distribution(&store, 4, 300, 0);
        assert_eq!(dist[0].0, store.blocks[idx].name);
        assert!(dist[0].1 > 250, "{dist:?}");
        assert!(tail_length(&dist) < store.projectable_indices().len());
    }

    #[test]
    fn uniform_model_has_long_tail() {
        let store = init_param_store(&registry::get("micro").unwrap(), 0);
        let dist = salient_tail_distribution(&store, 4, 2000, 0);
        // Random-init (isotropic) weights spread salient activations
        // across most modules.
        assert!(tail_length(&dist) >= 10, "{}", tail_length(&dist));
    }

    #[test]
    fn deterministic() {
        let store = init_param_store(&registry::get("micro").unwrap(), 0);
        let a = salient_tail_distribution(&store, 2, 100, 7);
        let b = salient_tail_distribution(&store, 2, 100, 7);
        assert_eq!(a, b);
    }
}
