//! Weight-spectrum analyses (Figs. 2, 3-left, 5): per-block singular
//! values and the model-level stable rank E[‖M‖_F²/‖M‖₂²].

use crate::linalg::{singular_values, stable_rank};
use crate::model::{BlockKind, ParamStore};

/// Spectrum summary for one block.
#[derive(Debug, Clone)]
pub struct SpectrumRow {
    pub block: String,
    pub stable_rank: f32,
    /// Descending singular values.
    pub singular_values: Vec<f32>,
    /// Tail mass: σ_{>k} sum / total sum, for k = len/4 (long-tail
    /// indicator used in Fig. 3/5 comparisons).
    pub tail_mass: f32,
}

/// Average stable rank over all projectable blocks — the paper's
/// Figure-2 x-axis.
pub fn model_stable_rank(store: &ParamStore) -> f64 {
    let idx = store.projectable_indices();
    if idx.is_empty() {
        return 0.0;
    }
    idx.iter()
        .map(|&i| stable_rank(&store.blocks[i].value) as f64)
        .sum::<f64>()
        / idx.len() as f64
}

/// Per-block spectrum rows for all projectable blocks.
pub fn spectrum_report(store: &ParamStore) -> Vec<SpectrumRow> {
    store
        .blocks
        .iter()
        .filter(|b| b.kind == BlockKind::Projectable)
        .map(|b| {
            let sv = singular_values(&b.value);
            let total: f32 = sv.iter().sum();
            let k = sv.len() / 4;
            let tail: f32 = sv[k..].iter().sum();
            SpectrumRow {
                block: b.name.clone(),
                stable_rank: stable_rank(&b.value),
                tail_mass: if total > 0.0 { tail / total } else { 0.0 },
                singular_values: sv,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, Matrix};
    use crate::model::{init_param_store, registry};
    use crate::rng::Pcg;

    #[test]
    fn model_stable_rank_of_random_init_is_high() {
        let store = init_param_store(&registry::get("micro").unwrap(), 0);
        let sr = model_stable_rank(&store);
        // Gaussian m×n has stable rank ≈ mn/(√m+√n)² — ~16 for 64²,
        // ~26 for 64×192; the average is comfortably above 10.
        assert!(sr > 10.0, "sr {sr}");
    }

    #[test]
    fn low_rank_weights_have_low_stable_rank() {
        let mut store = init_param_store(&registry::get("micro").unwrap(), 0);
        let mut rng = Pcg::new(0);
        // Overwrite one projectable block with a rank-2 matrix.
        let idx = store.projectable_indices()[0];
        let (m, n) = store.blocks[idx].value.shape();
        let u = Matrix::randn(m, 2, 1.0, &mut rng);
        let v = Matrix::randn(2, n, 1.0, &mut rng);
        store.blocks[idx].value = matmul(&u, &v);
        let rows = spectrum_report(&store);
        let row = rows.iter().find(|r| {
            r.block == store.blocks[idx].name
        }).unwrap();
        assert!(row.stable_rank < 3.0, "{}", row.stable_rank);
        // Tail mass collapses for a rank-2 matrix.
        assert!(row.tail_mass < 1e-3);
    }

    #[test]
    fn spectrum_rows_cover_projectable_blocks() {
        let store = init_param_store(&registry::get("micro").unwrap(), 0);
        let rows = spectrum_report(&store);
        assert_eq!(rows.len(), store.projectable_indices().len());
        for r in &rows {
            assert!(!r.singular_values.is_empty());
            // Sorted descending.
            for w in r.singular_values.windows(2) {
                assert!(w[0] >= w[1] - 1e-5);
            }
        }
    }
}
