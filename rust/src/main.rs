//! `gum` — CLI for the GUM training system.
//!
//! Subcommands:
//!   train        — run a training job (config file + overrides)
//!   experiment   — regenerate a paper table/figure (fig1…fig5,
//!                  table1…table4, theory, ablations, all)
//!   memory       — print the Table-1/Table-3 memory accountant
//!   models       — list model configs
//!   inspect      — summarize a checkpoint (stable rank, spectra)
//!   smoke        — load artifacts, run one grad step, verify numerics

use std::path::PathBuf;

use gum::coordinator::{TrainConfig, Trainer};
use gum::experiments::{self, ExpOpts};
use gum::model::registry;
use gum::util::cli::Args;

const USAGE: &str = "\
gum — GaLore Unbiased with Muon (paper reproduction)

USAGE:
  gum train [--config file.json] [--model micro] [--optimizer gum]
            [--steps N] [--lr X] [--period-k K] [--rank R] [--gamma G]
            [--period-schedule fixed|adaptive] [--period-min K]
            [--period-max K] [--period-drift X] [--period-patience N]
            [--rank-schedule fixed|adaptive] [--rank-energy 0.9]
            [--rank-budget B] [--rank-min R] [--rank-max R]
            [--refresh-strategy exact|randomized[:os[:iters]]|warm-start]
            [--refresh-pipeline sync|async]
            [--seed S] [--eval-every N] [--ckpt-every N] [--probes]
            [--replicas N] [--accum-steps N]
            [--shard-mode interleaved|docs] [--reduce dense|lowrank]
            [--resume state.bin]
            [--max-lane-restarts N]
            [--fault-plan kill:L@S,stall:L@S:MS,trunc:N@B]
            [--tune-cache tune.json]
            [--state-dtype f32|bf16|f16]
            [--out DIR] [--artifacts DIR]
  gum experiment <fig1|fig2|fig3|fig4|fig5|table1|table2|table3|table4|
                  theory|ablations|rank-schedule|period-schedule|all>
                 [--quick] [--steps N] [--out DIR]
  gum memory
  gum models
  gum inspect <checkpoint.bin>
  gum smoke [--artifacts DIR]
  gum bench-gate --baseline BENCH_x.json --fresh fresh.json
            [--tolerance 0.5] [--min-seconds 1e-4] [--github]
            [--speedup-floor 1.35] [--speedup-cases name1,name2]
";

fn main() {
    let args = Args::from_env();
    let code = match args.subcommand() {
        Some("train") => cmd_train(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("memory") => experiments::run(
            "table1",
            &ExpOpts::from_args(&args),
        )
        .and_then(|_| experiments::run("table3", &ExpOpts::from_args(&args))),
        Some("models") => cmd_models(),
        Some("inspect") => cmd_inspect(&args),
        Some("smoke") => cmd_smoke(&args),
        Some("bench-gate") => cmd_bench_gate(&args),
        _ => {
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = code {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let mut cfg = TrainConfig::default();
    // Optional config file, then CLI overrides.
    if let Some(path) = args.get("config") {
        let c = gum::util::config::Config::load(std::path::Path::new(path))?;
        cfg.model = c.str_or("model", &cfg.model);
        cfg.optimizer = c.str_or("optimizer", &cfg.optimizer);
        cfg.lr = c.f64_or("lr", cfg.lr);
        cfg.steps = c.usize_or("steps", cfg.steps);
        cfg.period_k = c.usize_or("period_k", cfg.period_k);
        if let Some(s) = c.str("period_schedule") {
            cfg.period_schedule = gum::optim::PeriodSchedule::parse(s)?;
        }
        if let gum::optim::PeriodSchedule::Adaptive(ref mut a) =
            cfg.period_schedule
        {
            a.drift = c.f64_or("period_drift", a.drift);
            a.patience =
                c.usize_or("period_patience", a.patience as usize) as u32;
            a.min_period = c.usize_or("period_min", a.min_period);
            a.max_period = c.usize_or("period_max", a.max_period);
        }
        cfg.rank = c.usize_or("rank", cfg.rank);
        if let Some(s) = c.str("rank_schedule") {
            cfg.rank_schedule = gum::optim::RankSchedule::parse(s)?;
        }
        if let gum::optim::RankSchedule::Adaptive(ref mut a) =
            cfg.rank_schedule
        {
            a.energy = c.f64_or("rank_energy", a.energy);
            a.budget = c.usize_or("rank_budget", a.budget);
            a.min_rank = c.usize_or("rank_min", a.min_rank);
            a.max_rank = c.usize_or("rank_max", a.max_rank);
        }
        cfg.gamma = c.f64_or("gamma", cfg.gamma);
        if let Some(r) = c.str("refresh_strategy") {
            cfg.refresh = gum::optim::RefreshStrategy::parse(r)?;
        }
        if let Some(p) = c.str("refresh_pipeline") {
            cfg.refresh_pipeline = gum::optim::RefreshPipelineMode::parse(p)?;
        }
        cfg.seed = c.u64_or("seed", cfg.seed);
        cfg.warmup = c.usize_or("warmup", cfg.warmup);
        cfg.eval_every = c.usize_or("eval_every", cfg.eval_every);
        cfg.ckpt_every = c.usize_or("ckpt_every", cfg.ckpt_every);
        cfg.probes = c.bool_or("probes", cfg.probes);
        cfg.replicas = c.usize_or("replicas", cfg.replicas);
        cfg.accum_steps = c.usize_or("accum_steps", cfg.accum_steps);
        if let Some(m) = c.str("shard_mode") {
            cfg.shard_mode = gum::coordinator::ShardMode::parse(m)?;
        }
        if let Some(m) = c.str("reduce") {
            cfg.reduce = gum::coordinator::ReduceMode::parse(m)?;
        }
        if let Some(r) = c.str("resume") {
            cfg.resume_from = Some(PathBuf::from(r));
        }
        cfg.max_lane_restarts =
            c.usize_or("max_lane_restarts", cfg.max_lane_restarts);
        if let Some(p) = c.str("fault_plan") {
            cfg.fault_plan = Some(p.to_string());
        }
        if let Some(p) = c.str("tune_cache") {
            cfg.tune_cache = Some(PathBuf::from(p));
        }
        if let Some(d) = c.str("state_dtype") {
            cfg.state_dtype = gum::optim::StateDtype::parse(d)?;
        }
        if let Some(o) = c.str("out") {
            cfg.out_dir = Some(PathBuf::from(o));
        }
        if let Some(a) = c.str("artifacts") {
            cfg.artifacts_dir = PathBuf::from(a);
        }
    }
    cfg.model = args.get_or("model", &cfg.model.clone()).to_string();
    cfg.optimizer = args.get_or("optimizer", &cfg.optimizer.clone()).to_string();
    cfg.lr = args.get_parse("lr", cfg.lr);
    cfg.steps = args.get_parse("steps", cfg.steps);
    cfg.period_k = args.get_parse("period-k", cfg.period_k);
    if let Some(s) = args.get("period-schedule") {
        cfg.period_schedule = gum::optim::PeriodSchedule::parse(s)?;
    }
    if let gum::optim::PeriodSchedule::Adaptive(ref mut a) =
        cfg.period_schedule
    {
        a.drift = args.get_parse("period-drift", a.drift);
        a.patience = args.get_parse("period-patience", a.patience);
        a.min_period = args.get_parse("period-min", a.min_period);
        a.max_period = args.get_parse("period-max", a.max_period);
    }
    cfg.rank = args.get_parse("rank", cfg.rank);
    if let Some(s) = args.get("rank-schedule") {
        cfg.rank_schedule = gum::optim::RankSchedule::parse(s)?;
    }
    if let gum::optim::RankSchedule::Adaptive(ref mut a) = cfg.rank_schedule {
        a.energy = args.get_parse("rank-energy", a.energy);
        a.budget = args.get_parse("rank-budget", a.budget);
        a.min_rank = args.get_parse("rank-min", a.min_rank);
        a.max_rank = args.get_parse("rank-max", a.max_rank);
    }
    cfg.gamma = args.get_parse("gamma", cfg.gamma);
    if let Some(r) = args.get("refresh-strategy") {
        cfg.refresh = gum::optim::RefreshStrategy::parse(r)?;
    }
    if let Some(p) = args.get("refresh-pipeline") {
        cfg.refresh_pipeline = gum::optim::RefreshPipelineMode::parse(p)?;
    }
    cfg.seed = args.get_parse("seed", cfg.seed);
    cfg.eval_every = args.get_parse("eval-every", cfg.eval_every);
    cfg.ckpt_every = args.get_parse("ckpt-every", cfg.ckpt_every);
    cfg.replicas = args.get_parse("replicas", cfg.replicas);
    cfg.accum_steps = args.get_parse("accum-steps", cfg.accum_steps);
    if let Some(m) = args.get("shard-mode") {
        cfg.shard_mode = gum::coordinator::ShardMode::parse(m)?;
    }
    if let Some(m) = args.get("reduce") {
        cfg.reduce = gum::coordinator::ReduceMode::parse(m)?;
    }
    if let Some(r) = args.get("resume") {
        cfg.resume_from = Some(PathBuf::from(r));
    }
    cfg.max_lane_restarts =
        args.get_parse("max-lane-restarts", cfg.max_lane_restarts);
    if let Some(p) = args.get("fault-plan") {
        // Validate the spec up front so a typo fails before artifacts
        // load, not at step k.
        gum::testing::FaultPlan::parse(p)?;
        cfg.fault_plan = Some(p.to_string());
    }
    if let Some(p) = args.get("tune-cache") {
        cfg.tune_cache = Some(PathBuf::from(p));
    }
    if let Some(d) = args.get("state-dtype") {
        cfg.state_dtype = gum::optim::StateDtype::parse(d)?;
    }
    if args.has_flag("probes") {
        cfg.probes = true;
    }
    if let Some(o) = args.get("out") {
        cfg.out_dir = Some(PathBuf::from(o));
    }
    if let Some(a) = args.get("artifacts") {
        cfg.artifacts_dir = PathBuf::from(a);
    }

    let result = Trainer::new(cfg).run()?;
    println!("\nfinal train loss: {:.4}", result.final_train_loss);
    if let Some(v) = result.final_val_loss {
        println!("final val loss:   {v:.4}");
    }
    if !result.probe_scores.is_empty() {
        println!("probe accuracies (chance 25%):");
        for (d, acc) in &result.probe_scores {
            println!("  {d:<16} {:.1}%", acc * 100.0);
        }
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> anyhow::Result<()> {
    let id = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    experiments::run(id, &ExpOpts::from_args(args))
}

fn cmd_models() -> anyhow::Result<()> {
    println!(
        "{:<12} {:>7} {:>5} {:>7} {:>6} {:>6} {:>6} {:>11}",
        "name", "vocab", "dim", "layers", "heads", "ffn", "seq", "params"
    );
    for c in registry::registry() {
        println!(
            "{:<12} {:>7} {:>5} {:>7} {:>6} {:>6} {:>6} {:>10.2}M",
            c.name,
            c.vocab,
            c.dim,
            c.n_layers,
            c.n_heads,
            c.ffn,
            c.seq_len,
            c.n_params() as f64 / 1e6
        );
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> anyhow::Result<()> {
    let path = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("usage: gum inspect <ckpt.bin>"))?;
    let store =
        gum::coordinator::load_checkpoint(std::path::Path::new(path))?;
    println!(
        "checkpoint: {} blocks, {:.2}M params",
        store.blocks.len(),
        store.n_params() as f64 / 1e6
    );
    println!(
        "model stable rank: {:.2}",
        gum::analysis::model_stable_rank(&store)
    );
    for row in gum::analysis::spectrum_report(&store) {
        println!(
            "  {:<24} SR {:>8.2}  tail-mass {:>8.4}  σ₁ {:>9.4}",
            row.block,
            row.stable_rank,
            row.tail_mass,
            row.singular_values.first().copied().unwrap_or(0.0)
        );
    }
    Ok(())
}

/// Compare a freshly generated `BENCH_*.json` against a checked-in
/// baseline: every case name present in both documents must not have
/// regressed its `mean_s` by more than `--tolerance` (relative).
/// Cases faster than `--min-seconds` in the baseline are skipped —
/// micro-cases are timer noise. Exit code 1 on regression (CI wires
/// this as a non-gating annotated step; `--github` emits
/// `::warning::` workflow annotations).
///
/// `--speedup-floor X` switches to **self-relative** mode: instead of
/// cross-machine `mean_s` ratios, gate on the `speedup` field of the
/// fresh report's sweep rows (packed-vs-legacy, and `tuned_` rows for
/// tuned-vs-fixed), which is measured in one process on one machine —
/// runner speed cancels out of the ratio, so the floor stays stable on
/// noisy shared runners. `--speedup-cases` names the exact rows to
/// gate (comma-separated; a named row that is missing fails the gate
/// rather than passing vacuously). This is the mode CI promotes to a
/// hard gate (EXPERIMENTS.md §Perf documents the floor and variance).
fn cmd_bench_gate(args: &Args) -> anyhow::Result<()> {
    use std::collections::BTreeMap;

    let fresh_path = args
        .get("fresh")
        .ok_or_else(|| anyhow::anyhow!("bench-gate needs --fresh <json>"))?;
    let tolerance: f64 = args.get_parse("tolerance", 0.5);
    let min_seconds: f64 = args.get_parse("min-seconds", 1e-4);
    let github = args.has_flag("github");

    if let Some(floor_s) = args.get("speedup-floor") {
        let floor: f64 = floor_s
            .parse()
            .map_err(|e| anyhow::anyhow!("--speedup-floor {floor_s}: {e}"))?;
        return bench_gate_speedup(args, fresh_path, floor, github);
    }

    let baseline_path = args
        .get("baseline")
        .ok_or_else(|| anyhow::anyhow!("bench-gate needs --baseline <json>"))?;

    let load_cases = |path: &str| -> anyhow::Result<BTreeMap<String, f64>> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        let doc = gum::util::json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))?;
        let cases = doc
            .get("cases")
            .and_then(|c| c.as_arr())
            .ok_or_else(|| anyhow::anyhow!("{path}: no 'cases' array"))?;
        let mut out = BTreeMap::new();
        for case in cases {
            if let (Some(name), Some(mean)) = (
                case.get("name").and_then(gum::util::json::Json::as_str),
                case.get("mean_s").and_then(|m| m.as_f64()),
            ) {
                out.insert(name.to_string(), mean);
            }
        }
        Ok(out)
    };

    let baseline = load_cases(baseline_path)?;
    let fresh = load_cases(fresh_path)?;
    let mut compared = 0usize;
    let mut regressions = 0usize;
    // Baseline rows with no fresh counterpart are *named* skips, not
    // silent ones: a renamed bench case would otherwise fall out of
    // the gate forever while the summary still read "ok".
    let mut skipped: Vec<&str> = Vec::new();
    for (name, &base) in &baseline {
        let Some(&new) = fresh.get(name) else {
            skipped.push(name.as_str());
            continue;
        };
        if base < min_seconds {
            continue; // timer noise
        }
        compared += 1;
        let ratio = new / base.max(1e-12);
        let regressed = ratio > 1.0 + tolerance;
        let marker = if regressed { "REGRESSED" } else { "ok" };
        println!(
            "  {name:<48} base {base:>10.6}s fresh {new:>10.6}s \
             ratio {ratio:>5.2}x {marker}"
        );
        if regressed {
            regressions += 1;
            if github {
                // GitHub Actions annotation syntax.
                println!(
                    "::warning title=bench regression::{name} is \
                     {ratio:.2}x its baseline mean ({base:.6}s -> {new:.6}s)"
                );
            }
        }
    }
    if !skipped.is_empty() {
        for name in &skipped {
            println!(
                "  {name:<48} SKIPPED — baseline row has no fresh \
                 counterpart (renamed or dropped?)"
            );
        }
        if github {
            println!(
                "::warning title=bench gate skipped {} baseline \
                 case(s)::no fresh counterpart for: {}",
                skipped.len(),
                skipped.join(", ")
            );
        }
    }
    println!(
        "bench-gate: {compared} cases compared ({} baseline / {} fresh), \
         tolerance {:.0}%, {regressions} regression(s), {} named skip(s)",
        baseline.len(),
        fresh.len(),
        tolerance * 100.0,
        skipped.len()
    );
    if compared == 0 {
        // A gate that compares nothing passes vacuously — say so loudly
        // (wrong case names, or every overlapping case filtered by
        // --min-seconds).
        let msg = format!(
            "bench-gate compared 0 cases between {baseline_path} and \
             {fresh_path} — the gate is vacuous (check case names and \
             --min-seconds {min_seconds})"
        );
        if github {
            println!("::warning title=bench gate vacuous::{msg}");
        }
        eprintln!("warning: {msg}");
    }
    anyhow::ensure!(
        regressions == 0,
        "{regressions} bench case(s) regressed beyond {:.0}% \
         (see rows above)",
        tolerance * 100.0
    );
    Ok(())
}

/// Self-relative bench gate: read the fresh report's `sweep` and
/// `tuned_sweep` extras, reconstruct each row's name
/// (`{op}_{m}x{n}_r{r}`, `tuned_` prefix for tuned-vs-fixed rows), and
/// require the named rows' `speedup` to clear the floor. Case-keyed
/// extras arrays (`elementwise_speedups`, `state_dtype` — rows carrying
/// `case` + `speedup` fields) gate under their `case` name, so
/// `--speedup-cases step_elementwise` works against the optim suite the
/// same way GEMM rows do. Exact-name matching on purpose:
/// `nt_1024x4096_r128` must not silently also gate
/// `tuned_nt_1024x4096_r128`, whose ratio has a different bar.
fn bench_gate_speedup(
    args: &Args,
    fresh_path: &str,
    floor: f64,
    github: bool,
) -> anyhow::Result<()> {
    let spec = args
        .get_or("speedup-cases", "nt_1024x4096_r128,tn_1024x4096_r128")
        .to_string();
    let text = std::fs::read_to_string(fresh_path)
        .map_err(|e| anyhow::anyhow!("reading {fresh_path}: {e}"))?;
    let doc = gum::util::json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing {fresh_path}: {e}"))?;

    let mut rows: Vec<(String, f64)> = Vec::new();
    for (key, prefix) in [("sweep", ""), ("tuned_sweep", "tuned_")] {
        let Some(arr) = doc.get(key).and_then(|a| a.as_arr()) else {
            continue;
        };
        for row in arr {
            let fields = (
                row.get("op").and_then(|v| v.as_str()),
                row.get("m").and_then(|v| v.as_usize()),
                row.get("n").and_then(|v| v.as_usize()),
                row.get("r").and_then(|v| v.as_usize()),
                row.get("speedup").and_then(|v| v.as_f64()),
            );
            if let (Some(op), Some(m), Some(n), Some(r), Some(s)) = fields {
                rows.push((format!("{prefix}{op}_{m}x{n}_r{r}"), s));
            }
        }
    }
    // Case-keyed extras (optim suite): the row's `case` IS the name.
    for key in ["elementwise_speedups", "state_dtype"] {
        let Some(arr) = doc.get(key).and_then(|a| a.as_arr()) else {
            continue;
        };
        for row in arr {
            let fields = (
                row.get("case").and_then(|v| v.as_str()),
                row.get("speedup").and_then(|v| v.as_f64()),
            );
            if let (Some(case), Some(s)) = fields {
                rows.push((case.to_string(), s));
            }
        }
    }

    let mut checked = 0usize;
    let mut failures = 0usize;
    for sel in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let matched: Vec<&(String, f64)> =
            rows.iter().filter(|(name, _)| name == sel).collect();
        if matched.is_empty() {
            // A named row that didn't run is a failure, not a skip —
            // otherwise a renamed case makes the gate vacuous forever.
            failures += 1;
            println!("  {sel:<48} MISSING — no fresh sweep row by that name");
            if github {
                println!(
                    "::error title=bench gate missing row::{sel} not \
                     found in {fresh_path}"
                );
            }
            continue;
        }
        for (name, speedup) in matched {
            checked += 1;
            let ok = *speedup >= floor;
            let marker = if ok { "ok" } else { "BELOW FLOOR" };
            println!(
                "  {name:<48} speedup {speedup:>5.2}x floor {floor:.2}x \
                 {marker}"
            );
            if !ok {
                failures += 1;
                if github {
                    println!(
                        "::error title=bench speedup below floor::{name} at \
                         {speedup:.2}x < {floor:.2}x"
                    );
                }
            }
        }
    }
    println!(
        "bench-gate (self-relative): floor {floor:.2}x, {checked} row(s) \
         checked, {failures} failure(s)"
    );
    anyhow::ensure!(
        failures == 0,
        "{failures} speedup-gate failure(s) (see rows above)"
    );
    Ok(())
}

fn cmd_smoke(args: &Args) -> anyhow::Result<()> {
    use gum::runtime::{Executor, HloKernels, ModelRunner};
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let mut exec = Executor::new(&dir)?;
    println!("platform: {}", exec.platform());
    println!("manifest: {} entries", exec.manifest.entries.len());

    // 1. Model grad step on the first available model config.
    let cfg_name = exec
        .manifest
        .entries
        .iter()
        .find(|e| e.kind == "model_grad")
        .and_then(|e| e.config_name.clone())
        .ok_or_else(|| anyhow::anyhow!("no model_grad artifact"))?;
    let model_cfg = registry::get(&cfg_name)
        .ok_or_else(|| anyhow::anyhow!("config {cfg_name} not in registry"))?;
    let runner = ModelRunner::new(&exec, &model_cfg)?;
    let params = gum::model::init_param_store(&model_cfg, 0);
    let n = model_cfg.batch * model_cfg.seq_len;
    let tokens: Vec<i32> = (0..n).map(|i| (i % 200 + 4) as i32).collect();
    let out = runner.grad_step(&mut exec, &params, &tokens, &tokens)?;
    anyhow::ensure!(out.loss.is_finite(), "loss not finite");
    anyhow::ensure!(
        out.grads.iter().all(|g| g.is_finite()),
        "gradients not finite"
    );
    println!(
        "model_grad_{cfg_name}: loss {:.4}, {} grads ✓ (ln V = {:.2})",
        out.loss,
        out.grads.len(),
        (model_cfg.vocab as f32).ln()
    );

    // 2. L1 Newton–Schulz kernel vs the native implementation.
    if let Some(e) = exec
        .manifest
        .entries
        .iter()
        .find(|e| e.kind == "newton_schulz")
        .cloned()
    {
        let (m, nn) = (e.inputs[0].shape[0], e.inputs[0].shape[1]);
        let mut rng = gum::rng::Pcg::new(0);
        let g = gum::linalg::Matrix::randn(m, nn, 1.0, &mut rng);
        let hlo = HloKernels::newton_schulz(&mut exec, &g)?;
        let native = gum::linalg::newton_schulz(&g, 5);
        let err = hlo.max_abs_diff(&native);
        anyhow::ensure!(err < 1e-3, "NS mismatch {err}");
        println!("{}: L1-kernel vs native max err {err:.2e} ✓", e.name);
    }
    println!("smoke OK");
    Ok(())
}
