//! Deterministic PRNG substrate: PCG-XSH-RR plus the samplers the
//! trainer, data pipeline and experiments need (uniform, normal,
//! Bernoulli, categorical, Zipf, shuffles).
//!
//! Everything in the repository that is random takes an explicit seed so
//! every experiment is exactly reproducible from its config.

mod pcg;

pub use pcg::{Pcg, ZipfSampler};

/// Derive a child seed from a parent seed and a stream label.
/// Used to give each parameter block / period / worker its own
/// independent stream without coupling their draws.
pub fn derive_seed(parent: u64, label: &str) -> u64 {
    // FNV-1a over the label, mixed with the parent via splitmix-style
    // finalization.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in label.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    let mut z = parent ^ h;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derive_seed_is_deterministic_and_label_sensitive() {
        assert_eq!(derive_seed(7, "a"), derive_seed(7, "a"));
        assert_ne!(derive_seed(7, "a"), derive_seed(7, "b"));
        assert_ne!(derive_seed(7, "a"), derive_seed(8, "a"));
    }
}
