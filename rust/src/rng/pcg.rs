//! PCG-XSH-RR 64/32 PRNG (O'Neill 2014) with distribution samplers.

/// Permuted congruential generator; 64-bit state, 32-bit output.
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
    /// Cached second normal from Box–Muller.
    spare_normal: Option<f64>,
}

const MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Seeded construction; `seed` selects the state, stream constant is
    /// fixed (distinct streams should derive distinct seeds via
    /// [`crate::rng::derive_seed`]).
    pub fn new(seed: u64) -> Self {
        let mut rng = Pcg {
            state: 0,
            inc: (0xda3e39cb94b95bdb << 1) | 1,
            spare_normal: None,
        };
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire rejection).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let hi = ((x as u128 * n as u128) >> 64) as u64;
            let lo = (x as u128 * n as u128) as u64;
            if lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            let u2 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Bernoulli(p).
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Categorical draw from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical with zero total weight");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Zipf(s) over {0, …, n−1} via inverse-CDF on the precomputable
    /// harmonic weights. For repeated draws prefer [`ZipfSampler`].
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        ZipfSampler::new(n, s).sample(self)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Raw generator state for checkpointing: (state, stream increment,
    /// cached Box–Muller spare). Round-trips through [`Pcg::from_raw`].
    pub fn to_raw(&self) -> (u64, u64, Option<f64>) {
        (self.state, self.inc, self.spare_normal)
    }

    /// Rebuild a generator from [`Pcg::to_raw`] output, resuming the
    /// stream exactly where it left off (including the cached normal).
    pub fn from_raw(state: u64, inc: u64, spare_normal: Option<f64>) -> Pcg {
        Pcg {
            state,
            inc,
            spare_normal,
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

/// Precomputed-CDF Zipf sampler (rank-frequency law for synthetic corpora).
pub struct ZipfSampler {
    cdf: Vec<f64>,
}

impl ZipfSampler {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for v in &mut cdf {
            *v /= total;
        }
        ZipfSampler { cdf }
    }

    pub fn sample(&self, rng: &mut Pcg) -> usize {
        let u = rng.f64();
        self.cdf.partition_point(|&c| c < u)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg::new(42);
        let mut b = Pcg::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn raw_state_roundtrip_resumes_stream() {
        let mut a = Pcg::new(9);
        // Advance and leave a Box–Muller spare cached.
        for _ in 0..13 {
            a.next_u64();
        }
        let _ = a.normal();
        let (s, i, spare) = a.to_raw();
        assert!(spare.is_some(), "normal() must cache a spare");
        let mut b = Pcg::from_raw(s, i, spare);
        for _ in 0..50 {
            assert_eq!(a.normal().to_bits(), b.normal().to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Pcg::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut rng = Pcg::new(2);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[rng.below(5)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg::new(3);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn bernoulli_rate() {
        let mut rng = Pcg::new(4);
        let hits = (0..40_000).filter(|_| rng.bernoulli(0.25)).count();
        assert!((hits as f64 / 40_000.0 - 0.25).abs() < 0.01);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Pcg::new(5);
        let w = [1.0, 3.0];
        let ones = (0..40_000)
            .filter(|_| rng.categorical(&w) == 1)
            .count();
        assert!((ones as f64 / 40_000.0 - 0.75).abs() < 0.01);
    }

    #[test]
    fn zipf_is_monotone_decreasing() {
        let mut rng = Pcg::new(6);
        let sampler = ZipfSampler::new(50, 1.2);
        let mut counts = vec![0usize; 50];
        for _ in 0..100_000 {
            counts[sampler.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[4] && counts[4] > counts[20]);
        assert!(counts[0] > 10 * counts[30].max(1));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg::new(7);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg::new(8);
        let idx = rng.sample_indices(20, 8);
        assert_eq!(idx.len(), 8);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 8);
        assert!(idx.iter().all(|&i| i < 20));
    }
}
