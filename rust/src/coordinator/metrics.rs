//! Metrics stream: in-memory rows + CSV/JSON export.
//!
//! Every experiment harness writes its table/figure data through this so
//! EXPERIMENTS.md numbers are regenerable byte-for-byte.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One (step, key, value) record.
#[derive(Debug, Clone, PartialEq)]
pub struct Row {
    pub step: usize,
    pub key: String,
    pub value: f64,
}

/// Append-only metrics log.
#[derive(Debug, Default, Clone)]
pub struct MetricsLog {
    pub rows: Vec<Row>,
}

impl MetricsLog {
    pub fn new() -> MetricsLog {
        MetricsLog::default()
    }

    pub fn push(&mut self, step: usize, key: &str, value: f64) {
        self.rows.push(Row {
            step,
            key: key.to_string(),
            value,
        });
    }

    /// Drop every row at or after `step`. The elastic trainer rewinds
    /// the log alongside a rollback so replayed steps are not
    /// double-counted in the CSV/JSON exports.
    pub fn retain_before(&mut self, step: usize) {
        self.rows.retain(|r| r.step < step);
    }

    /// All values for a key, in insertion (step) order.
    pub fn series(&self, key: &str) -> Vec<(usize, f64)> {
        self.rows
            .iter()
            .filter(|r| r.key == key)
            .map(|r| (r.step, r.value))
            .collect()
    }

    pub fn last(&self, key: &str) -> Option<f64> {
        self.rows
            .iter()
            .rev()
            .find(|r| r.key == key)
            .map(|r| r.value)
    }

    /// Mean of the last `n` values of a key.
    pub fn tail_mean(&self, key: &str, n: usize) -> Option<f64> {
        let s = self.series(key);
        if s.is_empty() {
            return None;
        }
        let tail = &s[s.len().saturating_sub(n)..];
        Some(tail.iter().map(|(_, v)| v).sum::<f64>() / tail.len() as f64)
    }

    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        writeln!(f, "step,key,value")?;
        for r in &self.rows {
            writeln!(f, "{},{},{}", r.step, r.key, r.value)?;
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.rows
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("step", Json::num(r.step as f64)),
                        ("key", Json::str(r.key.clone())),
                        ("value", Json::num(r.value)),
                    ])
                })
                .collect(),
        )
    }

    pub fn write_json(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        std::fs::write(path, self.to_json().to_string_pretty())
            .with_context(|| format!("writing {}", path.display()))
    }
}

/// Canonical metric key for a per-replica series, e.g.
/// `replica3/tokens_per_s`. The aggregate series keeps the bare key, so
/// dashboards can sum lanes against the total.
pub fn replica_key(replica: usize, key: &str) -> String {
    format!("replica{replica}/{key}")
}

/// Render an ASCII sparkline-style loss curve for terminal output.
pub fn ascii_curve(series: &[(usize, f64)], width: usize, height: usize) -> String {
    if series.is_empty() {
        return String::from("(empty series)");
    }
    let min = series.iter().map(|(_, v)| *v).fold(f64::INFINITY, f64::min);
    let max = series
        .iter()
        .map(|(_, v)| *v)
        .fold(f64::NEG_INFINITY, f64::max);
    let span = (max - min).max(1e-12);
    let mut grid = vec![vec![b' '; width]; height];
    for (i, (_, v)) in series.iter().enumerate() {
        let x = i * (width - 1) / series.len().max(1);
        let y = ((v - min) / span * (height - 1) as f64).round() as usize;
        let y = height - 1 - y.min(height - 1);
        grid[y][x.min(width - 1)] = b'*';
    }
    let mut out = String::new();
    out.push_str(&format!("{max:12.4} ┐\n"));
    for row in grid {
        out.push_str("             │");
        out.push_str(std::str::from_utf8(&row).unwrap());
        out.push('\n');
    }
    out.push_str(&format!("{min:12.4} ┘\n"));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_and_tail() {
        let mut m = MetricsLog::new();
        for i in 0..10 {
            m.push(i, "loss", 10.0 - i as f64);
            m.push(i, "lr", 0.1);
        }
        assert_eq!(m.series("loss").len(), 10);
        assert_eq!(m.last("loss"), Some(1.0));
        assert_eq!(m.tail_mean("loss", 2), Some(1.5));
        assert_eq!(m.tail_mean("missing", 2), None);
    }

    #[test]
    fn csv_and_json_export() {
        let mut m = MetricsLog::new();
        m.push(0, "a", 1.5);
        let dir = std::env::temp_dir().join("gum_metrics_test");
        let csv = dir.join("m.csv");
        let json = dir.join("m.json");
        m.write_csv(&csv).unwrap();
        m.write_json(&json).unwrap();
        let csv_text = std::fs::read_to_string(&csv).unwrap();
        assert!(csv_text.contains("0,a,1.5"));
        let parsed =
            crate::util::json::parse(&std::fs::read_to_string(&json).unwrap())
                .unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), 1);
    }

    #[test]
    fn retain_before_drops_the_rollback_step_itself() {
        // The elastic-rollback boundary: rewinding to step S must drop
        // rows logged *at* S too (the replay re-logs them), or the
        // exports double-count the rollback step.
        let mut m = MetricsLog::new();
        for step in 0..5 {
            m.push(step, "train_loss", step as f64);
        }
        m.retain_before(3);
        assert_eq!(
            m.series("train_loss"),
            vec![(0, 0.0), (1, 1.0), (2, 2.0)]
        );
        // Replay from step 3 leaves exactly one row per step.
        for step in 3..5 {
            m.push(step, "train_loss", step as f64 + 0.5);
        }
        let series = m.series("train_loss");
        assert_eq!(series.len(), 5);
        for (i, (step, _)) in series.iter().enumerate() {
            assert_eq!(*step, i, "one row per step after replay");
        }
    }

    #[test]
    fn replica_keys_are_distinct_series() {
        let mut m = MetricsLog::new();
        m.push(0, "tokens_per_s", 100.0);
        m.push(0, &replica_key(0, "tokens_per_s"), 60.0);
        m.push(0, &replica_key(1, "tokens_per_s"), 40.0);
        assert_eq!(m.series("tokens_per_s").len(), 1);
        assert_eq!(m.last(&replica_key(1, "tokens_per_s")), Some(40.0));
    }

    #[test]
    fn ascii_curve_renders() {
        let series: Vec<(usize, f64)> =
            (0..50).map(|i| (i, (50 - i) as f64)).collect();
        let s = ascii_curve(&series, 40, 8);
        assert!(s.contains('*'));
        assert!(s.lines().count() >= 8);
    }
}
