//! Checkpointing.
//!
//! Three self-describing binary formats, all little-endian:
//!
//! - **`GUMCKPT1`** — parameter store only (used by the spectral
//!   analyses of Figs. 2/3/5, which walk checkpoints saved every N
//!   steps). Layout: magic | u32 block count | per block: u32 name len |
//!   name bytes | u32 rank | u32 dims… | f32 data…
//! - **`GUMCKPT2`** — legacy full train state (read-compatible;
//!   [`save_train_state_v2`] still writes it for format-compat tests
//!   and downgrade escapes). No integrity protection: a torn write
//!   fails only at whatever offset the parse happens to die.
//! - **`GUMCKPT3`** — the current train-state format, hardened for the
//!   elastic trainer: magic | u32 section count | per section
//!   `u32 tag | u64 len | payload | u64 fnv1a-64(payload)`. Sections
//!   are CORE (step + coordinator RNG), PARAMS (v1 block layout),
//!   LANES (per-lane + validation stream positions), OPT (the
//!   optimizer snapshot: projector + momentum + sampler), REFRESH
//!   (a refresh-pipeline job armed or in flight at snapshot time,
//!   serialized as its resolved bases — see `optim::refresh_pipeline`)
//!   and RANKS (adaptive rank-schedule controller state: per-block
//!   ranks + hysteresis pressure; written only under
//!   `--rank-schedule adaptive`, so fixed-schedule files are
//!   byte-identical to earlier writers and absence reads as a static
//!   schedule). Unknown tags are skipped (forward compatibility);
//!   truncation and bit corruption are detected with a diagnostic
//!   naming the damaged section.
//!
//! **Every write commits atomically**: bytes go to a `.tmp` sibling
//! which is fsynced and renamed over the target, so a crash mid-write
//! leaves the previous snapshot intact instead of a truncated file.
//! [`load_latest_train_state`] walks a snapshot directory newest-first
//! and falls back past corrupt tails to the last good snapshot — the
//! recovery path the fault-injection suite drives.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::linalg::Matrix;
use crate::model::{BlockKind, ParamBlock, ParamStore};
use crate::optim::{
    OptSnapshot, PendingRefresh, PeriodState, PreparedRefresh, Projector,
    RankState, SnapValue,
};

use super::parallel::TrainState;
use super::scheduler::PeriodSnapshot;

const MAGIC: &[u8; 8] = b"GUMCKPT1";
const STATE_MAGIC_V2: &[u8; 8] = b"GUMCKPT2";
const STATE_MAGIC_V3: &[u8; 8] = b"GUMCKPT3";

/// Section tags of the `GUMCKPT3` container.
const SEC_CORE: u32 = 1;
const SEC_PARAMS: u32 = 2;
const SEC_LANES: u32 = 3;
const SEC_OPT: u32 = 4;
/// Resolved refresh-pipeline state (boundary + precomputed bases) — an
/// in-flight refresh job serialized by resolution. Readers predating
/// the pipeline skip the tag (forward compatibility); absence reads as
/// an idle pipeline.
const SEC_REFRESH: u32 = 5;
/// Adaptive rank-schedule controller state (per-block ranks +
/// hysteresis pressure). Written only when the run uses
/// `--rank-schedule adaptive`, so fixed-schedule snapshots stay
/// byte-identical to pre-RANKS writers; absence reads as a static
/// schedule.
const SEC_RANKS: u32 = 6;
/// Variable-boundary period-scheduler state (committed boundary pair +
/// period controller). Written only when the run uses
/// `--period-schedule adaptive`, so fixed-K snapshots stay
/// byte-identical to pre-PERIODS writers; absence reads as a fixed
/// schedule re-derived from `step % K`.
const SEC_PERIODS: u32 = 7;

fn section_name(tag: u32) -> &'static str {
    match tag {
        SEC_CORE => "CORE",
        SEC_PARAMS => "PARAMS",
        SEC_LANES => "LANES",
        SEC_OPT => "OPT",
        SEC_REFRESH => "REFRESH",
        SEC_RANKS => "RANKS",
        SEC_PERIODS => "PERIODS",
        _ => "UNKNOWN",
    }
}

/// FNV-1a over a byte slice — the per-section integrity checksum.
/// Deliberately simple: it reliably catches the failure modes torn
/// writes produce (truncated tails, zeroed pages, flipped bytes), and
/// it needs no tables.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Write `body` to a `.tmp` sibling of `path`, fsync, and rename over
/// `path` — the atomic-commit discipline every checkpoint write uses.
fn commit_atomic<F>(path: &Path, body: F) -> Result<()>
where
    F: FnOnce(&mut std::io::BufWriter<std::fs::File>) -> Result<()>,
{
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).ok();
        }
    }
    let file_name = path
        .file_name()
        .and_then(|n| n.to_str())
        .with_context(|| format!("checkpoint path {} has no file name", path.display()))?;
    let tmp = path.with_file_name(format!("{file_name}.tmp"));
    let write_result: Result<()> = (|| {
        let file = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        let mut w = std::io::BufWriter::new(file);
        body(&mut w)?;
        w.flush()
            .with_context(|| format!("flushing {}", tmp.display()))?;
        w.get_ref()
            .sync_all()
            .with_context(|| format!("syncing {}", tmp.display()))
    })();
    if let Err(err) = write_result {
        // Best-effort: a failed write (disk full, I/O error) must not
        // leave interrupted `.tmp` siblings accumulating.
        let _ = std::fs::remove_file(&tmp);
        return Err(err);
    }
    std::fs::rename(&tmp, path).with_context(|| {
        format!("committing {} -> {}", tmp.display(), path.display())
    })?;
    // The rename is atomic but not durable until the directory entry is
    // flushed; sync the parent so a committed snapshot survives power
    // loss (best-effort — not every platform lets a directory be
    // opened/synced).
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            if let Ok(d) = std::fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
    }
    Ok(())
}

/// Save a parameter store (v1 format, atomic commit).
pub fn save_checkpoint(store: &ParamStore, path: &Path) -> Result<()> {
    commit_atomic(path, |f| {
        f.write_all(MAGIC)?;
        write_store(f, store)
    })
}

/// Load a parameter store saved by [`save_checkpoint`].
pub fn load_checkpoint(path: &Path) -> Result<ParamStore> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{} is not a GUM checkpoint", path.display());
    }
    read_store(&mut f)
}

/// Save a full resumable train state in the hardened `GUMCKPT3` format:
/// length-prefixed checksummed sections, committed by atomic rename.
pub fn save_train_state(state: &TrainState, path: &Path) -> Result<()> {
    let mut core = Vec::new();
    write_core(&mut core, state)?;
    let mut params = Vec::new();
    write_store(&mut params, &state.params)?;
    let mut lanes = Vec::new();
    write_lanes(&mut lanes, state)?;
    let mut opt = Vec::new();
    write_opt(&mut opt, &state.opt)?;
    let mut refresh = Vec::new();
    write_refresh(&mut refresh, &state.pending_refresh)?;
    let mut sections: Vec<(u32, Vec<u8>)> = vec![
        (SEC_CORE, core),
        (SEC_PARAMS, params),
        (SEC_LANES, lanes),
        (SEC_OPT, opt),
        (SEC_REFRESH, refresh),
    ];
    if let Some(rs) = &state.rank_state {
        let mut ranks = Vec::new();
        write_rank_state(&mut ranks, rs)?;
        sections.push((SEC_RANKS, ranks));
    }
    if let Some(ps) = &state.period_state {
        let mut periods = Vec::new();
        write_period_snapshot(&mut periods, ps)?;
        sections.push((SEC_PERIODS, periods));
    }
    commit_atomic(path, |f| {
        f.write_all(STATE_MAGIC_V3)?;
        f.write_all(&(sections.len() as u32).to_le_bytes())?;
        for (tag, payload) in &sections {
            f.write_all(&tag.to_le_bytes())?;
            f.write_all(&(payload.len() as u64).to_le_bytes())?;
            f.write_all(payload)?;
            f.write_all(&fnv1a64(payload).to_le_bytes())?;
        }
        Ok(())
    })
}

/// Write the legacy `GUMCKPT2` layout (atomic commit). Kept so the
/// back-compat reader stays covered by tests; new code writes v3.
pub fn save_train_state_v2(state: &TrainState, path: &Path) -> Result<()> {
    commit_atomic(path, |f| {
        f.write_all(STATE_MAGIC_V2)?;
        f.write_all(&state.step.to_le_bytes())?;
        write_store(f, &state.params)?;
        write_rng(f, state)?;
        write_lanes(f, state)?;
        write_opt(f, &state.opt)?;
        Ok(())
    })
}

/// Load a train state saved by [`save_train_state`] (v3) or the legacy
/// v2 writer. Corruption — truncated sections, checksum mismatches —
/// fails with a diagnostic naming the damaged section; an unknown
/// `GUMCKPT*` magic fails with a version-mismatch message.
pub fn load_train_state(path: &Path) -> Result<TrainState> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("opening {}", path.display()))?;
    ensure!(
        bytes.len() >= 8,
        "{}: {} bytes is too short for any GUM checkpoint",
        path.display(),
        bytes.len()
    );
    let magic: [u8; 8] = bytes[..8].try_into().unwrap();
    if &magic == STATE_MAGIC_V3 {
        read_train_state_v3(&bytes, path)
    } else if &magic == STATE_MAGIC_V2 {
        let mut cursor = std::io::Cursor::new(&bytes[8..]);
        read_train_state_v2(&mut cursor)
            .with_context(|| format!("{}: parsing GUMCKPT2 body", path.display()))
    } else if &magic == MAGIC {
        bail!(
            "{} is a parameter-only checkpoint (GUMCKPT1), not a train state",
            path.display()
        );
    } else if magic.starts_with(b"GUMCKPT") {
        bail!(
            "{}: unsupported train-state format {:?} (this build reads \
             GUMCKPT2 and GUMCKPT3)",
            path.display(),
            String::from_utf8_lossy(&magic)
        );
    } else {
        bail!("{} is not a GUM train-state checkpoint", path.display());
    }
}

/// Newest loadable snapshot in a directory, plus the corrupt newer ones
/// skipped on the way to it.
#[derive(Debug)]
pub struct LatestState {
    pub path: PathBuf,
    pub state: TrainState,
    /// `(path, error)` for every newer `state_*.bin` rejected before
    /// `path` loaded — non-empty means corrupt-tail recovery engaged.
    pub skipped: Vec<(PathBuf, String)>,
}

/// Delete orphaned `*.bin.tmp` files a crashed writer left between
/// create and rename. The atomic-commit discipline means a `.tmp`
/// sibling is never a valid snapshot, so removing them is always safe;
/// without the sweep an interrupted run leaves one torn file per crash
/// accumulating in the checkpoint dir forever. Returns the removed
/// paths (sorted, for deterministic logging). A missing or unreadable
/// directory sweeps nothing.
pub fn sweep_orphaned_tmp(dir: &Path) -> Vec<PathBuf> {
    let mut removed = Vec::new();
    let Ok(entries) = std::fs::read_dir(dir) else {
        return removed;
    };
    for entry in entries.filter_map(|e| e.ok()) {
        let path = entry.path();
        let is_tmp = path
            .file_name()
            .and_then(|n| n.to_str())
            .map(|n| n.ends_with(".bin.tmp"))
            .unwrap_or(false);
        if is_tmp && std::fs::remove_file(&path).is_ok() {
            removed.push(path);
        }
    }
    removed.sort();
    removed
}

/// Walk `dir`'s `state_*.bin` snapshots newest-first and return the
/// first one that loads, skipping corrupt tails with a warning. `.tmp`
/// siblings from interrupted writes are ignored by the name filter and
/// swept from disk before the walk.
pub fn load_latest_train_state(dir: &Path) -> Result<LatestState> {
    for p in sweep_orphaned_tmp(dir) {
        crate::warn!(
            "removed orphaned checkpoint temp file {}",
            p.display()
        );
    }
    let mut candidates: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("reading snapshot dir {}", dir.display()))?
        .filter_map(|entry| entry.ok().map(|e| e.path()))
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .map(|n| n.starts_with("state_") && n.ends_with(".bin"))
                .unwrap_or(false)
        })
        .collect();
    // Length-then-lexicographic keeps numeric step order even once a
    // step number outgrows the writers' zero padding (state_1000000 >
    // state_999995).
    candidates
        .sort_by_key(|p| (p.as_os_str().len(), p.as_os_str().to_os_string()));
    let mut skipped: Vec<(PathBuf, String)> = Vec::new();
    for path in candidates.into_iter().rev() {
        match load_train_state(&path) {
            Ok(state) => {
                for (p, e) in &skipped {
                    crate::warn!(
                        "skipped corrupt snapshot {}: {e}",
                        p.display()
                    );
                }
                return Ok(LatestState {
                    path,
                    state,
                    skipped,
                });
            }
            Err(e) => skipped.push((path, format!("{e:#}"))),
        }
    }
    match skipped.first() {
        None => bail!(
            "no train-state snapshots (state_*.bin) in {}",
            dir.display()
        ),
        Some((newest, err)) => bail!(
            "all {} train-state snapshots in {} are unloadable \
             (newest {}: {err})",
            skipped.len(),
            dir.display(),
            newest.display()
        ),
    }
}

// ---- GUMCKPT3 section bodies -------------------------------------------

fn write_core<W: Write>(f: &mut W, state: &TrainState) -> Result<()> {
    f.write_all(&state.step.to_le_bytes())?;
    write_rng(f, state)
}

fn read_core<R: Read>(f: &mut R) -> Result<(u64, (u64, u64, Option<f64>))> {
    let step = read_u64(f)?;
    let rng = read_rng(f)?;
    Ok((step, rng))
}

fn write_rng<W: Write>(f: &mut W, state: &TrainState) -> Result<()> {
    let (rng_state, rng_inc, spare) = state.rng_raw;
    f.write_all(&rng_state.to_le_bytes())?;
    f.write_all(&rng_inc.to_le_bytes())?;
    match spare {
        Some(v) => {
            f.write_all(&[1])?;
            f.write_all(&v.to_le_bytes())?;
        }
        None => f.write_all(&[0])?,
    }
    Ok(())
}

fn read_rng<R: Read>(f: &mut R) -> Result<(u64, u64, Option<f64>)> {
    let rng_state = read_u64(f)?;
    let rng_inc = read_u64(f)?;
    let spare = match read_u8(f)? {
        0 => None,
        1 => Some(read_f64(f)?),
        other => bail!("bad RNG spare flag {other}"),
    };
    Ok((rng_state, rng_inc, spare))
}

fn write_lanes<W: Write>(f: &mut W, state: &TrainState) -> Result<()> {
    f.write_all(&(state.lanes.len() as u32).to_le_bytes())?;
    for (next_doc, buffer) in &state.lanes {
        write_lane(f, *next_doc, buffer)?;
    }
    match &state.val_lane {
        Some((next_doc, buffer)) => {
            f.write_all(&[1])?;
            write_lane(f, *next_doc, buffer)?;
        }
        None => f.write_all(&[0])?,
    }
    Ok(())
}

type LaneStates = (Vec<(u64, Vec<i32>)>, Option<(u64, Vec<i32>)>);

fn read_lanes<R: Read>(f: &mut R) -> Result<LaneStates> {
    let n_lanes = read_u32(f)? as usize;
    let mut lanes = Vec::with_capacity(n_lanes);
    for _ in 0..n_lanes {
        lanes.push(read_lane(f)?);
    }
    let val_lane = match read_u8(f)? {
        0 => None,
        1 => Some(read_lane(f)?),
        other => bail!("bad validation-lane flag {other}"),
    };
    Ok((lanes, val_lane))
}

fn write_opt<W: Write>(f: &mut W, opt: &Option<OptSnapshot>) -> Result<()> {
    match opt {
        None => f.write_all(&[0])?,
        Some(snap) => {
            f.write_all(&[1])?;
            f.write_all(&(snap.entries.len() as u32).to_le_bytes())?;
            for (key, value) in &snap.entries {
                let kb = key.as_bytes();
                f.write_all(&(kb.len() as u32).to_le_bytes())?;
                f.write_all(kb)?;
                match value {
                    SnapValue::U64(v) => {
                        f.write_all(&[0])?;
                        f.write_all(&v.to_le_bytes())?;
                    }
                    SnapValue::F64(v) => {
                        f.write_all(&[1])?;
                        f.write_all(&v.to_le_bytes())?;
                    }
                    SnapValue::Bool(v) => {
                        f.write_all(&[2, *v as u8])?;
                    }
                    SnapValue::Mat(m) => {
                        f.write_all(&[3])?;
                        f.write_all(&(m.rows as u32).to_le_bytes())?;
                        f.write_all(&(m.cols as u32).to_le_bytes())?;
                        for v in &m.data {
                            f.write_all(&v.to_le_bytes())?;
                        }
                    }
                    // Tag 4 carries a dtype byte: f32 moments keep the
                    // tag-3 layout above, so pre-dtype checkpoints stay
                    // byte-identical and old readers never see tag 4
                    // unless reduced-precision state was actually used.
                    SnapValue::LowpMat { dtype, rows, cols, bits } => {
                        f.write_all(&[4, dtype.code()])?;
                        f.write_all(&(*rows as u32).to_le_bytes())?;
                        f.write_all(&(*cols as u32).to_le_bytes())?;
                        for v in bits {
                            f.write_all(&v.to_le_bytes())?;
                        }
                    }
                }
            }
        }
    }
    Ok(())
}

fn read_opt<R: Read>(f: &mut R) -> Result<Option<OptSnapshot>> {
    match read_u8(f)? {
        0 => Ok(None),
        1 => {
            let n = read_u32(f)? as usize;
            let mut snap = OptSnapshot::default();
            for _ in 0..n {
                let key_len = read_u32(f)? as usize;
                let mut key = vec![0u8; key_len];
                f.read_exact(&mut key)?;
                let key = String::from_utf8(key).context("bad snapshot key")?;
                let value = match read_u8(f)? {
                    0 => SnapValue::U64(read_u64(f)?),
                    1 => SnapValue::F64(read_f64(f)?),
                    2 => SnapValue::Bool(read_u8(f)? != 0),
                    3 => {
                        let rows = read_u32(f)? as usize;
                        let cols = read_u32(f)? as usize;
                        let mut data = Vec::with_capacity(rows * cols);
                        for _ in 0..rows * cols {
                            data.push(read_f32(f)?);
                        }
                        SnapValue::Mat(Matrix::from_vec(rows, cols, data))
                    }
                    4 => {
                        let code = read_u8(f)?;
                        let dtype = crate::optim::StateDtype::from_code(code)
                            .with_context(|| {
                                format!(
                                    "bad state-dtype code {code} for '{key}'"
                                )
                            })?;
                        let rows = read_u32(f)? as usize;
                        let cols = read_u32(f)? as usize;
                        let mut bits = Vec::with_capacity(rows * cols);
                        for _ in 0..rows * cols {
                            bits.push(read_u16(f)?);
                        }
                        SnapValue::LowpMat { dtype, rows, cols, bits }
                    }
                    tag => bail!("bad snapshot tag {tag} for '{key}'"),
                };
                snap.push(key, value);
            }
            Ok(Some(snap))
        }
        other => bail!("bad optimizer-state flag {other}"),
    }
}

fn write_refresh<W: Write>(
    f: &mut W,
    pending: &Option<PendingRefresh>,
) -> Result<()> {
    match pending {
        None => f.write_all(&[0])?,
        Some(p) => {
            f.write_all(&[1])?;
            f.write_all(&p.boundary.to_le_bytes())?;
            f.write_all(
                &(p.prepared.projectors.len() as u32).to_le_bytes(),
            )?;
            for proj in &p.prepared.projectors {
                match proj {
                    None => f.write_all(&[0])?,
                    Some(p) => {
                        f.write_all(&[1, p.left as u8])?;
                        f.write_all(&(p.rank as u32).to_le_bytes())?;
                        f.write_all(&(p.p.rows as u32).to_le_bytes())?;
                        f.write_all(&(p.p.cols as u32).to_le_bytes())?;
                        for v in &p.p.data {
                            f.write_all(&v.to_le_bytes())?;
                        }
                    }
                }
            }
            // Optional tails (adaptive schedules only): the controller
            // bookkeeping the planned job resolved to. Omitted — not
            // zero flags — when neither schedule is adaptive, so
            // fixed-run REFRESH payloads stay byte-identical to the
            // pre-adaptive writer; and the period tail is omitted when
            // only the rank schedule is adaptive, keeping those
            // payloads byte-identical to the pre-PERIODS writer.
            match (&p.prepared.rank_state, &p.prepared.period_state) {
                (None, None) => {}
                (rank, period) => {
                    match rank {
                        None => f.write_all(&[0])?,
                        Some(rs) => {
                            f.write_all(&[1])?;
                            write_rank_state(f, rs)?;
                        }
                    }
                    if let Some(ps) = period {
                        f.write_all(&[1])?;
                        write_period_state(f, ps)?;
                    }
                }
            }
        }
    }
    Ok(())
}

fn read_refresh<R: Read>(f: &mut R) -> Result<Option<PendingRefresh>> {
    match read_u8(f)? {
        0 => Ok(None),
        1 => {
            let boundary = read_u64(f)?;
            let n = read_u32(f)? as usize;
            let mut projectors = Vec::with_capacity(n);
            for _ in 0..n {
                projectors.push(match read_u8(f)? {
                    0 => None,
                    1 => {
                        let left = read_u8(f)? != 0;
                        let rank = read_u32(f)? as usize;
                        let rows = read_u32(f)? as usize;
                        let cols = read_u32(f)? as usize;
                        let mut data = Vec::with_capacity(rows * cols);
                        for _ in 0..rows * cols {
                            data.push(read_f32(f)?);
                        }
                        Some(Projector {
                            p: Matrix::from_vec(rows, cols, data),
                            left,
                            rank,
                        })
                    }
                    other => bail!("bad refresh projector flag {other}"),
                });
            }
            // Tails are optional: pre-adaptive writers end the payload
            // at the projector list, so EOF here reads as "no rank
            // state", and pre-PERIODS writers end after the rank tail,
            // so EOF there reads as "no period state".
            let rank_state = match read_u8(f) {
                Err(_) => None,
                Ok(0) => None,
                Ok(1) => Some(read_rank_state(f)?),
                Ok(other) => bail!("bad refresh rank-state flag {other}"),
            };
            let period_state = match read_u8(f) {
                Err(_) => None,
                Ok(0) => None,
                Ok(1) => Some(read_period_state(f)?),
                Ok(other) => {
                    bail!("bad refresh period-state flag {other}")
                }
            };
            Ok(Some(PendingRefresh {
                boundary,
                prepared: PreparedRefresh {
                    projectors,
                    rank_state,
                    period_state,
                },
            }))
        }
        other => bail!("bad pending-refresh flag {other}"),
    }
}

fn write_rank_state<W: Write>(f: &mut W, rs: &RankState) -> Result<()> {
    f.write_all(&(rs.ranks.len() as u32).to_le_bytes())?;
    for r in &rs.ranks {
        f.write_all(&r.to_le_bytes())?;
    }
    f.write_all(&(rs.pressure.len() as u32).to_le_bytes())?;
    for p in &rs.pressure {
        f.write_all(&p.to_le_bytes())?;
    }
    Ok(())
}

fn read_rank_state<R: Read>(f: &mut R) -> Result<RankState> {
    let n = read_u32(f)? as usize;
    let mut ranks = Vec::with_capacity(n);
    for _ in 0..n {
        ranks.push(read_u32(f)?);
    }
    let n = read_u32(f)? as usize;
    let mut pressure = Vec::with_capacity(n);
    for _ in 0..n {
        pressure.push(read_i32(f)?);
    }
    Ok(RankState { ranks, pressure })
}

fn write_period_state<W: Write>(f: &mut W, ps: &PeriodState) -> Result<()> {
    f.write_all(&ps.period.to_le_bytes())?;
    f.write_all(&ps.streak.to_le_bytes())?;
    f.write_all(&ps.observations.to_le_bytes())?;
    f.write_all(&ps.last_drift.to_le_bytes())?;
    f.write_all(&(ps.prev_ranks.len() as u32).to_le_bytes())?;
    for r in &ps.prev_ranks {
        f.write_all(&r.to_le_bytes())?;
    }
    Ok(())
}

fn read_period_state<R: Read>(f: &mut R) -> Result<PeriodState> {
    let period = read_u32(f)?;
    let streak = read_u32(f)?;
    let observations = read_u32(f)?;
    let last_drift = read_f32(f)?;
    let n = read_u32(f)? as usize;
    let mut prev_ranks = Vec::with_capacity(n);
    for _ in 0..n {
        prev_ranks.push(read_u32(f)?);
    }
    Ok(PeriodState {
        period,
        streak,
        observations,
        last_drift,
        prev_ranks,
    })
}

fn write_period_snapshot<W: Write>(
    f: &mut W,
    ps: &PeriodSnapshot,
) -> Result<()> {
    f.write_all(&ps.period.to_le_bytes())?;
    match ps.last_boundary {
        None => f.write_all(&[0])?,
        Some(b) => {
            f.write_all(&[1])?;
            f.write_all(&b.to_le_bytes())?;
        }
    }
    f.write_all(&ps.next_boundary.to_le_bytes())?;
    f.write_all(&ps.completed.to_le_bytes())?;
    write_period_state(f, &ps.ctl)
}

fn read_period_snapshot<R: Read>(f: &mut R) -> Result<PeriodSnapshot> {
    let period = read_u32(f)?;
    let last_boundary = match read_u8(f)? {
        0 => None,
        1 => Some(read_u64(f)?),
        other => bail!("bad period last-boundary flag {other}"),
    };
    let next_boundary = read_u64(f)?;
    let completed = read_u64(f)?;
    let ctl = read_period_state(f)?;
    Ok(PeriodSnapshot {
        period,
        last_boundary,
        next_boundary,
        completed,
        ctl,
    })
}

// ---- container readers --------------------------------------------------

fn take_u32(bytes: &[u8], off: &mut usize, what: &str) -> Result<u32> {
    ensure!(
        *off + 4 <= bytes.len(),
        "truncated checkpoint: {what} needs 4 bytes at offset {}, file has {}",
        *off,
        bytes.len()
    );
    let v = u32::from_le_bytes(bytes[*off..*off + 4].try_into().unwrap());
    *off += 4;
    Ok(v)
}

fn take_u64(bytes: &[u8], off: &mut usize, what: &str) -> Result<u64> {
    ensure!(
        *off + 8 <= bytes.len(),
        "truncated checkpoint: {what} needs 8 bytes at offset {}, file has {}",
        *off,
        bytes.len()
    );
    let v = u64::from_le_bytes(bytes[*off..*off + 8].try_into().unwrap());
    *off += 8;
    Ok(v)
}

fn read_train_state_v3(bytes: &[u8], path: &Path) -> Result<TrainState> {
    let mut off = 8usize;
    let n_sections = take_u32(bytes, &mut off, "section count")? as usize;
    ensure!(
        n_sections <= 1024,
        "{}: implausible section count {n_sections} — corrupt header",
        path.display()
    );
    let mut core = None;
    let mut params = None;
    let mut lanes = None;
    let mut opt = None;
    // Optional: snapshots from before the refresh pipeline have no
    // REFRESH section — that reads as an idle pipeline.
    let mut pending_refresh = None;
    // Optional: fixed-schedule snapshots carry no RANKS section — that
    // reads as a static rank schedule.
    let mut rank_state = None;
    // Optional: fixed-K snapshots carry no PERIODS section — the
    // boundary state is then re-derived from `step % K` on restore.
    let mut period_state = None;
    for idx in 0..n_sections {
        let tag = take_u32(bytes, &mut off, "section tag")?;
        let name = section_name(tag);
        let len = take_u64(bytes, &mut off, "section length")? as usize;
        ensure!(
            off.checked_add(len)
                .and_then(|end| end.checked_add(8))
                .map(|end| end <= bytes.len())
                .unwrap_or(false),
            "{}: section {name} (index {idx}) truncated: {len}-byte payload \
             + checksum at offset {off} overruns the {}-byte file",
            path.display(),
            bytes.len()
        );
        let payload = &bytes[off..off + len];
        off += len;
        let stored = take_u64(bytes, &mut off, "section checksum")?;
        let computed = fnv1a64(payload);
        ensure!(
            stored == computed,
            "{}: section {name} checksum mismatch \
             (stored {stored:#018x}, computed {computed:#018x}) — corrupt \
             checkpoint, recover from the previous snapshot",
            path.display()
        );
        let mut cursor = std::io::Cursor::new(payload);
        match tag {
            SEC_CORE => {
                core = Some(
                    read_core(&mut cursor)
                        .with_context(|| format!("parsing {name}"))?,
                )
            }
            SEC_PARAMS => {
                params = Some(
                    read_store(&mut cursor)
                        .with_context(|| format!("parsing {name}"))?,
                )
            }
            SEC_LANES => {
                lanes = Some(
                    read_lanes(&mut cursor)
                        .with_context(|| format!("parsing {name}"))?,
                )
            }
            SEC_OPT => {
                opt = Some(
                    read_opt(&mut cursor)
                        .with_context(|| format!("parsing {name}"))?,
                )
            }
            SEC_REFRESH => {
                pending_refresh = read_refresh(&mut cursor)
                    .with_context(|| format!("parsing {name}"))?
            }
            SEC_RANKS => {
                rank_state = Some(
                    read_rank_state(&mut cursor)
                        .with_context(|| format!("parsing {name}"))?,
                )
            }
            SEC_PERIODS => {
                period_state = Some(
                    read_period_snapshot(&mut cursor)
                        .with_context(|| format!("parsing {name}"))?,
                )
            }
            // Unknown sections from a newer writer: checksum-verified,
            // then skipped.
            _ => {}
        }
    }
    ensure!(
        off == bytes.len(),
        "{}: {} trailing bytes after the last section — corrupt checkpoint",
        path.display(),
        bytes.len() - off
    );
    let (step, rng_raw) = core.with_context(|| {
        format!("{}: missing CORE section", path.display())
    })?;
    let params = params.with_context(|| {
        format!("{}: missing PARAMS section", path.display())
    })?;
    let (lanes, val_lane) = lanes.with_context(|| {
        format!("{}: missing LANES section", path.display())
    })?;
    let opt = opt
        .with_context(|| format!("{}: missing OPT section", path.display()))?;
    Ok(TrainState {
        step,
        params,
        opt,
        rng_raw,
        lanes,
        val_lane,
        pending_refresh,
        rank_state,
        period_state,
    })
}

fn read_train_state_v2<R: Read>(f: &mut R) -> Result<TrainState> {
    let step = read_u64(f)?;
    let params = read_store(f)?;
    let rng_raw = read_rng(f)?;
    let (lanes, val_lane) = read_lanes(f)?;
    let opt = read_opt(f)?;
    Ok(TrainState {
        step,
        params,
        opt,
        rng_raw,
        lanes,
        val_lane,
        // The legacy layout predates the refresh pipeline and adaptive
        // rank schedules; resumes recompute the period-0-style
        // synchronous refresh at the next boundary if nothing was
        // pending, ranks read as static, and the period schedule reads
        // as fixed.
        pending_refresh: None,
        rank_state: None,
        period_state: None,
    })
}

fn write_lane<W: Write>(f: &mut W, next_doc: u64, buffer: &[i32]) -> Result<()> {
    f.write_all(&next_doc.to_le_bytes())?;
    f.write_all(&(buffer.len() as u32).to_le_bytes())?;
    for t in buffer {
        f.write_all(&t.to_le_bytes())?;
    }
    Ok(())
}

fn read_lane<R: Read>(f: &mut R) -> Result<(u64, Vec<i32>)> {
    let next_doc = read_u64(f)?;
    let len = read_u32(f)? as usize;
    let mut buffer = Vec::with_capacity(len);
    for _ in 0..len {
        buffer.push(read_i32(f)?);
    }
    Ok((next_doc, buffer))
}

fn write_store<W: Write>(f: &mut W, store: &ParamStore) -> Result<()> {
    f.write_all(&(store.blocks.len() as u32).to_le_bytes())?;
    for b in &store.blocks {
        let name = b.name.as_bytes();
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name)?;
        f.write_all(&(b.shape.len() as u32).to_le_bytes())?;
        for &d in &b.shape {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        for v in &b.value.data {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

fn read_store<R: Read>(f: &mut R) -> Result<ParamStore> {
    let n = read_u32(f)? as usize;
    let mut blocks = Vec::with_capacity(n);
    for _ in 0..n {
        let name_len = read_u32(f)? as usize;
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("bad block name")?;
        let rank = read_u32(f)? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(read_u32(f)? as usize);
        }
        let numel: usize = shape.iter().product();
        let mut data = vec![0f32; numel];
        for v in &mut data {
            *v = read_f32(f)?;
        }
        let (rows, cols) = match shape.as_slice() {
            [d] => (1, *d),
            [m, nn] => (*m, *nn),
            other => bail!("unsupported rank {other:?}"),
        };
        // Reconstruct classification the same way init does.
        let kind = if shape.len() == 2
            && shape[0] > 1
            && shape[1] > 1
            && name != "embed"
            && name != "lm_head"
        {
            BlockKind::Projectable
        } else {
            BlockKind::Dense
        };
        blocks.push(ParamBlock {
            name,
            shape,
            kind,
            value: Matrix::from_vec(rows, cols, data),
        });
    }
    Ok(ParamStore { blocks })
}

fn read_u8<R: Read>(r: &mut R) -> Result<u8> {
    let mut buf = [0u8; 1];
    r.read_exact(&mut buf)?;
    Ok(buf[0])
}

fn read_u16<R: Read>(r: &mut R) -> Result<u16> {
    let mut buf = [0u8; 2];
    r.read_exact(&mut buf)?;
    Ok(u16::from_le_bytes(buf))
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut buf = [0u8; 4];
    r.read_exact(&mut buf)?;
    Ok(u32::from_le_bytes(buf))
}

fn read_i32<R: Read>(r: &mut R) -> Result<i32> {
    let mut buf = [0u8; 4];
    r.read_exact(&mut buf)?;
    Ok(i32::from_le_bytes(buf))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut buf = [0u8; 8];
    r.read_exact(&mut buf)?;
    Ok(u64::from_le_bytes(buf))
}

fn read_f32<R: Read>(r: &mut R) -> Result<f32> {
    let mut buf = [0u8; 4];
    r.read_exact(&mut buf)?;
    Ok(f32::from_le_bytes(buf))
}

fn read_f64<R: Read>(r: &mut R) -> Result<f64> {
    let mut buf = [0u8; 8];
    r.read_exact(&mut buf)?;
    Ok(f64::from_le_bytes(buf))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{init_param_store, registry};

    fn sample_state() -> TrainState {
        let store = init_param_store(&registry::get("micro").unwrap(), 1);
        let mut snap = OptSnapshot::default();
        snap.push("period", SnapValue::U64(3));
        snap.push("sampler/state", SnapValue::U64(0xdead_beef));
        snap.push("sampler/spare", SnapValue::F64(-0.25));
        snap.push("b0/full", SnapValue::Bool(true));
        snap.push(
            "b0/mom",
            SnapValue::Mat(Matrix::from_vec(
                2,
                3,
                vec![1.0, -2.0, 0.5, 0.0, 9.0, -0.125],
            )),
        );
        // Tag-4 body: a bf16-packed moment must survive the round trip
        // bit-exactly alongside the f32 (tag-3) one above.
        snap.push(
            "b1/mom",
            SnapValue::LowpMat {
                dtype: crate::optim::StateDtype::Bf16,
                rows: 2,
                cols: 2,
                bits: vec![0x3F80, 0xC000, 0x0000, 0x7F80],
            },
        );
        TrainState {
            step: 17,
            params: store,
            opt: Some(snap),
            rng_raw: (42, 99, Some(1.5)),
            lanes: vec![(7, vec![1, 2, 3]), (1007, vec![])],
            val_lane: Some((1_000_003, vec![9, 8])),
            pending_refresh: Some(PendingRefresh {
                boundary: 20,
                prepared: PreparedRefresh {
                    projectors: vec![
                        Some(Projector {
                            p: Matrix::from_vec(
                                3,
                                2,
                                vec![0.5, -1.0, 0.25, 2.0, -0.125, 0.0],
                            ),
                            left: true,
                            rank: 2,
                        }),
                        None,
                    ],
                    rank_state: Some(RankState {
                        ranks: vec![2, 0],
                        pressure: vec![-1, 0],
                    }),
                    period_state: None,
                },
            }),
            rank_state: Some(RankState {
                ranks: vec![3, 0],
                pressure: vec![1, 0],
            }),
            period_state: None,
        }
    }

    fn sample_period_state() -> PeriodState {
        PeriodState {
            period: 12,
            streak: 1,
            observations: 4,
            last_drift: 0.0625,
            prev_ranks: vec![2, 0],
        }
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let store = init_param_store(&registry::get("micro").unwrap(), 3);
        let path = std::env::temp_dir().join("gum_ckpt_test.bin");
        save_checkpoint(&store, &path).unwrap();
        let loaded = load_checkpoint(&path).unwrap();
        assert_eq!(loaded.blocks.len(), store.blocks.len());
        for (a, b) in store.blocks.iter().zip(&loaded.blocks) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.value, b.value);
        }
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("gum_ckpt_garbage.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load_checkpoint(&path).is_err());
    }

    #[test]
    fn train_state_roundtrips_bit_exactly() {
        let state = sample_state();
        let path = std::env::temp_dir().join("gum_train_state_test.bin");
        save_train_state(&state, &path).unwrap();
        let loaded = load_train_state(&path).unwrap();
        assert_eq!(loaded.step, 17);
        assert_eq!(loaded.params, state.params);
        assert_eq!(loaded.opt, state.opt);
        assert_eq!(loaded.rng_raw, (42, 99, Some(1.5)));
        assert_eq!(loaded.lanes, state.lanes);
        assert_eq!(loaded.val_lane, state.val_lane);
        assert_eq!(loaded.pending_refresh, state.pending_refresh);
        assert_eq!(loaded.rank_state, state.rank_state);
    }

    #[test]
    fn fixed_schedule_states_omit_the_ranks_section() {
        let mut state = sample_state();
        state.rank_state = None;
        if let Some(p) = state.pending_refresh.as_mut() {
            p.prepared.rank_state = None;
        }
        let path =
            std::env::temp_dir().join("gum_train_state_fixed_ranks.bin");
        save_train_state(&state, &path).unwrap();
        // Fixed-schedule files carry exactly the five pre-RANKS
        // sections — no RANKS, no PERIODS (byte-compat with the
        // earlier writer)…
        let bytes = std::fs::read(&path).unwrap();
        let n = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        assert_eq!(n, 5, "unexpected section count {n}");
        // …and read back as a static schedule with an untagged
        // pending refresh.
        let loaded = load_train_state(&path).unwrap();
        assert_eq!(loaded.rank_state, None);
        assert_eq!(loaded.period_state, None);
        assert_eq!(
            loaded.pending_refresh.unwrap().prepared.rank_state,
            None
        );
    }

    #[test]
    fn adaptive_period_states_round_trip() {
        let mut state = sample_state();
        state.period_state = Some(PeriodSnapshot {
            period: 12,
            last_boundary: Some(10),
            next_boundary: 22,
            completed: 3,
            ctl: sample_period_state(),
        });
        if let Some(p) = state.pending_refresh.as_mut() {
            p.prepared.period_state = Some(sample_period_state());
        }
        let path =
            std::env::temp_dir().join("gum_train_state_periods.bin");
        save_train_state(&state, &path).unwrap();
        // Adaptive-period files append a PERIODS section after RANKS.
        let bytes = std::fs::read(&path).unwrap();
        let n = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        assert_eq!(n, 7, "unexpected section count {n}");
        let loaded = load_train_state(&path).unwrap();
        assert_eq!(loaded.period_state, state.period_state);
        assert_eq!(loaded.pending_refresh, state.pending_refresh);

        // A never-committed scheduler (fresh start, boundary 0 still
        // pending) snapshots with last_boundary = None; that must
        // round-trip too.
        state.period_state = Some(PeriodSnapshot {
            period: 6,
            last_boundary: None,
            next_boundary: 0,
            completed: 0,
            ctl: sample_period_state(),
        });
        save_train_state(&state, &path).unwrap();
        let loaded = load_train_state(&path).unwrap();
        assert_eq!(loaded.period_state, state.period_state);
    }

    #[test]
    fn period_tail_without_rank_tail_round_trips() {
        // Adaptive period + fixed ranks: the REFRESH tail must encode
        // "no rank state" explicitly so the period tail stays parseable.
        let mut state = sample_state();
        state.rank_state = None;
        if let Some(p) = state.pending_refresh.as_mut() {
            p.prepared.rank_state = None;
            p.prepared.period_state = Some(sample_period_state());
        }
        let path =
            std::env::temp_dir().join("gum_train_state_period_tail.bin");
        save_train_state(&state, &path).unwrap();
        let loaded = load_train_state(&path).unwrap();
        let prepared = loaded.pending_refresh.unwrap().prepared;
        assert_eq!(prepared.rank_state, None);
        assert_eq!(prepared.period_state, Some(sample_period_state()));
    }

    #[test]
    fn legacy_v2_states_still_load() {
        let state = sample_state();
        let path = std::env::temp_dir().join("gum_train_state_v2_test.bin");
        save_train_state_v2(&state, &path).unwrap();
        let loaded = load_train_state(&path).unwrap();
        assert_eq!(loaded.step, state.step);
        assert_eq!(loaded.params, state.params);
        assert_eq!(loaded.opt, state.opt);
        assert_eq!(loaded.lanes, state.lanes);
    }

    #[test]
    fn train_state_rejects_v1_files() {
        let store = init_param_store(&registry::get("micro").unwrap(), 0);
        let path = std::env::temp_dir().join("gum_ckpt_v1_as_state.bin");
        save_checkpoint(&store, &path).unwrap();
        let err = load_train_state(&path).unwrap_err();
        assert!(format!("{err:#}").contains("GUMCKPT1"), "{err:#}");
    }

    #[test]
    fn atomic_commit_leaves_no_tmp_sibling() {
        let dir = std::env::temp_dir().join("gum_ckpt_atomic_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("state_000017.bin");
        save_train_state(&sample_state(), &path).unwrap();
        assert!(path.exists());
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.path().extension().map(|x| x == "tmp").unwrap_or(false))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
    }

    #[test]
    fn startup_sweep_removes_orphaned_tmp_files() {
        let dir = std::env::temp_dir().join("gum_ckpt_tmp_sweep_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // A committed snapshot…
        save_train_state(&sample_state(), &dir.join("state_000017.bin"))
            .unwrap();
        // …plus a simulated crash mid-write: a torn `.tmp` sibling of a
        // newer snapshot that never renamed into place, and an
        // unrelated non-checkpoint file that must survive the sweep.
        std::fs::write(dir.join("state_000020.bin.tmp"), b"torn write")
            .unwrap();
        std::fs::write(dir.join("notes.txt"), b"keep me").unwrap();
        let latest = load_latest_train_state(&dir).unwrap();
        // The torn tmp never shadows the committed snapshot…
        assert_eq!(latest.state.step, 17);
        assert!(latest.skipped.is_empty(), "{:?}", latest.skipped);
        // …and the sweep removed it from disk while leaving everything
        // else alone.
        assert!(!dir.join("state_000020.bin.tmp").exists());
        assert!(dir.join("state_000017.bin").exists());
        assert!(dir.join("notes.txt").exists());
        // Idempotent: a second sweep finds nothing.
        assert!(sweep_orphaned_tmp(&dir).is_empty());
    }
}
