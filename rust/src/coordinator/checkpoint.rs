//! Checkpointing: a simple self-describing binary format for parameter
//! stores (used by the spectral analyses of Figs. 2/3/5, which walk
//! checkpoints saved every N steps).
//!
//! Layout: magic "GUMCKPT1" | u32 block count | per block:
//! u32 name len | name bytes | u32 rank | u32 dims… | f32 data…
//! All integers little-endian.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::linalg::Matrix;
use crate::model::{BlockKind, ParamBlock, ParamStore};

const MAGIC: &[u8; 8] = b"GUMCKPT1";

/// Save a parameter store.
pub fn save_checkpoint(store: &ParamStore, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&(store.blocks.len() as u32).to_le_bytes())?;
    for b in &store.blocks {
        let name = b.name.as_bytes();
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name)?;
        f.write_all(&(b.shape.len() as u32).to_le_bytes())?;
        for &d in &b.shape {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        for v in &b.value.data {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Load a parameter store saved by [`save_checkpoint`].
pub fn load_checkpoint(path: &Path) -> Result<ParamStore> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{} is not a GUM checkpoint", path.display());
    }
    let n = read_u32(&mut f)? as usize;
    let mut blocks = Vec::with_capacity(n);
    for _ in 0..n {
        let name_len = read_u32(&mut f)? as usize;
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("bad block name")?;
        let rank = read_u32(&mut f)? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(read_u32(&mut f)? as usize);
        }
        let numel: usize = shape.iter().product();
        let mut data = vec![0f32; numel];
        let mut buf = [0u8; 4];
        for v in &mut data {
            f.read_exact(&mut buf)?;
            *v = f32::from_le_bytes(buf);
        }
        let (rows, cols) = match shape.as_slice() {
            [d] => (1, *d),
            [m, nn] => (*m, *nn),
            other => bail!("unsupported rank {other:?}"),
        };
        // Reconstruct classification the same way init does.
        let kind = if shape.len() == 2
            && shape[0] > 1
            && shape[1] > 1
            && name != "embed"
            && name != "lm_head"
        {
            BlockKind::Projectable
        } else {
            BlockKind::Dense
        };
        blocks.push(ParamBlock {
            name,
            shape,
            kind,
            value: Matrix::from_vec(rows, cols, data),
        });
    }
    Ok(ParamStore { blocks })
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut buf = [0u8; 4];
    r.read_exact(&mut buf)?;
    Ok(u32::from_le_bytes(buf))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{init_param_store, registry};

    #[test]
    fn roundtrip_preserves_everything() {
        let store = init_param_store(&registry::get("micro").unwrap(), 3);
        let path = std::env::temp_dir().join("gum_ckpt_test.bin");
        save_checkpoint(&store, &path).unwrap();
        let loaded = load_checkpoint(&path).unwrap();
        assert_eq!(loaded.blocks.len(), store.blocks.len());
        for (a, b) in store.blocks.iter().zip(&loaded.blocks) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.value, b.value);
        }
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("gum_ckpt_garbage.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load_checkpoint(&path).is_err());
    }
}
