//! Checkpointing.
//!
//! Two self-describing binary formats, both little-endian:
//!
//! - **`GUMCKPT1`** — parameter store only (used by the spectral
//!   analyses of Figs. 2/3/5, which walk checkpoints saved every N
//!   steps). Layout: magic | u32 block count | per block: u32 name len |
//!   name bytes | u32 rank | u32 dims… | f32 data…
//! - **`GUMCKPT2`** — full resumable train state
//!   ([`TrainState`]): step counter, parameter store (same block layout
//!   as v1), coordinator RNG, per-lane data-stream positions, and the
//!   optimizer snapshot (projector + momentum + sampler) so a run can
//!   resume *mid-period* and replay bit-identically.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::linalg::Matrix;
use crate::model::{BlockKind, ParamBlock, ParamStore};
use crate::optim::{OptSnapshot, SnapValue};

use super::parallel::TrainState;

const MAGIC: &[u8; 8] = b"GUMCKPT1";
const STATE_MAGIC: &[u8; 8] = b"GUMCKPT2";

/// Save a parameter store (v1 format).
pub fn save_checkpoint(store: &ParamStore, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?,
    );
    f.write_all(MAGIC)?;
    write_store(&mut f, store)?;
    Ok(())
}

/// Load a parameter store saved by [`save_checkpoint`].
pub fn load_checkpoint(path: &Path) -> Result<ParamStore> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{} is not a GUM checkpoint", path.display());
    }
    read_store(&mut f)
}

/// Save a full resumable train state (v2 format).
pub fn save_train_state(state: &TrainState, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?,
    );
    f.write_all(STATE_MAGIC)?;
    f.write_all(&state.step.to_le_bytes())?;
    write_store(&mut f, &state.params)?;

    let (rng_state, rng_inc, spare) = state.rng_raw;
    f.write_all(&rng_state.to_le_bytes())?;
    f.write_all(&rng_inc.to_le_bytes())?;
    match spare {
        Some(v) => {
            f.write_all(&[1])?;
            f.write_all(&v.to_le_bytes())?;
        }
        None => f.write_all(&[0])?,
    }

    f.write_all(&(state.lanes.len() as u32).to_le_bytes())?;
    for (next_doc, buffer) in &state.lanes {
        write_lane(&mut f, *next_doc, buffer)?;
    }
    match &state.val_lane {
        Some((next_doc, buffer)) => {
            f.write_all(&[1])?;
            write_lane(&mut f, *next_doc, buffer)?;
        }
        None => f.write_all(&[0])?,
    }

    match &state.opt {
        None => f.write_all(&[0])?,
        Some(snap) => {
            f.write_all(&[1])?;
            f.write_all(&(snap.entries.len() as u32).to_le_bytes())?;
            for (key, value) in &snap.entries {
                let kb = key.as_bytes();
                f.write_all(&(kb.len() as u32).to_le_bytes())?;
                f.write_all(kb)?;
                match value {
                    SnapValue::U64(v) => {
                        f.write_all(&[0])?;
                        f.write_all(&v.to_le_bytes())?;
                    }
                    SnapValue::F64(v) => {
                        f.write_all(&[1])?;
                        f.write_all(&v.to_le_bytes())?;
                    }
                    SnapValue::Bool(v) => {
                        f.write_all(&[2, *v as u8])?;
                    }
                    SnapValue::Mat(m) => {
                        f.write_all(&[3])?;
                        f.write_all(&(m.rows as u32).to_le_bytes())?;
                        f.write_all(&(m.cols as u32).to_le_bytes())?;
                        for v in &m.data {
                            f.write_all(&v.to_le_bytes())?;
                        }
                    }
                }
            }
        }
    }
    Ok(())
}

/// Load a train state saved by [`save_train_state`].
pub fn load_train_state(path: &Path) -> Result<TrainState> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != STATE_MAGIC {
        bail!("{} is not a GUM train-state checkpoint", path.display());
    }
    let step = read_u64(&mut f)?;
    let params = read_store(&mut f)?;

    let rng_state = read_u64(&mut f)?;
    let rng_inc = read_u64(&mut f)?;
    let spare = match read_u8(&mut f)? {
        0 => None,
        1 => Some(read_f64(&mut f)?),
        other => bail!("bad RNG spare flag {other}"),
    };

    let n_lanes = read_u32(&mut f)? as usize;
    let mut lanes = Vec::with_capacity(n_lanes);
    for _ in 0..n_lanes {
        lanes.push(read_lane(&mut f)?);
    }
    let val_lane = match read_u8(&mut f)? {
        0 => None,
        1 => Some(read_lane(&mut f)?),
        other => bail!("bad validation-lane flag {other}"),
    };

    let opt = match read_u8(&mut f)? {
        0 => None,
        1 => {
            let n = read_u32(&mut f)? as usize;
            let mut snap = OptSnapshot::default();
            for _ in 0..n {
                let key_len = read_u32(&mut f)? as usize;
                let mut key = vec![0u8; key_len];
                f.read_exact(&mut key)?;
                let key =
                    String::from_utf8(key).context("bad snapshot key")?;
                let value = match read_u8(&mut f)? {
                    0 => SnapValue::U64(read_u64(&mut f)?),
                    1 => SnapValue::F64(read_f64(&mut f)?),
                    2 => SnapValue::Bool(read_u8(&mut f)? != 0),
                    3 => {
                        let rows = read_u32(&mut f)? as usize;
                        let cols = read_u32(&mut f)? as usize;
                        let mut data = Vec::with_capacity(rows * cols);
                        for _ in 0..rows * cols {
                            data.push(read_f32(&mut f)?);
                        }
                        SnapValue::Mat(Matrix::from_vec(rows, cols, data))
                    }
                    tag => bail!("bad snapshot tag {tag} for '{key}'"),
                };
                snap.push(key, value);
            }
            Some(snap)
        }
        other => bail!("bad optimizer-state flag {other}"),
    };

    Ok(TrainState {
        step,
        params,
        opt,
        rng_raw: (rng_state, rng_inc, spare),
        lanes,
        val_lane,
    })
}

fn write_lane<W: Write>(f: &mut W, next_doc: u64, buffer: &[i32]) -> Result<()> {
    f.write_all(&next_doc.to_le_bytes())?;
    f.write_all(&(buffer.len() as u32).to_le_bytes())?;
    for t in buffer {
        f.write_all(&t.to_le_bytes())?;
    }
    Ok(())
}

fn read_lane<R: Read>(f: &mut R) -> Result<(u64, Vec<i32>)> {
    let next_doc = read_u64(f)?;
    let len = read_u32(f)? as usize;
    let mut buffer = Vec::with_capacity(len);
    for _ in 0..len {
        buffer.push(read_i32(f)?);
    }
    Ok((next_doc, buffer))
}

fn write_store<W: Write>(f: &mut W, store: &ParamStore) -> Result<()> {
    f.write_all(&(store.blocks.len() as u32).to_le_bytes())?;
    for b in &store.blocks {
        let name = b.name.as_bytes();
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name)?;
        f.write_all(&(b.shape.len() as u32).to_le_bytes())?;
        for &d in &b.shape {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        for v in &b.value.data {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

fn read_store<R: Read>(f: &mut R) -> Result<ParamStore> {
    let n = read_u32(f)? as usize;
    let mut blocks = Vec::with_capacity(n);
    for _ in 0..n {
        let name_len = read_u32(f)? as usize;
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("bad block name")?;
        let rank = read_u32(f)? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(read_u32(f)? as usize);
        }
        let numel: usize = shape.iter().product();
        let mut data = vec![0f32; numel];
        for v in &mut data {
            *v = read_f32(f)?;
        }
        let (rows, cols) = match shape.as_slice() {
            [d] => (1, *d),
            [m, nn] => (*m, *nn),
            other => bail!("unsupported rank {other:?}"),
        };
        // Reconstruct classification the same way init does.
        let kind = if shape.len() == 2
            && shape[0] > 1
            && shape[1] > 1
            && name != "embed"
            && name != "lm_head"
        {
            BlockKind::Projectable
        } else {
            BlockKind::Dense
        };
        blocks.push(ParamBlock {
            name,
            shape,
            kind,
            value: Matrix::from_vec(rows, cols, data),
        });
    }
    Ok(ParamStore { blocks })
}

fn read_u8<R: Read>(r: &mut R) -> Result<u8> {
    let mut buf = [0u8; 1];
    r.read_exact(&mut buf)?;
    Ok(buf[0])
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut buf = [0u8; 4];
    r.read_exact(&mut buf)?;
    Ok(u32::from_le_bytes(buf))
}

fn read_i32<R: Read>(r: &mut R) -> Result<i32> {
    let mut buf = [0u8; 4];
    r.read_exact(&mut buf)?;
    Ok(i32::from_le_bytes(buf))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut buf = [0u8; 8];
    r.read_exact(&mut buf)?;
    Ok(u64::from_le_bytes(buf))
}

fn read_f32<R: Read>(r: &mut R) -> Result<f32> {
    let mut buf = [0u8; 4];
    r.read_exact(&mut buf)?;
    Ok(f32::from_le_bytes(buf))
}

fn read_f64<R: Read>(r: &mut R) -> Result<f64> {
    let mut buf = [0u8; 8];
    r.read_exact(&mut buf)?;
    Ok(f64::from_le_bytes(buf))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{init_param_store, registry};

    #[test]
    fn roundtrip_preserves_everything() {
        let store = init_param_store(&registry::get("micro").unwrap(), 3);
        let path = std::env::temp_dir().join("gum_ckpt_test.bin");
        save_checkpoint(&store, &path).unwrap();
        let loaded = load_checkpoint(&path).unwrap();
        assert_eq!(loaded.blocks.len(), store.blocks.len());
        for (a, b) in store.blocks.iter().zip(&loaded.blocks) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.value, b.value);
        }
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("gum_ckpt_garbage.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load_checkpoint(&path).is_err());
    }

    #[test]
    fn train_state_roundtrips_bit_exactly() {
        let store = init_param_store(&registry::get("micro").unwrap(), 1);
        let mut snap = OptSnapshot::default();
        snap.push("period", SnapValue::U64(3));
        snap.push("sampler/state", SnapValue::U64(0xdead_beef));
        snap.push("sampler/spare", SnapValue::F64(-0.25));
        snap.push("b0/full", SnapValue::Bool(true));
        snap.push(
            "b0/mom",
            SnapValue::Mat(Matrix::from_vec(2, 3, vec![1.0, -2.0, 0.5, 0.0, 9.0, -0.125])),
        );
        let state = TrainState {
            step: 17,
            params: store.clone(),
            opt: Some(snap.clone()),
            rng_raw: (42, 99, Some(1.5)),
            lanes: vec![(7, vec![1, 2, 3]), (1007, vec![])],
            val_lane: Some((1_000_003, vec![9, 8])),
        };
        let path = std::env::temp_dir().join("gum_train_state_test.bin");
        save_train_state(&state, &path).unwrap();
        let loaded = load_train_state(&path).unwrap();
        assert_eq!(loaded.step, 17);
        assert_eq!(loaded.params, store);
        assert_eq!(loaded.opt, Some(snap));
        assert_eq!(loaded.rng_raw, (42, 99, Some(1.5)));
        assert_eq!(loaded.lanes, state.lanes);
        assert_eq!(loaded.val_lane, state.val_lane);
    }

    #[test]
    fn train_state_rejects_v1_files() {
        let store = init_param_store(&registry::get("micro").unwrap(), 0);
        let path = std::env::temp_dir().join("gum_ckpt_v1_as_state.bin");
        save_checkpoint(&store, &path).unwrap();
        assert!(load_train_state(&path).is_err());
    }
}
