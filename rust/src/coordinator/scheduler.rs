//! Period scheduling (Algorithm 2's outer loop) + LR schedules.
//!
//! The scheduler used to be modular arithmetic over a static K
//! (`step % K`). With the adaptive [`PeriodSchedule`] the period
//! length changes at boundaries, so the boundary sequence is now
//! explicit state: the scheduler tracks the last *committed* boundary
//! and the next pending one, and every query (`is_period_start`,
//! `steps_into_period`, `refresh_trigger`, …) derives from that pair
//! plus the *current* period length. The fixed schedule drives the
//! same state machine and commits exactly the old `step % K`
//! boundaries — locked in bitwise by the regression tests below and
//! `rust/tests/period_schedule.rs`.

use crate::optim::period_schedule::{
    PeriodController, PeriodSchedule, PeriodState,
};

/// Sampling-period scheduler: at each boundary the coordinator
/// triggers `Optimizer::begin_period` (projector refresh, momentum
/// restart, full-rank resampling) and then commits the boundary here,
/// which lays down the next one — `current_period()` steps later,
/// where the period length is either the static config K or whatever
/// the [`PeriodController`] decided from the refresh's subspace drift.
#[derive(Debug, Clone)]
pub struct PeriodScheduler {
    /// Configured base period K.
    base: usize,
    /// Current period length (== `base` under the fixed schedule).
    period: usize,
    /// Most recent committed boundary; `None` before step 0 commits.
    last_boundary: Option<usize>,
    /// The pending boundary: `begin_period` runs when `step` reaches
    /// it. A restored scheduler sitting exactly on a boundary keeps it
    /// *pending* (snapshots are taken before the boundary commits), so
    /// the resumed run re-executes it exactly like the original did.
    next_boundary: usize,
    /// Boundaries committed so far (refresh count, drives the
    /// refreshes-per-1k-steps metric).
    completed: usize,
    /// Drift-driven period controller under the adaptive schedule.
    controller: Option<PeriodController>,
}

/// Serializable scheduler state for adaptive-period checkpoints: the
/// boundary pair + current period + controller bookkeeping. Written as
/// the `GUMCKPT3` `PERIODS` section; absent ≙ fixed-K (the boundary
/// state is then re-derived from `step % K`, keeping fixed-schedule
/// files byte-identical to the pre-adaptive writer).
#[derive(Debug, Clone, PartialEq)]
pub struct PeriodSnapshot {
    pub period: u32,
    pub last_boundary: Option<u64>,
    pub next_boundary: u64,
    pub completed: u64,
    pub ctl: PeriodState,
}

impl PeriodScheduler {
    /// Fixed-K scheduler: boundaries at 0, K, 2K, …
    pub fn new(period_k: usize) -> PeriodScheduler {
        assert!(period_k >= 1, "period must be >= 1");
        PeriodScheduler {
            base: period_k,
            period: period_k,
            last_boundary: None,
            next_boundary: 0,
            completed: 0,
            controller: None,
        }
    }

    /// Scheduler with the configured schedule attached; `Fixed` is
    /// exactly [`PeriodScheduler::new`].
    pub fn with_schedule(
        period_k: usize,
        schedule: &PeriodSchedule,
    ) -> PeriodScheduler {
        let mut s = PeriodScheduler::new(period_k);
        if let PeriodSchedule::Adaptive(cfg) = schedule {
            let ctl = PeriodController::new(cfg, period_k);
            s.period = ctl.period();
            s.controller = Some(ctl);
        }
        s
    }

    /// Configured base period K.
    pub fn base_period(&self) -> usize {
        self.base
    }

    /// The current period length (the span the pending boundary closes).
    pub fn current_period(&self) -> usize {
        self.period
    }

    /// Boundaries committed so far.
    pub fn boundaries_committed(&self) -> usize {
        self.completed
    }

    /// The adaptive period controller, when one is attached.
    pub fn controller(&self) -> Option<&PeriodController> {
        self.controller.as_ref()
    }

    pub fn is_adaptive(&self) -> bool {
        self.controller.is_some()
    }

    /// True iff `step` is the pending period boundary — the
    /// coordinator must run `begin_period` and then
    /// [`PeriodScheduler::commit_boundary`] there.
    pub fn is_period_start(&self, step: usize) -> bool {
        step == self.next_boundary
    }

    /// Steps elapsed since the governing boundary (0 on the pending
    /// boundary itself). A checkpoint taken where this is non-zero is
    /// *mid-period*: resuming must restore projector/momentum/sampler
    /// state rather than re-running `begin_period`.
    pub fn steps_into_period(&self, step: usize) -> usize {
        if step >= self.next_boundary {
            step - self.next_boundary
        } else {
            step.saturating_sub(self.last_boundary.unwrap_or(0))
        }
    }

    /// First boundary strictly after the pending one when `step` sits
    /// on it, otherwise the pending boundary itself.
    pub fn next_period_start(&self, step: usize) -> usize {
        if step >= self.next_boundary {
            self.next_boundary + self.period
        } else {
            self.next_boundary
        }
    }

    /// The boundary governing `step` — the natural rollback barrier
    /// for elastic recovery (a snapshot taken there replays at most
    /// one period).
    pub fn last_period_start(&self, step: usize) -> usize {
        if step >= self.next_boundary {
            self.next_boundary
        } else {
            self.last_boundary.unwrap_or(0)
        }
    }

    /// The refresh-pipeline trigger hook: `Some(boundary)` iff the
    /// projector refresh for the pending boundary should be scheduled
    /// at `step`, with `lead` steps of lookahead (clamped to the
    /// *current* period length, floored at one step). With the default
    /// `lead = 1` the trigger is the last step before each boundary;
    /// under a period of 1 every step triggers the next step's
    /// refresh. Never fires at or past the pending boundary — a
    /// boundary that is about to commit (or already did) cannot be
    /// planned for again, which is what let the async pipeline plan a
    /// refresh for an already-committed boundary around step 0 /
    /// rollback replays under the old modular arithmetic.
    pub fn refresh_trigger(&self, step: usize, lead: usize) -> Option<usize> {
        let boundary = self.next_boundary;
        if step >= boundary {
            return None;
        }
        // Span of the period the trigger sits in: clamping the lead to
        // it keeps the plan inside the gradient stream of the current
        // period (planning from a pre-refresh gradient of the previous
        // period would bake a stale basis).
        let span = boundary - self.last_boundary.unwrap_or(boundary);
        let lead = lead.clamp(1, span.max(1));
        (boundary - step == lead).then_some(boundary)
    }

    /// Commit the pending boundary at `step` right after
    /// `begin_period*` ran there. Under the adaptive schedule,
    /// `decision` is the period-controller bookkeeping the refresh job
    /// shipped in `PreparedRefresh` (its drift observation already
    /// consumed); the next boundary lands `current_period()` steps out
    /// under the freshly committed length. `None` keeps the current
    /// length — the fixed schedule always, and the adaptive schedule
    /// on boundaries served without a pipelined refresh (e.g. step 0).
    pub fn commit_boundary(&mut self, step: usize, decision: Option<&PeriodState>) {
        debug_assert_eq!(
            step, self.next_boundary,
            "boundary commit out of sequence"
        );
        if let Some(ctl) = self.controller.as_mut() {
            if let Some(state) = decision {
                if let Err(e) = ctl.restore(state) {
                    eprintln!(
                        "[scheduler] period decision rejected ({e}); \
                         keeping period {}",
                        self.period
                    );
                }
            }
            self.period = ctl.period().max(1);
        }
        self.last_boundary = Some(step);
        self.next_boundary = step + self.period.max(1);
        self.completed += 1;
    }

    /// Re-derive fixed-K boundary state at `step` (resume or rollback
    /// from a checkpoint without a `PERIODS` section). A step exactly
    /// on a boundary comes back *pending* — train states are captured
    /// before their step executes, so the boundary's `begin_period`
    /// has not run in the restored timeline and must re-run. The old
    /// modular arithmetic conflated the two (`steps_into_period == 0`
    /// while `last_period_start` claimed the boundary had already
    /// happened); the explicit pending/committed split is the fix.
    pub fn sync_to(&mut self, step: usize) {
        self.period = self.base;
        let into = step % self.base;
        if into == 0 {
            self.next_boundary = step;
            self.last_boundary = (step > 0).then(|| step - self.base);
            self.completed = step / self.base;
        } else {
            self.last_boundary = Some(step - into);
            self.next_boundary = step - into + self.base;
            self.completed = step / self.base + 1;
        }
    }

    /// Serializable state for adaptive-period checkpoints; `None`
    /// under the fixed schedule (the `PERIODS` section is omitted and
    /// fixed-schedule files stay byte-identical).
    pub fn snapshot(&self) -> Option<PeriodSnapshot> {
        self.controller.as_ref().map(|ctl| PeriodSnapshot {
            period: self.period as u32,
            last_boundary: self.last_boundary.map(|b| b as u64),
            next_boundary: self.next_boundary as u64,
            completed: self.completed as u64,
            ctl: ctl.state(),
        })
    }

    /// Reinstate state captured by [`PeriodScheduler::snapshot`].
    /// Fails when this scheduler was built with a fixed schedule (the
    /// checkpoint and the session config disagree about period
    /// adaptivity) or the controller rejects the bookkeeping.
    pub fn restore_snapshot(
        &mut self,
        snap: &PeriodSnapshot,
    ) -> anyhow::Result<()> {
        let ctl = self.controller.as_mut().ok_or_else(|| {
            anyhow::anyhow!(
                "checkpoint carries adaptive period state but the session \
                 uses a fixed period schedule (pass --period-schedule \
                 adaptive to resume it)"
            )
        })?;
        ctl.restore(&snap.ctl)?;
        self.period = (snap.period as usize).max(1);
        self.last_boundary = snap.last_boundary.map(|b| b as usize);
        self.next_boundary = snap.next_boundary as usize;
        self.completed = snap.completed as usize;
        Ok(())
    }
}

/// Learning-rate schedule kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LrKind {
    Const,
    /// Linear warmup then cosine decay to 10% of base.
    WarmupCosine,
}

/// Learning-rate schedule.
#[derive(Debug, Clone, Copy)]
pub struct LrSchedule {
    pub base: f64,
    pub kind: LrKind,
    pub warmup: usize,
    pub total: usize,
}

impl LrSchedule {
    pub fn constant(base: f64) -> LrSchedule {
        LrSchedule {
            base,
            kind: LrKind::Const,
            warmup: 0,
            total: 1,
        }
    }

    pub fn warmup_cosine(base: f64, warmup: usize, total: usize) -> LrSchedule {
        LrSchedule {
            base,
            kind: LrKind::WarmupCosine,
            warmup,
            total: total.max(1),
        }
    }

    pub fn at(&self, step: usize) -> f64 {
        match self.kind {
            LrKind::Const => self.base,
            LrKind::WarmupCosine => {
                if self.warmup > 0 && step < self.warmup {
                    return self.base * (step + 1) as f64 / self.warmup as f64;
                }
                let t = (step.saturating_sub(self.warmup)) as f64
                    / (self.total.saturating_sub(self.warmup)).max(1) as f64;
                let t = t.min(1.0);
                let min_frac = 0.1;
                let cos = 0.5 * (1.0 + (std::f64::consts::PI * t).cos());
                self.base * (min_frac + (1.0 - min_frac) * cos)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive a fixed-K scheduler like the trainer does: commit every
    /// boundary the moment the step reaches it.
    fn drive(s: &mut PeriodScheduler, step: usize) {
        if s.is_period_start(step) {
            s.commit_boundary(step, None);
        }
    }

    #[test]
    fn fixed_schedule_matches_modular_arithmetic() {
        // The stateful boundary sequence must reproduce the old
        // `step % K` scheduler exactly, for every query, at every step.
        for k in [1usize, 2, 3, 5, 7] {
            let mut s = PeriodScheduler::new(k);
            for step in 0..4 * k + 3 {
                assert_eq!(s.is_period_start(step), step % k == 0, "K={k}");
                assert_eq!(s.steps_into_period(step), step % k, "K={k}");
                assert_eq!(
                    s.next_period_start(step),
                    (step / k + 1) * k,
                    "K={k}"
                );
                assert_eq!(s.last_period_start(step), step - step % k);
                drive(&mut s, step);
                assert_eq!(
                    s.refresh_trigger(step, 1),
                    ((step + 1) % k == 0).then(|| step + 1),
                    "K={k} step={step}"
                );
            }
            assert_eq!(s.boundaries_committed(), (4 * k + 3).div_ceil(k));
        }
    }

    #[test]
    fn period_boundaries() {
        let mut s = PeriodScheduler::new(5);
        assert!(s.is_period_start(0));
        drive(&mut s, 0);
        assert!(!s.is_period_start(4));
        assert!(s.is_period_start(5));
        assert_eq!(s.current_period(), 5);
        assert_eq!(s.base_period(), 5);
    }

    #[test]
    fn mid_period_bookkeeping() {
        let mut s = PeriodScheduler::new(5);
        drive(&mut s, 0);
        assert_eq!(s.steps_into_period(3), 3);
        assert_eq!(s.steps_into_period(5), 0);
        assert_eq!(s.next_period_start(4), 5);
        assert_eq!(s.next_period_start(5), 10);
        assert_eq!(s.last_period_start(4), 0);
        assert_eq!(s.last_period_start(5), 5);
        drive(&mut s, 5);
        assert_eq!(s.steps_into_period(7), 2);
        assert_eq!(s.last_period_start(7), 5);
        assert_eq!(s.next_period_start(7), 10);
    }

    #[test]
    fn k1_every_step_is_a_period() {
        let mut s = PeriodScheduler::new(1);
        for step in 0..10 {
            assert!(s.is_period_start(step));
            drive(&mut s, step);
        }
    }

    #[test]
    fn refresh_trigger_fires_lead_steps_before_each_boundary() {
        let mut s = PeriodScheduler::new(5);
        drive(&mut s, 0);
        assert_eq!(s.refresh_trigger(1, 1), None);
        assert_eq!(s.refresh_trigger(3, 1), None);
        assert_eq!(s.refresh_trigger(4, 1), Some(5));
        // Longer lead.
        assert_eq!(s.refresh_trigger(3, 2), Some(5));
        assert_eq!(s.refresh_trigger(4, 2), None);
        // Lead floored at one step.
        assert_eq!(s.refresh_trigger(4, 0), Some(5));
        drive(&mut s, 5);
        assert_eq!(s.refresh_trigger(5, 1), None);
        assert_eq!(s.refresh_trigger(9, 1), Some(10));
        // Lead clamped to the current period span.
        assert_eq!(s.refresh_trigger(5, 99), Some(10));
    }

    // --- boundary off-by-one regressions (the bugfix sweep) ---

    #[test]
    fn trigger_never_fires_for_a_committed_or_pending_boundary() {
        // Regression: with the pending boundary tracked explicitly, a
        // trigger can never name a boundary at or before the current
        // step — the async pipeline cannot plan a refresh for a
        // boundary that already committed. Before step 0's boundary
        // commits there is nothing to plan for either, at any lead.
        let s = PeriodScheduler::new(5);
        for lead in 0..8 {
            assert_eq!(s.refresh_trigger(0, lead), None, "lead={lead}");
            assert_eq!(s.refresh_trigger(3, lead), None, "lead={lead}");
        }
        let mut s = PeriodScheduler::new(5);
        s.commit_boundary(0, None);
        for step in 0..20 {
            for lead in 0..8 {
                if let Some(b) = s.refresh_trigger(step, lead) {
                    assert!(b > step, "boundary {b} not after step {step}");
                    assert!(
                        s.last_boundary.map_or(true, |lb| b > lb),
                        "boundary {b} already committed"
                    );
                }
            }
            drive(&mut s, step);
        }
    }

    #[test]
    fn k1_lead_clamp_triggers_exactly_one_step_ahead() {
        // Regression: under K = 1 every lead clamps to 1 and each step
        // triggers exactly the next boundary — never the current one.
        let mut s = PeriodScheduler::new(1);
        drive(&mut s, 0);
        for lead in [0usize, 1, 2, 99] {
            assert_eq!(s.refresh_trigger(0, lead), Some(1), "lead={lead}");
        }
        drive(&mut s, 1);
        assert_eq!(s.refresh_trigger(1, 1), Some(2));
    }

    #[test]
    fn resume_exactly_on_a_boundary_keeps_it_pending() {
        // Regression: a train state captured at step s is captured
        // *before* s executes, so resuming with s on a boundary must
        // re-run that boundary. The re-derived scheduler agrees with
        // itself: steps_into_period == 0, is_period_start true, and
        // the refresh trigger plans only *past* the pending boundary.
        let mut s = PeriodScheduler::new(5);
        s.sync_to(10);
        assert!(s.is_period_start(10));
        assert_eq!(s.steps_into_period(10), 0);
        assert_eq!(s.last_period_start(10), 10);
        assert_eq!(s.boundaries_committed(), 2); // 0 and 5, not 10 yet
        assert_eq!(s.refresh_trigger(10, 1), None);
        s.commit_boundary(10, None);
        assert_eq!(s.boundaries_committed(), 3);
        assert_eq!(s.refresh_trigger(14, 1), Some(15));

        // Mid-period resume: boundary bookkeeping agrees with the
        // modular arithmetic the live run used.
        let mut m = PeriodScheduler::new(5);
        m.sync_to(13);
        assert!(!m.is_period_start(13));
        assert_eq!(m.steps_into_period(13), 3);
        assert_eq!(m.last_period_start(13), 10);
        assert_eq!(m.next_period_start(13), 15);
        assert_eq!(m.refresh_trigger(14, 1), Some(15));

        // Step 0 is a pending boundary with no committed predecessor.
        let mut z = PeriodScheduler::new(5);
        z.sync_to(0);
        assert!(z.is_period_start(0));
        assert_eq!(z.boundaries_committed(), 0);
    }

    #[test]
    fn adaptive_commit_adopts_the_decided_period() {
        use crate::optim::period_schedule::{
            AdaptivePeriodCfg, PeriodSchedule,
        };
        let cfg = AdaptivePeriodCfg {
            drift: 0.2,
            patience: 1,
            min_period: 2,
            max_period: 40,
        };
        let mut s = PeriodScheduler::with_schedule(
            5,
            &PeriodSchedule::Adaptive(cfg.clone()),
        );
        assert!(s.is_adaptive());
        s.commit_boundary(0, None);
        assert_eq!(s.next_period_start(1), 5);
        // A stable refresh decided period 7 (5 + 5/2).
        let mut ctl = crate::optim::period_schedule::PeriodController::new(
            &cfg, 5,
        );
        ctl.observe(&[Some(0.01)], None);
        assert_eq!(ctl.period(), 7);
        s.commit_boundary(5, Some(&ctl.state()));
        assert_eq!(s.current_period(), 7);
        assert!(s.is_period_start(12));
        // Snapshot round-trips through a fresh adaptive scheduler.
        let snap = s.snapshot().expect("adaptive snapshot");
        let mut fresh = PeriodScheduler::with_schedule(
            5,
            &PeriodSchedule::Adaptive(cfg),
        );
        fresh.restore_snapshot(&snap).unwrap();
        assert_eq!(fresh.snapshot().unwrap(), snap);
        assert!(fresh.is_period_start(12));
        // A fixed scheduler refuses adaptive state.
        let mut fixed = PeriodScheduler::new(5);
        assert!(fixed.restore_snapshot(&snap).is_err());
    }

    #[test]
    fn constant_schedule() {
        let s = LrSchedule::constant(0.01);
        assert_eq!(s.at(0), 0.01);
        assert_eq!(s.at(1000), 0.01);
    }

    #[test]
    fn warmup_cosine_shape() {
        let s = LrSchedule::warmup_cosine(1.0, 10, 100);
        assert!(s.at(0) < 0.2); // warming up
        assert!((s.at(9) - 1.0).abs() < 1e-9); // warmup peak
        assert!(s.at(50) < 1.0 && s.at(50) > 0.1);
        assert!((s.at(100) - 0.1).abs() < 1e-6); // floor at 10%
        // Monotone decay after warmup.
        for w in (10..100).collect::<Vec<_>>().windows(2) {
            assert!(s.at(w[0]) >= s.at(w[1]) - 1e-12);
        }
    }
}
