//! Period scheduling (Algorithm 2's outer loop) + LR schedules.

/// Sampling-period scheduler: every K steps the coordinator triggers
/// `Optimizer::begin_period` (projector refresh, momentum restart,
/// full-rank resampling).
#[derive(Debug, Clone, Copy)]
pub struct PeriodScheduler {
    pub period_k: usize,
}

impl PeriodScheduler {
    pub fn new(period_k: usize) -> PeriodScheduler {
        assert!(period_k >= 1, "period must be >= 1");
        PeriodScheduler { period_k }
    }

    /// True on steps 0, K, 2K, … — the `t` loop boundaries of Alg. 2.
    pub fn is_period_start(&self, step: usize) -> bool {
        step % self.period_k == 0
    }

    /// Period index for a step.
    pub fn period_of(&self, step: usize) -> usize {
        step / self.period_k
    }

    /// Steps elapsed since the most recent period boundary (0 on a
    /// boundary). A checkpoint taken where this is non-zero is
    /// *mid-period*: resuming must restore projector/momentum/sampler
    /// state rather than re-running `begin_period`.
    pub fn steps_into_period(&self, step: usize) -> usize {
        step % self.period_k
    }

    /// First period boundary strictly after `step`.
    pub fn next_period_start(&self, step: usize) -> usize {
        (step / self.period_k + 1) * self.period_k
    }

    /// Most recent period boundary at or before `step` — the natural
    /// rollback barrier for elastic recovery (a snapshot taken there
    /// replays at most one period).
    pub fn last_period_start(&self, step: usize) -> usize {
        step - step % self.period_k
    }

    /// The refresh-pipeline trigger hook: `Some(boundary)` iff the
    /// projector refresh for the *next* period boundary should be
    /// scheduled at `step`, with `lead` steps of lookahead (clamped to
    /// one period, floored at one step). With the default `lead = 1`
    /// the trigger is the last step before each boundary; under
    /// `K = 1` every step triggers the next step's refresh.
    pub fn refresh_trigger(&self, step: usize, lead: usize) -> Option<usize> {
        let boundary = self.next_period_start(step);
        let lead = lead.min(self.period_k).max(1);
        (boundary - step == lead).then_some(boundary)
    }
}

/// Learning-rate schedule kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LrKind {
    Const,
    /// Linear warmup then cosine decay to 10% of base.
    WarmupCosine,
}

/// Learning-rate schedule.
#[derive(Debug, Clone, Copy)]
pub struct LrSchedule {
    pub base: f64,
    pub kind: LrKind,
    pub warmup: usize,
    pub total: usize,
}

impl LrSchedule {
    pub fn constant(base: f64) -> LrSchedule {
        LrSchedule {
            base,
            kind: LrKind::Const,
            warmup: 0,
            total: 1,
        }
    }

    pub fn warmup_cosine(base: f64, warmup: usize, total: usize) -> LrSchedule {
        LrSchedule {
            base,
            kind: LrKind::WarmupCosine,
            warmup,
            total: total.max(1),
        }
    }

    pub fn at(&self, step: usize) -> f64 {
        match self.kind {
            LrKind::Const => self.base,
            LrKind::WarmupCosine => {
                if self.warmup > 0 && step < self.warmup {
                    return self.base * (step + 1) as f64 / self.warmup as f64;
                }
                let t = (step.saturating_sub(self.warmup)) as f64
                    / (self.total.saturating_sub(self.warmup)).max(1) as f64;
                let t = t.min(1.0);
                let min_frac = 0.1;
                let cos = 0.5 * (1.0 + (std::f64::consts::PI * t).cos());
                self.base * (min_frac + (1.0 - min_frac) * cos)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn period_boundaries() {
        let s = PeriodScheduler::new(5);
        assert!(s.is_period_start(0));
        assert!(!s.is_period_start(4));
        assert!(s.is_period_start(5));
        assert_eq!(s.period_of(12), 2);
    }

    #[test]
    fn mid_period_bookkeeping() {
        let s = PeriodScheduler::new(5);
        assert_eq!(s.steps_into_period(0), 0);
        assert_eq!(s.steps_into_period(3), 3);
        assert_eq!(s.steps_into_period(5), 0);
        assert_eq!(s.next_period_start(0), 5);
        assert_eq!(s.next_period_start(4), 5);
        assert_eq!(s.next_period_start(5), 10);
        assert_eq!(s.last_period_start(0), 0);
        assert_eq!(s.last_period_start(4), 0);
        assert_eq!(s.last_period_start(5), 5);
        assert_eq!(s.last_period_start(12), 10);
    }

    #[test]
    fn k1_every_step_is_a_period() {
        let s = PeriodScheduler::new(1);
        assert!((0..10).all(|i| s.is_period_start(i)));
    }

    #[test]
    fn refresh_trigger_fires_lead_steps_before_each_boundary() {
        let s = PeriodScheduler::new(5);
        assert_eq!(s.refresh_trigger(0, 1), None);
        assert_eq!(s.refresh_trigger(3, 1), None);
        assert_eq!(s.refresh_trigger(4, 1), Some(5));
        assert_eq!(s.refresh_trigger(5, 1), None);
        assert_eq!(s.refresh_trigger(9, 1), Some(10));
        // Longer lead.
        assert_eq!(s.refresh_trigger(3, 2), Some(5));
        assert_eq!(s.refresh_trigger(4, 2), None);
        // Lead is clamped to one period (and floored at one step).
        assert_eq!(s.refresh_trigger(5, 99), Some(10));
        assert_eq!(s.refresh_trigger(4, 0), Some(5));
        // K = 1: every step triggers the next boundary.
        let s1 = PeriodScheduler::new(1);
        assert_eq!(s1.refresh_trigger(0, 1), Some(1));
        assert_eq!(s1.refresh_trigger(7, 1), Some(8));
    }

    #[test]
    fn constant_schedule() {
        let s = LrSchedule::constant(0.01);
        assert_eq!(s.at(0), 0.01);
        assert_eq!(s.at(1000), 0.01);
    }

    #[test]
    fn warmup_cosine_shape() {
        let s = LrSchedule::warmup_cosine(1.0, 10, 100);
        assert!(s.at(0) < 0.2); // warming up
        assert!((s.at(9) - 1.0).abs() < 1e-9); // warmup peak
        assert!(s.at(50) < 1.0 && s.at(50) > 0.1);
        assert!((s.at(100) - 0.1).abs() < 1e-6); // floor at 10%
        // Monotone decay after warmup.
        for w in (10..100).collect::<Vec<_>>().windows(2) {
            assert!(s.at(w[0]) >= s.at(w[1]) - 1e-12);
        }
    }
}
