//! Elastic lane supervision: replica lanes that can fail, be fenced,
//! and rejoin — without perturbing the debiased trajectory.
//!
//! [`ElasticSession`] wraps a [`ParallelSession`] with a supervision
//! loop built from four pieces:
//!
//! 1. **Failure detection.** Lanes run through
//!    [`supervised_lane_grads`], which isolates each lane under
//!    `catch_unwind` and classifies unwinds as *injected*
//!    ([`crate::testing::faults::InjectedFault`]) or *real*.
//! 2. **Fencing.** A failed lane is marked [`LaneStatus::Fenced`] and
//!    its partial gradients are discarded — nothing from the failed
//!    attempt ever reaches the tree all-reduce or the optimizer, so the
//!    fixed reduction order of `coordinator::parallel` is preserved by
//!    construction.
//! 3. **Rollback + deterministic re-entry.** Recovery restores the
//!    newest good `GUMCKPT2`-lineage snapshot (the hardened `GUMCKPT3`
//!    container: parameters, optimizer snapshot with projector /
//!    momentum / sampler / warm rsvd basis, per-lane loader positions,
//!    coordinator Pcg, and any resolved refresh-pipeline bases) and
//!    rebuilds the failed lanes from the source factory at the snapshot
//!    boundary — every lane re-enters at the same step, which is the
//!    re-entry barrier. Restoring also **discards any refresh job the
//!    failed attempt left armed or in flight**
//!    (`RefreshPipeline::restore`), so stale bases can never leak into
//!    the replay; the replayed trigger step re-derives them
//!    bit-identically. Fault plans are one-shot, so the replay runs
//!    clean.
//! 4. **Bounded retry budget.** Each lane restart consumes one unit of
//!    `max_lane_restarts`; exhaustion fails the run with the full event
//!    log and the fault-plan spec for replay.
//!
//! **The invariant the recovery suite locks in:** because a global step
//! only commits when *every* lane succeeded, and rollback restores the
//! complete resumable state, the sequence of committed steps — loss
//! trace and parameters — is **bit-identical** to a fault-free run at
//! the same seed, whatever faults fire and wherever they land relative
//! to refresh-period boundaries. Precondition: the optimizer implements
//! `snapshot`/`restore_snapshot` (GUM does); a rollback over an
//! optimizer without snapshot support warns that the replay may
//! diverge.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::model::ParamStore;
use crate::testing::faults::FaultPlan;

use super::checkpoint::{load_latest_train_state, save_train_state};
use super::parallel::{
    combine_lanes_compressed, supervised_lane_grads, GlobalGrad,
    GradSource, LaneFailure, LaneResult, ParallelSession, TrainState,
};

/// Supervision policy for an elastic run.
#[derive(Debug, Clone)]
pub struct ElasticConfig {
    /// Total lane-restart budget across the whole run; exceeding it
    /// fails the run with the event log.
    pub max_lane_restarts: usize,
    /// Global steps between supervision snapshots; 0 snapshots at every
    /// sampling-period boundary (the natural rollback granularity —
    /// recovery never replays more than one period).
    pub snapshot_every: usize,
    /// Directory for on-disk `GUMCKPT3` snapshots. When set, rollback
    /// goes through [`load_latest_train_state`] — exercising the
    /// corrupt-tail fallback — and snapshots survive the process. When
    /// `None`, the rollback state is held in memory only.
    pub snapshot_dir: Option<PathBuf>,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        ElasticConfig {
            max_lane_restarts: 3,
            snapshot_every: 0,
            snapshot_dir: None,
        }
    }
}

/// Supervision state of one replica lane.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaneStatus {
    Healthy,
    /// Fenced out after a failure at `since_step`; flips back to
    /// [`LaneStatus::Healthy`] when the lane rejoins at the rollback
    /// barrier.
    Fenced { since_step: u64 },
}

/// What happened during supervision, in order.
#[derive(Debug, Clone)]
pub enum ElasticEventKind {
    /// A lane's gradient computation died (`injected` separates planned
    /// faults from real bugs).
    LaneFault { injected: bool, message: String },
    /// The lane was fenced out of the reduction.
    Fence,
    /// The session rolled back to `to_step`.
    Rollback { to_step: u64, from_disk: bool },
    /// A corrupt snapshot was skipped during disk rollback.
    SnapshotCorrupt { path: String, error: String },
    /// The fenced lane re-entered at the rollback barrier.
    Rejoin,
    /// A lane straggled well past the median lane time (advisory; the
    /// committed trajectory is unaffected).
    SlowLane { grad_time_s: f64, median_s: f64 },
    /// The retry budget ran out; the run failed.
    BudgetExhausted,
}

/// One supervision event: the global step it happened at, the lane it
/// concerns (when lane-scoped), and what happened.
#[derive(Debug, Clone)]
pub struct ElasticEvent {
    pub step: u64,
    pub lane: Option<usize>,
    pub kind: ElasticEventKind,
}

impl std::fmt::Display for ElasticEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.lane {
            Some(lane) => {
                write!(f, "step {} lane {lane}: {:?}", self.step, self.kind)
            }
            None => write!(f, "step {}: {:?}", self.step, self.kind),
        }
    }
}

/// A [`ParallelSession`] under lane supervision (see module docs).
///
/// The source `factory` rebuilds lane `r`'s gradient engine over the
/// restored parameters when the lane rejoins; it must be deterministic
/// — same `(params, r)` → an engine producing the same gradients — for
/// the bit-identical-trace invariant to hold.
pub struct ElasticSession<S: GradSource> {
    pub inner: ParallelSession,
    pub cfg: ElasticConfig,
    plan: Arc<FaultPlan>,
    sources: Vec<S>,
    factory: Box<dyn Fn(&ParamStore, usize) -> S>,
    status: Vec<LaneStatus>,
    events: Vec<ElasticEvent>,
    restarts_used: usize,
    /// Last good snapshot (always maintained; the rollback source when
    /// no snapshot directory is configured).
    memory_snapshot: Option<TrainState>,
    /// Distinct on-disk save points committed so far — the ordinal the
    /// fault plan's `trunc` clauses schedule against. Post-rollback
    /// replays re-commit earlier steps without advancing it, so plan
    /// indices match the fault-free save timeline.
    saves: u64,
    /// Highest step a disk snapshot has been committed for.
    last_saved_step: Option<u64>,
}

impl<S: GradSource> ElasticSession<S> {
    pub fn new(
        inner: ParallelSession,
        cfg: ElasticConfig,
        plan: Arc<FaultPlan>,
        factory: impl Fn(&ParamStore, usize) -> S + 'static,
    ) -> ElasticSession<S> {
        let replicas = inner.batcher.replicas();
        let sources: Vec<S> =
            (0..replicas).map(|r| factory(&inner.params, r)).collect();
        ElasticSession {
            inner,
            cfg,
            plan,
            sources,
            factory: Box::new(factory),
            status: vec![LaneStatus::Healthy; replicas],
            events: Vec::new(),
            restarts_used: 0,
            memory_snapshot: None,
            saves: 0,
            last_saved_step: None,
        }
    }

    /// Supervision events so far, in order.
    pub fn events(&self) -> &[ElasticEvent] {
        &self.events
    }

    /// Per-lane supervision status.
    pub fn status(&self) -> &[LaneStatus] {
        &self.status
    }

    /// Lane restarts consumed from the budget.
    pub fn restarts_used(&self) -> usize {
        self.restarts_used
    }

    /// Advance to the *commit* of the step the session entered this
    /// call at. Internally this may take several attempts — fence, roll
    /// back, rejoin — and a rollback may replay earlier steps; those
    /// replayed commits are identical to the originals (the determinism
    /// contract) and are not re-surfaced. The call returns only when
    /// the entry step itself commits, so the caller's loss trace is
    /// exactly the committed trajectory, one entry per step.
    pub fn global_step(&mut self) -> Result<GlobalGrad> {
        let target = self.inner.step;
        loop {
            if self.snapshot_due() {
                self.take_snapshot()?;
            }
            let step = self.inner.step;
            for source in self.sources.iter_mut() {
                source.begin_step(step as u64);
            }
            let batches = self.inner.batcher.next_global();
            let outcomes = supervised_lane_grads(
                &mut self.sources,
                &self.inner.params,
                &batches,
            )?;
            let mut lanes = Vec::with_capacity(outcomes.len());
            let mut failures = Vec::new();
            for outcome in outcomes {
                match outcome {
                    Ok(lane) => lanes.push(lane),
                    Err(failure) => failures.push(failure),
                }
            }
            if failures.is_empty() {
                self.note_stragglers(step as u64, &lanes);
                // Plan recomputed per attempt from committed state, so
                // a rolled-back attempt and its replay ship identical
                // payloads (fenced lanes never reach the reduce).
                let plan = self.inner.reduce_plan();
                let (global, stats) = combine_lanes_compressed(lanes, &plan);
                self.inner.last_reduce = Some(stats);
                self.inner.apply(&global);
                if step == target {
                    return Ok(global);
                }
                // A post-rollback replay of an earlier step: committed,
                // not surfaced.
                continue;
            }
            self.recover(step as u64, failures)?;
        }
    }

    /// Drive `steps` committed global steps, returning their losses.
    pub fn run(&mut self, steps: usize) -> Result<Vec<f64>> {
        let mut losses = Vec::with_capacity(steps);
        for _ in 0..steps {
            losses.push(self.global_step()?.loss);
        }
        Ok(losses)
    }

    fn snapshot_due(&self) -> bool {
        // A rollback target must exist before the first attempt.
        if self.memory_snapshot.is_none() && self.saves == 0 {
            return true;
        }
        if self.cfg.snapshot_every > 0 {
            self.inner.step % self.cfg.snapshot_every == 0
        } else {
            self.inner.periods.is_period_start(self.inner.step)
        }
    }

    fn take_snapshot(&mut self) -> Result<()> {
        let state = self.inner.train_state();
        if let Some(dir) = &self.cfg.snapshot_dir {
            let path = dir.join(format!("state_{:06}.bin", state.step));
            save_train_state(&state, &path).with_context(|| {
                format!("elastic snapshot at step {}", state.step)
            })?;
            // Only a *new* save point advances the fault-plan ordinal;
            // a replay re-committing an earlier step (which also
            // repairs a previously torn file) must not consume or
            // shift `trunc:N` faults scheduled for later saves.
            let new_save_point =
                self.last_saved_step.map_or(true, |s| state.step > s);
            if new_save_point {
                self.plan.apply_truncation(self.saves, &path)?;
                self.saves += 1;
                self.last_saved_step = Some(state.step);
            }
        }
        self.memory_snapshot = Some(state);
        Ok(())
    }

    /// Fence the failed lanes, charge the budget, roll back, rejoin.
    fn recover(&mut self, step: u64, failures: Vec<LaneFailure>) -> Result<()> {
        for failure in &failures {
            crate::warn!(
                "lane {} {} at step {step}: {}",
                failure.replica,
                if failure.injected {
                    "hit an injected fault"
                } else {
                    "failed"
                },
                failure.message
            );
            self.events.push(ElasticEvent {
                step,
                lane: Some(failure.replica),
                kind: ElasticEventKind::LaneFault {
                    injected: failure.injected,
                    message: failure.message.clone(),
                },
            });
            self.status[failure.replica] =
                LaneStatus::Fenced { since_step: step };
            self.events.push(ElasticEvent {
                step,
                lane: Some(failure.replica),
                kind: ElasticEventKind::Fence,
            });
        }
        let needed = failures.len();
        if self.restarts_used + needed > self.cfg.max_lane_restarts {
            self.events.push(ElasticEvent {
                step,
                lane: None,
                kind: ElasticEventKind::BudgetExhausted,
            });
            bail!(
                "lane-restart budget exhausted at step {step}: {} used + \
                 {needed} needed > {} allowed (fault plan '{}'); events:\n{}",
                self.restarts_used,
                self.cfg.max_lane_restarts,
                self.plan.spec(),
                self.render_events()
            );
        }
        self.restarts_used += needed;
        self.rollback(step)?;
        for failure in &failures {
            self.sources[failure.replica] =
                (self.factory)(&self.inner.params, failure.replica);
            self.status[failure.replica] = LaneStatus::Healthy;
            self.events.push(ElasticEvent {
                step: self.inner.step as u64,
                lane: Some(failure.replica),
                kind: ElasticEventKind::Rejoin,
            });
        }
        Ok(())
    }

    fn rollback(&mut self, failed_step: u64) -> Result<()> {
        let (state, from_disk) = if let Some(dir) = self.cfg.snapshot_dir.clone()
        {
            match load_latest_train_state(&dir) {
                Ok(latest) => {
                    for (path, error) in &latest.skipped {
                        self.events.push(ElasticEvent {
                            step: failed_step,
                            lane: None,
                            kind: ElasticEventKind::SnapshotCorrupt {
                                path: path.display().to_string(),
                                error: error.clone(),
                            },
                        });
                    }
                    (latest.state, true)
                }
                Err(disk_err) => match self.memory_snapshot.clone() {
                    Some(state) => {
                        crate::warn!(
                            "disk snapshots unusable ({disk_err:#}); \
                             falling back to the in-memory snapshot"
                        );
                        (state, false)
                    }
                    None => {
                        return Err(disk_err.context(format!(
                            "elastic rollback after step {failed_step} failure"
                        )))
                    }
                },
            }
        } else {
            let state = self
                .memory_snapshot
                .clone()
                .context("elastic rollback with no snapshot taken")?;
            (state, false)
        };
        if state.opt.is_none() {
            // restore_train_state silently keeps the live optimizer
            // state when the snapshot has none; the bit-identical
            // invariant only holds for optimizers with snapshot support
            // (GUM) — say so loudly rather than diverge quietly.
            crate::warn!(
                "elastic rollback without an optimizer snapshot ('{}' \
                 does not implement snapshot/restore): momentum and \
                 projector state survive from the failed attempt, so \
                 the replayed trajectory may diverge from a fault-free \
                 run",
                self.inner.opt.name()
            );
        }
        self.inner
            .restore_train_state(&state)
            .context("elastic rollback: restoring snapshot")?;
        crate::warn!(
            "rolled back from step {failed_step} to step {} (period \
             boundary {}, {} snapshot)",
            state.step,
            self.inner.periods.last_period_start(state.step as usize),
            if from_disk { "disk" } else { "in-memory" }
        );
        self.events.push(ElasticEvent {
            step: failed_step,
            lane: None,
            kind: ElasticEventKind::Rollback {
                to_step: state.step,
                from_disk,
            },
        });
        Ok(())
    }

    /// Flag lanes that straggled well past the median lane time. A
    /// 20 ms floor keeps micro-benchmark noise from tripping it; the
    /// planned `stall:` faults sleep far longer.
    fn note_stragglers(&mut self, step: u64, lanes: &[LaneResult]) {
        if lanes.len() < 2 {
            return;
        }
        let mut times: Vec<f64> =
            lanes.iter().map(|l| l.grad_time_s).collect();
        times.sort_by(|a, b| a.total_cmp(b));
        let median = times[times.len() / 2];
        for lane in lanes {
            if lane.grad_time_s > (4.0 * median).max(0.02) {
                self.events.push(ElasticEvent {
                    step,
                    lane: Some(lane.replica),
                    kind: ElasticEventKind::SlowLane {
                        grad_time_s: lane.grad_time_s,
                        median_s: median,
                    },
                });
            }
        }
    }

    fn render_events(&self) -> String {
        self.events
            .iter()
            .map(|e| format!("  {e}"))
            .collect::<Vec<_>>()
            .join("\n")
    }
}
