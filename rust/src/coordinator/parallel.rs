//! Data-parallel training subsystem: replica lanes over sharded batch
//! streams, micro-batch gradient accumulation, and a **deterministic
//! fixed-order tree all-reduce** before the single optimizer step.
//!
//! The contract that makes data-parallel GUM trustworthy:
//!
//! 1. **Fixed reduction order.** Every gradient sum — within a lane's
//!    accumulation window and across lanes — is a pairwise tree whose
//!    combine order is a pure function of the operand *count*, never of
//!    thread scheduling. The combined gradient is bit-identical under
//!    any `GUM_THREADS`, and exactly equal (bitwise) between an
//!    `R`-replica run and a 1-replica run over the same global batch
//!    whenever the per-lane window is a power of two (within float
//!    round-off otherwise).
//! 2. **One `begin_period` per period, on the combined gradient.** GUM's
//!    layerwise sampling (Lemma 1) and projector refresh observe exactly
//!    the summed gradient they would sequentially, so the sampling
//!    sequence is independent of the replica count.
//! 3. **Resumable mid-period.** [`TrainState`] captures step counter,
//!    parameters, optimizer snapshot (projector + momentum + sampler),
//!    lane stream positions, and the coordinator RNG; a restored session
//!    replays bit-identically.
//!
//! Compute fan-out uses the in-tree thread pool ([`crate::thread`]):
//! [`parallel_lane_grads`] maps lanes across workers (any nested GEMM
//! parallelism is safe thanks to the pool's help-while-waiting scheme),
//! while [`sequential_lane_grads`] drives the same accumulation on the
//! calling thread for gradient engines that cannot cross threads (the
//! single-client PJRT runner). Both paths produce identical bytes.

use std::sync::{Arc, Mutex};

use anyhow::{ensure, Result};

use crate::data::corpus::{CorpusSpec, SyntheticCorpus};
use crate::data::loader::{Batch, BatchLoader};
use crate::data::tokenizer::ByteTokenizer;
use crate::linalg::Matrix;
use crate::model::{BlockKind, ParamStore};
use crate::optim::{
    Gum, OptSnapshot, Optimizer, PendingRefresh, PeriodSchedule, Projector,
    RankState, RefreshPipeline, RefreshPipelineMode, StepCtx,
};
use crate::rng::{derive_seed, Pcg};
use crate::testing::faults::{describe_panic, FaultPlan, InjectedFault};
use crate::thread::parallel_map;
use crate::util::timer::Timer;

use super::scheduler::{LrSchedule, PeriodScheduler, PeriodSnapshot};

/// Default document stride between lane shards under
/// [`ShardMode::DocPartition`] — far beyond what any run consumes, and
/// clear of the held-out validation offset (1M) for lane 0.
pub const DEFAULT_DOC_STRIDE: u64 = 10_000_000;

/// How replica lanes carve up the document stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardMode {
    /// All lanes share one global micro-batch stream; lane `r` owns the
    /// contiguous window `[r·A, (r+1)·A)` of each global step's `R·A`
    /// micro-batches and skips the rest. A 1-replica run with
    /// `accum_steps = R·A` consumes *identical tokens* — the layout the
    /// equivalence suite locks in. Skip-replay costs each lane the
    /// generation of the other lanes' batches (O(R²·A) total data work
    /// per step), so this is an opt-in paired-comparison mode, not the
    /// default.
    Interleaved,
    /// Each lane streams its own disjoint document range
    /// (`doc_offset = r · doc_stride`): no skip replay, the production
    /// (and default) layout for throughput.
    DocPartition,
}

impl ShardMode {
    pub fn parse(s: &str) -> Result<ShardMode> {
        match s {
            "interleaved" => Ok(ShardMode::Interleaved),
            "docs" | "doc-partition" => Ok(ShardMode::DocPartition),
            other => anyhow::bail!(
                "unknown shard mode '{other}' (expected interleaved|docs)"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ShardMode::Interleaved => "interleaved",
            ShardMode::DocPartition => "docs",
        }
    }
}

/// What each replica lane ships through the tree all-reduce.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReduceMode {
    /// The classic path: every block's dense m×n gradient.
    #[default]
    Dense,
    /// GUM's compressed path: per projectable block the projected
    /// gradient (`PᵀG`, r×n — or `G·P`, m×r for right-oriented
    /// projectors), except for blocks whose full-rank Bernoulli draw is
    /// set this period, dense blocks, and the refresh-trigger/boundary
    /// steps whose gradients feed the next SVD refresh — those ship
    /// dense (see [`ReducePlan::plan`]). Requires a GUM optimizer;
    /// anything else silently reduces dense.
    LowRank,
}

impl ReduceMode {
    pub fn parse(s: &str) -> Result<ReduceMode> {
        match s {
            "dense" => Ok(ReduceMode::Dense),
            "lowrank" | "low-rank" => Ok(ReduceMode::LowRank),
            other => anyhow::bail!(
                "unknown reduce mode '{other}' (expected dense|lowrank)"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ReduceMode::Dense => "dense",
            ReduceMode::LowRank => "lowrank",
        }
    }
}

/// Per-block wire tag for one global step's all-reduce: what each lane
/// puts on the (future) wire for this block. This is the format the
/// multi-process transport will serialize — one tag byte per block, then
/// the payload matrix.
#[derive(Debug, Clone)]
pub enum BlockPayload {
    /// The dense m×n gradient.
    Dense,
    /// The projected gradient under this period's committed basis. Every
    /// lane holds the same `P` (refreshed only inside the boundary
    /// commit, which this plan never compresses across), so the payloads
    /// sum in the same fixed tree order as the dense matrices would.
    LowRank(Projector),
}

/// Payload accounting for one global step's reduce, per lane.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReduceStats {
    /// Bytes one lane would ship under [`ReduceMode::Dense`].
    pub dense_bytes: usize,
    /// Bytes one lane ships under this plan.
    pub payload_bytes: usize,
    /// Blocks shipped as [`BlockPayload::LowRank`].
    pub lowrank_blocks: usize,
    /// Blocks shipped dense (dense-kind, full-rank-sampled, or forced
    /// by a boundary/trigger step).
    pub dense_blocks: usize,
}

impl ReduceStats {
    /// Dense-over-payload byte ratio (1.0 for an all-dense plan).
    pub fn compression(&self) -> f64 {
        self.dense_bytes as f64 / (self.payload_bytes as f64).max(1.0)
    }
}

/// The per-block payload decision for one global step, computed on the
/// coordinator from *committed* optimizer state before the lanes'
/// results are combined.
#[derive(Debug, Clone)]
pub struct ReducePlan {
    payloads: Vec<BlockPayload>,
}

impl ReducePlan {
    /// The all-dense plan (what [`ReduceMode::Dense`] always uses).
    pub fn dense(n_blocks: usize) -> ReducePlan {
        ReducePlan {
            payloads: vec![BlockPayload::Dense; n_blocks],
        }
    }

    /// Decide each block's payload for `step`. The boundary-handoff
    /// rule that keeps the committed trajectory equal to the dense
    /// reduce:
    ///
    /// - **Period-boundary steps ship dense.** `begin_period` (period 0
    ///   or a non-prepared handoff) rebuilds projectors from the
    ///   boundary gradient, and the full-rank mask resamples *before*
    ///   `Optimizer::step` consumes it — the plan would be stale.
    /// - **Refresh-trigger steps ship dense.** The pipeline snapshots
    ///   this step's combined gradient for the next boundary's SVD
    ///   refresh ([`RefreshPipeline::observe`]); a projected gradient
    ///   cannot seed it.
    /// - **Full-rank-sampled blocks ship dense** — GUM's compensated
    ///   update (eq. 2) consumes `G` itself, not `PᵀG`.
    /// - Everything else projectable with a committed basis ships
    ///   [`BlockPayload::LowRank`] under that basis; the bases change
    ///   only inside the boundary commit (the `PreparedRefresh` handoff
    ///   point), which the first two rules never compress across, so
    ///   every lane agrees on `P`.
    ///
    /// Only GUM exposes the full-rank mask this plan needs; any other
    /// optimizer gets the all-dense plan.
    pub fn plan(
        mode: ReduceMode,
        step: usize,
        periods: &PeriodScheduler,
        opt: &dyn Optimizer,
        refresh_lead: usize,
        params: &ParamStore,
    ) -> ReducePlan {
        let n_blocks = params.blocks.len();
        if mode == ReduceMode::Dense
            || periods.is_period_start(step)
            || periods.refresh_trigger(step, refresh_lead).is_some()
        {
            return ReducePlan::dense(n_blocks);
        }
        let Some(gum) =
            opt.as_any().and_then(|a| a.downcast_ref::<Gum>())
        else {
            return ReducePlan::dense(n_blocks);
        };
        let Some(projectors) = opt.projectors() else {
            return ReducePlan::dense(n_blocks);
        };
        // The mask covers projectable blocks only, in canonical order.
        let mask = gum.full_rank_mask();
        let mut next_projectable = 0usize;
        let payloads = params
            .blocks
            .iter()
            .zip(&projectors)
            .map(|(block, proj)| {
                let full_rank = match block.kind {
                    BlockKind::Dense => true,
                    BlockKind::Projectable => {
                        let f = mask
                            .get(next_projectable)
                            .copied()
                            .unwrap_or(true);
                        next_projectable += 1;
                        f
                    }
                };
                match (proj, full_rank) {
                    (Some(p), false) => BlockPayload::LowRank(p.clone()),
                    _ => BlockPayload::Dense,
                }
            })
            .collect();
        ReducePlan { payloads }
    }

    /// The per-block payload tags, aligned with `params.blocks`.
    pub fn payloads(&self) -> &[BlockPayload] {
        &self.payloads
    }

    pub fn is_all_dense(&self) -> bool {
        self.payloads
            .iter()
            .all(|p| matches!(p, BlockPayload::Dense))
    }

    /// Payload accounting against the given per-block gradient shapes
    /// (one lane's worth of bytes).
    pub fn stats(&self, grads: &[Matrix]) -> ReduceStats {
        assert_eq!(self.payloads.len(), grads.len(), "plan arity");
        let mut stats = ReduceStats {
            dense_bytes: 0,
            payload_bytes: 0,
            lowrank_blocks: 0,
            dense_blocks: 0,
        };
        for (payload, g) in self.payloads.iter().zip(grads) {
            let dense = g.numel() * std::mem::size_of::<f32>();
            stats.dense_bytes += dense;
            match payload {
                BlockPayload::Dense => {
                    stats.payload_bytes += dense;
                    stats.dense_blocks += 1;
                }
                BlockPayload::LowRank(p) => {
                    let (r, c) = p.projected_shape(g.rows, g.cols);
                    stats.payload_bytes +=
                        r * c * std::mem::size_of::<f32>();
                    stats.lowrank_blocks += 1;
                }
            }
        }
        stats
    }
}

/// Replication layout for one training run.
#[derive(Debug, Clone)]
pub struct ParallelConfig {
    /// Data-parallel replica lanes (1 = the classic sequential trainer).
    pub replicas: usize,
    /// Micro-batches accumulated per lane per global step.
    pub accum_steps: usize,
    pub shard_mode: ShardMode,
    /// Documents between lane starts under [`ShardMode::DocPartition`].
    pub doc_stride: u64,
}

impl Default for ParallelConfig {
    fn default() -> Self {
        ParallelConfig {
            replicas: 1,
            accum_steps: 1,
            shard_mode: ShardMode::DocPartition,
            doc_stride: DEFAULT_DOC_STRIDE,
        }
    }
}

impl ParallelConfig {
    /// Micro-batches per global step (`R·A`).
    pub fn global_microbatches(&self) -> usize {
        self.replicas * self.accum_steps
    }
}

/// Per-lane batch streams for a replicated run. Lane `r` owns its own
/// [`BatchLoader`]; `next_global` yields the micro-batches of one global
/// step, lane-major, deterministically.
pub struct ShardedBatcher {
    lanes: Vec<BatchLoader>,
    cfg: ParallelConfig,
    tokens_per_micro: usize,
}

impl ShardedBatcher {
    pub fn new(
        corpus: &CorpusSpec,
        tokenizer: &ByteTokenizer,
        batch: usize,
        seq: usize,
        cfg: &ParallelConfig,
    ) -> ShardedBatcher {
        assert!(cfg.replicas >= 1, "at least one replica");
        assert!(cfg.accum_steps >= 1, "at least one micro-batch per lane");
        let mut lanes = Vec::with_capacity(cfg.replicas);
        for r in 0..cfg.replicas {
            let loader = BatchLoader::new(
                SyntheticCorpus::new(corpus.clone()),
                tokenizer.clone(),
                batch,
                seq,
            );
            let mut loader = match cfg.shard_mode {
                ShardMode::Interleaved => loader,
                ShardMode::DocPartition => {
                    loader.with_doc_offset(r as u64 * cfg.doc_stride)
                }
            };
            if cfg.shard_mode == ShardMode::Interleaved {
                // Advance to this lane's window inside global step 0.
                loader.skip_batches(r * cfg.accum_steps);
            }
            lanes.push(loader);
        }
        ShardedBatcher {
            lanes,
            cfg: cfg.clone(),
            tokens_per_micro: batch * seq,
        }
    }

    pub fn replicas(&self) -> usize {
        self.cfg.replicas
    }

    pub fn accum_steps(&self) -> usize {
        self.cfg.accum_steps
    }

    /// Tokens consumed by one global step across all lanes.
    pub fn tokens_per_global_step(&self) -> usize {
        self.tokens_per_micro * self.cfg.global_microbatches()
    }

    /// Micro-batches for one global step: `out[r][a]` is lane `r`'s
    /// `a`-th accumulation micro-batch. Pure data movement on the
    /// coordinator thread — deterministic by construction.
    pub fn next_global(&mut self) -> Vec<Vec<Batch>> {
        let accum = self.cfg.accum_steps;
        let skip = match self.cfg.shard_mode {
            ShardMode::Interleaved => (self.cfg.replicas - 1) * accum,
            ShardMode::DocPartition => 0,
        };
        self.lanes
            .iter_mut()
            .map(|lane| {
                let batches: Vec<Batch> =
                    (0..accum).map(|_| lane.next_batch()).collect();
                lane.skip_batches(skip);
                batches
            })
            .collect()
    }

    /// Per-lane stream positions for checkpointing.
    pub fn stream_state(&self) -> Vec<(u64, Vec<i32>)> {
        self.lanes.iter().map(|l| l.stream_state()).collect()
    }

    /// Restore positions captured by [`ShardedBatcher::stream_state`].
    pub fn restore_stream_state(
        &mut self,
        states: Vec<(u64, Vec<i32>)>,
    ) -> Result<()> {
        ensure!(
            states.len() == self.lanes.len(),
            "checkpoint has {} lanes, run has {}",
            states.len(),
            self.lanes.len()
        );
        for (lane, (next_doc, buffer)) in self.lanes.iter_mut().zip(states) {
            lane.restore_stream_state(next_doc, buffer);
        }
        Ok(())
    }
}

/// Pairwise tree sum in a fixed order that is a pure function of
/// `parts.len()` — never of thread count or scheduling: stride-doubling
/// combines `acc[i] += acc[i + s]` for `i ≡ 0 (mod 2s)`.
pub fn pairwise_tree_sum(mut parts: Vec<Matrix>) -> Matrix {
    assert!(!parts.is_empty(), "tree sum of zero parts");
    let n = parts.len();
    let mut stride = 1;
    while stride < n {
        let mut i = 0;
        while i + stride < n {
            let (lo, hi) = parts.split_at_mut(i + stride);
            lo[i].add_scaled_in_place(1.0, &hi[0]);
            i += 2 * stride;
        }
        stride *= 2;
    }
    parts.swap_remove(0)
}

/// Deterministic tree all-reduce across replicas, parallelized over
/// parameter blocks: each block's reduction order depends only on the
/// replica count, so the result is bit-identical under any
/// `GUM_THREADS` and matches the sequential reduction exactly.
pub fn tree_all_reduce(per_replica: &[Vec<Matrix>]) -> Vec<Matrix> {
    assert!(!per_replica.is_empty(), "all-reduce over zero replicas");
    let n_blocks = per_replica[0].len();
    for (r, grads) in per_replica.iter().enumerate() {
        assert_eq!(grads.len(), n_blocks, "replica {r} gradient arity");
    }
    parallel_map(n_blocks, |b| {
        pairwise_tree_sum(per_replica.iter().map(|g| g[b].clone()).collect())
    })
}

/// A per-replica gradient engine: (params, micro-batch) → (loss, grads).
///
/// Implementations must be deterministic pure functions of their inputs
/// plus construction-time state — the equivalence and determinism suites
/// rely on replayed micro-batches producing identical gradients. `Send`
/// lets lanes fan out on the in-tree thread pool via
/// [`parallel_lane_grads`]; engines that cannot cross threads (the
/// single-client PJRT runner) go through [`sequential_lane_grads`].
pub trait GradSource: Send {
    fn grad(
        &mut self,
        params: &ParamStore,
        batch: &Batch,
    ) -> Result<(f32, Vec<Matrix>)>;

    /// Called by the coordinator on every lane before a global step's
    /// fan-out, with the step about to be computed. Sources that carry
    /// step-indexed state (the fault-injection arm of
    /// [`SyntheticGradSource`]) track it here; pure sources ignore it.
    fn begin_step(&mut self, _step: u64) {}
}

/// Deterministic synthetic gradient engine: a separable quadratic pull
/// toward per-block targets plus a data-dependent perturbation derived
/// from a hash of the micro-batch tokens. Needs no AOT artifacts — this
/// is what the equivalence/determinism/resume tests and the
/// replica-scaling bench drive.
#[derive(Debug, Clone)]
pub struct SyntheticGradSource {
    targets: Vec<Matrix>,
    /// Scale of the token-dependent gradient term.
    pub data_scale: f32,
    /// Extra single-threaded FLOP rounds per block, emulating a heavier
    /// model body (single-threaded on purpose: the replica-scaling bench
    /// measures lane parallelism, not nested GEMM parallelism).
    pub work: usize,
    /// Fault-injection arm: when armed via
    /// [`SyntheticGradSource::with_faults`], every `grad` call first
    /// fires the plan's faults for `(lane, step)` — stalls sleep, kills
    /// unwind with a typed [`InjectedFault`] payload from *inside* the
    /// gradient engine on whatever pool thread runs the lane, the same
    /// crash site a real engine failure has.
    faults: Option<Arc<FaultPlan>>,
    lane: usize,
    step: u64,
}

impl SyntheticGradSource {
    /// Targets are derived from the block *shapes* and `seed`, so every
    /// lane constructed over the same parameter store agrees.
    pub fn new(params: &ParamStore, seed: u64) -> SyntheticGradSource {
        let targets = params
            .blocks
            .iter()
            .map(|b| {
                let mut rng =
                    Pcg::new(derive_seed(seed, &format!("target/{}", b.name)));
                Matrix::randn(b.value.rows, b.value.cols, 1.0, &mut rng)
            })
            .collect();
        SyntheticGradSource {
            targets,
            data_scale: 0.05,
            work: 0,
            faults: None,
            lane: 0,
            step: 0,
        }
    }

    /// Arm this lane's copy with a shared fault plan. The plan's fired
    /// set is shared through the `Arc`, so a fault stays consumed across
    /// lane rebuilds and recovery replays.
    pub fn with_faults(
        mut self,
        lane: usize,
        plan: Arc<FaultPlan>,
    ) -> SyntheticGradSource {
        self.lane = lane;
        self.faults = Some(plan);
        self
    }

    fn token_hash(batch: &Batch) -> u64 {
        let mut h: u64 = 0x9e37_79b9_7f4a_7c15;
        for &t in &batch.tokens {
            h ^= t as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3).rotate_left(17);
        }
        h
    }

    fn entry_noise(h: u64, block: usize, entry: usize) -> f32 {
        let mut x = h ^ ((block as u64) << 32) ^ entry as u64;
        x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        x ^= x >> 31;
        let unit = (x >> 40) as f32 / (1u64 << 24) as f32; // [0, 1)
        2.0 * unit - 1.0
    }
}

impl GradSource for SyntheticGradSource {
    fn grad(
        &mut self,
        params: &ParamStore,
        batch: &Batch,
    ) -> Result<(f32, Vec<Matrix>)> {
        if let Some(plan) = &self.faults {
            plan.fire(self.lane, self.step);
        }
        ensure!(
            params.blocks.len() == self.targets.len(),
            "synthetic source built for {} blocks, got {}",
            self.targets.len(),
            params.blocks.len()
        );
        let h = Self::token_hash(batch);
        let mut loss = 0.0f64;
        let mut grads = Vec::with_capacity(params.blocks.len());
        for (i, (block, target)) in
            params.blocks.iter().zip(&self.targets).enumerate()
        {
            let mut g = block.value.sub(target);
            let numel = g.numel() as f64;
            loss += g
                .data
                .iter()
                .map(|v| (*v as f64) * (*v as f64))
                .sum::<f64>()
                / (2.0 * numel);
            for (j, v) in g.data.iter_mut().enumerate() {
                *v += self.data_scale * Self::entry_noise(h, i, j);
            }
            if self.work > 0 {
                let mut acc = 0.0f32;
                for _ in 0..self.work {
                    for v in &g.data {
                        acc = acc.mul_add(1.000_000_1, *v);
                    }
                }
                std::hint::black_box(acc);
            }
            grads.push(g);
        }
        Ok(((loss / params.blocks.len() as f64) as f32, grads))
    }

    fn begin_step(&mut self, step: u64) {
        self.step = step;
    }
}

/// One lane's contribution to a global step.
#[derive(Debug)]
pub struct LaneResult {
    pub replica: usize,
    /// Mean micro-batch loss over the lane's accumulation window.
    pub loss: f64,
    /// Pairwise-tree sum of the lane's micro-batch gradients.
    pub grads: Vec<Matrix>,
    pub micro_batches: usize,
    pub grad_time_s: f64,
    pub tokens: usize,
}

/// Per-lane throughput stats surfaced in [`GlobalGrad`].
#[derive(Debug, Clone, Copy)]
pub struct LaneStat {
    pub replica: usize,
    pub loss: f64,
    pub grad_time_s: f64,
    pub tokens: usize,
}

/// The combined result of one global step's gradient computation.
#[derive(Debug)]
pub struct GlobalGrad {
    /// Mean micro-batch loss across the global batch.
    pub loss: f64,
    /// Mean micro-batch gradient per block (canonical order).
    pub grads: Vec<Matrix>,
    pub lanes: Vec<LaneStat>,
    pub micro_batches: usize,
    pub tokens: usize,
}

fn lane_grad_with<F>(
    replica: usize,
    params: &ParamStore,
    batches: &[Batch],
    mut f: F,
) -> Result<LaneResult>
where
    F: FnMut(&ParamStore, &Batch) -> Result<(f32, Vec<Matrix>)>,
{
    let timer = Timer::start();
    ensure!(!batches.is_empty(), "lane {replica}: zero micro-batches");
    let mut loss_sum = 0.0f64;
    let mut tokens = 0usize;
    let mut micro: Vec<Vec<Matrix>> = Vec::with_capacity(batches.len());
    for batch in batches {
        let (loss, grads) = f(params, batch)?;
        if let Some(first) = micro.first() {
            ensure!(
                grads.len() == first.len(),
                "lane {replica}: gradient arity changed mid-window"
            );
        }
        loss_sum += loss as f64;
        tokens += batch.token_count();
        micro.push(grads);
    }
    let n_blocks = micro[0].len();
    // Pairwise tree over the accumulation window: a global step's R·A
    // micro-gradients reduce in the same order however the window is
    // split across replicas (bit-exactly so for power-of-two windows).
    let grads = (0..n_blocks)
        .map(|blk| {
            pairwise_tree_sum(micro.iter().map(|g| g[blk].clone()).collect())
        })
        .collect();
    Ok(LaneResult {
        replica,
        loss: loss_sum / batches.len() as f64,
        grads,
        micro_batches: batches.len(),
        grad_time_s: timer.elapsed_s(),
        tokens,
    })
}

/// Fan lanes out across the thread pool. Lane results come back in
/// replica order regardless of scheduling, and every reduction order is
/// fixed, so the output is byte-identical to [`sequential_lane_grads`].
pub fn parallel_lane_grads<S: GradSource>(
    sources: &mut [S],
    params: &ParamStore,
    batches: &[Vec<Batch>],
) -> Result<Vec<LaneResult>> {
    ensure!(
        sources.len() == batches.len(),
        "{} gradient sources for {} lanes",
        sources.len(),
        batches.len()
    );
    let cells: Vec<Mutex<&mut S>> = sources.iter_mut().map(Mutex::new).collect();
    parallel_map(batches.len(), |r| {
        let mut source = cells[r].lock().unwrap();
        lane_grad_with(r, params, &batches[r], |p, b| source.grad(p, b))
    })
    .into_iter()
    .collect()
}

/// One lane's failure under supervision: which replica died, whether
/// the unwind carried a planned [`InjectedFault`] (vs. a real bug), and
/// the rendered message.
#[derive(Debug, Clone)]
pub struct LaneFailure {
    pub replica: usize,
    pub injected: bool,
    pub message: String,
}

/// [`parallel_lane_grads`] with per-lane panic isolation: each lane's
/// accumulation runs under `catch_unwind`, so one lane unwinding —
/// injected kill or real bug — yields a [`LaneFailure`] for that lane
/// while every other lane's [`LaneResult`] survives. The supervision
/// layer ([`crate::coordinator::elastic`]) fences the failed lanes and
/// rolls the step back; the surviving lanes' bytes are identical to an
/// unsupervised run, so supervision costs nothing on the happy path.
pub fn supervised_lane_grads<S: GradSource>(
    sources: &mut [S],
    params: &ParamStore,
    batches: &[Vec<Batch>],
) -> Result<Vec<std::result::Result<LaneResult, LaneFailure>>> {
    ensure!(
        sources.len() == batches.len(),
        "{} gradient sources for {} lanes",
        sources.len(),
        batches.len()
    );
    let cells: Vec<Mutex<&mut S>> = sources.iter_mut().map(Mutex::new).collect();
    Ok(parallel_map(batches.len(), |r| {
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || {
                let mut source = cells[r].lock().unwrap();
                lane_grad_with(r, params, &batches[r], |p, b| source.grad(p, b))
            },
        ));
        match outcome {
            Ok(Ok(result)) => Ok(result),
            Ok(Err(err)) => Err(LaneFailure {
                replica: r,
                injected: err.downcast_ref::<InjectedFault>().is_some(),
                message: format!("{err:#}"),
            }),
            Err(payload) => {
                let (injected, message) = describe_panic(payload.as_ref());
                Err(LaneFailure {
                    replica: r,
                    injected,
                    message,
                })
            }
        }
    }))
}

/// Drive every lane's accumulation on the calling thread — the PJRT
/// path, where a single runtime client serves all lanes in replica
/// order. Byte-identical to [`parallel_lane_grads`].
pub fn sequential_lane_grads<F>(
    params: &ParamStore,
    batches: &[Vec<Batch>],
    mut grad_fn: F,
) -> Result<Vec<LaneResult>>
where
    F: FnMut(usize, &ParamStore, &Batch) -> Result<(f32, Vec<Matrix>)>,
{
    batches
        .iter()
        .enumerate()
        .map(|(r, lane)| {
            lane_grad_with(r, params, lane, |p, b| grad_fn(r, p, b))
        })
        .collect()
}

/// Tree-combine lane gradients and scale to the mean micro-batch
/// gradient (the scale a 1-micro-batch step sees). The divide is a
/// single scalar multiply after the fixed-order reduction, so
/// replica-count splits of the same global batch agree bit-for-bit
/// whenever the tree shapes align (power-of-two windows).
pub fn combine_lanes(lanes: Vec<LaneResult>) -> GlobalGrad {
    assert!(!lanes.is_empty(), "combine of zero lanes");
    let plan = ReducePlan::dense(lanes[0].grads.len());
    combine_lanes_compressed(lanes, &plan).0
}

/// [`combine_lanes`] with a per-block payload plan: blocks the plan tags
/// [`BlockPayload::LowRank`] are projected per lane *before* the tree
/// sum (each lane ships r×n instead of m×n) and lifted back through the
/// shared basis after it. The tree order over lanes is identical for
/// both payload kinds, so within one plan the result is a pure function
/// of the lane gradients — bit-identical across thread widths and
/// replays. Also returns the per-lane payload accounting.
pub fn combine_lanes_compressed(
    lanes: Vec<LaneResult>,
    plan: &ReducePlan,
) -> (GlobalGrad, ReduceStats) {
    assert!(!lanes.is_empty(), "combine of zero lanes");
    let micro_batches: usize = lanes.iter().map(|l| l.micro_batches).sum();
    let tokens: usize = lanes.iter().map(|l| l.tokens).sum();
    let loss = lanes
        .iter()
        .map(|l| l.loss * l.micro_batches as f64)
        .sum::<f64>()
        / micro_batches as f64;
    let stats: Vec<LaneStat> = lanes
        .iter()
        .map(|l| LaneStat {
            replica: l.replica,
            loss: l.loss,
            grad_time_s: l.grad_time_s,
            tokens: l.tokens,
        })
        .collect();
    let per_replica: Vec<Vec<Matrix>> =
        lanes.into_iter().map(|l| l.grads).collect();
    let n_blocks = per_replica[0].len();
    for (r, grads) in per_replica.iter().enumerate() {
        assert_eq!(grads.len(), n_blocks, "replica {r} gradient arity");
    }
    assert_eq!(plan.payloads.len(), n_blocks, "plan arity");
    let reduce_stats = plan.stats(&per_replica[0]);
    let mut grads = parallel_map(n_blocks, |b| match &plan.payloads[b] {
        BlockPayload::Dense => pairwise_tree_sum(
            per_replica.iter().map(|g| g[b].clone()).collect(),
        ),
        BlockPayload::LowRank(p) => {
            let reduced = pairwise_tree_sum(
                per_replica.iter().map(|g| p.project(&g[b])).collect(),
            );
            p.project_back(&reduced)
        }
    });
    let inv = 1.0 / micro_batches as f32;
    for g in &mut grads {
        g.scale_in_place(inv);
    }
    (
        GlobalGrad {
            loss,
            grads,
            lanes: stats,
            micro_batches,
            tokens,
        },
        reduce_stats,
    )
}

/// Checkpoint ↔ model layout compatibility: same block names and
/// shapes, in the same canonical order. Checked at the resume boundary
/// so a mismatched checkpoint fails with a clear error instead of a
/// deep GEMM panic (or silent divergence) later.
pub fn ensure_same_layout(
    checkpoint: &ParamStore,
    model: &ParamStore,
) -> Result<()> {
    ensure!(
        checkpoint.blocks.len() == model.blocks.len(),
        "checkpoint has {} blocks, model has {}",
        checkpoint.blocks.len(),
        model.blocks.len()
    );
    for (c, m) in checkpoint.blocks.iter().zip(&model.blocks) {
        ensure!(
            c.name == m.name && c.shape == m.shape,
            "checkpoint block '{}' {:?} does not match model block '{}' {:?}",
            c.name,
            c.shape,
            m.name,
            m.shape
        );
    }
    Ok(())
}

/// Everything needed to resume a run mid-period: step counter,
/// parameters, optimizer snapshot (projector + momentum + sampler),
/// lane stream positions (train + held-out validation), and the
/// coordinator RNG. Serialized by
/// `coordinator::checkpoint::{save,load}_train_state`.
#[derive(Debug, Clone)]
pub struct TrainState {
    pub step: u64,
    pub params: ParamStore,
    pub opt: Option<OptSnapshot>,
    /// `Pcg::to_raw()` of the coordinator RNG.
    pub rng_raw: (u64, u64, Option<f64>),
    /// `(next_doc, carry buffer)` per lane.
    pub lanes: Vec<(u64, Vec<i32>)>,
    /// Validation-loader position (trainer runs; `None` for sessions
    /// without a held-out stream).
    pub val_lane: Option<(u64, Vec<i32>)>,
    /// A refresh-pipeline job that was armed or in flight when the
    /// snapshot was taken, serialized by resolution (the bases are a
    /// pure function of an already-captured gradient, so resolving at
    /// snapshot time is the deterministic form of "serialize in-flight
    /// refresh jobs"). `None` when the pipeline was idle.
    pub pending_refresh: Option<PendingRefresh>,
    /// Adaptive rank-schedule controller state (per-block ranks +
    /// hysteresis pressure) at snapshot time; `None` for fixed-rank
    /// runs, so their serialized form is unchanged.
    pub rank_state: Option<RankState>,
    /// Adaptive period-schedule state (boundary pair + current period +
    /// controller bookkeeping) at snapshot time; `None` for fixed-K
    /// runs, so their serialized form is unchanged — the boundary state
    /// is then re-derived from `step % K` on restore.
    pub period_state: Option<PeriodSnapshot>,
}

/// A self-contained data-parallel optimization session over any
/// [`GradSource`] family: the exact global-step semantics the trainer
/// uses, minus the PJRT runtime — so the equivalence, determinism, and
/// resume properties are testable (and benchable) without AOT artifacts.
pub struct ParallelSession {
    pub params: ParamStore,
    pub opt: Box<dyn Optimizer>,
    pub batcher: ShardedBatcher,
    pub periods: PeriodScheduler,
    pub schedule: LrSchedule,
    pub rng: Pcg,
    pub step: usize,
    /// Off-critical-path projector refresh (async by default; see
    /// `optim::refresh_pipeline`). Swap to sync with
    /// [`ParallelSession::set_refresh_mode`] for bisection.
    pub refresh: RefreshPipeline,
    /// What the lanes ship through the tree all-reduce (dense by
    /// default; see [`ReduceMode`]).
    pub reduce: ReduceMode,
    /// Payload accounting for the most recent committed global step
    /// (`None` before the first step).
    pub last_reduce: Option<ReduceStats>,
}

impl ParallelSession {
    pub fn new(
        params: ParamStore,
        opt: Box<dyn Optimizer>,
        batcher: ShardedBatcher,
        period_k: usize,
        schedule: LrSchedule,
        seed: u64,
    ) -> ParallelSession {
        ParallelSession {
            params,
            opt,
            batcher,
            periods: PeriodScheduler::new(period_k),
            schedule,
            rng: Pcg::new(derive_seed(seed, "trainer")),
            step: 0,
            refresh: RefreshPipeline::new(
                RefreshPipelineMode::default(),
                derive_seed(seed, "refresh"),
            ),
            reduce: ReduceMode::default(),
            last_reduce: None,
        }
    }

    /// Select the reduce payload mode. Call before the first step so
    /// the whole run (and any fault replay) plans payloads the same
    /// way.
    pub fn set_reduce_mode(&mut self, mode: ReduceMode) {
        self.reduce = mode;
    }

    /// The payload plan for the *current* step, computed from committed
    /// optimizer/scheduler state only — so a rolled-back attempt and
    /// its replay (same committed state) plan identically.
    pub fn reduce_plan(&self) -> ReducePlan {
        ReducePlan::plan(
            self.reduce,
            self.step,
            &self.periods,
            &*self.opt,
            self.refresh.lead(),
            &self.params,
        )
    }

    /// Select the refresh-pipeline mode (sync = refresh on the critical
    /// path, async = overlapped). Sync and async commit bit-identical
    /// trajectories; call before the first step so the whole run uses
    /// one mode.
    pub fn set_refresh_mode(&mut self, mode: RefreshPipelineMode) {
        self.refresh.set_mode(mode);
    }

    /// Attach the period schedule (fixed keeps the constructor's K;
    /// adaptive wires a drift-driven [`PeriodScheduler`] controller).
    /// Call before the first step — the schedule governs the whole
    /// boundary sequence.
    pub fn set_period_schedule(&mut self, schedule: &PeriodSchedule) {
        self.periods =
            PeriodScheduler::with_schedule(self.periods.base_period(), schedule);
    }

    /// One global step: pump the lanes, fan the gradient computation out
    /// on the pool, tree-combine, and apply a single optimizer step
    /// (running `begin_period` first on period boundaries).
    pub fn global_step<S: GradSource>(
        &mut self,
        sources: &mut [S],
    ) -> Result<GlobalGrad> {
        for source in sources.iter_mut() {
            source.begin_step(self.step as u64);
        }
        let batches = self.batcher.next_global();
        let lanes = parallel_lane_grads(sources, &self.params, &batches)?;
        let plan = self.reduce_plan();
        let (global, stats) = combine_lanes_compressed(lanes, &plan);
        self.last_reduce = Some(stats);
        self.apply(&global);
        Ok(global)
    }

    /// Commit one combined gradient: the refresh-pipeline handoff +
    /// `begin_period` on boundaries, the trigger-step observation, then
    /// the optimizer step. Crate-visible so the elastic supervisor
    /// (`coordinator::elastic`) commits through the exact same path.
    pub(crate) fn apply(&mut self, global: &GlobalGrad) {
        if self.periods.is_period_start(self.step) {
            let taken = self.refresh.take(self.step);
            let decision =
                taken.as_ref().and_then(|p| p.period_state.clone());
            match taken {
                Some(prepared) => self.opt.begin_period_prepared(
                    &self.params,
                    &global.grads,
                    &mut self.rng,
                    prepared,
                ),
                // Period 0 (no earlier snapshot exists) and
                // non-projected optimizers refresh synchronously from
                // the boundary gradient, as before the pipeline.
                None => self.opt.begin_period(
                    &self.params,
                    &global.grads,
                    &mut self.rng,
                ),
            }
            // Lay down the next boundary: the current period length
            // under the fixed schedule, or whatever the refresh job's
            // drift observation decided under the adaptive one.
            self.periods.commit_boundary(self.step, decision.as_ref());
        }
        // Arm the next boundary's refresh when this step is its trigger
        // — the job overlaps with the remaining work of this step and
        // the next step's gradient fan-out.
        self.refresh
            .observe(self.step, &self.periods, &*self.opt, &global.grads);
        self.opt.step(
            &mut self.params,
            &global.grads,
            &StepCtx {
                lr: self.schedule.at(self.step) as f32,
                step: self.step,
            },
        );
        self.step += 1;
    }

    /// Snapshot the full resumable state (valid mid-period). Resolves
    /// any armed/in-flight refresh job first — the serialized form of an
    /// in-flight refresh is its (deterministic) result.
    pub fn train_state(&mut self) -> TrainState {
        let pending_refresh = self.refresh.resolve_pending();
        TrainState {
            step: self.step as u64,
            params: self.params.clone(),
            opt: self.opt.snapshot(),
            rng_raw: self.rng.to_raw(),
            lanes: self.batcher.stream_state(),
            val_lane: None,
            pending_refresh,
            rank_state: self.opt.rank_state(),
            period_state: self.periods.snapshot(),
        }
    }

    /// Restore state captured by [`ParallelSession::train_state`] into a
    /// session built with the same configuration. Any currently armed or
    /// in-flight refresh job is discarded in favor of the snapshot's
    /// (rollback must never let a failed attempt's bases leak into the
    /// replay).
    pub fn restore_train_state(&mut self, state: &TrainState) -> Result<()> {
        ensure_same_layout(&state.params, &self.params)?;
        self.step = state.step as usize;
        self.params = state.params.clone();
        if let Some(snap) = &state.opt {
            self.opt.restore_snapshot(snap)?;
        }
        if let Some(rs) = &state.rank_state {
            self.opt.restore_rank_state(rs)?;
        }
        match &state.period_state {
            Some(ps) => self.periods.restore_snapshot(ps)?,
            // Fixed-K snapshot: re-derive the boundary pair from the
            // step (a step sitting exactly on a boundary comes back
            // pending, so the resumed run re-executes it).
            None => self.periods.sync_to(state.step as usize),
        }
        self.rng =
            Pcg::from_raw(state.rng_raw.0, state.rng_raw.1, state.rng_raw.2);
        self.refresh.restore(state.pending_refresh.as_ref());
        self.batcher.restore_stream_state(state.lanes.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BATCH: usize = 4;
    const SEQ: usize = 16;

    fn batcher(replicas: usize, accum: usize, mode: ShardMode) -> ShardedBatcher {
        let cfg = ParallelConfig {
            replicas,
            accum_steps: accum,
            shard_mode: mode,
            doc_stride: 100_000,
        };
        ShardedBatcher::new(
            &CorpusSpec::default(),
            &ByteTokenizer::new(256),
            BATCH,
            SEQ,
            &cfg,
        )
    }

    #[test]
    fn pairwise_tree_matches_linear_sum() {
        let mut rng = Pcg::new(0);
        for n in [1usize, 2, 3, 5, 8] {
            let parts: Vec<Matrix> =
                (0..n).map(|_| Matrix::randn(7, 9, 1.0, &mut rng)).collect();
            let mut linear = Matrix::zeros(7, 9);
            for p in &parts {
                linear.add_scaled_in_place(1.0, p);
            }
            let tree = pairwise_tree_sum(parts);
            assert!(
                tree.max_abs_diff(&linear) < 1e-5,
                "n={n}: {}",
                tree.max_abs_diff(&linear)
            );
        }
    }

    /// Power-of-two windows: splitting 8 leaves as 2×4 or 4×2 lanes and
    /// tree-combining the lane sums is *bitwise* the flat 8-leaf tree.
    #[test]
    fn tree_reduction_is_partition_invariant_bitwise() {
        let mut rng = Pcg::new(1);
        let leaves: Vec<Matrix> =
            (0..8).map(|_| Matrix::randn(11, 5, 1.0, &mut rng)).collect();
        let flat = pairwise_tree_sum(leaves.clone());
        for lane_width in [2usize, 4] {
            let lane_sums: Vec<Matrix> = leaves
                .chunks(lane_width)
                .map(|c| pairwise_tree_sum(c.to_vec()))
                .collect();
            let split = pairwise_tree_sum(lane_sums);
            assert_eq!(flat, split, "lane width {lane_width}");
        }
    }

    #[test]
    fn all_reduce_matches_per_block_tree() {
        let mut rng = Pcg::new(2);
        let per_replica: Vec<Vec<Matrix>> = (0..4)
            .map(|_| {
                vec![
                    Matrix::randn(6, 8, 1.0, &mut rng),
                    Matrix::randn(3, 3, 1.0, &mut rng),
                ]
            })
            .collect();
        let reduced = tree_all_reduce(&per_replica);
        assert_eq!(reduced.len(), 2);
        for (b, got) in reduced.iter().enumerate() {
            let want = pairwise_tree_sum(
                per_replica.iter().map(|g| g[b].clone()).collect(),
            );
            assert_eq!(got, &want);
        }
    }

    #[test]
    fn interleaved_lanes_cover_the_global_stream_exactly() {
        let mut sharded = batcher(2, 2, ShardMode::Interleaved);
        let mut reference = BatchLoader::new(
            SyntheticCorpus::new(CorpusSpec::default()),
            ByteTokenizer::new(256),
            BATCH,
            SEQ,
        );
        for step in 0..3 {
            let global = sharded.next_global();
            for (r, lane) in global.iter().enumerate() {
                for (a, got) in lane.iter().enumerate() {
                    let want = reference.next_batch();
                    assert_eq!(
                        got.tokens, want.tokens,
                        "step {step} lane {r} micro {a}"
                    );
                }
            }
        }
    }

    #[test]
    fn doc_partition_lanes_stream_disjoint_tokens() {
        let mut sharded = batcher(3, 1, ShardMode::DocPartition);
        let global = sharded.next_global();
        assert_eq!(global.len(), 3);
        assert_ne!(global[0][0].tokens, global[1][0].tokens);
        assert_ne!(global[1][0].tokens, global[2][0].tokens);
    }

    #[test]
    fn batcher_stream_state_roundtrips() {
        let mut a = batcher(2, 2, ShardMode::Interleaved);
        let _ = a.next_global();
        let state = a.stream_state();
        let want = a.next_global();

        let mut b = batcher(2, 2, ShardMode::Interleaved);
        b.restore_stream_state(state).unwrap();
        let got = b.next_global();
        for (lw, lg) in want.iter().zip(&got) {
            for (bw, bg) in lw.iter().zip(lg) {
                assert_eq!(bw.tokens, bg.tokens);
            }
        }
    }

    #[test]
    fn synthetic_grads_are_deterministic_and_data_dependent() {
        let store = crate::model::init_param_store(
            &crate::model::registry::get("micro").unwrap(),
            0,
        );
        let mut src_a = SyntheticGradSource::new(&store, 7);
        let mut src_b = SyntheticGradSource::new(&store, 7);
        let mut sharded = batcher(1, 2, ShardMode::Interleaved);
        let global = sharded.next_global();
        let (l1, g1) = src_a.grad(&store, &global[0][0]).unwrap();
        let (l2, g2) = src_b.grad(&store, &global[0][0]).unwrap();
        assert_eq!(l1, l2);
        assert_eq!(g1, g2, "same batch must give identical grads");
        let (_, g3) = src_a.grad(&store, &global[0][1]).unwrap();
        assert_ne!(g1, g3, "different batch must perturb the gradient");
    }

    #[test]
    fn combine_scales_to_mean_micro_gradient() {
        let a = Matrix::from_vec(1, 2, vec![2.0, 4.0]);
        let b = Matrix::from_vec(1, 2, vec![6.0, 8.0]);
        let lanes = vec![
            LaneResult {
                replica: 0,
                loss: 1.0,
                grads: vec![a],
                micro_batches: 1,
                grad_time_s: 0.0,
                tokens: 4,
            },
            LaneResult {
                replica: 1,
                loss: 3.0,
                grads: vec![b],
                micro_batches: 1,
                grad_time_s: 0.0,
                tokens: 4,
            },
        ];
        let global = combine_lanes(lanes);
        assert_eq!(global.micro_batches, 2);
        assert_eq!(global.tokens, 8);
        assert!((global.loss - 2.0).abs() < 1e-12);
        assert_eq!(global.grads[0].data, vec![4.0, 6.0]);
    }

    #[test]
    fn reduce_mode_parses() {
        assert_eq!(ReduceMode::parse("dense").unwrap(), ReduceMode::Dense);
        assert_eq!(
            ReduceMode::parse("lowrank").unwrap(),
            ReduceMode::LowRank
        );
        assert_eq!(
            ReduceMode::parse("low-rank").unwrap(),
            ReduceMode::LowRank
        );
        assert_eq!(ReduceMode::default(), ReduceMode::Dense);
        let err = ReduceMode::parse("sparse").unwrap_err();
        assert!(format!("{err:#}").contains("sparse"));
        assert_eq!(ReduceMode::Dense.name(), "dense");
        assert_eq!(ReduceMode::LowRank.name(), "lowrank");
    }

    fn toy_lanes(grads: &[Matrix]) -> Vec<LaneResult> {
        grads
            .iter()
            .enumerate()
            .map(|(r, g)| LaneResult {
                replica: r,
                loss: 1.0,
                grads: vec![g.clone()],
                micro_batches: 1,
                grad_time_s: 0.0,
                tokens: 4,
            })
            .collect()
    }

    /// The legacy entry point is exactly the compressed combine under
    /// the all-dense plan — bitwise, with 1× accounting.
    #[test]
    fn dense_plan_combine_matches_legacy_bitwise() {
        let mut rng = Pcg::new(4);
        let grads: Vec<Matrix> =
            (0..3).map(|_| Matrix::randn(6, 10, 1.0, &mut rng)).collect();
        let legacy = combine_lanes(toy_lanes(&grads));
        let (compressed, stats) = combine_lanes_compressed(
            toy_lanes(&grads),
            &ReducePlan::dense(1),
        );
        assert_eq!(legacy.grads, compressed.grads);
        assert_eq!(stats.dense_bytes, stats.payload_bytes);
        assert_eq!(stats.lowrank_blocks, 0);
        assert_eq!(stats.dense_blocks, 1);
        assert_eq!(stats.compression(), 1.0);
    }

    /// A low-rank block reduces as lift(tree(project(g_r)))/R — the
    /// projection happens per lane *before* the fixed-order tree, the
    /// lift once after — and the payload accounting reflects the
    /// projected r×n shape.
    #[test]
    fn compressed_reduce_projects_then_lifts_through_the_same_tree() {
        use crate::optim::ProjKind;
        let mut rng = Pcg::new(5);
        let proto = Matrix::randn(8, 12, 1.0, &mut rng);
        let proj = Projector::build(&proto, 3, ProjKind::Random, &mut rng);
        let grads: Vec<Matrix> =
            (0..3).map(|_| Matrix::randn(8, 12, 1.0, &mut rng)).collect();
        let plan = ReducePlan {
            payloads: vec![BlockPayload::LowRank(proj.clone())],
        };
        let (global, stats) =
            combine_lanes_compressed(toy_lanes(&grads), &plan);
        let reduced = pairwise_tree_sum(
            grads.iter().map(|g| proj.project(g)).collect(),
        );
        let mut want = proj.project_back(&reduced);
        want.scale_in_place(1.0 / 3.0);
        assert_eq!(global.grads[0], want);
        // 8×12 is left-oriented: each lane ships r×n = 3×12 floats.
        assert_eq!(stats.dense_bytes, 8 * 12 * 4);
        assert_eq!(stats.payload_bytes, 3 * 12 * 4);
        assert_eq!(stats.lowrank_blocks, 1);
        assert_eq!(stats.dense_blocks, 0);
        assert!((stats.compression() - 8.0 / 3.0).abs() < 1e-12);
    }

    /// Resuming a snapshot whose stream count disagrees with the run's
    /// replica count must fail with both counts in the message, not
    /// silently truncate/skip lanes.
    #[test]
    fn restore_stream_state_rejects_lane_count_mismatch() {
        let mut two = batcher(2, 1, ShardMode::Interleaved);
        let three = batcher(3, 1, ShardMode::Interleaved);
        let err = two
            .restore_stream_state(three.stream_state())
            .expect_err("3-lane snapshot into a 2-lane run must fail");
        let msg = format!("{err:#}");
        assert!(
            msg.contains("checkpoint has 3 lanes")
                && msg.contains("run has 2"),
            "error must name both counts: {msg}"
        );
        // And the matching count restores cleanly after the rejection.
        let mut other_two = batcher(2, 1, ShardMode::Interleaved);
        let _ = other_two.next_global();
        two.restore_stream_state(other_two.stream_state()).unwrap();
        let (a, b) = (two.next_global(), other_two.next_global());
        for (la, lb) in a.iter().zip(&b) {
            for (ba, bb) in la.iter().zip(lb) {
                assert_eq!(ba.tokens, bb.tokens);
            }
        }
    }
}
