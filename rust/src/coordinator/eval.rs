//! Multi-domain probe evaluation — the stand-in for the paper's seven
//! commonsense suites (ARC-E/C, OBQA, HellaSwag, PIQA, SIQA, Winogrande).
//!
//! Each probe item is multiple-choice: a document prefix (prompt) with
//! the true continuation plus `n_choices−1` distractor continuations
//! drawn from *other* documents of the same domain. Scoring is
//! length-normalized continuation NLL through the model's forward pass
//! (the same protocol lm-eval-harness uses for those suites); accuracy =
//! fraction of items where the true continuation scores best. Chance is
//! 1/n_choices.

use anyhow::Result;

use crate::data::corpus::{Domain, SyntheticCorpus};
use crate::data::tokenizer::{ByteTokenizer, BOS};
use crate::model::ParamStore;
use crate::runtime::{Executor, ModelRunner};

/// One multiple-choice item (already tokenized & padded).
#[derive(Debug, Clone)]
pub struct ProbeItem {
    /// Per choice: (tokens, targets) rows of length seq.
    pub choices: Vec<(Vec<i32>, Vec<i32>)>,
    /// Index of the correct choice.
    pub correct: usize,
    /// Unmasked target counts per choice (for length normalization —
    /// already applied by the model's per-example NLL).
    pub spans: Vec<usize>,
}

/// A probe set for one domain.
#[derive(Debug, Clone)]
pub struct DomainProbe {
    pub domain: Domain,
    pub items: Vec<ProbeItem>,
}

/// All domains' probes.
#[derive(Debug, Clone)]
pub struct ProbeSet {
    pub probes: Vec<DomainProbe>,
}

/// Build one tokenized (tokens, targets) row: BOS + prefix + continuation,
/// targets = next-token over the continuation span only (−1 elsewhere).
fn build_row(
    tok: &ByteTokenizer,
    prefix: &str,
    continuation: &str,
    seq: usize,
) -> (Vec<i32>, Vec<i32>) {
    let mut ids = vec![BOS];
    ids.extend(tok.encode(prefix));
    // Cap the prefix at half the window so every choice always has a
    // scored continuation span (long documents would otherwise fill the
    // whole window with prompt).
    ids.truncate(1 + seq / 2);
    let cont_start = ids.len();
    ids.extend(tok.encode(continuation));
    ids.truncate(seq + 1);
    // Pad the *token* stream with BOS (never scored).
    while ids.len() < seq + 1 {
        ids.push(BOS);
    }
    let tokens: Vec<i32> = ids[..seq].to_vec();
    let mut targets = vec![-1i32; seq];
    let span_end = (cont_start.max(1) - 1)
        ..(ids.len().min(seq + 1) - 1).min(seq);
    // Score positions predicting continuation tokens only.
    for pos in span_end {
        if pos + 1 >= cont_start && ids[pos + 1] != BOS {
            targets[pos] = ids[pos + 1];
        }
    }
    (tokens, targets)
}

impl DomainProbe {
    /// Build `n_items` held-out items for a domain. `doc_offset` selects
    /// documents beyond the training stream.
    pub fn build(
        corpus: &SyntheticCorpus,
        tok: &ByteTokenizer,
        domain: Domain,
        n_items: usize,
        n_choices: usize,
        seq: usize,
        doc_offset: u64,
    ) -> DomainProbe {
        let mut items = Vec::with_capacity(n_items);
        for i in 0..n_items {
            let id = doc_offset + i as u64 * n_choices as u64;
            let doc = corpus.document(domain, id);
            let split = (doc.len() / 2).max(1);
            // Split at a char boundary (ASCII corpus ⇒ byte == char).
            let (prefix, true_cont) = doc.split_at(split.min(doc.len() - 1));
            let mut choices = Vec::with_capacity(n_choices);
            let mut spans = Vec::with_capacity(n_choices);
            // Correct answer occupies slot (i % n_choices) to avoid
            // position bias.
            let correct = i % n_choices;
            let mut distractor = 1u64;
            for c in 0..n_choices {
                let cont: String = if c == correct {
                    true_cont.to_string()
                } else {
                    // Distractor: same-domain continuation from another
                    // document, truncated to the same length.
                    let other =
                        corpus.document(domain, id + distractor);
                    distractor += 1;
                    let start = other.len() / 2;
                    other[start..]
                        .chars()
                        .take(true_cont.len())
                        .collect()
                };
                spans.push(cont.len());
                choices.push(build_row(tok, prefix, &cont, seq));
            }
            items.push(ProbeItem {
                choices,
                correct,
                spans,
            });
        }
        DomainProbe { domain, items }
    }

    /// Score this probe through the model: returns accuracy in [0, 1].
    pub fn evaluate(
        &self,
        runner: &ModelRunner,
        exec: &mut Executor,
        params: &ParamStore,
    ) -> Result<f64> {
        let bsz = runner.config.batch;
        let seq = runner.config.seq_len;
        // Flatten all (item, choice) rows, batch them, collect NLLs.
        let mut rows: Vec<(Vec<i32>, Vec<i32>)> = Vec::new();
        for item in &self.items {
            rows.extend(item.choices.iter().cloned());
        }
        let mut nll = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(bsz) {
            let mut tokens = Vec::with_capacity(bsz * seq);
            let mut targets = Vec::with_capacity(bsz * seq);
            for (t, g) in chunk {
                tokens.extend_from_slice(t);
                targets.extend_from_slice(g);
            }
            // Pad the final partial batch with the last row.
            while tokens.len() < bsz * seq {
                let (t, g) = &chunk[chunk.len() - 1];
                tokens.extend_from_slice(t);
                targets.extend_from_slice(g);
            }
            let (_, batch_nll) = runner.eval(exec, params, &tokens, &targets)?;
            nll.extend_from_slice(&batch_nll[..chunk.len()]);
        }
        // Argmin per item.
        let mut correct = 0usize;
        let mut cursor = 0usize;
        for item in &self.items {
            let k = item.choices.len();
            let scores = &nll[cursor..cursor + k];
            cursor += k;
            let best = scores
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if best == item.correct {
                correct += 1;
            }
        }
        Ok(correct as f64 / self.items.len().max(1) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{CorpusSpec, ALL_DOMAINS};

    #[test]
    fn build_row_masks_prefix_and_padding() {
        let tok = ByteTokenizer::new(256);
        let (tokens, targets) = build_row(&tok, "abc", "de", 16);
        assert_eq!(tokens.len(), 16);
        assert_eq!(targets.len(), 16);
        // Exactly the continuation tokens are scored ("de" = 2 targets).
        let scored: Vec<usize> = targets
            .iter()
            .enumerate()
            .filter(|(_, &t)| t >= 0)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(scored.len(), 2, "{targets:?}");
        // Scored targets decode back to 'd','e'.
        let vals: Vec<i32> =
            scored.iter().map(|&i| targets[i]).collect();
        assert_eq!(tok.decode(&vals), "de");
    }

    #[test]
    fn probes_deterministic_and_balanced() {
        let corpus = SyntheticCorpus::new(CorpusSpec::default());
        let tok = ByteTokenizer::new(256);
        let p1 = DomainProbe::build(
            &corpus, &tok, Domain::Grammar, 20, 4, 64, 50_000,
        );
        let p2 = DomainProbe::build(
            &corpus, &tok, Domain::Grammar, 20, 4, 64, 50_000,
        );
        assert_eq!(p1.items.len(), 20);
        for (a, b) in p1.items.iter().zip(&p2.items) {
            assert_eq!(a.correct, b.correct);
            assert_eq!(a.choices, b.choices);
        }
        // Correct positions rotate (no position bias).
        let positions: Vec<usize> =
            p1.items.iter().map(|i| i.correct).collect();
        for c in 0..4 {
            assert!(positions.iter().filter(|&&p| p == c).count() >= 4);
        }
    }

    #[test]
    fn all_domains_build() {
        let corpus = SyntheticCorpus::new(CorpusSpec::default());
        let tok = ByteTokenizer::new(256);
        for d in ALL_DOMAINS {
            let p = DomainProbe::build(&corpus, &tok, d, 4, 4, 64, 90_000);
            assert_eq!(p.items.len(), 4);
            for item in &p.items {
                assert_eq!(item.choices.len(), 4);
                // Every choice scores at least one position.
                for (_, targets) in &item.choices {
                    assert!(targets.iter().any(|&t| t >= 0), "{d:?}");
                }
            }
        }
    }
}
