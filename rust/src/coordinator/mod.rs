//! L3 coordinator — the paper's system contribution.
//!
//! The training loop that makes Algorithm 2 a *system*: the period
//! scheduler (K-step sampling periods: projector refresh, momentum
//! restart, layerwise Bernoulli sampling), LR schedules, the metrics
//! stream, checkpointing for the spectral analyses, and the multi-domain
//! probe evaluator that stands in for the paper's commonsense suites.

pub mod checkpoint;
pub mod eval;
pub mod metrics;
pub mod scheduler;
pub mod trainer;

pub use checkpoint::{load_checkpoint, save_checkpoint};
pub use eval::{DomainProbe, ProbeSet};
pub use metrics::MetricsLog;
pub use scheduler::{LrSchedule, PeriodScheduler};
pub use trainer::{TrainConfig, TrainResult, Trainer};
