//! L3 coordinator — the paper's system contribution.
//!
//! The training loop that makes Algorithm 2 a *system*: the period
//! scheduler (K-step sampling periods: projector refresh, momentum
//! restart, layerwise Bernoulli sampling), LR schedules, the metrics
//! stream, checkpointing for the spectral analyses *and* mid-period
//! resume, the multi-domain probe evaluator that stands in for the
//! paper's commonsense suites, and the data-parallel subsystem
//! ([`parallel`]): replica lanes, micro-batch accumulation, and the
//! deterministic tree all-reduce that keeps the parallel gradient path
//! provably equivalent to the sequential one. [`elastic`] supervises
//! those lanes — failure detection, fencing, rollback to the last good
//! hardened snapshot, deterministic re-entry — so a run with lane
//! faults commits a bit-identical trajectory to a fault-free one.

pub mod checkpoint;
pub mod elastic;
pub mod eval;
pub mod metrics;
pub mod parallel;
pub mod scheduler;
pub mod trainer;

pub use checkpoint::{
    load_checkpoint, load_latest_train_state, load_train_state,
    save_checkpoint, save_train_state, save_train_state_v2,
    sweep_orphaned_tmp, LatestState,
};
pub use elastic::{
    ElasticConfig, ElasticEvent, ElasticEventKind, ElasticSession,
    LaneStatus,
};
pub use eval::{DomainProbe, ProbeSet};
pub use metrics::MetricsLog;
pub use parallel::{
    combine_lanes, combine_lanes_compressed, ensure_same_layout,
    pairwise_tree_sum, parallel_lane_grads, sequential_lane_grads,
    supervised_lane_grads, tree_all_reduce, BlockPayload, GlobalGrad,
    GradSource, LaneFailure, LaneResult, LaneStat, ParallelConfig,
    ParallelSession, ReduceMode, ReducePlan, ReduceStats, ShardMode,
    ShardedBatcher, SyntheticGradSource, TrainState,
};
pub use scheduler::{LrSchedule, PeriodScheduler, PeriodSnapshot};
pub use trainer::{TrainConfig, TrainResult, Trainer};
