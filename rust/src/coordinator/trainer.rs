//! The training loop: L2 gradients through PJRT, L3 optimizer updates,
//! period scheduling, data-parallel replica lanes, eval, checkpoints,
//! metrics.
//!
//! Every global step consumes `replicas × accum_steps` micro-batches
//! through the sharded batcher, reduces the per-lane gradient sums with
//! the deterministic tree all-reduce (`coordinator::parallel`), and
//! applies a single optimizer update — so GUM's period sampling sees
//! exactly the gradient a sequential run would produce. The PJRT runner
//! serves lanes in replica order on the coordinator thread (one runtime
//! client); native gradient sources fan out on the thread pool through
//! the same combine path with byte-identical results.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::data::corpus::{CorpusSpec, SyntheticCorpus, ALL_DOMAINS};
use crate::data::loader::BatchLoader;
use crate::data::tokenizer::ByteTokenizer;
use crate::model::{init_param_store, registry, ParamStore};
use crate::optim::{self, StepCtx};
use crate::rng::{derive_seed, Pcg};
use crate::runtime::{Executor, ModelRunner};
use crate::testing::{FaultPlan, InjectedFault};
use crate::util::timer::Timer;

use super::checkpoint::{
    load_train_state, save_checkpoint, save_train_state, sweep_orphaned_tmp,
};
use super::eval::DomainProbe;
use super::metrics::{replica_key, MetricsLog};
use super::parallel::{
    combine_lanes_compressed, ensure_same_layout, sequential_lane_grads,
    ParallelConfig, ReduceMode, ReducePlan, ShardMode, ShardedBatcher,
    TrainState,
};
use super::scheduler::{LrSchedule, PeriodScheduler};

/// Full training-run configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub model: String,
    pub optimizer: String,
    pub lr: f64,
    pub steps: usize,
    /// Sampling period K (projector refresh / momentum restart /
    /// layer resampling cadence).
    pub period_k: usize,
    /// Refresh-period schedule: `fixed` keeps `period_k` for the whole
    /// run; `adaptive` lets a drift-driven controller stretch the
    /// period while the subspace is stable and shrink it after rank
    /// changes or drift spikes (`--period-schedule`, `--period-min`,
    /// `--period-max`, `--period-drift`).
    pub period_schedule: optim::PeriodSchedule,
    /// Projection rank r.
    pub rank: usize,
    /// Per-block rank schedule: `fixed` keeps `rank` everywhere;
    /// `adaptive` lets a spectrum-driven controller shrink/grow each
    /// block's rank at refresh boundaries under a global budget
    /// (`--rank-schedule`, `--rank-energy`, `--rank-budget`,
    /// `--rank-min`, `--rank-max`).
    pub rank_schedule: optim::RankSchedule,
    /// Expected number of full-rank blocks γ (GUM/LISA).
    pub gamma: f64,
    /// Projector-refresh engine for the low-rank optimizers
    /// (`--refresh-strategy exact | randomized[:os[:iters]] | warm-start`).
    pub refresh: optim::RefreshStrategy,
    /// Where the refresh runs relative to the critical path
    /// (`--refresh-pipeline sync|async`; default async — overlapped on
    /// the worker pool, bit-identical trajectory, sync kept for
    /// bisection).
    pub refresh_pipeline: optim::RefreshPipelineMode,
    pub seed: u64,
    pub warmup: usize,
    /// Data-parallel replica lanes per global step.
    pub replicas: usize,
    /// Micro-batches accumulated per lane per global step.
    pub accum_steps: usize,
    /// How replica lanes shard the document stream.
    pub shard_mode: ShardMode,
    /// What the lanes ship through the tree all-reduce
    /// (`--reduce dense|lowrank`; default dense. `lowrank` ships the
    /// period's projected gradients for low-rank GUM blocks, dense
    /// matrices for full-rank-sampled/dense blocks and boundary
    /// steps — see `coordinator::parallel::ReducePlan`).
    pub reduce: ReduceMode,
    /// Resume from a `GUMCKPT2`/`GUMCKPT3` train-state checkpoint
    /// (mid-period safe for optimizers that snapshot, e.g. GUM).
    pub resume_from: Option<PathBuf>,
    /// Total lane-restart budget: a failed gradient lane rolls the run
    /// back to the last known-good train state and replays, up to this
    /// many times across the run. 0 disables recovery (a lane failure
    /// fails the run, and no in-memory rollback state is kept).
    pub max_lane_restarts: usize,
    /// Fault-injection plan spec ([`FaultPlan`] grammar:
    /// `kill:L@S,stall:L@S:MS,trunc:N@B`) — the `--fault-plan`
    /// reproduction surface for elastic-recovery failures.
    pub fault_plan: Option<String>,
    /// GEMM autotuner cache file (`--tune-cache`): setting a path
    /// turns the [`crate::linalg::tune`] shape-class autotuner on and
    /// persists its measured tile choices there, so later runs skip
    /// the search. `None` leaves the `GUM_TUNE`/`GUM_TUNE_CACHE` env
    /// resolution in place (off by default — determinism suites and
    /// CI stay on the fixed tiling).
    pub tune_cache: Option<PathBuf>,
    /// Storage dtype for optimizer moment buffers (`--state-dtype
    /// f32|bf16|f16`): 16-bit formats pack the moments with
    /// round-to-nearest-even and accumulate in f32 inside the fused
    /// kernels; projector bases stay f32. Tracked per step by the
    /// `opt_state_bytes` metric.
    pub state_dtype: optim::StateDtype,
    /// Evaluate held-out loss every N steps (0 = off).
    pub eval_every: usize,
    pub eval_batches: usize,
    /// Save checkpoints every N steps into `out_dir` (0 = off).
    pub ckpt_every: usize,
    /// Run the 7-domain probe suite at the end.
    pub probes: bool,
    pub probe_items: usize,
    pub artifacts_dir: PathBuf,
    pub out_dir: Option<PathBuf>,
    /// Log every N steps.
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "micro".into(),
            optimizer: "gum".into(),
            lr: 0.01,
            steps: 100,
            period_k: 20,
            period_schedule: optim::PeriodSchedule::default(),
            rank: 16,
            rank_schedule: optim::RankSchedule::default(),
            gamma: 2.0,
            refresh: optim::RefreshStrategy::default(),
            refresh_pipeline: optim::RefreshPipelineMode::default(),
            seed: 0,
            warmup: 10,
            replicas: 1,
            accum_steps: 1,
            shard_mode: ShardMode::DocPartition,
            reduce: ReduceMode::default(),
            resume_from: None,
            max_lane_restarts: 3,
            fault_plan: None,
            tune_cache: None,
            state_dtype: optim::StateDtype::F32,
            eval_every: 0,
            eval_batches: 4,
            ckpt_every: 0,
            probes: false,
            probe_items: 24,
            artifacts_dir: PathBuf::from("artifacts"),
            out_dir: None,
            log_every: 10,
        }
    }
}

/// Result of a training run.
pub struct TrainResult {
    pub metrics: MetricsLog,
    pub params: ParamStore,
    /// (domain name, accuracy) for the probe suite, if run.
    pub probe_scores: Vec<(String, f64)>,
    pub final_train_loss: f64,
    pub final_val_loss: Option<f64>,
    pub optimizer_name: String,
    pub state_bytes: usize,
}

/// Restore the mutable run components from a [`TrainState`] — the one
/// sequence both `--resume` and elastic rollback go through, so the two
/// paths cannot drift.
#[allow(clippy::too_many_arguments)]
fn restore_train_components(
    state: &TrainState,
    params: &mut ParamStore,
    opt: &mut dyn optim::Optimizer,
    rng: &mut Pcg,
    batcher: &mut ShardedBatcher,
    val_loader: &mut BatchLoader,
    periods: &mut PeriodScheduler,
    refresh_pipeline: &mut optim::RefreshPipeline,
) -> Result<()> {
    *params = state.params.clone();
    // Re-anchor the boundary sequence first: the mid-period diagnostics
    // below consult it. A PERIODS snapshot restores the adaptive
    // boundary pair exactly; its absence means a fixed schedule, whose
    // state re-derives from `step % K` (a step landing exactly on a
    // boundary comes back *pending*, so the resumed run re-runs the
    // refresh instead of silently skipping it).
    match &state.period_state {
        Some(ps) => periods.restore_snapshot(ps).context(
            "restoring adaptive period-schedule state",
        )?,
        None => periods.sync_to(state.step as usize),
    }
    if let Some(snap) = &state.opt {
        let name = opt.name();
        opt.restore_snapshot(snap).with_context(|| {
            format!("restoring optimizer '{name}' state")
        })?;
    } else if periods.steps_into_period(state.step as usize) != 0 {
        crate::warn!(
            "restoring mid-period without optimizer state: \
             momentum/projector restart at the next boundary"
        );
    }
    *rng = Pcg::from_raw(state.rng_raw.0, state.rng_raw.1, state.rng_raw.2);
    batcher.restore_stream_state(state.lanes.clone())?;
    if let Some((next_doc, buffer)) = &state.val_lane {
        val_loader.restore_stream_state(*next_doc, buffer.clone());
    }
    if let Some(rs) = &state.rank_state {
        let name = opt.name();
        opt.restore_rank_state(rs).with_context(|| {
            format!("restoring '{name}' adaptive rank-schedule state")
        })?;
    }
    // Discard whatever refresh was armed/in flight; the snapshot's
    // resolved bases (if any) are the only state a replay may consume.
    refresh_pipeline.restore(state.pending_refresh.as_ref());
    Ok(())
}

/// Orchestrates one training run end-to-end.
pub struct Trainer {
    pub cfg: TrainConfig,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Trainer {
        Trainer { cfg }
    }

    pub fn run(&self) -> Result<TrainResult> {
        let cfg = &self.cfg;
        // Arm the GEMM autotuner before any projection work runs: a
        // configured cache path implies tuning on and persists new
        // searches for the next run.
        if let Some(p) = &cfg.tune_cache {
            crate::linalg::tune::set_cache_path(Some(p.clone()));
            crate::linalg::tune::set_mode(Some(crate::linalg::tune::TuneMode::On));
        }
        let model_cfg = registry::get(&cfg.model)
            .with_context(|| format!("unknown model '{}'", cfg.model))?;

        let mut exec = Executor::new(&cfg.artifacts_dir)?;
        let runner = ModelRunner::new(&exec, &model_cfg)?;
        let pcfg = ParallelConfig {
            replicas: cfg.replicas.max(1),
            accum_steps: cfg.accum_steps.max(1),
            shard_mode: cfg.shard_mode,
            ..ParallelConfig::default()
        };
        crate::info!(
            "trainer: model={} opt={} steps={} K={} ksched={} r={} sched={} \
             γ={} refresh={} pipeline={} replicas={} accum={} shard={} \
             reduce={} on {}",
            cfg.model,
            cfg.optimizer,
            cfg.steps,
            cfg.period_k,
            cfg.period_schedule.label(),
            cfg.rank,
            cfg.rank_schedule.label(),
            cfg.gamma,
            cfg.refresh.label(),
            cfg.refresh_pipeline.label(),
            pcfg.replicas,
            pcfg.accum_steps,
            pcfg.shard_mode.name(),
            cfg.reduce.name(),
            exec.platform()
        );

        let mut params = init_param_store(&model_cfg, cfg.seed);
        let mut opt = optim::build_with_state(
            &cfg.optimizer,
            &params,
            cfg.rank,
            cfg.gamma,
            derive_seed(cfg.seed, "opt"),
            cfg.refresh,
            &cfg.rank_schedule,
            cfg.state_dtype,
        )?;
        // Projected-moment count for the adaptive-rank footprint metric
        // (Adam-style optimizers carry m and v at the projected shape;
        // the momentum ones a single buffer).
        let proj_moments = match cfg.optimizer.as_str() {
            "galore-adam" | "fira" => 2,
            _ => 1,
        };
        let mut refresh_pipeline = optim::RefreshPipeline::new(
            cfg.refresh_pipeline,
            derive_seed(cfg.seed, "refresh"),
        );
        // The adaptive period controller measures principal-angle drift
        // between consecutive projector bases — meaningless for
        // optimizers that keep no projector state (adam, sgd, lion):
        // every boundary would read as "no signal" and K would never
        // move. Reject the combination up front.
        if matches!(
            cfg.period_schedule,
            optim::PeriodSchedule::Adaptive(_)
        ) && opt.projectors().is_none()
        {
            anyhow::bail!(
                "--period-schedule adaptive requires a low-rank \
                 projection optimizer (gum, galore, galore-adam, \
                 galore-muon, fira); '{}' exposes no projector bases to \
                 measure subspace drift on",
                opt.name()
            );
        }

        let tok = ByteTokenizer::new(model_cfg.vocab);
        let corpus_spec = CorpusSpec {
            seed: derive_seed(cfg.seed, "corpus"),
            ..CorpusSpec::default()
        };
        let mut batcher = ShardedBatcher::new(
            &corpus_spec,
            &tok,
            model_cfg.batch,
            model_cfg.seq_len,
            &pcfg,
        );
        // Held-out stream for validation (far beyond the train docs).
        let mut val_loader = BatchLoader::new(
            SyntheticCorpus::new(corpus_spec.clone()),
            tok.clone(),
            model_cfg.batch,
            model_cfg.seq_len,
        )
        .with_doc_offset(1_000_000);

        let schedule = LrSchedule::warmup_cosine(cfg.lr, cfg.warmup, cfg.steps);
        let mut periods =
            PeriodScheduler::with_schedule(cfg.period_k, &cfg.period_schedule);
        let mut rng = Pcg::new(derive_seed(cfg.seed, "trainer"));
        let mut metrics = MetricsLog::new();
        let mut final_val = None;
        let run_timer = Timer::start();

        // Startup hygiene: sweep orphaned `.tmp` siblings a crashed
        // earlier run left in the checkpoint dir before writing (or
        // resuming over) anything.
        if let Some(dir) = &cfg.out_dir {
            for p in sweep_orphaned_tmp(dir) {
                crate::warn!(
                    "removed orphaned checkpoint temp file {}",
                    p.display()
                );
            }
        }

        let mut start_step = 0usize;
        if let Some(path) = &cfg.resume_from {
            let state = load_train_state(path)?;
            ensure_same_layout(&state.params, &params).with_context(|| {
                format!(
                    "resume checkpoint {} does not fit model '{}'",
                    path.display(),
                    cfg.model
                )
            })?;
            restore_train_components(
                &state,
                &mut params,
                &mut opt,
                &mut rng,
                &mut batcher,
                &mut val_loader,
                &mut periods,
                &mut refresh_pipeline,
            )?;
            start_step = state.step as usize;
            crate::info!(
                "resumed from {} at step {start_step}",
                path.display()
            );
        }

        // Elastic recovery: a seeded fault plan (reproduction surface)
        // plus the last known-good rollback state — loop entry, then
        // refreshed at every period boundary and train-state save.
        // `--max-lane-restarts 0` opts out of both the recovery and the
        // in-memory state copy.
        let plan: Arc<FaultPlan> = Arc::new(match &cfg.fault_plan {
            Some(spec) => FaultPlan::parse(spec)
                .with_context(|| format!("parsing fault plan '{spec}'"))?,
            None => FaultPlan::empty(),
        });
        let mut last_state: Option<TrainState> = if cfg.max_lane_restarts > 0
        {
            Some(TrainState {
                step: start_step as u64,
                params: params.clone(),
                opt: opt.snapshot(),
                rng_raw: rng.to_raw(),
                lanes: batcher.stream_state(),
                val_lane: Some(val_loader.stream_state()),
                pending_refresh: refresh_pipeline.resolve_pending(),
                rank_state: opt.rank_state(),
                period_state: periods.snapshot(),
            })
        } else {
            None
        };
        let mut restarts_used = 0usize;
        let mut saves = 0u64;

        let mut step = start_step;
        while step < cfg.steps {
            // Refresh the in-memory rollback target at every sampling-
            // period boundary (before this step mutates anything), so
            // recovery never replays more than one period even when no
            // checkpoints are being written to disk.
            if cfg.max_lane_restarts > 0
                && periods.is_period_start(step)
                && last_state
                    .as_ref()
                    .map_or(true, |s| (s.step as usize) < step)
            {
                last_state = Some(TrainState {
                    step: step as u64,
                    params: params.clone(),
                    opt: opt.snapshot(),
                    rng_raw: rng.to_raw(),
                    lanes: batcher.stream_state(),
                    val_lane: Some(val_loader.stream_state()),
                    pending_refresh: refresh_pipeline.resolve_pending(),
                    rank_state: opt.rank_state(),
                    period_state: periods.snapshot(),
                });
            }
            let batches = batcher.next_global();
            let t = Timer::start();
            let lanes = sequential_lane_grads(&params, &batches, |r, p, b| {
                plan.check(r, step as u64)?;
                let out =
                    runner.grad_step(&mut exec, p, &b.tokens, &b.targets)?;
                Ok((out.loss, out.grads))
            });
            let lanes = match lanes {
                Ok(lanes) => lanes,
                Err(err) => {
                    let injected =
                        err.downcast_ref::<InjectedFault>().is_some();
                    let recoverable = restarts_used < cfg.max_lane_restarts
                        && last_state.is_some();
                    if !recoverable {
                        return Err(err).with_context(|| {
                            format!(
                                "step {step}: gradient lane failed with no \
                                 recovery left (restarts {restarts_used}/{})",
                                cfg.max_lane_restarts
                            )
                        });
                    }
                    let state = last_state.as_ref().unwrap();
                    restarts_used += 1;
                    crate::warn!(
                        "step {step}: gradient lane {} ({err:#}); rolling \
                         back to step {} (lane restart {restarts_used}/{})",
                        if injected {
                            "hit an injected fault"
                        } else {
                            "failed"
                        },
                        state.step,
                        cfg.max_lane_restarts
                    );
                    restore_train_components(
                        state,
                        &mut params,
                        &mut opt,
                        &mut rng,
                        &mut batcher,
                        &mut val_loader,
                        &mut periods,
                        &mut refresh_pipeline,
                    )
                    .context("elastic rollback")?;
                    metrics.retain_before(state.step as usize);
                    step = state.step as usize;
                    continue;
                }
            };
            // Payload plan from committed state only (projectors and
            // the full-rank mask change inside the boundary block
            // below, which always ships dense), so a rollback replay
            // plans — and reduces — identically.
            let plan = ReducePlan::plan(
                cfg.reduce,
                step,
                &periods,
                &*opt,
                refresh_pipeline.lead(),
                &params,
            );
            let (global, reduce_stats) =
                combine_lanes_compressed(lanes, &plan);
            let grad_s = t.elapsed_s();

            if periods.is_period_start(step) {
                let taken = refresh_pipeline.take(step);
                // The period decision rode along with the prepared
                // refresh (observed off-thread against the same bases
                // it will install); committing the boundary adopts it,
                // so the *next* boundary lands `decided period` steps
                // out. Synchronous fallbacks carry no decision and the
                // current period rolls forward unchanged.
                let decision =
                    taken.as_ref().and_then(|p| p.period_state.clone());
                match taken {
                    Some(prepared) => opt.begin_period_prepared(
                        &params,
                        &global.grads,
                        &mut rng,
                        prepared,
                    ),
                    // Period 0 and non-projected optimizers refresh
                    // synchronously from the boundary gradient.
                    None => {
                        opt.begin_period(&params, &global.grads, &mut rng)
                    }
                }
                periods.commit_boundary(step, decision.as_ref());
                metrics.push(
                    step,
                    "refresh_stall_s",
                    refresh_pipeline.stall_seconds(),
                );
                metrics.push(
                    step,
                    "refresh_period",
                    periods.current_period() as f64,
                );
                metrics.push(
                    step,
                    "refreshes_per_1k_steps",
                    periods.boundaries_committed() as f64 * 1000.0
                        / (step + 1) as f64,
                );
                if let Some(ctl) = periods.controller() {
                    metrics.push(
                        step,
                        "subspace_drift",
                        ctl.last_drift() as f64,
                    );
                }
                // Adaptive rank schedule: log the controller's decision
                // for this period — total and per-block ranks plus the
                // projected optimizer-state footprint they imply.
                if let Some(rs) = opt.rank_state() {
                    metrics.push(step, "rank_total", rs.total() as f64);
                    let ranks: Vec<usize> =
                        rs.ranks.iter().map(|&r| r as usize).collect();
                    for (b, &r) in params.blocks.iter().zip(&rs.ranks) {
                        if r > 0 {
                            metrics.push(
                                step,
                                &format!("rank/{}", b.name),
                                r as f64,
                            );
                        }
                    }
                    metrics.push(
                        step,
                        "proj_state_bytes",
                        optim::projected_state_bytes(
                            &params,
                            &ranks,
                            proj_moments,
                        ) as f64,
                    );
                }
            }
            // Arm the next boundary's refresh when this step is its
            // trigger; under async the job overlaps with the optimizer
            // step below and the next step's gradient computation.
            refresh_pipeline.observe(step, &periods, &*opt, &global.grads);
            let t = Timer::start();
            opt.step(
                &mut params,
                &global.grads,
                &StepCtx {
                    lr: schedule.at(step) as f32,
                    step,
                },
            );
            let opt_s = t.elapsed_s();

            let tokens_per_s = global.tokens as f64 / (grad_s + opt_s);
            metrics.push(step, "train_loss", global.loss);
            metrics.push(step, "lr", schedule.at(step));
            metrics.push(step, "grad_time_s", grad_s);
            metrics.push(step, "opt_time_s", opt_s);
            metrics.push(step, "tokens_per_s", tokens_per_s);
            metrics.push(step, "opt_state_bytes", opt.state_bytes() as f64);
            metrics.push(
                step,
                "reduce_bytes",
                reduce_stats.payload_bytes as f64,
            );
            metrics.push(
                step,
                "reduce_compression",
                reduce_stats.compression(),
            );
            if pcfg.replicas > 1 {
                for lane in &global.lanes {
                    metrics.push(
                        step,
                        &replica_key(lane.replica, "tokens_per_s"),
                        lane.tokens as f64 / lane.grad_time_s.max(1e-9),
                    );
                }
            }

            if cfg.log_every > 0 && step % cfg.log_every == 0 {
                crate::info!(
                    "step {step:>5} loss {:.4} lr {:.2e} {:.0} tok/s state {}",
                    global.loss,
                    schedule.at(step),
                    tokens_per_s,
                    crate::optim::bytes_human(opt.state_bytes())
                );
            }

            if cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0 {
                let val = self.val_loss(
                    &runner,
                    &mut exec,
                    &params,
                    &mut val_loader,
                )?;
                metrics.push(step, "val_loss", val);
                final_val = Some(val);
                crate::info!("step {step:>5} val_loss {val:.4}");
            }

            if cfg.ckpt_every > 0 && (step + 1) % cfg.ckpt_every == 0 {
                if let Some(dir) = &cfg.out_dir {
                    let p = dir.join(format!("ckpt_{:06}.bin", step + 1));
                    save_checkpoint(&params, &p)?;
                    let state = TrainState {
                        step: (step + 1) as u64,
                        params: params.clone(),
                        opt: opt.snapshot(),
                        rng_raw: rng.to_raw(),
                        lanes: batcher.stream_state(),
                        val_lane: Some(val_loader.stream_state()),
                        pending_refresh: refresh_pipeline.resolve_pending(),
                        rank_state: opt.rank_state(),
                        period_state: periods.snapshot(),
                    };
                    let state_path =
                        dir.join(format!("state_{:06}.bin", step + 1));
                    save_train_state(&state, &state_path)?;
                    plan.apply_truncation(saves, &state_path)?;
                    saves += 1;
                    if cfg.max_lane_restarts > 0 {
                        last_state = Some(state);
                    }
                }
            }
            step += 1;
        }

        // Final probe suite.
        let mut probe_scores = Vec::new();
        if cfg.probes {
            let corpus = SyntheticCorpus::new(corpus_spec);
            for d in ALL_DOMAINS {
                let probe = DomainProbe::build(
                    &corpus,
                    &tok,
                    d,
                    cfg.probe_items,
                    4,
                    model_cfg.seq_len,
                    2_000_000 + 10_000 * d as u64,
                );
                let acc = probe.evaluate(&runner, &mut exec, &params)?;
                metrics.push(cfg.steps, &format!("probe/{}", d.name()), acc);
                probe_scores.push((d.name().to_string(), acc));
            }
        }

        if let Some(dir) = &cfg.out_dir {
            std::fs::create_dir_all(dir).ok();
            metrics.write_csv(&dir.join("metrics.csv"))?;
            save_checkpoint(&params, &dir.join("final.bin"))?;
        }

        let final_train_loss =
            metrics.tail_mean("train_loss", 10).unwrap_or(f64::NAN);
        crate::info!(
            "run done in {:.1}s: final loss {:.4}",
            run_timer.elapsed_s(),
            final_train_loss
        );
        Ok(TrainResult {
            final_train_loss,
            final_val_loss: final_val,
            probe_scores,
            state_bytes: opt.state_bytes(),
            optimizer_name: opt.name(),
            metrics,
            params,
        })
    }

    fn val_loss(
        &self,
        runner: &ModelRunner,
        exec: &mut Executor,
        params: &ParamStore,
        val_loader: &mut BatchLoader,
    ) -> Result<f64> {
        let mut total = 0.0;
        for _ in 0..self.cfg.eval_batches {
            let b = val_loader.next_batch();
            let (loss, _) = runner.eval(exec, params, &b.tokens, &b.targets)?;
            total += loss as f64;
        }
        Ok(total / self.cfg.eval_batches as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_sane() {
        let c = TrainConfig::default();
        assert_eq!(c.model, "micro");
        assert!(c.period_k >= 1);
        assert!(c.lr > 0.0);
        assert_eq!(c.replicas, 1);
        assert_eq!(c.accum_steps, 1);
        // Static per-block ranks unless --rank-schedule adaptive.
        assert_eq!(c.rank_schedule, optim::RankSchedule::Fixed);
        // Fixed refresh period unless --period-schedule adaptive.
        assert_eq!(c.period_schedule, optim::PeriodSchedule::Fixed);
        // Elastic recovery on by default, no faults planned.
        assert_eq!(c.max_lane_restarts, 3);
        assert!(c.fault_plan.is_none());
        // Overlapped projector refresh by default; sync for bisection.
        assert_eq!(
            c.refresh_pipeline,
            optim::RefreshPipelineMode::Async
        );
        // Disjoint document shards by default: no skip-replay overhead.
        // (With replicas = 1 both modes stream identically.)
        assert_eq!(c.shard_mode, ShardMode::DocPartition);
        // Dense all-reduce payloads unless --reduce lowrank.
        assert_eq!(c.reduce, ReduceMode::Dense);
    }
    // End-to-end trainer tests live in rust/tests/train_loop.rs (they
    // need the AOT artifacts); the artifact-free equivalence and resume
    // suites live in rust/tests/parallel_equivalence.rs.
}
