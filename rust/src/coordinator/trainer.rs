//! The training loop: L2 gradients through PJRT, L3 optimizer updates,
//! period scheduling, eval, checkpoints, metrics.

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::data::corpus::{CorpusSpec, SyntheticCorpus, ALL_DOMAINS};
use crate::data::loader::BatchLoader;
use crate::data::tokenizer::ByteTokenizer;
use crate::model::{init_param_store, registry, ParamStore};
use crate::optim::{self, StepCtx};
use crate::rng::{derive_seed, Pcg};
use crate::runtime::{Executor, ModelRunner};
use crate::util::timer::Timer;

use super::eval::DomainProbe;
use super::metrics::MetricsLog;
use super::scheduler::{LrSchedule, PeriodScheduler};
use super::checkpoint::save_checkpoint;

/// Full training-run configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub model: String,
    pub optimizer: String,
    pub lr: f64,
    pub steps: usize,
    /// Sampling period K (projector refresh / momentum restart /
    /// layer resampling cadence).
    pub period_k: usize,
    /// Projection rank r.
    pub rank: usize,
    /// Expected number of full-rank blocks γ (GUM/LISA).
    pub gamma: f64,
    pub seed: u64,
    pub warmup: usize,
    /// Evaluate held-out loss every N steps (0 = off).
    pub eval_every: usize,
    pub eval_batches: usize,
    /// Save checkpoints every N steps into `out_dir` (0 = off).
    pub ckpt_every: usize,
    /// Run the 7-domain probe suite at the end.
    pub probes: bool,
    pub probe_items: usize,
    pub artifacts_dir: PathBuf,
    pub out_dir: Option<PathBuf>,
    /// Log every N steps.
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "micro".into(),
            optimizer: "gum".into(),
            lr: 0.01,
            steps: 100,
            period_k: 20,
            rank: 16,
            gamma: 2.0,
            seed: 0,
            warmup: 10,
            eval_every: 0,
            eval_batches: 4,
            ckpt_every: 0,
            probes: false,
            probe_items: 24,
            artifacts_dir: PathBuf::from("artifacts"),
            out_dir: None,
            log_every: 10,
        }
    }
}

/// Result of a training run.
pub struct TrainResult {
    pub metrics: MetricsLog,
    pub params: ParamStore,
    /// (domain name, accuracy) for the probe suite, if run.
    pub probe_scores: Vec<(String, f64)>,
    pub final_train_loss: f64,
    pub final_val_loss: Option<f64>,
    pub optimizer_name: String,
    pub state_bytes: usize,
}

/// Orchestrates one training run end-to-end.
pub struct Trainer {
    pub cfg: TrainConfig,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Trainer {
        Trainer { cfg }
    }

    pub fn run(&self) -> Result<TrainResult> {
        let cfg = &self.cfg;
        let model_cfg = registry::get(&cfg.model)
            .with_context(|| format!("unknown model '{}'", cfg.model))?;

        let mut exec = Executor::new(&cfg.artifacts_dir)?;
        let runner = ModelRunner::new(&exec, &model_cfg)?;
        crate::info!(
            "trainer: model={} opt={} steps={} K={} r={} γ={} on {}",
            cfg.model,
            cfg.optimizer,
            cfg.steps,
            cfg.period_k,
            cfg.rank,
            cfg.gamma,
            exec.platform()
        );

        let mut params = init_param_store(&model_cfg, cfg.seed);
        let mut opt = optim::build(
            &cfg.optimizer,
            &params,
            cfg.rank,
            cfg.gamma,
            derive_seed(cfg.seed, "opt"),
        )?;

        let tok = ByteTokenizer::new(model_cfg.vocab);
        let corpus_spec = CorpusSpec {
            seed: derive_seed(cfg.seed, "corpus"),
            ..CorpusSpec::default()
        };
        let mut loader = BatchLoader::new(
            SyntheticCorpus::new(corpus_spec.clone()),
            tok.clone(),
            model_cfg.batch,
            model_cfg.seq_len,
        );
        // Held-out stream for validation (far beyond the train docs).
        let mut val_loader = BatchLoader::new(
            SyntheticCorpus::new(corpus_spec.clone()),
            tok.clone(),
            model_cfg.batch,
            model_cfg.seq_len,
        )
        .with_doc_offset(1_000_000);

        let schedule = LrSchedule::warmup_cosine(cfg.lr, cfg.warmup, cfg.steps);
        let periods = PeriodScheduler::new(cfg.period_k);
        let mut rng = Pcg::new(derive_seed(cfg.seed, "trainer"));
        let mut metrics = MetricsLog::new();
        let mut final_val = None;
        let run_timer = Timer::start();

        for step in 0..cfg.steps {
            let batch = loader.next_batch();
            let t = Timer::start();
            let out =
                runner.grad_step(&mut exec, &params, &batch.tokens, &batch.targets)?;
            let grad_s = t.elapsed_s();

            if periods.is_period_start(step) {
                opt.begin_period(&params, &out.grads, &mut rng);
            }
            let t = Timer::start();
            opt.step(
                &mut params,
                &out.grads,
                &StepCtx {
                    lr: schedule.at(step) as f32,
                    step,
                },
            );
            let opt_s = t.elapsed_s();

            metrics.push(step, "train_loss", out.loss as f64);
            metrics.push(step, "lr", schedule.at(step));
            metrics.push(step, "grad_time_s", grad_s);
            metrics.push(step, "opt_time_s", opt_s);
            metrics.push(
                step,
                "tokens_per_s",
                batch.token_count() as f64 / (grad_s + opt_s),
            );
            metrics.push(step, "state_bytes", opt.state_bytes() as f64);

            if cfg.log_every > 0 && step % cfg.log_every == 0 {
                crate::info!(
                    "step {step:>5} loss {:.4} lr {:.2e} {:.0} tok/s state {}",
                    out.loss,
                    schedule.at(step),
                    batch.token_count() as f64 / (grad_s + opt_s),
                    crate::optim::bytes_human(opt.state_bytes())
                );
            }

            if cfg.eval_every > 0
                && (step + 1) % cfg.eval_every == 0
            {
                let val = self.val_loss(
                    &runner,
                    &mut exec,
                    &params,
                    &mut val_loader,
                )?;
                metrics.push(step, "val_loss", val);
                final_val = Some(val);
                crate::info!("step {step:>5} val_loss {val:.4}");
            }

            if cfg.ckpt_every > 0
                && (step + 1) % cfg.ckpt_every == 0
            {
                if let Some(dir) = &cfg.out_dir {
                    let p = dir.join(format!("ckpt_{:06}.bin", step + 1));
                    save_checkpoint(&params, &p)?;
                }
            }
        }

        // Final probe suite.
        let mut probe_scores = Vec::new();
        if cfg.probes {
            let corpus = SyntheticCorpus::new(corpus_spec);
            for d in ALL_DOMAINS {
                let probe = DomainProbe::build(
                    &corpus,
                    &tok,
                    d,
                    cfg.probe_items,
                    4,
                    model_cfg.seq_len,
                    2_000_000 + 10_000 * d as u64,
                );
                let acc = probe.evaluate(&runner, &mut exec, &params)?;
                metrics.push(cfg.steps, &format!("probe/{}", d.name()), acc);
                probe_scores.push((d.name().to_string(), acc));
            }
        }

        if let Some(dir) = &cfg.out_dir {
            std::fs::create_dir_all(dir).ok();
            metrics.write_csv(&dir.join("metrics.csv"))?;
            save_checkpoint(&params, &dir.join("final.bin"))?;
        }

        let final_train_loss =
            metrics.tail_mean("train_loss", 10).unwrap_or(f64::NAN);
        crate::info!(
            "run done in {:.1}s: final loss {:.4}",
            run_timer.elapsed_s(),
            final_train_loss
        );
        Ok(TrainResult {
            final_train_loss,
            final_val_loss: final_val,
            probe_scores,
            state_bytes: opt.state_bytes(),
            optimizer_name: opt.name(),
            metrics,
            params,
        })
    }

    fn val_loss(
        &self,
        runner: &ModelRunner,
        exec: &mut Executor,
        params: &ParamStore,
        val_loader: &mut BatchLoader,
    ) -> Result<f64> {
        let mut total = 0.0;
        for _ in 0..self.cfg.eval_batches {
            let b = val_loader.next_batch();
            let (loss, _) = runner.eval(exec, params, &b.tokens, &b.targets)?;
            total += loss as f64;
        }
        Ok(total / self.cfg.eval_batches as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_sane() {
        let c = TrainConfig::default();
        assert_eq!(c.model, "micro");
        assert!(c.period_k >= 1);
        assert!(c.lr > 0.0);
    }
    // End-to-end trainer tests live in rust/tests/train_loop.rs (they
    // need the AOT artifacts).
}
