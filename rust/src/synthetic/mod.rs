//! Synthetic optimization problems from the paper's Section 5.1.

pub mod linreg;
pub mod quadratic;

pub use linreg::NoisyLinReg;
pub use quadratic::Quadratic;
