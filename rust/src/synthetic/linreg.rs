//! The paper's Figure-1 counterexample: noisy linear regression where
//! GaLore-Muon fails to converge but GUM matches full Muon.
//!
//!   min_X f(X) = ½‖A X‖_F² + ⟨B, X⟩,
//!   ∇f(X; ξ) = ∇f(X) + ξ·σ·C,
//!
//! with A = [I_{n−r} 0] ∈ R^{(n−r)×n}, B = [[D 0],[0 0]] (D Gaussian in
//! the top-left (n−r)² block), C = [[0 0],[0 I_r]], ξ ~ Bernoulli(½).
//!
//! The noise is rank-r and supported on exactly the coordinates the true
//! gradient never touches, so whenever the noise fires, GaLore's top-r
//! SVD projector locks onto pure noise directions and the projected
//! update carries no signal (paper §5.1's analysis). GUM's compensated
//! full-rank samples restore the signal in expectation.

use crate::linalg::Matrix;
use crate::rng::Pcg;

/// Problem instance (n×n parameter, rank-r noise).
pub struct NoisyLinReg {
    pub n: usize,
    pub r: usize,
    pub sigma: f32,
    /// D: (n−r)×(n−r) Gaussian block of B.
    d: Matrix,
    /// Minimum value of f (for adjusted-loss curves): f* = −½‖D‖_F².
    pub f_star: f64,
}

impl NoisyLinReg {
    pub fn new(n: usize, r: usize, sigma: f32, seed: u64) -> NoisyLinReg {
        assert!(r < n);
        let mut rng = Pcg::new(seed);
        let d = Matrix::randn(n - r, n - r, 1.0, &mut rng);
        // f(X) = ½‖X_top‖² + ⟨D, X_top-left⟩ over the (n−r)-row block;
        // minimized at X_top-left = −D (other top rows 0): f* = −½‖D‖².
        let f_star: f64 = -0.5
            * d.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>();
        NoisyLinReg { n, r, sigma, d, f_star }
    }

    /// Exact objective value.
    pub fn loss(&self, x: &Matrix) -> f64 {
        assert_eq!(x.shape(), (self.n, self.n));
        let k = self.n - self.r;
        let mut quad = 0.0f64;
        // ‖A X‖² = sum over first k rows of X.
        for i in 0..k {
            for j in 0..self.n {
                let v = x.at(i, j) as f64;
                quad += v * v;
            }
        }
        let mut lin = 0.0f64;
        for i in 0..k {
            for j in 0..k {
                lin += self.d.at(i, j) as f64 * x.at(i, j) as f64;
            }
        }
        0.5 * quad + lin
    }

    /// Adjusted loss f(X) − f* (≥ 0; what Figure 1 plots).
    pub fn adjusted_loss(&self, x: &Matrix) -> f64 {
        self.loss(x) - self.f_star
    }

    /// Deterministic gradient ∇f(X) = AᵀA X + B.
    pub fn grad_exact(&self, x: &Matrix) -> Matrix {
        let k = self.n - self.r;
        let mut g = Matrix::zeros(self.n, self.n);
        for i in 0..k {
            for j in 0..self.n {
                *g.at_mut(i, j) = x.at(i, j);
            }
        }
        for i in 0..k {
            for j in 0..k {
                *g.at_mut(i, j) += self.d.at(i, j);
            }
        }
        g
    }

    /// Stochastic gradient: exact + ξ·σ·C with ξ ~ Bernoulli(½).
    pub fn grad_stochastic(&self, x: &Matrix, rng: &mut Pcg) -> Matrix {
        let mut g = self.grad_exact(x);
        if rng.bernoulli(0.5) {
            let k = self.n - self.r;
            for i in k..self.n {
                *g.at_mut(i, i) += self.sigma;
            }
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::fro_norm;

    #[test]
    fn loss_minimum_is_f_star() {
        let p = NoisyLinReg::new(10, 4, 50.0, 0);
        // Optimal X: top-left = −D, rest 0.
        let mut x = Matrix::zeros(10, 10);
        for i in 0..6 {
            for j in 0..6 {
                *x.at_mut(i, j) = -p.d.at(i, j);
            }
        }
        assert!((p.loss(&x) - p.f_star).abs() < 1e-6);
        assert!(p.adjusted_loss(&x) < 1e-6);
        // Any other point is worse.
        let x2 = Matrix::zeros(10, 10);
        assert!(p.adjusted_loss(&x2) > p.adjusted_loss(&x));
    }

    #[test]
    fn gradient_is_zero_at_optimum() {
        let p = NoisyLinReg::new(8, 3, 10.0, 1);
        let mut x = Matrix::zeros(8, 8);
        for i in 0..5 {
            for j in 0..5 {
                *x.at_mut(i, j) = -p.d.at(i, j);
            }
        }
        assert!(fro_norm(&p.grad_exact(&x)) < 1e-6);
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let p = NoisyLinReg::new(6, 2, 1.0, 2);
        let mut rng = Pcg::new(3);
        let x = Matrix::randn(6, 6, 1.0, &mut rng);
        let g = p.grad_exact(&x);
        let eps = 1e-3;
        for (i, j) in [(0usize, 0usize), (2, 4), (3, 3), (5, 5), (4, 1)] {
            let mut xp = x.clone();
            *xp.at_mut(i, j) += eps;
            let mut xm = x.clone();
            *xm.at_mut(i, j) -= eps;
            let fd = (p.loss(&xp) - p.loss(&xm)) / (2.0 * eps as f64);
            assert!(
                (g.at(i, j) as f64 - fd).abs() < 1e-2,
                "({i},{j}): {} vs {}",
                g.at(i, j),
                fd
            );
        }
    }

    #[test]
    fn noise_is_rank_r_and_mean_half_sigma() {
        let p = NoisyLinReg::new(10, 4, 100.0, 4);
        let x = Matrix::zeros(10, 10);
        let mut rng = Pcg::new(5);
        let mut fired = 0;
        for _ in 0..200 {
            let g = p.grad_stochastic(&x, &mut rng);
            let noise = g.sub(&p.grad_exact(&x));
            let nn = fro_norm(&noise);
            if nn > 0.0 {
                fired += 1;
                // Noise is σ·I_r on the bottom-right diagonal:
                // ‖σ·I_4‖_F = σ·√4 = 200.
                assert!((nn - 200.0).abs() < 1e-2, "noise norm {nn}");
                // Supported only on the bottom-right block.
                for i in 0..10 {
                    for j in 0..10 {
                        if i < 6 || j < 6 {
                            assert_eq!(noise.at(i, j), 0.0);
                        }
                    }
                }
            }
        }
        let rate = fired as f64 / 200.0;
        assert!((rate - 0.5).abs() < 0.1, "rate {rate}");
    }
}
