//! Simple matrix quadratic ½‖W − T‖_F² — the sanity problem used by the
//! theory-scaling experiment (E9) and optimizer unit benches.

use crate::linalg::Matrix;
use crate::rng::Pcg;

/// min_W ½‖W − T‖² with optional isotropic gradient noise.
pub struct Quadratic {
    pub target: Matrix,
    pub noise_std: f32,
}

impl Quadratic {
    pub fn new(m: usize, n: usize, noise_std: f32, seed: u64) -> Quadratic {
        let mut rng = Pcg::new(seed);
        Quadratic {
            target: Matrix::randn(m, n, 1.0, &mut rng),
            noise_std,
        }
    }

    pub fn loss(&self, w: &Matrix) -> f64 {
        w.data
            .iter()
            .zip(&self.target.data)
            .map(|(a, b)| {
                let d = (a - b) as f64;
                0.5 * d * d
            })
            .sum()
    }

    pub fn grad(&self, w: &Matrix, rng: &mut Pcg) -> Matrix {
        let mut g = w.sub(&self.target);
        if self.noise_std > 0.0 {
            for v in &mut g.data {
                *v += self.noise_std * rng.normal_f32();
            }
        }
        g
    }

    /// Exact gradient norm at w (for Theorem-1 style ‖∇f‖ tracking).
    pub fn grad_norm(&self, w: &Matrix) -> f64 {
        crate::linalg::fro_norm(&w.sub(&self.target)) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_zero_at_target() {
        let q = Quadratic::new(5, 7, 0.0, 0);
        assert!(q.loss(&q.target.clone()) < 1e-10);
        assert!(q.grad_norm(&q.target.clone()) < 1e-6);
    }

    #[test]
    fn noisy_grad_is_unbiased() {
        let q = Quadratic::new(4, 4, 2.0, 1);
        let w = Matrix::zeros(4, 4);
        let mut rng = Pcg::new(2);
        let mut mean = Matrix::zeros(4, 4);
        let n = 3000;
        for _ in 0..n {
            mean.add_scaled_in_place(1.0 / n as f32, &q.grad(&w, &mut rng));
        }
        let exact = w.sub(&q.target);
        assert!(mean.max_abs_diff(&exact) < 0.15);
    }
}
