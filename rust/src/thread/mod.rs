//! Data-parallel substrate: a **persistent worker pool** behind
//! `parallel_chunks` (no rayon in the offline registry).
//!
//! §Perf note: the first implementation spawned OS threads per call via
//! `std::thread::scope`; with Muon's ~560 small GEMMs per optimizer step
//! that meant thousands of thread spawns per training step and made the
//! optimizer 5× the cost of the whole fwd/bwd. The pool keeps workers
//! parked on a condvar; dispatch cost is ~a few µs. See EXPERIMENTS.md
//! §Perf for before/after.
//!
//! Waiting is **cooperative**: a caller blocked on its latch drains the
//! shared job queue instead of sleeping (`wait_helping`), so
//! `parallel_chunks` may be called from inside pool workers — the
//! replica lanes of the data-parallel coordinator
//! (`coordinator::parallel`) nest GEMM parallelism this way without
//! deadlock, because every pending chunk is runnable by whichever
//! thread is waiting on it.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

static CACHED_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Number of worker threads to use (env `GUM_THREADS` overrides).
pub fn num_threads() -> usize {
    let cached = CACHED_THREADS.load(Ordering::Relaxed);
    if cached != 0 {
        return cached;
    }
    let n = std::env::var("GUM_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&v| v >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|v| v.get())
                .unwrap_or(1)
        });
    CACHED_THREADS.store(n, Ordering::Relaxed);
    n
}

/// Override the chunking width at runtime (tests/benches — the in-process
/// equivalent of re-launching with a different `GUM_THREADS`). The
/// persistent worker pool keeps whatever size it was first built with;
/// widths larger than the pool still complete because waiters execute
/// queued chunks themselves (see `wait_helping`). Returns the previous
/// width so callers can restore it.
pub fn set_num_threads(n: usize) -> usize {
    let prev = num_threads();
    CACHED_THREADS.store(n.max(1), Ordering::Relaxed);
    prev
}

/// A unit of work: closure pointer + argument range + completion latch.
/// The closure outlives the job because `parallel_chunks` blocks until
/// every chunk completes before returning (scoped semantics by latch).
struct Job {
    /// Type-erased `&(dyn Fn(usize, usize) + Sync)`.
    run: unsafe fn(*const (), usize, usize),
    ctx: *const (),
    start: usize,
    end: usize,
    done: *const Latch,
}
unsafe impl Send for Job {}

impl Job {
    /// Execute the chunk and release its latch.
    ///
    /// Panic-isolating: a chunk that unwinds must not kill the worker
    /// thread (the pool would silently shrink) and must still release
    /// the latch (the submitter would deadlock). The payload is stashed
    /// in the latch and rethrown on the submitting thread — so a panic
    /// in a gradient lane or a nested GEMM surfaces where the elastic
    /// supervisor can catch it, never in pool machinery.
    ///
    /// SAFETY: the submitting thread waits on the latch before dropping
    /// `ctx`, so both pointers are live until `count_down` runs.
    unsafe fn execute(self) {
        let result = catch_unwind(AssertUnwindSafe(|| unsafe {
            (self.run)(self.ctx, self.start, self.end)
        }));
        unsafe {
            if let Err(payload) = result {
                (*self.done).record_panic(payload);
            }
            (*self.done).count_down();
        }
    }
}

struct Latch {
    remaining: AtomicUsize,
    notify: Mutex<()>,
    cv: Condvar,
    /// First panic payload from any chunk of this dispatch; rethrown on
    /// the submitting thread once every chunk has retired.
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch {
            remaining: AtomicUsize::new(n),
            notify: Mutex::new(()),
            cv: Condvar::new(),
            panic: Mutex::new(None),
        }
    }

    fn record_panic(&self, payload: Box<dyn Any + Send>) {
        let mut slot = self.panic.lock().unwrap_or_else(|e| e.into_inner());
        if slot.is_none() {
            *slot = Some(payload);
        }
    }

    fn take_panic(&self) -> Option<Box<dyn Any + Send>> {
        self.panic.lock().unwrap_or_else(|e| e.into_inner()).take()
    }

    fn count_down(&self) {
        // The decrement happens *under* the notify lock so a waiter that
        // observed `remaining == 0` can serialize with the final worker
        // (see `close`) before destroying the latch. With a bare
        // fetch_sub, the worker could sit between the decrement and the
        // notify while the stack frame owning the latch unwinds —
        // a use-after-free on the mutex/condvar.
        let _g = self.notify.lock().unwrap();
        if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            self.cv.notify_all();
        }
    }

    /// Lock-free completion check — a fast-path hint only. The latch
    /// owner must serialize through [`Latch::close`] (or observe
    /// completion inside `wait_timeout`, which holds the lock) before
    /// letting the latch drop.
    fn done(&self) -> bool {
        self.remaining.load(Ordering::Acquire) == 0
    }

    /// Serialize with the final `count_down`: every decrement happens
    /// under the notify lock, so once this acquires the lock after
    /// `done()` read true, no worker will touch this latch again.
    fn close(&self) {
        let _g = self.notify.lock().unwrap();
    }

    /// Park until notified or `dur` elapses; true when the latch is
    /// open. The completion check holds the notify lock, so a `true`
    /// return already serializes with the final worker.
    fn wait_timeout(&self, dur: Duration) -> bool {
        let g = self.notify.lock().unwrap();
        if self.done() {
            return true;
        }
        let _g = self.cv.wait_timeout(g, dur).unwrap();
        self.done()
    }
}

/// A queued unit of pool work: either one chunk of a blocking
/// `parallel_chunks` dispatch (raw-pointer `Job`, submitter keeps the
/// closure alive), or a detached owned task from [`spawn_background`]
/// (fully self-contained, completion signalled through its own latch).
enum Work {
    Chunk(Job),
    Task(Box<dyn FnOnce() + Send>),
}

impl Work {
    /// Execute this unit on the current thread. Panic-isolating for both
    /// variants: chunk panics are stashed in the dispatch latch (see
    /// [`Job::execute`]); task closures do their own payload capture
    /// (see [`spawn_background`]), so a stray unwind is swallowed here
    /// rather than killing a pool worker.
    fn execute(self) {
        match self {
            // SAFETY: submitter keeps ctx/latch alive (see Job).
            Work::Chunk(job) => unsafe { job.execute() },
            Work::Task(f) => {
                let _ = catch_unwind(AssertUnwindSafe(f));
            }
        }
    }
}

/// FIFO job queue. Workers block on the condvar; helpers only `try_pop`,
/// so the lock is never held across a blocking wait for new work.
struct JobQueue {
    jobs: Mutex<VecDeque<Work>>,
    cv: Condvar,
}

impl JobQueue {
    fn new() -> JobQueue {
        JobQueue {
            jobs: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
        }
    }

    fn push(&self, work: Work) {
        self.jobs.lock().unwrap().push_back(work);
        self.cv.notify_one();
    }

    fn pop_blocking(&self) -> Work {
        let mut guard = self.jobs.lock().unwrap();
        loop {
            if let Some(work) = guard.pop_front() {
                return work;
            }
            guard = self.cv.wait(guard).unwrap();
        }
    }

    fn try_pop(&self) -> Option<Work> {
        self.jobs.lock().unwrap().pop_front()
    }
}

static POOL: OnceLock<&'static JobQueue> = OnceLock::new();

fn pool() -> &'static JobQueue {
    *POOL.get_or_init(|| {
        let queue: &'static JobQueue = Box::leak(Box::new(JobQueue::new()));
        // N−1 workers; the calling thread always runs one chunk itself.
        for _ in 0..num_threads().saturating_sub(1) {
            std::thread::Builder::new()
                .name("gum-worker".into())
                .spawn(move || loop {
                    queue.pop_blocking().execute();
                })
                .expect("spawning worker");
        }
        queue
    })
}

/// Wait on `latch` while *helping*: drain queued jobs (ours or anyone
/// else's) instead of sleeping. This is what makes nested
/// `parallel_chunks` calls deadlock-free — if every worker is occupied,
/// each waiting caller executes pending chunks itself, so some pending
/// chunk always has a thread able to run it.
fn wait_helping(latch: &Latch, queue: &JobQueue) {
    loop {
        if latch.done() {
            latch.close();
            return;
        }
        match queue.try_pop() {
            Some(work) => work.execute(),
            None => {
                // Our chunks are in flight on other threads; park briefly.
                // The timeout re-polls the queue in case those chunks
                // spawn nested jobs we should help with.
                if latch.wait_timeout(Duration::from_micros(200)) {
                    return;
                }
            }
        }
    }
}

unsafe fn run_erased<F: Fn(usize, usize) + Sync>(
    ctx: *const (),
    start: usize,
    end: usize,
) {
    let f = unsafe { &*(ctx as *const F) };
    f(start, end);
}

/// Run `f(start, end)` over disjoint chunks of `0..len` in parallel.
///
/// Chunks are contiguous ranges so memory access stays streaming-
/// friendly. Small inputs (fewer than `min_chunk` items per available
/// thread) run inline — dispatch overhead is only paid when it pays off.
///
/// Determinism contract: which thread runs a chunk is unspecified, but
/// every chunk is a pure function of its `(start, end)` range, so any
/// algorithm whose per-index work is independent of the chunking (GEMM
/// rows, `parallel_map` slots, per-block tree reductions) produces
/// bit-identical results under any `GUM_THREADS`.
pub fn parallel_chunks<F>(len: usize, min_chunk: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let threads = num_threads().min(len / min_chunk.max(1)).max(1);
    if threads <= 1 || len == 0 {
        if len > 0 {
            f(0, len);
        }
        return;
    }
    let chunk = len.div_ceil(threads);
    let latch = Latch::new(threads - 1);
    let queue = pool();
    for t in 1..threads {
        let start = t * chunk;
        let end = ((t + 1) * chunk).min(len);
        if start >= end {
            latch.count_down();
            continue;
        }
        queue.push(Work::Chunk(Job {
            run: run_erased::<F>,
            ctx: &f as *const F as *const (),
            start,
            end,
            done: &latch as *const Latch,
        }));
    }
    // The caller runs chunk 0 itself, then helps until the rest finish.
    // The inline chunk is panic-isolated like worker chunks: the latch
    // must fully retire before anything unwinds out of this frame (the
    // pending jobs borrow `f` and the latch), then the first payload —
    // inline first, workers second — is rethrown.
    let inline = catch_unwind(AssertUnwindSafe(|| f(0, chunk.min(len))));
    wait_helping(&latch, queue);
    if let Err(payload) = inline {
        resume_unwind(payload);
    }
    if let Some(payload) = latch.take_panic() {
        resume_unwind(payload);
    }
}

/// Completion state shared between a background task and its handle.
struct TaskState<T> {
    latch: Latch,
    slot: Mutex<Option<std::thread::Result<T>>>,
}

/// Handle to a detached pool task started by [`spawn_background`]. The
/// task keeps running if the handle is dropped (its shared state is
/// reference-counted), so dropping is a cancel-by-abandonment: the
/// result is discarded whenever the task eventually retires.
pub struct BackgroundTask<T> {
    shared: Arc<TaskState<T>>,
}

impl<T: Send + 'static> BackgroundTask<T> {
    /// Lock-free completion check (a hint — `join` does the
    /// serialization).
    pub fn is_finished(&self) -> bool {
        self.shared.latch.done()
    }

    /// Wait for the task *helping*: while blocked, this thread drains
    /// queued pool work (including, possibly, the task itself — which
    /// is what makes joining safe from inside pool workers and under
    /// `GUM_THREADS=1`, where the pool has no dedicated workers).
    /// Rethrows the task's panic on the joining thread.
    pub fn join(self) -> T {
        wait_helping(&self.shared.latch, pool());
        let result = self
            .shared
            .slot
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take()
            .expect("background task latch opened without a result");
        match result {
            Ok(v) => v,
            Err(payload) => resume_unwind(payload),
        }
    }
}

/// Run `f` on the worker pool as a detached, owned task, returning a
/// waitable handle. Unlike [`parallel_chunks`] this does not block: the
/// caller keeps executing while the pool runs `f` — the primitive behind
/// the off-critical-path projector-refresh pipeline
/// (`optim::refresh_pipeline`). The closure is fully owned by the queue
/// entry, so there are no lifetime obligations on the caller.
pub fn spawn_background<T, F>(f: F) -> BackgroundTask<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let shared = Arc::new(TaskState {
        latch: Latch::new(1),
        slot: Mutex::new(None),
    });
    let state = Arc::clone(&shared);
    pool().push(Work::Task(Box::new(move || {
        let out = catch_unwind(AssertUnwindSafe(f));
        *state.slot.lock().unwrap_or_else(|e| e.into_inner()) = Some(out);
        // `state` (and with it the latch) stays alive past the
        // count-down because this closure owns its own Arc clone.
        state.latch.count_down();
    })));
    BackgroundTask { shared }
}

/// Map `f` over `0..len` in parallel, collecting results in index order.
pub fn parallel_map<T, F>(len: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<Option<T>> = (0..len).map(|_| None).collect();
    {
        let slots = as_send_ptr(&mut out);
        parallel_chunks(len, 1, |start, end| {
            let slots = &slots;
            for i in start..end {
                // SAFETY: each index is written by exactly one chunk.
                unsafe {
                    *slots.0.add(i) = Some(f(i));
                }
            }
        });
    }
    out.into_iter().map(|v| v.unwrap()).collect()
}

struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

fn as_send_ptr<T>(v: &mut Vec<T>) -> SendPtr<T> {
    SendPtr(v.as_mut_ptr())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunks_cover_range_exactly_once() {
        let sum = AtomicU64::new(0);
        parallel_chunks(1000, 8, |s, e| {
            for i in s..e {
                sum.fetch_add(i as u64, Ordering::Relaxed);
            }
        });
        assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2);
    }

    #[test]
    fn chunks_small_input_runs_inline() {
        let sum = AtomicU64::new(0);
        parallel_chunks(3, 100, |s, e| {
            sum.fetch_add((e - s) as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn chunks_empty_is_noop() {
        parallel_chunks(0, 1, |_, _| panic!("must not run"));
    }

    #[test]
    fn map_preserves_order() {
        let out = parallel_map(257, |i| i * i);
        assert_eq!(out.len(), 257);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn reentrant_calls_do_not_deadlock() {
        // Many successive dispatches through the persistent pool.
        for round in 0..200 {
            let sum = AtomicU64::new(0);
            parallel_chunks(64, 1, |s, e| {
                for i in s..e {
                    sum.fetch_add((i + round) as u64, Ordering::Relaxed);
                }
            });
            let expect: u64 =
                (0..64).map(|i| (i + round) as u64).sum();
            assert_eq!(sum.load(Ordering::Relaxed), expect);
        }
    }

    #[test]
    fn nested_parallel_calls_complete() {
        // Outer chunks each dispatch an inner parallel loop; under the
        // old blocking wait this deadlocked once the pool saturated.
        let total = AtomicU64::new(0);
        parallel_chunks(8, 1, |s, e| {
            for _ in s..e {
                let inner = AtomicU64::new(0);
                parallel_chunks(64, 1, |a, b| {
                    inner.fetch_add((b - a) as u64, Ordering::Relaxed);
                });
                total.fetch_add(inner.load(Ordering::Relaxed), Ordering::Relaxed);
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 8 * 64);
    }

    #[test]
    fn width_override_still_covers_range() {
        let orig = num_threads();
        for n in [1usize, 2, 8, 16] {
            set_num_threads(n);
            let sum = AtomicU64::new(0);
            parallel_chunks(1000, 1, |s, e| {
                for i in s..e {
                    sum.fetch_add(i as u64, Ordering::Relaxed);
                }
            });
            assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2, "width {n}");
        }
        set_num_threads(orig);
    }

    #[test]
    fn chunk_panics_propagate_to_caller_and_pool_survives() {
        for round in 0..3 {
            let caught = std::panic::catch_unwind(|| {
                parallel_chunks(64, 1, |s, e| {
                    for i in s..e {
                        if i == 50 {
                            panic!("chunk bug at {i}");
                        }
                    }
                });
            });
            let payload = caught.expect_err("panic must propagate");
            let msg = payload
                .downcast_ref::<String>()
                .expect("string payload preserved");
            assert!(msg.contains("chunk bug"), "round {round}: {msg}");
            // Workers caught the unwind and stayed alive: the next
            // dispatch must complete normally.
            let sum = AtomicU64::new(0);
            parallel_chunks(1000, 1, |s, e| {
                for i in s..e {
                    sum.fetch_add(i as u64, Ordering::Relaxed);
                }
            });
            assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2);
        }
    }

    #[test]
    fn typed_panic_payloads_survive_the_pool() {
        #[derive(Debug, PartialEq)]
        struct Marker(usize);
        let caught = std::panic::catch_unwind(|| {
            parallel_chunks(64, 1, |s, e| {
                for i in s..e {
                    if i == 63 {
                        std::panic::panic_any(Marker(i));
                    }
                }
            });
        });
        let payload = caught.expect_err("panic must propagate");
        assert_eq!(payload.downcast_ref::<Marker>(), Some(&Marker(63)));
    }

    #[test]
    fn parallel_results_match_serial() {
        let serial: Vec<usize> = (0..1000).map(|i| i * 3).collect();
        let par = parallel_map(1000, |i| i * 3);
        assert_eq!(serial, par);
    }

    #[test]
    fn background_task_joins_with_result() {
        let task = spawn_background(|| (0..100u64).sum::<u64>());
        assert_eq!(task.join(), 4950);
    }

    #[test]
    fn background_task_runs_concurrently_with_dispatches() {
        // A detached task must complete while the submitting thread keeps
        // dispatching chunk work through the same pool.
        let task = spawn_background(|| {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        let sum = AtomicU64::new(0);
        parallel_chunks(512, 1, |s, e| {
            for i in s..e {
                sum.fetch_add(i as u64, Ordering::Relaxed);
            }
        });
        assert_eq!(sum.load(Ordering::Relaxed), 511 * 512 / 2);
        let want: u64 = (0..10_000u64).fold(0, |a, i| a.wrapping_add(i * i));
        assert_eq!(task.join(), want);
    }

    #[test]
    fn background_task_can_spawn_nested_parallel_work() {
        let task = spawn_background(|| {
            let inner = AtomicU64::new(0);
            parallel_chunks(256, 1, |s, e| {
                inner.fetch_add((e - s) as u64, Ordering::Relaxed);
            });
            inner.load(Ordering::Relaxed)
        });
        assert_eq!(task.join(), 256);
    }

    #[test]
    fn background_task_panic_rethrows_on_join() {
        let task = spawn_background(|| -> u64 { panic!("task bug") });
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| task.join()));
        let payload = caught.expect_err("panic must surface at join");
        let msg = payload.downcast_ref::<&str>().expect("str payload");
        assert!(msg.contains("task bug"));
        // The pool survives: further work completes normally.
        assert_eq!(spawn_background(|| 7u32).join(), 7);
    }

    #[test]
    fn dropped_background_task_still_retires() {
        use std::sync::atomic::AtomicBool;
        let ran = Arc::new(AtomicBool::new(false));
        let flag = ran.clone();
        drop(spawn_background(move || {
            flag.store(true, Ordering::SeqCst);
        }));
        // FIFO pop order means a later task starts after the dropped one,
        // but completion may interleave — poll briefly for the flag.
        spawn_background(|| ()).join();
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while !ran.load(Ordering::SeqCst) {
            assert!(
                std::time::Instant::now() < deadline,
                "dropped task never ran"
            );
            std::thread::yield_now();
        }
    }
}
