//! Data-parallel substrate: a **persistent worker pool** behind
//! `parallel_chunks` (no rayon in the offline registry).
//!
//! §Perf note: the first implementation spawned OS threads per call via
//! `std::thread::scope`; with Muon's ~560 small GEMMs per optimizer step
//! that meant thousands of thread spawns per training step and made the
//! optimizer 5× the cost of the whole fwd/bwd. The pool keeps workers
//! parked on a channel; dispatch cost is ~a few µs. See EXPERIMENTS.md
//! §Perf for before/after.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex, OnceLock};

/// Number of worker threads to use (env `GUM_THREADS` overrides).
pub fn num_threads() -> usize {
    static CACHED: AtomicUsize = AtomicUsize::new(0);
    let cached = CACHED.load(Ordering::Relaxed);
    if cached != 0 {
        return cached;
    }
    let n = std::env::var("GUM_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&v| v >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|v| v.get())
                .unwrap_or(1)
        });
    CACHED.store(n, Ordering::Relaxed);
    n
}

/// A unit of work: closure pointer + argument range + completion latch.
/// The closure outlives the job because `parallel_chunks` blocks until
/// every chunk completes before returning (scoped semantics by latch).
struct Job {
    /// Type-erased `&(dyn Fn(usize, usize) + Sync)`.
    run: unsafe fn(*const (), usize, usize),
    ctx: *const (),
    start: usize,
    end: usize,
    done: *const Latch,
}
unsafe impl Send for Job {}

struct Latch {
    remaining: AtomicUsize,
    notify: Mutex<()>,
    cv: std::sync::Condvar,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch {
            remaining: AtomicUsize::new(n),
            notify: Mutex::new(()),
            cv: std::sync::Condvar::new(),
        }
    }

    fn count_down(&self) {
        if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            let _g = self.notify.lock().unwrap();
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut g = self.notify.lock().unwrap();
        while self.remaining.load(Ordering::Acquire) != 0 {
            g = self.cv.wait(g).unwrap();
        }
    }
}

struct Pool {
    sender: mpsc::Sender<Job>,
}

static POOL: OnceLock<Mutex<Pool>> = OnceLock::new();

fn pool() -> &'static Mutex<Pool> {
    POOL.get_or_init(|| {
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = std::sync::Arc::new(Mutex::new(rx));
        // N−1 workers; the calling thread always runs one chunk itself.
        for _ in 0..num_threads().saturating_sub(1) {
            let rx = rx.clone();
            std::thread::Builder::new()
                .name("gum-worker".into())
                .spawn(move || loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match job {
                        Ok(job) => {
                            // SAFETY: the submitting thread waits on the
                            // latch before dropping ctx.
                            unsafe {
                                (job.run)(job.ctx, job.start, job.end);
                                (*job.done).count_down();
                            }
                        }
                        Err(_) => return,
                    }
                })
                .expect("spawning worker");
        }
        Mutex::new(Pool { sender: tx })
    })
}

unsafe fn run_erased<F: Fn(usize, usize) + Sync>(
    ctx: *const (),
    start: usize,
    end: usize,
) {
    let f = unsafe { &*(ctx as *const F) };
    f(start, end);
}

/// Run `f(start, end)` over disjoint chunks of `0..len` in parallel.
///
/// Chunks are contiguous ranges so memory access stays streaming-
/// friendly. Small inputs (fewer than `min_chunk` items per available
/// thread) run inline — dispatch overhead is only paid when it pays off.
pub fn parallel_chunks<F>(len: usize, min_chunk: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let threads = num_threads().min(len / min_chunk.max(1)).max(1);
    if threads <= 1 || len == 0 {
        if len > 0 {
            f(0, len);
        }
        return;
    }
    let chunk = len.div_ceil(threads);
    let latch = Latch::new(threads - 1);
    {
        let sender = pool().lock().unwrap().sender.clone();
        for t in 1..threads {
            let start = t * chunk;
            let end = ((t + 1) * chunk).min(len);
            if start >= end {
                latch.count_down();
                continue;
            }
            sender
                .send(Job {
                    run: run_erased::<F>,
                    ctx: &f as *const F as *const (),
                    start,
                    end,
                    done: &latch as *const Latch,
                })
                .expect("pool send");
        }
    }
    // The caller runs chunk 0 itself, then waits for the rest.
    f(0, chunk.min(len));
    latch.wait();
}

/// Map `f` over `0..len` in parallel, collecting results in index order.
pub fn parallel_map<T, F>(len: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<Option<T>> = (0..len).map(|_| None).collect();
    {
        let slots = as_send_ptr(&mut out);
        parallel_chunks(len, 1, |start, end| {
            let slots = &slots;
            for i in start..end {
                // SAFETY: each index is written by exactly one chunk.
                unsafe {
                    *slots.0.add(i) = Some(f(i));
                }
            }
        });
    }
    out.into_iter().map(|v| v.unwrap()).collect()
}

struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

fn as_send_ptr<T>(v: &mut Vec<T>) -> SendPtr<T> {
    SendPtr(v.as_mut_ptr())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunks_cover_range_exactly_once() {
        let sum = AtomicU64::new(0);
        parallel_chunks(1000, 8, |s, e| {
            for i in s..e {
                sum.fetch_add(i as u64, Ordering::Relaxed);
            }
        });
        assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2);
    }

    #[test]
    fn chunks_small_input_runs_inline() {
        let sum = AtomicU64::new(0);
        parallel_chunks(3, 100, |s, e| {
            sum.fetch_add((e - s) as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn chunks_empty_is_noop() {
        parallel_chunks(0, 1, |_, _| panic!("must not run"));
    }

    #[test]
    fn map_preserves_order() {
        let out = parallel_map(257, |i| i * i);
        assert_eq!(out.len(), 257);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn reentrant_calls_do_not_deadlock() {
        // Many successive dispatches through the persistent pool.
        for round in 0..200 {
            let sum = AtomicU64::new(0);
            parallel_chunks(64, 1, |s, e| {
                for i in s..e {
                    sum.fetch_add((i + round) as u64, Ordering::Relaxed);
                }
            });
            let expect: u64 =
                (0..64).map(|i| (i + round) as u64).sum();
            assert_eq!(sum.load(Ordering::Relaxed), expect);
        }
    }

    #[test]
    fn parallel_results_match_serial() {
        let serial: Vec<usize> = (0..1000).map(|i| i * 3).collect();
        let par = parallel_map(1000, |i| i * 3);
        assert_eq!(serial, par);
    }
}
