//! Byte-level tokenizer with a small reserved-special-token prefix.
//!
//! Vocabulary layout: ids 0..SPECIALS are control tokens (pad/bos/eos/sep),
//! ids SPECIALS..SPECIALS+256 are raw bytes. The runnable model configs use
//! vocab ≥ 260, so every byte is always representable.

/// Number of reserved special tokens.
pub const SPECIALS: usize = 4;
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const SEP: i32 = 3;

/// Byte-level tokenizer. `vocab` is the model's vocabulary size; byte ids
/// are folded into `vocab` when the model vocab is smaller than 260
/// (micro/tiny configs use 256: bytes ≥ 252 alias, which is harmless for
/// ASCII synthetic corpora).
#[derive(Debug, Clone)]
pub struct ByteTokenizer {
    pub vocab: usize,
}

impl ByteTokenizer {
    pub fn new(vocab: usize) -> ByteTokenizer {
        assert!(vocab > SPECIALS + 1, "vocab too small");
        ByteTokenizer { vocab }
    }

    #[inline]
    pub fn byte_to_id(&self, b: u8) -> i32 {
        (SPECIALS + (b as usize) % (self.vocab - SPECIALS)) as i32
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.bytes().map(|b| self.byte_to_id(b)).collect()
    }

    /// Encode with BOS prefix and optional EOS.
    pub fn encode_with_specials(&self, text: &str, eos: bool) -> Vec<i32> {
        let mut ids = vec![BOS];
        ids.extend(self.encode(text));
        if eos {
            ids.push(EOS);
        }
        ids
    }

    /// Decode byte-range ids back to text (specials dropped). Only exact
    /// for vocab ≥ 260; ASCII is exact for vocab ≥ SPECIALS+128.
    pub fn decode(&self, ids: &[i32]) -> String {
        let bytes: Vec<u8> = ids
            .iter()
            .filter(|&&id| id >= SPECIALS as i32)
            .map(|&id| (id as usize - SPECIALS) as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascii_roundtrip() {
        let tok = ByteTokenizer::new(256);
        let text = "Hello, GUM! 123";
        let ids = tok.encode(text);
        assert_eq!(tok.decode(&ids), text);
        assert!(ids.iter().all(|&i| (SPECIALS as i32) <= i
            && i < tok.vocab as i32));
    }

    #[test]
    fn specials_framing() {
        let tok = ByteTokenizer::new(256);
        let ids = tok.encode_with_specials("ab", true);
        assert_eq!(ids[0], BOS);
        assert_eq!(*ids.last().unwrap(), EOS);
        assert_eq!(tok.decode(&ids), "ab");
    }

    #[test]
    fn ids_in_vocab_even_for_tiny_vocab() {
        let tok = ByteTokenizer::new(64);
        for b in 0..=255u8 {
            let id = tok.byte_to_id(b);
            assert!((SPECIALS as i32) <= id && id < 64);
        }
    }
}
