//! Data substrate: synthetic multi-domain corpus, byte tokenizer,
//! deterministic batch loader, and the fine-tuning task builders.
//!
//! The paper trains on C4 and fine-tunes on instruction/math sets; the
//! offline substitution (DESIGN.md §2) generates multi-domain text whose
//! token statistics are non-trivially structured (Zipf unigrams, Markov
//! bigram chains, templated grammars, arithmetic word problems) so that
//! low-rank-bias effects and per-domain score differences are visible.

pub mod corpus;
pub mod loader;
pub mod tasks;
pub mod tokenizer;

pub use corpus::{CorpusSpec, Domain, SyntheticCorpus};
pub use loader::{Batch, BatchLoader};
pub use tasks::{ArithmeticTask, InstructionTask, TaskExample};
pub use tokenizer::ByteTokenizer;
