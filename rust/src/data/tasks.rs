//! Fine-tuning task builders (the IFEval / GSM8K substitutes).
//!
//! Two task families, mirroring the paper's Table 2 structure:
//!
//! - [`InstructionTask`]: "instruction → constrained transformation"
//!   prompts (copy / reverse / uppercase / duplicate-first-word) scored
//!   by strict (exact match) and loose (prefix match) accuracy — the
//!   analogue of IFEval's prompt-level strict/loose accuracy.
//! - [`ArithmeticTask`]: small addition/subtraction word problems with
//!   exact numeric answers — the GSM8K analogue.
//!
//! Both produce `TaskExample { prompt, answer }`; the trainer packs them
//! as `prompt SEP answer EOS` with the loss masked to the answer span.

use crate::rng::{derive_seed, Pcg};

/// One supervised example.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskExample {
    pub prompt: String,
    pub answer: String,
}

/// Instruction-following task generator.
#[derive(Debug, Clone)]
pub struct InstructionTask {
    pub seed: u64,
}

const WORDS: &[&str] = &[
    "moon", "river", "stone", "cloud", "ember", "frost", "haven", "quill",
    "sable", "tidal", "umber", "viola", "woven", "zephy", "amber", "birch",
];

impl InstructionTask {
    pub fn new(seed: u64) -> Self {
        InstructionTask { seed }
    }

    /// Deterministic example `i`.
    pub fn example(&self, i: u64) -> TaskExample {
        let mut rng = Pcg::new(derive_seed(self.seed, &format!("instr/{i}")));
        let n_words = 2 + rng.below(3);
        let words: Vec<&str> = (0..n_words)
            .map(|_| WORDS[rng.below(WORDS.len())])
            .collect();
        let text = words.join(" ");
        match rng.below(4) {
            0 => TaskExample {
                prompt: format!("copy: {text}"),
                answer: text,
            },
            1 => TaskExample {
                prompt: format!("reverse words: {text}"),
                answer: words
                    .iter()
                    .rev()
                    .copied()
                    .collect::<Vec<_>>()
                    .join(" "),
            },
            2 => TaskExample {
                prompt: format!("uppercase: {text}"),
                answer: text.to_uppercase(),
            },
            _ => TaskExample {
                prompt: format!("first word twice: {text}"),
                answer: format!("{} {}", words[0], words[0]),
            },
        }
    }
}

/// Arithmetic word-problem generator.
#[derive(Debug, Clone)]
pub struct ArithmeticTask {
    pub seed: u64,
}

impl ArithmeticTask {
    pub fn new(seed: u64) -> Self {
        ArithmeticTask { seed }
    }

    pub fn example(&self, i: u64) -> TaskExample {
        let mut rng = Pcg::new(derive_seed(self.seed, &format!("math/{i}")));
        let a = 2 + rng.below(40) as i64;
        let b = 2 + rng.below(40) as i64;
        let c = 1 + rng.below(10) as i64;
        match rng.below(3) {
            0 => TaskExample {
                prompt: format!(
                    "Tom has {a} apples and buys {b} more. How many now?"
                ),
                answer: format!("{}", a + b),
            },
            1 => TaskExample {
                prompt: format!(
                    "A box holds {} pens; {b} are removed. How many left?",
                    a + b
                ),
                answer: format!("{a}"),
            },
            _ => TaskExample {
                prompt: format!(
                    "Each of {c} bags has {a} marbles. Total marbles?"
                ),
                answer: format!("{}", c * a),
            },
        }
    }
}

/// Pack one supervised example into a fixed-length (tokens, targets)
/// row: `BOS prompt SEP answer EOS`, loss masked to the answer + EOS
/// span (prompt and padding score −1).
pub fn sft_row(
    tok: &crate::data::tokenizer::ByteTokenizer,
    ex: &TaskExample,
    seq: usize,
) -> (Vec<i32>, Vec<i32>) {
    use crate::data::tokenizer::{BOS, EOS, SEP};
    let mut ids = vec![BOS];
    ids.extend(tok.encode(&ex.prompt));
    ids.push(SEP);
    let answer_start = ids.len();
    ids.extend(tok.encode(&ex.answer));
    ids.push(EOS);
    ids.truncate(seq + 1);
    while ids.len() < seq + 1 {
        ids.push(BOS);
    }
    let tokens = ids[..seq].to_vec();
    let mut targets = vec![-1i32; seq];
    for pos in 0..seq {
        // Score positions predicting answer/EOS tokens.
        let predicted = pos + 1;
        if predicted >= answer_start
            && predicted < ids.len()
            && !(ids[predicted] == BOS)
        {
            targets[pos] = ids[predicted];
            if ids[predicted] == EOS {
                break;
            }
        }
    }
    (tokens, targets)
}

/// Tokenized prompt for generation: `BOS prompt SEP`.
pub fn gen_prompt(
    tok: &crate::data::tokenizer::ByteTokenizer,
    prompt: &str,
) -> Vec<i32> {
    use crate::data::tokenizer::{BOS, SEP};
    let mut ids = vec![BOS];
    ids.extend(tok.encode(prompt));
    ids.push(SEP);
    ids
}

/// Strict metric: exact string match.
pub fn strict_match(predicted: &str, answer: &str) -> bool {
    predicted.trim() == answer.trim()
}

/// Loose metric: prediction starts with the answer (tolerates trailing
/// babble), mirroring IFEval's loose mode.
pub fn loose_match(predicted: &str, answer: &str) -> bool {
    predicted.trim().starts_with(answer.trim())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn instruction_examples_deterministic() {
        let t = InstructionTask::new(1);
        assert_eq!(t.example(3), t.example(3));
        assert_ne!(t.example(3), t.example(4));
    }

    #[test]
    fn instruction_answers_consistent_with_prompts() {
        let t = InstructionTask::new(2);
        for i in 0..50 {
            let ex = t.example(i);
            let (kind, text) = ex.prompt.split_once(':').unwrap();
            let text = text.trim();
            match kind {
                "copy" => assert_eq!(ex.answer, text),
                "reverse words" => {
                    let mut w: Vec<&str> = text.split(' ').collect();
                    w.reverse();
                    assert_eq!(ex.answer, w.join(" "));
                }
                "uppercase" => assert_eq!(ex.answer, text.to_uppercase()),
                "first word twice" => {
                    let first = text.split(' ').next().unwrap();
                    assert_eq!(ex.answer, format!("{first} {first}"));
                }
                _ => panic!("unknown kind {kind}"),
            }
        }
    }

    #[test]
    fn arithmetic_answers_are_numbers() {
        let t = ArithmeticTask::new(3);
        for i in 0..50 {
            let ex = t.example(i);
            let n: i64 = ex.answer.parse().unwrap();
            assert!(n >= 0);
        }
    }

    #[test]
    fn sft_row_masks_prompt_and_scores_answer() {
        use crate::data::tokenizer::{ByteTokenizer, EOS};
        let tok = ByteTokenizer::new(256);
        let ex = TaskExample {
            prompt: "copy: ab".into(),
            answer: "ab".into(),
        };
        let (tokens, targets) = sft_row(&tok, &ex, 32);
        assert_eq!(tokens.len(), 32);
        let scored: Vec<i32> =
            targets.iter().copied().filter(|&t| t >= 0).collect();
        // "ab" (2 tokens) + EOS.
        assert_eq!(scored.len(), 3, "{targets:?}");
        assert_eq!(*scored.last().unwrap(), EOS);
        assert_eq!(tok.decode(&scored[..2]), "ab");
    }

    #[test]
    fn gen_prompt_framing() {
        use crate::data::tokenizer::{ByteTokenizer, BOS, SEP};
        let tok = ByteTokenizer::new(256);
        let ids = gen_prompt(&tok, "x");
        assert_eq!(ids[0], BOS);
        assert_eq!(*ids.last().unwrap(), SEP);
        assert_eq!(ids.len(), 3);
    }

    #[test]
    fn metrics() {
        assert!(strict_match(" 42 ", "42"));
        assert!(!strict_match("42!", "42"));
        assert!(loose_match("42 and more", "42"));
        assert!(!loose_match("a 42", "42"));
    }
}
