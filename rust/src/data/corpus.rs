//! Synthetic multi-domain corpus generator (the C4 substitute).
//!
//! Seven domains mirror the paper's seven commonsense evaluation tasks:
//! each domain is a distinct generative process over ASCII text, and
//! held-out samples double as the multiple-choice probe sets for
//! `Table 4` / `Fig. 2` style evaluation. Long-tailed structure comes
//! from Zipf word frequencies and per-domain vocabulary tails.

use crate::rng::{derive_seed, Pcg, ZipfSampler};

/// One generative text domain.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Domain {
    /// Zipf-weighted word soup from a shared lexicon ("web text").
    ZipfWords,
    /// Second-order Markov chain over a letter alphabet ("natural prose").
    MarkovChars,
    /// Subject–verb–object templated grammar ("simple facts").
    Grammar,
    /// Arithmetic equalities "12 + 7 = 19" ("math").
    Arithmetic,
    /// Sorted letter runs with occasional breaks ("structured data").
    SortedRuns,
    /// Repeated key-value records ("tables").
    KeyValue,
    /// Bracket-balanced nesting ("code").
    Brackets,
}

pub const ALL_DOMAINS: [Domain; 7] = [
    Domain::ZipfWords,
    Domain::MarkovChars,
    Domain::Grammar,
    Domain::Arithmetic,
    Domain::SortedRuns,
    Domain::KeyValue,
    Domain::Brackets,
];

impl Domain {
    pub fn name(&self) -> &'static str {
        match self {
            Domain::ZipfWords => "zipf-words",
            Domain::MarkovChars => "markov-chars",
            Domain::Grammar => "grammar",
            Domain::Arithmetic => "arithmetic",
            Domain::SortedRuns => "sorted-runs",
            Domain::KeyValue => "key-value",
            Domain::Brackets => "brackets",
        }
    }
}

/// Corpus generation parameters.
#[derive(Debug, Clone)]
pub struct CorpusSpec {
    pub seed: u64,
    /// Mixture weights over `ALL_DOMAINS` (unnormalized).
    pub weights: [f64; 7],
    /// Lexicon size for the Zipf domain.
    pub lexicon: usize,
    /// Zipf exponent.
    pub zipf_s: f64,
}

impl Default for CorpusSpec {
    fn default() -> Self {
        CorpusSpec {
            seed: 0,
            weights: [3.0, 2.0, 2.0, 1.0, 1.0, 1.0, 1.0],
            lexicon: 2000,
            zipf_s: 1.1,
        }
    }
}

/// A generator producing an endless token stream of mixed-domain
/// documents.
pub struct SyntheticCorpus {
    spec: CorpusSpec,
    lexicon: Vec<String>,
    zipf: ZipfSampler,
    markov: MarkovTable,
}

impl SyntheticCorpus {
    pub fn new(spec: CorpusSpec) -> SyntheticCorpus {
        let mut rng = Pcg::new(derive_seed(spec.seed, "lexicon"));
        let lexicon = build_lexicon(spec.lexicon, &mut rng);
        let zipf = ZipfSampler::new(spec.lexicon, spec.zipf_s);
        let markov = MarkovTable::new(derive_seed(spec.seed, "markov"));
        SyntheticCorpus {
            spec,
            lexicon,
            zipf,
            markov,
        }
    }

    /// Generate one document for a specific domain (`doc_id` seeds it).
    pub fn document(&self, domain: Domain, doc_id: u64) -> String {
        let seed = derive_seed(
            self.spec.seed,
            &format!("{}/{doc_id}", domain.name()),
        );
        let mut rng = Pcg::new(seed);
        match domain {
            Domain::ZipfWords => self.gen_zipf(&mut rng),
            Domain::MarkovChars => self.markov.generate(&mut rng, 160),
            Domain::Grammar => gen_grammar(&mut rng),
            Domain::Arithmetic => gen_arithmetic(&mut rng),
            Domain::SortedRuns => gen_sorted_runs(&mut rng),
            Domain::KeyValue => gen_key_value(&mut rng),
            Domain::Brackets => gen_brackets(&mut rng),
        }
    }

    /// Sample a (domain, document) pair from the mixture.
    pub fn mixed_document(&self, doc_id: u64) -> (Domain, String) {
        let mut rng =
            Pcg::new(derive_seed(self.spec.seed, &format!("mix/{doc_id}")));
        let d = ALL_DOMAINS[rng.categorical(&self.spec.weights)];
        (d, self.document(d, doc_id))
    }

    fn gen_zipf(&self, rng: &mut Pcg) -> String {
        let n_words = 20 + rng.below(30);
        let mut out = String::new();
        for i in 0..n_words {
            if i > 0 {
                out.push(' ');
            }
            out.push_str(&self.lexicon[self.zipf.sample(rng)]);
        }
        out.push('.');
        out
    }
}

fn build_lexicon(n: usize, rng: &mut Pcg) -> Vec<String> {
    const CONS: &[u8] = b"bcdfghjklmnprstvwz";
    const VOW: &[u8] = b"aeiou";
    (0..n)
        .map(|_| {
            let syllables = 1 + rng.below(3);
            let mut w = String::new();
            for _ in 0..syllables {
                w.push(CONS[rng.below(CONS.len())] as char);
                w.push(VOW[rng.below(VOW.len())] as char);
                if rng.bernoulli(0.3) {
                    w.push(CONS[rng.below(CONS.len())] as char);
                }
            }
            w
        })
        .collect()
}

/// Second-order Markov chain over a–z+space with a random sparse
/// transition structure (deterministic per corpus seed).
struct MarkovTable {
    /// For each (prev) char index, a weight table over next chars.
    table: Vec<[f64; 27]>,
}

impl MarkovTable {
    fn new(seed: u64) -> MarkovTable {
        let mut rng = Pcg::new(seed);
        let table = (0..27)
            .map(|_| {
                let mut row = [0.0f64; 27];
                // Sparse support: each char can be followed by ~6 others.
                for _ in 0..6 {
                    row[rng.below(27)] += 1.0 + 4.0 * rng.f64();
                }
                row[26] += 0.7; // spaces keep text word-like
                row
            })
            .collect();
        MarkovTable { table }
    }

    fn generate(&self, rng: &mut Pcg, len: usize) -> String {
        let mut out = String::with_capacity(len);
        let mut prev = rng.below(26);
        for _ in 0..len {
            let next = rng.categorical(&self.table[prev]);
            out.push(if next == 26 {
                ' '
            } else {
                (b'a' + next as u8) as char
            });
            prev = next;
        }
        out
    }
}

fn gen_grammar(rng: &mut Pcg) -> String {
    const SUBJ: &[&str] = &["the cat", "a robot", "my friend", "the river",
        "an owl", "the teacher", "a cloud"];
    const VERB: &[&str] = &["sees", "follows", "builds", "finds", "likes",
        "carries", "paints"];
    const OBJ: &[&str] = &["the moon", "a bridge", "the garden", "a song",
        "the door", "an apple", "the map"];
    let n = 3 + rng.below(4);
    let mut out = String::new();
    for _ in 0..n {
        out.push_str(SUBJ[rng.below(SUBJ.len())]);
        out.push(' ');
        out.push_str(VERB[rng.below(VERB.len())]);
        out.push(' ');
        out.push_str(OBJ[rng.below(OBJ.len())]);
        out.push_str(". ");
    }
    out
}

fn gen_arithmetic(rng: &mut Pcg) -> String {
    let mut out = String::new();
    for _ in 0..4 + rng.below(4) {
        let a = rng.below(50);
        let b = rng.below(50);
        if rng.bernoulli(0.5) {
            out.push_str(&format!("{a} + {b} = {} ; ", a + b));
        } else {
            let (hi, lo) = (a.max(b), a.min(b));
            out.push_str(&format!("{hi} - {lo} = {} ; ", hi - lo));
        }
    }
    out
}

fn gen_sorted_runs(rng: &mut Pcg) -> String {
    let mut out = String::new();
    for _ in 0..6 {
        let start = rng.below(20);
        let len = 3 + rng.below(6);
        for i in 0..len {
            out.push((b'a' + ((start + i) % 26) as u8) as char);
        }
        out.push(' ');
    }
    out
}

fn gen_key_value(rng: &mut Pcg) -> String {
    const KEYS: &[&str] = &["id", "name", "size", "kind", "rank"];
    let mut out = String::new();
    for _ in 0..5 {
        for k in KEYS {
            out.push_str(&format!("{k}={} ", rng.below(100)));
        }
        out.push('|');
        out.push(' ');
    }
    out
}

fn gen_brackets(rng: &mut Pcg) -> String {
    let mut out = String::new();
    let mut depth: usize = 0;
    for _ in 0..120 {
        if depth == 0 || (depth < 6 && rng.bernoulli(0.55)) {
            out.push(if rng.bernoulli(0.5) { '(' } else { '[' });
            depth += 1;
        } else {
            // Close with the matching bracket type tracked loosely; use
            // position parity for determinism.
            out.push(if rng.bernoulli(0.5) { ')' } else { ']' });
            depth -= 1;
        }
    }
    while depth > 0 {
        out.push(')');
        depth -= 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn documents_deterministic_per_seed() {
        let c1 = SyntheticCorpus::new(CorpusSpec::default());
        let c2 = SyntheticCorpus::new(CorpusSpec::default());
        for d in ALL_DOMAINS {
            assert_eq!(c1.document(d, 5), c2.document(d, 5));
        }
        let mut spec = CorpusSpec::default();
        spec.seed = 9;
        let c3 = SyntheticCorpus::new(spec);
        assert_ne!(c1.document(Domain::ZipfWords, 5),
                   c3.document(Domain::ZipfWords, 5));
    }

    #[test]
    fn docs_differ_across_ids_and_domains() {
        let c = SyntheticCorpus::new(CorpusSpec::default());
        assert_ne!(c.document(Domain::Grammar, 0),
                   c.document(Domain::Grammar, 1));
        assert_ne!(c.document(Domain::Grammar, 0),
                   c.document(Domain::KeyValue, 0));
    }

    #[test]
    fn mixture_respects_weights_roughly() {
        let mut spec = CorpusSpec::default();
        spec.weights = [1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 1.0];
        let c = SyntheticCorpus::new(spec);
        let mut zipf = 0;
        for i in 0..500 {
            let (d, _) = c.mixed_document(i);
            assert!(d == Domain::ZipfWords || d == Domain::Brackets);
            if d == Domain::ZipfWords {
                zipf += 1;
            }
        }
        assert!((zipf as f64 / 500.0 - 0.5).abs() < 0.1);
    }

    #[test]
    fn all_docs_ascii_nonempty() {
        let c = SyntheticCorpus::new(CorpusSpec::default());
        for d in ALL_DOMAINS {
            let doc = c.document(d, 3);
            assert!(!doc.is_empty());
            assert!(doc.is_ascii(), "{d:?}");
        }
    }

    #[test]
    fn arithmetic_docs_are_correct_equations() {
        let c = SyntheticCorpus::new(CorpusSpec::default());
        let doc = c.document(Domain::Arithmetic, 0);
        for eq in doc.split(';').filter(|s| s.contains('=')) {
            let (lhs, rhs) = eq.split_once('=').unwrap();
            let rhs: i64 = rhs.trim().parse().unwrap();
            let lhs = lhs.trim();
            let val = if let Some((a, b)) = lhs.split_once('+') {
                a.trim().parse::<i64>().unwrap()
                    + b.trim().parse::<i64>().unwrap()
            } else {
                let (a, b) = lhs.split_once('-').unwrap();
                a.trim().parse::<i64>().unwrap()
                    - b.trim().parse::<i64>().unwrap()
            };
            assert_eq!(val, rhs, "{eq}");
        }
    }
}
