//! Deterministic batch loader: documents → packed token batches.
//!
//! Packs BOS-framed documents into fixed (batch, seq_len) windows with
//! next-token targets, streaming from the synthetic corpus. Every batch
//! is a pure function of (corpus seed, batch index), so training runs
//! replay exactly and data order is identical across optimizers — the
//! comparisons in Tables 2/4 are paired.

use super::corpus::SyntheticCorpus;
use super::tokenizer::{ByteTokenizer, BOS};

/// One training batch: row-major (batch, seq) token/target grids.
#[derive(Debug, Clone)]
pub struct Batch {
    pub batch: usize,
    pub seq: usize,
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
}

impl Batch {
    pub fn token_count(&self) -> usize {
        self.batch * self.seq
    }
}

/// Streaming loader over the synthetic corpus.
pub struct BatchLoader {
    corpus: SyntheticCorpus,
    tokenizer: ByteTokenizer,
    batch: usize,
    seq: usize,
    /// Next document id to consume.
    next_doc: u64,
    /// Carry-over tokens from the previous document.
    buffer: Vec<i32>,
}

impl BatchLoader {
    pub fn new(
        corpus: SyntheticCorpus,
        tokenizer: ByteTokenizer,
        batch: usize,
        seq: usize,
    ) -> BatchLoader {
        BatchLoader {
            corpus,
            tokenizer,
            batch,
            seq,
            next_doc: 0,
            buffer: Vec::new(),
        }
    }

    /// Skip ahead to a document offset (used to hold out eval data and
    /// to give each data-parallel replica a disjoint document shard).
    pub fn with_doc_offset(mut self, offset: u64) -> Self {
        self.next_doc = offset;
        self
    }

    /// Generate and discard `n` batches. Interleaved data-parallel
    /// sharding uses this to advance past the micro-batches owned by
    /// other replicas, keeping every lane aligned to the same global
    /// stream a 1-replica accumulation run would consume.
    pub fn skip_batches(&mut self, n: usize) {
        for _ in 0..n {
            let _ = self.next_batch();
        }
    }

    /// Stream position for checkpointing: (next document id, carry-over
    /// token buffer). Restoring both resumes the stream mid-document.
    pub fn stream_state(&self) -> (u64, Vec<i32>) {
        (self.next_doc, self.buffer.clone())
    }

    /// Restore a position captured by [`BatchLoader::stream_state`].
    pub fn restore_stream_state(&mut self, next_doc: u64, buffer: Vec<i32>) {
        self.next_doc = next_doc;
        self.buffer = buffer;
    }

    fn refill(&mut self, needed: usize) {
        while self.buffer.len() < needed {
            let (_, doc) = self.corpus.mixed_document(self.next_doc);
            self.next_doc += 1;
            self.buffer.push(BOS);
            self.buffer.extend(self.tokenizer.encode(&doc));
        }
    }

    /// Produce the next batch. Targets are tokens shifted left by one
    /// (the +1 lookahead token is consumed but not advanced past, so no
    /// token is skipped between batches).
    pub fn next_batch(&mut self) -> Batch {
        let need = self.batch * self.seq + 1;
        self.refill(need);
        let mut tokens = Vec::with_capacity(self.batch * self.seq);
        let mut targets = Vec::with_capacity(self.batch * self.seq);
        for b in 0..self.batch {
            let start = b * self.seq;
            tokens.extend_from_slice(&self.buffer[start..start + self.seq]);
            targets
                .extend_from_slice(&self.buffer[start + 1..start + self.seq + 1]);
        }
        // Keep the final lookahead token as the start of the next batch.
        self.buffer.drain(..self.batch * self.seq);
        Batch {
            batch: self.batch,
            seq: self.seq,
            tokens,
            targets,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::CorpusSpec;

    fn loader(seed: u64) -> BatchLoader {
        let mut spec = CorpusSpec::default();
        spec.seed = seed;
        BatchLoader::new(
            SyntheticCorpus::new(spec),
            ByteTokenizer::new(256),
            4,
            32,
        )
    }

    #[test]
    fn batches_have_correct_shape_and_alignment() {
        let mut l = loader(0);
        let b = l.next_batch();
        assert_eq!(b.tokens.len(), 4 * 32);
        assert_eq!(b.targets.len(), 4 * 32);
        // Target at position i equals token at i+1 within the stream.
        for i in 0..4 * 32 - 1 {
            // rows are contiguous in the same stream, so cross-row holds
            // too in this packed layout
            assert_eq!(b.targets[i], b.tokens[i + 1]);
        }
    }

    #[test]
    fn deterministic_replay() {
        let mut a = loader(7);
        let mut b = loader(7);
        for _ in 0..5 {
            let ba = a.next_batch();
            let bb = b.next_batch();
            assert_eq!(ba.tokens, bb.tokens);
            assert_eq!(ba.targets, bb.targets);
        }
    }

    #[test]
    fn no_token_skipped_between_batches() {
        let mut l = loader(3);
        let b1 = l.next_batch();
        let b2 = l.next_batch();
        // Last target of batch1 is the first token of batch2.
        assert_eq!(*b1.targets.last().unwrap(), b2.tokens[0]);
    }

    #[test]
    fn doc_offset_changes_stream() {
        let mut a = loader(0);
        let mut b = loader(0).with_doc_offset(10_000);
        assert_ne!(a.next_batch().tokens, b.next_batch().tokens);
    }

    #[test]
    fn skip_batches_matches_manual_draining() {
        let mut a = loader(4);
        let mut b = loader(4);
        a.skip_batches(3);
        for _ in 0..3 {
            let _ = b.next_batch();
        }
        assert_eq!(a.next_batch().tokens, b.next_batch().tokens);
    }

    #[test]
    fn stream_state_roundtrips_mid_stream() {
        let mut a = loader(5);
        let _ = a.next_batch();
        let (doc, buf) = a.stream_state();
        assert!(!buf.is_empty(), "carry-over buffer expected mid-stream");
        let want = a.next_batch();

        let mut b = loader(5);
        b.restore_stream_state(doc, buf);
        assert_eq!(b.next_batch().tokens, want.tokens);
    }

    #[test]
    fn all_ids_in_vocab() {
        let mut l = loader(1);
        for _ in 0..3 {
            let b = l.next_batch();
            assert!(b.tokens.iter().all(|&t| (0..256).contains(&t)));
        }
    }
}
