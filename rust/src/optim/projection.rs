//! Low-rank projectors: GaLore's SVD top-r and GoLore's random
//! orthonormal, with left/right orientation handling.
//!
//! For a block G (m×n): if m ≤ n the projector is P ∈ R^{m×r} applied as
//! R = PᵀG (r×n); otherwise P ∈ R^{n×r} applied as R = G·P (m×r). This is
//! exactly GaLore's convention (project the shorter side).

use crate::linalg::{
    gemm, gemm_nt, gemm_tn, matmul, matmul_nt, matmul_tn,
    random_orthonormal, rsvd, svd_thin, top_singular_vectors, Matrix,
    RsvdOpts,
};
use crate::rng::Pcg;

/// Projector construction policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProjKind {
    /// GaLore: top-r singular vectors of the (fresh) gradient.
    SvdTopR,
    /// GoLore: random orthonormal basis, independent of the gradient.
    Random,
}

/// How `ProjKind::SvdTopR` computes the top-r basis at each refresh.
///
/// `ExactJacobi` is the reference fallback (full Gram eigendecomposition,
/// deterministic, no RNG draws); `Randomized` is the shipped engine
/// (oversampled subspace iteration, `linalg::rsvd`); `WarmStart` seeds
/// the range-finder with the previous period's projector so steady-state
/// refreshes converge in a single subspace iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RefreshStrategy {
    /// Exact top-r via cyclic-Jacobi eigendecomposition of the Gram
    /// matrix — the numerical reference every other strategy is tested
    /// against.
    ExactJacobi,
    /// Randomized range-finder + `power_iters` subspace iterations with
    /// `oversample` extra sketch columns.
    Randomized {
        oversample: usize,
        power_iters: usize,
    },
    /// `Randomized` seeded with the previous period's basis (falls back
    /// to a cold 2-iteration sketch on the first refresh). The warm
    /// basis rides in optimizer snapshots, so resumed runs keep their
    /// steady-state refresh cost.
    WarmStart,
}

impl RefreshStrategy {
    /// Oversampling used by `WarmStart` and the default `Randomized`.
    pub const OVERSAMPLE: usize = 4;

    /// Parse a CLI/config spelling. Accepted: `exact` / `jacobi` /
    /// `exact-jacobi`, `randomized` (optionally
    /// `randomized:<oversample>:<power_iters>`), `warm` / `warm-start` /
    /// `warmstart`.
    pub fn parse(s: &str) -> anyhow::Result<RefreshStrategy> {
        let lower = s.to_ascii_lowercase();
        match lower.as_str() {
            "exact" | "jacobi" | "exact-jacobi" => {
                return Ok(RefreshStrategy::ExactJacobi)
            }
            "randomized" | "rsvd" => {
                return Ok(RefreshStrategy::default())
            }
            "warm" | "warm-start" | "warmstart" => {
                return Ok(RefreshStrategy::WarmStart)
            }
            _ => {}
        }
        if let Some(rest) = lower
            .strip_prefix("randomized:")
            .or_else(|| lower.strip_prefix("rsvd:"))
        {
            let mut parts = rest.split(':');
            let os = parts
                .next()
                .and_then(|v| v.parse::<usize>().ok())
                .ok_or_else(|| {
                    anyhow::anyhow!("bad oversample in refresh strategy '{s}'")
                })?;
            let pi = match parts.next() {
                None => 2,
                Some(v) => v.parse::<usize>().map_err(|_| {
                    anyhow::anyhow!("bad power_iters in refresh strategy '{s}'")
                })?,
            };
            anyhow::ensure!(
                parts.next().is_none(),
                "refresh strategy '{s}' has trailing fields"
            );
            return Ok(RefreshStrategy::Randomized {
                oversample: os,
                power_iters: pi,
            });
        }
        anyhow::bail!(
            "unknown refresh strategy '{s}' \
             (expected exact | randomized[:os[:iters]] | warm-start)"
        )
    }

    /// Stable label for logs/metrics.
    pub fn label(&self) -> String {
        match self {
            RefreshStrategy::ExactJacobi => "exact-jacobi".into(),
            RefreshStrategy::Randomized {
                oversample,
                power_iters,
            } => format!("randomized(os={oversample},p={power_iters})"),
            RefreshStrategy::WarmStart => "warm-start".into(),
        }
    }
}

impl Default for RefreshStrategy {
    /// The shipped refresh engine (matches the historical behaviour of
    /// `Projector::build`): oversampled 2-step subspace iteration.
    fn default() -> Self {
        RefreshStrategy::Randomized {
            oversample: Self::OVERSAMPLE,
            power_iters: 2,
        }
    }
}

/// A rank-r projector for one block.
#[derive(Debug, Clone, PartialEq)]
pub struct Projector {
    /// Column-orthonormal basis: (min_side × r).
    pub p: Matrix,
    /// True when the *left* side is projected (m ≤ n).
    pub left: bool,
    pub rank: usize,
}

/// One refresh probe for the adaptive rank schedule: an orthonormal
/// basis at the probe width plus the singular values the range capture
/// observed. The rank controller reads [`RankProbe::spectrum`] to
/// decide the block's next rank, then [`RankProbe::into_projector`]
/// truncates the already-computed basis — re-ranking costs one column
/// slice, not a second SVD.
#[derive(Debug, Clone, PartialEq)]
pub struct RankProbe {
    /// Column-orthonormal basis at the probe width (min_side × probe_r).
    u: Matrix,
    /// Leading singular values observed by the probe (descending).
    s: Vec<f32>,
    left: bool,
}

impl RankProbe {
    /// The observed leading singular values (controller input).
    pub fn spectrum(&self) -> &[f32] {
        &self.s
    }

    /// Truncate the probe basis to the committed rank. `rank` is
    /// clamped to the probe width (and floored at 1).
    pub fn into_projector(self, rank: usize) -> Projector {
        let r = rank.max(1).min(self.u.cols);
        Projector {
            p: self.u.left_cols(r),
            left: self.left,
            rank: r,
        }
    }
}

impl Projector {
    /// Build a projector for gradient `g` with the given policy and the
    /// default refresh strategy (randomized, 2 power steps: same
    /// projector quality as exact SVD for the separated spectra GaLore
    /// exploits, ~50× cheaper on the refresh path — §Perf).
    pub fn build(g: &Matrix, rank: usize, kind: ProjKind, rng: &mut Pcg) -> Projector {
        Projector::build_with(g, rank, kind, RefreshStrategy::default(), None, rng)
    }

    /// Build a projector with an explicit [`RefreshStrategy`] and an
    /// optional previous-period projector (`warm`) for
    /// [`RefreshStrategy::WarmStart`]. A warm projector with a different
    /// orientation or side length (block reshaped) is ignored.
    pub fn build_with(
        g: &Matrix,
        rank: usize,
        kind: ProjKind,
        refresh: RefreshStrategy,
        warm: Option<&Projector>,
        rng: &mut Pcg,
    ) -> Projector {
        let (m, n) = g.shape();
        let left = m <= n;
        let side = m.min(n);
        let r = rank.min(side);
        let p = match kind {
            ProjKind::Random => random_orthonormal(side, r, rng),
            ProjKind::SvdTopR => {
                // Orient so we always take top *left* singular vectors:
                // right singular vectors of G = left singular vectors
                // of Gᵀ.
                let gt;
                let a: &Matrix = if left {
                    g
                } else {
                    gt = g.transpose();
                    &gt
                };
                match refresh {
                    RefreshStrategy::ExactJacobi => {
                        top_singular_vectors(a, r)
                    }
                    RefreshStrategy::Randomized {
                        oversample,
                        power_iters,
                    } => {
                        let opts = RsvdOpts {
                            oversample,
                            power_iters,
                        };
                        rsvd(a, r, &opts, None, rng).u
                    }
                    RefreshStrategy::WarmStart => {
                        let basis = warm.and_then(|w| {
                            (w.left == left && w.p.rows == side)
                                .then_some(&w.p)
                        });
                        let opts = RsvdOpts {
                            oversample: RefreshStrategy::OVERSAMPLE,
                            // Steady state: one tracking iteration; cold
                            // start: the default two.
                            power_iters: if basis.is_some() { 1 } else { 2 },
                        };
                        rsvd(a, r, &opts, basis, rng).u
                    }
                }
            }
        };
        Projector { p, left, rank: r }
    }

    /// Compute a [`RankProbe`] for gradient `g` at width `probe_rank`
    /// (the adaptive schedule's rank ceiling): the same orientation,
    /// warm-start acceptance, and RNG discipline as
    /// [`Projector::build_with`] with `ProjKind::SvdTopR`, but the
    /// singular values are kept so the controller can re-decide the
    /// rank before the basis is truncated.
    pub fn probe_with(
        g: &Matrix,
        probe_rank: usize,
        refresh: RefreshStrategy,
        warm: Option<&Projector>,
        rng: &mut Pcg,
    ) -> RankProbe {
        let (m, n) = g.shape();
        let left = m <= n;
        let side = m.min(n);
        let r = probe_rank.min(side).max(1);
        let gt;
        let a: &Matrix = if left {
            g
        } else {
            gt = g.transpose();
            &gt
        };
        let (u, s) = match refresh {
            RefreshStrategy::ExactJacobi => {
                let svd = svd_thin(a);
                let rr = r.min(svd.s.len()).min(svd.u.cols);
                (svd.u.left_cols(rr), svd.s[..rr].to_vec())
            }
            RefreshStrategy::Randomized {
                oversample,
                power_iters,
            } => {
                let opts = RsvdOpts {
                    oversample,
                    power_iters,
                };
                let svd = rsvd(a, r, &opts, None, rng);
                (svd.u, svd.s)
            }
            RefreshStrategy::WarmStart => {
                let basis = warm.and_then(|w| {
                    (w.left == left && w.p.rows == side).then_some(&w.p)
                });
                let opts = RsvdOpts {
                    oversample: RefreshStrategy::OVERSAMPLE,
                    power_iters: if basis.is_some() { 1 } else { 2 },
                };
                let svd = rsvd(a, r, &opts, basis, rng);
                (svd.u, svd.s)
            }
        };
        RankProbe { u, s, left }
    }

    /// Project the gradient into the low-rank space:
    /// left: PᵀG (r×n); right: G·P (m×r).
    pub fn project(&self, g: &Matrix) -> Matrix {
        if self.left {
            matmul_tn(&self.p, g)
        } else {
            matmul(g, &self.p)
        }
    }

    /// [`Projector::project`] into a caller-owned buffer (resized in
    /// place) — the per-step form for optimizer scratch reuse.
    pub fn project_into(&self, g: &Matrix, out: &mut Matrix) {
        if self.left {
            out.resize(self.p.cols, g.cols);
            gemm_tn(1.0, &self.p, g, 0.0, out);
        } else {
            out.resize(g.rows, self.p.cols);
            gemm(1.0, g, &self.p, 0.0, out);
        }
    }

    /// Lift a low-rank quantity back: left: P·R; right: R·Pᵀ.
    pub fn project_back(&self, r: &Matrix) -> Matrix {
        if self.left {
            matmul(&self.p, r)
        } else {
            matmul_nt(r, &self.p)
        }
    }

    /// [`Projector::project_back`] into a caller-owned buffer.
    pub fn project_back_into(&self, r: &Matrix, out: &mut Matrix) {
        if self.left {
            out.resize(self.p.rows, r.cols);
            gemm(1.0, &self.p, r, 0.0, out);
        } else {
            out.resize(r.rows, self.p.rows);
            gemm_nt(1.0, r, &self.p, 0.0, out);
        }
    }

    /// The rank-r reconstruction P Pᵀ G (or G P Pᵀ on the right).
    pub fn reconstruct(&self, g: &Matrix) -> Matrix {
        self.project_back(&self.project(g))
    }

    /// [`Projector::reconstruct`] through caller-owned buffers: `tmp`
    /// holds the low-rank intermediate, `out` the reconstruction.
    pub fn reconstruct_into(&self, g: &Matrix, tmp: &mut Matrix, out: &mut Matrix) {
        self.project_into(g, tmp);
        self.project_back_into(tmp, out);
    }

    /// The debias residual (I − PPᵀ)G (resp. G(I − PPᵀ)) scaled.
    pub fn residual_scaled(&self, g: &Matrix, scale: f32) -> Matrix {
        let mut rec = self.reconstruct(g);
        // scale * (g - rec)
        rec.axpby_in_place(-scale, scale, g);
        rec
    }

    /// [`Projector::residual_scaled`] through caller-owned buffers.
    pub fn residual_scaled_into(
        &self,
        g: &Matrix,
        scale: f32,
        tmp: &mut Matrix,
        out: &mut Matrix,
    ) {
        self.reconstruct_into(g, tmp, out);
        // scale * (g - rec)
        out.axpby_in_place(-scale, scale, g);
    }

    /// Bytes held by the projector matrix.
    pub fn state_bytes(&self) -> usize {
        self.p.numel() * std::mem::size_of::<f32>()
    }

    /// Shape of the projected (low-rank) gradient for block shape (m,n).
    pub fn projected_shape(&self, m: usize, n: usize) -> (usize, usize) {
        if self.left {
            (self.rank, n)
        } else {
            (m, self.rank)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::fro_norm;
    use crate::testing;

    #[test]
    fn svd_projector_captures_low_rank_gradient_exactly() {
        // If G has rank ≤ r, PPᵀG = G.
        let mut rng = Pcg::new(0);
        let u = Matrix::randn(20, 3, 1.0, &mut rng);
        let v = Matrix::randn(3, 40, 1.0, &mut rng);
        let g = matmul(&u, &v);
        let proj = Projector::build(&g, 3, ProjKind::SvdTopR, &mut rng);
        let rec = proj.reconstruct(&g);
        assert!(rec.max_abs_diff(&g) < 1e-2 * fro_norm(&g));
    }

    #[test]
    fn right_projection_for_tall_blocks() {
        let mut rng = Pcg::new(1);
        let g = Matrix::randn(50, 10, 1.0, &mut rng);
        let proj = Projector::build(&g, 4, ProjKind::SvdTopR, &mut rng);
        assert!(!proj.left);
        assert_eq!(proj.p.shape(), (10, 4));
        assert_eq!(proj.project(&g).shape(), (50, 4));
        assert_eq!(proj.reconstruct(&g).shape(), (50, 10));
    }

    #[test]
    fn projection_is_idempotent_and_contractive() {
        testing::check(20, |gen| {
            let m = gen.dim(2, 40);
            let n = gen.dim(2, 40);
            let r = gen.dim(1, m.min(n));
            let g = gen.matrix(m, n);
            let kind = if gen.bool() {
                ProjKind::SvdTopR
            } else {
                ProjKind::Random
            };
            let proj = Projector::build(&g, r, kind, &mut gen.rng);
            // PᵀP = I
            let ptp = matmul_tn(&proj.p, &proj.p);
            assert!(
                ptp.max_abs_diff(&Matrix::eye(proj.rank)) < 1e-3,
                "orthonormality"
            );
            // Idempotence: PPᵀ(PPᵀG) = PPᵀG
            let rec = proj.reconstruct(&g);
            let rec2 = proj.reconstruct(&rec);
            assert!(rec2.max_abs_diff(&rec) < 1e-3, "idempotent");
            // Contraction: ‖PPᵀG‖ ≤ ‖G‖
            assert!(fro_norm(&rec) <= fro_norm(&g) * (1.0 + 1e-4));
        });
    }

    #[test]
    fn residual_plus_reconstruction_is_identity() {
        testing::check(20, |gen| {
            let m = gen.dim(2, 30);
            let n = gen.dim(2, 30);
            let r = gen.dim(1, m.min(n));
            let g = gen.matrix(m, n);
            let proj =
                Projector::build(&g, r, ProjKind::Random, &mut gen.rng);
            let rec = proj.reconstruct(&g);
            let res = proj.residual_scaled(&g, 1.0);
            let mut sum = rec.clone();
            sum.add_scaled_in_place(1.0, &res);
            assert!(sum.max_abs_diff(&g) < 1e-3);
        });
    }

    #[test]
    fn into_variants_match_allocating_both_orientations() {
        // Scratch buffers resized across calls (the optimizer pattern)
        // must reproduce the allocating paths bit-for-bit.
        let mut rng = Pcg::new(7);
        let mut low = Matrix::zeros(0, 0);
        let mut full = Matrix::zeros(0, 0);
        let mut tmp = Matrix::zeros(0, 0);
        for (m, n) in [(16usize, 40usize), (40, 16)] {
            let g = Matrix::randn(m, n, 1.0, &mut rng);
            let proj = Projector::build(&g, 5, ProjKind::SvdTopR, &mut rng);
            proj.project_into(&g, &mut low);
            assert_eq!(low.data, proj.project(&g).data, "project {m}x{n}");
            proj.project_back_into(&low, &mut full);
            assert_eq!(
                full.data,
                proj.project_back(&low).data,
                "back {m}x{n}"
            );
            proj.reconstruct_into(&g, &mut tmp, &mut full);
            assert_eq!(full.data, proj.reconstruct(&g).data, "rec {m}x{n}");
            proj.residual_scaled_into(&g, 1.7, &mut tmp, &mut full);
            assert_eq!(
                full.data,
                proj.residual_scaled(&g, 1.7).data,
                "resid {m}x{n}"
            );
        }
    }

    #[test]
    fn rank_clamped_to_side() {
        let mut rng = Pcg::new(2);
        let g = Matrix::randn(4, 32, 1.0, &mut rng);
        let proj = Projector::build(&g, 100, ProjKind::SvdTopR, &mut rng);
        assert_eq!(proj.rank, 4);
    }

    #[test]
    fn refresh_strategies_agree_on_separated_spectrum() {
        // All three strategies must recover the same dominant subspace
        // on a gradient with a clear spectral gap — in both orientations.
        let mut rng = Pcg::new(3);
        for (m, n) in [(24usize, 48usize), (48, 24)] {
            let u = Matrix::randn(m, 3, 1.0, &mut rng);
            let v = Matrix::randn(3, n, 1.0, &mut rng);
            let mut g = matmul(&u, &v);
            g.add_scaled_in_place(0.01, &Matrix::randn(m, n, 1.0, &mut rng));
            let exact = Projector::build_with(
                &g,
                3,
                ProjKind::SvdTopR,
                RefreshStrategy::ExactJacobi,
                None,
                &mut rng,
            );
            for strat in [RefreshStrategy::default(), RefreshStrategy::WarmStart]
            {
                let got = Projector::build_with(
                    &g,
                    3,
                    ProjKind::SvdTopR,
                    strat,
                    None,
                    &mut rng,
                );
                assert_eq!(got.left, exact.left);
                let cross = matmul_tn(&exact.p, &got.p);
                let gram = matmul_tn(&cross, &cross);
                assert!(
                    gram.max_abs_diff(&Matrix::eye(3)) < 1e-2,
                    "{} ({m}x{n}): subspace mismatch",
                    strat.label()
                );
            }
        }
    }

    #[test]
    fn warm_start_accepts_matching_and_ignores_stale_basis() {
        let mut rng = Pcg::new(4);
        let g = Matrix::randn(20, 40, 1.0, &mut rng);
        let prev =
            Projector::build(&g, 5, ProjKind::SvdTopR, &mut rng);
        let proj = Projector::build_with(
            &g,
            5,
            ProjKind::SvdTopR,
            RefreshStrategy::WarmStart,
            Some(&prev),
            &mut rng,
        );
        let ptp = matmul_tn(&proj.p, &proj.p);
        assert!(ptp.max_abs_diff(&Matrix::eye(5)) < 1e-3);
        // A projector from a transposed block (wrong orientation) must
        // not be used as a warm basis — but must not panic either.
        let stale =
            Projector::build(&g.transpose(), 5, ProjKind::SvdTopR, &mut rng);
        assert!(stale.left != prev.left || g.rows == g.cols);
        let proj2 = Projector::build_with(
            &g,
            5,
            ProjKind::SvdTopR,
            RefreshStrategy::WarmStart,
            Some(&stale),
            &mut rng,
        );
        assert!(proj2.p.is_finite());
        assert_eq!(proj2.p.shape(), (20, 5));
    }

    #[test]
    fn probe_truncation_matches_direct_build_subspace() {
        // A probe at the rank ceiling, truncated to r, must span the
        // same dominant subspace as building at r directly — in both
        // orientations and for every strategy.
        let mut rng = Pcg::new(12);
        for (m, n) in [(20usize, 44usize), (44, 20)] {
            let u = Matrix::randn(m, 3, 1.0, &mut rng);
            let v = Matrix::randn(3, n, 1.0, &mut rng);
            let mut g = matmul(&u, &v);
            g.add_scaled_in_place(0.01, &Matrix::randn(m, n, 1.0, &mut rng));
            let exact = Projector::build_with(
                &g,
                3,
                ProjKind::SvdTopR,
                RefreshStrategy::ExactJacobi,
                None,
                &mut rng,
            );
            for strat in [
                RefreshStrategy::ExactJacobi,
                RefreshStrategy::default(),
                RefreshStrategy::WarmStart,
            ] {
                let probe =
                    Projector::probe_with(&g, 8, strat, None, &mut rng);
                assert_eq!(probe.spectrum().len(), 8);
                for w in probe.spectrum().windows(2) {
                    assert!(w[0] >= w[1] - 1e-4, "spectrum not descending");
                }
                let proj = probe.into_projector(3);
                assert_eq!(proj.rank, 3);
                assert_eq!(proj.left, exact.left);
                let ptp = matmul_tn(&proj.p, &proj.p);
                assert!(ptp.max_abs_diff(&Matrix::eye(3)) < 1e-3);
                let cross = matmul_tn(&exact.p, &proj.p);
                let gram = matmul_tn(&cross, &cross);
                assert!(
                    gram.max_abs_diff(&Matrix::eye(3)) < 1e-2,
                    "{} ({m}x{n}): truncated probe subspace mismatch",
                    strat.label()
                );
            }
        }
    }

    #[test]
    fn refresh_strategy_parse_spellings() {
        assert_eq!(
            RefreshStrategy::parse("exact").unwrap(),
            RefreshStrategy::ExactJacobi
        );
        assert_eq!(
            RefreshStrategy::parse("Exact-Jacobi").unwrap(),
            RefreshStrategy::ExactJacobi
        );
        assert_eq!(
            RefreshStrategy::parse("randomized").unwrap(),
            RefreshStrategy::default()
        );
        assert_eq!(
            RefreshStrategy::parse("randomized:8:3").unwrap(),
            RefreshStrategy::Randomized {
                oversample: 8,
                power_iters: 3
            }
        );
        assert_eq!(
            RefreshStrategy::parse("rsvd:6").unwrap(),
            RefreshStrategy::Randomized {
                oversample: 6,
                power_iters: 2
            }
        );
        assert_eq!(
            RefreshStrategy::parse("warm-start").unwrap(),
            RefreshStrategy::WarmStart
        );
        assert!(RefreshStrategy::parse("bogus").is_err());
        assert!(RefreshStrategy::parse("randomized:x").is_err());
        assert!(RefreshStrategy::parse("randomized:4:2:9").is_err());
    }
}
