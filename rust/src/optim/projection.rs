//! Low-rank projectors: GaLore's SVD top-r and GoLore's random
//! orthonormal, with left/right orientation handling.
//!
//! For a block G (m×n): if m ≤ n the projector is P ∈ R^{m×r} applied as
//! R = PᵀG (r×n); otherwise P ∈ R^{n×r} applied as R = G·P (m×r). This is
//! exactly GaLore's convention (project the shorter side).

use crate::linalg::{
    matmul, matmul_nt, matmul_tn, random_orthonormal,
    top_singular_vectors_randomized, Matrix,
};
use crate::rng::Pcg;

/// Projector construction policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProjKind {
    /// GaLore: top-r singular vectors of the (fresh) gradient.
    SvdTopR,
    /// GoLore: random orthonormal basis, independent of the gradient.
    Random,
}

/// A rank-r projector for one block.
#[derive(Debug, Clone)]
pub struct Projector {
    /// Column-orthonormal basis: (min_side × r).
    pub p: Matrix,
    /// True when the *left* side is projected (m ≤ n).
    pub left: bool,
    pub rank: usize,
}

impl Projector {
    /// Build a projector for gradient `g` with the given policy.
    pub fn build(g: &Matrix, rank: usize, kind: ProjKind, rng: &mut Pcg) -> Projector {
        let (m, n) = g.shape();
        let left = m <= n;
        let side = m.min(n);
        let r = rank.min(side);
        // Randomized subspace iteration (2 power steps): same projector
        // quality as exact SVD for the separated spectra GaLore exploits,
        // ~50× cheaper on the refresh path (§Perf).
        let p = match kind {
            ProjKind::SvdTopR => {
                if left {
                    top_singular_vectors_randomized(g, r, 2, rng)
                } else {
                    // Right singular vectors = top left-singular vectors
                    // of Gᵀ.
                    top_singular_vectors_randomized(&g.transpose(), r, 2, rng)
                }
            }
            ProjKind::Random => random_orthonormal(side, r, rng),
        };
        Projector { p, left, rank: r }
    }

    /// Project the gradient into the low-rank space:
    /// left: PᵀG (r×n); right: G·P (m×r).
    pub fn project(&self, g: &Matrix) -> Matrix {
        if self.left {
            matmul_tn(&self.p, g)
        } else {
            matmul(g, &self.p)
        }
    }

    /// Lift a low-rank quantity back: left: P·R; right: R·Pᵀ.
    pub fn project_back(&self, r: &Matrix) -> Matrix {
        if self.left {
            matmul(&self.p, r)
        } else {
            matmul_nt(r, &self.p)
        }
    }

    /// The rank-r reconstruction P Pᵀ G (or G P Pᵀ on the right).
    pub fn reconstruct(&self, g: &Matrix) -> Matrix {
        self.project_back(&self.project(g))
    }

    /// The debias residual (I − PPᵀ)G (resp. G(I − PPᵀ)) scaled.
    pub fn residual_scaled(&self, g: &Matrix, scale: f32) -> Matrix {
        let mut rec = self.reconstruct(g);
        // scale * (g - rec)
        rec.axpby_in_place(-scale, scale, g);
        rec
    }

    /// Bytes held by the projector matrix.
    pub fn state_bytes(&self) -> usize {
        self.p.numel() * std::mem::size_of::<f32>()
    }

    /// Shape of the projected (low-rank) gradient for block shape (m,n).
    pub fn projected_shape(&self, m: usize, n: usize) -> (usize, usize) {
        if self.left {
            (self.rank, n)
        } else {
            (m, self.rank)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::fro_norm;
    use crate::testing;

    #[test]
    fn svd_projector_captures_low_rank_gradient_exactly() {
        // If G has rank ≤ r, PPᵀG = G.
        let mut rng = Pcg::new(0);
        let u = Matrix::randn(20, 3, 1.0, &mut rng);
        let v = Matrix::randn(3, 40, 1.0, &mut rng);
        let g = matmul(&u, &v);
        let proj = Projector::build(&g, 3, ProjKind::SvdTopR, &mut rng);
        let rec = proj.reconstruct(&g);
        assert!(rec.max_abs_diff(&g) < 1e-2 * fro_norm(&g));
    }

    #[test]
    fn right_projection_for_tall_blocks() {
        let mut rng = Pcg::new(1);
        let g = Matrix::randn(50, 10, 1.0, &mut rng);
        let proj = Projector::build(&g, 4, ProjKind::SvdTopR, &mut rng);
        assert!(!proj.left);
        assert_eq!(proj.p.shape(), (10, 4));
        assert_eq!(proj.project(&g).shape(), (50, 4));
        assert_eq!(proj.reconstruct(&g).shape(), (50, 10));
    }

    #[test]
    fn projection_is_idempotent_and_contractive() {
        testing::check(20, |gen| {
            let m = gen.dim(2, 40);
            let n = gen.dim(2, 40);
            let r = gen.dim(1, m.min(n));
            let g = gen.matrix(m, n);
            let kind = if gen.bool() {
                ProjKind::SvdTopR
            } else {
                ProjKind::Random
            };
            let proj = Projector::build(&g, r, kind, &mut gen.rng);
            // PᵀP = I
            let ptp = matmul_tn(&proj.p, &proj.p);
            assert!(
                ptp.max_abs_diff(&Matrix::eye(proj.rank)) < 1e-3,
                "orthonormality"
            );
            // Idempotence: PPᵀ(PPᵀG) = PPᵀG
            let rec = proj.reconstruct(&g);
            let rec2 = proj.reconstruct(&rec);
            assert!(rec2.max_abs_diff(&rec) < 1e-3, "idempotent");
            // Contraction: ‖PPᵀG‖ ≤ ‖G‖
            assert!(fro_norm(&rec) <= fro_norm(&g) * (1.0 + 1e-4));
        });
    }

    #[test]
    fn residual_plus_reconstruction_is_identity() {
        testing::check(20, |gen| {
            let m = gen.dim(2, 30);
            let n = gen.dim(2, 30);
            let r = gen.dim(1, m.min(n));
            let g = gen.matrix(m, n);
            let proj =
                Projector::build(&g, r, ProjKind::Random, &mut gen.rng);
            let rec = proj.reconstruct(&g);
            let res = proj.residual_scaled(&g, 1.0);
            let mut sum = rec.clone();
            sum.add_scaled_in_place(1.0, &res);
            assert!(sum.max_abs_diff(&g) < 1e-3);
        });
    }

    #[test]
    fn rank_clamped_to_side() {
        let mut rng = Pcg::new(2);
        let g = Matrix::randn(4, 32, 1.0, &mut rng);
        let proj = Projector::build(&g, 100, ProjKind::SvdTopR, &mut rng);
        assert_eq!(proj.rank, 4);
    }
}
