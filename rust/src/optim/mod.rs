//! Optimizers: the paper's algorithm family plus every baseline in its
//! evaluation.
//!
//! | Name | Paper role | Module |
//! |---|---|---|
//! | SGD / SGD-M | substrate | `sgd` |
//! | Adam / AdamW | FT-AdamW baseline | `adam` |
//! | Muon | FT-Muon baseline + GUM's base | `muon` |
//! | GaLore (Adam or Muon base) | biased low-rank baseline | `galore` |
//! | GoLore | random-projector unbiased baseline | `galore` (`ProjKind::Random`) |
//! | Fira | full-rank-under-low-rank baseline | `fira` |
//! | LISA | layerwise-sampling ancestor | `lisa` |
//! | **GUM** | **the paper's contribution (Alg. 2)** | `gum` |
//!
//! All optimizers implement [`Optimizer`] over a [`ParamStore`]; the
//! coordinator drives `begin_period` every K steps (projector refresh,
//! momentum restart, layer sampling — Algorithm 2's outer loop) and
//! `step` every iteration.
//!
//! Determinism invariant: every step and refresh is a pure function of
//! (seed, step index, snapshot state) — RNG draws come from named
//! [`crate::rng::derive_seed`] streams, never from ambient state — so
//! committed trajectories are bit-identical across `GUM_THREADS`,
//! replica splits, sync↔async refresh pipelining, faults, and resume.

pub mod adam;
pub mod dense;
pub mod fira;
pub mod galore;
pub mod gum;
pub mod lisa;
pub mod memory;
pub mod muon;
pub mod projection;
pub mod rank_schedule;
pub mod period_schedule;
pub mod refresh_pipeline;
pub mod sgd;

use crate::linalg::lowp::MomentBuf;
use crate::linalg::{Matrix, NsWorkspace};
use crate::model::ParamStore;
use crate::rng::Pcg;

pub use crate::linalg::lowp::StateDtype;

pub use adam::Adam;
pub use fira::Fira;
pub use galore::{BaseOpt, GaLore};
pub use gum::{Compensation, Gum};
pub use lisa::Lisa;
pub use memory::{bytes_human, MemoryReport};
pub use muon::Muon;
pub use projection::{ProjKind, Projector, RankProbe, RefreshStrategy};
pub use period_schedule::{
    subspace_drift, AdaptivePeriodCfg, PeriodController, PeriodSchedule,
    PeriodState,
};
pub use rank_schedule::{
    projected_state_bytes, resize_moment, resize_moment_buf, AdaptiveRankCfg,
    RankController, RankSchedule, RankState,
};
pub use refresh_pipeline::{
    PendingRefresh, RefreshPipeline, RefreshPipelineMode,
};
pub use sgd::Sgd;

/// Per-step context handed to optimizers.
#[derive(Debug, Clone, Copy)]
pub struct StepCtx {
    pub lr: f32,
    /// Global step index (0-based).
    pub step: usize,
}

/// Shared per-step scratch for the projected optimizers: every matrix
/// temp of the momentum-project-orthogonalize chain lands in one of
/// these buffers (resized in place, allocations reused across blocks
/// and steps), so the per-step allocation count is zero once warm.
/// Transient state — never snapshotted, never part of `state_bytes`
/// accounting (it is bounded by the largest single block, not by the
/// model).
#[derive(Debug, Default)]
pub(crate) struct StepScratch {
    /// Projected (low-rank) gradient, or the compensated full-rank
    /// gradient's low-rank intermediate.
    pub low: Matrix,
    /// Elementwise update in the projected space (Adam-style bases).
    pub upd: Matrix,
    /// Newton–Schulz direction.
    pub dir: Matrix,
    /// Full-space update / compensated gradient.
    pub full: Matrix,
    /// Fira's lifted low-rank reconstruction P(PᵀG) — the residual
    /// itself is never materialized (fused `elementwise::residual_add`).
    pub resid: Matrix,
    /// Unrounded f32 momentum accumulator for the 16-bit state paths:
    /// the fused lowp kernels write the pre-rounding accumulator here
    /// (the Newton–Schulz input), while only the RTNE-packed bits
    /// persist as state.
    pub mom: Matrix,
    /// Newton–Schulz product buffers.
    pub ns: NsWorkspace,
}

impl StepScratch {
    pub fn new() -> StepScratch {
        StepScratch::default()
    }
}

/// The product of one projector refresh, computed ahead of its period
/// boundary from a gradient snapshot at refresh-trigger time: the next
/// period's bases, aligned with `params.blocks` (`None` for dense /
/// non-projected blocks). Built by an owned [`RefreshJob`] (possibly on
/// a background pool thread), consumed by
/// [`Optimizer::begin_period_prepared`] at the boundary handoff.
#[derive(Debug, Clone, PartialEq)]
pub struct PreparedRefresh {
    pub projectors: Vec<Option<Projector>>,
    /// Under an adaptive [`RankSchedule`], the controller bookkeeping
    /// *after* observing this refresh's spectra — the planned job
    /// decides the new ranks, the boundary handoff installs them.
    /// `None` under the fixed schedule (fixed-run bytes unchanged).
    pub rank_state: Option<RankState>,
    /// Under an adaptive [`PeriodSchedule`], the period-controller
    /// bookkeeping *after* observing this refresh's subspace drift —
    /// the boundary commit adopts it and lays down the next boundary.
    /// `None` under the fixed schedule (fixed-run bytes unchanged).
    pub period_state: Option<PeriodState>,
}

/// An owned, `Send` closure computing a [`PreparedRefresh`]: everything
/// the refresh needs (gradient snapshot clones, warm bases, derived RNG
/// streams) is captured at plan time, so the job is a pure function —
/// it returns the same bases whether it runs immediately (sync
/// pipeline), on a pool worker (async pipeline), or during a
/// checkpoint-time resolve.
pub type RefreshJob = Box<dyn FnOnce() -> PreparedRefresh + Send>;

/// One serializable piece of optimizer state.
#[derive(Debug, Clone, PartialEq)]
pub enum SnapValue {
    U64(u64),
    F64(f64),
    Bool(bool),
    Mat(Matrix),
    /// A 16-bit-packed moment matrix (`--state-dtype bf16|f16`).
    /// Serialized with a `DTYPE` tag; f32 moments keep using
    /// [`SnapValue::Mat`], so checkpoints of f32 runs stay
    /// byte-identical to the pre-dtype layer.
    LowpMat {
        dtype: StateDtype,
        rows: usize,
        cols: usize,
        bits: Vec<u16>,
    },
}

/// Wrap a [`MomentBuf`] as the matching [`SnapValue`] (f32 → `Mat`,
/// 16-bit → `LowpMat`).
pub fn snap_moment(m: &MomentBuf) -> SnapValue {
    match m {
        MomentBuf::F32(m) => SnapValue::Mat(m.clone()),
        MomentBuf::Lowp {
            dtype,
            rows,
            cols,
            bits,
        } => SnapValue::LowpMat {
            dtype: *dtype,
            rows: *rows,
            cols: *cols,
            bits: bits.clone(),
        },
    }
}

/// A flat, order-preserving key → value snapshot of optimizer state
/// (projectors, momenta, sampler streams). Produced by
/// [`Optimizer::snapshot`], serialized by the coordinator's checkpoint
/// layer (`GUMCKPT2`), and consumed by [`Optimizer::restore_snapshot`]
/// for mid-period resume.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct OptSnapshot {
    pub entries: Vec<(String, SnapValue)>,
}

impl OptSnapshot {
    pub fn push(&mut self, key: impl Into<String>, value: SnapValue) {
        self.entries.push((key.into(), value));
    }

    pub fn get(&self, key: &str) -> Option<&SnapValue> {
        self.entries.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    pub fn as_u64(&self, key: &str) -> Option<u64> {
        match self.get(key)? {
            SnapValue::U64(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_f64(&self, key: &str) -> Option<f64> {
        match self.get(key)? {
            SnapValue::F64(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_bool(&self, key: &str) -> Option<bool> {
        match self.get(key)? {
            SnapValue::Bool(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_mat(&self, key: &str) -> Option<&Matrix> {
        match self.get(key)? {
            SnapValue::Mat(v) => Some(v),
            _ => None,
        }
    }

    /// A moment buffer at whichever dtype the snapshot stored (`Mat` ≙
    /// f32, `LowpMat` ≙ 16-bit). Dtype agreement with the session
    /// config is checked by the consumer (e.g. `DenseAdamW::restore`),
    /// which can name both sides in its diagnostic.
    pub fn as_moment(&self, key: &str) -> Option<MomentBuf> {
        match self.get(key)? {
            SnapValue::Mat(v) => Some(MomentBuf::F32(v.clone())),
            SnapValue::LowpMat {
                dtype,
                rows,
                cols,
                bits,
            } => Some(MomentBuf::Lowp {
                dtype: *dtype,
                rows: *rows,
                cols: *cols,
                bits: bits.clone(),
            }),
            _ => None,
        }
    }
}

/// Optimizer over named parameter blocks.
///
/// `grads` is aligned with `params.blocks` (canonical order).
pub trait Optimizer {
    fn name(&self) -> String;

    /// Called by the coordinator at the start of each sampling period
    /// (every K steps) with the *fresh gradients at the period boundary*
    /// — Algorithm 2 lines 3–9: restart momentum, recompute projectors,
    /// resample full-rank blocks. Stateless optimizers ignore this.
    fn begin_period(
        &mut self,
        _params: &ParamStore,
        _grads: &[Matrix],
        _rng: &mut Pcg,
    ) {
    }

    /// Package the *next* period's projector refresh as an owned
    /// [`RefreshJob`] over a gradient snapshot — the prepare half of the
    /// off-critical-path refresh pipeline
    /// ([`refresh_pipeline::RefreshPipeline`]). `rng` is a dedicated
    /// stream the pipeline derives from the session seed and the
    /// boundary step; optimizers with their own per-period derived
    /// sketch streams (GUM) ignore it. Optimizers without projector
    /// state return `None` (the pipeline then no-ops and `begin_period`
    /// runs unchanged at the boundary).
    fn plan_refresh(
        &self,
        _grads: &[Matrix],
        _rng: &mut Pcg,
    ) -> Option<RefreshJob> {
        None
    }

    /// [`Optimizer::begin_period`] consuming a precomputed refresh: the
    /// handoff swaps in `prepared`'s bases instead of rebuilding them
    /// from `grads`, and runs the rest of the period transition
    /// (momentum restart, full-rank resampling) unchanged. Must commit
    /// exactly what running the [`Optimizer::plan_refresh`] job inline
    /// and swapping would — the pipeline determinism suite
    /// (`rust/tests/refresh_pipeline.rs`) locks sync/async equality in.
    /// The default ignores `prepared` and falls back to `begin_period`.
    fn begin_period_prepared(
        &mut self,
        params: &ParamStore,
        grads: &[Matrix],
        rng: &mut Pcg,
        _prepared: PreparedRefresh,
    ) {
        self.begin_period(params, grads, rng)
    }

    /// Apply one update step in place.
    fn step(&mut self, params: &mut ParamStore, grads: &[Matrix], ctx: &StepCtx);

    /// Bytes of optimizer state currently held (projectors + moments).
    fn state_bytes(&self) -> usize;

    /// Reconfigure the storage dtype of the moment buffers (the
    /// `--state-dtype` surface). Build-time only: implementations may
    /// reallocate still-zero state. The default refuses — optimizers
    /// without matrix moment state (the SGD family) have nothing to
    /// store at reduced precision.
    fn set_state_dtype(&mut self, dtype: StateDtype) -> anyhow::Result<()> {
        anyhow::bail!(
            "optimizer '{}' does not support --state-dtype {} (supported: \
             adam/adamw/muon/galore/golore/fira/lisa/gum)",
            self.name(),
            dtype
        )
    }

    /// Full state snapshot for mid-period checkpoint resume (projector,
    /// momentum, sampler stream). Optimizers without resume support
    /// return `None`; the trainer then checkpoints parameters only.
    fn snapshot(&self) -> Option<OptSnapshot> {
        None
    }

    /// Restore state captured by [`Optimizer::snapshot`]. The optimizer
    /// must already be built over an identically-shaped parameter store.
    fn restore_snapshot(&mut self, _snap: &OptSnapshot) -> anyhow::Result<()> {
        anyhow::bail!("{} does not support state restore", self.name())
    }

    /// The current per-block projector bases, aligned with
    /// `params.blocks` (`None` for dense blocks), or `None` for
    /// optimizers without projector state. The adaptive
    /// [`PeriodSchedule`] snapshots these at refresh-trigger time so
    /// the refresh job can measure how far the next basis drifted from
    /// the one it replaces.
    fn projectors(&self) -> Option<Vec<Option<Projector>>> {
        None
    }

    /// The adaptive rank controller's current bookkeeping (committed
    /// per-block ranks + hysteresis streaks) — `None` under the fixed
    /// schedule. Serialized as the `GUMCKPT3` `RANKS` section.
    fn rank_state(&self) -> Option<RankState> {
        None
    }

    /// Reinstate controller bookkeeping captured by
    /// [`Optimizer::rank_state`]. Fails when this optimizer was built
    /// with a fixed schedule (the checkpoint and the session config
    /// disagree about rank adaptivity).
    fn restore_rank_state(&mut self, _state: &RankState) -> anyhow::Result<()> {
        anyhow::bail!(
            "{} was built with a fixed rank schedule; cannot restore \
             adaptive rank state",
            self.name()
        )
    }

    /// Downcast hook for tests/instrumentation (e.g. reading GUM's
    /// `full_rank_mask` through a `Box<dyn Optimizer>`).
    fn as_any(&self) -> Option<&dyn std::any::Any> {
        None
    }
}

/// Construct an optimizer by name (CLI/config surface) with the default
/// projector-refresh strategy.
///
/// Recognized: `sgd`, `sgdm`, `adam`, `adamw`, `muon`, `galore-adam`,
/// `galore-muon` (alias `galore`), `golore-muon`, `fira`, `lisa`, `gum`.
pub fn build(
    name: &str,
    params: &ParamStore,
    rank: usize,
    gamma: f64,
    seed: u64,
) -> anyhow::Result<Box<dyn Optimizer>> {
    build_with_refresh(name, params, rank, gamma, seed, RefreshStrategy::default())
}

/// [`build`] with an explicit [`RefreshStrategy`] for the projector-based
/// optimizers (GaLore/Fira/GUM); others ignore it.
pub fn build_with_refresh(
    name: &str,
    params: &ParamStore,
    rank: usize,
    gamma: f64,
    seed: u64,
    refresh: RefreshStrategy,
) -> anyhow::Result<Box<dyn Optimizer>> {
    build_with_schedule(
        name,
        params,
        rank,
        gamma,
        seed,
        refresh,
        &RankSchedule::Fixed,
    )
}

/// [`build_with_refresh`] with a [`RankSchedule`]: under
/// `RankSchedule::Adaptive` the SVD-projected optimizers (GaLore, Fira,
/// GUM) get a spectrum-driven [`RankController`] seeded at `rank`;
/// `RankSchedule::Fixed` is exactly the historical behavior. Adaptive
/// scheduling on optimizers without a gradient-driven projector (dense
/// baselines, GoLore's random bases, LISA) is a config error.
pub fn build_with_schedule(
    name: &str,
    params: &ParamStore,
    rank: usize,
    gamma: f64,
    seed: u64,
    refresh: RefreshStrategy,
    schedule: &RankSchedule,
) -> anyhow::Result<Box<dyn Optimizer>> {
    build_with_state(
        name,
        params,
        rank,
        gamma,
        seed,
        refresh,
        schedule,
        StateDtype::F32,
    )
}

/// [`build_with_schedule`] with a moment-storage [`StateDtype`]
/// (`--state-dtype`): `F32` is exactly the historical behavior; `Bf16`
/// / `F16` store every moment buffer packed at 16 bits with f32
/// accumulation in the fused kernels. Projectors always stay f32.
/// Optimizers without moment state (SGD family) reject non-f32.
#[allow(clippy::too_many_arguments)]
pub fn build_with_state(
    name: &str,
    params: &ParamStore,
    rank: usize,
    gamma: f64,
    seed: u64,
    refresh: RefreshStrategy,
    schedule: &RankSchedule,
    state_dtype: StateDtype,
) -> anyhow::Result<Box<dyn Optimizer>> {
    let mut opt =
        build_inner(name, params, rank, gamma, seed, refresh, schedule)?;
    if state_dtype != StateDtype::F32 {
        opt.set_state_dtype(state_dtype)?;
    }
    Ok(opt)
}

fn build_inner(
    name: &str,
    params: &ParamStore,
    rank: usize,
    gamma: f64,
    seed: u64,
    refresh: RefreshStrategy,
    schedule: &RankSchedule,
) -> anyhow::Result<Box<dyn Optimizer>> {
    let n_proj = params.projectable_indices().len().max(1);
    let q = (gamma / n_proj as f64).clamp(0.0, 1.0);
    let controller = |params: &ParamStore| match schedule {
        RankSchedule::Fixed => None,
        RankSchedule::Adaptive(cfg) => {
            Some(RankController::new(cfg, params, rank))
        }
    };
    let adaptive = !matches!(schedule, RankSchedule::Fixed);
    let ensure_fixed = |name: &str| -> anyhow::Result<()> {
        anyhow::ensure!(
            !adaptive,
            "optimizer '{name}' has no spectrum-driven projector; \
             --rank-schedule adaptive requires galore/fira/gum"
        );
        Ok(())
    };
    Ok(match name {
        "sgd" => {
            ensure_fixed(name)?;
            Box::new(Sgd::new(params, 0.0))
        }
        "sgdm" => {
            ensure_fixed(name)?;
            Box::new(Sgd::new(params, 0.9))
        }
        "adam" => {
            ensure_fixed(name)?;
            Box::new(Adam::new(params, 0.9, 0.999, 1e-8, 0.0))
        }
        "adamw" => {
            ensure_fixed(name)?;
            Box::new(Adam::new(params, 0.9, 0.999, 1e-8, 0.01))
        }
        "muon" => {
            ensure_fixed(name)?;
            Box::new(Muon::new(params, 0.95))
        }
        "galore" | "galore-muon" => {
            let mut g = GaLore::new(
                params,
                rank,
                BaseOpt::Muon { beta: 0.95 },
                ProjKind::SvdTopR,
            );
            g.refresh = refresh;
            g.rank_ctl = controller(params);
            Box::new(g)
        }
        "galore-adam" => {
            let mut g = GaLore::new(
                params,
                rank,
                BaseOpt::Adam {
                    beta1: 0.9,
                    beta2: 0.999,
                    eps: 1e-8,
                },
                ProjKind::SvdTopR,
            );
            g.refresh = refresh;
            g.rank_ctl = controller(params);
            Box::new(g)
        }
        "golore" | "golore-muon" => {
            // GoLore's bases are random, not spectral — there is no
            // spectrum to drive the controller with.
            ensure_fixed(name)?;
            Box::new(GaLore::new(
                params,
                rank,
                BaseOpt::Muon { beta: 0.95 },
                ProjKind::Random,
            ))
        }
        "fira" => {
            let mut f = Fira::new(params, rank);
            f.refresh = refresh;
            f.rank_ctl = controller(params);
            Box::new(f)
        }
        "lisa" => {
            ensure_fixed(name)?;
            Box::new(Lisa::new(params, gamma))
        }
        "gum" => {
            let mut g = Gum::new(
                params,
                rank,
                q,
                0.95,
                Compensation::Paper,
                seed,
            );
            g.refresh = refresh;
            g.rank_ctl = controller(params);
            Box::new(g)
        }
        other => anyhow::bail!("unknown optimizer '{other}'"),
    })
}
