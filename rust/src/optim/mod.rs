//! Optimizers: the paper's algorithm family plus every baseline in its
//! evaluation.
//!
//! | Name | Paper role | Module |
//! |---|---|---|
//! | SGD / SGD-M | substrate | `sgd` |
//! | Adam / AdamW | FT-AdamW baseline | `adam` |
//! | Muon | FT-Muon baseline + GUM's base | `muon` |
//! | GaLore (Adam or Muon base) | biased low-rank baseline | `galore` |
//! | GoLore | random-projector unbiased baseline | `galore` (`ProjKind::Random`) |
//! | Fira | full-rank-under-low-rank baseline | `fira` |
//! | LISA | layerwise-sampling ancestor | `lisa` |
//! | **GUM** | **the paper's contribution (Alg. 2)** | `gum` |
//!
//! All optimizers implement [`Optimizer`] over a [`ParamStore`]; the
//! coordinator drives `begin_period` every K steps (projector refresh,
//! momentum restart, layer sampling — Algorithm 2's outer loop) and
//! `step` every iteration.

pub mod adam;
pub mod dense;
pub mod fira;
pub mod galore;
pub mod gum;
pub mod lisa;
pub mod memory;
pub mod muon;
pub mod projection;
pub mod sgd;

use crate::linalg::Matrix;
use crate::model::ParamStore;
use crate::rng::Pcg;

pub use adam::Adam;
pub use fira::Fira;
pub use galore::{BaseOpt, GaLore};
pub use gum::{Compensation, Gum};
pub use lisa::Lisa;
pub use memory::{bytes_human, MemoryReport};
pub use muon::Muon;
pub use projection::{ProjKind, Projector};
pub use sgd::Sgd;

/// Per-step context handed to optimizers.
#[derive(Debug, Clone, Copy)]
pub struct StepCtx {
    pub lr: f32,
    /// Global step index (0-based).
    pub step: usize,
}

/// Optimizer over named parameter blocks.
///
/// `grads` is aligned with `params.blocks` (canonical order).
pub trait Optimizer {
    fn name(&self) -> String;

    /// Called by the coordinator at the start of each sampling period
    /// (every K steps) with the *fresh gradients at the period boundary*
    /// — Algorithm 2 lines 3–9: restart momentum, recompute projectors,
    /// resample full-rank blocks. Stateless optimizers ignore this.
    fn begin_period(
        &mut self,
        _params: &ParamStore,
        _grads: &[Matrix],
        _rng: &mut Pcg,
    ) {
    }

    /// Apply one update step in place.
    fn step(&mut self, params: &mut ParamStore, grads: &[Matrix], ctx: &StepCtx);

    /// Bytes of optimizer state currently held (projectors + moments).
    fn state_bytes(&self) -> usize;
}

/// Construct an optimizer by name (CLI/config surface).
///
/// Recognized: `sgd`, `sgdm`, `adam`, `adamw`, `muon`, `galore-adam`,
/// `galore-muon` (alias `galore`), `golore-muon`, `fira`, `lisa`, `gum`.
pub fn build(
    name: &str,
    params: &ParamStore,
    rank: usize,
    gamma: f64,
    seed: u64,
) -> anyhow::Result<Box<dyn Optimizer>> {
    let n_proj = params.projectable_indices().len().max(1);
    let q = (gamma / n_proj as f64).clamp(0.0, 1.0);
    Ok(match name {
        "sgd" => Box::new(Sgd::new(params, 0.0)),
        "sgdm" => Box::new(Sgd::new(params, 0.9)),
        "adam" => Box::new(Adam::new(params, 0.9, 0.999, 1e-8, 0.0)),
        "adamw" => Box::new(Adam::new(params, 0.9, 0.999, 1e-8, 0.01)),
        "muon" => Box::new(Muon::new(params, 0.95)),
        "galore" | "galore-muon" => Box::new(GaLore::new(
            params,
            rank,
            BaseOpt::Muon { beta: 0.95 },
            ProjKind::SvdTopR,
        )),
        "galore-adam" => Box::new(GaLore::new(
            params,
            rank,
            BaseOpt::Adam {
                beta1: 0.9,
                beta2: 0.999,
                eps: 1e-8,
            },
            ProjKind::SvdTopR,
        )),
        "golore" | "golore-muon" => Box::new(GaLore::new(
            params,
            rank,
            BaseOpt::Muon { beta: 0.95 },
            ProjKind::Random,
        )),
        "fira" => Box::new(Fira::new(params, rank)),
        "lisa" => Box::new(Lisa::new(params, gamma)),
        "gum" => Box::new(Gum::new(
            params,
            rank,
            q,
            0.95,
            Compensation::Paper,
            seed,
        )),
        other => anyhow::bail!("unknown optimizer '{other}'"),
    })
}
