//! Fira [Chen et al., 2024]: full-rank training under a low-rank
//! constraint. GaLore-Adam in the projected space **plus** the full-rank
//! residual (I − PPᵀ)G re-scaled by the ratio of the low-rank update
//! norm to the low-rank gradient norm (the "norm-based scaling" that
//! substitutes Adam's adaptive step for the residual directions).
//!
//! The paper's evaluation includes Fira as the strongest
//! full-rank-under-low-rank baseline; note it carries no unbiasedness
//! guarantee (the residual scaling is heuristic).

use crate::linalg::lowp::{self, MomentBuf, StateDtype};
use crate::linalg::{fro_norm, Matrix};
use crate::model::{BlockKind, ParamStore};
use crate::rng::Pcg;

use super::dense::DenseAdamW;
use super::projection::{ProjKind, Projector, RankProbe, RefreshStrategy};
use super::rank_schedule::{resize_moment_buf, RankController, RankState};
use super::{Optimizer, PreparedRefresh, RefreshJob, StepCtx, StepScratch};

struct BlockState {
    proj: Option<Projector>,
    m: Option<MomentBuf>,
    v: Option<MomentBuf>,
    t: usize,
}

impl BlockState {
    /// Install a refreshed projector; when the projected shape changed
    /// (an adaptive rank change), the persistent Adam moments are
    /// resized (overlap-copy + zero-pad) so the fused kernel keeps
    /// operating on length-matched buffers. Fixed-rank refreshes never
    /// change the shape, so this is the plain swap there.
    fn install(&mut self, proj: Projector, block_shape: (usize, usize)) {
        let (pm, pn) = proj.projected_shape(block_shape.0, block_shape.1);
        for buf in [&mut self.m, &mut self.v] {
            if let Some(b) = buf.as_mut() {
                if b.shape() != (pm, pn) {
                    *b = resize_moment_buf(b, pm, pn);
                }
            }
        }
        self.proj = Some(proj);
    }
}

/// Fira-Adam over a parameter store.
pub struct Fira {
    pub rank: usize,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// Limiter on the residual scaling factor (Fira's γ-limiter keeps
    /// spikes bounded; 1.01 per the reference implementation).
    pub limiter: f32,
    /// Projector-refresh engine.
    pub refresh: RefreshStrategy,
    /// Adaptive rank controller (`--rank-schedule adaptive`). Fira's
    /// projected Adam moments persist across refreshes, so a rank
    /// change also resizes them to the new projected shape. `None` ≙
    /// the fixed schedule, bit-for-bit.
    pub rank_ctl: Option<RankController>,
    /// Storage dtype for the projected Adam moments (projectors stay
    /// f32). Configured at build time via `set_state_dtype`.
    state_dtype: StateDtype,
    states: Vec<Option<BlockState>>,
    prev_scale: Vec<f32>,
    dense: Vec<Option<DenseAdamW>>,
    /// Per-step matrix temps, reused across blocks and steps.
    scratch: StepScratch,
}

impl Fira {
    pub fn new(params: &ParamStore, rank: usize) -> Fira {
        let mut states = Vec::new();
        let mut dense = Vec::new();
        for b in &params.blocks {
            match b.kind {
                BlockKind::Projectable => {
                    states.push(Some(BlockState {
                        proj: None,
                        m: None,
                        v: None,
                        t: 0,
                    }));
                    dense.push(None);
                }
                BlockKind::Dense => {
                    states.push(None);
                    dense.push(Some(DenseAdamW::new(
                        b.value.shape(),
                        0.9,
                        0.999,
                        1e-8,
                        0.0,
                    )));
                }
            }
        }
        let n = params.blocks.len();
        Fira {
            rank,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            limiter: 1.01,
            refresh: RefreshStrategy::default(),
            rank_ctl: None,
            state_dtype: StateDtype::F32,
            states,
            prev_scale: vec![0.0; n],
            dense,
            scratch: StepScratch::new(),
        }
    }
}

impl Optimizer for Fira {
    fn name(&self) -> String {
        format!("fira(r={})", self.rank)
    }

    fn begin_period(
        &mut self,
        params: &ParamStore,
        grads: &[Matrix],
        rng: &mut Pcg,
    ) {
        if self.rank_ctl.is_some() {
            // Adaptive: probe every block at the rank ceiling (same RNG
            // stream and block order as the fixed path), let the
            // controller read all spectra, then install one truncation
            // per block — moments are resized by `install`.
            let probe_ranks: Vec<usize> = {
                let ctl = self.rank_ctl.as_ref().unwrap();
                (0..self.states.len()).map(|i| ctl.probe_rank(i)).collect()
            };
            let mut probes: Vec<Option<RankProbe>> =
                Vec::with_capacity(self.states.len());
            for (i, state) in self.states.iter_mut().enumerate() {
                probes.push(state.as_mut().map(|state| {
                    let prev = state.proj.take();
                    Projector::probe_with(
                        &grads[i],
                        probe_ranks[i],
                        self.refresh,
                        prev.as_ref(),
                        rng,
                    )
                }));
            }
            let spectra: Vec<Option<&[f32]>> = probes
                .iter()
                .map(|p| p.as_ref().map(|p| p.spectrum()))
                .collect();
            let ctl = self.rank_ctl.as_mut().unwrap();
            ctl.observe(&spectra);
            drop(spectra);
            let ranks: Vec<usize> =
                (0..self.states.len()).map(|i| ctl.rank_of(i)).collect();
            for (i, (state, probe)) in
                self.states.iter_mut().zip(probes).enumerate()
            {
                if let (Some(state), Some(probe)) = (state, probe) {
                    state.install(
                        probe.into_projector(ranks[i]),
                        params.blocks[i].value.shape(),
                    );
                }
            }
            return;
        }
        for (i, state) in self.states.iter_mut().enumerate() {
            if let Some(state) = state {
                let prev = state.proj.take();
                let proj = Projector::build_with(
                    &grads[i],
                    self.rank,
                    ProjKind::SvdTopR,
                    self.refresh,
                    prev.as_ref(),
                    rng,
                );
                state.install(proj, params.blocks[i].value.shape());
            }
        }
    }

    /// Refresh-pipeline prepare (same contract as GaLore's): gradient
    /// snapshot + warm bases + a cloned derived RNG stream, drawn in
    /// canonical block order.
    fn plan_refresh(
        &self,
        grads: &[Matrix],
        rng: &mut Pcg,
    ) -> Option<RefreshJob> {
        let rank = self.rank;
        let refresh = self.refresh;
        let rank_ctl = self.rank_ctl.clone();
        let blocks: Vec<_> = self
            .states
            .iter()
            .enumerate()
            .map(|(i, state)| {
                state
                    .as_ref()
                    .map(|s| (grads[i].clone(), s.proj.clone()))
            })
            .collect();
        let mut job_rng = rng.clone();
        Some(Box::new(move || match rank_ctl {
            None => PreparedRefresh {
                projectors: blocks
                    .into_iter()
                    .map(|slot| {
                        slot.map(|(g, warm)| {
                            Projector::build_with(
                                &g,
                                rank,
                                ProjKind::SvdTopR,
                                refresh,
                                warm.as_ref(),
                                &mut job_rng,
                            )
                        })
                    })
                    .collect(),
                rank_state: None,
                period_state: None,
            },
            Some(mut ctl) => {
                let probes: Vec<Option<RankProbe>> = blocks
                    .into_iter()
                    .enumerate()
                    .map(|(i, slot)| {
                        slot.map(|(g, warm)| {
                            Projector::probe_with(
                                &g,
                                ctl.probe_rank(i),
                                refresh,
                                warm.as_ref(),
                                &mut job_rng,
                            )
                        })
                    })
                    .collect();
                let spectra: Vec<Option<&[f32]>> = probes
                    .iter()
                    .map(|p| p.as_ref().map(|p| p.spectrum()))
                    .collect();
                ctl.observe(&spectra);
                drop(spectra);
                PreparedRefresh {
                    projectors: probes
                        .into_iter()
                        .enumerate()
                        .map(|(i, p)| {
                            p.map(|p| p.into_projector(ctl.rank_of(i)))
                        })
                        .collect(),
                    rank_state: Some(ctl.state()),
                    period_state: None,
                }
            }
        }))
    }

    /// Refresh-pipeline handoff: swap in the precomputed bases (Fira
    /// keeps its projected moments across refreshes, so the swap is the
    /// whole transition).
    fn begin_period_prepared(
        &mut self,
        params: &ParamStore,
        grads: &[Matrix],
        rng: &mut Pcg,
        prepared: PreparedRefresh,
    ) {
        if self.rank_ctl.is_some() {
            match prepared.rank_state.as_ref() {
                Some(rs) => {
                    if let Err(e) =
                        self.rank_ctl.as_mut().unwrap().restore(rs)
                    {
                        crate::warn!(
                            "fira: prepared rank state rejected ({e}); \
                             keeping controller as-is"
                        );
                    }
                }
                None => {
                    // A fixed-schedule plan handed to an adaptive
                    // optimizer: fall back to the synchronous adaptive
                    // refresh so ranks stay controller-driven.
                    crate::warn!(
                        "fira: prepared refresh carries no rank state; \
                         refreshing synchronously"
                    );
                    self.begin_period(params, grads, rng);
                    return;
                }
            }
        }
        let (rank, refresh) = (self.rank, self.refresh);
        let ctl = self.rank_ctl.as_ref();
        let mut slots = prepared.projectors;
        slots.resize_with(self.states.len(), || None);
        for (i, (state, slot)) in
            self.states.iter_mut().zip(slots).enumerate()
        {
            let Some(state) = state else { continue };
            let proj = match slot {
                Some(p) => p,
                None => {
                    // Unreachable through a well-formed pipeline (every
                    // projectable block is planned); diverges from the
                    // trigger-time spec trace, so say so.
                    crate::warn!(
                        "fira: prepared refresh missing block {i}; \
                         rebuilding synchronously (trajectory may \
                         diverge from the sync spec)"
                    );
                    let prev = state.proj.take();
                    match ctl {
                        Some(ctl) => Projector::probe_with(
                            &grads[i],
                            ctl.probe_rank(i),
                            refresh,
                            prev.as_ref(),
                            rng,
                        )
                        .into_projector(ctl.rank_of(i)),
                        None => Projector::build_with(
                            &grads[i],
                            rank,
                            ProjKind::SvdTopR,
                            refresh,
                            prev.as_ref(),
                            rng,
                        ),
                    }
                }
            };
            state.install(proj, params.blocks[i].value.shape());
        }
    }

    fn step(&mut self, params: &mut ParamStore, grads: &[Matrix], ctx: &StepCtx) {
        assert_eq!(params.blocks.len(), grads.len());
        for (i, block) in params.blocks.iter_mut().enumerate() {
            match block.kind {
                BlockKind::Dense => {
                    self.dense[i].as_mut().unwrap().step(
                        &mut block.value,
                        &grads[i],
                        ctx.lr,
                    );
                }
                BlockKind::Projectable => {
                    let (b1, b2, eps) = (self.beta1, self.beta2, self.eps);
                    let dtype = self.state_dtype;
                    let state = self.states[i].as_mut().unwrap();
                    let scr = &mut self.scratch;
                    let proj = state
                        .proj
                        .as_ref()
                        .expect("begin_period must run before step");
                    proj.project_into(&grads[i], &mut scr.low);
                    let (rr, rc) = scr.low.shape();
                    let m = state
                        .m
                        .get_or_insert_with(|| MomentBuf::zeros(dtype, rr, rc));
                    let v = state
                        .v
                        .get_or_insert_with(|| MomentBuf::zeros(dtype, rr, rc));
                    state.t += 1;
                    let bc1 = 1.0 - b1.powi(state.t as i32);
                    let bc2 = 1.0 - b2.powi(state.t as i32);
                    scr.upd.resize(rr, rc);
                    // Fused single pass: both moment updates + the
                    // bias-corrected step direction.
                    match (m, v) {
                        (MomentBuf::F32(m), MomentBuf::F32(v)) => {
                            crate::linalg::elementwise::adam_update(
                                &mut scr.upd.data,
                                &scr.low.data,
                                &mut m.data,
                                &mut v.data,
                                b1,
                                b2,
                                bc1,
                                bc2,
                                eps,
                            )
                        }
                        (
                            MomentBuf::Lowp { dtype, bits: mb, .. },
                            MomentBuf::Lowp { bits: vb, .. },
                        ) => lowp::adam_update(
                            *dtype,
                            &mut scr.upd.data,
                            &scr.low.data,
                            mb,
                            vb,
                            b1,
                            b2,
                            bc1,
                            bc2,
                            eps,
                        ),
                        _ => unreachable!("m and v share a dtype"),
                    }
                    // Low-rank part of the step.
                    proj.project_back_into(&scr.upd, &mut scr.full);
                    // Residual scaled by ‖update‖/‖projected grad‖ —
                    // Fira's substitute for adaptive steps on the
                    // residual directions — with the spike limiter.
                    let gnorm = fro_norm(&scr.low).max(1e-12);
                    let mut phi = fro_norm(&scr.upd) / gnorm;
                    let prev = self.prev_scale[i];
                    if prev > 0.0 && phi > self.limiter * prev {
                        phi = prev; // limiter clamps sudden spikes
                    }
                    self.prev_scale[i] = phi;
                    // scr.low still holds PᵀG, so the residual needs
                    // only the lift: φ·(G − P(PᵀG)) — one GEMM, not the
                    // full reconstruct (which would re-project G).
                    proj.project_back_into(&scr.low, &mut scr.resid);
                    block.value.add_scaled_in_place(-ctx.lr, &scr.full);
                    // w += (−lr·φ)·(G − lift) in one fused pass, never
                    // materializing the scaled residual.
                    crate::linalg::elementwise::residual_add(
                        &mut block.value.data,
                        -ctx.lr * phi,
                        &grads[i].data,
                        &scr.resid.data,
                    );
                }
            }
        }
    }

    fn state_bytes(&self) -> usize {
        let mut total = 0;
        for s in self.states.iter().flatten() {
            total += s.proj.as_ref().map_or(0, |p| p.state_bytes());
            total += s.m.as_ref().map_or(0, |m| m.state_bytes());
            total += s.v.as_ref().map_or(0, |v| v.state_bytes());
        }
        total
            + self
                .dense
                .iter()
                .flatten()
                .map(|d| d.state_bytes())
                .sum::<usize>()
            + self.prev_scale.len() * 4
    }

    fn projectors(&self) -> Option<Vec<Option<Projector>>> {
        Some(
            self.states
                .iter()
                .map(|s| s.as_ref().and_then(|s| s.proj.clone()))
                .collect(),
        )
    }

    fn rank_state(&self) -> Option<RankState> {
        self.rank_ctl.as_ref().map(|c| c.state())
    }

    fn restore_rank_state(&mut self, state: &RankState) -> anyhow::Result<()> {
        match self.rank_ctl.as_mut() {
            Some(ctl) => ctl.restore(state),
            None => anyhow::bail!(
                "fira was built with a fixed rank schedule; the \
                 checkpoint carries adaptive rank state"
            ),
        }
    }

    fn set_state_dtype(&mut self, dtype: StateDtype) -> anyhow::Result<()> {
        self.state_dtype = dtype;
        for d in self.dense.iter_mut().flatten() {
            d.set_dtype(dtype);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{init_param_store, registry};

    fn setup() -> (ParamStore, Vec<Matrix>, Pcg) {
        let store = init_param_store(&registry::get("micro").unwrap(), 0);
        let mut rng = Pcg::new(0);
        let grads: Vec<Matrix> = store
            .blocks
            .iter()
            .map(|b| Matrix::randn(b.value.rows, b.value.cols, 1.0, &mut rng))
            .collect();
        (store, grads, rng)
    }

    #[test]
    fn update_is_full_rank_unlike_galore() {
        let (mut store, grads, mut rng) = setup();
        let mut opt = Fira::new(&store, 2);
        opt.begin_period(&store, &grads, &mut rng);
        let idx = store.projectable_indices()[0];
        let before = store.blocks[idx].value.clone();
        opt.step(&mut store, &grads, &StepCtx { lr: 0.1, step: 0 });
        let delta = before.sub(&store.blocks[idx].value);
        let s = crate::linalg::singular_values(&delta);
        // Unlike GaLore(r=2), the residual makes the update high-rank.
        assert!(s[5] > 1e-4 * s[0], "{:?}", &s[..8]);
    }

    #[test]
    fn limiter_caps_scale_growth() {
        let (mut store, grads, mut rng) = setup();
        let mut opt = Fira::new(&store, 2);
        opt.begin_period(&store, &grads, &mut rng);
        opt.step(&mut store, &grads, &StepCtx { lr: 0.01, step: 0 });
        let idx = store.projectable_indices()[0];
        let s1 = opt.prev_scale[idx];
        assert!(s1 > 0.0);
        // Second step with identical grads: scale can't jump > limiter×.
        opt.step(&mut store, &grads, &StepCtx { lr: 0.01, step: 1 });
        let s2 = opt.prev_scale[idx];
        assert!(s2 <= opt.limiter * s1 + 1e-6);
    }

    #[test]
    fn bf16_state_shrinks_moment_footprint() {
        let (mut store, grads, mut rng) = setup();
        let mut opt = Fira::new(&store, 2);
        opt.set_state_dtype(StateDtype::Bf16).unwrap();
        let mut f32_opt = Fira::new(&store, 2);
        let mut rng2 = Pcg::new(0);
        opt.begin_period(&store, &grads, &mut rng);
        f32_opt.begin_period(&store, &grads, &mut rng2);
        let mut s2 = store.clone();
        opt.step(&mut store, &grads, &StepCtx { lr: 0.01, step: 0 });
        f32_opt.step(&mut s2, &grads, &StepCtx { lr: 0.01, step: 0 });
        assert!(opt.state_bytes() < f32_opt.state_bytes());
        for b in &store.blocks {
            assert!(b.value.is_finite(), "{} went non-finite", b.name);
        }
    }

    #[test]
    fn state_scales_with_rank_not_full_dim() {
        let (store, grads, mut rng) = setup();
        let mut opt = Fira::new(&store, 2);
        opt.begin_period(&store, &grads, &mut rng);
        let mut s = store.clone();
        opt.step(&mut s, &grads, &StepCtx { lr: 0.01, step: 0 });
        // Projected moments are rank-2 sized, far below full Adam.
        let full_adam = super::super::Adam::new(&store, 0.9, 0.999, 1e-8, 0.0);
        let mut s2 = store.clone();
        let mut fa = full_adam;
        fa.step(&mut s2, &grads, &StepCtx { lr: 0.01, step: 0 });
        assert!(opt.state_bytes() < fa.state_bytes());
    }
}
