//! Adaptive refresh-period scheduling: a drift-driven controller that
//! re-decides the projector refresh period K at every boundary.
//!
//! PR 6 made the projection rank r adaptive; this module co-adapts the
//! refresh *frequency* from the same refresh-time observations
//! (AdaRankGrad argues rank and refresh cadence should move together;
//! GaLore 2 shows refresh cost dominates at scale). The controller
//! watches how much the committed subspace actually moves between
//! consecutive refreshes — the principal-angle drift between the old
//! and new orthonormal bases — and:
//!
//! 1. **Stretches** the period (up to `max_period`) when the subspace
//!    is stable: drift stays below the `drift` threshold for
//!    `patience` consecutive refreshes (hysteresis, so one quiet
//!    refresh never commits a longer period).
//! 2. **Shrinks** it immediately (down to `min_period`) on a drift
//!    spike or whenever the rank controller changed any block's rank —
//!    a rank change re-shapes the subspace, so the next refresh should
//!    come sooner, not later.
//!
//! The decision is a pure integer function of the observed drift
//! sequence, so adaptive-K runs keep the repo's bit-identical
//! trajectory invariant: the drift is computed inside the (sync or
//! async) refresh job from snapshotted bases, ships in
//! [`PreparedRefresh::period_state`](crate::optim::PreparedRefresh),
//! and only commits at the boundary via
//! [`PeriodScheduler::commit_boundary`](crate::coordinator::PeriodScheduler::commit_boundary).
//! Controller bookkeeping rides in checkpoints as a [`PeriodState`]
//! (part of the `GUMCKPT3` `PERIODS` section) so resumes continue the
//! schedule rather than restarting it.

use anyhow::{ensure, Result};

use super::projection::Projector;

/// Whether the refresh period K is static config or driven by the
/// subspace-drift controller.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum PeriodSchedule {
    /// Static period: exactly the pre-existing behavior, bit-for-bit.
    #[default]
    Fixed,
    /// Drift-driven controller re-decides K at every boundary.
    Adaptive(AdaptivePeriodCfg),
}

impl PeriodSchedule {
    /// Parse a CLI/config spelling: `fixed` | `adaptive`.
    pub fn parse(s: &str) -> Result<PeriodSchedule> {
        match s.to_ascii_lowercase().as_str() {
            "fixed" | "static" => Ok(PeriodSchedule::Fixed),
            "adaptive" | "auto" => {
                Ok(PeriodSchedule::Adaptive(AdaptivePeriodCfg::default()))
            }
            other => anyhow::bail!(
                "unknown period schedule '{other}' (expected fixed|adaptive)"
            ),
        }
    }

    /// Stable label for logs/metrics.
    pub fn label(&self) -> &'static str {
        match self {
            PeriodSchedule::Fixed => "fixed",
            PeriodSchedule::Adaptive(_) => "adaptive",
        }
    }
}

/// Controller knobs. Zero-valued period fields are sentinels resolved
/// against the configured base period at build time (see
/// [`AdaptivePeriodCfg::resolved`]).
#[derive(Debug, Clone, PartialEq)]
pub struct AdaptivePeriodCfg {
    /// Subspace-drift threshold: refreshes whose worst per-block drift
    /// stays at or below this count as "stable". Drift is
    /// `1 - ‖P_oldᵀ P_new‖²_F / min(r_old, r_new)` ∈ [0, 1]
    /// (0 ≙ identical subspace, 1 ≙ orthogonal).
    pub drift: f64,
    /// Consecutive stable refreshes required before the period
    /// stretches. Shrinks are immediate — a drift spike or rank change
    /// must not wait out a patience window while the basis goes stale.
    pub patience: u32,
    /// Period floor (0 ≙ auto: `max(1, base / 2)`).
    pub min_period: usize,
    /// Period ceiling (0 ≙ auto: `8 · base`).
    pub max_period: usize,
}

impl Default for AdaptivePeriodCfg {
    fn default() -> Self {
        AdaptivePeriodCfg {
            drift: 0.15,
            patience: 2,
            min_period: 0,
            max_period: 0,
        }
    }
}

impl AdaptivePeriodCfg {
    /// Concretize the auto sentinels against the configured base
    /// period.
    pub fn resolved(&self, base_period: usize) -> AdaptivePeriodCfg {
        let base = base_period.max(1);
        let mut c = self.clone();
        if c.min_period == 0 {
            c.min_period = (base / 2).max(1);
        }
        c.min_period = c.min_period.max(1);
        if c.max_period == 0 {
            c.max_period = 8 * base;
        }
        c.max_period = c.max_period.max(c.min_period);
        c.drift = c.drift.clamp(0.0, 1.0);
        c
    }
}

/// The controller's serializable bookkeeping: the committed period
/// plus everything the next decision depends on. Rides in
/// `PreparedRefresh` through the async pipeline and in the `GUMCKPT3`
/// `PERIODS` section.
#[derive(Debug, Clone, PartialEq)]
pub struct PeriodState {
    /// Committed period length after the most recent observation.
    pub period: u32,
    /// Consecutive stable refreshes accumulated toward a stretch.
    pub streak: u32,
    /// Drift observations consumed so far (the first refresh after a
    /// cold start has no predecessor basis and contributes none).
    pub observations: u32,
    /// Worst per-block drift at the most recent observation (metrics /
    /// diagnostics only — decisions use it before it is stored).
    pub last_drift: f32,
    /// Per-block committed ranks at the previous refresh; a mismatch
    /// against the next refresh's ranks triggers an immediate shrink.
    /// Empty until a rank-controlled refresh has been observed.
    pub prev_ranks: Vec<u32>,
}

/// Drift-driven refresh-period controller. Observes one drift summary
/// per refresh (computed off the critical path inside the refresh
/// job) and maintains the committed period with stretch-hysteresis /
/// immediate-shrink semantics.
#[derive(Debug, Clone)]
pub struct PeriodController {
    cfg: AdaptivePeriodCfg,
    period: usize,
    streak: u32,
    observations: u32,
    last_drift: f32,
    prev_ranks: Vec<u32>,
}

impl PeriodController {
    /// Build a controller starting at the configured base period
    /// (clamped into the resolved `[min_period, max_period]`).
    pub fn new(cfg: &AdaptivePeriodCfg, base_period: usize) -> PeriodController {
        let cfg = cfg.resolved(base_period);
        let period = base_period.clamp(cfg.min_period, cfg.max_period);
        PeriodController {
            cfg,
            period,
            streak: 0,
            observations: 0,
            last_drift: 0.0,
            prev_ranks: Vec::new(),
        }
    }

    /// The currently committed period length.
    pub fn period(&self) -> usize {
        self.period
    }

    /// Worst per-block drift at the most recent observation.
    pub fn last_drift(&self) -> f32 {
        self.last_drift
    }

    /// Resolved controller configuration.
    pub fn cfg(&self) -> &AdaptivePeriodCfg {
        &self.cfg
    }

    /// Consume one refresh observation: per-block subspace drifts
    /// (`None` where a block had no predecessor basis) plus the
    /// refresh's committed ranks when a rank controller ran. Pure
    /// integer/`f64` state machine — no RNG, no time.
    pub fn observe(&mut self, drifts: &[Option<f64>], ranks: Option<&[u32]>) {
        let max_drift = drifts
            .iter()
            .flatten()
            .copied()
            .fold(None, |acc: Option<f64>, d| {
                Some(acc.map_or(d, |a| a.max(d)))
            });
        let rank_changed = match ranks {
            Some(r) if !self.prev_ranks.is_empty() => r != &self.prev_ranks[..],
            _ => false,
        };
        if let Some(r) = ranks {
            self.prev_ranks = r.to_vec();
        }
        let Some(drift) = max_drift else {
            // First refresh (no predecessor basis anywhere): no signal,
            // and no stable-streak credit either.
            self.streak = 0;
            return;
        };
        self.observations += 1;
        self.last_drift = drift as f32;
        if rank_changed || drift > self.cfg.drift {
            // Spike: halve toward the floor immediately.
            self.period = (self.period / 2).max(self.cfg.min_period);
            self.streak = 0;
        } else {
            self.streak += 1;
            if self.streak >= self.cfg.patience.max(1) {
                // Stable long enough: stretch by 3/2 (at least +1).
                let grown = self.period + (self.period / 2).max(1);
                self.period = grown.min(self.cfg.max_period);
                self.streak = 0;
            }
        }
    }

    /// Serializable bookkeeping for checkpoints / the refresh
    /// pipeline.
    pub fn state(&self) -> PeriodState {
        PeriodState {
            period: self.period as u32,
            streak: self.streak,
            observations: self.observations,
            last_drift: self.last_drift,
            prev_ranks: self.prev_ranks.clone(),
        }
    }

    /// Adopt bookkeeping from a checkpoint or a prepared refresh.
    /// Rejects a period outside the resolved clamps (a snapshot from a
    /// differently-configured run).
    pub fn restore(&mut self, state: &PeriodState) -> Result<()> {
        let period = state.period as usize;
        ensure!(
            (self.cfg.min_period..=self.cfg.max_period).contains(&period),
            "period state {} outside configured clamp [{}, {}]",
            period,
            self.cfg.min_period,
            self.cfg.max_period,
        );
        self.period = period;
        self.streak = state.streak;
        self.observations = state.observations;
        self.last_drift = state.last_drift;
        self.prev_ranks = state.prev_ranks.clone();
        Ok(())
    }
}

/// Principal-angle drift between two column-orthonormal projector
/// bases: `1 - ‖P_oldᵀ P_new‖²_F / min(r_old, r_new)`, clamped to
/// [0, 1]. 0 means the new basis spans the old subspace exactly; 1
/// means the subspaces are orthogonal. Bases that project different
/// sides (or different row dimensions — a reshaped block) count as a
/// full drift of 1.
pub fn subspace_drift(old: &Projector, new: &Projector) -> f64 {
    if old.left != new.left || old.p.rows != new.p.rows {
        return 1.0;
    }
    let (r_old, r_new) = (old.p.cols, new.p.cols);
    if r_old == 0 || r_new == 0 {
        return 1.0;
    }
    // overlap = Σ_{ij} (old[:,i] · new[:,j])² accumulated in f64 over
    // sequential loops — deterministic regardless of thread width.
    let mut overlap = 0.0f64;
    for i in 0..r_old {
        for j in 0..r_new {
            let mut dot = 0.0f64;
            for k in 0..old.p.rows {
                dot += old.p.at(k, i) as f64 * new.p.at(k, j) as f64;
            }
            overlap += dot * dot;
        }
    }
    (1.0 - overlap / r_old.min(r_new) as f64).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    fn basis(rows: usize, cols: &[usize]) -> Projector {
        // Columns = standard unit vectors at the given row indices.
        let mut p = Matrix::zeros(rows, cols.len());
        for (j, &i) in cols.iter().enumerate() {
            *p.at_mut(i, j) = 1.0;
        }
        Projector {
            p,
            left: true,
            rank: cols.len(),
        }
    }

    #[test]
    fn parse_and_label() {
        assert_eq!(PeriodSchedule::parse("fixed").unwrap().label(), "fixed");
        assert_eq!(
            PeriodSchedule::parse("adaptive").unwrap().label(),
            "adaptive"
        );
        assert!(PeriodSchedule::parse("wat").is_err());
    }

    #[test]
    fn resolved_sentinels() {
        let c = AdaptivePeriodCfg::default().resolved(10);
        assert_eq!(c.min_period, 5);
        assert_eq!(c.max_period, 80);
        let c1 = AdaptivePeriodCfg::default().resolved(1);
        assert_eq!(c1.min_period, 1);
        assert_eq!(c1.max_period, 8);
    }

    #[test]
    fn drift_of_identical_and_orthogonal_bases() {
        let a = basis(8, &[0, 1, 2]);
        let b = basis(8, &[0, 1, 2]);
        assert!(subspace_drift(&a, &b) < 1e-9);
        let c = basis(8, &[3, 4, 5]);
        assert!((subspace_drift(&a, &c) - 1.0).abs() < 1e-9);
        // Partial overlap: 1 of min(3,3) directions shared.
        let d = basis(8, &[2, 6, 7]);
        assert!((subspace_drift(&a, &d) - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn drift_of_mismatched_shapes_is_full() {
        let a = basis(8, &[0]);
        let mut b = basis(9, &[0]);
        assert_eq!(subspace_drift(&a, &b), 1.0);
        b = basis(8, &[0]);
        b.left = false;
        assert_eq!(subspace_drift(&a, &b), 1.0);
    }

    #[test]
    fn stretch_needs_patience_and_shrink_is_immediate() {
        let cfg = AdaptivePeriodCfg {
            drift: 0.2,
            patience: 2,
            min_period: 2,
            max_period: 40,
        };
        let mut ctl = PeriodController::new(&cfg, 10);
        assert_eq!(ctl.period(), 10);
        // One stable refresh: no change yet (patience = 2).
        ctl.observe(&[Some(0.05)], None);
        assert_eq!(ctl.period(), 10);
        // Second stable refresh: stretch 10 → 15.
        ctl.observe(&[Some(0.05)], None);
        assert_eq!(ctl.period(), 15);
        // Drift spike: shrink immediately 15 → 7.
        ctl.observe(&[Some(0.9)], None);
        assert_eq!(ctl.period(), 7);
        // Spikes keep halving down to the floor.
        ctl.observe(&[Some(0.9)], None);
        ctl.observe(&[Some(0.9)], None);
        assert_eq!(ctl.period(), 2);
    }

    #[test]
    fn rank_change_shrinks_even_when_drift_is_low() {
        let cfg = AdaptivePeriodCfg {
            drift: 0.5,
            patience: 1,
            min_period: 1,
            max_period: 100,
        };
        let mut ctl = PeriodController::new(&cfg, 8);
        ctl.observe(&[Some(0.01)], Some(&[4, 4]));
        assert_eq!(ctl.period(), 12);
        // Same ranks: stable, keeps stretching.
        ctl.observe(&[Some(0.01)], Some(&[4, 4]));
        assert_eq!(ctl.period(), 18);
        // Rank changed: immediate shrink despite tiny drift.
        ctl.observe(&[Some(0.01)], Some(&[4, 2]));
        assert_eq!(ctl.period(), 9);
    }

    #[test]
    fn first_observation_without_drift_gives_no_credit() {
        let cfg = AdaptivePeriodCfg {
            drift: 0.2,
            patience: 1,
            min_period: 1,
            max_period: 100,
        };
        let mut ctl = PeriodController::new(&cfg, 4);
        // Cold start: no predecessor basis anywhere.
        ctl.observe(&[None, None], None);
        assert_eq!(ctl.period(), 4);
        assert_eq!(ctl.state().observations, 0);
    }

    #[test]
    fn period_clamps_at_max() {
        let cfg = AdaptivePeriodCfg {
            drift: 0.5,
            patience: 1,
            min_period: 1,
            max_period: 10,
        };
        let mut ctl = PeriodController::new(&cfg, 8);
        ctl.observe(&[Some(0.0)], None);
        assert_eq!(ctl.period(), 10);
        ctl.observe(&[Some(0.0)], None);
        assert_eq!(ctl.period(), 10);
    }

    #[test]
    fn state_round_trips_and_rejects_out_of_clamp() {
        let cfg = AdaptivePeriodCfg::default();
        let mut ctl = PeriodController::new(&cfg, 10);
        ctl.observe(&[Some(0.01)], Some(&[3]));
        ctl.observe(&[Some(0.01)], Some(&[3]));
        let state = ctl.state();
        let mut fresh = PeriodController::new(&cfg, 10);
        fresh.restore(&state).unwrap();
        assert_eq!(fresh.state(), state);
        let mut bad = state.clone();
        bad.period = 100_000;
        assert!(fresh.restore(&bad).is_err());
    }
}
