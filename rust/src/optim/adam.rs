//! Adam / AdamW over all blocks (the paper's FT-AdamW baseline).

use crate::linalg::lowp::StateDtype;
use crate::linalg::Matrix;
use crate::model::ParamStore;

use super::dense::DenseAdamW;
use super::{Optimizer, StepCtx};

/// Full-parameter Adam(W).
pub struct Adam {
    states: Vec<DenseAdamW>,
    weight_decay: f32,
}

impl Adam {
    pub fn new(
        params: &ParamStore,
        beta1: f32,
        beta2: f32,
        eps: f32,
        weight_decay: f32,
    ) -> Adam {
        let states = params
            .blocks
            .iter()
            .map(|b| {
                DenseAdamW::new(
                    b.value.shape(),
                    beta1,
                    beta2,
                    eps,
                    weight_decay,
                )
            })
            .collect();
        Adam {
            states,
            weight_decay,
        }
    }
}

impl Optimizer for Adam {
    fn name(&self) -> String {
        if self.weight_decay > 0.0 {
            "adamw".into()
        } else {
            "adam".into()
        }
    }

    fn step(&mut self, params: &mut ParamStore, grads: &[Matrix], ctx: &StepCtx) {
        assert_eq!(params.blocks.len(), grads.len());
        for (i, block) in params.blocks.iter_mut().enumerate() {
            self.states[i].step(&mut block.value, &grads[i], ctx.lr);
        }
    }

    fn state_bytes(&self) -> usize {
        self.states.iter().map(|s| s.state_bytes()).sum()
    }

    fn set_state_dtype(&mut self, dtype: StateDtype) -> anyhow::Result<()> {
        for s in &mut self.states {
            s.set_dtype(dtype);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{init_param_store, registry};

    #[test]
    fn state_is_two_moments_per_param() {
        let store = init_param_store(&registry::get("micro").unwrap(), 0);
        let opt = Adam::new(&store, 0.9, 0.999, 1e-8, 0.01);
        assert_eq!(opt.state_bytes(), 2 * store.n_params() * 4);
        assert_eq!(opt.name(), "adamw");
    }

    #[test]
    fn bf16_state_halves_accounting() {
        let store = init_param_store(&registry::get("micro").unwrap(), 0);
        let mut opt = Adam::new(&store, 0.9, 0.999, 1e-8, 0.01);
        opt.set_state_dtype(StateDtype::Bf16).unwrap();
        assert_eq!(opt.state_bytes(), 2 * store.n_params() * 2);
    }

    #[test]
    fn reduces_quadratic_loss_on_all_blocks() {
        let mut store = init_param_store(&registry::get("micro").unwrap(), 0);
        let targets: Vec<Matrix> = store
            .blocks
            .iter()
            .map(|b| Matrix::zeros(b.value.rows, b.value.cols))
            .collect();
        let mut opt = Adam::new(&store, 0.9, 0.999, 1e-8, 0.0);
        let loss = |s: &ParamStore| -> f64 {
            s.blocks
                .iter()
                .map(|b| {
                    b.value.data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>()
                })
                .sum()
        };
        let l0 = loss(&store);
        for step in 0..50 {
            let grads: Vec<Matrix> = store
                .blocks
                .iter()
                .zip(&targets)
                .map(|(b, t)| b.value.sub(t))
                .collect();
            opt.step(&mut store, &grads, &StepCtx { lr: 0.05, step });
        }
        assert!(loss(&store) < 0.5 * l0);
    }
}
