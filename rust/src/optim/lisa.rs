//! LISA [Pan et al., 2024]: layerwise importance sampling — the
//! debiasing technique's ancestor. Each period, γ of the N_L projectable
//! blocks are sampled active (full AdamW updates); the rest are frozen.
//! Dense blocks (embeddings/norms/head) are always trained, as in the
//! LISA paper.

use crate::linalg::lowp::StateDtype;
use crate::linalg::Matrix;
use crate::model::{BlockKind, ParamStore};
use crate::rng::Pcg;

use super::dense::DenseAdamW;
use super::{Optimizer, StepCtx};

/// LISA over a parameter store.
pub struct Lisa {
    /// Number of projectable blocks active per period.
    pub gamma: f64,
    active: Vec<bool>,
    states: Vec<Option<DenseAdamW>>,
    dense: Vec<Option<DenseAdamW>>,
}

impl Lisa {
    pub fn new(params: &ParamStore, gamma: f64) -> Lisa {
        let n = params.blocks.len();
        let mut states = Vec::with_capacity(n);
        let mut dense = Vec::with_capacity(n);
        for b in &params.blocks {
            match b.kind {
                BlockKind::Projectable => {
                    states.push(Some(DenseAdamW::new(
                        b.value.shape(),
                        0.9,
                        0.999,
                        1e-8,
                        0.0,
                    )));
                    dense.push(None);
                }
                BlockKind::Dense => {
                    states.push(None);
                    dense.push(Some(DenseAdamW::new(
                        b.value.shape(),
                        0.9,
                        0.999,
                        1e-8,
                        0.0,
                    )));
                }
            }
        }
        Lisa {
            gamma,
            active: vec![false; n],
            states,
            dense,
        }
    }

    pub fn active_mask(&self) -> &[bool] {
        &self.active
    }
}

impl Optimizer for Lisa {
    fn name(&self) -> String {
        format!("lisa(g={})", self.gamma)
    }

    fn begin_period(
        &mut self,
        params: &ParamStore,
        _grads: &[Matrix],
        rng: &mut Pcg,
    ) {
        let proj = params.projectable_indices();
        self.active.fill(false);
        let k = (self.gamma.round() as usize).min(proj.len());
        for pick in rng.sample_indices(proj.len(), k) {
            self.active[proj[pick]] = true;
        }
        // Activated blocks restart their moments (their states went
        // stale while frozen); matches the LISA reference.
        for (i, active) in self.active.iter().enumerate() {
            if *active {
                if let Some(s) = self.states[i].as_mut() {
                    s.reset();
                }
            }
        }
    }

    fn step(&mut self, params: &mut ParamStore, grads: &[Matrix], ctx: &StepCtx) {
        for (i, block) in params.blocks.iter_mut().enumerate() {
            match block.kind {
                BlockKind::Dense => {
                    self.dense[i].as_mut().unwrap().step(
                        &mut block.value,
                        &grads[i],
                        ctx.lr,
                    );
                }
                BlockKind::Projectable => {
                    if self.active[i] {
                        self.states[i].as_mut().unwrap().step(
                            &mut block.value,
                            &grads[i],
                            ctx.lr,
                        );
                    }
                }
            }
        }
    }

    fn state_bytes(&self) -> usize {
        // Only active blocks need live moments on-device; frozen blocks'
        // moments are zeroed/offloadable. Count active + dense.
        let active: usize = self
            .states
            .iter()
            .enumerate()
            .filter(|(i, s)| self.active[*i] && s.is_some())
            .map(|(_, s)| s.as_ref().unwrap().state_bytes())
            .sum();
        active
            + self
                .dense
                .iter()
                .flatten()
                .map(|d| d.state_bytes())
                .sum::<usize>()
    }

    fn set_state_dtype(&mut self, dtype: StateDtype) -> anyhow::Result<()> {
        // Every per-block AdamW state exists from construction (frozen
        // blocks merely reset on activation), so one sweep covers all.
        for s in self.states.iter_mut().chain(self.dense.iter_mut()).flatten()
        {
            s.set_dtype(dtype);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{init_param_store, registry};

    fn setup() -> (ParamStore, Vec<Matrix>) {
        let store = init_param_store(&registry::get("micro").unwrap(), 0);
        let mut rng = Pcg::new(0);
        let grads: Vec<Matrix> = store
            .blocks
            .iter()
            .map(|b| Matrix::randn(b.value.rows, b.value.cols, 1.0, &mut rng))
            .collect();
        (store, grads)
    }

    #[test]
    fn exactly_gamma_blocks_active() {
        let (store, grads) = setup();
        let mut opt = Lisa::new(&store, 3.0);
        let mut rng = Pcg::new(1);
        opt.begin_period(&store, &grads, &mut rng);
        assert_eq!(opt.active_mask().iter().filter(|&&a| a).count(), 3);
    }

    #[test]
    fn frozen_blocks_do_not_move() {
        let (mut store, grads) = setup();
        let mut opt = Lisa::new(&store, 1.0);
        let mut rng = Pcg::new(2);
        opt.begin_period(&store, &grads, &mut rng);
        let frozen: Vec<usize> = store
            .projectable_indices()
            .into_iter()
            .filter(|&i| !opt.active_mask()[i])
            .collect();
        assert!(!frozen.is_empty());
        let before: Vec<Matrix> = frozen
            .iter()
            .map(|&i| store.blocks[i].value.clone())
            .collect();
        opt.step(&mut store, &grads, &StepCtx { lr: 0.1, step: 0 });
        for (j, &i) in frozen.iter().enumerate() {
            assert_eq!(store.blocks[i].value, before[j], "block {i} moved");
        }
    }

    #[test]
    fn dense_blocks_always_train() {
        let (mut store, grads) = setup();
        let mut opt = Lisa::new(&store, 1.0);
        let mut rng = Pcg::new(3);
        opt.begin_period(&store, &grads, &mut rng);
        let before = store.get("embed").unwrap().value.clone();
        opt.step(&mut store, &grads, &StepCtx { lr: 0.1, step: 0 });
        assert!(store.get("embed").unwrap().value.max_abs_diff(&before) > 0.0);
    }

    #[test]
    fn sampling_varies_across_periods() {
        let (store, grads) = setup();
        let mut opt = Lisa::new(&store, 2.0);
        let mut rng = Pcg::new(4);
        opt.begin_period(&store, &grads, &mut rng);
        let m1 = opt.active_mask().to_vec();
        let mut changed = false;
        for _ in 0..10 {
            opt.begin_period(&store, &grads, &mut rng);
            if opt.active_mask() != m1.as_slice() {
                changed = true;
                break;
            }
        }
        assert!(changed, "sampling never changed in 10 periods");
    }
}
