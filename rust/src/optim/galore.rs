//! GaLore [Zhao et al., 2024]: gradient low-rank projection with a
//! pluggable base optimizer (Algorithm 1 of the paper).
//!
//! `ProjKind::SvdTopR` gives vanilla GaLore; `ProjKind::Random` gives
//! GoLore [He et al., 2024]. Base optimizer options are Muon (the
//! GaLore-Muon baseline the paper's Figure 1 breaks) and Adam (the
//! original GaLore). Dense blocks use AdamW.
//!
//! This is the **biased** algorithm: the effective gradient P Pᵀ G is not
//! an unbiased estimate of G — quantified by `analysis::bias` (Fig. 4)
//! and broken outright by `synthetic::linreg` (Fig. 1).

use crate::linalg::lowp::{self, MomentBuf, StateDtype};
use crate::linalg::{newton_schulz_into, Matrix, NS_STEPS};
use crate::model::{BlockKind, ParamStore};
use crate::rng::Pcg;

use super::dense::DenseAdamW;
use super::projection::{ProjKind, Projector, RankProbe, RefreshStrategy};
use super::rank_schedule::{resize_moment_buf, RankController, RankState};
use super::{Optimizer, PreparedRefresh, RefreshJob, StepCtx, StepScratch};

/// Base optimizer run inside the projected space.
#[derive(Debug, Clone, Copy)]
pub enum BaseOpt {
    Muon { beta: f32 },
    Adam { beta1: f32, beta2: f32, eps: f32 },
}

/// Per-projectable-block state.
enum BlockState {
    Muon {
        proj: Option<Projector>,
        momentum: Option<MomentBuf>,
    },
    Adam {
        proj: Option<Projector>,
        m: Option<MomentBuf>,
        v: Option<MomentBuf>,
        t: usize,
    },
}

impl BlockState {
    fn take_proj(&mut self) -> Option<Projector> {
        match self {
            BlockState::Muon { proj, .. } => proj.take(),
            BlockState::Adam { proj, .. } => proj.take(),
        }
    }
}

/// Install a freshly built projector, honoring `restart_on_period`; when
/// the projected shape changed (an adaptive rank change), the persistent
/// base-optimizer moments are resized (overlap-copy + zero-pad) so the
/// fused elementwise kernels keep operating on length-matched buffers.
fn install_projector(
    state: &mut BlockState,
    proj: Projector,
    block_shape: (usize, usize),
    restart: bool,
) {
    let (pm, pn) = proj.projected_shape(block_shape.0, block_shape.1);
    match state {
        BlockState::Muon { proj: p, momentum } => {
            *p = Some(proj);
            if restart {
                *momentum = None;
            } else if let Some(mom) = momentum.as_mut() {
                if mom.shape() != (pm, pn) {
                    *mom = resize_moment_buf(mom, pm, pn);
                }
            }
        }
        BlockState::Adam { proj: p, m, v, t } => {
            *p = Some(proj);
            if restart {
                *m = None;
                *v = None;
                *t = 0;
            } else {
                for buf in [m, v] {
                    if let Some(b) = buf.as_mut() {
                        if b.shape() != (pm, pn) {
                            *b = resize_moment_buf(b, pm, pn);
                        }
                    }
                }
            }
        }
    }
}

/// GaLore/GoLore over a parameter store.
pub struct GaLore {
    pub rank: usize,
    pub base: BaseOpt,
    pub kind: ProjKind,
    /// Restart base-optimizer state when projectors refresh. Official
    /// GaLore keeps state across refreshes; Algorithm 1/3 in this paper
    /// restarts. Default false (official behaviour).
    pub restart_on_period: bool,
    /// Muon-style update RMS scaling (LLM practice). Off for the
    /// paper-faithful synthetic benches.
    pub rms_scale: bool,
    /// Projector-refresh engine for `ProjKind::SvdTopR` (ignored for
    /// GoLore's random projectors).
    pub refresh: RefreshStrategy,
    /// Adaptive rank controller (`--rank-schedule adaptive`). GaLore's
    /// base-optimizer moments persist across refreshes, so a rank
    /// change also resizes them (overlap-copy + zero-pad) to the new
    /// projected shape. `None` ≙ the fixed schedule, bit-for-bit.
    pub rank_ctl: Option<RankController>,
    /// Storage dtype for the base-optimizer moments (projectors stay
    /// f32). Configured at build time via `set_state_dtype`; lazily
    /// allocated moments pick it up on first use.
    state_dtype: StateDtype,
    states: Vec<Option<BlockState>>,
    dense: Vec<Option<DenseAdamW>>,
    /// Per-step matrix temps, reused across blocks and steps.
    scratch: StepScratch,
}

impl GaLore {
    pub fn new(
        params: &ParamStore,
        rank: usize,
        base: BaseOpt,
        kind: ProjKind,
    ) -> GaLore {
        let mut states = Vec::new();
        let mut dense = Vec::new();
        for b in &params.blocks {
            match b.kind {
                BlockKind::Projectable => {
                    states.push(Some(match base {
                        BaseOpt::Muon { .. } => BlockState::Muon {
                            proj: None,
                            momentum: None,
                        },
                        BaseOpt::Adam { .. } => BlockState::Adam {
                            proj: None,
                            m: None,
                            v: None,
                            t: 0,
                        },
                    }));
                    dense.push(None);
                }
                BlockKind::Dense => {
                    states.push(None);
                    dense.push(Some(DenseAdamW::new(
                        b.value.shape(),
                        0.9,
                        0.999,
                        1e-8,
                        0.0,
                    )));
                }
            }
        }
        GaLore {
            rank,
            base,
            kind,
            restart_on_period: false,
            rms_scale: true,
            refresh: RefreshStrategy::default(),
            rank_ctl: None,
            state_dtype: StateDtype::F32,
            states,
            dense,
            scratch: StepScratch::new(),
        }
    }

    fn update_scale(&self, rows: usize, cols: usize) -> f32 {
        if self.rms_scale {
            0.2 * (rows.max(cols) as f32).sqrt()
        } else {
            1.0
        }
    }
}

impl Optimizer for GaLore {
    fn name(&self) -> String {
        let base = match self.base {
            BaseOpt::Muon { .. } => "muon",
            BaseOpt::Adam { .. } => "adam",
        };
        let fam = match self.kind {
            ProjKind::SvdTopR => "galore",
            ProjKind::Random => "golore",
        };
        format!("{fam}-{base}(r={})", self.rank)
    }

    fn begin_period(
        &mut self,
        params: &ParamStore,
        grads: &[Matrix],
        rng: &mut Pcg,
    ) {
        if self.rank_ctl.is_some() {
            // Adaptive schedule: probe every block at the rank ceiling
            // (same canonical order and caller stream as the fixed
            // rebuild), let the controller commit ranks from the
            // observed spectra, then truncate each probe basis.
            let ctl_ref = self.rank_ctl.as_ref().unwrap();
            let mut probes: Vec<Option<RankProbe>> =
                Vec::with_capacity(self.states.len());
            for (i, state) in self.states.iter_mut().enumerate() {
                let Some(state) = state else {
                    probes.push(None);
                    continue;
                };
                let prev = state.take_proj();
                probes.push(Some(Projector::probe_with(
                    &grads[i],
                    ctl_ref.probe_rank(i),
                    self.refresh,
                    prev.as_ref(),
                    rng,
                )));
            }
            let ctl = self.rank_ctl.as_mut().unwrap();
            let spectra: Vec<Option<&[f32]>> = probes
                .iter()
                .map(|p| p.as_ref().map(|p| p.spectrum()))
                .collect();
            ctl.observe(&spectra);
            drop(spectra);
            let restart = self.restart_on_period;
            for (i, (state, probe)) in
                self.states.iter_mut().zip(probes).enumerate()
            {
                let (Some(state), Some(probe)) = (state.as_mut(), probe)
                else {
                    continue;
                };
                install_projector(
                    state,
                    probe.into_projector(ctl.rank_of(i)),
                    params.blocks[i].value.shape(),
                    restart,
                );
            }
            return;
        }
        for (i, state) in self.states.iter_mut().enumerate() {
            let Some(state) = state else { continue };
            let prev = state.take_proj();
            let proj = Projector::build_with(
                &grads[i],
                self.rank,
                self.kind,
                self.refresh,
                prev.as_ref(),
                rng,
            );
            install_projector(
                state,
                proj,
                params.blocks[i].value.shape(),
                self.restart_on_period,
            );
        }
    }

    /// Refresh-pipeline prepare: clone the gradient snapshot, the
    /// current projectors as warm bases, and the pipeline-derived RNG
    /// stream into an owned job building every projectable block's next
    /// basis in canonical block order — the same sequence of draws a
    /// synchronous rebuild over one stream makes.
    fn plan_refresh(
        &self,
        grads: &[Matrix],
        rng: &mut Pcg,
    ) -> Option<RefreshJob> {
        let rank = self.rank;
        let kind = self.kind;
        let refresh = self.refresh;
        let blocks: Vec<_> = self
            .states
            .iter()
            .enumerate()
            .map(|(i, state)| {
                state.as_ref().map(|s| {
                    let prev = match s {
                        BlockState::Muon { proj, .. } => proj.clone(),
                        BlockState::Adam { proj, .. } => proj.clone(),
                    };
                    (grads[i].clone(), prev)
                })
            })
            .collect();
        let mut job_rng = rng.clone();
        let rank_ctl = self.rank_ctl.clone();
        Some(Box::new(move || match rank_ctl {
            None => PreparedRefresh {
                projectors: blocks
                    .into_iter()
                    .map(|slot| {
                        slot.map(|(g, warm)| {
                            Projector::build_with(
                                &g,
                                rank,
                                kind,
                                refresh,
                                warm.as_ref(),
                                &mut job_rng,
                            )
                        })
                    })
                    .collect(),
                rank_state: None,
                period_state: None,
            },
            Some(mut ctl) => {
                // The job owns a controller clone: probe, observe, and
                // commit the next ranks off the critical path; the
                // bookkeeping rides back for the boundary handoff.
                let probes: Vec<Option<RankProbe>> = blocks
                    .into_iter()
                    .enumerate()
                    .map(|(i, slot)| {
                        slot.map(|(g, warm)| {
                            Projector::probe_with(
                                &g,
                                ctl.probe_rank(i),
                                refresh,
                                warm.as_ref(),
                                &mut job_rng,
                            )
                        })
                    })
                    .collect();
                let spectra: Vec<Option<&[f32]>> = probes
                    .iter()
                    .map(|p| p.as_ref().map(|p| p.spectrum()))
                    .collect();
                ctl.observe(&spectra);
                drop(spectra);
                PreparedRefresh {
                    projectors: probes
                        .into_iter()
                        .enumerate()
                        .map(|(i, p)| {
                            p.map(|p| p.into_projector(ctl.rank_of(i)))
                        })
                        .collect(),
                    rank_state: Some(ctl.state()),
                    period_state: None,
                }
            }
        }))
    }

    /// Refresh-pipeline handoff: swap in the precomputed bases, honoring
    /// `restart_on_period` exactly as [`GaLore::begin_period`] does. A
    /// missing slot falls back to a synchronous rebuild from the
    /// boundary gradient (defensive only).
    fn begin_period_prepared(
        &mut self,
        params: &ParamStore,
        grads: &[Matrix],
        rng: &mut Pcg,
        prepared: PreparedRefresh,
    ) {
        if self.rank_ctl.is_some() {
            match &prepared.rank_state {
                Some(rs) => {
                    if let Err(e) =
                        self.rank_ctl.as_mut().unwrap().restore(rs)
                    {
                        crate::warn!(
                            "galore: prepared rank state rejected ({e}); \
                             keeping controller state"
                        );
                    }
                }
                None => {
                    // Defensive: unreachable through the pipeline —
                    // plan_refresh always clones the controller. Fall
                    // back to the synchronous adaptive refresh.
                    crate::warn!(
                        "galore: prepared refresh missing rank state; \
                         re-probing synchronously"
                    );
                    self.begin_period(params, grads, rng);
                    return;
                }
            }
        }
        let restart = self.restart_on_period;
        let (rank, kind, refresh) = (self.rank, self.kind, self.refresh);
        let mut slots = prepared.projectors;
        slots.resize_with(self.states.len(), || None);
        let ctl = self.rank_ctl.as_ref();
        for (i, (state, slot)) in
            self.states.iter_mut().zip(slots).enumerate()
        {
            let Some(state) = state else { continue };
            let prev = state.take_proj();
            let proj = match slot {
                Some(p) => p,
                None => {
                    // Unreachable through a well-formed pipeline (every
                    // projectable block is planned); diverges from the
                    // trigger-time spec trace, so say so.
                    crate::warn!(
                        "galore: prepared refresh missing block {i}; \
                         rebuilding synchronously (trajectory may \
                         diverge from the sync spec)"
                    );
                    match ctl {
                        Some(ctl) => Projector::probe_with(
                            &grads[i],
                            ctl.probe_rank(i),
                            refresh,
                            prev.as_ref(),
                            rng,
                        )
                        .into_projector(ctl.rank_of(i)),
                        None => Projector::build_with(
                            &grads[i],
                            rank,
                            kind,
                            refresh,
                            prev.as_ref(),
                            rng,
                        ),
                    }
                }
            };
            install_projector(
                state,
                proj,
                params.blocks[i].value.shape(),
                restart,
            );
        }
    }

    fn step(&mut self, params: &mut ParamStore, grads: &[Matrix], ctx: &StepCtx) {
        assert_eq!(params.blocks.len(), grads.len());
        for (i, block) in params.blocks.iter_mut().enumerate() {
            match block.kind {
                BlockKind::Dense => {
                    self.dense[i].as_mut().unwrap().step(
                        &mut block.value,
                        &grads[i],
                        ctx.lr,
                    );
                }
                BlockKind::Projectable => {
                    let scale =
                        self.update_scale(block.value.rows, block.value.cols);
                    let base = self.base;
                    let dtype = self.state_dtype;
                    let scr = &mut self.scratch;
                    match self.states[i].as_mut().unwrap() {
                        BlockState::Muon { proj, momentum } => {
                            let proj = proj.as_ref().expect(
                                "begin_period must run before step",
                            );
                            proj.project_into(&grads[i], &mut scr.low);
                            let (rr, rc) = scr.low.shape();
                            let mom = momentum.get_or_insert_with(|| {
                                MomentBuf::zeros(dtype, rr, rc)
                            });
                            let beta = match base {
                                BaseOpt::Muon { beta } => beta,
                                _ => unreachable!(),
                            };
                            match mom {
                                MomentBuf::F32(mom) => {
                                    mom.axpby_in_place(beta, 1.0, &scr.low);
                                    newton_schulz_into(
                                        mom,
                                        NS_STEPS,
                                        &mut scr.ns,
                                        &mut scr.dir,
                                    );
                                }
                                MomentBuf::Lowp {
                                    dtype, rows, cols, bits,
                                } => {
                                    // The unrounded f32 accumulator is
                                    // the Newton–Schulz input; only the
                                    // rounded bits persist.
                                    scr.mom.resize(*rows, *cols);
                                    lowp::axpby(
                                        *dtype,
                                        beta,
                                        bits,
                                        1.0,
                                        &scr.low.data,
                                        &mut scr.mom.data,
                                    );
                                    newton_schulz_into(
                                        &scr.mom,
                                        NS_STEPS,
                                        &mut scr.ns,
                                        &mut scr.dir,
                                    );
                                }
                            }
                            proj.project_back_into(&scr.dir, &mut scr.full);
                            block
                                .value
                                .add_scaled_in_place(-ctx.lr * scale, &scr.full);
                        }
                        BlockState::Adam { proj, m, v, t } => {
                            let proj = proj.as_ref().expect(
                                "begin_period must run before step",
                            );
                            let (b1, b2, eps) = match base {
                                BaseOpt::Adam { beta1, beta2, eps } => {
                                    (beta1, beta2, eps)
                                }
                                _ => unreachable!(),
                            };
                            proj.project_into(&grads[i], &mut scr.low);
                            let (rr, rc) = scr.low.shape();
                            let m = m.get_or_insert_with(|| {
                                MomentBuf::zeros(dtype, rr, rc)
                            });
                            let v = v.get_or_insert_with(|| {
                                MomentBuf::zeros(dtype, rr, rc)
                            });
                            *t += 1;
                            let bc1 = 1.0 - b1.powi(*t as i32);
                            let bc2 = 1.0 - b2.powi(*t as i32);
                            scr.upd.resize(rr, rc);
                            // Fused single pass: both moment updates +
                            // the bias-corrected step direction.
                            match (m, v) {
                                (MomentBuf::F32(m), MomentBuf::F32(v)) => {
                                    crate::linalg::elementwise::adam_update(
                                        &mut scr.upd.data,
                                        &scr.low.data,
                                        &mut m.data,
                                        &mut v.data,
                                        b1,
                                        b2,
                                        bc1,
                                        bc2,
                                        eps,
                                    )
                                }
                                (
                                    MomentBuf::Lowp {
                                        dtype, bits: mb, ..
                                    },
                                    MomentBuf::Lowp { bits: vb, .. },
                                ) => lowp::adam_update(
                                    *dtype,
                                    &mut scr.upd.data,
                                    &scr.low.data,
                                    mb,
                                    vb,
                                    b1,
                                    b2,
                                    bc1,
                                    bc2,
                                    eps,
                                ),
                                _ => unreachable!("m and v share a dtype"),
                            }
                            proj.project_back_into(&scr.upd, &mut scr.full);
                            block.value.add_scaled_in_place(-ctx.lr, &scr.full);
                        }
                    }
                }
            }
        }
    }

    fn state_bytes(&self) -> usize {
        let mut total = 0;
        for s in self.states.iter().flatten() {
            match s {
                BlockState::Muon { proj, momentum } => {
                    total += proj.as_ref().map_or(0, |p| p.state_bytes());
                    total += momentum.as_ref().map_or(0, |m| m.state_bytes());
                }
                BlockState::Adam { proj, m, v, .. } => {
                    total += proj.as_ref().map_or(0, |p| p.state_bytes());
                    total += m.as_ref().map_or(0, |m| m.state_bytes());
                    total += v.as_ref().map_or(0, |v| v.state_bytes());
                }
            }
        }
        total += self
            .dense
            .iter()
            .flatten()
            .map(|d| d.state_bytes())
            .sum::<usize>();
        total
    }

    fn projectors(&self) -> Option<Vec<Option<Projector>>> {
        Some(
            self.states
                .iter()
                .map(|s| {
                    s.as_ref().and_then(|s| match s {
                        BlockState::Muon { proj, .. } => proj.clone(),
                        BlockState::Adam { proj, .. } => proj.clone(),
                    })
                })
                .collect(),
        )
    }

    fn rank_state(&self) -> Option<RankState> {
        self.rank_ctl.as_ref().map(|c| c.state())
    }

    fn restore_rank_state(&mut self, state: &RankState) -> anyhow::Result<()> {
        match self.rank_ctl.as_mut() {
            Some(c) => c.restore(state),
            None => anyhow::bail!(
                "galore was built with a fixed rank schedule; the \
                 checkpoint carries adaptive rank state"
            ),
        }
    }

    fn set_state_dtype(&mut self, dtype: StateDtype) -> anyhow::Result<()> {
        self.state_dtype = dtype;
        for d in self.dense.iter_mut().flatten() {
            d.set_dtype(dtype);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{init_param_store, registry};

    fn setup() -> (ParamStore, Vec<Matrix>, Pcg) {
        let store = init_param_store(&registry::get("micro").unwrap(), 0);
        let mut rng = Pcg::new(0);
        let grads: Vec<Matrix> = store
            .blocks
            .iter()
            .map(|b| Matrix::randn(b.value.rows, b.value.cols, 1.0, &mut rng))
            .collect();
        (store, grads, rng)
    }

    #[test]
    fn update_stays_in_projected_subspace_muon() {
        let (mut store, grads, mut rng) = setup();
        let mut opt = GaLore::new(
            &store,
            4,
            BaseOpt::Muon { beta: 0.9 },
            ProjKind::SvdTopR,
        );
        opt.rms_scale = false;
        opt.begin_period(&store, &grads, &mut rng);
        let idx = store.projectable_indices()[0];
        let before = store.blocks[idx].value.clone();
        opt.step(&mut store, &grads, &StepCtx { lr: 0.1, step: 0 });
        let delta = before.sub(&store.blocks[idx].value);
        // Δ lies in span(P): rank(Δ) ≤ 4 → 5th singular value ≈ 0.
        let s = crate::linalg::singular_values(&delta);
        assert!(s[3] > 1e-4, "update nontrivial");
        assert!(s[4] < 1e-4 * s[0], "rank ≤ 4: {:?}", &s[..6]);
    }

    #[test]
    fn adam_base_also_low_rank() {
        let (mut store, grads, mut rng) = setup();
        let mut opt = GaLore::new(
            &store,
            2,
            BaseOpt::Adam {
                beta1: 0.9,
                beta2: 0.999,
                eps: 1e-8,
            },
            ProjKind::SvdTopR,
        );
        opt.begin_period(&store, &grads, &mut rng);
        let idx = store.projectable_indices()[1];
        let before = store.blocks[idx].value.clone();
        opt.step(&mut store, &grads, &StepCtx { lr: 0.1, step: 0 });
        let delta = before.sub(&store.blocks[idx].value);
        let s = crate::linalg::singular_values(&delta);
        assert!(s[2] < 1e-4 * s[0], "rank ≤ 2");
    }

    #[test]
    fn state_bytes_scale_with_rank() {
        let (store, grads, mut rng) = setup();
        let mut lo = GaLore::new(
            &store,
            2,
            BaseOpt::Muon { beta: 0.9 },
            ProjKind::SvdTopR,
        );
        let mut hi = GaLore::new(
            &store,
            16,
            BaseOpt::Muon { beta: 0.9 },
            ProjKind::SvdTopR,
        );
        lo.begin_period(&store, &grads, &mut rng);
        hi.begin_period(&store, &grads, &mut rng);
        // Momentum allocates lazily on the first step.
        let mut s1 = store.clone();
        let mut s2 = store.clone();
        lo.step(&mut s1, &grads, &StepCtx { lr: 0.01, step: 0 });
        hi.step(&mut s2, &grads, &StepCtx { lr: 0.01, step: 0 });
        assert!(lo.state_bytes() < hi.state_bytes());
    }

    #[test]
    fn golore_uses_gradient_independent_projector() {
        // Two different gradients produce the same Random projector when
        // the RNG stream is the same.
        let (store, grads, _) = setup();
        let mut opt = GaLore::new(
            &store,
            4,
            BaseOpt::Muon { beta: 0.9 },
            ProjKind::Random,
        );
        let mut rng1 = Pcg::new(5);
        opt.begin_period(&store, &grads, &mut rng1);
        assert_eq!(opt.name(), "golore-muon(r=4)");
    }

    #[test]
    fn full_rank_galore_muon_equals_muon() {
        // With r = min(m, n) the projector is a complete orthonormal
        // basis, PPᵀ = I; by the commutation property (Lemma 1) the
        // GaLore-Muon update then equals plain Muon exactly.
        let (store, grads, mut rng) = setup();
        let mut ga = GaLore::new(
            &store,
            usize::MAX,
            BaseOpt::Muon { beta: 0.95 },
            ProjKind::SvdTopR,
        );
        ga.rms_scale = false;
        ga.begin_period(&store, &grads, &mut rng);
        let mut s1 = store.clone();
        ga.step(&mut s1, &grads, &StepCtx { lr: 0.1, step: 0 });

        let mut mu = super::super::Muon::new(&store, 0.95);
        mu.rms_scale = false;
        let mut s2 = store.clone();
        mu.step(&mut s2, &grads, &StepCtx { lr: 0.1, step: 0 });

        for idx in store.projectable_indices() {
            let d = s1.blocks[idx].value.max_abs_diff(&s2.blocks[idx].value);
            assert!(d < 2e-3, "block {idx}: {d}");
        }
    }

    #[test]
    fn projected_momentum_survives_refresh_without_restart() {
        // Official-GaLore semantics: momentum persists across projector
        // refreshes (the stale-basis effect behind Fig. 1's failure).
        let (mut store, grads, mut rng) = setup();
        let mut opt = GaLore::new(
            &store,
            4,
            BaseOpt::Muon { beta: 0.9 },
            ProjKind::SvdTopR,
        );
        assert!(!opt.restart_on_period);
        opt.begin_period(&store, &grads, &mut rng);
        opt.step(&mut store, &grads, &StepCtx { lr: 0.01, step: 0 });
        let bytes_before = opt.state_bytes();
        opt.begin_period(&store, &grads, &mut rng);
        // Momentum allocation was not dropped.
        assert_eq!(opt.state_bytes(), bytes_before);
    }

    #[test]
    fn adaptive_rank_change_resizes_persistent_moments() {
        use super::super::rank_schedule::{AdaptiveRankCfg, RankController};
        let (mut store, grads, mut rng) = setup();
        let mut opt = GaLore::new(
            &store,
            8,
            BaseOpt::Adam {
                beta1: 0.9,
                beta2: 0.999,
                eps: 1e-8,
            },
            ProjKind::SvdTopR,
        );
        let cfg = AdaptiveRankCfg {
            energy: 0.9,
            deadband: 0,
            patience: 1,
            min_rank: 1,
            max_rank: 12,
            budget: 1000,
        };
        opt.rank_ctl = Some(RankController::new(&cfg, &store, 8));
        opt.begin_period(&store, &grads, &mut rng);
        // Allocate Adam moments at the initial projected shapes.
        opt.step(&mut store, &grads, &StepCtx { lr: 0.01, step: 0 });
        // Rank-1 gradients collapse the spectrum → the controller
        // shrinks every projectable block to rank 1 (patience 1).
        let lr_grads: Vec<Matrix> = store
            .blocks
            .iter()
            .map(|b| {
                let u = Matrix::randn(b.value.rows, 1, 1.0, &mut rng);
                let v = Matrix::randn(1, b.value.cols, 1.0, &mut rng);
                crate::linalg::matmul(&u, &v)
            })
            .collect();
        opt.begin_period(&store, &lr_grads, &mut rng);
        let state = opt.rank_state().expect("adaptive rank state");
        for (b, &r) in store.blocks.iter().zip(&state.ranks) {
            match b.kind {
                BlockKind::Projectable => {
                    assert_eq!(r, 1, "{}: rank must collapse", b.name)
                }
                BlockKind::Dense => assert_eq!(r, 0),
            }
        }
        // Persistent moments were resized, so the fused Adam kernel
        // keeps operating on length-matched buffers.
        opt.step(&mut store, &lr_grads, &StepCtx { lr: 0.01, step: 1 });
        // And growing back (flat spectrum) also steps cleanly.
        opt.begin_period(&store, &grads, &mut rng);
        opt.step(&mut store, &grads, &StepCtx { lr: 0.01, step: 2 });
        for b in &store.blocks {
            assert!(b.value.is_finite(), "{} went non-finite", b.name);
        }
    }

    #[test]
    fn bf16_moments_shrink_state_and_stay_low_rank() {
        let (mut store, grads, mut rng) = setup();
        let mut opt = GaLore::new(
            &store,
            4,
            BaseOpt::Adam {
                beta1: 0.9,
                beta2: 0.999,
                eps: 1e-8,
            },
            ProjKind::SvdTopR,
        );
        opt.set_state_dtype(StateDtype::Bf16).unwrap();
        let mut f32_opt = GaLore::new(
            &store,
            4,
            BaseOpt::Adam {
                beta1: 0.9,
                beta2: 0.999,
                eps: 1e-8,
            },
            ProjKind::SvdTopR,
        );
        let mut rng2 = Pcg::new(0);
        opt.begin_period(&store, &grads, &mut rng);
        f32_opt.begin_period(&store, &grads, &mut rng2);
        let idx = store.projectable_indices()[0];
        let before = store.blocks[idx].value.clone();
        let mut s2 = store.clone();
        opt.step(&mut store, &grads, &StepCtx { lr: 0.1, step: 0 });
        f32_opt.step(&mut s2, &grads, &StepCtx { lr: 0.1, step: 0 });
        // Updates stay rank-4 and the moments cost half the bytes.
        let delta = before.sub(&store.blocks[idx].value);
        let s = crate::linalg::singular_values(&delta);
        assert!(s[4] < 1e-4 * s[0], "rank ≤ 4");
        assert!(opt.state_bytes() < f32_opt.state_bytes());
    }

    #[test]
    #[should_panic(expected = "begin_period")]
    fn step_without_period_panics() {
        let (mut store, grads, _) = setup();
        let mut opt = GaLore::new(
            &store,
            4,
            BaseOpt::Muon { beta: 0.9 },
            ProjKind::SvdTopR,
        );
        opt.step(&mut store, &grads, &StepCtx { lr: 0.1, step: 0 });
    }
}
