//! SGD with optional heavy-ball momentum (substrate baseline; also the
//! base algorithm in GoLore's original analysis).

use crate::linalg::Matrix;
use crate::model::ParamStore;

use super::{Optimizer, StepCtx};

/// SGD(+momentum) over all blocks.
pub struct Sgd {
    momentum: f32,
    velocity: Vec<Matrix>,
}

impl Sgd {
    pub fn new(params: &ParamStore, momentum: f32) -> Sgd {
        let velocity = if momentum > 0.0 {
            params
                .blocks
                .iter()
                .map(|b| Matrix::zeros(b.value.rows, b.value.cols))
                .collect()
        } else {
            Vec::new()
        };
        Sgd { momentum, velocity }
    }
}

impl Optimizer for Sgd {
    fn name(&self) -> String {
        if self.momentum > 0.0 {
            format!("sgdm(b={})", self.momentum)
        } else {
            "sgd".into()
        }
    }

    fn step(&mut self, params: &mut ParamStore, grads: &[Matrix], ctx: &StepCtx) {
        assert_eq!(params.blocks.len(), grads.len());
        for (i, block) in params.blocks.iter_mut().enumerate() {
            if self.momentum > 0.0 {
                let v = &mut self.velocity[i];
                v.axpby_in_place(self.momentum, 1.0, &grads[i]);
                block.value.add_scaled_in_place(-ctx.lr, v);
            } else {
                block.value.add_scaled_in_place(-ctx.lr, &grads[i]);
            }
        }
    }

    fn state_bytes(&self) -> usize {
        self.velocity
            .iter()
            .map(|m| m.numel() * std::mem::size_of::<f32>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{init_param_store, registry};

    fn tiny_store() -> ParamStore {
        init_param_store(&registry::get("micro").unwrap(), 0)
    }

    fn zero_grads(store: &ParamStore) -> Vec<Matrix> {
        store
            .blocks
            .iter()
            .map(|b| Matrix::zeros(b.value.rows, b.value.cols))
            .collect()
    }

    #[test]
    fn sgd_moves_against_gradient() {
        let mut store = tiny_store();
        let mut grads = zero_grads(&store);
        grads[1].fill(1.0); // attn_norm block
        let before = store.blocks[1].value.clone();
        let mut opt = Sgd::new(&store, 0.0);
        opt.step(&mut store, &grads, &StepCtx { lr: 0.1, step: 0 });
        let after = &store.blocks[1].value;
        for (b, a) in before.data.iter().zip(&after.data) {
            assert!((b - 0.1 - a).abs() < 1e-6);
        }
        assert_eq!(opt.state_bytes(), 0);
    }

    #[test]
    fn momentum_accumulates() {
        let mut store = tiny_store();
        let mut grads = zero_grads(&store);
        grads[1].fill(1.0);
        let mut opt = Sgd::new(&store, 0.9);
        let x0 = store.blocks[1].value.data[0];
        opt.step(&mut store, &grads, &StepCtx { lr: 0.1, step: 0 });
        let x1 = store.blocks[1].value.data[0];
        opt.step(&mut store, &grads, &StepCtx { lr: 0.1, step: 1 });
        let x2 = store.blocks[1].value.data[0];
        // Second step is larger: v2 = 0.9·1 + 1 = 1.9
        assert!(((x0 - x1) - 0.1).abs() < 1e-6);
        assert!(((x1 - x2) - 0.19).abs() < 1e-6);
        assert!(opt.state_bytes() > 0);
    }
}
