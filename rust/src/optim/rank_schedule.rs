//! Adaptive per-layer rank scheduling: a spectrum-driven controller
//! that re-decides each block's projection rank at every refresh
//! boundary.
//!
//! The paper's memory claim hinges on the rank r, yet the gradient
//! spectrum collapses as training progresses (AdaRankGrad; optimal
//! low-rank gradient estimation) — a static r either wastes memory
//! early or starves quality late. The controller here reads the
//! per-block singular spectrum that the rsvd refresh already computes
//! and picks the smallest rank capturing a target energy fraction,
//! with three stabilizers:
//!
//! 1. **Hysteresis** — a proposed change must (a) exceed a `deadband`
//!    around the current rank and (b) persist for `patience`
//!    consecutive refreshes in the same direction before it commits.
//!    A flat or noisy spectrum therefore never makes the rank
//!    oscillate.
//! 2. **Clamps** — committed ranks stay in
//!    `[min_rank, min(max_rank, side)]`.
//! 3. **Global budget** — if the per-block targets sum past `budget`
//!    total rank, the largest blocks give ranks back (deterministic
//!    largest-first, lowest-index tie-break) until the sum fits.
//!
//! Everything is a pure function of the observed spectra, so the
//! controller joins the repo's bit-identical-trajectory invariant for
//! free: replicas, thread widths, sync/async refresh pipelines, and
//! fault-injected replays all observe the same spectra in the same
//! order and commit the same ranks. The controller's bookkeeping
//! (`ranks` + hysteresis `pressure`) rides in checkpoints as a
//! [`RankState`] (the `GUMCKPT3` `RANKS` section) so resumes continue
//! the schedule rather than restarting it.

use anyhow::{ensure, Result};

use crate::linalg::Matrix;
use crate::model::{BlockKind, ParamStore};

/// Whether the per-block projection rank is static config or driven by
/// the spectrum controller.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum RankSchedule {
    /// Static ranks: exactly the pre-existing behavior, bit-for-bit.
    #[default]
    Fixed,
    /// Spectrum-driven controller re-decides ranks at every refresh.
    Adaptive(AdaptiveRankCfg),
}

impl RankSchedule {
    /// Parse a CLI/config spelling: `fixed` | `adaptive`.
    pub fn parse(s: &str) -> Result<RankSchedule> {
        match s.to_ascii_lowercase().as_str() {
            "fixed" | "static" => Ok(RankSchedule::Fixed),
            "adaptive" | "auto" => {
                Ok(RankSchedule::Adaptive(AdaptiveRankCfg::default()))
            }
            other => anyhow::bail!(
                "unknown rank schedule '{other}' (expected fixed|adaptive)"
            ),
        }
    }

    /// Stable label for logs/metrics.
    pub fn label(&self) -> &'static str {
        match self {
            RankSchedule::Fixed => "fixed",
            RankSchedule::Adaptive(_) => "adaptive",
        }
    }
}

/// Controller knobs. Zero-valued rank/budget fields are sentinels
/// resolved against the base rank at build time (see
/// [`AdaptiveRankCfg::resolved`]).
#[derive(Debug, Clone, PartialEq)]
pub struct AdaptiveRankCfg {
    /// Fraction of spectral energy (Σσ²) the kept ranks must capture.
    pub energy: f64,
    /// Proposed ranks within `deadband` of the current rank are treated
    /// as "no change" (and reset the pressure counter).
    pub deadband: usize,
    /// Consecutive same-direction proposals required before a rank
    /// change commits.
    pub patience: u32,
    /// Per-block floor (0 ≙ auto: `max(1, base_rank / 4)`).
    pub min_rank: usize,
    /// Per-block ceiling, also the probe width (0 ≙ auto:
    /// `2 · base_rank`).
    pub max_rank: usize,
    /// Total-rank budget across all projectable blocks (0 ≙ auto:
    /// `n_proj · base_rank` — matched memory with the fixed schedule).
    pub budget: usize,
}

impl Default for AdaptiveRankCfg {
    fn default() -> Self {
        AdaptiveRankCfg {
            energy: 0.90,
            deadband: 1,
            patience: 2,
            min_rank: 0,
            max_rank: 0,
            budget: 0,
        }
    }
}

impl AdaptiveRankCfg {
    /// Concretize the auto sentinels against the base rank and the
    /// number of projectable blocks.
    pub fn resolved(&self, base_rank: usize, n_proj: usize) -> AdaptiveRankCfg {
        let base = base_rank.max(1);
        let mut c = self.clone();
        if c.min_rank == 0 {
            c.min_rank = (base / 4).max(1);
        }
        if c.max_rank == 0 {
            c.max_rank = 2 * base;
        }
        c.max_rank = c.max_rank.max(c.min_rank);
        if c.budget == 0 {
            c.budget = n_proj.max(1) * base;
        }
        c.energy = c.energy.clamp(0.0, 1.0);
        c
    }
}

/// Serializable controller bookkeeping: per-block committed ranks plus
/// the signed hysteresis streak. This is the `GUMCKPT3` `RANKS` payload
/// — restoring it resumes the schedule exactly where the snapshot left
/// it.
#[derive(Debug, Clone, PartialEq)]
pub struct RankState {
    /// Committed rank per param block (0 ≙ dense / not projected).
    pub ranks: Vec<u32>,
    /// Signed consecutive-proposal streak per block (sign = direction).
    pub pressure: Vec<i32>,
}

impl RankState {
    /// Sum of committed ranks across projectable blocks.
    pub fn total(&self) -> usize {
        self.ranks.iter().map(|r| *r as usize).sum()
    }
}

/// The per-session rank controller. Aligned with `params.blocks`:
/// dense blocks carry rank 0 and are never touched.
#[derive(Debug, Clone, PartialEq)]
pub struct RankController {
    cfg: AdaptiveRankCfg,
    /// Short side of each block (0 for dense) — the hard rank ceiling.
    sides: Vec<usize>,
    ranks: Vec<usize>,
    pressure: Vec<i32>,
}

impl RankController {
    /// Build a controller for `params`, starting every projectable
    /// block at the (clamped) base rank. `cfg` may still carry auto
    /// sentinels; they are resolved here.
    pub fn new(
        cfg: &AdaptiveRankCfg,
        params: &ParamStore,
        base_rank: usize,
    ) -> RankController {
        let n_proj = params
            .blocks
            .iter()
            .filter(|b| b.kind == BlockKind::Projectable)
            .count();
        let cfg = cfg.resolved(base_rank, n_proj);
        let mut sides = Vec::with_capacity(params.blocks.len());
        let mut ranks = Vec::with_capacity(params.blocks.len());
        for b in &params.blocks {
            if b.kind == BlockKind::Projectable {
                let side = b.value.rows.min(b.value.cols);
                sides.push(side);
                ranks.push(
                    base_rank
                        .max(1)
                        .clamp(cfg.min_rank, cfg.max_rank.min(side).max(1)),
                );
            } else {
                sides.push(0);
                ranks.push(0);
            }
        }
        let pressure = vec![0; ranks.len()];
        RankController {
            cfg,
            sides,
            ranks,
            pressure,
        }
    }

    /// The resolved controller knobs.
    pub fn cfg(&self) -> &AdaptiveRankCfg {
        &self.cfg
    }

    /// Committed rank of block `i` (0 for dense blocks).
    pub fn rank_of(&self, i: usize) -> usize {
        self.ranks[i]
    }

    /// Committed ranks, aligned with `params.blocks`.
    pub fn ranks(&self) -> &[usize] {
        &self.ranks
    }

    /// Sum of committed ranks across projectable blocks.
    pub fn total_rank(&self) -> usize {
        self.ranks.iter().sum()
    }

    /// The width the refresh probes block `i` at — the rank ceiling, so
    /// the controller always sees enough spectrum to grow back up.
    pub fn probe_rank(&self, i: usize) -> usize {
        self.cfg.max_rank.min(self.sides[i]).max(1)
    }

    /// Smallest t ≥ 1 with Σ_{j<t} σⱼ² ≥ energy · Σ σ². A zero (or
    /// empty) spectrum proposes the floor.
    fn energy_target(&self, spectrum: &[f32]) -> usize {
        let total: f64 = spectrum.iter().map(|s| (*s as f64).powi(2)).sum();
        if total <= 0.0 {
            return self.cfg.min_rank;
        }
        let want = self.cfg.energy * total;
        let mut acc = 0.0f64;
        for (t, s) in spectrum.iter().enumerate() {
            acc += (*s as f64).powi(2);
            if acc >= want {
                return t + 1;
            }
        }
        spectrum.len()
    }

    /// Feed one refresh's per-block spectra (aligned with
    /// `params.blocks`; `None` ≙ dense / not refreshed) and commit the
    /// next ranks. Pure and deterministic: same spectra in, same ranks
    /// out, regardless of threads, replicas, or pipeline mode.
    pub fn observe(&mut self, spectra: &[Option<&[f32]>]) {
        for (i, spec) in spectra.iter().enumerate() {
            let Some(spec) = spec else { continue };
            if self.sides[i] == 0 {
                continue;
            }
            let hi = self.cfg.max_rank.min(self.sides[i]).max(1);
            let lo = self.cfg.min_rank.min(hi);
            let target = self.energy_target(spec).clamp(lo, hi);
            let cur = self.ranks[i];
            let delta = target as i64 - cur as i64;
            if delta.unsigned_abs() as usize <= self.cfg.deadband {
                // Within the deadband: no change, streak resets.
                self.pressure[i] = 0;
                continue;
            }
            let dir: i32 = if delta > 0 { 1 } else { -1 };
            // Direction flip restarts the streak.
            if self.pressure[i] * dir <= 0 {
                self.pressure[i] = dir;
            } else {
                self.pressure[i] += dir;
            }
            if self.pressure[i].unsigned_abs() >= self.cfg.patience.max(1) {
                self.ranks[i] = target;
                self.pressure[i] = 0;
            }
        }
        self.enforce_budget();
    }

    /// Shrink the largest blocks (lowest index wins ties) until the
    /// total rank fits the budget. Floors at rank 1 per block.
    fn enforce_budget(&mut self) {
        loop {
            let total: usize = self.ranks.iter().sum();
            if total <= self.cfg.budget {
                return;
            }
            let Some(i) = (0..self.ranks.len())
                .filter(|&i| self.sides[i] > 0 && self.ranks[i] > 1)
                .max_by(|&a, &b| {
                    self.ranks[a].cmp(&self.ranks[b]).then(b.cmp(&a))
                })
            else {
                return; // every projectable block already at 1
            };
            self.ranks[i] -= 1;
        }
    }

    /// Snapshot the controller bookkeeping for checkpoints.
    pub fn state(&self) -> RankState {
        RankState {
            ranks: self.ranks.iter().map(|r| *r as u32).collect(),
            pressure: self.pressure.clone(),
        }
    }

    /// Reinstate checkpointed bookkeeping. The block layout must match
    /// the store this controller was built for.
    pub fn restore(&mut self, state: &RankState) -> Result<()> {
        ensure!(
            state.ranks.len() == self.ranks.len()
                && state.pressure.len() == self.pressure.len(),
            "rank state holds {} blocks, controller has {}",
            state.ranks.len(),
            self.ranks.len()
        );
        for (i, (&r, &side)) in
            state.ranks.iter().zip(&self.sides).enumerate()
        {
            let r = r as usize;
            ensure!(
                (side == 0) == (r == 0),
                "rank state block {i}: rank {r} vs side {side} \
                 (dense/projectable mismatch)"
            );
            ensure!(
                r <= side,
                "rank state block {i}: rank {r} exceeds side {side}"
            );
        }
        self.ranks = state.ranks.iter().map(|r| *r as usize).collect();
        self.pressure = state.pressure.clone();
        Ok(())
    }
}

/// Resize a persistent moment buffer to a new projected shape after a
/// rank change: the overlapping prefix is copied, new rows/columns are
/// zero — deterministic and a no-op when shapes already match.
pub fn resize_moment(m: &Matrix, rows: usize, cols: usize) -> Matrix {
    if m.shape() == (rows, cols) {
        return m.clone();
    }
    let mut out = Matrix::zeros(rows, cols);
    let rr = m.rows.min(rows);
    let cc = m.cols.min(cols);
    for i in 0..rr {
        out.row_mut(i)[..cc].copy_from_slice(&m.row(i)[..cc]);
    }
    out
}

/// [`resize_moment`] over a dtype-carrying [`MomentBuf`]: the f32
/// variant delegates, the 16-bit variants overlap-copy the packed bits
/// directly (no unpack/re-pack round trip, so surviving entries keep
/// their exact stored values) and zero-pad the growth — 0 bits is
/// exactly 0.0 in both 16-bit formats.
pub fn resize_moment_buf(
    m: &crate::linalg::lowp::MomentBuf,
    rows: usize,
    cols: usize,
) -> crate::linalg::lowp::MomentBuf {
    use crate::linalg::lowp::MomentBuf;
    match m {
        MomentBuf::F32(m) => MomentBuf::F32(resize_moment(m, rows, cols)),
        MomentBuf::Lowp {
            dtype,
            rows: orows,
            cols: ocols,
            bits,
        } => {
            let (orows, ocols) = (*orows, *ocols);
            if (orows, ocols) == (rows, cols) {
                return m.clone();
            }
            let mut out = vec![0u16; rows * cols];
            let rr = orows.min(rows);
            let cc = ocols.min(cols);
            for i in 0..rr {
                out[i * cols..i * cols + cc]
                    .copy_from_slice(&bits[i * ocols..i * ocols + cc]);
            }
            MomentBuf::Lowp {
                dtype: *dtype,
                rows,
                cols,
                bits: out,
            }
        }
    }
}

/// Projected optimizer-state footprint in bytes for a rank assignment:
/// per projectable block, the `side × r` projector plus `moments`
/// moment buffers at the `r × long` projected shape, in f32. Dense
/// blocks are excluded — their state is rank-independent.
pub fn projected_state_bytes(
    params: &ParamStore,
    ranks: &[usize],
    moments: usize,
) -> usize {
    let mut floats = 0usize;
    for (b, &r) in params.blocks.iter().zip(ranks) {
        if b.kind != BlockKind::Projectable || r == 0 {
            continue;
        }
        let (m, n) = b.value.shape();
        let side = m.min(n);
        let long = m.max(n);
        let r = r.min(side);
        floats += side * r + moments * r * long;
    }
    floats * std::mem::size_of::<f32>()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ParamBlock;
    use crate::rng::Pcg;

    fn store() -> ParamStore {
        let mut rng = Pcg::new(11);
        ParamStore {
            blocks: vec![
                ParamBlock {
                    name: "w0".into(),
                    shape: vec![16, 24],
                    kind: BlockKind::Projectable,
                    value: Matrix::randn(16, 24, 0.1, &mut rng),
                },
                ParamBlock {
                    name: "norm".into(),
                    shape: vec![8],
                    kind: BlockKind::Dense,
                    value: Matrix::from_vec(1, 8, vec![1.0; 8]),
                },
                ParamBlock {
                    name: "w1".into(),
                    shape: vec![24, 16],
                    kind: BlockKind::Projectable,
                    value: Matrix::randn(24, 16, 0.1, &mut rng),
                },
            ],
        }
    }

    fn cfg(energy: f64, budget: usize) -> AdaptiveRankCfg {
        AdaptiveRankCfg {
            energy,
            deadband: 0,
            patience: 1,
            min_rank: 1,
            max_rank: 12,
            budget,
            ..AdaptiveRankCfg::default()
        }
    }

    #[test]
    fn parse_and_labels() {
        assert_eq!(RankSchedule::parse("fixed").unwrap(), RankSchedule::Fixed);
        assert!(matches!(
            RankSchedule::parse("Adaptive").unwrap(),
            RankSchedule::Adaptive(_)
        ));
        assert!(RankSchedule::parse("wavy").is_err());
        assert_eq!(RankSchedule::Fixed.label(), "fixed");
        assert_eq!(RankSchedule::default(), RankSchedule::Fixed);
    }

    #[test]
    fn auto_sentinels_resolve_against_base_rank() {
        let c = AdaptiveRankCfg::default().resolved(8, 3);
        assert_eq!(c.min_rank, 2);
        assert_eq!(c.max_rank, 16);
        assert_eq!(c.budget, 24);
        // Explicit knobs pass through.
        let c2 = cfg(0.5, 10).resolved(8, 3);
        assert_eq!((c2.min_rank, c2.max_rank, c2.budget), (1, 12, 10));
    }

    #[test]
    fn energy_target_tracks_spectrum_concentration() {
        let ctl = RankController::new(&cfg(0.90, 100), &store(), 8);
        // One dominant value → rank 1.
        assert_eq!(ctl.energy_target(&[10.0, 0.1, 0.1, 0.1]), 1);
        // Flat spectrum → needs 90% of the entries.
        assert_eq!(ctl.energy_target(&[1.0; 10]), 9);
        // Zero spectrum → floor.
        assert_eq!(ctl.energy_target(&[0.0; 4]), ctl.cfg.min_rank);
    }

    #[test]
    fn dense_blocks_stay_rank_zero() {
        let mut ctl = RankController::new(&cfg(0.9, 100), &store(), 8);
        assert_eq!(ctl.ranks(), &[8, 0, 8]);
        let flat = [1.0f32; 12];
        ctl.observe(&[Some(&flat), None, Some(&flat)]);
        assert_eq!(ctl.rank_of(1), 0);
        assert_eq!(ctl.probe_rank(0), 12);
    }

    #[test]
    fn deadband_and_patience_gate_changes() {
        let mut c = cfg(0.9, 100);
        c.deadband = 1;
        c.patience = 2;
        let mut ctl = RankController::new(&c, &store(), 8);
        // Target 9 vs current 8: inside the deadband → never moves.
        let near = [1.0f32; 10];
        for _ in 0..5 {
            ctl.observe(&[Some(&near), None, Some(&near)]);
        }
        assert_eq!(ctl.ranks(), &[8, 0, 8]);
        assert_eq!(ctl.pressure, vec![0, 0, 0]);
        // Target 1 (dominant σ): outside the deadband, but needs two
        // consecutive proposals before committing.
        let spike = [10.0f32, 0.01, 0.01];
        ctl.observe(&[Some(&spike), None, Some(&near)]);
        assert_eq!(ctl.rank_of(0), 8, "patience must delay the commit");
        ctl.observe(&[Some(&spike), None, Some(&near)]);
        assert_eq!(ctl.rank_of(0), 1, "second proposal commits");
        assert_eq!(ctl.rank_of(2), 8);
    }

    #[test]
    fn direction_flip_resets_the_streak() {
        let mut c = cfg(0.9, 100);
        c.deadband = 0;
        c.patience = 2;
        let mut ctl = RankController::new(&c, &store(), 6);
        let shrink = [10.0f32, 0.01, 0.01]; // target 1
        let grow = [1.0f32; 12]; // target 11
        ctl.observe(&[Some(&shrink), None, None]);
        ctl.observe(&[Some(&grow), None, None]);
        ctl.observe(&[Some(&shrink), None, None]);
        // Alternating directions never accumulate two in a row.
        assert_eq!(ctl.rank_of(0), 6, "oscillating targets must not commit");
    }

    #[test]
    fn budget_redistributes_from_the_largest_block() {
        let mut c = cfg(0.9, 14);
        c.deadband = 0;
        c.patience = 1;
        let mut ctl = RankController::new(&c, &store(), 8);
        let flat = [1.0f32; 12]; // target 11 for both blocks
        ctl.observe(&[Some(&flat), None, Some(&flat)]);
        assert!(ctl.total_rank() <= 14, "budget exceeded: {:?}", ctl.ranks());
        // Largest-first trimming keeps the assignment balanced.
        assert_eq!(ctl.ranks(), &[7, 0, 7]);
    }

    #[test]
    fn state_round_trips_and_rejects_layout_mismatch() {
        let mut ctl = RankController::new(&cfg(0.9, 100), &store(), 8);
        let spike = [10.0f32, 0.01];
        ctl.observe(&[Some(&spike), None, None]);
        let state = ctl.state();
        let mut fresh = RankController::new(&cfg(0.9, 100), &store(), 8);
        fresh.restore(&state).unwrap();
        assert_eq!(fresh, ctl);
        // Wrong block count is rejected.
        let bad = RankState {
            ranks: vec![4, 4],
            pressure: vec![0, 0],
        };
        assert!(fresh.restore(&bad).is_err());
        // Dense block must stay rank 0.
        let bad2 = RankState {
            ranks: vec![4, 3, 4],
            pressure: vec![0, 0, 0],
        };
        assert!(fresh.restore(&bad2).is_err());
    }

    #[test]
    fn resize_moment_copies_overlap_and_zero_pads() {
        let m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let shrunk = resize_moment(&m, 1, 2);
        assert_eq!(shrunk.data, vec![1.0, 2.0]);
        let grown = resize_moment(&m, 3, 4);
        assert_eq!(grown.row(0), &[1.0, 2.0, 3.0, 0.0]);
        assert_eq!(grown.row(1), &[4.0, 5.0, 6.0, 0.0]);
        assert_eq!(grown.row(2), &[0.0; 4]);
        // Same shape is an identity.
        assert_eq!(resize_moment(&m, 2, 3), m);
    }

    #[test]
    fn projected_bytes_count_projector_plus_moments() {
        let s = store();
        // w0: side 16, long 24; w1: same. rank 4, one moment each:
        // (16·4 + 4·24) · 2 blocks · 4 bytes.
        let got = projected_state_bytes(&s, &[4, 0, 4], 1);
        assert_eq!(got, (16 * 4 + 4 * 24) * 2 * 4);
        // Dense rank entries are ignored.
        assert_eq!(projected_state_bytes(&s, &[0, 0, 0], 1), 0);
    }
}
