//! **GUM — GaLore Unbiased with Muon** (the paper's Algorithm 2).
//!
//! Each sampling period (K steps, driven by the coordinator):
//!   1. momentum restart `R ← 0` for every projectable block,
//!   2. fresh-gradient SVD → projector `P = U[:, :r]`,
//!   3. each block sampled **full-rank** with probability `q = γ/N_L`.
//!
//! Per step, low-rank blocks (probability 1−q) run
//! `R ← βR + PᵀG/(1−q)`, `W ← W − η·P·NS(R)` — eq. (1) — while sampled
//! blocks run the **compensated full-rank update**
//! `R ← βR + (G − PPᵀG)/q`, `W ← W − η·NS(R)` — eq. (2).
//!
//! In expectation the effective gradient equals G (Lemma 1), so GUM
//! inherits Muon's convergence (Theorem 1) at GaLore-like memory cost:
//! `(2−q)·m·r + q·m²` floats per m×m block vs GaLore's `2·m·r`.
//!
//! `Compensation::Scaled` implements the Appendix C.1 variant
//! (full-rank: `(G − (1−q)PPᵀG)/q`, low-rank unscaled), which recovers
//! exact full-parameter Muon at `q = 1`.

use anyhow::Context;

use crate::linalg::lowp::{self, MomentBuf, StateDtype};
use crate::linalg::{newton_schulz_into, Matrix, NS_STEPS};
use crate::model::{BlockKind, ParamStore};
use crate::rng::{derive_seed, Pcg};

use super::dense::DenseAdamW;
use super::projection::{ProjKind, Projector, RankProbe, RefreshStrategy};
use super::rank_schedule::{RankController, RankState};
use super::{
    snap_moment, OptSnapshot, Optimizer, PreparedRefresh, RefreshJob,
    SnapValue, StepCtx, StepScratch,
};

/// Debias-compensation variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Compensation {
    /// Algorithm 2 exactly: full-rank `(G−PPᵀG)/q`, low-rank `PᵀG/(1−q)`.
    Paper,
    /// Appendix C.1: full-rank `(G−(1−q)PPᵀG)/q`, low-rank `PᵀG`
    /// (unscaled). Recovers full Muon at q = 1.
    Scaled,
}

struct BlockState {
    proj: Option<Projector>,
    /// Sampled to run the compensated full-rank update this period.
    full_rank: bool,
    /// Momentum: (r×n) low-rank or (m×n) full-rank, per period, stored
    /// at the configured state dtype.
    momentum: Option<MomentBuf>,
}

/// GUM optimizer state.
pub struct Gum {
    pub rank: usize,
    /// Full-rank sampling probability q = γ/N_L.
    pub q: f64,
    pub beta: f32,
    pub compensation: Compensation,
    /// Muon-style update RMS scaling (LLM practice); off for the
    /// paper-faithful synthetic benches.
    pub rms_scale: bool,
    /// Projector-refresh engine. The rsvd sketch draws come from a
    /// stream derived per (seed, period, block) — never from the
    /// Bernoulli sampler — so the full-rank mask sequence is identical
    /// across strategies.
    pub refresh: RefreshStrategy,
    /// Adaptive rank controller (`--rank-schedule adaptive`): each
    /// refresh probes at the rank ceiling, feeds the observed spectra
    /// to the controller, and truncates the probe basis to the
    /// committed rank. `None` ≙ the fixed schedule, bit-for-bit.
    pub rank_ctl: Option<RankController>,
    /// Storage dtype for the momentum (and dense AdamW) buffers;
    /// projectors stay f32. Configured at build via `set_state_dtype`.
    state_dtype: StateDtype,
    states: Vec<Option<BlockState>>,
    dense: Vec<Option<DenseAdamW>>,
    sampler: Pcg,
    seed: u64,
    period: usize,
    /// Per-step matrix temps, reused across blocks and steps (the
    /// momentum-project-orthogonalize chain runs allocation-free once
    /// these are warm). Never snapshotted.
    scratch: StepScratch,
}

impl Gum {
    pub fn new(
        params: &ParamStore,
        rank: usize,
        q: f64,
        beta: f32,
        compensation: Compensation,
        seed: u64,
    ) -> Gum {
        let mut states = Vec::new();
        let mut dense = Vec::new();
        for b in &params.blocks {
            match b.kind {
                BlockKind::Projectable => {
                    states.push(Some(BlockState {
                        proj: None,
                        full_rank: false,
                        momentum: None,
                    }));
                    dense.push(None);
                }
                BlockKind::Dense => {
                    states.push(None);
                    dense.push(Some(DenseAdamW::new(
                        b.value.shape(),
                        0.9,
                        0.999,
                        1e-8,
                        0.0,
                    )));
                }
            }
        }
        Gum {
            rank,
            q,
            beta,
            compensation,
            rms_scale: true,
            refresh: RefreshStrategy::default(),
            rank_ctl: None,
            state_dtype: StateDtype::F32,
            states,
            dense,
            sampler: Pcg::new(seed),
            seed,
            period: 0,
            scratch: StepScratch::new(),
        }
    }

    /// The effective (debiased) gradient estimate for one block under the
    /// current sampling outcome — the quantity Lemma 1 proves unbiased.
    /// Exposed for the property tests and the bias instrumentation.
    pub fn effective_gradient(
        proj: &Projector,
        g: &Matrix,
        full_rank: bool,
        q: f64,
        comp: Compensation,
    ) -> Matrix {
        match (full_rank, comp) {
            (true, Compensation::Paper) => {
                proj.residual_scaled(g, (1.0 / q) as f32)
            }
            (true, Compensation::Scaled) => {
                // (G − (1−q)·PPᵀG)/q
                let mut rec = proj.reconstruct(g);
                let a = (1.0 / q) as f32;
                let b = (-(1.0 - q) / q) as f32;
                rec.axpby_in_place(b, a, g);
                rec
            }
            (false, Compensation::Paper) => {
                proj.reconstruct(g).scaled((1.0 / (1.0 - q)) as f32)
            }
            (false, Compensation::Scaled) => proj.reconstruct(g),
        }
    }

    fn update_scale(&self, rows: usize, cols: usize) -> f32 {
        if self.rms_scale {
            0.2 * (rows.max(cols) as f32).sqrt()
        } else {
            1.0
        }
    }

    /// Which projectable blocks are full-rank this period (for tests and
    /// the memory instrumentation).
    pub fn full_rank_mask(&self) -> Vec<bool> {
        self.states
            .iter()
            .flatten()
            .map(|s| s.full_rank)
            .collect()
    }

    /// The adaptive-schedule refresh for the (already incremented)
    /// current period: probe every projectable block at the rank
    /// ceiling, let the controller commit the next ranks from the
    /// observed spectra, then truncate each probe basis to its
    /// committed rank. Same per-(seed, period, block) sketch streams as
    /// the fixed path, so the Bernoulli mask sequence is untouched.
    fn refresh_adaptive(&mut self, grads: &[Matrix]) {
        let ctl_ref = self.rank_ctl.as_ref().expect("adaptive refresh");
        let mut probes: Vec<Option<RankProbe>> =
            Vec::with_capacity(self.states.len());
        for (i, state) in self.states.iter_mut().enumerate() {
            let Some(state) = state else {
                probes.push(None);
                continue;
            };
            let prev = state.proj.take();
            let mut sketch_rng = Pcg::new(derive_seed(
                self.seed,
                &format!("rsvd/p{}/b{i}", self.period),
            ));
            probes.push(Some(Projector::probe_with(
                &grads[i],
                ctl_ref.probe_rank(i),
                self.refresh,
                prev.as_ref(),
                &mut sketch_rng,
            )));
        }
        let ctl = self.rank_ctl.as_mut().expect("adaptive refresh");
        let spectra: Vec<Option<&[f32]>> = probes
            .iter()
            .map(|p| p.as_ref().map(|p| p.spectrum()))
            .collect();
        ctl.observe(&spectra);
        drop(spectra);
        for (i, (state, probe)) in
            self.states.iter_mut().zip(probes).enumerate()
        {
            let (Some(state), Some(probe)) = (state.as_mut(), probe) else {
                continue;
            };
            state.proj = Some(probe.into_projector(ctl.rank_of(i)));
        }
    }
}

impl Optimizer for Gum {
    fn name(&self) -> String {
        format!("gum(r={},q={:.3})", self.rank, self.q)
    }

    fn begin_period(
        &mut self,
        _params: &ParamStore,
        grads: &[Matrix],
        _rng: &mut Pcg,
    ) {
        // Algorithm 2 lines 3–9. The sampler is owned (seeded at build)
        // so period sampling is independent of the caller's RNG usage;
        // the refresh sketch gets its own per-(period, block) derived
        // stream so the mask sequence is also independent of the
        // refresh strategy (and, under the adaptive schedule, of the
        // committed ranks).
        self.period += 1;
        if self.rank_ctl.is_some() {
            self.refresh_adaptive(grads);
        } else {
            for (i, state) in self.states.iter_mut().enumerate() {
                let Some(state) = state else { continue };
                let prev = state.proj.take();
                let mut sketch_rng = Pcg::new(derive_seed(
                    self.seed,
                    &format!("rsvd/p{}/b{i}", self.period),
                ));
                state.proj = Some(Projector::build_with(
                    &grads[i],
                    self.rank,
                    ProjKind::SvdTopR,
                    self.refresh,
                    prev.as_ref(),
                    &mut sketch_rng,
                ));
            }
        }
        for state in self.states.iter_mut().flatten() {
            state.full_rank = self.sampler.bernoulli(self.q);
            state.momentum = None; // restart (line 4)
        }
    }

    /// The prepare half of the refresh pipeline: clone everything the
    /// *next* period's projector rebuild needs — gradient snapshot, the
    /// current projectors as warm bases, and the per-(period, block)
    /// derived sketch seeds — into an owned job. The job computes
    /// exactly what [`Gum::begin_period`] would at the next boundary
    /// (the sketch streams never touch the Bernoulli sampler, so the
    /// full-rank mask sequence is untouched by who runs it, or when).
    fn plan_refresh(
        &self,
        grads: &[Matrix],
        _rng: &mut Pcg,
    ) -> Option<RefreshJob> {
        let next_period = self.period + 1;
        let rank = self.rank;
        let refresh = self.refresh;
        // Under the adaptive schedule the job carries its own clone of
        // the controller: it probes, observes, and commits the next
        // ranks off the critical path, and the resulting bookkeeping
        // rides back in the PreparedRefresh for the boundary handoff.
        let rank_ctl = self.rank_ctl.clone();
        let blocks: Vec<_> = self
            .states
            .iter()
            .enumerate()
            .map(|(i, state)| {
                state.as_ref().map(|s| {
                    (
                        grads[i].clone(),
                        s.proj.clone(),
                        derive_seed(
                            self.seed,
                            &format!("rsvd/p{next_period}/b{i}"),
                        ),
                    )
                })
            })
            .collect();
        Some(Box::new(move || match rank_ctl {
            None => PreparedRefresh {
                projectors: blocks
                    .into_iter()
                    .map(|slot| {
                        slot.map(|(g, warm, seed)| {
                            let mut sketch_rng = Pcg::new(seed);
                            Projector::build_with(
                                &g,
                                rank,
                                ProjKind::SvdTopR,
                                refresh,
                                warm.as_ref(),
                                &mut sketch_rng,
                            )
                        })
                    })
                    .collect(),
                rank_state: None,
                period_state: None,
            },
            Some(mut ctl) => {
                let probes: Vec<Option<RankProbe>> = blocks
                    .into_iter()
                    .enumerate()
                    .map(|(i, slot)| {
                        slot.map(|(g, warm, seed)| {
                            let mut sketch_rng = Pcg::new(seed);
                            Projector::probe_with(
                                &g,
                                ctl.probe_rank(i),
                                refresh,
                                warm.as_ref(),
                                &mut sketch_rng,
                            )
                        })
                    })
                    .collect();
                let spectra: Vec<Option<&[f32]>> = probes
                    .iter()
                    .map(|p| p.as_ref().map(|p| p.spectrum()))
                    .collect();
                ctl.observe(&spectra);
                drop(spectra);
                PreparedRefresh {
                    projectors: probes
                        .into_iter()
                        .enumerate()
                        .map(|(i, p)| {
                            p.map(|p| p.into_projector(ctl.rank_of(i)))
                        })
                        .collect(),
                    rank_state: Some(ctl.state()),
                    period_state: None,
                }
            }
        }))
    }

    /// The handoff half: swap in the precomputed bases, then run the
    /// rest of the period transition exactly as [`Gum::begin_period`]
    /// does — sampler draw, momentum restart. A missing slot (defensive;
    /// the pipeline always plans every projectable block) falls back to
    /// the synchronous rebuild with the same derived sketch stream.
    fn begin_period_prepared(
        &mut self,
        _params: &ParamStore,
        grads: &[Matrix],
        _rng: &mut Pcg,
        prepared: PreparedRefresh,
    ) {
        self.period += 1;
        if self.rank_ctl.is_some() {
            match &prepared.rank_state {
                Some(rs) => {
                    // The job already observed this refresh's spectra;
                    // adopt its committed ranks + hysteresis streaks.
                    if let Err(e) =
                        self.rank_ctl.as_mut().unwrap().restore(rs)
                    {
                        crate::warn!(
                            "gum: prepared rank state rejected ({e}); \
                             keeping controller state"
                        );
                    }
                }
                None => {
                    // Defensive: an adaptive session handed a
                    // rank-blind refresh (unreachable through the
                    // pipeline — plan_refresh always clones the
                    // controller). Re-probe synchronously with the same
                    // derived streams so the trajectory stays on spec.
                    crate::warn!(
                        "gum: prepared refresh missing rank state; \
                         re-probing synchronously"
                    );
                    self.refresh_adaptive(grads);
                    for state in self.states.iter_mut().flatten() {
                        state.full_rank = self.sampler.bernoulli(self.q);
                        state.momentum = None; // restart (line 4)
                    }
                    return;
                }
            }
        }
        let mut slots = prepared.projectors;
        slots.resize_with(self.states.len(), || None);
        let ctl = self.rank_ctl.as_ref();
        for (i, (state, slot)) in
            self.states.iter_mut().zip(slots).enumerate()
        {
            let Some(state) = state else { continue };
            let prev = state.proj.take();
            state.proj = Some(match slot {
                Some(p) => p,
                None => {
                    // Rebuilding from the *boundary* gradient diverges
                    // from the trigger-time spec trace — loud, because
                    // a well-formed pipeline plans every projectable
                    // block and this should be unreachable.
                    crate::warn!(
                        "gum: prepared refresh missing block {i}; \
                         rebuilding synchronously (trajectory may \
                         diverge from the sync spec)"
                    );
                    let mut sketch_rng = Pcg::new(derive_seed(
                        self.seed,
                        &format!("rsvd/p{}/b{i}", self.period),
                    ));
                    match ctl {
                        Some(ctl) => Projector::probe_with(
                            &grads[i],
                            ctl.probe_rank(i),
                            self.refresh,
                            prev.as_ref(),
                            &mut sketch_rng,
                        )
                        .into_projector(ctl.rank_of(i)),
                        None => Projector::build_with(
                            &grads[i],
                            self.rank,
                            ProjKind::SvdTopR,
                            self.refresh,
                            prev.as_ref(),
                            &mut sketch_rng,
                        ),
                    }
                }
            });
            state.full_rank = self.sampler.bernoulli(self.q);
            state.momentum = None; // restart (line 4)
        }
    }

    fn step(&mut self, params: &mut ParamStore, grads: &[Matrix], ctx: &StepCtx) {
        assert_eq!(params.blocks.len(), grads.len());
        for (i, block) in params.blocks.iter_mut().enumerate() {
            match block.kind {
                BlockKind::Dense => {
                    self.dense[i].as_mut().unwrap().step(
                        &mut block.value,
                        &grads[i],
                        ctx.lr,
                    );
                }
                BlockKind::Projectable => {
                    let scale =
                        self.update_scale(block.value.rows, block.value.cols);
                    let (q, beta, comp_kind) =
                        (self.q, self.beta, self.compensation);
                    let dtype = self.state_dtype;
                    let state = self.states[i].as_mut().unwrap();
                    let scr = &mut self.scratch;
                    let proj = state
                        .proj
                        .as_ref()
                        .expect("begin_period must run before step");
                    if state.full_rank {
                        // eq. (2): R ← βR + comp(G); W ← W − η NS(R).
                        // comp(G) = a·G + b·PPᵀG for both variants
                        // (Paper: a = 1/q, b = −1/q; Appendix C.1:
                        // a = 1/q, b = −(1−q)/q), so the reconstruction
                        // feeds the momentum through one fused
                        // decay-accumulate pass — the compensated
                        // gradient is never materialized.
                        proj.reconstruct_into(
                            &grads[i],
                            &mut scr.low,
                            &mut scr.full,
                        );
                        let a = (1.0 / q) as f32;
                        let b = match comp_kind {
                            Compensation::Paper => (-1.0 / q) as f32,
                            Compensation::Scaled => (-(1.0 - q) / q) as f32,
                        };
                        let (mr, mc) = scr.full.shape();
                        let mom = state
                            .momentum
                            .get_or_insert_with(|| MomentBuf::zeros(dtype, mr, mc));
                        match mom {
                            MomentBuf::F32(mom) => {
                                crate::linalg::elementwise::decay_accumulate2(
                                    &mut mom.data,
                                    beta,
                                    a,
                                    &grads[i].data,
                                    b,
                                    &scr.full.data,
                                );
                                newton_schulz_into(
                                    mom,
                                    NS_STEPS,
                                    &mut scr.ns,
                                    &mut scr.dir,
                                );
                            }
                            MomentBuf::Lowp { dtype, rows, cols, bits } => {
                                // The unrounded f32 accumulator is what
                                // Newton–Schulz sees; only the RTNE
                                // 16-bit image persists across steps.
                                scr.mom.resize(*rows, *cols);
                                lowp::decay_accumulate2(
                                    *dtype,
                                    bits,
                                    beta,
                                    a,
                                    &grads[i].data,
                                    b,
                                    &scr.full.data,
                                    &mut scr.mom.data,
                                );
                                newton_schulz_into(
                                    &scr.mom,
                                    NS_STEPS,
                                    &mut scr.ns,
                                    &mut scr.dir,
                                );
                            }
                        }
                        block.value.add_scaled_in_place(-ctx.lr * scale, &scr.dir);
                    } else {
                        // eq. (1): R ← βR + PᵀG/(1−q); W ← W − η P NS(R).
                        // The 1/(1−q) debias scale folds into the fused
                        // momentum accumulate (no separate scale pass).
                        proj.project_into(&grads[i], &mut scr.low);
                        let s = match comp_kind {
                            Compensation::Paper => (1.0 / (1.0 - q)) as f32,
                            Compensation::Scaled => 1.0,
                        };
                        let (mr, mc) = scr.low.shape();
                        let mom = state
                            .momentum
                            .get_or_insert_with(|| MomentBuf::zeros(dtype, mr, mc));
                        match mom {
                            MomentBuf::F32(mom) => {
                                mom.axpby_in_place(beta, s, &scr.low);
                                newton_schulz_into(
                                    mom,
                                    NS_STEPS,
                                    &mut scr.ns,
                                    &mut scr.dir,
                                );
                            }
                            MomentBuf::Lowp { dtype, rows, cols, bits } => {
                                scr.mom.resize(*rows, *cols);
                                lowp::axpby(
                                    *dtype,
                                    beta,
                                    bits,
                                    s,
                                    &scr.low.data,
                                    &mut scr.mom.data,
                                );
                                newton_schulz_into(
                                    &scr.mom,
                                    NS_STEPS,
                                    &mut scr.ns,
                                    &mut scr.dir,
                                );
                            }
                        }
                        proj.project_back_into(&scr.dir, &mut scr.full);
                        block.value.add_scaled_in_place(-ctx.lr * scale, &scr.full);
                    }
                }
            }
        }
    }

    fn state_bytes(&self) -> usize {
        let mut total = 0;
        for s in self.states.iter().flatten() {
            total += s.proj.as_ref().map_or(0, |p| p.state_bytes());
            total += s.momentum.as_ref().map_or(0, |m| m.state_bytes());
        }
        total += self
            .dense
            .iter()
            .flatten()
            .map(|d| d.state_bytes())
            .sum::<usize>();
        total
    }

    /// Everything a mid-period resume needs: the period counter, the
    /// private sampler stream, and per block the projector, full-rank
    /// flag, momentum, and dense-AdamW moments.
    fn snapshot(&self) -> Option<OptSnapshot> {
        let mut snap = OptSnapshot::default();
        snap.push("period", SnapValue::U64(self.period as u64));
        // The construction seed feeds the per-period rsvd sketch streams
        // (and, under WarmStart, the basis padding), so a restored twin
        // must inherit it to refresh identically.
        snap.push("seed", SnapValue::U64(self.seed));
        let (state, inc, spare) = self.sampler.to_raw();
        snap.push("sampler/state", SnapValue::U64(state));
        snap.push("sampler/inc", SnapValue::U64(inc));
        if let Some(sp) = spare {
            snap.push("sampler/spare", SnapValue::F64(sp));
        }
        for (i, block) in self.states.iter().enumerate() {
            if let Some(block) = block {
                snap.push(format!("b{i}/full"), SnapValue::Bool(block.full_rank));
                if let Some(p) = &block.proj {
                    snap.push(format!("b{i}/proj/p"), SnapValue::Mat(p.p.clone()));
                    snap.push(format!("b{i}/proj/left"), SnapValue::Bool(p.left));
                    snap.push(
                        format!("b{i}/proj/rank"),
                        SnapValue::U64(p.rank as u64),
                    );
                }
                if let Some(m) = &block.momentum {
                    snap.push(format!("b{i}/mom"), snap_moment(m));
                }
            }
            if let Some(d) = &self.dense[i] {
                let (m, v, t) = d.snapshot();
                snap.push(format!("b{i}/adam/m"), snap_moment(&m));
                snap.push(format!("b{i}/adam/v"), snap_moment(&v));
                snap.push(format!("b{i}/adam/t"), SnapValue::U64(t as u64));
            }
        }
        Some(snap)
    }

    fn restore_snapshot(&mut self, snap: &OptSnapshot) -> anyhow::Result<()> {
        self.period = snap.as_u64("period").context("gum snapshot: period")? as usize;
        // Older snapshots predate the seed entry; keep the constructed
        // seed then (their refreshes drew from the sampler stream, which
        // is restored below).
        if let Some(seed) = snap.as_u64("seed") {
            self.seed = seed;
        }
        let state = snap
            .as_u64("sampler/state")
            .context("gum snapshot: sampler/state")?;
        let inc = snap
            .as_u64("sampler/inc")
            .context("gum snapshot: sampler/inc")?;
        self.sampler = Pcg::from_raw(state, inc, snap.as_f64("sampler/spare"));
        let want = self.state_dtype;
        for (i, block) in self.states.iter_mut().enumerate() {
            if let Some(block) = block {
                block.full_rank = snap
                    .as_bool(&format!("b{i}/full"))
                    .with_context(|| format!("gum snapshot: b{i} full flag"))?;
                block.proj = match snap.as_mat(&format!("b{i}/proj/p")) {
                    Some(p) => Some(Projector {
                        p: p.clone(),
                        left: snap
                            .as_bool(&format!("b{i}/proj/left"))
                            .with_context(|| format!("gum snapshot: b{i} left"))?,
                        rank: snap
                            .as_u64(&format!("b{i}/proj/rank"))
                            .with_context(|| format!("gum snapshot: b{i} rank"))?
                            as usize,
                    }),
                    None => None,
                };
                block.momentum = match snap.as_moment(&format!("b{i}/mom")) {
                    Some(m) => {
                        anyhow::ensure!(
                            m.dtype() == want,
                            "gum snapshot: b{i} momentum stored as {}, but \
                             this session is configured for {} (rerun with \
                             the matching --state-dtype)",
                            m.dtype(),
                            want,
                        );
                        Some(m)
                    }
                    None => None,
                };
            }
            if let Some(d) = self.dense[i].as_mut() {
                if let (Some(m), Some(v), Some(t)) = (
                    snap.as_moment(&format!("b{i}/adam/m")),
                    snap.as_moment(&format!("b{i}/adam/v")),
                    snap.as_u64(&format!("b{i}/adam/t")),
                ) {
                    d.restore(m, v, t as usize)
                        .with_context(|| format!("gum snapshot: b{i} adam"))?;
                }
            }
        }
        Ok(())
    }

    fn projectors(&self) -> Option<Vec<Option<Projector>>> {
        Some(
            self.states
                .iter()
                .map(|s| s.as_ref().and_then(|s| s.proj.clone()))
                .collect(),
        )
    }

    fn rank_state(&self) -> Option<RankState> {
        self.rank_ctl.as_ref().map(|c| c.state())
    }

    fn restore_rank_state(&mut self, state: &RankState) -> anyhow::Result<()> {
        match self.rank_ctl.as_mut() {
            Some(c) => c.restore(state),
            None => anyhow::bail!(
                "gum was built with a fixed rank schedule; the checkpoint \
                 carries adaptive rank state"
            ),
        }
    }

    fn set_state_dtype(&mut self, dtype: StateDtype) -> anyhow::Result<()> {
        self.state_dtype = dtype;
        for s in self.states.iter_mut().flatten() {
            s.momentum = s.momentum.as_ref().map(|m| {
                let (r, c) = m.shape();
                MomentBuf::zeros(dtype, r, c)
            });
        }
        for d in self.dense.iter_mut().flatten() {
            d.set_dtype(dtype);
        }
        Ok(())
    }

    fn as_any(&self) -> Option<&dyn std::any::Any> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{fro_norm, newton_schulz};
    use crate::model::{init_param_store, registry};
    use crate::testing;

    fn setup(seed: u64) -> (ParamStore, Vec<Matrix>) {
        let store = init_param_store(&registry::get("micro").unwrap(), 0);
        let mut rng = Pcg::new(seed);
        let grads: Vec<Matrix> = store
            .blocks
            .iter()
            .map(|b| Matrix::randn(b.value.rows, b.value.cols, 1.0, &mut rng))
            .collect();
        (store, grads)
    }

    /// Lemma 1/2: E[effective gradient] = G, for both variants.
    #[test]
    fn effective_gradient_is_unbiased() {
        testing::check(8, |gen| {
            let m = gen.dim(4, 24);
            let n = gen.dim(4, 24);
            let r = gen.dim(1, m.min(n) - 1);
            let q = gen.prob();
            let g = gen.matrix(m, n);
            let proj =
                Projector::build(&g, r, ProjKind::SvdTopR, &mut gen.rng);
            for comp in [Compensation::Paper, Compensation::Scaled] {
                // E = q · full + (1−q) · low_backprojected
                let full =
                    Gum::effective_gradient(&proj, &g, true, q, comp);
                let low_est = match comp {
                    // low branch's contribution to the *full-space*
                    // effective gradient is PPᵀG scaled per variant.
                    Compensation::Paper => proj
                        .reconstruct(&g)
                        .scaled((1.0 / (1.0 - q)) as f32),
                    Compensation::Scaled => proj.reconstruct(&g),
                };
                let mut e = full.scaled(q as f32);
                e.add_scaled_in_place((1.0 - q) as f32, &low_est);
                assert!(
                    e.max_abs_diff(&g) < 1e-3 * (1.0 + fro_norm(&g)),
                    "comp {comp:?} q {q}"
                );
            }
        });
    }

    /// Property II (Lemma 1): the low-rank branch P·NS(PᵀG) equals
    /// NS(PPᵀG) — projection and Newton–Schulz commute.
    #[test]
    fn low_rank_update_equals_projected_full_update() {
        testing::check(8, |gen| {
            let m = gen.dim(4, 20);
            let n = gen.dim(m, 30); // m ≤ n
            let r = gen.dim(1, m - 1);
            let g = gen.matrix(m, n);
            let proj =
                Projector::build(&g, r, ProjKind::SvdTopR, &mut gen.rng);
            let low = proj.project(&g);
            let left = proj.project_back(&newton_schulz(&low, NS_STEPS));
            let right = newton_schulz(&proj.reconstruct(&g), NS_STEPS);
            assert!(
                left.max_abs_diff(&right) < 5e-3,
                "err {}",
                left.max_abs_diff(&right)
            );
        });
    }

    #[test]
    fn sampling_rate_matches_q() {
        let (store, grads) = setup(0);
        let mut gum =
            Gum::new(&store, 2, 0.3, 0.95, Compensation::Paper, 42);
        let mut rng = Pcg::new(0);
        let mut full = 0usize;
        let mut total = 0usize;
        for _ in 0..400 {
            gum.begin_period(&store, &grads, &mut rng);
            let mask = gum.full_rank_mask();
            full += mask.iter().filter(|&&b| b).count();
            total += mask.len();
        }
        let rate = full as f64 / total as f64;
        assert!((rate - 0.3).abs() < 0.03, "rate {rate}");
    }

    #[test]
    fn full_rank_update_is_high_rank() {
        let (mut store, grads) = setup(1);
        // q → 1: every block full-rank (extreme enough that no draw can
        // cross it).
        let mut gum =
            Gum::new(&store, 2, 1.0 - 1e-9, 0.95, Compensation::Paper, 7);
        gum.rms_scale = false;
        let mut rng = Pcg::new(1);
        gum.begin_period(&store, &grads, &mut rng);
        assert!(gum.full_rank_mask().iter().all(|&b| b));
        let idx = store.projectable_indices()[0];
        let before = store.blocks[idx].value.clone();
        gum.step(&mut store, &grads, &StepCtx { lr: 0.1, step: 0 });
        let delta = before.sub(&store.blocks[idx].value);
        let s = crate::linalg::singular_values(&delta);
        // Residual (I−PPᵀ)G has rank ≈ min(m,n) − 2 ≫ 2.
        assert!(s[10] > 1e-3 * s[0], "high-rank update: {:?}", &s[..12]);
    }

    #[test]
    fn low_rank_update_is_rank_r() {
        let (mut store, grads) = setup(2);
        let mut gum =
            Gum::new(&store, 3, 1e-9, 0.95, Compensation::Paper, 7);
        gum.rms_scale = false;
        let mut rng = Pcg::new(2);
        gum.begin_period(&store, &grads, &mut rng);
        assert!(gum.full_rank_mask().iter().all(|&b| !b));
        let idx = store.projectable_indices()[0];
        let before = store.blocks[idx].value.clone();
        gum.step(&mut store, &grads, &StepCtx { lr: 0.1, step: 0 });
        let delta = before.sub(&store.blocks[idx].value);
        let s = crate::linalg::singular_values(&delta);
        assert!(s[3] < 1e-4 * s[0], "rank ≤ 3: {:?}", &s[..5]);
    }

    #[test]
    fn scaled_variant_with_q1_is_plain_muon() {
        let (store, grads) = setup(3);
        let idx = store.projectable_indices()[0];

        let mut gum =
            Gum::new(&store, 2, 1.0 - 1e-9, 0.95, Compensation::Scaled, 7);
        gum.rms_scale = false;
        let mut rng = Pcg::new(3);
        let mut s1 = store.clone();
        gum.begin_period(&s1, &grads, &mut rng);
        gum.step(&mut s1, &grads, &StepCtx { lr: 0.1, step: 0 });

        let mut muon = super::super::Muon::new(&store, 0.95);
        muon.rms_scale = false;
        let mut s2 = store.clone();
        muon.step(&mut s2, &grads, &StepCtx { lr: 0.1, step: 0 });

        let d = s1.blocks[idx].value.max_abs_diff(&s2.blocks[idx].value);
        assert!(d < 1e-3, "gum(q=1,scaled) vs muon: {d}");
    }

    /// Mid-period snapshot/restore: a restored twin must take bit-equal
    /// steps *and* sample the next period identically (sampler stream).
    #[test]
    fn snapshot_restore_resumes_identically() {
        let (mut store, grads) = setup(5);
        let mut gum =
            Gum::new(&store, 2, 0.4, 0.95, Compensation::Paper, 11);
        let mut rng = Pcg::new(9);
        gum.begin_period(&store, &grads, &mut rng);
        gum.step(&mut store, &grads, &StepCtx { lr: 0.05, step: 0 });
        gum.step(&mut store, &grads, &StepCtx { lr: 0.05, step: 1 });

        let snap = gum.snapshot().expect("gum snapshots");
        // Different construction seed: restore must fully overwrite it.
        let mut twin =
            Gum::new(&store, 2, 0.4, 0.95, Compensation::Paper, 0);
        twin.restore_snapshot(&snap).unwrap();

        let mut s1 = store.clone();
        let mut s2 = store.clone();
        gum.step(&mut s1, &grads, &StepCtx { lr: 0.05, step: 2 });
        twin.step(&mut s2, &grads, &StepCtx { lr: 0.05, step: 2 });
        for (a, b) in s1.blocks.iter().zip(&s2.blocks) {
            assert_eq!(a.value, b.value, "{}", a.name);
        }

        // Next period must sample the same mask (GUM ignores the caller
        // RNG; its restored private sampler drives the draws).
        gum.begin_period(&s1, &grads, &mut rng);
        let mut other_rng = Pcg::new(1234);
        twin.begin_period(&s2, &grads, &mut other_rng);
        assert_eq!(gum.full_rank_mask(), twin.full_rank_mask());
    }

    /// bf16 moments round-trip through snapshot/restore bit-exactly, a
    /// restored twin resumes on the identical trajectory, and a session
    /// configured for f32 rejects the bf16 snapshot with a diagnostic.
    #[test]
    fn bf16_snapshot_round_trips_and_mismatch_is_rejected() {
        let (mut store, grads) = setup(6);
        let mut gum =
            Gum::new(&store, 2, 0.4, 0.95, Compensation::Paper, 11);
        gum.set_state_dtype(StateDtype::Bf16).unwrap();
        let mut rng = Pcg::new(9);
        gum.begin_period(&store, &grads, &mut rng);
        gum.step(&mut store, &grads, &StepCtx { lr: 0.05, step: 0 });
        gum.step(&mut store, &grads, &StepCtx { lr: 0.05, step: 1 });

        let snap = gum.snapshot().expect("gum snapshots");
        let mut twin =
            Gum::new(&store, 2, 0.4, 0.95, Compensation::Paper, 0);
        twin.set_state_dtype(StateDtype::Bf16).unwrap();
        twin.restore_snapshot(&snap).unwrap();

        let mut s1 = store.clone();
        let mut s2 = store.clone();
        gum.step(&mut s1, &grads, &StepCtx { lr: 0.05, step: 2 });
        twin.step(&mut s2, &grads, &StepCtx { lr: 0.05, step: 2 });
        for (a, b) in s1.blocks.iter().zip(&s2.blocks) {
            assert_eq!(a.value, b.value, "{}", a.name);
        }

        // Same run at f32 must hold more state than the bf16 twin.
        let mut f32_gum =
            Gum::new(&store, 2, 0.4, 0.95, Compensation::Paper, 11);
        let mut rng2 = Pcg::new(9);
        f32_gum.begin_period(&store, &grads, &mut rng2);
        let mut s3 = store.clone();
        f32_gum.step(&mut s3, &grads, &StepCtx { lr: 0.05, step: 0 });
        assert!(gum.state_bytes() < f32_gum.state_bytes());

        // Dtype mismatch on restore is an error naming both dtypes.
        let mut wrong =
            Gum::new(&store, 2, 0.4, 0.95, Compensation::Paper, 0);
        let err = wrong.restore_snapshot(&snap).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("bf16") && msg.contains("f32"), "{msg}");
    }

    #[test]
    fn state_bytes_between_galore_and_full() {
        let (store, grads) = setup(4);
        let mut rng = Pcg::new(4);
        let mut gum =
            Gum::new(&store, 2, 0.5, 0.95, Compensation::Paper, 7);
        gum.begin_period(&store, &grads, &mut rng);
        let mut s = store.clone();
        gum.step(&mut s, &grads, &StepCtx { lr: 0.01, step: 0 });
        let bytes = gum.state_bytes();
        assert!(bytes > 0);
        // Full-rank momentum only on sampled blocks: less than full Muon
        // + dense states would be.
        let mut muon = super::super::Muon::new(&store, 0.95);
        let mut s2 = store.clone();
        muon.step(&mut s2, &grads, &StepCtx { lr: 0.01, step: 0 });
        assert!(bytes < muon.state_bytes() + 1_000_000);
    }
}
