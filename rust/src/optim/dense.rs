//! Dense AdamW core over a single matrix — shared by every optimizer for
//! the non-projectable blocks (embeddings, norms, LM head), matching the
//! practice in GaLore/Muon implementations of keeping AdamW on those.
//! The whole step — both moment updates, bias correction, decoupled
//! decay, weight write — is one fused pass: `elementwise::adam_apply`
//! at f32 state, `lowp::adam_apply` when the moments are stored at a
//! 16-bit [`StateDtype`] (f32 accumulation in-register either way).

use crate::linalg::lowp::{self, MomentBuf, StateDtype};
use crate::linalg::{elementwise, Matrix};

/// AdamW state + hyperparameters for one block.
#[derive(Debug, Clone)]
pub struct DenseAdamW {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    m: MomentBuf,
    v: MomentBuf,
    t: usize,
}

impl DenseAdamW {
    pub fn new(
        shape: (usize, usize),
        beta1: f32,
        beta2: f32,
        eps: f32,
        weight_decay: f32,
    ) -> DenseAdamW {
        DenseAdamW {
            beta1,
            beta2,
            eps,
            weight_decay,
            m: MomentBuf::zeros(StateDtype::F32, shape.0, shape.1),
            v: MomentBuf::zeros(StateDtype::F32, shape.0, shape.1),
            t: 0,
        }
    }

    /// Switch the storage dtype of the (still-zero) moments. Build-time
    /// only: the moments are reallocated, so this panics once a step
    /// has run.
    pub fn set_dtype(&mut self, dtype: StateDtype) {
        assert_eq!(
            self.t, 0,
            "state dtype must be configured before the first step"
        );
        let (rows, cols) = self.m.shape();
        self.m = MomentBuf::zeros(dtype, rows, cols);
        self.v = MomentBuf::zeros(dtype, rows, cols);
    }

    /// Storage dtype of the moment buffers.
    pub fn dtype(&self) -> StateDtype {
        self.m.dtype()
    }

    /// One AdamW step (decoupled weight decay), in place on `w`.
    pub fn step(&mut self, w: &mut Matrix, g: &Matrix, lr: f32) {
        debug_assert_eq!(w.shape(), g.shape());
        self.t += 1;
        let b1 = self.beta1;
        let b2 = self.beta2;
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        match (&mut self.m, &mut self.v) {
            (MomentBuf::F32(m), MomentBuf::F32(v)) => elementwise::adam_apply(
                &mut w.data,
                &g.data,
                &mut m.data,
                &mut v.data,
                b1,
                b2,
                bc1,
                bc2,
                self.eps,
                lr,
                self.weight_decay,
            ),
            (
                MomentBuf::Lowp { dtype, bits: mb, .. },
                MomentBuf::Lowp { bits: vb, .. },
            ) => lowp::adam_apply(
                *dtype,
                &mut w.data,
                &g.data,
                mb,
                vb,
                b1,
                b2,
                bc1,
                bc2,
                self.eps,
                lr,
                self.weight_decay,
            ),
            _ => unreachable!("m and v always share a dtype"),
        }
    }

    /// Snapshot `(m, v, t)` for mid-run checkpointing.
    pub fn snapshot(&self) -> (MomentBuf, MomentBuf, usize) {
        (self.m.clone(), self.v.clone(), self.t)
    }

    /// Restore moments captured by [`DenseAdamW::snapshot`]. Fails on a
    /// shape or storage-dtype mismatch (a checkpoint written at one
    /// `--state-dtype` cannot resume a session configured at another).
    pub fn restore(
        &mut self,
        m: MomentBuf,
        v: MomentBuf,
        t: usize,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            m.shape() == self.m.shape() && v.shape() == self.v.shape(),
            "adam moment shape mismatch: snapshot {:?}/{:?} vs built {:?}",
            m.shape(),
            v.shape(),
            self.m.shape()
        );
        anyhow::ensure!(
            m.dtype() == self.m.dtype() && v.dtype() == self.v.dtype(),
            "adam moment dtype mismatch: checkpoint stores {}, session is \
             configured for {} (rerun with the matching --state-dtype)",
            m.dtype(),
            self.m.dtype()
        );
        self.m = m;
        self.v = v;
        self.t = t;
        Ok(())
    }

    /// Reset moments (used on period restarts).
    pub fn reset(&mut self) {
        for buf in [&mut self.m, &mut self.v] {
            match buf {
                MomentBuf::F32(m) => m.fill(0.0),
                MomentBuf::Lowp { bits, .. } => bits.fill(0),
            }
        }
        self.t = 0;
    }

    pub fn state_bytes(&self) -> usize {
        self.m.state_bytes() + self.v.state_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg;

    /// AdamW on a quadratic must reach the optimum.
    #[test]
    fn converges_on_quadratic() {
        let mut rng = Pcg::new(0);
        let target = Matrix::randn(4, 6, 1.0, &mut rng);
        let mut w = Matrix::zeros(4, 6);
        let mut opt = DenseAdamW::new((4, 6), 0.9, 0.999, 1e-8, 0.0);
        for _ in 0..400 {
            let g = w.sub(&target); // ∇ of ½‖w−t‖²
            opt.step(&mut w, &g, 0.05);
        }
        assert!(w.max_abs_diff(&target) < 0.05);
    }

    #[test]
    fn first_step_is_signed_gradient() {
        // With bias correction, step 1 moves by ≈ lr·sign(g).
        let mut w = Matrix::zeros(1, 3);
        let g = Matrix::from_vec(1, 3, vec![0.5, -2.0, 0.0]);
        let mut opt = DenseAdamW::new((1, 3), 0.9, 0.999, 1e-8, 0.0);
        opt.step(&mut w, &g, 0.1);
        assert!((w.data[0] + 0.1).abs() < 1e-4);
        assert!((w.data[1] - 0.1).abs() < 1e-4);
        assert_eq!(w.data[2], 0.0);
    }

    #[test]
    fn weight_decay_shrinks_weights() {
        let mut w = Matrix::from_vec(1, 1, vec![1.0]);
        let g = Matrix::zeros(1, 1);
        let mut opt = DenseAdamW::new((1, 1), 0.9, 0.999, 1e-8, 0.1);
        opt.step(&mut w, &g, 0.5);
        assert!(w.data[0] < 1.0 && w.data[0] > 0.9);
    }

    #[test]
    fn snapshot_restore_resumes_identically() {
        let g = Matrix::from_vec(2, 2, vec![0.3, -1.0, 2.0, 0.5]);
        let mut w1 = Matrix::zeros(2, 2);
        let mut opt1 = DenseAdamW::new((2, 2), 0.9, 0.999, 1e-8, 0.01);
        opt1.step(&mut w1, &g, 0.1);
        opt1.step(&mut w1, &g, 0.1);

        let (m, v, t) = opt1.snapshot();
        let mut opt2 = DenseAdamW::new((2, 2), 0.9, 0.999, 1e-8, 0.01);
        opt2.restore(m, v, t).unwrap();
        let mut w2 = w1.clone();

        opt1.step(&mut w1, &g, 0.1);
        opt2.step(&mut w2, &g, 0.1);
        assert_eq!(w1, w2, "restored AdamW must step identically");
    }

    #[test]
    fn restore_rejects_dtype_mismatch() {
        let mut opt_bf16 = DenseAdamW::new((2, 2), 0.9, 0.999, 1e-8, 0.0);
        opt_bf16.set_dtype(StateDtype::Bf16);
        let (m, v, t) = opt_bf16.snapshot();
        let mut opt_f32 = DenseAdamW::new((2, 2), 0.9, 0.999, 1e-8, 0.0);
        let err = opt_f32.restore(m, v, t).unwrap_err();
        assert!(err.to_string().contains("dtype"), "{err}");
    }

    #[test]
    fn reset_clears_state() {
        let mut w = Matrix::zeros(2, 2);
        let g = Matrix::from_vec(2, 2, vec![1.0; 4]);
        let mut opt = DenseAdamW::new((2, 2), 0.9, 0.999, 1e-8, 0.0);
        opt.step(&mut w, &g, 0.1);
        opt.reset();
        assert_eq!(opt.t, 0);
        assert!(opt.m.as_f32().unwrap().data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn bf16_state_halves_bytes_and_tracks_f32() {
        let mut rng = Pcg::new(7);
        let target = Matrix::randn(4, 6, 1.0, &mut rng);
        let mut w32 = Matrix::zeros(4, 6);
        let mut w16 = Matrix::zeros(4, 6);
        let mut o32 = DenseAdamW::new((4, 6), 0.9, 0.999, 1e-8, 0.0);
        let mut o16 = DenseAdamW::new((4, 6), 0.9, 0.999, 1e-8, 0.0);
        o16.set_dtype(StateDtype::Bf16);
        assert_eq!(o16.state_bytes() * 2, o32.state_bytes());
        for _ in 0..100 {
            let g32 = w32.sub(&target);
            o32.step(&mut w32, &g32, 0.05);
            let g16 = w16.sub(&target);
            o16.step(&mut w16, &g16, 0.05);
        }
        // Same trajectory up to bf16 rounding of the stored moments.
        assert!(w32.max_abs_diff(&w16) < 0.05);
    }
}
