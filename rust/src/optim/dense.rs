//! Dense AdamW core over a single matrix — shared by every optimizer for
//! the non-projectable blocks (embeddings, norms, LM head), matching the
//! practice in GaLore/Muon implementations of keeping AdamW on those.
//! The whole step — both moment updates, bias correction, decoupled
//! decay, weight write — is one fused pass (`elementwise::adam_apply`).

use crate::linalg::{elementwise, Matrix};

/// AdamW state + hyperparameters for one block.
#[derive(Debug, Clone)]
pub struct DenseAdamW {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    m: Matrix,
    v: Matrix,
    t: usize,
}

impl DenseAdamW {
    pub fn new(
        shape: (usize, usize),
        beta1: f32,
        beta2: f32,
        eps: f32,
        weight_decay: f32,
    ) -> DenseAdamW {
        DenseAdamW {
            beta1,
            beta2,
            eps,
            weight_decay,
            m: Matrix::zeros(shape.0, shape.1),
            v: Matrix::zeros(shape.0, shape.1),
            t: 0,
        }
    }

    /// One AdamW step (decoupled weight decay), in place on `w`.
    pub fn step(&mut self, w: &mut Matrix, g: &Matrix, lr: f32) {
        debug_assert_eq!(w.shape(), g.shape());
        self.t += 1;
        let b1 = self.beta1;
        let b2 = self.beta2;
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        elementwise::adam_apply(
            &mut w.data,
            &g.data,
            &mut self.m.data,
            &mut self.v.data,
            b1,
            b2,
            bc1,
            bc2,
            self.eps,
            lr,
            self.weight_decay,
        );
    }

    /// Snapshot `(m, v, t)` for mid-run checkpointing.
    pub fn snapshot(&self) -> (Matrix, Matrix, usize) {
        (self.m.clone(), self.v.clone(), self.t)
    }

    /// Restore moments captured by [`DenseAdamW::snapshot`].
    pub fn restore(&mut self, m: Matrix, v: Matrix, t: usize) {
        assert_eq!(m.shape(), self.m.shape(), "adam m shape");
        assert_eq!(v.shape(), self.v.shape(), "adam v shape");
        self.m = m;
        self.v = v;
        self.t = t;
    }

    /// Reset moments (used on period restarts).
    pub fn reset(&mut self) {
        self.m.fill(0.0);
        self.v.fill(0.0);
        self.t = 0;
    }

    pub fn state_bytes(&self) -> usize {
        (self.m.numel() + self.v.numel()) * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg;

    /// AdamW on a quadratic must reach the optimum.
    #[test]
    fn converges_on_quadratic() {
        let mut rng = Pcg::new(0);
        let target = Matrix::randn(4, 6, 1.0, &mut rng);
        let mut w = Matrix::zeros(4, 6);
        let mut opt = DenseAdamW::new((4, 6), 0.9, 0.999, 1e-8, 0.0);
        for _ in 0..400 {
            let g = w.sub(&target); // ∇ of ½‖w−t‖²
            opt.step(&mut w, &g, 0.05);
        }
        assert!(w.max_abs_diff(&target) < 0.05);
    }

    #[test]
    fn first_step_is_signed_gradient() {
        // With bias correction, step 1 moves by ≈ lr·sign(g).
        let mut w = Matrix::zeros(1, 3);
        let g = Matrix::from_vec(1, 3, vec![0.5, -2.0, 0.0]);
        let mut opt = DenseAdamW::new((1, 3), 0.9, 0.999, 1e-8, 0.0);
        opt.step(&mut w, &g, 0.1);
        assert!((w.data[0] + 0.1).abs() < 1e-4);
        assert!((w.data[1] - 0.1).abs() < 1e-4);
        assert_eq!(w.data[2], 0.0);
    }

    #[test]
    fn weight_decay_shrinks_weights() {
        let mut w = Matrix::from_vec(1, 1, vec![1.0]);
        let g = Matrix::zeros(1, 1);
        let mut opt = DenseAdamW::new((1, 1), 0.9, 0.999, 1e-8, 0.1);
        opt.step(&mut w, &g, 0.5);
        assert!(w.data[0] < 1.0 && w.data[0] > 0.9);
    }

    #[test]
    fn snapshot_restore_resumes_identically() {
        let g = Matrix::from_vec(2, 2, vec![0.3, -1.0, 2.0, 0.5]);
        let mut w1 = Matrix::zeros(2, 2);
        let mut opt1 = DenseAdamW::new((2, 2), 0.9, 0.999, 1e-8, 0.01);
        opt1.step(&mut w1, &g, 0.1);
        opt1.step(&mut w1, &g, 0.1);

        let (m, v, t) = opt1.snapshot();
        let mut opt2 = DenseAdamW::new((2, 2), 0.9, 0.999, 1e-8, 0.01);
        opt2.restore(m, v, t);
        let mut w2 = w1.clone();

        opt1.step(&mut w1, &g, 0.1);
        opt2.step(&mut w2, &g, 0.1);
        assert_eq!(w1, w2, "restored AdamW must step identically");
    }

    #[test]
    fn reset_clears_state() {
        let mut w = Matrix::zeros(2, 2);
        let g = Matrix::from_vec(2, 2, vec![1.0; 4]);
        let mut opt = DenseAdamW::new((2, 2), 0.9, 0.999, 1e-8, 0.0);
        opt.step(&mut w, &g, 0.1);
        opt.reset();
        assert_eq!(opt.t, 0);
        assert!(opt.m.data.iter().all(|&v| v == 0.0));
    }
}
