//! Memory accounting: the closed forms behind Table 1 and the peak-GPU
//! estimates behind Table 3.
//!
//! Table 1 (per m×m block, floats):
//!   GaLore:  2·m·r                  (P: m×r, projected moment r×m)
//!   GUM:     (2−q)·m·r′ + q·m²      (expected; full-rank momentum on
//!                                    sampled blocks)
//!   SFT:     m²                     (full-rank moment, Muon)
//! Memory-equal line: q = 2(r − r′)/(m − r′).
//!
//! Table 3: peak GPU bytes for the paper's 7–9B models under bf16
//! weights/grads + f32 optimizer state, plus a per-model activation
//! budget (batch 1, no flash-attention / offload, as in the paper's
//! setup).

use crate::model::PaperModel;

/// Expected optimizer-state floats for one m×n block under each method.
///
/// The `*_split` variants separate projector floats (always f32, even
/// under `--state-dtype bf16|f16`) from moment floats (stored at the
/// configured dtype) so [`super::memory::block_state_bytes`] can price
/// them independently; the plain forms are their sums.
pub mod per_block {
    /// GaLore(-Muon) split: (projector floats s×r, moment floats r×l)
    /// where s = min(m,n), l = max(m,n).
    pub fn galore_split(m: usize, n: usize, r: usize) -> (f64, f64) {
        let s = m.min(n) as f64;
        let l = m.max(n) as f64;
        let r = (r as f64).min(s);
        (s * r, r * l)
    }

    /// GaLore(-Muon) with projector rank r: P (s×r) + moment (r×l).
    pub fn galore(m: usize, n: usize, r: usize) -> f64 {
        let (p, mo) = galore_split(m, n, r);
        p + mo
    }

    /// GUM split (expected value): projector s×r′ always; moment r′×l
    /// w.p. (1−q) + moment m×n w.p. q.
    pub fn gum_split(m: usize, n: usize, r: usize, q: f64) -> (f64, f64) {
        let s = m.min(n) as f64;
        let l = m.max(n) as f64;
        let r = (r as f64).min(s);
        (s * r, (1.0 - q) * r * l + q * (m as f64) * (n as f64))
    }

    /// GUM with rank r′ and full-rank probability q (expected value):
    /// P (s×r′) always + moment r′×l w.p. (1−q) + moment m×n w.p. q.
    pub fn gum(m: usize, n: usize, r: usize, q: f64) -> f64 {
        let (p, mo) = gum_split(m, n, r, q);
        p + mo
    }

    /// Full-parameter Muon: one m×n momentum.
    pub fn sft_muon(m: usize, n: usize) -> f64 {
        (m * n) as f64
    }

    /// Full-parameter Adam(W): two m×n moments.
    pub fn adamw(m: usize, n: usize) -> f64 {
        2.0 * (m * n) as f64
    }

    /// Fira: GaLore-Adam states (P + 2 projected moments) + scale scalar.
    pub fn fira(m: usize, n: usize, r: usize) -> f64 {
        let s = m.min(n) as f64;
        let l = m.max(n) as f64;
        let r = (r as f64).min(s);
        s * r + 2.0 * r * l + 1.0
    }
}

/// Price a block's split state count under a moment-storage dtype:
/// projector floats stay 4 bytes, moment floats cost
/// [`StateDtype::bytes`]. This is the closed form the runtime
/// `Optimizer::state_bytes` accounting must reproduce (see the
/// reconciliation test below).
pub fn block_state_bytes(
    split: (f64, f64),
    dtype: crate::linalg::lowp::StateDtype,
) -> f64 {
    let (proj, moments) = split;
    proj * STATE_BYTES + moments * dtype.bytes() as f64
}

/// The q making GUM's expected memory equal GaLore's for an m×m block
/// (paper Table 1 caption): q = 2(r − r′)/(m − r′).
pub fn memory_equal_q(m: usize, r: usize, r_prime: usize) -> f64 {
    2.0 * (r as f64 - r_prime as f64) / (m as f64 - r_prime as f64)
}

/// Bytes per element for the mixed-precision regime the paper measures
/// (bf16 weights/grads, f32 states).
pub const WEIGHT_BYTES: f64 = 2.0;
pub const GRAD_BYTES: f64 = 2.0;
pub const STATE_BYTES: f64 = 4.0;

/// Method descriptor for the Table 3 estimator.
#[derive(Debug, Clone, Copy)]
pub enum Method {
    GaLore { rank: usize },
    Gum { rank: usize, gamma: usize },
    Muon,
    AdamW,
    Fira { rank: usize },
}

/// One row of a memory report.
#[derive(Debug, Clone)]
pub struct MemoryReport {
    pub model: String,
    pub method: String,
    pub weights_gb: f64,
    pub grads_gb: f64,
    pub states_gb: f64,
    pub activations_gb: f64,
    pub total_gb: f64,
}

const GB: f64 = 1024.0 * 1024.0 * 1024.0;

/// Estimate peak training memory for a paper-scale model (Table 3).
///
/// Activation budget: batch 1, seq 1024, no flash-attention — dominated
/// by per-layer attention scores (heads·seq²) and MLP activations kept
/// for backward; a fixed framework overhead (CUDA context etc.) of 1.5
/// GB matches the paper's measurement setup.
pub fn estimate(model: &PaperModel, method: Method) -> MemoryReport {
    let n_params = model.n_params() as f64;
    let weights = n_params * WEIGHT_BYTES;
    let grads = n_params * GRAD_BYTES;

    let blocks = model.matrix_blocks();
    let n_blocks = blocks.len();
    let dense_params: f64 =
        n_params - blocks.iter().map(|(_, m, n)| (m * n) as f64).sum::<f64>();

    let (label, state_floats) = match method {
        Method::GaLore { rank } => (
            format!("galore(r={rank})"),
            blocks
                .iter()
                .map(|(_, m, n)| per_block::galore(*m, *n, rank))
                .sum::<f64>()
                + 2.0 * dense_params,
        ),
        Method::Gum { rank, gamma } => {
            let q = gamma as f64 / n_blocks as f64;
            (
                format!("gum({gamma}+{rank})"),
                blocks
                    .iter()
                    .map(|(_, m, n)| per_block::gum(*m, *n, rank, q))
                    .sum::<f64>()
                    + 2.0 * dense_params,
            )
        }
        Method::Muon => (
            "muon".into(),
            blocks
                .iter()
                .map(|(_, m, n)| per_block::sft_muon(*m, *n))
                .sum::<f64>()
                + 2.0 * dense_params,
        ),
        Method::AdamW => ("adamw".into(), 2.0 * n_params),
        Method::Fira { rank } => (
            format!("fira(r={rank})"),
            blocks
                .iter()
                .map(|(_, m, n)| per_block::fira(*m, *n, rank))
                .sum::<f64>()
                + 2.0 * dense_params,
        ),
    };
    let states = state_floats * STATE_BYTES;

    // Activation estimate (batch 1, seq 1024, gradient checkpointing as
    // in the HF-Trainer setups the paper uses): per layer only the block
    // inputs + a few residual saves survive to backward; logits/softmax
    // buffers dominate the rest.
    let seq = 1024.0;
    let per_layer = 4.0 * seq * model.dim as f64;
    let logits = seq * model.vocab as f64;
    let activations = (model.n_layers as f64 * per_layer + 3.0 * logits) * 4.0;
    let overhead = 1.5 * GB;

    let total = weights + grads + states + activations + overhead;
    MemoryReport {
        model: model.name.to_string(),
        method: label,
        weights_gb: weights / GB,
        grads_gb: grads / GB,
        states_gb: states / GB,
        activations_gb: activations / GB,
        total_gb: total / GB,
    }
}

/// Pretty-print bytes.
pub fn bytes_human(bytes: usize) -> String {
    let b = bytes as f64;
    if b >= GB {
        format!("{:.2} GiB", b / GB)
    } else if b >= 1024.0 * 1024.0 {
        format!("{:.2} MiB", b / (1024.0 * 1024.0))
    } else if b >= 1024.0 {
        format!("{:.1} KiB", b / 1024.0)
    } else {
        format!("{bytes} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::paper_shape_table;

    #[test]
    fn table1_formulas_square_block() {
        // m×m block, r=12 GaLore vs GUM r′=2 q=0.5 at m=20 (Fig. 1's
        // setting): equal memory per the paper.
        let m = 20;
        let galore = per_block::galore(m, m, 12);
        assert_eq!(galore, 2.0 * 20.0 * 12.0);
        let q = memory_equal_q(m, 12, 2);
        let gum = per_block::gum(m, m, 2, q);
        assert!(
            (gum - galore).abs() / galore < 0.05,
            "gum {gum} vs galore {galore} at q={q}"
        );
    }

    #[test]
    fn memory_equal_q_for_fig1_setting() {
        // n=20, r=12, r′=2 → q = 2·10/18 ≈ 1.11 > 1: at *any* q ≤ 1 GUM
        // uses no more memory than GaLore(r=12); the paper's Fig. 1 runs
        // q = 0.5, comfortably below.
        let q = memory_equal_q(20, 12, 2);
        assert!((q - 20.0 / 18.0).abs() < 1e-9);
        let gum_at_half = per_block::gum(20, 20, 2, 0.5);
        assert!(gum_at_half <= per_block::galore(20, 20, 12) + 1.0);
    }

    #[test]
    fn gum_between_galore_and_full() {
        let (m, n) = (4096, 14336);
        let galore = per_block::galore(m, n, 512);
        let gum = per_block::gum(m, n, 128, 2.0 / 224.0);
        let full = per_block::sft_muon(m, n);
        assert!(gum < galore, "gum {gum} < galore {galore}");
        assert!(galore < full);
    }

    #[test]
    fn table3_ordering_matches_paper() {
        // Paper Table 3: GaLore(512) > GUM(4+128) > GUM(2+128) for every
        // model.
        for model in paper_shape_table() {
            let ga = estimate(&model, Method::GaLore { rank: 512 });
            let g4 = estimate(
                &model,
                Method::Gum {
                    rank: 128,
                    gamma: 4,
                },
            );
            let g2 = estimate(
                &model,
                Method::Gum {
                    rank: 128,
                    gamma: 2,
                },
            );
            assert!(
                ga.total_gb > g4.total_gb && g4.total_gb > g2.total_gb,
                "{}: {} vs {} vs {}",
                model.name,
                ga.total_gb,
                g4.total_gb,
                g2.total_gb
            );
            // Absolute scale in the right ballpark (paper: 39–47 GB).
            assert!(
                ga.total_gb > 28.0 && ga.total_gb < 58.0,
                "{}: {}",
                model.name,
                ga.total_gb
            );
        }
    }

    /// The runtime `Optimizer::state_bytes` accounting must agree with
    /// the Table-1 closed forms at every moment dtype: projector floats
    /// at 4 bytes, moment floats at the dtype width. GUM is pinned at
    /// its deterministic q extremes (γ = #projectable ⇒ q = 1, γ = 0 ⇒
    /// q = 0) so the expected-value form is exact, not stochastic.
    #[test]
    fn runtime_accounting_matches_closed_forms_per_dtype() {
        use crate::linalg::lowp::StateDtype;
        use crate::linalg::Matrix;
        use crate::model::{BlockKind, ParamBlock, ParamStore};
        use crate::optim::{self, RankSchedule, RefreshStrategy, StepCtx};
        use crate::rng::Pcg;

        let (m, n, r) = (48usize, 96usize, 8usize);
        let mut rng = Pcg::new(3);
        let store = ParamStore {
            blocks: vec![ParamBlock {
                name: "w".into(),
                shape: vec![m, n],
                kind: BlockKind::Projectable,
                value: Matrix::randn(m, n, 0.1, &mut rng),
            }],
        };
        let grads = vec![Matrix::randn(m, n, 1.0, &mut rng)];
        let run = |name: &str, gamma: f64, dtype: StateDtype| -> usize {
            let mut opt = optim::build_with_state(
                name,
                &store,
                r,
                gamma,
                7,
                RefreshStrategy::default(),
                &RankSchedule::Fixed,
                dtype,
            )
            .unwrap();
            let mut s = store.clone();
            let mut prng = Pcg::new(1);
            opt.begin_period(&s, &grads, &mut prng);
            opt.step(&mut s, &grads, &StepCtx { lr: 1e-3, step: 0 });
            opt.state_bytes()
        };
        for dtype in [StateDtype::F32, StateDtype::Bf16, StateDtype::F16] {
            assert_eq!(
                run("galore-muon", 0.0, dtype) as f64,
                block_state_bytes(per_block::galore_split(m, n, r), dtype),
                "galore-muon at {dtype}"
            );
            for (gamma, q) in [(0.0, 0.0), (1.0, 1.0)] {
                assert_eq!(
                    run("gum", gamma, dtype) as f64,
                    block_state_bytes(per_block::gum_split(m, n, r, q), dtype),
                    "gum at {dtype}, q={q}"
                );
            }
        }
    }

    #[test]
    fn human_bytes() {
        assert_eq!(bytes_human(512), "512 B");
        assert_eq!(bytes_human(2048), "2.0 KiB");
        assert!(bytes_human(3 << 30).starts_with("3.00 GiB"));
    }
}
