//! Off-critical-path projector refresh: compute the next period's bases
//! **while training steps continue**, with a deterministic handoff at
//! the period boundary.
//!
//! ## The spec trace
//!
//! Sampling period `p ≥ 1` (first boundary step `b = p·K`) trains
//! against bases derived from the **combined gradient at the
//! refresh-trigger step** `b − lead` (lead = 1 global step), not the
//! boundary gradient; period 0 has no earlier snapshot and refreshes
//! synchronously from the step-0 gradient through the classic
//! `begin_period` path. The trigger step, the gradient snapshot, and
//! the refresh's RNG stream (`derive_seed(seed, "refresh/s<b>")`, or
//! GUM's own per-(period, block) sketch streams) are all pure functions
//! of the step index — never of wall-clock timing — so the committed
//! trajectory is **bit-identical whether the job runs inline at the
//! boundary (`Sync`), finishes early on a pool worker (`Async`), or is
//! resolved mid-flight by a checkpoint**.
//!
//! ## Modes
//!
//! - [`RefreshPipelineMode::Async`] (default): [`plan_refresh`] runs as
//!   a detached pool task ([`crate::thread::spawn_background`]) spawned
//!   at the trigger step; the boundary handoff joins it (helping with
//!   queued pool work while it waits), so the period-boundary stall is
//!   only whatever fraction of the refresh did not overlap with the
//!   last step's gradient + optimizer work.
//! - [`RefreshPipelineMode::Sync`]: same plan, executed inline at the
//!   handoff — the refresh cost sits on the critical path exactly as it
//!   measures in `benches/optim_step.rs`. Kept for bisection
//!   (`--refresh-pipeline sync`); byte-identical trajectory.
//!
//! ## Checkpoints, rollback, resume
//!
//! In-flight jobs are **serialized by resolution**: snapshotting a
//! session (`ParallelSession::train_state`, the trainer's rollback
//! states) resolves any pending job — a pure function of an
//! already-captured snapshot — and stores the finished bases as a
//! [`PendingRefresh`] (the `GUMCKPT3` `REFRESH` section). Restoring
//! (`--resume` or elastic rollback) **discards whatever is currently
//! armed or in flight** and reinstates exactly the serialized state, so
//! fault-injected replays and mid-period resumes commit the same bytes
//! as an uninterrupted run.
//!
//! [`plan_refresh`]: super::Optimizer::plan_refresh

use std::time::Instant;

use crate::coordinator::scheduler::PeriodScheduler;
use crate::linalg::Matrix;
use crate::rng::{derive_seed, Pcg};
use crate::thread::{spawn_background, BackgroundTask};

use super::period_schedule::subspace_drift;
use super::{Optimizer, PreparedRefresh, RefreshJob};

/// Where the projector refresh runs relative to the critical path.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RefreshPipelineMode {
    /// Refresh executes inline at the period boundary (the bisection
    /// reference; full stall, identical bytes).
    Sync,
    /// Refresh executes on the worker pool from the trigger step on;
    /// the boundary handoff only joins.
    #[default]
    Async,
}

impl RefreshPipelineMode {
    /// Parse a CLI/config spelling: `sync` | `async`.
    pub fn parse(s: &str) -> anyhow::Result<RefreshPipelineMode> {
        match s.to_ascii_lowercase().as_str() {
            "sync" => Ok(RefreshPipelineMode::Sync),
            "async" => Ok(RefreshPipelineMode::Async),
            other => anyhow::bail!(
                "unknown refresh pipeline mode '{other}' (expected sync|async)"
            ),
        }
    }

    /// Stable label for logs/metrics.
    pub fn label(&self) -> &'static str {
        match self {
            RefreshPipelineMode::Sync => "sync",
            RefreshPipelineMode::Async => "async",
        }
    }
}

/// A resolved refresh riding in a train-state snapshot: the boundary
/// step the bases are for, plus the bases themselves.
#[derive(Debug, Clone, PartialEq)]
pub struct PendingRefresh {
    /// The period-boundary step whose `begin_period` consumes these
    /// bases.
    pub boundary: u64,
    pub prepared: PreparedRefresh,
}

enum State {
    Idle,
    /// Sync mode between trigger and boundary: the job is planned (its
    /// inputs snapshotted) but executes at the handoff.
    Armed { boundary: usize, job: RefreshJob },
    /// Async mode between trigger and boundary: the job is running (or
    /// queued) on the worker pool.
    InFlight {
        boundary: usize,
        task: BackgroundTask<PreparedRefresh>,
    },
    /// Resolved ahead of the handoff (checkpoint-time resolution or a
    /// restored snapshot).
    Ready {
        boundary: usize,
        prepared: PreparedRefresh,
    },
}

impl std::fmt::Debug for State {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            State::Idle => write!(f, "Idle"),
            State::Armed { boundary, .. } => {
                write!(f, "Armed {{ boundary: {boundary} }}")
            }
            State::InFlight { boundary, .. } => {
                write!(f, "InFlight {{ boundary: {boundary} }}")
            }
            State::Ready { boundary, .. } => {
                write!(f, "Ready {{ boundary: {boundary} }}")
            }
        }
    }
}

/// The double-buffered refresh scheduler one training session owns (see
/// module docs). Drive it with [`RefreshPipeline::observe`] after every
/// combined gradient and [`RefreshPipeline::take`] at every period
/// boundary before `begin_period`.
#[derive(Debug)]
pub struct RefreshPipeline {
    mode: RefreshPipelineMode,
    /// Global steps between the refresh trigger and its boundary. Fixed
    /// at 1: the job overlaps with one full step of gradient + optimizer
    /// work, and the snapshot is as fresh as possible.
    lead: usize,
    seed: u64,
    state: State,
    /// Cumulative seconds the boundary handoff blocked (sync: the whole
    /// refresh; async: only the non-overlapped tail).
    stall_s: f64,
    /// Handoffs that consumed a prepared refresh.
    handoffs: usize,
}

impl RefreshPipeline {
    pub fn new(mode: RefreshPipelineMode, seed: u64) -> RefreshPipeline {
        RefreshPipeline {
            mode,
            lead: 1,
            seed,
            state: State::Idle,
            stall_s: 0.0,
            handoffs: 0,
        }
    }

    pub fn mode(&self) -> RefreshPipelineMode {
        self.mode
    }

    /// Global steps between a refresh trigger and its boundary — the
    /// reduce planner needs it to ship trigger-step gradients dense.
    pub fn lead(&self) -> usize {
        self.lead
    }

    /// Switch mode (meaningful before the run starts; an armed or
    /// in-flight job keeps the mode it was scheduled under).
    pub fn set_mode(&mut self, mode: RefreshPipelineMode) {
        self.mode = mode;
    }

    /// Total seconds period-boundary handoffs have blocked so far — the
    /// number the refresh-overlap benches compare sync vs async on.
    pub fn stall_seconds(&self) -> f64 {
        self.stall_s
    }

    /// Handoffs that consumed a prepared refresh.
    pub fn handoffs(&self) -> usize {
        self.handoffs
    }

    fn pending_boundary(&self) -> Option<usize> {
        match &self.state {
            State::Idle => None,
            State::Armed { boundary, .. }
            | State::InFlight { boundary, .. }
            | State::Ready { boundary, .. } => Some(*boundary),
        }
    }

    /// Observe the combined gradient of `step` (before the optimizer
    /// consumes it). If `step` is the refresh trigger for the next
    /// period boundary, snapshot the inputs and schedule the job —
    /// inline-at-handoff under `Sync`, on the pool under `Async`.
    pub fn observe(
        &mut self,
        step: usize,
        periods: &PeriodScheduler,
        opt: &dyn Optimizer,
        grads: &[Matrix],
    ) {
        let Some(boundary) = periods.refresh_trigger(step, self.lead) else {
            return;
        };
        if self.pending_boundary() == Some(boundary) {
            // Already holding this boundary's refresh (a restored
            // snapshot replaying its trigger step): keep it — the job is
            // a pure function, recomputing would produce the same bytes.
            return;
        }
        let mut rng =
            Pcg::new(derive_seed(self.seed, &format!("refresh/s{boundary}")));
        let job = opt.plan_refresh(grads, &mut rng).map(|job| {
            match periods.controller() {
                None => job,
                Some(ctl) => {
                    // Adaptive period: snapshot the outgoing bases and a
                    // controller clone; the job measures how far the new
                    // subspace drifted off the critical path and ships
                    // the period decision with the bases, so sync, async,
                    // and checkpoint-resolved refreshes commit the same
                    // decision.
                    let mut ctl = ctl.clone();
                    let old = opt.projectors().unwrap_or_default();
                    Box::new(move || {
                        let mut prepared = job();
                        let drifts: Vec<Option<f64>> = prepared
                            .projectors
                            .iter()
                            .enumerate()
                            .map(|(i, new)| {
                                let old = old.get(i).and_then(|o| o.as_ref());
                                match (old, new) {
                                    (Some(o), Some(n)) => {
                                        Some(subspace_drift(o, n))
                                    }
                                    _ => None,
                                }
                            })
                            .collect();
                        let ranks: Option<Vec<u32>> = prepared
                            .rank_state
                            .as_ref()
                            .map(|rs| rs.ranks.clone());
                        ctl.observe(&drifts, ranks.as_deref());
                        prepared.period_state = Some(ctl.state());
                        prepared
                    }) as RefreshJob
                }
            }
        });
        self.state = match job {
            None => State::Idle,
            Some(job) => match self.mode {
                RefreshPipelineMode::Sync => State::Armed { boundary, job },
                RefreshPipelineMode::Async => State::InFlight {
                    boundary,
                    task: spawn_background(job),
                },
            },
        };
    }

    /// The boundary handoff: consume the prepared refresh for
    /// `boundary_step`, blocking (and helping the pool) if the job is
    /// still running. Returns `None` when nothing was scheduled (period
    /// 0, non-projected optimizers, or a resume that landed past the
    /// trigger of a boundary no snapshot covered — impossible through
    /// the checkpoint path, which resolves pending jobs). Stale state
    /// for a *different* boundary is discarded, never consumed — the
    /// rollback contract.
    pub fn take(&mut self, boundary_step: usize) -> Option<PreparedRefresh> {
        match std::mem::replace(&mut self.state, State::Idle) {
            State::Idle => None,
            State::Armed { boundary, job } if boundary == boundary_step => {
                let t = Instant::now();
                let prepared = job();
                self.stall_s += t.elapsed().as_secs_f64();
                self.handoffs += 1;
                Some(prepared)
            }
            State::InFlight { boundary, task } if boundary == boundary_step => {
                let t = Instant::now();
                let prepared = task.join();
                self.stall_s += t.elapsed().as_secs_f64();
                self.handoffs += 1;
                Some(prepared)
            }
            State::Ready { boundary, prepared } if boundary == boundary_step => {
                self.handoffs += 1;
                Some(prepared)
            }
            // A boundary mismatch is stale state from before a rollback
            // or a reconfigured resume: discard it (async tasks retire
            // in the background and drop their result).
            _stale => None,
        }
    }

    /// Resolve any armed/in-flight job now and return the serializable
    /// pending state — the checkpoint path ("serialize in-flight refresh
    /// jobs" as finished bases, which is sound because the job is a pure
    /// function of an already-snapshotted gradient). The resolved result
    /// is kept (`Ready`), so the live session consumes it at the
    /// boundary without recomputing.
    pub fn resolve_pending(&mut self) -> Option<PendingRefresh> {
        self.state = match std::mem::replace(&mut self.state, State::Idle) {
            State::Idle => State::Idle,
            State::Armed { boundary, job } => State::Ready {
                boundary,
                prepared: job(),
            },
            State::InFlight { boundary, task } => State::Ready {
                boundary,
                prepared: task.join(),
            },
            ready @ State::Ready { .. } => ready,
        };
        match &self.state {
            State::Ready { boundary, prepared } => Some(PendingRefresh {
                boundary: *boundary as u64,
                prepared: prepared.clone(),
            }),
            _ => None,
        }
    }

    /// Reinstate the pipeline from a snapshot, **discarding** whatever
    /// is currently armed or in flight — elastic rollback and mid-period
    /// resume both come through here, so a failed attempt's stale bases
    /// can never leak into the replayed trajectory.
    pub fn restore(&mut self, pending: Option<&PendingRefresh>) {
        self.state = match pending {
            Some(p) => State::Ready {
                boundary: p.boundary as usize,
                prepared: p.prepared.clone(),
            },
            None => State::Idle,
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{BlockKind, ParamBlock, ParamStore};
    use crate::optim::{self, StepCtx};

    fn store() -> ParamStore {
        let mut rng = Pcg::new(3);
        ParamStore {
            blocks: vec![ParamBlock {
                name: "w".into(),
                shape: vec![12, 20],
                kind: BlockKind::Projectable,
                value: Matrix::randn(12, 20, 0.1, &mut rng),
            }],
        }
    }

    fn grads(store: &ParamStore, seed: u64) -> Vec<Matrix> {
        let mut rng = Pcg::new(seed);
        store
            .blocks
            .iter()
            .map(|b| Matrix::randn(b.value.rows, b.value.cols, 1.0, &mut rng))
            .collect()
    }

    /// A scheduler whose step-0 boundary already committed — the state
    /// every live session is in once training starts.
    fn running_periods(k: usize) -> PeriodScheduler {
        let mut s = PeriodScheduler::new(k);
        s.commit_boundary(0, None);
        s
    }

    #[test]
    fn mode_parse_spellings() {
        assert_eq!(
            RefreshPipelineMode::parse("sync").unwrap(),
            RefreshPipelineMode::Sync
        );
        assert_eq!(
            RefreshPipelineMode::parse("Async").unwrap(),
            RefreshPipelineMode::Async
        );
        assert!(RefreshPipelineMode::parse("eager").is_err());
        assert_eq!(RefreshPipelineMode::default(), RefreshPipelineMode::Async);
        assert_eq!(RefreshPipelineMode::Sync.label(), "sync");
    }

    #[test]
    fn trigger_fires_one_step_before_each_boundary() {
        let periods = running_periods(5);
        let store = store();
        let opt = optim::build("gum", &store, 4, 1.0, 7).unwrap();
        let g = grads(&store, 1);
        let mut pipe =
            RefreshPipeline::new(RefreshPipelineMode::Sync, 42);
        for step in 0..4 {
            pipe.observe(step, &periods, &*opt, &g);
        }
        // Steps 0..3: triggers are at 4 (for boundary 5); nothing yet.
        assert!(pipe.pending_boundary().is_none());
        pipe.observe(4, &periods, &*opt, &g);
        assert_eq!(pipe.pending_boundary(), Some(5));
        // Handoff for the right boundary consumes; wrong boundary would
        // have discarded.
        let prepared = pipe.take(5).expect("armed refresh must hand off");
        assert_eq!(prepared.projectors.len(), 1);
        assert!(prepared.projectors[0].is_some());
        assert!(pipe.pending_boundary().is_none());
        assert_eq!(pipe.handoffs(), 1);
    }

    #[test]
    fn k1_triggers_every_step() {
        let periods = running_periods(1);
        let store = store();
        let opt = optim::build("gum", &store, 4, 1.0, 7).unwrap();
        let g = grads(&store, 2);
        let mut pipe =
            RefreshPipeline::new(RefreshPipelineMode::Async, 42);
        pipe.observe(0, &periods, &*opt, &g);
        assert_eq!(pipe.pending_boundary(), Some(1));
        assert!(pipe.take(1).is_some());
    }

    #[test]
    fn sync_and_async_jobs_produce_identical_bases() {
        let periods = running_periods(5);
        let store = store();
        let g = grads(&store, 3);
        let mut run = |mode: RefreshPipelineMode| {
            let mut opt = optim::build("gum", &store, 4, 1.0, 7).unwrap();
            let mut rng = Pcg::new(9);
            let mut s = store.clone();
            opt.begin_period(&s, &g, &mut rng);
            opt.step(&mut s, &g, &StepCtx { lr: 0.01, step: 0 });
            let mut pipe = RefreshPipeline::new(mode, 42);
            pipe.observe(4, &periods, &*opt, &g);
            pipe.take(5).expect("refresh prepared")
        };
        let sync = run(RefreshPipelineMode::Sync);
        let async_ = run(RefreshPipelineMode::Async);
        assert_eq!(sync, async_, "sync and async bases must be bit-equal");
    }

    #[test]
    fn stale_boundaries_are_discarded_and_restore_overrides() {
        let periods = running_periods(5);
        let store = store();
        let opt = optim::build("gum", &store, 4, 1.0, 7).unwrap();
        let g = grads(&store, 4);
        let mut pipe =
            RefreshPipeline::new(RefreshPipelineMode::Sync, 42);
        pipe.observe(4, &periods, &*opt, &g);
        // A handoff for a different boundary (post-rollback replay that
        // re-enters an earlier period) must not consume boundary-5 bases.
        assert!(pipe.take(10).is_none());
        assert!(pipe.pending_boundary().is_none(), "stale state discarded");

        // Restore replaces whatever is pending.
        pipe.observe(4, &periods, &*opt, &g);
        let resolved = pipe.resolve_pending().expect("resolvable");
        pipe.restore(None);
        assert!(pipe.pending_boundary().is_none());
        pipe.restore(Some(&resolved));
        assert_eq!(pipe.pending_boundary(), Some(5));
        let prepared = pipe.take(5).expect("restored refresh hands off");
        assert_eq!(prepared, resolved.prepared);
    }

    #[test]
    fn resolve_keeps_the_result_for_the_live_handoff() {
        let periods = running_periods(5);
        let store = store();
        let opt = optim::build("gum", &store, 4, 1.0, 7).unwrap();
        let g = grads(&store, 5);
        let mut pipe =
            RefreshPipeline::new(RefreshPipelineMode::Async, 42);
        pipe.observe(4, &periods, &*opt, &g);
        let pending = pipe.resolve_pending().expect("in-flight resolves");
        assert_eq!(pending.boundary, 5);
        // Resolving twice is idempotent.
        assert_eq!(pipe.resolve_pending(), Some(pending.clone()));
        let prepared = pipe.take(5).expect("ready state consumed");
        assert_eq!(prepared, pending.prepared);
    }

    #[test]
    fn non_projected_optimizers_keep_the_pipeline_idle() {
        let periods = running_periods(5);
        let store = store();
        let opt = optim::build("adamw", &store, 4, 1.0, 7).unwrap();
        let g = grads(&store, 6);
        let mut pipe =
            RefreshPipeline::new(RefreshPipelineMode::Async, 42);
        pipe.observe(4, &periods, &*opt, &g);
        assert!(pipe.pending_boundary().is_none());
        assert!(pipe.take(5).is_none());
    }

    #[test]
    fn adaptive_period_jobs_ship_the_period_decision() {
        use crate::optim::period_schedule::{
            AdaptivePeriodCfg, PeriodSchedule,
        };
        let schedule = PeriodSchedule::Adaptive(AdaptivePeriodCfg {
            drift: 1.0, // everything counts as stable
            patience: 1,
            min_period: 1,
            max_period: 40,
        });
        let store = store();
        let g = grads(&store, 7);
        let mut run = |mode: RefreshPipelineMode| {
            let mut periods = PeriodScheduler::with_schedule(5, &schedule);
            periods.commit_boundary(0, None);
            let mut opt = optim::build("gum", &store, 4, 1.0, 7).unwrap();
            let mut rng = Pcg::new(9);
            opt.begin_period(&store, &g, &mut rng);
            let mut pipe = RefreshPipeline::new(mode, 42);
            pipe.observe(4, &periods, &*opt, &g);
            pipe.take(5).expect("refresh prepared")
        };
        let sync = run(RefreshPipelineMode::Sync);
        let async_ = run(RefreshPipelineMode::Async);
        assert_eq!(sync, async_, "decision must not depend on the mode");
        let state = sync.period_state.expect("adaptive job ships a decision");
        // One stable drift observation at patience 1: 5 stretches to 7.
        assert_eq!(state.period, 7);
        assert_eq!(state.observations, 1);
    }
}
