//! Muon [Jordan et al., 2024] — momentum + Newton–Schulz orthogonalized
//! updates on matrix blocks; AdamW on dense blocks (standard practice:
//! Muon is "an optimizer for the hidden layers").
//!
//! This is both the FT-Muon baseline and the base algorithm inside GUM.

use crate::linalg::lowp::{self, MomentBuf, StateDtype};
use crate::linalg::{newton_schulz, newton_schulz_into, Matrix, NsWorkspace, NS_STEPS};
use crate::model::{BlockKind, ParamStore};

use super::dense::DenseAdamW;
use super::{Optimizer, StepCtx};

/// Full-parameter Muon.
pub struct Muon {
    pub beta: f32,
    pub ns_steps: usize,
    /// Scale updates by √max(m,n)·0.2 (match update RMS to AdamW), the
    /// convention from the reference implementation. Disabled in the
    /// paper-faithful algorithm benches, enabled for LLM training.
    pub rms_scale: bool,
    momentum: Vec<Option<MomentBuf>>,
    dense: Vec<Option<DenseAdamW>>,
    /// Newton–Schulz workspace + direction buffer, reused across blocks
    /// and steps (the ~560-GEMMs-per-step hot loop, §Perf).
    ws: NsWorkspace,
    dir: Matrix,
    /// Unrounded f32 momentum accumulator for the 16-bit state paths
    /// (the Newton–Schulz input; transient, never counted as state).
    acc: Matrix,
}

impl Muon {
    pub fn new(params: &ParamStore, beta: f32) -> Muon {
        let mut momentum = Vec::new();
        let mut dense = Vec::new();
        for b in &params.blocks {
            match b.kind {
                BlockKind::Projectable => {
                    momentum.push(Some(MomentBuf::zeros(
                        StateDtype::F32,
                        b.value.rows,
                        b.value.cols,
                    )));
                    dense.push(None);
                }
                BlockKind::Dense => {
                    momentum.push(None);
                    dense.push(Some(DenseAdamW::new(
                        b.value.shape(),
                        0.9,
                        0.999,
                        1e-8,
                        0.0,
                    )));
                }
            }
        }
        Muon {
            beta,
            ns_steps: NS_STEPS,
            rms_scale: true,
            momentum,
            dense,
            ws: NsWorkspace::new(),
            dir: Matrix::zeros(0, 0),
            acc: Matrix::zeros(0, 0),
        }
    }

    /// The per-block matrix update direction: NS(βM + G).
    pub fn direction(&self, m: &Matrix) -> Matrix {
        newton_schulz(m, self.ns_steps)
    }

    fn update_scale(&self, rows: usize, cols: usize) -> f32 {
        if self.rms_scale {
            0.2 * (rows.max(cols) as f32).sqrt()
        } else {
            1.0
        }
    }
}

impl Optimizer for Muon {
    fn name(&self) -> String {
        "muon".into()
    }

    fn step(&mut self, params: &mut ParamStore, grads: &[Matrix], ctx: &StepCtx) {
        assert_eq!(params.blocks.len(), grads.len());
        for (i, block) in params.blocks.iter_mut().enumerate() {
            match block.kind {
                BlockKind::Projectable => {
                    let s = self.update_scale(block.value.rows, block.value.cols);
                    let ns_steps = self.ns_steps;
                    let beta = self.beta;
                    match self.momentum[i].as_mut().unwrap() {
                        MomentBuf::F32(m) => {
                            m.axpby_in_place(beta, 1.0, &grads[i]);
                            newton_schulz_into(
                                m,
                                ns_steps,
                                &mut self.ws,
                                &mut self.dir,
                            );
                        }
                        MomentBuf::Lowp {
                            dtype, rows, cols, bits,
                        } => {
                            self.acc.resize(*rows, *cols);
                            lowp::axpby(
                                *dtype,
                                beta,
                                bits,
                                1.0,
                                &grads[i].data,
                                &mut self.acc.data,
                            );
                            newton_schulz_into(
                                &self.acc,
                                ns_steps,
                                &mut self.ws,
                                &mut self.dir,
                            );
                        }
                    }
                    block.value.add_scaled_in_place(-ctx.lr * s, &self.dir);
                }
                BlockKind::Dense => {
                    self.dense[i].as_mut().unwrap().step(
                        &mut block.value,
                        &grads[i],
                        ctx.lr,
                    );
                }
            }
        }
    }

    fn state_bytes(&self) -> usize {
        let m: usize = self
            .momentum
            .iter()
            .flatten()
            .map(|m| m.state_bytes())
            .sum();
        let d: usize = self
            .dense
            .iter()
            .flatten()
            .map(|d| d.state_bytes())
            .sum();
        m + d
    }

    fn set_state_dtype(&mut self, dtype: StateDtype) -> anyhow::Result<()> {
        // Build-time only: the zero momenta are reallocated at the new
        // dtype (0.0 packs to 0 bits, so this is exact).
        for m in self.momentum.iter_mut().flatten() {
            let (rows, cols) = m.shape();
            *m = MomentBuf::zeros(dtype, rows, cols);
        }
        for d in self.dense.iter_mut().flatten() {
            d.set_dtype(dtype);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::fro_norm;
    use crate::model::{init_param_store, registry};
    use crate::rng::Pcg;

    #[test]
    fn projectable_blocks_get_orthogonal_updates() {
        let mut store = init_param_store(&registry::get("micro").unwrap(), 0);
        let mut rng = Pcg::new(0);
        let grads: Vec<Matrix> = store
            .blocks
            .iter()
            .map(|b| Matrix::randn(b.value.rows, b.value.cols, 1.0, &mut rng))
            .collect();
        let mut opt = Muon::new(&store, 0.95);
        opt.rms_scale = false;
        let idx = store.projectable_indices()[0];
        let before = store.blocks[idx].value.clone();
        opt.step(&mut store, &grads, &StepCtx { lr: 0.1, step: 0 });
        let delta = before.sub(&store.blocks[idx].value).scaled(1.0 / 0.1);
        // Update direction ≈ msign ⇒ singular values ≈ 1 ⇒ ‖Δ‖_F ≈ √min(m,n).
        let (m, n) = delta.shape();
        let expect = (m.min(n) as f32).sqrt();
        let got = fro_norm(&delta);
        assert!(
            (got - expect).abs() / expect < 0.35,
            "fro {got} vs expected ≈{expect}"
        );
    }

    #[test]
    fn momentum_restart_not_needed_state_persistent() {
        // Muon has no period structure; two steps accumulate momentum.
        let mut store = init_param_store(&registry::get("micro").unwrap(), 0);
        let grads: Vec<Matrix> = store
            .blocks
            .iter()
            .map(|b| {
                let mut g = Matrix::zeros(b.value.rows, b.value.cols);
                g.fill(0.01);
                g
            })
            .collect();
        let mut opt = Muon::new(&store, 0.95);
        opt.step(&mut store, &grads, &StepCtx { lr: 0.01, step: 0 });
        opt.step(&mut store, &grads, &StepCtx { lr: 0.01, step: 1 });
        assert!(opt.state_bytes() > 0);
    }

    #[test]
    fn bf16_momentum_shrinks_state_and_still_descends() {
        let mut rng = Pcg::new(3);
        let cfg = registry::get("micro").unwrap();
        let mut store = init_param_store(&cfg, 0);
        let mut opt32 = Muon::new(&store, 0.95);
        let mut opt = Muon::new(&store, 0.95);
        opt.set_state_dtype(crate::linalg::lowp::StateDtype::Bf16).unwrap();
        assert!(opt.state_bytes() < opt32.state_bytes());
        opt32.rms_scale = false;
        opt.rms_scale = false;
        let idx = store.projectable_indices()[0];
        let target = Matrix::randn(
            store.blocks[idx].value.rows,
            store.blocks[idx].value.cols,
            1.0,
            &mut rng,
        );
        let loss = |s: &ParamStore| fro_norm(&s.blocks[idx].value.sub(&target));
        let l0 = loss(&store);
        for step in 0..60 {
            let grads: Vec<Matrix> = store
                .blocks
                .iter()
                .enumerate()
                .map(|(i, b)| {
                    if i == idx {
                        b.value.sub(&target)
                    } else {
                        Matrix::zeros(b.value.rows, b.value.cols)
                    }
                })
                .collect();
            opt.step(&mut store, &grads, &StepCtx { lr: 0.3, step });
        }
        assert!(loss(&store) < 0.7 * l0, "{} -> {}", l0, loss(&store));
    }

    #[test]
    fn solves_matrix_regression_faster_than_sgd() {
        // min ‖W − T‖_F²: Muon's orthogonalized steps make steady
        // progress scale-free; verify loss decreases monotonically-ish.
        let mut rng = Pcg::new(1);
        let cfg = registry::get("micro").unwrap();
        let mut store = init_param_store(&cfg, 0);
        let idx = store.projectable_indices()[0];
        let target = Matrix::randn(
            store.blocks[idx].value.rows,
            store.blocks[idx].value.cols,
            1.0,
            &mut rng,
        );
        let mut opt = Muon::new(&store, 0.9);
        opt.rms_scale = false;
        let loss = |s: &ParamStore| fro_norm(&s.blocks[idx].value.sub(&target));
        let l0 = loss(&store);
        // msign steps have ‖Δ‖_F = lr·√min(m,n) ≈ 0.3·8; the start is
        // ‖W₀−T‖_F ≈ 64, so ~100 steps suffice to cover the distance.
        for step in 0..120 {
            let grads: Vec<Matrix> = store
                .blocks
                .iter()
                .enumerate()
                .map(|(i, b)| {
                    if i == idx {
                        b.value.sub(&target)
                    } else {
                        Matrix::zeros(b.value.rows, b.value.cols)
                    }
                })
                .collect();
            opt.step(&mut store, &grads, &StepCtx { lr: 0.3, step });
        }
        assert!(loss(&store) < 0.3 * l0, "{} -> {}", l0, loss(&store));
    }
}
