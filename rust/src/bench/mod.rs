//! Criterion-style micro-benchmark harness (offline registry has no
//! criterion). Used by `benches/*.rs` with `harness = false`.
//!
//! Protocol per benchmark: warmup runs, then N timed samples of the
//! closure; reports min/mean/median/p95/σ and optional throughput.
//! `--bench-filter substr` (env `GUM_BENCH_FILTER`) selects benchmarks.

use std::hint::black_box;
use std::time::Instant;

pub use std::hint::black_box as bb;

/// One benchmark group printer.
pub struct Bench {
    name: String,
    warmup: usize,
    samples: usize,
    filter: Option<String>,
}

/// Aggregated statistics for one benchmark.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub samples: usize,
    pub min_s: f64,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub std_s: f64,
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        let filter = std::env::var("GUM_BENCH_FILTER").ok().or_else(|| {
            let args: Vec<String> = std::env::args().collect();
            args.iter()
                .position(|a| a == "--bench-filter")
                .and_then(|i| args.get(i + 1).cloned())
        });
        println!("\n== bench group: {name} ==");
        Bench {
            name: name.to_string(),
            warmup: 3,
            samples: 12,
            filter,
        }
    }

    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup = n;
        self
    }

    pub fn samples(mut self, n: usize) -> Self {
        self.samples = n;
        self
    }

    /// Time `f`, printing a stats row. `work` is the per-call work unit
    /// count for throughput (0 to suppress), `unit` its label.
    pub fn run<F: FnMut()>(
        &self,
        case: &str,
        work: f64,
        unit: &str,
        mut f: F,
    ) -> Option<Stats> {
        let full = format!("{}/{}", self.name, case);
        if let Some(filter) = &self.filter {
            if !full.contains(filter.as_str()) {
                return None;
            }
        }
        for _ in 0..self.warmup {
            f();
        }
        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            f();
            times.push(t.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = times.len();
        let mean = times.iter().sum::<f64>() / n as f64;
        let median = times[n / 2];
        let p95 = times[((n as f64 * 0.95) as usize).min(n - 1)];
        let var = times
            .iter()
            .map(|t| (t - mean) * (t - mean))
            .sum::<f64>()
            / n as f64;
        let stats = Stats {
            name: full.clone(),
            samples: n,
            min_s: times[0],
            mean_s: mean,
            median_s: median,
            p95_s: p95,
            std_s: var.sqrt(),
        };
        let tput = if work > 0.0 {
            format!(
                "  {:>10.2} {unit}/s",
                work / mean
            )
        } else {
            String::new()
        };
        println!(
            "  {:<44} mean {:>10}  med {:>10}  p95 {:>10}  σ {:>9}{}",
            full,
            crate::util::timer::format_duration(mean),
            crate::util::timer::format_duration(median),
            crate::util::timer::format_duration(p95),
            crate::util::timer::format_duration(stats.std_s),
            tput
        );
        Some(stats)
    }

    /// Convenience: time `f` discarding its output via black_box.
    pub fn run_val<T, F: FnMut() -> T>(
        &self,
        case: &str,
        work: f64,
        unit: &str,
        mut f: F,
    ) -> Option<Stats> {
        self.run(case, work, unit, || {
            black_box(f());
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_sane() {
        let b = Bench::new("test").warmup(1).samples(5);
        let s = b
            .run_val("noop", 1.0, "op", || 1 + 1)
            .expect("not filtered");
        assert_eq!(s.samples, 5);
        assert!(s.min_s <= s.median_s);
        assert!(s.median_s <= s.p95_s + 1e-12);
        assert!(s.mean_s >= 0.0);
    }
}
