//! Criterion-style micro-benchmark harness (offline registry has no
//! criterion). Used by `benches/*.rs` with `harness = false`.
//!
//! Protocol per benchmark: warmup runs, then N timed samples of the
//! closure; reports min/mean/median/p95/σ and optional throughput.
//! `--bench-filter substr` (env `GUM_BENCH_FILTER`) selects benchmarks.
//!
//! Machine-readable output: every [`Stats`] produced in the process is
//! recorded, and `--bench-json PATH` (env `GUM_BENCH_JSON`) makes
//! [`write_json_report`] dump them as one JSON document — the
//! `BENCH_*.json` trajectory CI records on every push (EXPERIMENTS.md
//! §Perf). The schema is flat on purpose: one `cases` array of
//! `{name, samples, min_s, mean_s, median_s, p95_s, std_s, work, unit,
//! throughput}` rows plus whatever extra sections the bench binary
//! attaches (e.g. the GEMM sweep's packed-vs-legacy speedups).

use std::hint::black_box;
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;

pub use std::hint::black_box as bb;

/// Every `Stats` produced in this process, in completion order —
/// the source for [`write_json_report`].
static RECORDED: Mutex<Vec<Stats>> = Mutex::new(Vec::new());

/// One benchmark group printer.
pub struct Bench {
    name: String,
    warmup: usize,
    samples: usize,
    filter: Option<String>,
}

/// Aggregated statistics for one benchmark.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub samples: usize,
    pub min_s: f64,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub std_s: f64,
    /// Per-call work units for throughput (0 suppresses the column).
    pub work: f64,
    /// Unit label for `work` (e.g. "GFLOP", "tok").
    pub unit: String,
}

impl Stats {
    /// work / mean seconds, when a work unit was declared.
    pub fn throughput(&self) -> Option<f64> {
        if self.work > 0.0 {
            Some(self.work / self.mean_s)
        } else {
            None
        }
    }

    /// Flat JSON row (`throughput` is null when no work unit was set).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("samples", Json::num(self.samples as f64)),
            ("min_s", Json::num(self.min_s)),
            ("mean_s", Json::num(self.mean_s)),
            ("median_s", Json::num(self.median_s)),
            ("p95_s", Json::num(self.p95_s)),
            ("std_s", Json::num(self.std_s)),
            ("work", Json::num(self.work)),
            ("unit", Json::str(self.unit.clone())),
            (
                "throughput",
                self.throughput().map_or(Json::Null, Json::num),
            ),
        ])
    }
}

/// One CLI/env string argument shared by the bench binaries.
fn arg_or_env(flag: &str, env: &str) -> Option<String> {
    std::env::var(env).ok().or_else(|| {
        let args: Vec<String> = std::env::args().collect();
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1).cloned())
    })
}

/// The benchmark filter (`--bench-filter` / `GUM_BENCH_FILTER`).
pub fn filter() -> Option<String> {
    arg_or_env("--bench-filter", "GUM_BENCH_FILTER")
}

/// Where to write the JSON report (`--bench-json` / `GUM_BENCH_JSON`).
pub fn json_path() -> Option<PathBuf> {
    arg_or_env("--bench-json", "GUM_BENCH_JSON").map(PathBuf::from)
}

/// The JSON document [`write_json_report`] would write: every recorded
/// case plus caller-provided extra sections. Split out so tests can
/// check the schema without touching the filesystem.
pub fn json_report(suite: &str, extra: Vec<(&str, Json)>) -> Json {
    let cases: Vec<Json> = RECORDED
        .lock()
        .unwrap()
        .iter()
        .map(Stats::to_json)
        .collect();
    let mut fields = vec![
        ("suite", Json::str(suite)),
        ("threads", Json::num(crate::thread::num_threads() as f64)),
        ("cases", Json::arr(cases)),
    ];
    fields.extend(extra);
    Json::obj(fields)
}

/// Write the JSON report to `--bench-json`/`GUM_BENCH_JSON`, falling
/// back to `default_path` (pass `None` to write only when explicitly
/// requested). Returns the path written, if any.
pub fn write_json_report(
    suite: &str,
    default_path: Option<&str>,
    extra: Vec<(&str, Json)>,
) -> std::io::Result<Option<PathBuf>> {
    let Some(path) = json_path().or_else(|| default_path.map(PathBuf::from))
    else {
        return Ok(None);
    };
    let doc = json_report(suite, extra);
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(&path, doc.to_string_pretty())?;
    println!("wrote bench JSON: {}", path.display());
    Ok(Some(path))
}

/// Median of an ascending-sorted, non-empty sample vector. Even counts
/// take the midpoint of the two middle samples (the naive `times[n/2]`
/// biased medians high).
fn median_sorted(times: &[f64]) -> f64 {
    let n = times.len();
    if n % 2 == 0 {
        0.5 * (times[n / 2 - 1] + times[n / 2])
    } else {
        times[n / 2]
    }
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        println!("\n== bench group: {name} ==");
        Bench {
            name: name.to_string(),
            warmup: 3,
            samples: 12,
            filter: filter(),
        }
    }

    /// A same-named sibling with different warmup/sample counts —
    /// prints no new group header, so one group can time cheap and
    /// expensive cases at different budgets (the GEMM shape sweep).
    pub fn reconfigured(&self, warmup: usize, samples: usize) -> Bench {
        Bench {
            name: self.name.clone(),
            warmup,
            samples: samples.max(1),
            filter: self.filter.clone(),
        }
    }

    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup = n;
        self
    }

    pub fn samples(mut self, n: usize) -> Self {
        self.samples = n.max(1);
        self
    }

    /// Time `f`, printing a stats row. `work` is the per-call work unit
    /// count for throughput (0 to suppress), `unit` its label.
    pub fn run<F: FnMut()>(
        &self,
        case: &str,
        work: f64,
        unit: &str,
        mut f: F,
    ) -> Option<Stats> {
        let full = format!("{}/{}", self.name, case);
        if let Some(filter) = &self.filter {
            if !full.contains(filter.as_str()) {
                return None;
            }
        }
        for _ in 0..self.warmup {
            f();
        }
        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            f();
            times.push(t.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = times.len();
        let mean = times.iter().sum::<f64>() / n as f64;
        let median = median_sorted(&times);
        let p95 = times[((n as f64 * 0.95) as usize).min(n - 1)];
        let var = times
            .iter()
            .map(|t| (t - mean) * (t - mean))
            .sum::<f64>()
            / n as f64;
        let stats = Stats {
            name: full.clone(),
            samples: n,
            min_s: times[0],
            mean_s: mean,
            median_s: median,
            p95_s: p95,
            std_s: var.sqrt(),
            work,
            unit: unit.to_string(),
        };
        let tput = match stats.throughput() {
            Some(t) => format!("  {:>10.2} {unit}/s", t),
            None => String::new(),
        };
        println!(
            "  {:<44} min {:>10}  mean {:>10}  med {:>10}  p95 {:>10}  σ {:>9}{}",
            full,
            crate::util::timer::format_duration(stats.min_s),
            crate::util::timer::format_duration(mean),
            crate::util::timer::format_duration(median),
            crate::util::timer::format_duration(p95),
            crate::util::timer::format_duration(stats.std_s),
            tput
        );
        RECORDED.lock().unwrap().push(stats.clone());
        Some(stats)
    }

    /// Convenience: time `f` discarding its output via black_box.
    pub fn run_val<T, F: FnMut() -> T>(
        &self,
        case: &str,
        work: f64,
        unit: &str,
        mut f: F,
    ) -> Option<Stats> {
        self.run(case, work, unit, || {
            black_box(f());
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_sane() {
        let b = Bench::new("test").warmup(1).samples(5);
        let s = b
            .run_val("noop", 1.0, "op", || 1 + 1)
            .expect("not filtered");
        assert_eq!(s.samples, 5);
        assert!(s.min_s <= s.median_s);
        assert!(s.median_s <= s.p95_s + 1e-12);
        assert!(s.mean_s >= 0.0);
        assert!(s.throughput().unwrap() > 0.0);
    }

    #[test]
    fn even_sample_median_averages_middle_pair() {
        // The estimator Bench::run uses: even counts take the midpoint
        // of the middle pair, odd counts the middle sample.
        assert_eq!(median_sorted(&[1.0, 2.0, 3.0, 4.0]), 2.5);
        assert_eq!(median_sorted(&[1.0, 2.0, 10.0]), 2.0);
        assert_eq!(median_sorted(&[5.0]), 5.0);
        assert_eq!(median_sorted(&[1.0, 3.0]), 2.0);
        // And the harness path produces medians bounded by min/p95.
        let b = Bench::new("median").warmup(0).samples(6);
        let s = b.run_val("noop", 0.0, "", || 1 + 1).unwrap();
        assert!(s.min_s <= s.median_s && s.median_s <= s.p95_s + 1e-12);
        assert!(s.throughput().is_none());
    }

    #[test]
    fn json_report_schema() {
        let b = Bench::new("jsonschema").warmup(0).samples(2);
        b.run_val("case", 2.0, "op", || 1 + 1).unwrap();
        let doc = json_report("unit-test", vec![("extra", Json::num(1.0))]);
        assert_eq!(doc.get("suite").unwrap().as_str(), Some("unit-test"));
        assert!(doc.get("threads").unwrap().as_f64().unwrap() >= 1.0);
        assert_eq!(doc.get("extra").unwrap().as_f64(), Some(1.0));
        let cases = doc.get("cases").unwrap().as_arr().unwrap();
        let ours = cases
            .iter()
            .find(|c| {
                c.get("name").and_then(Json::as_str)
                    == Some("jsonschema/case")
            })
            .expect("recorded case present");
        for key in [
            "samples", "min_s", "mean_s", "median_s", "p95_s", "std_s",
            "work", "unit", "throughput",
        ] {
            assert!(ours.get(key).is_some(), "missing {key}");
        }
        // Round-trips through the in-tree parser.
        let text = doc.to_string_pretty();
        assert_eq!(crate::util::json::parse(&text).unwrap(), doc);
    }
}
