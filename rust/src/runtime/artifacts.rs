//! Artifact manifest: the typed index over `artifacts/` produced by
//! `python/compile/aot.py`. The manifest is the ABI contract between the
//! Python compile path and this runtime; loading validates it eagerly.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

/// Shape + dtype of one entry-point input/output.
#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<IoSpec> {
        let name = v
            .get("name")
            .and_then(|s| s.as_str())
            .context("io spec missing name")?
            .to_string();
        let shape = v
            .get("shape")
            .and_then(|s| s.as_arr())
            .context("io spec missing shape")?
            .iter()
            .map(|d| d.as_usize().context("bad dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = v
            .get("dtype")
            .and_then(|s| s.as_str())
            .context("io spec missing dtype")?
            .to_string();
        if dtype != "f32" && dtype != "i32" {
            bail!("unsupported dtype '{dtype}' for '{name}'");
        }
        Ok(IoSpec { name, shape, dtype })
    }
}

/// One AOT-lowered entry point.
#[derive(Debug, Clone)]
pub struct EntryPoint {
    pub name: String,
    pub kind: String,
    pub path: PathBuf,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    /// Model config name for `model_*` kinds.
    pub config_name: Option<String>,
}

/// Parsed + validated manifest.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub entries: Vec<EntryPoint>,
}

impl ArtifactManifest {
    /// Load `manifest.json` from an artifacts directory and verify every
    /// referenced HLO file exists.
    pub fn load(dir: &Path) -> Result<ArtifactManifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        let root = json::parse(&text).context("parsing manifest.json")?;
        let version = root
            .get("version")
            .and_then(|v| v.as_usize())
            .context("manifest missing version")?;
        if version != 1 {
            bail!("manifest version {version} unsupported (expect 1)");
        }
        let mut entries = Vec::new();
        for e in root
            .get("entries")
            .and_then(|v| v.as_arr())
            .context("manifest missing entries")?
        {
            let name = e
                .get("name")
                .and_then(|s| s.as_str())
                .context("entry missing name")?
                .to_string();
            let rel = e
                .get("path")
                .and_then(|s| s.as_str())
                .context("entry missing path")?;
            let hlo_path = dir.join(rel);
            if !hlo_path.exists() {
                bail!("artifact {} missing ({})", name, hlo_path.display());
            }
            let parse_specs = |key: &str| -> Result<Vec<IoSpec>> {
                e.get(key)
                    .and_then(|v| v.as_arr())
                    .with_context(|| format!("entry {name} missing {key}"))?
                    .iter()
                    .map(IoSpec::from_json)
                    .collect()
            };
            let inputs = parse_specs("inputs")?;
            let outputs = parse_specs("outputs")?;
            entries.push(EntryPoint {
                name,
                kind: e
                    .get("kind")
                    .and_then(|s| s.as_str())
                    .unwrap_or("")
                    .to_string(),
                path: hlo_path,
                inputs,
                outputs,
                config_name: e
                    .get("config")
                    .and_then(|c| c.get("name"))
                    .and_then(|s| s.as_str())
                    .map(|s| s.to_string()),
            });
        }
        Ok(ArtifactManifest {
            dir: dir.to_path_buf(),
            entries,
        })
    }

    pub fn find(&self, name: &str) -> Option<&EntryPoint> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Model grad entry for a config, e.g. `model_grad_micro`.
    pub fn model_entry(&self, which: &str, config: &str) -> Result<&EntryPoint> {
        let name = format!("model_{which}_{config}");
        self.find(&name).with_context(|| {
            format!(
                "artifact '{name}' not in manifest — re-run `make artifacts` \
                 with --configs {config}"
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        let mut f =
            std::fs::File::create(dir.join("manifest.json")).unwrap();
        f.write_all(body.as_bytes()).unwrap();
    }

    #[test]
    fn loads_valid_manifest() {
        let dir = std::env::temp_dir().join("gum_manifest_ok");
        write_manifest(
            &dir,
            r#"{"version":1,"entries":[{"name":"ns_4x4","kind":"newton_schulz","path":"ns_4x4.hlo.txt","inputs":[{"name":"g","shape":[4,4],"dtype":"f32"}],"outputs":[{"name":"o","shape":[4,4],"dtype":"f32"}]}]}"#,
        );
        std::fs::write(dir.join("ns_4x4.hlo.txt"), "HloModule x").unwrap();
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = m.find("ns_4x4").unwrap();
        assert_eq!(e.inputs[0].numel(), 16);
        assert_eq!(e.inputs[0].dtype, "f32");
    }

    #[test]
    fn missing_file_is_error() {
        let dir = std::env::temp_dir().join("gum_manifest_missing");
        write_manifest(
            &dir,
            r#"{"version":1,"entries":[{"name":"a","path":"a.hlo.txt","inputs":[],"outputs":[]}]}"#,
        );
        let err = ArtifactManifest::load(&dir).unwrap_err();
        assert!(err.to_string().contains("missing"));
    }

    #[test]
    fn version_mismatch_is_error() {
        let dir = std::env::temp_dir().join("gum_manifest_ver");
        write_manifest(&dir, r#"{"version":9,"entries":[]}"#);
        assert!(ArtifactManifest::load(&dir).is_err());
    }

    #[test]
    fn unknown_dtype_rejected() {
        let dir = std::env::temp_dir().join("gum_manifest_dtype");
        write_manifest(
            &dir,
            r#"{"version":1,"entries":[{"name":"a","path":"a.hlo.txt","inputs":[{"name":"x","shape":[1],"dtype":"f64"}],"outputs":[]}]}"#,
        );
        std::fs::write(dir.join("a.hlo.txt"), "x").unwrap();
        assert!(ArtifactManifest::load(&dir).is_err());
    }
}
