//! Executable cache + model runner over the PJRT CPU client.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::linalg::Matrix;
use crate::model::{ParamStore, ModelConfig};

use super::artifacts::{ArtifactManifest, EntryPoint};

/// Owns the PJRT client and a name→compiled-executable cache.
pub struct Executor {
    client: xla::PjRtClient,
    pub manifest: ArtifactManifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Executor {
    /// Create a CPU-PJRT executor over an artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Executor> {
        let manifest = ArtifactManifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Executor {
            client,
            manifest,
            cache: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) an entry point by manifest name.
    pub fn compile(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let entry = self
            .manifest
            .find(name)
            .with_context(|| format!("entry '{name}' not in manifest"))?
            .clone();
        let proto = xla::HloModuleProto::from_text_file(
            entry.path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", entry.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an entry point on literal inputs; returns the decomposed
    /// output tuple as literals.
    pub fn execute(
        &mut self,
        name: &str,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        self.compile(name)?;
        let entry = self.manifest.find(name).unwrap();
        if inputs.len() != entry.inputs.len() {
            bail!(
                "entry '{name}' expects {} inputs, got {}",
                entry.inputs.len(),
                inputs.len()
            );
        }
        let exe = self.cache.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {name} output: {e:?}"))?;
        // return_tuple=True ⇒ a single tuple literal.
        tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("decomposing {name} output: {e:?}"))
    }

    /// Convenience: matrix → literal with a manifest-declared shape.
    pub fn matrix_literal(m: &Matrix, shape: &[usize]) -> Result<xla::Literal> {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(&m.data)
            .reshape(&dims)
            .map_err(|e| anyhow::anyhow!("reshape {shape:?}: {e:?}"))
    }

    /// Convenience: i32 grid → literal (tokens/targets).
    pub fn tokens_literal(
        data: &[i32],
        batch: usize,
        seq: usize,
    ) -> Result<xla::Literal> {
        assert_eq!(data.len(), batch * seq);
        xla::Literal::vec1(data)
            .reshape(&[batch as i64, seq as i64])
            .map_err(|e| anyhow::anyhow!("token reshape: {e:?}"))
    }

    /// Literal → Matrix with a known 2-D-or-less shape.
    pub fn literal_matrix(lit: &xla::Literal, shape: &[usize]) -> Result<Matrix> {
        let data = lit
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("literal to_vec: {e:?}"))?;
        let (rows, cols) = match shape {
            [] => (1, 1),
            [d] => (1, *d),
            [m, n] => (*m, *n),
            other => bail!("unsupported output rank {other:?}"),
        };
        Ok(Matrix::from_vec(rows, cols, data))
    }
}

/// Output of one training step through the L2 graph.
#[derive(Debug)]
pub struct StepOutput {
    pub loss: f32,
    /// Per-block gradients in canonical order.
    pub grads: Vec<Matrix>,
}

/// High-level model runner: validates the manifest against the param
/// store once, then drives `model_grad`/`model_fwd` per step.
pub struct ModelRunner {
    pub config: ModelConfig,
    grad_entry: String,
    fwd_entry: String,
    /// Present when the `model_logits_*` artifact exists (greedy decode).
    logits_entry: Option<String>,
    /// Declared input shapes (params then tokens/targets).
    input_shapes: Vec<Vec<usize>>,
}

impl ModelRunner {
    /// Bind a model config to its artifacts, validating the ABI.
    pub fn new(exec: &Executor, config: &ModelConfig) -> Result<ModelRunner> {
        let grad = exec.manifest.model_entry("grad", &config.name)?;
        let fwd = exec.manifest.model_entry("fwd", &config.name)?;
        validate_model_entry(grad, config)?;
        validate_model_entry(fwd, config)?;
        let logits_entry = exec
            .manifest
            .find(&format!("model_logits_{}", config.name))
            .map(|e| e.name.clone());
        Ok(ModelRunner {
            config: config.clone(),
            grad_entry: grad.name.clone(),
            fwd_entry: fwd.name.clone(),
            logits_entry,
            input_shapes: grad.inputs.iter().map(|s| s.shape.clone()).collect(),
        })
    }

    fn inputs(
        &self,
        params: &ParamStore,
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<Vec<xla::Literal>> {
        let n = params.blocks.len();
        let mut lits = Vec::with_capacity(n + 2);
        for (b, shape) in params.blocks.iter().zip(&self.input_shapes) {
            lits.push(Executor::matrix_literal(&b.value, shape)?);
        }
        let (bsz, seq) = (self.config.batch, self.config.seq_len);
        lits.push(Executor::tokens_literal(tokens, bsz, seq)?);
        lits.push(Executor::tokens_literal(targets, bsz, seq)?);
        Ok(lits)
    }

    /// Forward+backward: loss + per-block gradients.
    pub fn grad_step(
        &self,
        exec: &mut Executor,
        params: &ParamStore,
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<StepOutput> {
        let lits = self.inputs(params, tokens, targets)?;
        let outs = exec.execute(&self.grad_entry, &lits)?;
        if outs.len() != params.blocks.len() + 1 {
            bail!(
                "model_grad returned {} outputs, expected {}",
                outs.len(),
                params.blocks.len() + 1
            );
        }
        let loss = outs[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("loss fetch: {e:?}"))?[0];
        let mut grads = Vec::with_capacity(params.blocks.len());
        for (lit, b) in outs[1..].iter().zip(&params.blocks) {
            let g = Executor::literal_matrix(lit, &b.shape)?;
            grads.push(g);
        }
        Ok(StepOutput { loss, grads })
    }

    /// Forward only: (mean loss, per-example NLL).
    pub fn eval(
        &self,
        exec: &mut Executor,
        params: &ParamStore,
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<(f32, Vec<f32>)> {
        let lits = self.inputs(params, tokens, targets)?;
        let outs = exec.execute(&self.fwd_entry, &lits)?;
        let loss = outs[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("loss fetch: {e:?}"))?[0];
        let nll = outs[1]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("nll fetch: {e:?}"))?;
        Ok((loss, nll))
    }
}

impl ModelRunner {
    /// Full logits (B·S·V flattened, row-major) for a token batch.
    pub fn logits(
        &self,
        exec: &mut Executor,
        params: &ParamStore,
        tokens: &[i32],
    ) -> Result<Vec<f32>> {
        let entry = self.logits_entry.as_ref().context(
            "no model_logits artifact — re-run `make artifacts`",
        )?;
        let n = params.blocks.len();
        let mut lits = Vec::with_capacity(n + 1);
        for (b, shape) in params.blocks.iter().zip(&self.input_shapes) {
            lits.push(Executor::matrix_literal(&b.value, shape)?);
        }
        lits.push(Executor::tokens_literal(
            tokens,
            self.config.batch,
            self.config.seq_len,
        )?);
        let outs = exec.execute(entry, &lits)?;
        outs[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("logits fetch: {e:?}"))
    }

    /// Greedy decode: for each row, `prompts[b]` tokens are placed at the
    /// start; decodes until EOS (`crate::data::tokenizer::EOS`) or
    /// `max_new` tokens. Returns generated ids per row (EOS excluded).
    pub fn greedy_decode(
        &self,
        exec: &mut Executor,
        params: &ParamStore,
        prompts: &[Vec<i32>],
        max_new: usize,
    ) -> Result<Vec<Vec<i32>>> {
        let (bsz, seq, vocab) =
            (self.config.batch, self.config.seq_len, self.config.vocab);
        anyhow::ensure!(prompts.len() <= bsz, "too many prompts for batch");
        let mut tokens = vec![crate::data::tokenizer::BOS; bsz * seq];
        let mut cursors = Vec::new();
        let mut budgets = Vec::new();
        for (b, p) in prompts.iter().enumerate() {
            anyhow::ensure!(p.len() < seq, "prompt fills the whole window");
            tokens[b * seq..b * seq + p.len()].copy_from_slice(p);
            cursors.push(p.len());
            // Per-row budget: never write past the window.
            budgets.push(max_new.min(seq - p.len()));
        }
        let mut done = vec![false; prompts.len()];
        let mut out: Vec<Vec<i32>> = vec![Vec::new(); prompts.len()];
        for _ in 0..max_new {
            if done.iter().all(|&d| d) {
                break;
            }
            let logits = self.logits(exec, params, &tokens)?;
            for (b, &cur) in cursors.iter().enumerate() {
                if done[b] || out[b].len() >= budgets[b] {
                    done[b] = true;
                    continue;
                }
                let off = (b * seq + cur - 1) * vocab;
                let row = &logits[off..off + vocab];
                let next = row
                    .iter()
                    .enumerate()
                    .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
                    .unwrap()
                    .0 as i32;
                if next == crate::data::tokenizer::EOS {
                    done[b] = true;
                } else {
                    tokens[b * seq + cur] = next;
                    out[b].push(next);
                }
            }
            for (b, c) in cursors.iter_mut().enumerate() {
                if !done[b] {
                    *c += 1;
                    if *c >= seq {
                        done[b] = true;
                    }
                }
            }
        }
        Ok(out)
    }
}

fn validate_model_entry(entry: &EntryPoint, config: &ModelConfig) -> Result<()> {
    let blocks = config.param_blocks();
    if entry.inputs.len() != blocks.len() + 2 {
        bail!(
            "artifact '{}' has {} inputs but config '{}' has {} blocks (+2); \
             re-run `make artifacts`",
            entry.name,
            entry.inputs.len(),
            config.name,
            blocks.len()
        );
    }
    for (spec, (name, shape)) in entry.inputs.iter().zip(&blocks) {
        if &spec.name != name || &spec.shape != shape {
            bail!(
                "ABI mismatch in '{}': artifact block '{}'{:?} vs config \
                 '{}'{:?}",
                entry.name,
                spec.name,
                spec.shape,
                name,
                shape
            );
        }
    }
    let tok = &entry.inputs[blocks.len()];
    if tok.shape != vec![config.batch, config.seq_len] {
        bail!(
            "token shape {:?} != config ({}, {})",
            tok.shape,
            config.batch,
            config.seq_len
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_literal_roundtrip() {
        let m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let lit = Executor::matrix_literal(&m, &[2, 3]).unwrap();
        let back = Executor::literal_matrix(&lit, &[2, 3]).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn vector_block_as_1d_literal() {
        let m = Matrix::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        let lit = Executor::matrix_literal(&m, &[4]).unwrap();
        let back = Executor::literal_matrix(&lit, &[4]).unwrap();
        assert_eq!(back.shape(), (1, 4));
        assert_eq!(back.data, m.data);
    }

    #[test]
    fn tokens_literal_shape_checked() {
        let t = vec![0i32; 12];
        assert!(Executor::tokens_literal(&t, 3, 4).is_ok());
    }
}
