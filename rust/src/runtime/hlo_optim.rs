//! HLO-backed optimizer kernels: the L1 Pallas artifacts
//! (`ns_<m>x<n>`, `project_*`, `debias_*`) callable from L3.
//!
//! The native `linalg` twins remain the default inside the optimizers
//! (they handle arbitrary ranks without recompiles); these bindings prove
//! the L1↔L3 contract and power the `runtime_exec` benches plus the
//! cross-layer numerics tests (`rust/tests/runtime_roundtrip.rs`).

use anyhow::{Context, Result};

use crate::linalg::Matrix;

use super::executor::Executor;

/// Typed access to the lowered L1 kernels.
pub struct HloKernels;

impl HloKernels {
    /// msign via the lowered Pallas Newton–Schulz kernel, if an artifact
    /// for this exact shape exists.
    pub fn newton_schulz(exec: &mut Executor, g: &Matrix) -> Result<Matrix> {
        let name = format!("ns_{}x{}", g.rows, g.cols);
        exec.manifest
            .find(&name)
            .with_context(|| format!("no NS artifact for shape {:?}", g.shape()))?;
        let lit = Executor::matrix_literal(g, &[g.rows, g.cols])?;
        let outs = exec.execute(&name, &[lit])?;
        Executor::literal_matrix(&outs[0], &[g.rows, g.cols])
    }

    /// R = Pᵀ G via the lowered projection kernel.
    pub fn project(
        exec: &mut Executor,
        p: &Matrix,
        g: &Matrix,
    ) -> Result<Matrix> {
        let name = format!("project_{}x{}_r{}", g.rows, g.cols, p.cols);
        exec.manifest
            .find(&name)
            .with_context(|| format!("no project artifact '{name}'"))?;
        let pl = Executor::matrix_literal(p, &[p.rows, p.cols])?;
        let gl = Executor::matrix_literal(g, &[g.rows, g.cols])?;
        let outs = exec.execute(&name, &[pl, gl])?;
        Executor::literal_matrix(&outs[0], &[p.cols, g.cols])
    }

    /// D = scale·(G − P Pᵀ G) via the lowered debias kernel.
    pub fn debias(
        exec: &mut Executor,
        p: &Matrix,
        g: &Matrix,
        scale: f32,
    ) -> Result<Matrix> {
        let name = format!("debias_{}x{}_r{}", g.rows, g.cols, p.cols);
        exec.manifest
            .find(&name)
            .with_context(|| format!("no debias artifact '{name}'"))?;
        let pl = Executor::matrix_literal(p, &[p.rows, p.cols])?;
        let gl = Executor::matrix_literal(g, &[g.rows, g.cols])?;
        let sl = xla::Literal::scalar(scale);
        let outs = exec.execute(&name, &[pl, gl, sl])?;
        Executor::literal_matrix(&outs[0], &[g.rows, g.cols])
    }
}
