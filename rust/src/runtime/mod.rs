//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json`) produced by `python/compile/aot.py` and executes them
//! on the CPU PJRT client. This is the only place where L3 touches L2/L1
//! compute; Python never runs here.
//!
//! Key choices (see /opt/xla-example/README.md):
//! - HLO **text** interchange (`HloModuleProto::from_text_file`) — jax ≥
//!   0.5 serialized protos are rejected by xla_extension 0.5.1.
//! - Entry points are lowered with `return_tuple=True`; outputs come back
//!   as a 1-tuple literal that we decompose.
//! - The hot path keeps parameters as device buffers (`execute_b`),
//!   avoiding host↔device literal churn per step (§Perf).

mod artifacts;
mod executor;
mod hlo_optim;

pub use artifacts::{ArtifactManifest, EntryPoint, IoSpec};
pub use executor::{Executor, ModelRunner, StepOutput};
pub use hlo_optim::HloKernels;
