//! **Figures 3 & 5 / E7** — trained-weight spectra and salient-
//! activation tails: GaLore vs GUM final checkpoints.
//!
//! Paper shape: GUM's singular-value distributions are flatter /
//! longer-tailed (higher tail mass, higher stable rank per module), and
//! its salient activations spread over more modules.

use crate::analysis::{
    model_stable_rank, salient_tail_distribution, spectrum_report,
};
use crate::analysis::activations::tail_length;
use crate::coordinator::{load_checkpoint, TrainConfig, Trainer};
use crate::model::ParamStore;

use super::ExpOpts;

fn train_or_load(
    opts: &ExpOpts,
    method: &str,
    steps: usize,
) -> anyhow::Result<ParamStore> {
    let out = opts.out_dir.join(format!("fig3/{method}"));
    let final_path = out.join("final.bin");
    if final_path.exists() {
        println!("  (reusing checkpoint {})", final_path.display());
        return load_checkpoint(&final_path);
    }
    let cfg = TrainConfig {
        model: "micro".into(),
        optimizer: method.into(),
        lr: 8e-3,
        steps,
        period_k: (steps / 10).clamp(10, 100),
        rank: 16,
        gamma: 2.0,
        seed: opts.seed,
        warmup: steps / 20,
        out_dir: Some(out),
        artifacts_dir: opts.artifacts_dir.clone(),
        log_every: 100,
        ..TrainConfig::default()
    };
    Ok(Trainer::new(cfg).run()?.params)
}

pub fn run(opts: &ExpOpts) -> anyhow::Result<()> {
    let steps = opts.steps.unwrap_or(if opts.quick { 150 } else { 500 });
    println!("Figs. 3 & 5 — spectra + activation tails (micro, {steps} steps)\n");

    let galore = train_or_load(opts, "galore-muon", steps)?;
    let gum = train_or_load(opts, "gum", steps)?;

    // Fig. 3-left / Fig. 5: per-module singular-value summary.
    println!("\n  per-module spectrum (tail mass = σ[k/4:] / Σσ):");
    println!(
        "    {:<24} {:>10} {:>10} | {:>10} {:>10}",
        "module", "GaLore SR", "tail", "GUM SR", "tail"
    );
    let ga_rows = spectrum_report(&galore);
    let gu_rows = spectrum_report(&gum);
    let mut ga_tail_sum = 0.0;
    let mut gu_tail_sum = 0.0;
    for (a, b) in ga_rows.iter().zip(&gu_rows) {
        println!(
            "    {:<24} {:>10.2} {:>10.4} | {:>10.2} {:>10.4}",
            a.block, a.stable_rank, a.tail_mass, b.stable_rank, b.tail_mass
        );
        ga_tail_sum += a.tail_mass as f64;
        gu_tail_sum += b.tail_mass as f64;
    }
    let n = ga_rows.len() as f64;
    println!(
        "\n  mean tail mass: GaLore {:.4} vs GUM {:.4} — {}",
        ga_tail_sum / n,
        gu_tail_sum / n,
        if gu_tail_sum >= ga_tail_sum {
            "GUM longer-tailed ✓"
        } else {
            "⚠ inverted"
        }
    );
    println!(
        "  overall stable rank: GaLore {:.2} vs GUM {:.2} — {}",
        model_stable_rank(&galore),
        model_stable_rank(&gum),
        if model_stable_rank(&gum) >= model_stable_rank(&galore) {
            "GUM higher ✓"
        } else {
            "⚠ inverted"
        }
    );

    // Fig. 3-right: salient activation tails.
    let k = if opts.quick { 2000 } else { 10_000 };
    let ga_dist = salient_tail_distribution(&galore, 8, k, opts.seed);
    let gu_dist = salient_tail_distribution(&gum, 8, k, opts.seed);
    println!(
        "\n  salient-activation tail (top-{k} |Wx|): GaLore spans {} \
         modules, GUM spans {} — {}",
        tail_length(&ga_dist),
        tail_length(&gu_dist),
        if tail_length(&gu_dist) >= tail_length(&ga_dist) {
            "GUM longer tail ✓"
        } else {
            "⚠ inverted"
        }
    );
    println!("    top-5 owners (GaLore): {:?}",
        &ga_dist[..5.min(ga_dist.len())]
            .iter().map(|(n, c)| format!("{n}:{c}")).collect::<Vec<_>>());
    println!("    top-5 owners (GUM):    {:?}",
        &gu_dist[..5.min(gu_dist.len())]
            .iter().map(|(n, c)| format!("{n}:{c}")).collect::<Vec<_>>());
    Ok(())
}
