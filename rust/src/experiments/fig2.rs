//! **Figure 2 / E6** — stable rank ↔ performance correlation: train
//! GaLore and GUM with periodic checkpoints, then plot (stable rank,
//! probe score) per checkpoint and report the correlation.

use crate::analysis::model_stable_rank;
use crate::coordinator::eval::DomainProbe;
use crate::coordinator::{load_checkpoint, TrainConfig, Trainer};
use crate::data::corpus::{CorpusSpec, Domain, SyntheticCorpus};
use crate::data::tokenizer::ByteTokenizer;
use crate::model::registry;
use crate::rng::derive_seed;
use crate::runtime::{Executor, ModelRunner};

use super::ExpOpts;

pub fn run(opts: &ExpOpts) -> anyhow::Result<()> {
    let steps = opts.steps.unwrap_or(if opts.quick { 160 } else { 800 });
    let ckpt_every = (steps / 16).max(10);
    println!(
        "Fig. 2 — stable rank vs probe accuracy (micro, {steps} steps, \
         checkpoints every {ckpt_every})\n"
    );

    let mut points: Vec<(String, f64, f64)> = Vec::new();
    for method in ["galore-muon", "gum"] {
        let out = opts.out_dir.join(format!("fig2/{method}"));
        let cfg = TrainConfig {
            model: "micro".into(),
            optimizer: method.into(),
            lr: 8e-3,
            steps,
            period_k: (steps / 10).clamp(10, 100),
            rank: 16,
            gamma: 2.0,
            seed: opts.seed,
            warmup: steps / 20,
            eval_every: 0,
            ckpt_every,
            probes: false,
            out_dir: Some(out.clone()),
            artifacts_dir: opts.artifacts_dir.clone(),
            log_every: 100,
            ..TrainConfig::default()
        };
        Trainer::new(cfg).run()?;

        // Walk checkpoints: stable rank + grammar-domain probe accuracy
        // (the ARC-E stand-in).
        let model_cfg = registry::get("micro").unwrap();
        let mut exec = Executor::new(&opts.artifacts_dir)?;
        let runner = ModelRunner::new(&exec, &model_cfg)?;
        let tok = ByteTokenizer::new(model_cfg.vocab);
        let corpus = SyntheticCorpus::new(CorpusSpec {
            seed: derive_seed(opts.seed, "corpus"),
            ..CorpusSpec::default()
        });
        // Three domains averaged to cut probe variance (±3–4 pts at 64
        // items/domain) — the ARC-E stand-in.
        let probes: Vec<DomainProbe> = [
            Domain::Grammar,
            Domain::SortedRuns,
            Domain::Brackets,
        ]
        .into_iter()
        .map(|d| {
            DomainProbe::build(
                &corpus,
                &tok,
                d,
                if opts.quick { 16 } else { 64 },
                4,
                model_cfg.seq_len,
                3_000_000,
            )
        })
        .collect();
        // The paper's Fig. 2 takes checkpoints *after* 1,000 steps (past
        // the initial stable-rank transient); mirror that by analyzing
        // only the second half of training.
        let burn_in = steps / 2;
        let mut entries: Vec<_> = std::fs::read_dir(&out)?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .and_then(|n| {
                        n.strip_prefix("ckpt_")?
                            .strip_suffix(".bin")?
                            .parse::<usize>()
                            .ok()
                    })
                    .map(|step| step > burn_in)
                    .unwrap_or(false)
            })
            .collect();
        entries.sort();
        println!("  {method}: {} checkpoints", entries.len());
        println!("    {:>10} {:>12} {:>10}", "ckpt", "stable-rank", "probe");
        for p in entries {
            let store = load_checkpoint(&p)?;
            let sr = model_stable_rank(&store);
            let mut acc = 0.0;
            for probe in &probes {
                acc += probe.evaluate(&runner, &mut exec, &store)?
                    / probes.len() as f64;
            }
            println!(
                "    {:>10} {:>12.2} {:>10.3}",
                p.file_stem().unwrap().to_string_lossy(),
                sr,
                acc
            );
            points.push((method.to_string(), sr, acc));
        }
    }

    // Pearson correlation over all points.
    let n = points.len() as f64;
    let (mx, my) = (
        points.iter().map(|p| p.1).sum::<f64>() / n,
        points.iter().map(|p| p.2).sum::<f64>() / n,
    );
    let cov: f64 = points
        .iter()
        .map(|p| (p.1 - mx) * (p.2 - my))
        .sum::<f64>();
    let sx: f64 = points.iter().map(|p| (p.1 - mx).powi(2)).sum::<f64>().sqrt();
    let sy: f64 = points.iter().map(|p| (p.2 - my).powi(2)).sum::<f64>().sqrt();
    let r = cov / (sx * sy).max(1e-12);
    println!("\n  Pearson r(stable rank, probe accuracy) = {r:.3}");
    // Per-method means (the cross-method clustering the paper plots).
    for m in ["galore-muon", "gum"] {
        let pts: Vec<&(String, f64, f64)> =
            points.iter().filter(|p| p.0 == m).collect();
        let n = pts.len().max(1) as f64;
        println!(
            "  {m}: mean SR {:.2}, mean probe {:.3}",
            pts.iter().map(|p| p.1).sum::<f64>() / n,
            pts.iter().map(|p| p.2).sum::<f64>() / n
        );
    }
    println!("  paper shape: positive correlation (higher SR → better)");
    Ok(())
}
