//! **Period-schedule table** — fixed vs adaptive refresh period K on a
//! stationary task, at matched final loss.
//!
//! Setting: two 20×20 projectable blocks with quadratic losses
//! ½‖W_b − T_b‖²_F against *static* diagonal targets. The gradient
//! subspace is frozen from step 0, so after the first refresh the
//! measured principal-angle drift collapses to ~0 — exactly the regime
//! where refreshing every K steps is wasted work. The fixed schedule
//! refreshes every `BASE_K` steps regardless; the adaptive controller
//! observes the near-zero drift and stretches the period toward
//! `max_period`, cutting refresh count ≥ 1.3× while landing at the
//! same final loss. Invoke via `gum experiment period-schedule`.
//!
//! The driver goes through the real machinery — a
//! [`PeriodScheduler`] with an attached controller and a synchronous
//! [`RefreshPipeline`], so every period decision rides a
//! [`PreparedRefresh`](crate::optim::PreparedRefresh) and is adopted at
//! [`PeriodScheduler::commit_boundary`], the same path `Trainer::run`
//! takes.

use crate::coordinator::metrics::MetricsLog;
use crate::coordinator::scheduler::PeriodScheduler;
use crate::linalg::{fro_norm, Matrix};
use crate::model::{BlockKind, ParamBlock, ParamStore};
use crate::optim::{
    self, AdaptivePeriodCfg, PeriodSchedule, RankSchedule, RefreshPipeline,
    RefreshPipelineMode, RefreshStrategy, StepCtx,
};
use crate::rng::{derive_seed, Pcg};

use super::ExpOpts;

const N: usize = 20;
const RANK: usize = 8;
const BASE_K: usize = 5;
const LR: f32 = 0.04;

/// Static per-block target ranks (both well under `RANK`, so the
/// projected subspace captures the full gradient and the trajectory is
/// insensitive to refresh cadence — the matched-loss half of the claim).
const TARGET_RANKS: [usize; 2] = [6, 2];
const TARGET_SIGMA: f32 = 8.0;

fn two_block_store() -> ParamStore {
    ParamStore {
        blocks: vec![
            ParamBlock {
                name: "w_hi".into(),
                shape: vec![N, N],
                kind: BlockKind::Projectable,
                value: Matrix::zeros(N, N),
            },
            ParamBlock {
                name: "w_lo".into(),
                shape: vec![N, N],
                kind: BlockKind::Projectable,
                value: Matrix::zeros(N, N),
            },
        ],
    }
}

/// Diagonal rank-`k` target: exactly `k` singular values at
/// [`TARGET_SIGMA`], frozen for the whole run.
fn target(k: usize) -> Matrix {
    let mut t = Matrix::zeros(N, N);
    for j in 0..k {
        t.data[j * N + j] = TARGET_SIGMA;
    }
    t
}

/// The adaptive configuration used throughout: stretch after one stable
/// observation, shrink floor at 2, ceiling at 8·K.
pub fn adaptive_cfg() -> AdaptivePeriodCfg {
    AdaptivePeriodCfg {
        drift: 0.15,
        patience: 1,
        min_period: 2,
        max_period: 8 * BASE_K,
    }
}

/// Outcome of one schedule's run.
pub struct PeriodRun {
    pub label: &'static str,
    pub final_loss: f64,
    /// Refresh boundaries actually committed.
    pub refreshes: usize,
    /// `(step, period length adopted at that boundary)`.
    pub period_trajectory: Vec<(usize, usize)>,
}

/// Train GUM (γ = 0, exact refresh) for `steps` under `schedule`,
/// through the scheduler + pipeline commit path, and report final loss
/// plus the refresh-boundary trajectory.
pub fn run_schedule(
    schedule: &PeriodSchedule,
    label: &'static str,
    steps: usize,
    seed: u64,
) -> anyhow::Result<PeriodRun> {
    let mut store = two_block_store();
    let targets: Vec<Matrix> =
        TARGET_RANKS.iter().map(|&k| target(k)).collect();
    let mut opt = optim::build_with_schedule(
        "gum",
        &store,
        RANK,
        0.0, // γ = 0: no full-rank lanes, purely projected updates
        derive_seed(seed, "opt"),
        RefreshStrategy::ExactJacobi,
        &RankSchedule::Fixed,
    )?;
    let mut periods = PeriodScheduler::with_schedule(BASE_K, schedule);
    let mut pipeline = RefreshPipeline::new(
        RefreshPipelineMode::Sync,
        derive_seed(seed, "refresh"),
    );
    let mut rng = Pcg::new(derive_seed(seed, "period"));
    let mut refreshes = 0usize;
    let mut period_trajectory = Vec::new();
    for step in 0..steps {
        let grads: Vec<Matrix> = store
            .blocks
            .iter()
            .zip(&targets)
            .map(|(b, t)| b.value.sub(t))
            .collect();
        if periods.is_period_start(step) {
            let taken = pipeline.take(step);
            let decision =
                taken.as_ref().and_then(|p| p.period_state.clone());
            match taken {
                Some(prepared) => opt.begin_period_prepared(
                    &store, &grads, &mut rng, prepared,
                ),
                None => opt.begin_period(&store, &grads, &mut rng),
            }
            periods.commit_boundary(step, decision.as_ref());
            refreshes += 1;
            period_trajectory.push((step, periods.current_period()));
        }
        pipeline.observe(step, &periods, &*opt, &grads);
        opt.step(&mut store, &grads, &StepCtx { lr: LR, step });
    }
    let final_loss: f64 = store
        .blocks
        .iter()
        .zip(&targets)
        .map(|(b, t)| {
            let r = fro_norm(&b.value.sub(t)) as f64;
            0.5 * r * r
        })
        .sum();
    Ok(PeriodRun {
        label,
        final_loss,
        refreshes,
        period_trajectory,
    })
}

pub fn run(opts: &ExpOpts) -> anyhow::Result<()> {
    let steps = opts.steps.unwrap_or(if opts.quick { 160 } else { 240 });
    let cfg = adaptive_cfg();
    println!(
        "Period-schedule comparison: two {N}×{N} blocks, static target \
         ranks {TARGET_RANKS:?} (σ = {TARGET_SIGMA}), base K = {BASE_K}, \
         r = {RANK}, lr = {LR}, steps = {steps}"
    );
    println!(
        "  fixed: refresh every {BASE_K} steps · adaptive: drift \
         threshold {}, patience {}, clamp [{}, {}]",
        cfg.drift, cfg.patience, cfg.min_period, cfg.max_period
    );

    let fixed =
        run_schedule(&PeriodSchedule::Fixed, "fixed", steps, opts.seed)?;
    let adaptive = run_schedule(
        &PeriodSchedule::Adaptive(cfg),
        "adaptive",
        steps,
        opts.seed,
    )?;

    let mut metrics = MetricsLog::new();
    println!(
        "\n  {:<10} {:>14} {:>10} {:>18}",
        "schedule", "final loss", "refreshes", "refreshes/1k steps"
    );
    for run in [&fixed, &adaptive] {
        println!(
            "  {:<10} {:>14.6} {:>10} {:>18.1}",
            run.label,
            run.final_loss,
            run.refreshes,
            run.refreshes as f64 * 1000.0 / steps as f64
        );
        metrics.push(steps, &format!("loss/{}", run.label), run.final_loss);
        metrics.push(
            steps,
            &format!("refreshes/{}", run.label),
            run.refreshes as f64,
        );
        for (step, k) in &run.period_trajectory {
            metrics.push(
                *step,
                &format!("refresh_period/{}", run.label),
                *k as f64,
            );
        }
    }
    let show = |run: &PeriodRun| {
        let tail: Vec<String> = run
            .period_trajectory
            .iter()
            .step_by((run.period_trajectory.len() / 10).max(1))
            .map(|(s, k)| format!("{s}:K={k}"))
            .collect();
        println!("  {} period trajectory: {}", run.label, tail.join(" "));
    };
    show(&fixed);
    show(&adaptive);

    std::fs::create_dir_all(&opts.out_dir).ok();
    metrics.write_csv(&opts.out_dir.join("period_schedule.csv"))?;
    println!(
        "  series → {}",
        opts.out_dir.join("period_schedule.csv").display()
    );
    println!(
        "\n  check: adaptive ≥ 1.3× fewer refreshes at matched loss — \
         refreshes {} vs {} ({:.2}×), loss {:.4} vs {:.4}",
        adaptive.refreshes,
        fixed.refreshes,
        fixed.refreshes as f64 / adaptive.refreshes.max(1) as f64,
        adaptive.final_loss,
        fixed.final_loss
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance claim, as a test: on the stationary task the
    /// adaptive schedule refreshes ≥ 1.3× less often than fixed-K while
    /// matching its final loss, and the controller actually stretched
    /// the period rather than sitting at the base K.
    #[test]
    fn adaptive_refreshes_at_least_1_3x_less_at_matched_loss() {
        let steps = 240;
        let fixed =
            run_schedule(&PeriodSchedule::Fixed, "fixed", steps, 0).unwrap();
        let adaptive = run_schedule(
            &PeriodSchedule::Adaptive(adaptive_cfg()),
            "adaptive",
            steps,
            0,
        )
        .unwrap();
        assert!(
            adaptive.refreshes as f64 * 1.3 <= fixed.refreshes as f64,
            "adaptive {} refreshes is not ≥1.3× fewer than fixed {}",
            adaptive.refreshes,
            fixed.refreshes
        );
        assert!(
            adaptive.final_loss <= fixed.final_loss * 1.10 + 1e-6,
            "adaptive loss {} should match fixed {}",
            adaptive.final_loss,
            fixed.final_loss
        );
        // The controller stretched K (did not just sit at the base
        // period), and never exceeded its ceiling.
        let peak = adaptive
            .period_trajectory
            .iter()
            .map(|&(_, k)| k)
            .max()
            .unwrap_or(0);
        assert!(
            peak > BASE_K,
            "period never stretched: {:?}",
            adaptive.period_trajectory
        );
        assert!(
            peak <= adaptive_cfg().max_period,
            "period {peak} exceeded the ceiling {}",
            adaptive_cfg().max_period
        );
        // The fixed run is exactly the legacy cadence.
        assert_eq!(fixed.refreshes, steps.div_ceil(BASE_K));
    }
}
