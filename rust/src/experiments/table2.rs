//! **Table 2 / E3** — fine-tuning comparison. Pipeline mirror of the
//! paper: pretrain a base model once (Muon), then fine-tune it with each
//! method on (a) instruction-following tasks scored by prompt-level
//! strict/loose exact-match accuracy (IFEval analog) and (b) arithmetic
//! word problems scored by exact numeric accuracy (GSM8K analog).
//! Greedy decoding through the `model_logits` artifact.



use anyhow::Result;

use crate::coordinator::checkpoint::{load_checkpoint, save_checkpoint};
use crate::coordinator::scheduler::{LrSchedule, PeriodScheduler};
use crate::coordinator::{TrainConfig, Trainer};
use crate::data::tasks::{
    gen_prompt, loose_match, sft_row, strict_match, ArithmeticTask,
    InstructionTask, TaskExample,
};
use crate::data::tokenizer::ByteTokenizer;
use crate::model::{init_param_store, registry, ParamStore};
use crate::optim::{self, StepCtx};
use crate::rng::{derive_seed, Pcg};
use crate::runtime::{Executor, ModelRunner};

use super::ExpOpts;

/// Get (or train) the shared pretrained base.
fn base_model(opts: &ExpOpts, steps: usize) -> Result<ParamStore> {
    let path = opts.out_dir.join("table2/base.bin");
    if path.exists() {
        println!("  (reusing base checkpoint {})", path.display());
        return load_checkpoint(&path);
    }
    println!("  pretraining shared base (muon, {steps} steps)…");
    let cfg = TrainConfig {
        model: "micro".into(),
        optimizer: "muon".into(),
        lr: 8e-3,
        steps,
        period_k: 50,
        seed: opts.seed,
        warmup: steps / 20,
        artifacts_dir: opts.artifacts_dir.clone(),
        log_every: 100,
        ..TrainConfig::default()
    };
    let result = Trainer::new(cfg).run()?;
    save_checkpoint(&result.params, &path)?;
    Ok(result.params)
}

/// Fine-tune `base` with `method` on a 50/50 instruction+math mixture.
#[allow(clippy::too_many_arguments)]
fn finetune(
    opts: &ExpOpts,
    exec: &mut Executor,
    runner: &ModelRunner,
    base: &ParamStore,
    method: &str,
    steps: usize,
    rank: usize,
    gamma: f64,
) -> Result<ParamStore> {
    let model_cfg = registry::get("micro").unwrap();
    let tok = ByteTokenizer::new(model_cfg.vocab);
    let instr = InstructionTask::new(derive_seed(opts.seed, "sft-instr"));
    let math = ArithmeticTask::new(derive_seed(opts.seed, "sft-math"));
    let mut params = base.clone();
    let mut opt = optim::build(
        method,
        &params,
        rank,
        gamma,
        derive_seed(opts.seed, method),
    )?;
    let schedule = LrSchedule::warmup_cosine(4e-3, steps / 10, steps);
    let mut periods = PeriodScheduler::new((steps / 6).clamp(10, 200));
    let mut rng = Pcg::new(derive_seed(opts.seed, "sft"));
    let (bsz, seq) = (model_cfg.batch, model_cfg.seq_len);

    for step in 0..steps {
        // Pack a batch of task rows (alternating instruction/math).
        let mut tokens = Vec::with_capacity(bsz * seq);
        let mut targets = Vec::with_capacity(bsz * seq);
        for b in 0..bsz {
            let i = (step * bsz + b) as u64;
            let ex = if b % 2 == 0 {
                instr.example(i)
            } else {
                math.example(i)
            };
            let (t, g) = sft_row(&tok, &ex, seq);
            tokens.extend(t);
            targets.extend(g);
        }
        let out = runner.grad_step(exec, &params, &tokens, &targets)?;
        if periods.is_period_start(step) {
            opt.begin_period(&params, &out.grads, &mut rng);
            periods.commit_boundary(step, None);
        }
        opt.step(
            &mut params,
            &out.grads,
            &StepCtx {
                lr: schedule.at(step) as f32,
                step,
            },
        );
    }
    Ok(params)
}

/// Evaluate exact-match metrics by greedy decoding held-out examples.
fn decode_eval(
    exec: &mut Executor,
    runner: &ModelRunner,
    params: &ParamStore,
    examples: &[TaskExample],
) -> Result<(f64, f64)> {
    let tok = ByteTokenizer::new(runner.config.vocab);
    let bsz = runner.config.batch;
    let mut strict = 0usize;
    let mut loose = 0usize;
    for chunk in examples.chunks(bsz) {
        let prompts: Vec<Vec<i32>> = chunk
            .iter()
            .map(|ex| gen_prompt(&tok, &ex.prompt))
            .collect();
        let max_new = chunk
            .iter()
            .map(|ex| ex.answer.len() + 4)
            .max()
            .unwrap_or(8);
        let outs = runner.greedy_decode(exec, params, &prompts, max_new)?;
        for (ex, ids) in chunk.iter().zip(&outs) {
            let text = tok.decode(ids);
            if strict_match(&text, &ex.answer) {
                strict += 1;
            }
            if loose_match(&text, &ex.answer) {
                loose += 1;
            }
        }
    }
    let n = examples.len() as f64;
    Ok((strict as f64 / n, loose as f64 / n))
}

pub fn run(opts: &ExpOpts) -> anyhow::Result<()> {
    let pretrain_steps = if opts.quick { 150 } else { 600 };
    let sft_steps = opts.steps.unwrap_or(if opts.quick { 80 } else { 1500 });
    let n_eval = if opts.quick { 24 } else { 64 };
    println!(
        "Table 2 — fine-tuning comparison (micro base, {sft_steps} SFT \
         steps, {n_eval} eval items/task)\n"
    );

    let model_cfg = registry::get("micro").unwrap();
    let mut exec = Executor::new(&opts.artifacts_dir)?;
    let runner = ModelRunner::new(&exec, &model_cfg)?;
    let base = base_model(opts, pretrain_steps)?;
    // Sanity: a fresh (untrained) store differs from base.
    debug_assert!(
        init_param_store(&model_cfg, opts.seed)
            .blocks[1]
            .value
            .max_abs_diff(&base.blocks[1].value)
            > 0.0
    );

    // Held-out eval examples (ids far beyond the SFT stream).
    let instr = InstructionTask::new(derive_seed(opts.seed, "sft-instr"));
    let math = ArithmeticTask::new(derive_seed(opts.seed, "sft-math"));
    let instr_eval: Vec<TaskExample> =
        (0..n_eval).map(|i| instr.example(1_000_000 + i as u64)).collect();
    let math_eval: Vec<TaskExample> =
        (0..n_eval).map(|i| math.example(1_000_000 + i as u64)).collect();

    println!(
        "\n  {:<22} {:>14} {:>14} {:>10}",
        "Method", "IF strict", "IF loose", "Math acc"
    );
    let mut results = Vec::new();
    for method in ["adamw", "muon", "galore-muon", "fira", "gum"] {
        let tuned = finetune(
            opts, &mut exec, &runner, &base, method, sft_steps, 16, 2.0,
        )?;
        let (strict, loose) =
            decode_eval(&mut exec, &runner, &tuned, &instr_eval)?;
        let (macc, _) = decode_eval(&mut exec, &runner, &tuned, &math_eval)?;
        println!(
            "  {:<22} {:>13.1}% {:>13.1}% {:>9.1}%",
            method,
            strict * 100.0,
            loose * 100.0,
            macc * 100.0
        );
        results.push((method, strict, loose, macc));
    }

    let get = |m: &str| results.iter().find(|r| r.0 == m).unwrap();
    let (ga, gu) = (get("galore-muon"), get("gum"));
    let gum_wins = (gu.1 >= ga.1) as u8 + (gu.2 >= ga.2) as u8 + (gu.3 >= ga.3) as u8;
    println!(
        "\n  check (paper shape): GUM ≥ GaLore on {gum_wins}/3 metrics"
    );
    Ok(())
}
