//! **Table 1 / E2** — space complexity: GaLore O(2mr) vs GUM
//! O((2−q)mr′ + qm²) vs SFT O(m²), plus the memory-equal q line and a
//! sweep over m showing where each method wins.

use crate::optim::memory::{memory_equal_q, per_block};

use super::ExpOpts;

pub fn run(_opts: &ExpOpts) -> anyhow::Result<()> {
    println!("Table 1 — space complexity per m×m block (floats)\n");
    println!("  Method   | Space Complexity");
    println!("  ---------|--------------------------");
    println!("  GaLore   | 2·m·r");
    println!("  GUM      | (2−q)·m·r′ + q·m²");
    println!("  SFT      | m²\n");

    println!(
        "  {:>6} {:>6} {:>6} {:>8} | {:>12} {:>12} {:>12} | {:>10}",
        "m", "r", "r'", "q", "GaLore", "GUM", "SFT(Muon)", "q_equal"
    );
    for (m, r, rp, q) in [
        (20usize, 12usize, 2usize, 0.5f64), // Fig. 1's setting
        (512, 128, 32, 0.1),
        (4096, 512, 128, 2.0 / 224.0), // paper fine-tuning setting
        (4096, 512, 128, 4.0 / 224.0),
        (14336, 512, 128, 2.0 / 224.0),
    ] {
        let ga = per_block::galore(m, m, r);
        let gu = per_block::gum(m, m, rp, q);
        let sft = per_block::sft_muon(m, m);
        let qe = memory_equal_q(m, r, rp);
        println!(
            "  {:>6} {:>6} {:>6} {:>8.4} | {:>12.0} {:>12.0} {:>12.0} | {:>10.4}",
            m, r, rp, q, ga, gu, sft, qe
        );
    }
    println!(
        "\n  (q_equal = 2(r−r′)/(m−r′): the q at which GUM's expected \
         memory equals GaLore's; above the listed q ⇒ GUM uses less.)"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn complexity_ordering_at_paper_settings() {
        // At the paper's fine-tuning setting (m=4096, GaLore r=512,
        // GUM 2+128 over 224 blocks): GUM < GaLore < SFT.
        let q = 2.0 / 224.0;
        let ga = per_block::galore(4096, 4096, 512);
        let gu = per_block::gum(4096, 4096, 128, q);
        let sft = per_block::sft_muon(4096, 4096);
        assert!(gu < ga && ga < sft, "{gu} {ga} {sft}");
    }
}
