//! **Table 4 / E5** — pre-training comparison: AdamW, Muon, GaLore,
//! Fira, GUM trained from scratch on the synthetic multi-domain corpus
//! (paired data order), evaluated on the seven domain probes.
//!
//! Paper shape to reproduce: GUM ≥ GaLore on the average, competitive
//! with (or better than) full-parameter training; per-domain ordering
//! varies.

use crate::coordinator::{TrainConfig, Trainer};
use crate::data::corpus::ALL_DOMAINS;

use super::ExpOpts;

pub struct MethodRow {
    pub method: String,
    pub scores: Vec<f64>,
    pub avg: f64,
    pub val_loss: f64,
    pub state_bytes: usize,
}

pub fn run_methods(
    opts: &ExpOpts,
    model: &str,
    steps: usize,
    methods: &[&str],
) -> anyhow::Result<Vec<MethodRow>> {
    let mut rows = Vec::new();
    for &method in methods {
        let cfg = TrainConfig {
            model: model.into(),
            optimizer: method.into(),
            lr: match method {
                "adamw" => 3e-3,
                _ => 8e-3,
            },
            steps,
            period_k: (steps / 10).clamp(10, 100),
            // Paper ratio: GaLore rank 256 vs GUM γ+rank 4+128 at dim
            // 512–1024 → here dim 64: GaLore r=16, GUM r′=8 + γ=2
            // full-rank samples (comparable expected memory).
            rank: if method == "gum" { 8 } else { 16 },
            gamma: 2.0,
            seed: opts.seed,
            warmup: steps / 20,
            eval_every: steps / 4,
            eval_batches: 4,
            ckpt_every: 0,
            probes: true,
            probe_items: if opts.quick { 12 } else { 48 },
            artifacts_dir: opts.artifacts_dir.clone(),
            out_dir: Some(opts.out_dir.join(format!("table4/{method}"))),
            log_every: 50,
            ..TrainConfig::default()
        };
        let result = Trainer::new(cfg).run()?;
        let scores: Vec<f64> =
            result.probe_scores.iter().map(|(_, v)| *v).collect();
        let avg = scores.iter().sum::<f64>() / scores.len().max(1) as f64;
        rows.push(MethodRow {
            method: result.optimizer_name,
            scores,
            avg,
            val_loss: result.final_val_loss.unwrap_or(f64::NAN),
            state_bytes: result.state_bytes,
        });
    }
    Ok(rows)
}

pub fn print_table(rows: &[MethodRow]) {
    print!("  {:<22}", "Method");
    for d in ALL_DOMAINS {
        print!(" {:>9}", &d.name()[..d.name().len().min(9)]);
    }
    println!(" {:>7} {:>9} {:>10}", "Avg", "ValLoss", "States");
    for r in rows {
        print!("  {:<22}", r.method);
        for s in &r.scores {
            print!(" {:>9.2}", s * 100.0);
        }
        println!(
            " {:>7.2} {:>9.4} {:>10}",
            r.avg * 100.0,
            r.val_loss,
            crate::optim::bytes_human(r.state_bytes)
        );
    }
}

pub fn run(opts: &ExpOpts) -> anyhow::Result<()> {
    let steps = opts.steps.unwrap_or(if opts.quick { 120 } else { 1000 });
    println!(
        "Table 4 — pre-training on the synthetic corpus (micro model, \
         {steps} steps, paired batches, probe chance = 25%)\n"
    );
    let rows = run_methods(
        opts,
        "micro",
        steps,
        &["adamw", "muon", "galore-muon", "fira", "gum"],
    )?;
    print_table(&rows);

    let find = |n: &str| rows.iter().find(|r| r.method.starts_with(n));
    if let (Some(ga), Some(gu)) = (find("galore"), find("gum")) {
        println!(
            "\n  check (paper shape): GUM avg {:.2} vs GaLore avg {:.2} — {}",
            gu.avg * 100.0,
            ga.avg * 100.0,
            if gu.avg >= ga.avg { "GUM ≥ GaLore ✓" } else { "⚠ inverted" }
        );
    }
    Ok(())
}
