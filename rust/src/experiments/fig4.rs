//! **Figure 4 / E8** — GaLore's bias residual χ_t = ‖Gᵘ−Gᵖ‖_F/‖Gᵘ‖_F
//! along a real training trajectory: small right after each projector
//! refresh, rising to 60–80%+ within a few iterations.
//!
//! Scaled-down mirror of the paper's Gemma-2-9B run: micro model,
//! GaLore-Muon, projector refresh period 50, residual sampled every 5
//! steps for a selection of attention/MLP blocks.

use crate::analysis::bias_residual;
use crate::coordinator::metrics::MetricsLog;
use crate::data::corpus::CorpusSpec;
use crate::data::loader::BatchLoader;
use crate::data::corpus::SyntheticCorpus;
use crate::data::tokenizer::ByteTokenizer;
use crate::model::{init_param_store, registry};
use crate::optim::{BaseOpt, GaLore, Optimizer, ProjKind, Projector, StepCtx};
use crate::rng::{derive_seed, Pcg};
use crate::runtime::{Executor, ModelRunner};

use super::ExpOpts;

pub fn run(opts: &ExpOpts) -> anyhow::Result<()> {
    let steps = opts.steps.unwrap_or(if opts.quick { 120 } else { 300 });
    let period = 50usize;
    let rank = 16usize;
    let sample_every = 5usize;
    println!(
        "Fig. 4 — GaLore bias residual χ_t (micro, {steps} steps, \
         refresh {period}, rank {rank})\n"
    );

    let model_cfg = registry::get("micro").unwrap();
    let mut exec = Executor::new(&opts.artifacts_dir)?;
    let runner = ModelRunner::new(&exec, &model_cfg)?;
    let mut params = init_param_store(&model_cfg, opts.seed);
    let mut opt = GaLore::new(
        &params,
        rank,
        BaseOpt::Muon { beta: 0.95 },
        ProjKind::SvdTopR,
    );
    let tok = ByteTokenizer::new(model_cfg.vocab);
    let mut loader = BatchLoader::new(
        SyntheticCorpus::new(CorpusSpec {
            seed: derive_seed(opts.seed, "corpus"),
            ..CorpusSpec::default()
        }),
        tok,
        model_cfg.batch,
        model_cfg.seq_len,
    );
    let mut rng = Pcg::new(derive_seed(opts.seed, "fig4"));

    // Track χ_t for representative blocks (layer 1 = "layer 10" analog).
    let tracked: Vec<usize> = params
        .blocks
        .iter()
        .enumerate()
        .filter(|(_, b)| {
            ["layers.1.wq", "layers.1.wo", "layers.1.w_gate",
             "layers.1.w_up", "layers.1.w_down"]
                .contains(&b.name.as_str())
        })
        .map(|(i, _)| i)
        .collect();
    // Shadow projectors rebuilt at each refresh from the fresh grads
    // (same construction GaLore uses internally).
    let mut shadow: Vec<Option<Projector>> = vec![None; params.blocks.len()];
    let mut metrics = MetricsLog::new();
    let mut refresh_chis: Vec<f64> = Vec::new();
    let mut mid_chis: Vec<f64> = Vec::new();

    for step in 0..steps {
        let batch = loader.next_batch();
        let out =
            runner.grad_step(&mut exec, &params, &batch.tokens, &batch.targets)?;
        if step % period == 0 {
            opt.begin_period(&params, &out.grads, &mut rng);
            for &i in &tracked {
                shadow[i] = Some(Projector::build(
                    &out.grads[i],
                    rank,
                    ProjKind::SvdTopR,
                    &mut rng,
                ));
            }
        }
        if step % sample_every == 0 {
            let mut step_mean = 0.0;
            for &i in &tracked {
                let chi =
                    bias_residual(shadow[i].as_ref().unwrap(), &out.grads[i]);
                metrics.push(
                    step,
                    &format!("chi/{}", params.blocks[i].name),
                    chi,
                );
                step_mean += chi / tracked.len() as f64;
            }
            metrics.push(step, "chi/mean", step_mean);
            if step % period == 0 {
                refresh_chis.push(step_mean);
            } else {
                mid_chis.push(step_mean);
            }
            if step % (sample_every * 2) == 0 {
                println!("    step {step:>5}: mean χ = {step_mean:.3}");
            }
        }
        opt.step(
            &mut params,
            &out.grads,
            &StepCtx { lr: 8e-3, step },
        );
    }

    metrics.write_csv(&opts.out_dir.join("fig4.csv"))?;
    let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    let (at_refresh, between) = (avg(&refresh_chis), avg(&mid_chis));
    println!(
        "\n  χ at refresh steps: {:.3}   between refreshes: {:.3}  — {}",
        at_refresh,
        between,
        if between > at_refresh + 0.1 {
            "periodic bias pattern ✓ (matches paper: small at refresh, \
             60-80% between)"
        } else {
            "⚠ pattern weak"
        }
    );
    println!("  series → {}", opts.out_dir.join("fig4.csv").display());
    Ok(())
}
