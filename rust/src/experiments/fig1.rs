//! **Figure 1 / E1** — the synthetic counterexample: GaLore-Muon fails
//! to converge on noisy linear regression while GUM matches full Muon.
//!
//! Setting (paper §5.1): n = 20, noise rank r = 12, σ = 100;
//! GaLore rank 12 vs GUM (r′ = 2, q = 0.5); Muon full-rank baseline.
//! Every method uses the same Muon base (β = 0.95) and a small constant
//! LR; period K refreshes projectors.

use crate::coordinator::metrics::{ascii_curve, MetricsLog};
use crate::linalg::Matrix;
use crate::model::{BlockKind, ParamBlock, ParamStore};
use crate::optim::{
    BaseOpt, Compensation, GaLore, Gum, Muon, Optimizer, ProjKind, StepCtx,
};
use crate::rng::{derive_seed, Pcg};
use crate::synthetic::NoisyLinReg;

use super::ExpOpts;

/// Wrap one n×n matrix as a single-block "model".
fn single_block_store(n: usize) -> ParamStore {
    ParamStore {
        blocks: vec![ParamBlock {
            name: "x".into(),
            shape: vec![n, n],
            kind: BlockKind::Projectable,
            value: Matrix::zeros(n, n),
        }],
    }
}

/// Run one optimizer on the problem; returns the adjusted-loss curve.
pub fn run_method(
    problem: &NoisyLinReg,
    mut opt: Box<dyn Optimizer>,
    steps: usize,
    period_k: usize,
    lr: f32,
    seed: u64,
) -> Vec<(usize, f64)> {
    let mut store = single_block_store(problem.n);
    let mut rng = Pcg::new(derive_seed(seed, "grad"));
    let mut period_rng = Pcg::new(derive_seed(seed, "period"));
    let mut curve = Vec::with_capacity(steps);
    for step in 0..steps {
        let g = problem.grad_stochastic(&store.blocks[0].value, &mut rng);
        if step % period_k == 0 {
            opt.begin_period(&store, std::slice::from_ref(&g), &mut period_rng);
        }
        opt.step(
            &mut store,
            std::slice::from_ref(&g),
            &StepCtx { lr, step },
        );
        curve.push((step, problem.adjusted_loss(&store.blocks[0].value)));
    }
    curve
}

pub fn run(opts: &ExpOpts) -> anyhow::Result<()> {
    let steps = opts.steps.unwrap_or(if opts.quick { 400 } else { 3000 });
    let (n, noise_rank, sigma) = (20usize, 12usize, 100.0f32);
    let (galore_rank, gum_rank, gum_q) = (12usize, 2usize, 0.5f64);
    let period_k = 20;
    let lr = 0.02;
    let problem = NoisyLinReg::new(n, noise_rank, sigma, opts.seed);
    let store = single_block_store(n);

    println!(
        "Fig.1 counterexample: n={n} noise-rank={noise_rank} σ={sigma} \
         steps={steps} K={period_k} lr={lr}"
    );
    println!(
        "  memory/block (floats): galore(r=12)={}  gum(r'=2,q=0.5)={}  \
         muon={}",
        crate::optim::memory::per_block::galore(n, n, galore_rank),
        crate::optim::memory::per_block::gum(n, n, gum_rank, gum_q),
        crate::optim::memory::per_block::sft_muon(n, n),
    );

    let mut metrics = MetricsLog::new();
    let mut finals = Vec::new();
    let methods: Vec<(&str, Box<dyn Optimizer>)> = vec![
        ("muon", {
            let mut m = Muon::new(&store, 0.95);
            m.rms_scale = false;
            Box::new(m)
        }),
        ("galore-muon", {
            let mut g = GaLore::new(
                &store,
                galore_rank,
                BaseOpt::Muon { beta: 0.95 },
                ProjKind::SvdTopR,
            );
            g.rms_scale = false;
            g.restart_on_period = false; // official GaLore: state persists across refreshes
            Box::new(g)
        }),
        ("golore-muon", {
            let mut g = GaLore::new(
                &store,
                galore_rank,
                BaseOpt::Muon { beta: 0.95 },
                ProjKind::Random,
            );
            g.rms_scale = false;
            g.restart_on_period = false; // official GaLore: state persists across refreshes
            Box::new(g)
        }),
        ("gum", {
            let mut g = Gum::new(
                &store,
                gum_rank,
                gum_q,
                0.95,
                Compensation::Paper,
                derive_seed(opts.seed, "gum"),
            );
            g.rms_scale = false;
            Box::new(g)
        }),
    ];

    for (name, opt) in methods {
        let curve =
            run_method(&problem, opt, steps, period_k, lr, opts.seed);
        let tail: f64 = curve[curve.len().saturating_sub(50)..]
            .iter()
            .map(|(_, v)| v)
            .sum::<f64>()
            / 50.0;
        for (s, v) in &curve {
            if s % 10 == 0 {
                metrics.push(*s, &format!("loss/{name}"), *v);
            }
        }
        println!("\n  {name}: final adjusted loss (tail-50 mean) = {tail:.3}");
        println!(
            "{}",
            ascii_curve(
                &curve.iter().step_by(steps / 60).cloned().collect::<Vec<_>>(),
                60,
                10
            )
        );
        finals.push((name.to_string(), tail));
    }

    metrics.write_csv(&opts.out_dir.join("fig1.csv"))?;
    println!("  series → {}", opts.out_dir.join("fig1.csv").display());

    // Paper's qualitative claim, checked numerically:
    let get = |n: &str| finals.iter().find(|(m, _)| m == n).unwrap().1;
    let (muon, galore, gum) = (get("muon"), get("galore-muon"), get("gum"));
    println!("\n  check: GaLore stalls ≫ GUM ≈ Muon");
    println!(
        "    muon={muon:.2}  gum={gum:.2}  galore={galore:.2}  \
         (galore/gum ratio {:.1}×)",
        galore / gum.max(1e-9)
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's headline qualitative result, as a test: on the rank-r
    /// noise problem GaLore-Muon plateaus orders of magnitude above GUM.
    #[test]
    fn galore_fails_gum_converges() {
        let problem = NoisyLinReg::new(20, 12, 100.0, 0);
        let store = single_block_store(20);
        let steps = 1200;

        let mut muon = Muon::new(&store, 0.95);
        muon.rms_scale = false;
        let muon_curve =
            run_method(&problem, Box::new(muon), steps, 20, 0.02, 1);

        let mut galore = GaLore::new(
            &store,
            12,
            BaseOpt::Muon { beta: 0.95 },
            ProjKind::SvdTopR,
        );
        galore.rms_scale = false;
        galore.restart_on_period = false;
        let galore_curve =
            run_method(&problem, Box::new(galore), steps, 20, 0.02, 1);

        let mut gum =
            Gum::new(&store, 2, 0.5, 0.95, Compensation::Paper, 3);
        gum.rms_scale = false;
        let gum_curve =
            run_method(&problem, Box::new(gum), steps, 20, 0.02, 1);

        let tail = |c: &[(usize, f64)]| -> f64 {
            c[c.len() - 50..].iter().map(|(_, v)| v).sum::<f64>() / 50.0
        };
        let (m, ga, gu) =
            (tail(&muon_curve), tail(&galore_curve), tail(&gum_curve));
        let start = problem.adjusted_loss(&Matrix::zeros(20, 20));
        // Muon and GUM make real progress; GaLore barely moves.
        assert!(m < 0.2 * start, "muon tail {m} vs start {start}");
        assert!(gu < 0.3 * start, "gum tail {gu} vs start {start}");
        assert!(
            ga > 100.0 * gu.max(1e-9) && ga > 0.25 * start,
            "galore {ga} should stall vs gum {gu} (start {start})"
        );
    }
}
