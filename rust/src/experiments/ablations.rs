//! **E10 — ablations** over GUM's design choices (DESIGN.md §5):
//! projection rank r′, full-rank probability q, sampling period K,
//! projector type (SVD vs random = GoLore), and the compensation
//! variant (Algorithm 2 vs Appendix C.1). All on the Fig.-1 synthetic
//! problem where the bias mechanism is fully controlled.

use crate::model::{BlockKind, ParamBlock, ParamStore};
use crate::linalg::Matrix;
use crate::optim::{Compensation, Gum, Optimizer};
use crate::rng::derive_seed;
use crate::synthetic::NoisyLinReg;

use super::fig1::run_method;
use super::ExpOpts;

fn store(n: usize) -> ParamStore {
    ParamStore {
        blocks: vec![ParamBlock {
            name: "x".into(),
            shape: vec![n, n],
            kind: BlockKind::Projectable,
            value: Matrix::zeros(n, n),
        }],
    }
}

fn tail(curve: &[(usize, f64)]) -> f64 {
    let k = curve.len().saturating_sub(50);
    curve[k..].iter().map(|(_, v)| v).sum::<f64>() / (curve.len() - k) as f64
}

/// Convergence speed: first step with adjusted loss below `thresh`
/// (None = never reached).
fn steps_to(curve: &[(usize, f64)], thresh: f64) -> Option<usize> {
    curve.iter().find(|(_, v)| *v < thresh).map(|(s, _)| *s)
}

fn fmt_speed(curve: &[(usize, f64)]) -> String {
    match steps_to(curve, 1.0) {
        Some(s) => format!("tail {:.3}, reaches <1.0 at step {s}", tail(curve)),
        None => format!("tail {:.3}, never reaches <1.0", tail(curve)),
    }
}

fn gum_with(
    s: &ParamStore,
    rank: usize,
    q: f64,
    comp: Compensation,
    seed: u64,
) -> Box<dyn Optimizer> {
    let mut g = Gum::new(s, rank, q, 0.95, comp, seed);
    g.rms_scale = false;
    Box::new(g)
}

pub fn run(opts: &ExpOpts) -> anyhow::Result<()> {
    let steps = opts.steps.unwrap_or(if opts.quick { 600 } else { 2000 });
    let n = 20;
    let problem = NoisyLinReg::new(n, 12, 100.0, opts.seed);
    let s = store(n);
    let lr = 0.02;
    println!("Ablations on the Fig.-1 problem ({steps} steps, tail-50 loss)\n");

    println!("  (a) rank r′ sweep (q = 0.5):");
    for r in [1usize, 2, 4, 8] {
        let c = run_method(
            &problem,
            gum_with(&s, r, 0.5, Compensation::Paper, derive_seed(opts.seed, "a")),
            steps,
            20,
            lr,
            opts.seed,
        );
        println!("      r′ = {r}: {}", fmt_speed(&c));
    }

    println!("\n  (b) q sweep (r′ = 2): bias-variance of the debiasing");
    for q in [0.1, 0.3, 0.5, 0.7, 0.9] {
        let c = run_method(
            &problem,
            gum_with(&s, 2, q, Compensation::Paper, derive_seed(opts.seed, "b")),
            steps,
            20,
            lr,
            opts.seed,
        );
        println!("      q = {q}: {}", fmt_speed(&c));
    }

    println!("\n  (c) period K sweep (r′ = 2, q = 0.5):");
    for k in [5usize, 20, 100] {
        let c = run_method(
            &problem,
            gum_with(&s, 2, 0.5, Compensation::Paper, derive_seed(opts.seed, "c")),
            steps,
            k,
            lr,
            opts.seed,
        );
        println!("      K = {k}: {}", fmt_speed(&c));
    }

    println!("\n  (d) compensation variant (r′ = 2, q = 0.5):");
    for (name, comp) in [
        ("paper (Alg. 2)", Compensation::Paper),
        ("scaled (App. C.1)", Compensation::Scaled),
    ] {
        let c = run_method(
            &problem,
            gum_with(&s, 2, 0.5, comp, derive_seed(opts.seed, "d")),
            steps,
            20,
            lr,
            opts.seed,
        );
        println!("      {name}: {}", fmt_speed(&c));
    }

    println!(
        "\n  (e) projector type at matched memory: GaLore vs GoLore \
         (random) vs GUM — see `gum experiment fig1` (golore series)."
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn q_extremes_hurt() {
        // α = min(q, 1−q) drives Theorem 1: q = 0.5 should beat q = 0.05
        // on this noise-dominated problem.
        let problem = NoisyLinReg::new(20, 12, 100.0, 0);
        let s = store(20);
        let mid = tail(&run_method(
            &problem,
            gum_with(&s, 2, 0.5, Compensation::Paper, 1),
            1200,
            20,
            0.02,
            0,
        ));
        let low = tail(&run_method(
            &problem,
            gum_with(&s, 2, 0.05, Compensation::Paper, 1),
            1200,
            20,
            0.02,
            0,
        ));
        assert!(mid < low, "q=0.5 ({mid}) should beat q=0.05 ({low})");
    }
}
