//! **Theorem 1 / E9** — empirical convergence-rate validation: GUM's
//! min-gradient-norm vs T scaling on the noisy quadratic, and the α =
//! min{q, 1−q} dependence (sweeping q toward 0 or 1 should slow
//! convergence symmetrically).

use crate::linalg::Matrix;
use crate::model::{BlockKind, ParamBlock, ParamStore};
use crate::optim::{Compensation, Gum, Optimizer, StepCtx};
use crate::rng::{derive_seed, Pcg};
use crate::synthetic::Quadratic;

use super::ExpOpts;

fn store_for(n: usize) -> ParamStore {
    ParamStore {
        blocks: vec![ParamBlock {
            name: "w".into(),
            shape: vec![n, n],
            kind: BlockKind::Projectable,
            value: Matrix::zeros(n, n),
        }],
    }
}

/// min_t ‖∇f(W_t)‖ after T steps of GUM on the noisy quadratic.
pub fn min_grad_norm(
    n: usize,
    noise: f32,
    q: f64,
    t_steps: usize,
    lr: f32,
    seed: u64,
) -> f64 {
    let problem = Quadratic::new(n, n, noise, seed);
    let mut store = store_for(n);
    let mut gum = Gum::new(
        &store,
        2,
        q,
        0.9,
        Compensation::Paper,
        derive_seed(seed, "gum"),
    );
    gum.rms_scale = false;
    let mut rng = Pcg::new(derive_seed(seed, "noise"));
    let mut prng = Pcg::new(derive_seed(seed, "period"));
    let mut min_norm = f64::INFINITY;
    let k = 10;
    for step in 0..t_steps {
        let g = problem.grad(&store.blocks[0].value, &mut rng);
        if step % k == 0 {
            gum.begin_period(&store, std::slice::from_ref(&g), &mut prng);
        }
        gum.step(&mut store, std::slice::from_ref(&g), &StepCtx { lr, step });
        min_norm = min_norm.min(problem.grad_norm(&store.blocks[0].value));
    }
    min_norm
}

pub fn run(opts: &ExpOpts) -> anyhow::Result<()> {
    let n = 16;
    let noise = 2.0;
    println!("Theorem-1 validation on the noisy quadratic (n={n}, σ={noise})\n");

    println!("  (a) min‖∇f‖ vs T (q = 0.5): expect decreasing in T");
    let ts = if opts.quick {
        vec![100usize, 400]
    } else {
        vec![100, 400, 1600, 6400]
    };
    let mut prev = f64::INFINITY;
    for &t in &ts {
        // LR ∝ 1/√T per (12).
        let lr = 0.5 / (t as f32).sqrt();
        let v = min_grad_norm(n, noise, 0.5, t, lr, opts.seed);
        println!("    T = {t:>6}: min‖∇f‖ = {v:.4}");
        prev = prev.min(v);
    }

    println!("\n  (b) α-dependence: min‖∇f‖ vs q at fixed T (expect best near q=0.5)");
    for &q in &[0.05, 0.2, 0.5, 0.8, 0.95] {
        let t = if opts.quick { 400 } else { 2000 };
        let lr = 0.5 / (t as f32).sqrt();
        let v = min_grad_norm(n, noise, q, t, lr, opts.seed);
        println!("    q = {q:>5}: min‖∇f‖ = {v:.4}  (α = {:.2})", q.min(1.0 - q));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn more_steps_reach_smaller_grad_norm() {
        let short = min_grad_norm(12, 1.0, 0.5, 150, 0.04, 0);
        let long = min_grad_norm(12, 1.0, 0.5, 1500, 0.013, 0);
        assert!(
            long < short,
            "T=1500 ({long}) should beat T=150 ({short})"
        );
    }
}
