//! **Rank-schedule table** — fixed vs adaptive per-block rank at
//! matched memory, on a synthetic two-block task with mismatched
//! per-block spectral demand.
//!
//! Setting: two 20×20 projectable blocks with quadratic losses
//! ½‖W_b − T_b‖²_F. `w_hi`'s target has 12 equal nonzero singular
//! values (needs rank ≥ 12 to converge in one sweep); `w_lo`'s has 2.
//! The fixed schedule spends rank 8 on each block (total 16); the
//! adaptive controller starts there with the same total as its budget,
//! shrinks `w_lo` toward 2, and grows `w_hi` toward the spectrum's
//! demand — so at equal-or-lower projected optimizer-state bytes the
//! adaptive run matches or beats the fixed run's final loss. Invoke via
//! `gum experiment rank-schedule`.

use crate::coordinator::metrics::MetricsLog;
use crate::linalg::{fro_norm, Matrix};
use crate::model::{BlockKind, ParamBlock, ParamStore};
use crate::optim::{
    self, projected_state_bytes, AdaptiveRankCfg, RankSchedule,
    RefreshStrategy, StepCtx,
};
use crate::rng::{derive_seed, Pcg};

use super::ExpOpts;

const N: usize = 20;
const BASE_RANK: usize = 8;
const PERIOD_K: usize = 5;
const LR: f32 = 0.04;

/// Per-block target spectral demand: `w_hi` needs 12 directions,
/// `w_lo` needs 2.
const TARGET_RANKS: [usize; 2] = [12, 2];
const TARGET_SIGMA: f32 = 8.0;

fn two_block_store() -> ParamStore {
    ParamStore {
        blocks: vec![
            ParamBlock {
                name: "w_hi".into(),
                shape: vec![N, N],
                kind: BlockKind::Projectable,
                value: Matrix::zeros(N, N),
            },
            ParamBlock {
                name: "w_lo".into(),
                shape: vec![N, N],
                kind: BlockKind::Projectable,
                value: Matrix::zeros(N, N),
            },
        ],
    }
}

/// Diagonal rank-`k` target: exactly `k` singular values at
/// [`TARGET_SIGMA`], so the gradient spectrum the controller observes
/// is unambiguous.
fn target(k: usize) -> Matrix {
    let mut t = Matrix::zeros(N, N);
    for j in 0..k {
        t.data[j * N + j] = TARGET_SIGMA;
    }
    t
}

/// The adaptive configuration used throughout: energy capture 0.95,
/// clamps [2, 14], and a global budget equal to the fixed run's total
/// rank — the matched-memory comparison.
pub fn adaptive_cfg() -> AdaptiveRankCfg {
    AdaptiveRankCfg {
        energy: 0.95,
        deadband: 1,
        patience: 1,
        min_rank: 2,
        max_rank: 14,
        budget: 2 * BASE_RANK,
    }
}

/// Outcome of one schedule's run.
pub struct ScheduleRun {
    pub label: &'static str,
    pub final_loss: f64,
    /// Largest projected optimizer-state footprint over the run (the
    /// memory the schedule actually commits to).
    pub peak_proj_bytes: usize,
    /// Largest total rank the controller ever committed.
    pub peak_rank_total: usize,
    /// `(step, per-block ranks)` at each refresh boundary.
    pub rank_trajectory: Vec<(usize, Vec<usize>)>,
}

/// Train GUM (q = 0, exact refresh) for `steps` under `schedule` and
/// report final loss + rank/memory trajectory.
pub fn run_schedule(
    schedule: &RankSchedule,
    label: &'static str,
    steps: usize,
    seed: u64,
) -> anyhow::Result<ScheduleRun> {
    let mut store = two_block_store();
    let targets: Vec<Matrix> =
        TARGET_RANKS.iter().map(|&k| target(k)).collect();
    let mut opt = optim::build_with_schedule(
        "gum",
        &store,
        BASE_RANK,
        0.0, // γ = 0: no full-rank lanes, purely projected updates
        derive_seed(seed, "opt"),
        RefreshStrategy::ExactJacobi,
        schedule,
    )?;
    let mut rng = Pcg::new(derive_seed(seed, "period"));
    let mut peak_proj_bytes = 0usize;
    let mut peak_rank_total = 0usize;
    let mut rank_trajectory = Vec::new();
    for step in 0..steps {
        let grads: Vec<Matrix> = store
            .blocks
            .iter()
            .zip(&targets)
            .map(|(b, t)| b.value.sub(t))
            .collect();
        if step % PERIOD_K == 0 {
            opt.begin_period(&store, &grads, &mut rng);
            let ranks: Vec<usize> = match opt.rank_state() {
                Some(rs) => {
                    rs.ranks.iter().map(|&r| r as usize).collect()
                }
                None => store
                    .blocks
                    .iter()
                    .map(|b| match b.kind {
                        BlockKind::Projectable => BASE_RANK,
                        BlockKind::Dense => 0,
                    })
                    .collect(),
            };
            peak_rank_total =
                peak_rank_total.max(ranks.iter().sum::<usize>());
            peak_proj_bytes = peak_proj_bytes
                .max(projected_state_bytes(&store, &ranks, 1));
            rank_trajectory.push((step, ranks));
        }
        opt.step(&mut store, &grads, &StepCtx { lr: LR, step });
    }
    let final_loss: f64 = store
        .blocks
        .iter()
        .zip(&targets)
        .map(|(b, t)| {
            let r = fro_norm(&b.value.sub(t)) as f64;
            0.5 * r * r
        })
        .sum();
    Ok(ScheduleRun {
        label,
        final_loss,
        peak_proj_bytes,
        peak_rank_total,
        rank_trajectory,
    })
}

pub fn run(opts: &ExpOpts) -> anyhow::Result<()> {
    let steps = opts.steps.unwrap_or(if opts.quick { 160 } else { 240 });
    println!(
        "Rank-schedule comparison: two {N}×{N} blocks, target ranks \
         {TARGET_RANKS:?} (σ = {TARGET_SIGMA}), K = {PERIOD_K}, \
         lr = {LR}, steps = {steps}"
    );
    println!(
        "  fixed: r = {BASE_RANK}/block · adaptive: energy 0.95, \
         clamp [2, 14], budget {} (matched memory)",
        2 * BASE_RANK
    );

    let fixed =
        run_schedule(&RankSchedule::Fixed, "fixed", steps, opts.seed)?;
    let adaptive = run_schedule(
        &RankSchedule::Adaptive(adaptive_cfg()),
        "adaptive",
        steps,
        opts.seed,
    )?;

    let mut metrics = MetricsLog::new();
    println!(
        "\n  {:<10} {:>14} {:>16} {:>10}",
        "schedule", "final loss", "peak proj bytes", "peak Σr"
    );
    for run in [&fixed, &adaptive] {
        println!(
            "  {:<10} {:>14.6} {:>16} {:>10}",
            run.label,
            run.final_loss,
            run.peak_proj_bytes,
            run.peak_rank_total
        );
        metrics.push(steps, &format!("loss/{}", run.label), run.final_loss);
        metrics.push(
            steps,
            &format!("proj_bytes/{}", run.label),
            run.peak_proj_bytes as f64,
        );
        for (step, ranks) in &run.rank_trajectory {
            metrics.push(
                *step,
                &format!("rank_total/{}", run.label),
                ranks.iter().sum::<usize>() as f64,
            );
        }
    }
    let show = |run: &ScheduleRun| {
        let tail: Vec<String> = run
            .rank_trajectory
            .iter()
            .step_by((run.rank_trajectory.len() / 8).max(1))
            .map(|(s, r)| format!("{s}:{r:?}"))
            .collect();
        println!("  {} rank trajectory: {}", run.label, tail.join(" "));
    };
    show(&fixed);
    show(&adaptive);

    std::fs::create_dir_all(&opts.out_dir).ok();
    metrics.write_csv(&opts.out_dir.join("rank_schedule.csv"))?;
    println!(
        "  series → {}",
        opts.out_dir.join("rank_schedule.csv").display()
    );
    println!(
        "\n  check: adaptive ≤ fixed loss at ≤ memory — \
         loss {:.4} vs {:.4}, bytes {} vs {}",
        adaptive.final_loss,
        fixed.final_loss,
        adaptive.peak_proj_bytes,
        fixed.peak_proj_bytes
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance claim, as a test: at matched memory (adaptive
    /// budget = fixed total rank), the adaptive schedule matches or
    /// beats the fixed final loss without ever exceeding the fixed
    /// footprint.
    #[test]
    fn adaptive_matches_fixed_at_equal_or_lower_memory() {
        let steps = 240;
        let fixed =
            run_schedule(&RankSchedule::Fixed, "fixed", steps, 0).unwrap();
        let adaptive = run_schedule(
            &RankSchedule::Adaptive(adaptive_cfg()),
            "adaptive",
            steps,
            0,
        )
        .unwrap();
        assert!(
            adaptive.final_loss <= fixed.final_loss * 1.05 + 1e-6,
            "adaptive {} should match/beat fixed {}",
            adaptive.final_loss,
            fixed.final_loss
        );
        assert!(
            adaptive.peak_proj_bytes <= fixed.peak_proj_bytes,
            "adaptive peak {} bytes exceeds fixed {}",
            adaptive.peak_proj_bytes,
            fixed.peak_proj_bytes
        );
        // The budget is a hard ceiling on committed rank.
        assert!(
            adaptive.peak_rank_total <= 2 * BASE_RANK,
            "peak total rank {} exceeds budget {}",
            adaptive.peak_rank_total,
            2 * BASE_RANK
        );
        // The controller actually moved rank around (it did not just
        // sit at the uniform initialization).
        assert!(
            adaptive
                .rank_trajectory
                .iter()
                .any(|(_, r)| r != &vec![BASE_RANK, BASE_RANK]),
            "controller never deviated from the uniform init: {:?}",
            adaptive.rank_trajectory
        );
    }
}
