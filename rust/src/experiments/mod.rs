//! Experiment harnesses: one per paper table/figure (DESIGN.md §5).
//!
//! Each harness regenerates its artifact's rows/series, printing them in
//! the paper's format and writing CSV/JSON under `--out` for
//! EXPERIMENTS.md. Invoke via `gum experiment <id>`.

pub mod ablations;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod period_table;
pub mod rank_table;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod theory;

use std::path::PathBuf;

use crate::util::cli::Args;

/// Common experiment options parsed from the CLI.
#[derive(Debug, Clone)]
pub struct ExpOpts {
    pub out_dir: PathBuf,
    pub artifacts_dir: PathBuf,
    pub seed: u64,
    /// Scale factor for step counts (1 = EXPERIMENTS.md defaults; lower
    /// for smoke tests).
    pub steps: Option<usize>,
    pub quick: bool,
}

impl ExpOpts {
    pub fn from_args(args: &Args) -> ExpOpts {
        ExpOpts {
            out_dir: PathBuf::from(args.get_or("out", "results")),
            artifacts_dir: PathBuf::from(args.get_or("artifacts", "artifacts")),
            seed: args.get_parse("seed", 0u64),
            steps: args.get("steps").and_then(|s| s.parse().ok()),
            quick: args.has_flag("quick"),
        }
    }
}

/// Run an experiment by id.
pub fn run(id: &str, opts: &ExpOpts) -> anyhow::Result<()> {
    match id {
        "fig1" => fig1::run(opts),
        "fig2" => fig2::run(opts),
        "fig3" | "fig5" => fig3::run(opts),
        "fig4" => fig4::run(opts),
        "table1" => table1::run(opts),
        "table2" => table2::run(opts),
        "table3" => table3::run(opts),
        "table4" => table4::run(opts),
        "theory" => theory::run(opts),
        "ablations" => ablations::run(opts),
        "rank-schedule" => rank_table::run(opts),
        "period-schedule" => period_table::run(opts),
        "all" => {
            for id in [
                "table1", "table3", "fig1", "theory", "fig4", "table4",
                "fig2", "fig3", "table2", "ablations", "rank-schedule",
                "period-schedule",
            ] {
                println!("\n================ experiment {id} ================");
                run(id, opts)?;
            }
            Ok(())
        }
        other => anyhow::bail!(
            "unknown experiment '{other}' (have: fig1-5, table1-4, theory, \
             ablations, rank-schedule, period-schedule, all)"
        ),
    }
}
